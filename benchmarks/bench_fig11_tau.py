"""Fig. 11: execution time vs τ. Paper claim: Kyiv's time decreases
monotonically with τ (MINIT/MIWI initially *rise* — an algorithm artifact
Kyiv does not share)."""

from __future__ import annotations

import numpy as np

from repro.core import KyivConfig, mine, minit_minimal_infrequent
from repro.data.synth import pumsb_like

from .common import QUICK, Row, timed


def run(cfg=QUICK) -> tuple[list[Row], dict]:
    D = pumsb_like(n=cfg["domain_n"], m=10)
    taus = cfg["taus"] + [50]
    kmax = 3
    t_kyiv, t_minit = [], []
    for tau in taus:
        _, tk = timed(mine, D, KyivConfig(tau=tau, kmax=kmax))
        _, tm = timed(minit_minimal_infrequent, D, tau, kmax)
        t_kyiv.append(tk)
        t_minit.append(tm)
    # count the "initial rise" behaviour
    kyiv_rises = sum(1 for i in range(len(taus) - 1) if t_kyiv[i + 1] > t_kyiv[i] * 1.15)
    rows = [
        Row("fig11/kyiv_vs_tau", t_kyiv[0] * 1e6,
            f"taus={taus} t={[round(t, 3) for t in t_kyiv]} rises={kyiv_rises}"),
        Row("fig11/minit_vs_tau", t_minit[0] * 1e6,
            f"t={[round(t, 3) for t in t_minit]}"),
    ]
    return rows, {"taus": taus, "kyiv": t_kyiv, "minit": t_minit}


if __name__ == "__main__":
    from .common import emit

    emit(run()[0])
