"""Fig. 2: distribution of execution time and intersection time over
randomized datasets (paper: mean 280s total / 190s intersect = 68% at
k_max=5; the *fraction* is the validated claim at our scale)."""

from __future__ import annotations

import numpy as np

from repro.core import KyivConfig, mine
from repro.data.synth import randomized_dataset

from .common import QUICK, Row


def run(cfg=QUICK, seed0: int = 0) -> tuple[list[Row], dict]:
    totals, inters = [], []
    for r in range(cfg["rand_reps"]):
        D = randomized_dataset(cfg["rand_n"], cfg["rand_m"], seed=seed0 + r)
        res = mine(D, KyivConfig(tau=1, kmax=cfg["kmax"], engine="numpy"))
        totals.append(res.wall_time)
        inters.append(res.total_intersect_time)
    totals = np.asarray(totals)
    inters = np.asarray(inters)
    frac = inters.sum() / totals.sum()
    rows = [
        Row("fig2/exec_time_mean", totals.mean() * 1e6,
            f"std={totals.std():.3f}s reps={len(totals)}"),
        Row("fig2/intersect_time_mean", inters.mean() * 1e6,
            f"fraction_of_exec={frac:.2f} (paper: 0.68 @ kmax=5, higher for lower kmax)"),
    ]
    return rows, {"totals": totals.tolist(), "intersect": inters.tolist(), "fraction": frac}


if __name__ == "__main__":
    from .common import emit

    emit(run()[0])
