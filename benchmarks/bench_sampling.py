"""Sampled-mining fast path benchmark: approx first-response vs cold exact.

Two services over the same randomized table:

1. **cold exact** — a fresh ``MiningService.mine`` (preprocess + full
   Algorithm 1 over every row). This is what an exact ``/mine`` costs at
   this scale.
2. **approx first response** — a fresh service answering
   ``mine(mode="approx", epsilon=0.1)``: deterministic ε-sized row sample
   gathered from the store's word tiles, sample mine, per-itemset
   confidence classification. Acceptance: **>= 5x faster** than the cold
   exact mine at the 1M-row ``--full`` config.
3. **refinement** — the background job (boundary-band recount + exact
   promotion) is drained and the promoted answer must be **bit-identical**
   to the cold exact mine from step 1 — itemsets *and* counts.

Results append to ``BENCH_sampling.json`` next to this file (one record
per invocation) so the fast-path trajectory is tracked across PRs.
Default is a container-sized 50k-row table; ``--full`` is the 1M-row
acceptance config.
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.data.synth import randomized_dataset  # noqa: E402
from repro.service import MiningService  # noqa: E402

try:  # package-relative when run via benchmarks.run
    from .common import Row, emit
except ImportError:  # direct `python benchmarks/bench_sampling.py`
    from common import Row, emit  # type: ignore

OUT_PATH = os.path.join(os.path.dirname(__file__), "BENCH_sampling.json")

# the acceptance bar: approx first response at least this much faster
# than a cold exact mine of the same table
SPEEDUP_BAR = 5.0


def _canonical(result) -> list[tuple[tuple[int, ...], int]]:
    return sorted(
        (tuple(sorted(ids)), int(cnt)) for ids, cnt in result.itemsets
    )


def run(cfg=None, *, engine="numpy", n=None, m=None, tau=None, kmax=None,
        epsilon=0.1, full=False) -> tuple[list[Row], dict]:
    # the sampling bound is a function of m/ε, not n — the speedup therefore
    # *grows* with n; --full is the 1M-row acceptance config
    full = full or bool(cfg and cfg.get("rand_n", 0) >= 50_000)
    n = n or (1_000_000 if full else 50_000)
    m = m or 8
    tau = tau if tau is not None else (100 if full else 10)
    kmax = kmax or 2
    data = randomized_dataset(n, m, seed=0)

    rows: list[Row] = []
    record: dict = {
        "engine": engine, "n": n, "m": m, "tau": tau, "kmax": kmax,
        "epsilon": epsilon, "timestamp": time.time(),
        "platform": platform.platform(),
    }

    # cold exact baseline on its own service (nothing warm, nothing shared)
    exact_svc = MiningService.from_dataset(data, engine=engine)
    cold = exact_svc.mine(tau=tau, kmax=kmax)
    assert cold.source == "cold", cold.source
    exact_svc.close()

    # approx first response on a second fresh service over the same table
    svc = MiningService.from_dataset(data, engine=engine)
    approx = svc.mine(tau=tau, kmax=kmax, mode="approx", epsilon=epsilon)
    assert approx.source == "approx", approx.source

    # drain the background refinement (boundary recount + exact promotion)
    t0 = time.perf_counter()
    svc.scheduler.drain(timeout=max(600.0, 20 * cold.latency_s))
    refine_s = time.perf_counter() - t0
    refined = svc.mine(tau=tau, kmax=kmax, mode="approx", epsilon=epsilon)
    assert refined.info.get("refined") is True, refined.info
    assert refined.info.get("confidence") == 1.0, refined.info
    assert _canonical(refined.result) == _canonical(cold.result), (
        "refined approx answer is not bit-identical to the cold exact mine"
    )
    sampling_stats = svc.stats()["sampling"]
    svc.close()

    speedup = cold.latency_s / max(approx.latency_s, 1e-9)
    record.update(
        cold_exact_s=cold.latency_s,
        approx_first_response_s=approx.latency_s,
        approx_speedup=speedup,
        speedup_ge_5x=bool(speedup >= SPEEDUP_BAR),
        refine_drain_s=refine_s,
        refined_bit_identical=True,
        n_itemsets=cold.n_itemsets,
        confidence=approx.info["confidence"],
        boundary_count=approx.info["boundary_count"],
        sample_rows=approx.info["sample_rows"],
        sampler_seed=approx.info["seed"],
        recount_bucket_hits=sampling_stats["recount_bucket_hits"],
        recount_bucket_misses=sampling_stats["recount_bucket_misses"],
    )
    rows.append(Row("sampling/cold_exact", cold.latency_s * 1e6,
                    f"n_itemsets={cold.n_itemsets}"))
    rows.append(Row("sampling/approx_first_response",
                    approx.latency_s * 1e6,
                    f"speedup={speedup:.1f}x "
                    f"sample_rows={approx.info['sample_rows']}"))
    rows.append(Row("sampling/refine_to_exact", refine_s * 1e6,
                    f"boundary={approx.info['boundary_count']} "
                    f"bit_identical=True"))
    # the acceptance bar is asserted at scale: at toy sizes fixed overheads
    # (snapshot copy, preprocess) dominate both sides and the ratio is noise
    if n >= 500_000:
        assert speedup >= SPEEDUP_BAR, (
            f"approx first response only {speedup:.1f}x faster than cold "
            f"exact at n={n} (bar: {SPEEDUP_BAR}x)"
        )
    return rows, record


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true",
                    help="1M-row acceptance config")
    ap.add_argument("--engine", default="numpy")
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--m", type=int, default=None)
    ap.add_argument("--tau", type=int, default=None)
    ap.add_argument("--kmax", type=int, default=None)
    ap.add_argument("--epsilon", type=float, default=0.1)
    args = ap.parse_args()
    rows, record = run(engine=args.engine, n=args.n, m=args.m, tau=args.tau,
                       kmax=args.kmax, epsilon=args.epsilon, full=args.full)
    emit(rows)
    history = []
    if os.path.exists(OUT_PATH):
        with open(OUT_PATH) as f:
            history = json.load(f)
    history.append(record)
    with open(OUT_PATH, "w") as f:
        json.dump(history, f, indent=1)
    print(f"# appended run to {OUT_PATH}", file=sys.stderr)


if __name__ == "__main__":
    main()
