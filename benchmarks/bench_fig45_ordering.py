"""Figs. 4+5: impact of the L ordering (ascending / random / descending) and
of Lemma 4.6 / Corollary 4.7 on vertices visited and execution time.

Paper claims validated:
  * ascending visits ~2x fewer vertices than random, ~4x fewer than
    descending (Fig. 4);
  * type-A counts are ordering-invariant; type-B varies (Fig. 4);
  * bounds cut runtime substantially at k_max (>=50%-class on Connect/Pumsb,
    §5.3.2) — measured here as intersections avoided at the last level.
"""

from __future__ import annotations

import numpy as np

from repro.core import KyivConfig, mine
from repro.data.synth import randomized_dataset

from .common import QUICK, Row


def run(cfg=QUICK, seed0: int = 200) -> tuple[list[Row], dict]:
    reps = max(cfg["rand_reps"] // 2, 2)
    data = {}
    for ordering in ("ascending", "random", "descending"):
        for bounds in (True, False):
            verts_a, verts_tot, times, inters = [], [], [], []
            for r in range(reps):
                D = randomized_dataset(cfg["rand_n"], cfg["rand_m"], seed=seed0 + r)
                res = mine(
                    D,
                    KyivConfig(
                        tau=2, kmax=cfg["kmax"], ordering=ordering,
                        use_bounds=bounds, seed=r,
                    ),
                )
                a = sum(s.type_a for s in res.stats if s.k > 1)
                tot = sum(s.type_a + s.type_b + s.type_c for s in res.stats if s.k > 1)
                verts_a.append(a)
                verts_tot.append(tot)
                times.append(res.wall_time)
                inters.append(res.total_intersections)
            data[(ordering, bounds)] = {
                "A": float(np.mean(verts_a)),
                "total": float(np.mean(verts_tot)),
                "time": float(np.mean(times)),
                "intersections": float(np.mean(inters)),
            }

    asc = data[("ascending", True)]
    rnd = data[("random", True)]
    dsc = data[("descending", True)]
    nb = data[("ascending", False)]
    rows = [
        Row("fig4/vertices_ascending", asc["time"] * 1e6,
            f"total={asc['total']:.0f} A={asc['A']:.0f}"),
        Row("fig4/vertices_random", rnd["time"] * 1e6,
            f"total={rnd['total']:.0f} ratio_vs_asc={rnd['total'] / max(asc['total'], 1):.2f} (paper ~2)"),
        Row("fig4/vertices_descending", dsc["time"] * 1e6,
            f"total={dsc['total']:.0f} ratio_vs_asc={dsc['total'] / max(asc['total'], 1):.2f} (paper ~4)"),
        Row("fig4/type_A_invariance", 0.0,
            f"A asc/rnd/desc={asc['A']:.0f}/{rnd['A']:.0f}/{dsc['A']:.0f} (should match)"),
        # paper Fig 4 text: the bounds have LITTLE impact on randomized data —
        # zero saving here reproduces that observation.
        Row("fig5/bounds_randomized_saved", nb["time"] * 1e6,
            f"with={asc['intersections']:.0f} without={nb['intersections']:.0f} "
            f"saved={1 - asc['intersections'] / max(nb['intersections'], 1):.2%} "
            f"(paper: ~none on randomized data)"),
    ]

    # §5.3.2: on Connect-family data the bounds cut >=50%-class of the work
    # at k_max (paper: 269s -> 130s on Connect at kmax=6).
    from repro.data.synth import connect_like

    Dc = connect_like(n=cfg["domain_n"], m=12)
    res_b = mine(Dc, KyivConfig(tau=1, kmax=cfg["kmax"], use_bounds=True))
    res_nb = mine(Dc, KyivConfig(tau=1, kmax=cfg["kmax"], use_bounds=False))
    last_b = [s for s in res_b.stats if s.k == cfg["kmax"]][0]
    last_nb = [s for s in res_nb.stats if s.k == cfg["kmax"]][0]
    saved = 1 - last_b.intersections / max(last_nb.intersections, 1)
    rows.append(
        Row("fig5/bounds_connect_kmax_saved", res_nb.wall_time * 1e6,
            f"kmax-level intersections with={last_b.intersections} "
            f"without={last_nb.intersections} saved={saved:.2%} "
            f"time {res_nb.wall_time:.2f}s -> {res_b.wall_time:.2f}s "
            f"(paper §5.3.2: >=50%-class on Connect)")
    )
    data["connect_bounds"] = {
        "saved_frac": saved,
        "t_with": res_b.wall_time,
        "t_without": res_nb.wall_time,
    }
    return rows, {f"{k[0]}_bounds={k[1]}" if isinstance(k, tuple) else k: v
                  for k, v in data.items()}


if __name__ == "__main__":
    from .common import emit

    emit(run()[0])
