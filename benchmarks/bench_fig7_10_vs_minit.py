"""Figs. 7-10: Kyiv vs MINIT across the four domain datasets (structural
synthetic analogues — see data/synth.py) for k_max sweeps at several τ.

Validated claim: Kyiv consistently outperforms MINIT across datasets, k_max,
and τ (paper: 2-33x)."""

from __future__ import annotations

import time

import numpy as np

from repro.core import KyivConfig, mine, minit_minimal_infrequent
from repro.data.synth import connect_like, poker_like, pumsb_like, uscensus_like

from .common import QUICK, Row, timed


def run(cfg=QUICK, quick_scale: bool = True) -> tuple[list[Row], dict]:
    n = cfg["domain_n"]
    datasets = {
        "connect": connect_like(n=n, m=12 if quick_scale else 43),
        "pumsb": pumsb_like(n=n, m=12 if quick_scale else 74),
        "poker": poker_like(n=n, m=10),
        "uscensus": uscensus_like(n=min(n, 2000) if quick_scale else 200_000,
                                  m=10 if quick_scale else 68),
    }
    rows, raw = [], {}
    for name, D in datasets.items():
        for tau in (1, cfg["taus"][-1]):
            kmax = min(cfg["minit_kmax"], 4)
            res, t_kyiv = timed(mine, D, KyivConfig(tau=tau, kmax=kmax))
            got_minit, t_minit = timed(minit_minimal_infrequent, D, tau, kmax)
            assert res.canonical_set() == got_minit, f"{name} tau={tau} mismatch!"
            speedup = t_minit / max(t_kyiv, 1e-9)
            rows.append(
                Row(f"fig7_10/{name}_tau{tau}", t_kyiv * 1e6,
                    f"kyiv={t_kyiv:.3f}s minit={t_minit:.3f}s speedup={speedup:.1f}x "
                    f"results={len(res.itemsets)}")
            )
            raw[f"{name}_tau{tau}"] = {
                "kyiv_s": t_kyiv, "minit_s": t_minit, "speedup": speedup,
                "n_results": len(res.itemsets),
            }
    return rows, raw


if __name__ == "__main__":
    from .common import emit

    emit(run()[0])
