"""Shared benchmark utilities: scaled dataset configs + CSV emission.

All mining benches follow the paper's experimental design at a scale that
fits this single-core CPU container (the paper used 50k x 25 randomized
datasets and a 32-thread Xeon; we default to 2000 x 10 and note the scale in
EXPERIMENTS.md). ``--full`` on benchmarks.run selects paper-scale settings.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

QUICK = {
    "rand_n": 2000,
    "rand_m": 10,
    "rand_reps": 5,
    "kmax": 4,
    "minit_kmax": 4,
    "scale_n": [500, 1000, 2000, 4000, 8000],
    "scale_m": [4, 6, 8, 10, 12],
    "domain_n": 4000,
    "taus": [1, 5, 10],
}

FULL = {
    "rand_n": 50_000,
    "rand_m": 25,
    "rand_reps": 50,
    "kmax": 5,
    "minit_kmax": 5,
    "scale_n": [62_500, 125_000, 250_000, 500_000, 1_000_000],
    "scale_m": [10, 20, 30, 40],
    "domain_n": 49_046,
    "taus": [1, 5, 10, 100],
}


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float
    derived: str

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.1f},{self.derived}"


def timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, time.perf_counter() - t0


def emit(rows: list[Row]) -> None:
    print("name,us_per_call,derived")
    for r in rows:
        print(r.csv())
