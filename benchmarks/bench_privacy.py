"""Privacy risk engine benchmark: coverage kernels vs the python loop, and
planner end-to-end time.

Two measurements on one synthetic *exposed* table (frequent background with
planted singleton- and pair-quasi-identifiers, ``data.synth.exposed_dataset``
— the shape where QI counts scale linearly with rows, so the bench runs at
the criterion's 100k rows without the τ=1 QI explosion of the fully
randomized table):

1. **coverage** — per-record risk profiling of a mined result. Baseline is
   the seed implementation of ``sdc.quasi.unique_records``: a Python loop
   over itemsets with per-word bit twiddling to expand each QI's row set.
   The engine path batches every QI through ``kernels.coverage``
   (AND + bit-plane accumulation, numpy/jnp engines here; Pallas and mesh
   are covered by the tests). Acceptance: **>= 10x** over the python loop at
   100k rows. The engine's answers are asserted identical to the loop's.
2. **planner** — ``plan_anonymization`` end-to-end (greedy weighted set
   cover + verification re-mines until zero residual QIs), recorded for the
   trajectory; the plan must verify.

Results are appended to ``BENCH_privacy.json`` next to this file (a list of
runs, one per invocation). Default is the criterion-sized 100k-row config;
``--n`` scales it down for CI smoke.
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.core import KyivConfig, mine  # noqa: E402
from repro.core.placement import make_placement  # noqa: E402
from repro.data.synth import exposed_dataset  # noqa: E402
from repro.privacy import apply_plan, mine_masked, plan_anonymization  # noqa: E402
from repro.privacy.risk import risk_profile  # noqa: E402

try:  # package-relative when run via benchmarks.run
    from .common import Row, emit
except ImportError:  # direct `python benchmarks/bench_privacy.py`
    from common import Row, emit  # type: ignore

OUT_PATH = os.path.join(os.path.dirname(__file__), "BENCH_privacy.json")


def _bits_to_rows_slow(bits_row: np.ndarray) -> np.ndarray:
    """The seed repo's per-word Python bit twiddling (pre-vectorisation)."""
    out = []
    for w, word in enumerate(np.asarray(bits_row, dtype=np.uint32)):
        word = int(word)
        base = w * 32
        while word:
            lsb = word & -word
            out.append(base + lsb.bit_length() - 1)
            word ^= lsb
    return np.asarray(out, dtype=np.int64)


def python_loop_profile(result) -> tuple[int, np.ndarray]:
    """Seed-style record profiling: per-itemset AND + Python row expansion
    (exactly the old ``sdc.quasi.unique_records`` loop, plus the per-record
    counter the risk engine also produces)."""
    table = result.prep.table
    hit = np.zeros(table.n_rows, dtype=bool)
    qi_count = np.zeros(table.n_rows, dtype=np.int64)
    for ids, _ in result.itemsets:
        m = table.bits[ids[0]].copy()
        for i in ids[1:]:
            m &= table.bits[i]
        rows = _bits_to_rows_slow(m)
        hit[rows] = True
        qi_count[rows] += 1
    return int(hit.sum()), qi_count


def run(*, n=100_000, m=6, tau=1, kmax=3, planner_n=None, seed=0):
    dataset = exposed_dataset(n, m, seed=seed)
    rows: list[Row] = []
    record: dict = {
        "n": n, "m": m, "tau": tau, "kmax": kmax,
        "timestamp": time.time(), "platform": platform.platform(),
    }

    t0 = time.perf_counter()
    result = mine(dataset, KyivConfig(tau=tau, kmax=kmax, engine="numpy"))
    mine_s = time.perf_counter() - t0
    record["mine_s"] = mine_s
    record["n_qis"] = len(result.itemsets)
    rows.append(Row("privacy/mine", mine_s * 1e6, f"n_qis={len(result.itemsets)}"))

    t0 = time.perf_counter()
    loop_unique, loop_counts = python_loop_profile(result)
    loop_s = time.perf_counter() - t0
    record["python_loop_s"] = loop_s
    rows.append(Row("privacy/python_loop", loop_s * 1e6, f"unique={loop_unique}"))

    for engine in ("numpy", "jnp"):
        placement = make_placement(engine if engine != "numpy" else "host")
        t0 = time.perf_counter()
        prof = risk_profile(result, placement=placement)
        cov_s = time.perf_counter() - t0
        assert prof.records_at_risk == loop_unique, (prof.records_at_risk, loop_unique)
        assert np.array_equal(prof.qi_count, loop_counts)
        speedup = loop_s / max(cov_s, 1e-9)
        record[f"coverage_{engine}_s"] = cov_s
        record[f"coverage_{engine}_speedup"] = speedup
        rows.append(
            Row(f"privacy/coverage_{engine}", cov_s * 1e6, f"speedup={speedup:.1f}x")
        )
    best = max(record["coverage_numpy_speedup"], record["coverage_jnp_speedup"])
    record["criterion"] = ">=10x over python loop at 100k rows"
    record["speedup_ge_10x"] = bool(best >= 10.0)

    # planner end-to-end (smaller table: it re-mines per verification round)
    planner_n = planner_n or max(n // 5, 1000)
    pdata = exposed_dataset(planner_n, m, seed=seed + 1)
    t0 = time.perf_counter()
    plan = plan_anonymization(pdata, tau=tau, kmax=kmax)
    plan_s = time.perf_counter() - t0
    assert plan.verified, "planner failed to verify zero residual QIs"
    post = mine_masked(apply_plan(pdata, plan), KyivConfig(tau=tau, kmax=kmax))
    assert post is None or len(post.itemsets) == 0
    record["planner"] = {
        "n": planner_n,
        "m": m,
        "seconds": plan_s,
        "rounds": plan.rounds,
        "initial_qis": plan.initial_qis,
        "cells_suppressed": plan.cells_suppressed,
        "generalized_columns": plan.generalized_columns,
    }
    rows.append(
        Row(
            "privacy/planner_e2e",
            plan_s * 1e6,
            f"n={planner_n} rounds={plan.rounds} cells={plan.cells_suppressed}",
        )
    )
    return rows, record


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=100_000)
    ap.add_argument("--m", type=int, default=6)
    ap.add_argument("--tau", type=int, default=1)
    ap.add_argument("--kmax", type=int, default=3)
    ap.add_argument("--planner-n", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    rows, record = run(
        n=args.n, m=args.m, tau=args.tau, kmax=args.kmax,
        planner_n=args.planner_n, seed=args.seed,
    )
    emit(rows)

    history = []
    if os.path.exists(OUT_PATH):
        with open(OUT_PATH) as f:
            history = json.load(f)
    history.append(record)
    with open(OUT_PATH, "w") as f:
        json.dump(history, f, indent=2)
    print(f"wrote {OUT_PATH}")
    print(
        f"PRIVACY_BENCH n={args.n} qis={record['n_qis']} "
        f"loop={record['python_loop_s']:.2f}s "
        f"numpy={record['coverage_numpy_s']:.3f}s "
        f"({record['coverage_numpy_speedup']:.0f}x) "
        f"ge_10x={record['speedup_ge_10x']} "
        f"planner={record['planner']['seconds']:.2f}s"
    )


if __name__ == "__main__":
    main()
