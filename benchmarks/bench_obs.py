"""Flight-recorder overhead benchmark: forensics must be ~free.

Two fresh ``MiningService`` instances over the same randomized table and
the same ``wal_dir``-style durability setup, differing only in
``flight_enabled``. Each performs the identical cold exact mine
(preprocess + full Algorithm 1); the recorder side additionally persists
span open/close events, level checkpoints and config through the
CRC-framed flight ring with its batched-fsync cadence.

Acceptance: median recorder-on wall time within **5%** of recorder-off on
the 100k-row config (the cost-envelope accounting runs on both sides —
it is part of every mine now; the knob under test is the on-disk ring).

Results append to ``BENCH_obs.json`` next to this file (one record per
invocation) so the overhead trajectory is tracked across PRs.
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import shutil
import statistics
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.data.synth import randomized_dataset  # noqa: E402
from repro.service import MiningService  # noqa: E402

try:  # package-relative when run via benchmarks.run
    from .common import Row, emit
except ImportError:  # direct `python benchmarks/bench_obs.py`
    from common import Row, emit  # type: ignore

OUT_PATH = os.path.join(os.path.dirname(__file__), "BENCH_obs.json")

# the acceptance bar: flight recording costs at most this fraction of a
# cold mine's wall time
OVERHEAD_BAR = 0.05


def _cold_mine_s(data, tau, kmax, *, flight: bool) -> tuple[float, dict]:
    """One fresh durable service, one cold mine, cleanup. Returns the
    service-measured wall latency and the recorder's own stats."""
    d = tempfile.mkdtemp(prefix="bench_obs_")
    try:
        svc = MiningService(
            engine="numpy",
            wal_dir=d,
            flight_enabled=flight,
            slow_mine_threshold_s=float("inf"),
        )
        svc.append(data)
        r = svc.mine(tau=tau, kmax=kmax)
        assert r.source == "cold", r.source
        fstats = svc.flight.stats() if svc.flight is not None else {}
        svc.close()
        return r.latency_s, fstats
    finally:
        shutil.rmtree(d, ignore_errors=True)


def run(cfg=None, *, n=None, m=None, tau=None, kmax=None, repeats=3,
        full=False) -> tuple[list[Row], dict]:
    full = full or bool(cfg and cfg.get("rand_n", 0) >= 50_000)
    n = n or (100_000 if full else 20_000)
    m = m or 8
    tau = tau if tau is not None else max(2, n // 1000)
    kmax = kmax or 3
    data = randomized_dataset(n, m, seed=0)

    off: list[float] = []
    on: list[float] = []
    fstats: dict = {}
    # one untimed warmup mine: process-level costs (allocator arenas, LUTs,
    # import side effects) land here instead of skewing the first arm
    _cold_mine_s(data, tau, kmax, flight=False)
    # interleave the arms so drift (page cache, CPU frequency) hits both
    for _ in range(repeats):
        t, _ = _cold_mine_s(data, tau, kmax, flight=False)
        off.append(t)
        t, fstats = _cold_mine_s(data, tau, kmax, flight=True)
        on.append(t)

    base = statistics.median(off)
    with_flight = statistics.median(on)
    overhead = with_flight / max(base, 1e-9) - 1.0
    record = {
        "n": n, "m": m, "tau": tau, "kmax": kmax, "repeats": repeats,
        "timestamp": time.time(), "platform": platform.platform(),
        "cold_mine_s_no_flight": base,
        "cold_mine_s_with_flight": with_flight,
        "overhead_frac": overhead,
        "overhead_le_5pct": bool(overhead <= OVERHEAD_BAR),
        "flight_events": fstats.get("events_recorded"),
        "flight_flushes": fstats.get("flushes"),
        "flight_bytes": fstats.get("bytes_written"),
    }
    rows = [
        Row("obs/cold_mine_no_flight", base * 1e6, f"n={n}"),
        Row("obs/cold_mine_with_flight", with_flight * 1e6,
            f"overhead={overhead * 100:.1f}% "
            f"events={fstats.get('events_recorded')}"),
    ]
    # assert at scale only: at toy sizes a cold mine is milliseconds and
    # scheduler/thread jitter alone exceeds the bar
    if n >= 100_000:
        assert overhead <= OVERHEAD_BAR, (
            f"flight recorder costs {overhead * 100:.1f}% of a cold mine "
            f"at n={n} (bar: {OVERHEAD_BAR * 100:.0f}%)"
        )
    return rows, record


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true",
                    help="100k-row acceptance config")
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--m", type=int, default=None)
    ap.add_argument("--tau", type=int, default=None)
    ap.add_argument("--kmax", type=int, default=None)
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args()
    rows, record = run(n=args.n, m=args.m, tau=args.tau, kmax=args.kmax,
                       repeats=args.repeats, full=args.full)
    emit(rows)
    history = []
    if os.path.exists(OUT_PATH):
        with open(OUT_PATH) as f:
            history = json.load(f)
    history.append(record)
    with open(OUT_PATH, "w") as f:
        json.dump(history, f, indent=1)
    print(f"# appended run to {OUT_PATH}", file=sys.stderr)


if __name__ == "__main__":
    main()
