"""Fig. 12: memory consumption vs k_max. The paper's claim: memory is
dominated by level (itemset-rows) storage, with an 'equator' level where the
stored level peaks; when k = k_max only one level is held."""

from __future__ import annotations

import numpy as np

from repro.core import KyivConfig, mine
from repro.data.synth import pumsb_like

from .common import QUICK, Row


def run(cfg=QUICK) -> tuple[list[Row], dict]:
    D = pumsb_like(n=cfg["domain_n"], m=10)
    rows, raw = [], {}
    for kmax in range(2, cfg["kmax"] + 2):
        res = mine(D, KyivConfig(tau=1, kmax=kmax))
        peak = res.peak_level_bytes
        per_level = {s.k: s.level_bytes for s in res.stats}
        rows.append(
            Row(f"fig12/kmax{kmax}_peak_bytes", peak,
                f"levels={ {k: v for k, v in sorted(per_level.items())} }")
        )
        raw[kmax] = {"peak": peak, "levels": per_level}
    return rows, raw


if __name__ == "__main__":
    from .common import emit

    emit(run()[0])
