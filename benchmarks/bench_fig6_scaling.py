"""Fig. 6: execution time vs rows n (expected ~linear) and vs columns m
(expected ~exponential), plus memory growth (§5.2.5)."""

from __future__ import annotations

import numpy as np

from repro.core import KyivConfig, mine
from repro.data.synth import randomized_dataset

from .common import QUICK, Row


def run(cfg=QUICK, seed: int = 300) -> tuple[list[Row], dict]:
    kmax = 3
    rows = []
    # vs n (fixed m). The paper takes prefixes of one fixed dataset whose
    # prefix-tree size has *saturated* (1M rows, every item everywhere) so
    # runtime ∝ row-set length ∝ n. A small domain puts our scaled bench in
    # the same saturated regime.
    m = cfg["scale_m"][2]
    base = randomized_dataset(max(cfg["scale_n"]), m, d_low=6, d_high=14, seed=seed)
    t_n = []
    for n in cfg["scale_n"]:
        res = mine(base[:n], KyivConfig(tau=1, kmax=kmax))
        t_n.append((n, res.wall_time, res.peak_level_bytes))
    # linearity: time per row roughly constant
    per_row = [t / n for n, t, _ in t_n]
    lin = max(per_row) / max(min(per_row), 1e-12)
    rows.append(
        Row("fig6a/time_vs_n", t_n[-1][1] * 1e6,
            f"n={[x[0] for x in t_n]} t={[round(x[1], 3) for x in t_n]} "
            f"per_row_spread={lin:.2f}x (≈linear)")
    )
    # vs m (fixed n)
    n = cfg["scale_n"][2]
    wide = randomized_dataset(n, max(cfg["scale_m"]), seed=seed + 1)
    t_m = []
    for mm in cfg["scale_m"]:
        res = mine(wide[:, :mm], KyivConfig(tau=1, kmax=kmax))
        t_m.append((mm, res.wall_time, res.peak_level_bytes))
    ratios = [t_m[i + 1][1] / max(t_m[i][1], 1e-9) for i in range(len(t_m) - 1)]
    rows.append(
        Row("fig6b/time_vs_m", t_m[-1][1] * 1e6,
            f"m={[x[0] for x in t_m]} t={[round(x[1], 3) for x in t_m]} "
            f"growth_ratios={[round(r, 2) for r in ratios]} (superlinear)")
    )
    rows.append(
        Row("fig6/memory_vs_m", t_m[-1][2],
            f"peak_level_bytes={[x[2] for x in t_m]}")
    )
    return rows, {"vs_n": t_n, "vs_m": t_m}


if __name__ == "__main__":
    from .common import emit

    emit(run()[0])
