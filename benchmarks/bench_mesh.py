"""Multi-host fleet benchmark: N-process localhost DCN ring vs single host.

Spawns ``--nproc`` real processes joined through ``jax.distributed`` on a
localhost coordinator, each holding its word stripe of the store behind a
``FleetPlacement``, and drives the same append / cold-mine / append /
incremental-mine sequence through the process-0 ``FleetFrontend`` that the
single-process baseline runs directly. Records, per process:

* store shape — rows, local words vs global words (the stripe ratio),
* collective cost — rounds, seconds, payload bytes from
  ``Collective.stats()`` (the *only* cross-host traffic in a mine),
* the launch environment (``launch_env_summary()``: XLA flags, allocator
  preload) so every number carries the config that produced it,

plus fleet-level rows: cold/incremental mine wall time against the
single-process baseline, level throughput (levels and itemsets per
second), and a bit-identity check of the mined itemsets — the fleet is a
perf configuration, never an accuracy trade.

Appends one record to ``BENCH_frontier.json`` (the level-scaling history
file) tagged ``"bench": "mesh"`` — the multi-host scaling row next to the
single-host frontier rows.
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import socket
import subprocess
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

try:  # package-relative when run via benchmarks.run
    from .common import Row, emit
except ImportError:  # direct `python benchmarks/bench_mesh.py`
    from common import Row, emit  # type: ignore

OUT_PATH = os.path.join(os.path.dirname(__file__), "BENCH_frontier.json")
_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


# Worker body: argv = [pid, nproc, port, src, n, m, vals, delta_n, tau, kmax]
_WORKER = r"""
import json, sys, time
import numpy as np
sys.path.insert(0, sys.argv[4])
pid, nproc, port = int(sys.argv[1]), int(sys.argv[2]), sys.argv[3]
n, m, vals, delta_n = (int(a) for a in sys.argv[5:9])
tau, kmax = int(sys.argv[9]), int(sys.argv[10])
import jax
jax.distributed.initialize(f"localhost:{port}", nproc, pid)
from repro.core.collective import FleetCollective
from repro.core.fleet import FleetPlacement
from repro.core.placement import HostPlacement
from repro.core.preprocess import set_row_group_collective
from repro.launch.mesh import launch_env_summary
from repro.service import FleetFrontend, MiningService, serve_fleet_peer

fc = FleetCollective(timeout_s=120.0)
set_row_group_collective(fc)
svc = MiningService(placement=FleetPlacement(HostPlacement(), collective=fc))
rng = np.random.default_rng(23)
rows = rng.integers(0, vals, size=(n, m))
delta = rng.integers(0, vals, size=(delta_n, m))

if pid != 0:
    out = serve_fleet_peer(svc, fc)
    st = svc.store.stats()
    print(json.dumps({
        "pid": pid, "peer": out,
        "store": {k: st[k] for k in ("n_rows", "n_words", "n_words_global", "shard")},
        "collective": fc.stats(),
        "env": launch_env_summary(),
    }), flush=True)
    sys.exit(0)

front = FleetFrontend(svc, fc)  # no shadow: a bench failure should be loud
t0 = time.perf_counter(); front.append(rows); t_append = time.perf_counter() - t0
t0 = time.perf_counter(); r1 = front.mine(tau=tau, kmax=kmax); t_cold = time.perf_counter() - t0
front.append(delta)
t0 = time.perf_counter(); r2 = front.mine(tau=tau, kmax=kmax); t_inc = time.perf_counter() - t0
st = svc.store.stats()
front.close()
print(json.dumps({
    "pid": 0,
    "t_append_s": t_append, "t_cold_mine_s": t_cold, "t_inc_mine_s": t_inc,
    "r2_source": r2.source,
    "n_itemsets": len(r1.result.itemsets),
    "itemsets_sha": __import__("hashlib").sha256(
        repr(sorted((tuple(map(int, i)), int(c))
                    for i, c in r1.result.itemsets)).encode()).hexdigest(),
    "store": {k: st[k] for k in ("n_rows", "n_words", "n_words_global", "shard")},
    "collective": fc.stats(),
    "env": launch_env_summary(),
}), flush=True)
"""


def _spawn(pid: int, nproc: int, port: int, shape) -> subprocess.Popen:
    env = dict(os.environ, JAX_PLATFORMS="cpu")
    # the forced-device-count flag from mesh CI jobs confuses distributed
    # init on CPU; the per-worker env summary records whatever survives
    env.pop("XLA_FLAGS", None)
    argv = [sys.executable, "-c", _WORKER, str(pid), str(nproc), str(port), _SRC]
    argv += [str(x) for x in shape]
    return subprocess.Popen(
        argv, stdout=subprocess.PIPE, stderr=subprocess.PIPE, env=env, text=True
    )


def _baseline(n, m, vals, delta_n, tau, kmax):
    """Single-process reference: same data, same sequence, plain service."""
    import hashlib

    from repro.service import MiningService

    rng = np.random.default_rng(23)
    rows = rng.integers(0, vals, size=(n, m))
    delta = rng.integers(0, vals, size=(delta_n, m))
    svc = MiningService(engine="numpy")
    svc.append(rows)
    t0 = time.perf_counter(); r1 = svc.mine(tau=tau, kmax=kmax)
    t_cold = time.perf_counter() - t0
    svc.append(delta)
    t0 = time.perf_counter(); svc.mine(tau=tau, kmax=kmax)
    t_inc = time.perf_counter() - t0
    sha = hashlib.sha256(
        repr(sorted((tuple(map(int, i)), int(c))
                    for i, c in r1.result.itemsets)).encode()
    ).hexdigest()
    svc.close()
    return {"t_cold_mine_s": t_cold, "t_inc_mine_s": t_inc,
            "n_itemsets": len(r1.result.itemsets), "itemsets_sha": sha}


def run(*, nproc=2, n=4000, m=8, vals=6, delta_n=400, tau=40, kmax=3,
        timeout_s=600):
    port = _free_port()
    shape = (n, m, vals, delta_n, tau, kmax)
    procs = [_spawn(p, nproc, port, shape) for p in range(nproc)]
    outs = []
    for p in procs:
        so, se = p.communicate(timeout=timeout_s)
        if p.returncode != 0:
            raise RuntimeError(f"fleet worker failed:\n{se[-3000:]}")
        outs.append(json.loads(so.strip().splitlines()[-1]))
    o0 = next(o for o in outs if o["pid"] == 0)
    base = _baseline(n, m, vals, delta_n, tau, kmax)
    if o0["itemsets_sha"] != base["itemsets_sha"]:
        raise RuntimeError("fleet mine is not bit-identical to single-process")
    if o0["r2_source"] != "incremental":
        raise RuntimeError(f"fleet repeat mine took {o0['r2_source']!r} path")

    levels_per_s = kmax / max(o0["t_cold_mine_s"], 1e-12)
    sets_per_s = o0["n_itemsets"] / max(o0["t_cold_mine_s"], 1e-12)
    rows_out = [
        Row("mesh/fleet_cold_mine", o0["t_cold_mine_s"] * 1e6,
            f"nproc={nproc} single={base['t_cold_mine_s']:.3f}s"),
        Row("mesh/fleet_incremental_mine", o0["t_inc_mine_s"] * 1e6,
            f"nproc={nproc} single={base['t_inc_mine_s']:.3f}s"),
        Row("mesh/level_throughput", 1e6 / max(levels_per_s, 1e-12),
            f"levels/s={levels_per_s:.2f} itemsets/s={sets_per_s:.0f}"),
        Row("mesh/collective", o0["collective"]["seconds"] * 1e6,
            f"rounds={o0['collective']['rounds']} "
            f"bytes={o0['collective']['payload_bytes']}"),
    ]
    record = {
        "meta": {
            "bench": "mesh", "nproc": nproc, "n": n, "m": m, "vals": vals,
            "delta_n": delta_n, "tau": tau, "kmax": kmax,
            "timestamp": time.time(), "platform": platform.platform(),
            "numpy": np.__version__,
        },
        "fleet": {
            "bit_identical": True,
            "cold_mine_s": o0["t_cold_mine_s"],
            "incremental_mine_s": o0["t_inc_mine_s"],
            "levels_per_s": levels_per_s,
            "itemsets_per_s": sets_per_s,
            "n_itemsets": o0["n_itemsets"],
            "processes": [
                {
                    "pid": o["pid"],
                    "store": o["store"],  # rows + local/global words per host
                    "collective": o["collective"],
                    "env": o["env"],  # XLA flags / allocator per host
                }
                for o in sorted(outs, key=lambda o: o["pid"])
            ],
        },
        "single_process": base,
    }
    return rows_out, record


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--nproc", type=int, default=2)
    ap.add_argument("--n", type=int, default=4000)
    ap.add_argument("--m", type=int, default=8)
    ap.add_argument("--vals", type=int, default=6)
    ap.add_argument("--delta-n", type=int, default=400)
    ap.add_argument("--tau", type=int, default=40)
    ap.add_argument("--kmax", type=int, default=3)
    ap.add_argument("--timeout-s", type=int, default=600)
    args = ap.parse_args()
    rows, record = run(
        nproc=args.nproc, n=args.n, m=args.m, vals=args.vals,
        delta_n=args.delta_n, tau=args.tau, kmax=args.kmax,
        timeout_s=args.timeout_s,
    )
    emit(rows)
    history = []
    if os.path.exists(OUT_PATH):
        with open(OUT_PATH) as f:
            history = json.load(f)
    history.append(record)
    with open(OUT_PATH, "w") as f:
        json.dump(history, f, indent=2)
    print(f"wrote {OUT_PATH} ({len(history)} run(s))")


if __name__ == "__main__":
    main()
