"""Frontier benchmark: host vs device candidate-gen + support-test per level.

Two measurements, both appended to ``BENCH_frontier.json``:

* **level micro-bench** — a synthetic prefix-grouped level table (sized like
  the wide levels of the paper-scale configs) is pushed through one full
  frontier stage per path: the host reference
  (``generate_candidates`` + packed-key ``support_test`` numpy) vs the
  device frontier (``repeat``/``cumsum`` pair gen + packed-key binary
  search + pruned-pair masking, jit-compiled, warmed). This isolates
  exactly the work the tentpole moved off the host.
* **end-to-end** — ``mine()`` on the randomized dataset config with
  ``device_frontier`` on vs off for each device engine, recording
  ``LevelStats.time_candidates`` (candidate gen + support + bounds) and the
  per-level host-busy / device-busy split.

Default is a container-sized config; ``--full`` selects the paper-scale
million-row config (the acceptance target: >=3x faster candidate-gen +
support-test per level on the device path, measured on a real accelerator
host — interpret-mode CPU numbers are recorded for trend only).
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.core import KyivConfig, mine  # noqa: E402
from repro.core.placement import make_placement  # noqa: E402
from repro.core.prefix import iter_group_spans, prefix_group_sizes  # noqa: E402
from repro.data.synth import randomized_dataset  # noqa: E402

try:  # package-relative when run via benchmarks.run
    from .common import FULL, QUICK, Row, emit
except ImportError:  # direct `python benchmarks/bench_frontier.py`
    from common import FULL, QUICK, Row, emit  # type: ignore

OUT_PATH = os.path.join(os.path.dirname(__file__), "BENCH_frontier.json")


def synth_level(t: int, group: int, n_symbols: int, seed: int = 0):
    """A lex-sorted (t, 2) level table of ~``t/group`` prefix groups, the
    shape of a wide level-2 frontier."""
    rng = np.random.default_rng(seed)
    rows = []
    n_prefix = max(1, t // group)
    prefixes = np.sort(rng.choice(n_symbols, size=n_prefix, replace=False))
    for p in prefixes:
        lasts = rng.choice(n_symbols, size=min(group, n_symbols - 1), replace=False)
        lasts = np.sort(lasts[lasts != p])
        for l in lasts:
            rows.append((int(p), int(l)))
    its = np.asarray(sorted(set(rows)), dtype=np.int32)[:t]
    counts = rng.integers(1, 1000, size=its.shape[0]).astype(np.int64)
    return its, counts


def bench_level_stage(t: int, group: int, n_symbols: int, max_pairs: int, reps: int):
    """Time one full candidate-gen + support-test pass over a level."""
    its, counts = synth_level(t, group, n_symbols)
    sizes = prefix_group_sizes(its)
    spans = [s for s in iter_group_spans(sizes, max_pairs) if s[2] > 0]
    n_pairs = sum(s[2] for s in spans)

    host = make_placement("numpy")
    dev = make_placement("jnp")

    def run_host():
        state = host.prepare_frontier(its, counts, n_symbols)
        pruned = 0
        for lo, hi, np_ in spans:
            cand, ok = host.frontier_dispatch(state, lo, hi, np_)
            pruned += int((~ok).sum())
        return pruned

    def run_device():
        state = dev.prepare_frontier(its, counts, n_symbols)
        n_ok_total = 0
        for lo, hi, np_ in spans:
            pairs, ok = dev.frontier_dispatch(state, lo, hi, np_)
            _, n_ok = dev.frontier_mask(state, pairs, ok)
            n_ok_total += int(n_ok)  # block: the host path is synchronous too
        dev.release(state)
        return n_ok_total

    host_pruned = run_host()
    dev_ok = run_device()  # warm the executables before timing
    assert n_pairs - host_pruned == dev_ok, "host/device support verdicts differ!"

    t_host = min(
        (lambda t0=time.perf_counter(): (run_host(), time.perf_counter() - t0)[1])()
        for _ in range(reps)
    )
    t_dev = min(
        (lambda t0=time.perf_counter(): (run_device(), time.perf_counter() - t0)[1])()
        for _ in range(reps)
    )
    return {
        "t": int(its.shape[0]),
        "n_pairs": int(n_pairs),
        "survivors": int(n_pairs - host_pruned),
        "host_s": t_host,
        "device_s": t_dev,
        "speedup": t_host / max(t_dev, 1e-12),
    }


def bench_end_to_end(D, engine: str, kmax: int, tau: int, reps: int = 2):
    out = {}
    for frontier_on in (False, True):
        # warm reps: executables bind through the process-wide cache, so the
        # steady-state (resident-service) cost is the min over repeats —
        # the first rep carries XLA compile time
        runs = [
            mine(
                D,
                KyivConfig(
                    tau=tau, kmax=kmax, engine=engine,
                    device_frontier=frontier_on, interpret=True,
                ),
            )
            for _ in range(max(1, reps))
        ]
        res = min(runs, key=lambda r: r.wall_time)
        out[frontier_on] = {
            "wall_time": res.wall_time,
            "time_candidates": res.total_candidate_time,
            "time_intersect": res.total_intersect_time,
            "per_level_timing": res.timing_breakdown(),
            "n_results": len(res.itemsets),
        }
    assert out[False]["n_results"] == out[True]["n_results"], "frontier changed results!"
    return {
        "engine": engine,
        "host_path": out[False],
        "device_frontier": out[True],
        "candidates_speedup": out[False]["time_candidates"]
        / max(out[True]["time_candidates"], 1e-12),
    }


def run(cfg=QUICK, *, engines=("jnp",), n=None, m=None, kmax=None, tau=1,
        reps=3, level_t=None, full=False):
    n = n or cfg["rand_n"]
    m = m or cfg["rand_m"]
    kmax = kmax or cfg["kmax"]
    # level micro-bench sized to the config: --full mimics the million-row
    # run's wide level (tens of thousands of stored itemsets)
    level_t = level_t or (50_000 if full else 4_000)
    rows: list[Row] = []
    micro = bench_level_stage(
        t=level_t, group=32, n_symbols=max(2 * level_t, 64),
        max_pairs=1 << 22, reps=reps,
    )
    rows.append(Row("frontier/level_stage_host", micro["host_s"] * 1e6,
                    f"pairs={micro['n_pairs']}"))
    rows.append(Row("frontier/level_stage_device", micro["device_s"] * 1e6,
                    f"speedup={micro['speedup']:.2f}x"))

    D = randomized_dataset(n, m, seed=0)
    e2e = []
    for engine in engines:
        r = bench_end_to_end(D, engine, kmax, tau, reps=min(reps, 3))
        e2e.append(r)
        rows.append(
            Row(
                f"frontier/e2e_{engine}_candidates",
                r["device_frontier"]["time_candidates"] * 1e6,
                f"host={r['host_path']['time_candidates']:.3f}s "
                f"speedup={r['candidates_speedup']:.2f}x",
            )
        )
    meta = {
        "n": n, "m": m, "kmax": kmax, "tau": tau, "level_t": level_t,
        "timestamp": time.time(), "platform": platform.platform(),
        "numpy": np.__version__, "full": full,
    }
    return rows, {"meta": meta, "level_stage": micro, "end_to_end": e2e}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true",
                    help="paper-scale million-row config")
    ap.add_argument("--engines", default="jnp")
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--m", type=int, default=None)
    ap.add_argument("--kmax", type=int, default=None)
    ap.add_argument("--tau", type=int, default=1)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--level-t", type=int, default=None,
                    help="synthetic level size for the micro-bench")
    args = ap.parse_args()
    cfg = FULL if args.full else QUICK
    n = args.n or (cfg["scale_n"][-1] if args.full else None)  # 1M rows on --full
    rows, data = run(
        cfg,
        engines=tuple(args.engines.split(",")),
        n=n, m=args.m, kmax=args.kmax, tau=args.tau, reps=args.reps,
        level_t=args.level_t, full=args.full,
    )
    emit(rows)
    history = []
    if os.path.exists(OUT_PATH):
        with open(OUT_PATH) as f:
            history = json.load(f)
    history.append(data)
    with open(OUT_PATH, "w") as f:
        json.dump(history, f, indent=2)
    print(f"wrote {OUT_PATH} ({len(history)} run(s))")


if __name__ == "__main__":
    main()
