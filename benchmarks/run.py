"""Benchmark orchestrator — one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Quick mode (default) scales the
paper's datasets to this single-core container; ``--full`` selects
paper-scale parameters (hours of runtime). Raw per-bench data is saved to
artifacts/bench/*.json.

``--summary`` additionally writes ``benchmarks/BENCH_summary.json``: this
run's rows plus every standalone ``BENCH_*.json`` record already in the
benchmarks directory, so CI can upload one consolidated artifact.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from .common import FULL, QUICK, Row, emit  # noqa: E402

BENCHES = [
    ("fig2", "bench_fig2_timedist"),
    ("fig3", "bench_fig3_vertices"),
    ("fig45", "bench_fig45_ordering"),
    ("fig6", "bench_fig6_scaling"),
    ("fig7_10", "bench_fig7_10_vs_minit"),
    ("fig11", "bench_fig11_tau"),
    ("fig12", "bench_fig12_memory"),
    ("fig13", "bench_fig13_parallel"),
    ("fused", "bench_fused_pipeline"),
    ("service", "bench_service"),
    ("sampling", "bench_sampling"),
    ("obs", "bench_obs"),
    ("roofline", "bench_roofline"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale datasets")
    ap.add_argument("--only", default=None, help="comma-separated bench keys")
    ap.add_argument("--summary", action="store_true",
                    help="write benchmarks/BENCH_summary.json consolidating "
                         "this run's rows with standalone BENCH_*.json files")
    args = ap.parse_args()
    cfg = FULL if args.full else QUICK
    only = set(args.only.split(",")) if args.only else None

    out_dir = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")
    os.makedirs(out_dir, exist_ok=True)

    all_rows: list[Row] = []
    for key, mod_name in BENCHES:
        if only and key not in only:
            continue
        mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
        t0 = time.perf_counter()
        try:
            if key == "roofline":
                rows, raw = mod.run()
            else:
                rows, raw = mod.run(cfg)
            all_rows.extend(rows)
            with open(os.path.join(out_dir, f"{key}.json"), "w") as f:
                json.dump(raw, f, indent=1, default=str)
            print(f"# {key} done in {time.perf_counter() - t0:.1f}s", file=sys.stderr)
        except Exception as e:
            all_rows.append(Row(f"{key}/ERROR", 0.0, repr(e)))
            print(f"# {key} FAILED: {e!r}", file=sys.stderr)

    emit(all_rows)
    if args.summary:
        write_summary(all_rows, mode="full" if args.full else "quick")


def write_summary(rows: list[Row], mode: str) -> str:
    """Consolidate this run's rows + standalone BENCH_*.json records into
    one ``benchmarks/BENCH_summary.json`` artifact."""
    bench_dir = os.path.dirname(os.path.abspath(__file__))
    standalone = {}
    for fname in sorted(os.listdir(bench_dir)):
        if not (fname.startswith("BENCH_") and fname.endswith(".json")):
            continue
        if fname == "BENCH_summary.json":
            continue
        try:
            with open(os.path.join(bench_dir, fname)) as f:
                standalone[fname[len("BENCH_"):-len(".json")]] = json.load(f)
        except Exception as e:
            standalone[fname] = {"error": repr(e)}
    summary = {
        "generated_at": time.time(),
        "mode": mode,
        "rows": [
            {"name": r.name, "us_per_call": r.us_per_call, "derived": r.derived}
            for r in rows
        ],
        "standalone": standalone,
    }
    path = os.path.join(bench_dir, "BENCH_summary.json")
    with open(path, "w") as f:
        json.dump(summary, f, indent=1, default=str)
    print(f"# wrote {path}", file=sys.stderr)
    return path


if __name__ == "__main__":
    main()
