"""Benchmark orchestrator — one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Quick mode (default) scales the
paper's datasets to this single-core container; ``--full`` selects
paper-scale parameters (hours of runtime). Raw per-bench data is saved to
artifacts/bench/*.json.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from .common import FULL, QUICK, Row, emit  # noqa: E402

BENCHES = [
    ("fig2", "bench_fig2_timedist"),
    ("fig3", "bench_fig3_vertices"),
    ("fig45", "bench_fig45_ordering"),
    ("fig6", "bench_fig6_scaling"),
    ("fig7_10", "bench_fig7_10_vs_minit"),
    ("fig11", "bench_fig11_tau"),
    ("fig12", "bench_fig12_memory"),
    ("fig13", "bench_fig13_parallel"),
    ("fused", "bench_fused_pipeline"),
    ("service", "bench_service"),
    ("roofline", "bench_roofline"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale datasets")
    ap.add_argument("--only", default=None, help="comma-separated bench keys")
    args = ap.parse_args()
    cfg = FULL if args.full else QUICK
    only = set(args.only.split(",")) if args.only else None

    out_dir = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")
    os.makedirs(out_dir, exist_ok=True)

    all_rows: list[Row] = []
    for key, mod_name in BENCHES:
        if only and key not in only:
            continue
        mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
        t0 = time.perf_counter()
        try:
            if key == "roofline":
                rows, raw = mod.run()
            else:
                rows, raw = mod.run(cfg)
            all_rows.extend(rows)
            with open(os.path.join(out_dir, f"{key}.json"), "w") as f:
                json.dump(raw, f, indent=1, default=str)
            print(f"# {key} done in {time.perf_counter() - t0:.1f}s", file=sys.stderr)
        except Exception as e:
            all_rows.append(Row(f"{key}/ERROR", 0.0, repr(e)))
            print(f"# {key} FAILED: {e!r}", file=sys.stderr)

    emit(all_rows)


if __name__ == "__main__":
    main()
