"""§Roofline table: aggregates the dry-run artifacts into the per-(arch x
shape x mesh) three-term roofline table (no new compilation — reads
artifacts/dryrun/*.json written by repro.launch.dryrun)."""

from __future__ import annotations

import glob
import json
import os

from .common import Row

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load_records(art_dir: str = ART) -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def table(recs: list[dict]) -> str:
    hdr = (f"{'arch':24s} {'shape':12s} {'mesh':10s} {'t_comp':>9s} {'t_mem':>9s} "
           f"{'t_coll':>9s} {'dom':>10s} {'useful':>7s} {'fits':>5s}")
    lines = [hdr, "-" * len(hdr)]
    for r in recs:
        if r.get("status") == "skipped":
            lines.append(f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:10s} "
                         f"{'—':>9s} {'—':>9s} {'—':>9s} {'skip':>10s}")
            continue
        if r.get("status") != "ok":
            lines.append(f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:10s} ERROR")
            continue
        rl = r["roofline"]
        fits = r.get("memory", {}).get("fits", "?")
        lines.append(
            f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:10s} "
            f"{rl['t_compute']:9.2e} {rl['t_memory']:9.2e} {rl['t_collective']:9.2e} "
            f"{rl['dominant']:>10s} {rl.get('useful_flops_ratio', 0):7.2%} {str(fits):>5s}"
        )
    return "\n".join(lines)


def run() -> tuple[list[Row], dict]:
    recs = load_records()
    ok = [r for r in recs if r.get("status") == "ok"]
    skipped = [r for r in recs if r.get("status") == "skipped"]
    err = [r for r in recs if r.get("status") == "error"]
    rows = [
        Row("roofline/cells_ok", 0.0, f"count={len(ok)}"),
        Row("roofline/cells_skipped", 0.0,
            f"count={len(skipped)} (long_500k for full-attention archs)"),
        Row("roofline/cells_error", 0.0, f"count={len(err)}"),
    ]
    for dom in ("compute", "memory", "collective"):
        n = sum(1 for r in ok if r["roofline"]["dominant"] == dom)
        rows.append(Row(f"roofline/dominant_{dom}", 0.0, f"count={n}"))
    return rows, {"table": table(recs)}


if __name__ == "__main__":
    rows, extra = run()
    from .common import emit

    emit(rows)
    print()
    print(extra["table"])
