"""Resident mining service benchmark: warm-vs-cold and append-vs-full.

Three measurements on one synthetic randomized table:

1. **cold**     — first ``MiningService.mine`` at a fresh version
                  (preprocess + full Algorithm 1).
2. **cached**   — the same query repeated: an LRU hit on
                  ``(version, tau, kmax, ordering)``. Acceptance: >= 20x
                  faster than cold.
3. **append**   — for growing delta block sizes, ``/append`` then re-mine.
                  The incremental path (recount + boundary expansion +
                  delta-born scan) must cost a function of the *delta*, not
                  the accumulated table: the recorded ``incremental_s``
                  column grows with the block size and every block stays
                  far below ``cold_equiv_s`` (a cold re-mine of the same
                  concatenated table).

Results are appended to ``BENCH_service.json`` next to this file (a list of
runs, one per invocation) so the serving-perf trajectory is tracked across
PRs. Default is the container-sized config; ``--full`` is the paper-scale
50k-row randomized table.
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.core import KyivConfig, mine  # noqa: E402
from repro.data.synth import randomized_dataset  # noqa: E402
from repro.service import IncrementalConfig, MiningService  # noqa: E402

try:  # package-relative when run via benchmarks.run
    from .common import FULL, QUICK, Row, emit
except ImportError:  # direct `python benchmarks/bench_service.py`
    from common import FULL, QUICK, Row, emit  # type: ignore

OUT_PATH = os.path.join(os.path.dirname(__file__), "BENCH_service.json")


def run(cfg=QUICK, *, engine="numpy", n=None, m=None, tau=1, kmax=None,
        full=False) -> tuple[list[Row], dict]:
    n = n or cfg["rand_n"]
    m = m or cfg["rand_m"]
    kmax = kmax or min(cfg["kmax"], 3)
    base = randomized_dataset(n, m, seed=0)
    rng = np.random.default_rng(1)

    service = MiningService.from_dataset(
        base,
        engine=engine,
        incremental=IncrementalConfig(max_delta_fraction=0.5),
    )

    rows: list[Row] = []
    record: dict = {
        "engine": engine, "n": n, "m": m, "tau": tau, "kmax": kmax,
        "timestamp": time.time(), "platform": platform.platform(),
    }

    cold = service.mine(tau=tau, kmax=kmax)
    cached = service.mine(tau=tau, kmax=kmax)
    assert (cold.source, cached.source) == ("cold", "cache"), (
        cold.source, cached.source,
    )
    cached_speedup = cold.latency_s / max(cached.latency_s, 1e-9)
    record.update(
        cold_s=cold.latency_s,
        cached_s=cached.latency_s,
        cached_speedup=cached_speedup,
        n_itemsets=cold.n_itemsets,
        cached_speedup_ge_20x=bool(cached_speedup >= 20.0),
    )
    rows.append(Row("service/cold_mine", cold.latency_s * 1e6,
                    f"n_itemsets={cold.n_itemsets}"))
    rows.append(Row("service/cached_repeat", cached.latency_s * 1e6,
                    f"speedup={cached_speedup:.0f}x"))

    # append-vs-full: growing delta blocks on the same accumulated table
    deltas = [max(n // 1000, 1), max(n // 100, 2), max(n // 20, 4)]
    appends = []
    acc = base
    domain_hi = int(base.max()) + 1
    for d in deltas:
        block = rng.integers(1, domain_hi, size=(d, m))
        service.append(block)
        acc = np.concatenate([acc, block])
        inc = service.mine(tau=tau, kmax=kmax)
        # cold equivalent of the same concatenated table (what re-answering
        # without the resident store would cost)
        t0 = time.perf_counter()
        cold_equiv = mine(acc, KyivConfig(tau=tau, kmax=kmax, engine=engine))
        cold_equiv_s = time.perf_counter() - t0
        assert len(cold_equiv.itemsets) == inc.n_itemsets, (
            "incremental diverged from cold",
            len(cold_equiv.itemsets),
            inc.n_itemsets,
        )
        appends.append(
            {
                "delta_rows": d,
                "total_rows": int(acc.shape[0]),
                "source": inc.source,
                "incremental_s": inc.latency_s,
                "cold_equiv_s": cold_equiv_s,
                "speedup_vs_cold": cold_equiv_s / max(inc.latency_s, 1e-9),
                "info": inc.info,
                "n_itemsets": inc.n_itemsets,
            }
        )
        rows.append(
            Row(
                f"service/append_{d}_rows",
                inc.latency_s * 1e6,
                f"source={inc.source} cold_equiv={cold_equiv_s:.3f}s",
            )
        )
    # delta scaling: incremental cost must track the block size, i.e. the
    # smallest block is the cheapest and every block beats the cold re-mine
    incs = [a for a in appends if a["source"] == "incremental"]
    record["appends"] = appends
    record["delta_scaling_ok"] = bool(
        len(incs) == len(appends)
        and all(a["incremental_s"] < a["cold_equiv_s"] for a in incs)
        and incs[0]["incremental_s"] <= incs[-1]["incremental_s"]
    )
    service.close()
    return rows, record


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true", help="paper-scale table")
    ap.add_argument("--engine", default="numpy")
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--m", type=int, default=None)
    ap.add_argument("--tau", type=int, default=1)
    ap.add_argument("--kmax", type=int, default=None)
    args = ap.parse_args()
    cfg = FULL if args.full else QUICK
    rows, record = run(cfg, engine=args.engine, n=args.n, m=args.m,
                       tau=args.tau, kmax=args.kmax, full=args.full)
    emit(rows)
    history = []
    if os.path.exists(OUT_PATH):
        with open(OUT_PATH) as f:
            history = json.load(f)
    history.append(record)
    with open(OUT_PATH, "w") as f:
        json.dump(history, f, indent=1)
    print(f"# appended run to {OUT_PATH}", file=sys.stderr)


if __name__ == "__main__":
    main()
