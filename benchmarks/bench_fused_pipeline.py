"""Fused intersect-classify pipeline benchmark: host-classified batch
dispatch vs the device-classified fused pipeline, per engine.

For each engine it mines the same synthetic randomized dataset twice —
``fused_classify=False`` (the pre-fusion baseline: counts come back to the
host and the absent/uniform/infrequent/store masks are re-derived in numpy
per batch) and ``fused_classify=True`` (class codes computed by the engine,
host only gathers) — and records wall time, intersect time, and the
per-level host classification time (``LevelStats.time_classify``, the
component that used to hide inside ``time_total - time_intersect``).

Results are appended to ``BENCH_fused.json`` next to this file (a list of
runs, one per invocation) so the perf trajectory is tracked across PRs.

Default is a container-sized config; ``--full`` selects the paper-scale
synthetic million-row config (FULL["scale_n"][-1] rows — hours on CPU,
intended for real TPU hosts).
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.core import KyivConfig, mine  # noqa: E402
from repro.data.synth import randomized_dataset  # noqa: E402

try:  # package-relative when run via benchmarks.run
    from .common import FULL, QUICK, Row, emit
except ImportError:  # direct `python benchmarks/bench_fused_pipeline.py`
    from common import FULL, QUICK, Row, emit  # type: ignore

OUT_PATH = os.path.join(os.path.dirname(__file__), "BENCH_fused.json")


def _mine_once(D, engine: str, fused: bool, kmax: int, tau: int) -> dict:
    res = mine(
        D,
        KyivConfig(
            tau=tau,
            kmax=kmax,
            engine=engine,
            fused_classify=fused,
            interpret=True,
            # pin the host candidate path so this bench keeps isolating
            # classification fusion (device frontier vs host candidate gen
            # is benchmarks/bench_frontier.py's comparison)
            device_frontier=False,
        ),
    )
    return {
        "engine": engine,
        "fused_classify": fused,
        "wall_time": res.wall_time,
        "time_intersect": res.total_intersect_time,
        "time_classify": res.total_classify_time,
        "time_candidates": res.total_candidate_time,
        "per_level_classify": [s.time_classify for s in res.stats],
        # per-level host-busy vs device-busy split (candidate gen + support
        # + classify vs dispatch + sync) — the frontier win at --full scale
        "per_level_timing": res.timing_breakdown(),
        "intersections": res.total_intersections,
        "n_results": len(res.itemsets),
    }


def run(cfg=QUICK, *, engines=("numpy", "jnp", "pallas"), n=None, m=None,
        kmax=None, tau=1, reps=1, full=False) -> tuple[list[Row], dict]:
    n = n or cfg["rand_n"]
    m = m or cfg["rand_m"]
    kmax = kmax or cfg["kmax"]
    D = randomized_dataset(n, m, seed=0)
    # interpret-mode pallas on CPU is a *validation* platform (the grid runs
    # interpreted); time it on a scaled-down dataset so the bench stays
    # runnable off-TPU. On real TPU (--full), pallas gets the full config.
    D_small = randomized_dataset(min(n, 300), min(m, 6), seed=0)
    kmax_small = min(kmax, 3)
    rows: list[Row] = []
    runs: list[dict] = []
    checks: dict[str, int] = {}
    for engine in engines:
        eng_D, eng_kmax = (D, kmax)
        if engine == "pallas" and not full and n > 300:
            eng_D, eng_kmax = D_small, kmax_small
        best: dict[bool, dict] = {}
        for fused in (False, True):
            recs = [_mine_once(eng_D, engine, fused, eng_kmax, tau) for _ in range(reps)]
            rec = min(recs, key=lambda r: r["wall_time"])
            rec["n_effective"] = int(eng_D.shape[0])
            rec["kmax_effective"] = eng_kmax
            best[fused] = rec
            runs.append(rec)
            checks.setdefault(engine, rec["n_results"])
            assert checks[engine] == rec["n_results"], "fused changed the result!"
        base, fus = best[False], best[True]
        speedup = base["time_classify"] / max(fus["time_classify"], 1e-12)
        rows.append(
            Row(
                f"fused/{engine}/classify_time_host", base["time_classify"] * 1e6,
                f"wall={base['wall_time']:.3f}s intersect={base['time_intersect']:.3f}s",
            )
        )
        rows.append(
            Row(
                f"fused/{engine}/classify_time_fused", fus["time_classify"] * 1e6,
                f"wall={fus['wall_time']:.3f}s speedup={speedup:.1f}x",
            )
        )
    meta = {
        "n": n, "m": m, "kmax": kmax, "tau": tau,
        "timestamp": time.time(),
        "platform": platform.platform(),
        "numpy": np.__version__,
    }
    return rows, {"meta": meta, "runs": runs}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true",
                    help="paper-scale synthetic million-row config")
    ap.add_argument("--engines", default="numpy,jnp,pallas")
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--m", type=int, default=None)
    ap.add_argument("--kmax", type=int, default=None)
    ap.add_argument("--tau", type=int, default=1)
    ap.add_argument("--reps", type=int, default=1)
    args = ap.parse_args()
    cfg = FULL if args.full else QUICK
    n = args.n or (cfg["scale_n"][-1] if args.full else None)  # 1M rows on --full
    rows, data = run(
        cfg,
        engines=tuple(args.engines.split(",")),
        n=n, m=args.m, kmax=args.kmax, tau=args.tau, reps=args.reps,
        full=args.full,
    )
    emit(rows)
    history = []
    if os.path.exists(OUT_PATH):
        with open(OUT_PATH) as f:
            history = json.load(f)
    history.append(data)
    with open(OUT_PATH, "w") as f:
        json.dump(history, f, indent=2)
    print(f"wrote {OUT_PATH} ({len(history)} run(s))")


if __name__ == "__main__":
    main()
