"""Fig. 3: distribution of prefix-tree vertex types A (minimal infrequent),
B (visited, no intersection), C (rest) over randomized datasets (paper:
~17.5% A, ~23% B on average at k_max=5)."""

from __future__ import annotations

import numpy as np

from repro.core import KyivConfig, mine
from repro.data.synth import randomized_dataset

from .common import QUICK, Row


def vertex_fractions(res) -> tuple[float, float, float]:
    a = sum(s.type_a for s in res.stats if s.k > 1)
    b = sum(s.type_b for s in res.stats if s.k > 1)
    c = sum(s.type_c for s in res.stats if s.k > 1)
    tot = max(a + b + c, 1)
    return a / tot, b / tot, c / tot


def run(cfg=QUICK, seed0: int = 100) -> tuple[list[Row], dict]:
    fracs = []
    for r in range(cfg["rand_reps"]):
        D = randomized_dataset(cfg["rand_n"], cfg["rand_m"], seed=seed0 + r)
        res = mine(D, KyivConfig(tau=1, kmax=cfg["kmax"]))
        fracs.append(vertex_fractions(res))
    fr = np.asarray(fracs)
    rows = [
        Row("fig3/type_A_fraction", fr[:, 0].mean() * 1e6,
            f"mean={fr[:, 0].mean():.3f} (paper ~0.175)"),
        Row("fig3/type_B_fraction", fr[:, 1].mean() * 1e6,
            f"mean={fr[:, 1].mean():.3f} (paper ~0.23, up to 0.45)"),
        Row("fig3/type_C_fraction", fr[:, 2].mean() * 1e6,
            f"mean={fr[:, 2].mean():.3f}"),
    ]
    return rows, {"fractions": fr.tolist()}


if __name__ == "__main__":
    from .common import emit

    emit(run()[0])
