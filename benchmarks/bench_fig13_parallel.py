"""Fig. 13 + Tables II-IV: parallel work balance.

The paper shows per-thread runtimes with a narrow spread (greedy T-array
assignment). We measure the analogous quantity for both schedulers:

  * paper-faithful greedy assignment: per-worker *intersection work* spread
    at each level for 4/8/16 workers (Tables II-IV analogue);
  * SPMD balanced blocks: per-shard pair counts are exactly equal by
    construction — reported as max/min ratio 1.0.

Work here is measured in row intersections (the paper's own estimate), which
on this container is directly proportional to wall time in the numpy engine.
"""

from __future__ import annotations

import numpy as np

from repro.core import KyivConfig, itemize, preprocess
from repro.core.balance import balanced_blocks, greedy_assign, pair_work_per_unit
from repro.core.kyiv import mine_preprocessed
from repro.core.prefix import Level
from repro.data.synth import pumsb_like

from .common import QUICK, Row


def run(cfg=QUICK) -> tuple[list[Row], dict]:
    D = pumsb_like(n=cfg["domain_n"], m=10)
    config = KyivConfig(tau=1, kmax=4)
    prep = preprocess(itemize(D), config.tau)

    # capture per-level stored itemsets by running and reconstructing levels
    levels = []

    def hook(k, state):
        levels.append(state["level"])

    mine_preprocessed(prep, config, on_level_end=hook)
    level1 = Level(k=1, itemsets=np.arange(prep.n_l, dtype=np.int32)[:, None],
                   counts=prep.l_freq, bits=None)
    all_levels = [level1] + [l for l in levels if l.t > 1]

    rows, raw = [], {}
    for n_workers in (4, 8, 16):
        spreads = []
        for lvl in all_levels:
            work = pair_work_per_unit(lvl.itemsets)
            if work.sum() == 0:
                continue
            _, loads = greedy_assign(work, n_workers)
            busy = loads[loads > 0]
            if len(busy) > 1:
                spreads.append(float(busy.max() / max(busy.mean(), 1)))
        spread = float(np.mean(spreads)) if spreads else 1.0
        rows.append(
            Row(f"fig13/greedy_{n_workers}workers", 0.0,
                f"max/mean_load={spread:.3f} over {len(spreads)} levels "
                f"(paper: narrow spread)")
        )
        raw[f"greedy_{n_workers}"] = spread
    # SPMD exact balance
    m_pairs = 1_000_000
    padded, block = balanced_blocks(m_pairs, 256)
    rows.append(
        Row("fig13/spmd_256shards", 0.0,
            f"block={block} pad_overhead={(padded - m_pairs) / m_pairs:.4%} "
            f"max/min=1.0 (exact)")
    )
    return rows, raw


if __name__ == "__main__":
    from .common import emit

    emit(run()[0])
