"""End-to-end smoke of the telemetry substrate over real HTTP.

Starts ``repro.launch.serve_miner`` as a subprocess (JSON logs on), mines,
then checks the observability contract the CI obs-smoke job enforces:

  1. a cold /mine response and its ``X-Trace-Id`` header carry the same
     trace id, and ``GET /trace?id=...`` returns a span tree whose direct
     children account for >= 95% of the request's wall time,
  2. a client-supplied ``X-Trace-Id`` is honoured and echoed back,
  3. ``GET /metrics`` is valid Prometheus text exposition (linted with
     ``repro.obs.metrics.lint_exposition``) with >= 20 metric families,
  4. ``GET /stats`` keeps its pre-observability sections (backward
     compatibility) and folds the registry snapshot in under ``"obs"``.

Used by the CI obs-smoke job; also runnable directly:

  PYTHONPATH=src python examples/obs_smoke.py
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import time
import urllib.error
import urllib.request

PORT = int(os.environ.get("SMOKE_PORT", "8754"))
BASE = f"http://127.0.0.1:{PORT}"


def req(path: str, payload: dict | None = None, headers: dict | None = None):
    request = urllib.request.Request(
        BASE + path,
        data=json.dumps(payload).encode() if payload is not None else None,
        headers={"Content-Type": "application/json", **(headers or {})},
    )
    resp = urllib.request.urlopen(request, timeout=60)
    return resp, resp.read()


def req_json(path: str, payload: dict | None = None, headers: dict | None = None):
    resp, body = req(path, payload, headers)
    return resp, json.loads(body)


def wait_healthy(proc: subprocess.Popen, timeout: float = 60.0) -> None:
    deadline = time.time() + timeout
    while time.time() < deadline:
        if proc.poll() is not None:
            raise RuntimeError(f"serve_miner exited early: rc={proc.returncode}")
        try:
            if req_json("/healthz")[1].get("ok"):
                return
        except (urllib.error.URLError, ConnectionError):
            time.sleep(0.3)
    raise RuntimeError("serve_miner did not become healthy in time")


def main() -> None:
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    sys.path.insert(0, src)
    from repro.obs.metrics import lint_exposition

    proc = subprocess.Popen(
        [
            sys.executable, "-m", "repro.launch.serve_miner",
            "--port", str(PORT),
            "--preload", "randomized", "--n", "500", "--m", "6",
            "--log-json", "--log-level", "info",
        ],
        env=env,
    )
    try:
        wait_healthy(proc)

        # 1. cold mine: trace id in body == header, span tree retrievable
        resp, m1 = req_json("/mine", {"tau": 1, "kmax": 3, "max_itemsets": 3})
        assert m1["source"] == "cold", m1["source"]
        tid = m1["trace_id"]
        assert resp.headers["X-Trace-Id"] == tid, (resp.headers, tid)
        _, tr = req_json(f"/trace?id={tid}")
        tree = tr["trace"]
        assert tree["trace_id"] == tid
        assert tree["coverage"] >= 0.95, tree["coverage"]
        assert tree["n_spans"] >= 5, tree["n_spans"]

        # 2. client-supplied correlation id is honoured
        resp2, m2 = req_json(
            "/mine", {"tau": 1, "kmax": 3}, headers={"X-Trace-Id": "smoke0001"}
        )
        assert m2["trace_id"] == "smoke0001"
        assert resp2.headers["X-Trace-Id"] == "smoke0001"

        # 3. /metrics: valid exposition, >= 20 families
        resp3, body3 = req("/metrics")
        assert resp3.headers["Content-Type"].startswith(
            "text/plain; version=0.0.4"
        )
        text = body3.decode()
        problems = lint_exposition(text)
        assert not problems, problems[:10]
        families = {
            line.split()[2]
            for line in text.splitlines()
            if line.startswith("# TYPE")
        }
        assert len(families) >= 20, sorted(families)
        assert "repro_mine_wall_seconds" in families
        assert "repro_http_requests_total" in families

        # 4. /stats keeps its old shape and gains the obs fold-in
        _, stats = req_json("/stats")
        for section in ("store", "cache", "scheduler", "served", "http"):
            assert section in stats, section
        assert "metrics" in stats["obs"] and "traces" in stats["obs"]

        print(
            "OBS_SMOKE_OK "
            f"families={len(families)} coverage={tree['coverage']:.3f} "
            f"spans={tree['n_spans']} trace_id={tid}"
        )
    finally:
        proc.terminate()
        try:
            proc.wait(timeout=10)
        except subprocess.TimeoutExpired:
            proc.kill()


if __name__ == "__main__":
    main()
