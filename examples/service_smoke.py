"""End-to-end smoke of the resident mining service over real HTTP.

Starts ``repro.launch.serve_miner`` as a subprocess, issues /append + /mine
requests with stdlib urllib, and asserts the caching/incremental contract:

  1. first /mine is cold,
  2. the repeat at the same version is a cache hit,
  3. /append bumps the version,
  4. /mine after the append is served (incrementally or cold) with the new
     version and a repeat hits the cache again,
  5. /report agrees with /mine,
  6. /risk agrees with /report, repeats hit the privacy cache, and
     /anonymize returns a verified zero-residual plan.

Used by the CI service smoke job; also runnable directly:

  PYTHONPATH=src python examples/service_smoke.py
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import time
import urllib.error
import urllib.request

PORT = int(os.environ.get("SMOKE_PORT", "8753"))
BASE = f"http://127.0.0.1:{PORT}"


def req(path: str, payload: dict | None = None) -> dict:
    if payload is None:
        r = urllib.request.urlopen(BASE + path, timeout=60)
    else:
        r = urllib.request.urlopen(
            urllib.request.Request(
                BASE + path,
                data=json.dumps(payload).encode(),
                headers={"Content-Type": "application/json"},
            ),
            timeout=60,
        )
    return json.loads(r.read())


def wait_healthy(proc: subprocess.Popen, timeout: float = 60.0) -> None:
    deadline = time.time() + timeout
    while time.time() < deadline:
        if proc.poll() is not None:
            raise RuntimeError(f"serve_miner exited early: rc={proc.returncode}")
        try:
            if req("/healthz").get("ok"):
                return
        except (urllib.error.URLError, ConnectionError):
            time.sleep(0.3)
    raise RuntimeError("serve_miner did not become healthy in time")


def main() -> None:
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.Popen(
        [
            sys.executable, "-m", "repro.launch.serve_miner",
            "--port", str(PORT),
            "--preload", "randomized", "--n", "500", "--m", "6",
        ],
        env=env,
    )
    try:
        wait_healthy(proc)

        m1 = req("/mine", {"tau": 1, "kmax": 3, "max_itemsets": 3})
        assert m1["source"] == "cold", m1["source"]
        assert m1["n_itemsets"] > 0

        m2 = req("/mine", {"tau": 1, "kmax": 3, "max_itemsets": 3})
        assert m2["source"] == "cache", m2["source"]
        assert m2["n_itemsets"] == m1["n_itemsets"]

        a = req("/append", {"rows": [[1, 2, 3, 4, 5, 6], [7, 8, 9, 1, 2, 3]]})
        assert a["version"] == m1["version"] + 1, a

        m3 = req("/mine", {"tau": 1, "kmax": 3, "max_itemsets": 3})
        assert m3["version"] == a["version"]
        assert m3["source"] in ("incremental", "cold"), m3["source"]

        m4 = req("/mine", {"tau": 1, "kmax": 3, "max_itemsets": 3})
        assert m4["source"] == "cache", m4["source"]

        rep = req("/report?tau=1&kmax=3")
        assert rep["n_quasi_identifiers"] == m3["n_itemsets"], rep
        assert "top_risk_records" in rep and "risk_histogram" in rep

        risk = req("/risk?tau=1&kmax=3&top=5")
        assert risk["records_at_risk"] == rep["unique_records"], risk
        assert req("/risk?tau=1&kmax=3&top=5")["source"] == "privacy-cache"

        plan = req("/anonymize?tau=1&kmax=3")
        assert plan["verified"] and plan["residual_qis"] == 0, plan

        stats = req("/stats")
        assert stats["cache"]["hits"] >= 2, stats
        assert stats["privacy"]["entries"] >= 2, stats

        print(
            "SERVICE_SMOKE_OK "
            f"cold={m1['latency_s']:.3f}s cache={m2['latency_s']:.5f}s "
            f"post_append={m3['source']} n_itemsets={m3['n_itemsets']}"
        )
    finally:
        proc.terminate()
        try:
            proc.wait(timeout=10)
        except subprocess.TimeoutExpired:
            proc.kill()


if __name__ == "__main__":
    main()
