"""Statistical disclosure control end-to-end (the paper's §1.1 scenario):

1. build an AOL-style categorical table with rare value combinations,
2. k-anonymise single columns (the paper's grouping transform),
3. mine the *remaining* multi-column quasi-identifiers with Kyiv,
4. report re-identification risk.

  PYTHONPATH=src python examples/sdc_quasi_identifiers.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.sdc.quasi import find_quasi_identifiers, k_anonymize_columns


def main() -> None:
    rng = np.random.default_rng(0)
    n = 5000
    # user table: zip-like code (zipf), age bucket, gender, query category
    table = np.stack(
        [
            rng.zipf(1.3, n).clip(max=2000),  # "zip": many rare values
            rng.integers(0, 9, n),  # age bucket
            rng.integers(0, 2, n),  # gender
            rng.zipf(1.6, n).clip(max=500),  # "query category"
        ],
        axis=1,
    )

    print("=== before anonymisation ===")
    rep = find_quasi_identifiers(table, tau=1, kmax=3)
    print(f"quasi-identifiers (tau=1, kmax=3): {rep.n_quasi_identifiers}")
    print(f"by size: {rep.by_size()}")
    print(f"records pinpointed by at least one: {rep.unique_records()}/{n}")
    print(f"columns by involvement: {rep.risky_columns()}")

    print("\n=== after per-column 5-anonymisation (paper §1.1 transform) ===")
    anon = k_anonymize_columns(table, k=5)
    rep2 = find_quasi_identifiers(anon, tau=1, kmax=3)
    print(f"quasi-identifiers: {rep2.n_quasi_identifiers}")
    print(f"by size: {rep2.by_size()}")
    print(f"records pinpointed: {rep2.unique_records()}/{n}")
    print("\nNote the paper's observation: single-column grouping removes "
          "1-item identifiers,\nbut multi-column combinations remain — "
          "exactly what Kyiv enumerates for masking tools.")


if __name__ == "__main__":
    main()
