"""Distributed mining end-to-end: the Kyiv level step sharded over an 8-device
mesh (pairs over 'data', bitset words over 'model'), with level checkpointing
and a simulated mid-run failure + elastic restart on a smaller mesh.

  PYTHONPATH=src python examples/distributed_mining.py
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import tempfile

import numpy as np
import jax

from repro.core import KyivConfig, itemize, preprocess
from repro.core.kyiv import mine_preprocessed
from repro.core.sharded import make_sharded_pipeline
from repro.data.synth import randomized_dataset
from repro.distributed.checkpoint import CheckpointManager


def main() -> None:
    D = randomized_dataset(n=4000, m=9, seed=1)
    cfg = KyivConfig(tau=1, kmax=4)
    prep = preprocess(itemize(D), cfg.tau)

    # --- 8-device run: pairs over data(4), words over model(2) -------------
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    factory = make_sharded_pipeline(mesh, pair_axes=("data",), word_axis="model")
    with tempfile.TemporaryDirectory() as ckdir:
        cm = CheckpointManager(ckdir)

        class SimulatedFailure(Exception):
            pass

        state_store = {}

        def hook(k, state):
            lvl = state["level"]
            cm.save(k, {"itemsets": lvl.itemsets, "counts": lvl.counts,
                        "bits": lvl.bits, "next_k": state["next_k"]})
            state_store[k] = state
            if k == 2:
                raise SimulatedFailure  # "node died" after level 2

        try:
            mine_preprocessed(prep, cfg, pipeline_factory=factory, on_level_end=hook)
        except SimulatedFailure:
            print(f"node failure simulated after level 2 "
                  f"(checkpoints: steps {cm.steps()})")

        # --- elastic restart: resume on a smaller (2, 2) mesh --------------
        mesh2 = jax.make_mesh((2, 2), ("data", "model"))
        factory2 = make_sharded_pipeline(mesh2, pair_axes=("data",), word_axis="model")
        res = mine_preprocessed(prep, cfg, pipeline_factory=factory2,
                                resume_state=state_store[2])
        print(f"resumed on 2x2 mesh -> {len(res.itemsets)} minimal "
              f"tau-infrequent itemsets")

    # cross-check against a fresh sequential run
    seq = mine_preprocessed(prep, cfg)
    assert res.canonical_set() == seq.canonical_set()
    print("distributed + elastic-restart result == sequential result ✓")


if __name__ == "__main__":
    main()
