"""End-to-end training driver: train a reduced-config architecture for a few
hundred steps on CPU with checkpoint/restart, demonstrating the training
substrate (AdamW, schedules, remat+scan forward, checkpoint manager).

  PYTHONPATH=src python examples/train_tiny_lm.py --arch granite-moe-1b-a400m
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import tempfile

from repro.launch.train import main as train_main


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()

    with tempfile.TemporaryDirectory() as ck:
        sys.argv = [
            "train", "--arch", args.arch, "--reduced",
            "--steps", str(args.steps), "--batch", "8", "--seq", "32",
            "--lr", "3e-3", "--ckpt-dir", ck, "--ckpt-every", "50",
        ]
        train_main()
        # restart from the last checkpoint for a few more steps
        sys.argv = sys.argv + ["--resume"]
        sys.argv[sys.argv.index("--steps") + 1] = str(args.steps + 20)
        print("\n--- restart from checkpoint ---")
        train_main()


if __name__ == "__main__":
    main()
