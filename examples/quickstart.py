"""Quickstart: mine the paper's Example 4.8 dataset and a randomized dataset.

  PYTHONPATH=src python examples/quickstart.py
"""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import KyivConfig, mine
from repro.data.synth import randomized_dataset


def main() -> None:
    # --- the paper's Example 4.8 (7x5, * = unique values) ------------------
    u = [100]
    star = lambda: (u.__setitem__(0, u[0] + 1), u[0])[1]
    A = np.array([
        [star(), star(), star(), 4, star()],
        [1, 2, star(), 4, star()],
        [1, 2, 3, 4, star()],
        [1, 2, 3, 4, 5],
        [1, star(), 3, star(), 5],
        [star(), 2, 3, star(), 5],
        [star(), star(), star(), star(), 5],
    ])
    res = mine(A, KyivConfig(tau=1, kmax=3))
    print("Example 4.8 minimal unique itemsets (as (column, value) pairs):")
    for items, count in res.as_value_sets():
        if len(items) > 1:  # multi-item results; singletons are the * cells
            print(f"  {items}  |R| = {count}")
    print("  (paper expects {d,e} at k=2 and {a,b,e} at k=3)\n")

    # --- a paper-style randomized dataset (scaled down) --------------------
    D = randomized_dataset(n=2000, m=8, seed=0)
    res = mine(D, KyivConfig(tau=1, kmax=3))
    print(f"randomized 2000x8: {len(res.itemsets)} minimal unique itemsets, "
          f"{res.wall_time:.2f}s "
          f"({res.total_intersect_time / max(res.wall_time, 1e-9):.0%} in intersections)")
    for s in res.stats:
        print(f"  k={s.k}: candidates={s.candidates} pruned(B)={s.type_b} "
              f"intersections={s.intersections} found(A)={s.emitted}")


if __name__ == "__main__":
    main()
