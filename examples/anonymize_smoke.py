"""End-to-end smoke of the privacy risk engine: score, plan, verify.

On a synthetic exposed table (frequent background + planted singleton and
pair quasi-identifiers):

  1. mine the quasi-identifiers and compute the per-record risk profile
     (coverage kernels) — the planted exposed rows must be the at-risk ones;
  2. plan anonymization (greedy weighted set cover + verification re-mines);
  3. apply the plan and re-mine the masked table — **zero** residual QIs;
  4. exercise the service surface: ``MiningService.risk`` /
     ``.anonymize_plan`` with the privacy cache warm on repeat.

Used by the CI service-smoke job; also runnable directly:

  PYTHONPATH=src python examples/anonymize_smoke.py
"""

from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.core import KyivConfig, mine  # noqa: E402
from repro.data.synth import exposed_dataset  # noqa: E402
from repro.privacy import apply_plan, mine_masked, plan_anonymization  # noqa: E402
from repro.privacy.risk import risk_profile  # noqa: E402
from repro.service import MiningService  # noqa: E402


def main() -> None:
    D = exposed_dataset(2000, 6, seed=7)
    res = mine(D, KyivConfig(tau=1, kmax=3))
    assert res.itemsets, "exposed table must have quasi-identifiers"

    prof = risk_profile(res)
    assert prof.records_at_risk > 0
    assert prof.risk.max() == 1.0  # planted unique singletons
    top = prof.top_records(5)
    assert top and top[0]["risk"] == 1.0

    plan = plan_anonymization(D, tau=1, kmax=3, base_result=res)
    assert plan.verified and plan.residual_qis == 0, plan
    masked = apply_plan(D, plan)
    post = mine_masked(masked, KyivConfig(tau=1, kmax=3))
    assert post is None or len(post.itemsets) == 0, "residual QIs after masking"

    svc = MiningService.from_dataset(D)
    risk1 = svc.risk(tau=1, kmax=3)
    risk2 = svc.risk(tau=1, kmax=3)
    assert risk2["source"] == "privacy-cache", risk2["source"]
    assert risk1["records_at_risk"] == prof.records_at_risk
    splan = svc.anonymize_plan(tau=1, kmax=3)
    assert splan["verified"] and splan["residual_qis"] == 0
    stats = svc.stats()
    assert stats["privacy"]["entries"] >= 2
    svc.close()

    print(
        "ANONYMIZE_SMOKE_OK "
        f"qis={len(res.itemsets)} at_risk={prof.records_at_risk} "
        f"cells={plan.cells_suppressed} gen_cols={plan.generalized_columns} "
        f"rounds={plan.rounds}"
    )


if __name__ == "__main__":
    main()
