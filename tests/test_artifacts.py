"""Dry-run artifact validation: asserts the committed deliverable (e)/(g)
state — every runnable (arch × shape × mesh) cell compiled, skips are the
documented long_500k exemptions, and every record carries the three roofline
terms. Skipped when artifacts haven't been generated yet."""

import glob
import json
import os

import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def _records():
    return [json.load(open(p)) for p in glob.glob(os.path.join(ART, "*.json"))]


@pytest.mark.skipif(
    len(glob.glob(os.path.join(ART, "*.json"))) < 10,
    reason="dry-run artifacts not generated (run repro.launch.dryrun)",
)
def test_dryrun_artifacts_complete():
    from repro.configs import ARCHS, SHAPES

    recs = _records()
    by_key = {}
    for r in recs:
        by_key.setdefault((r["arch"], r["shape"], r["mesh"]), []).append(r)

    meshes = ("pod16x16", "pod2x16x16")
    n_ok = n_skip = 0
    for arch in ARCHS.values():
        for shape in SHAPES.values():
            for mesh in meshes:
                entries = by_key.get((arch.name, shape.name, mesh))
                assert entries, f"missing cell {arch.name} x {shape.name} x {mesh}"
                statuses = {e["status"] for e in entries}
                assert "error" not in statuses or ("ok" in statuses), (
                    f"unrecovered failure: {arch.name} x {shape.name} x {mesh}"
                )
                if shape.name == "long_500k" and not arch.supports_long_context:
                    assert "skipped" in statuses
                    n_skip += 1
                else:
                    assert "ok" in statuses, (arch.name, shape.name, mesh)
                    n_ok += 1
    assert n_ok == 66  # 40 cells x 2 meshes - 14 documented skips
    assert n_skip == 14


@pytest.mark.skipif(
    len(glob.glob(os.path.join(ART, "*.json"))) < 10,
    reason="dry-run artifacts not generated",
)
def test_roofline_terms_present_and_sane():
    for r in _records():
        if r.get("status") != "ok":
            continue
        rl = r["roofline"]
        for term in ("t_compute", "t_memory", "t_collective"):
            assert term in rl and rl[term] >= 0, (r["arch"], r["shape"], term)
        assert rl["dominant"] in ("compute", "memory", "collective")
        if r.get("kind") in ("train", "prefill"):
            assert rl["t_compute"] > 0
        if not r.get("analytic_only"):
            assert "fits" in r["memory"]


@pytest.mark.skipif(
    len(glob.glob(os.path.join(ART, "*.json"))) < 10,
    reason="dry-run artifacts not generated",
)
def test_optimized_cells_fit():
    """Every train/decode cell has at least one artifact variant that fits
    the 16 GB chip (the §Perf deliverable)."""
    recs = _records()
    by_cell = {}
    for r in recs:
        if r.get("status") != "ok" or r.get("analytic_only"):
            continue
        key = (r["arch"], r["shape"], r["mesh"])
        by_cell.setdefault(key, []).append(r["memory"]["fits"])
    for (arch, shape, mesh), fits in by_cell.items():
        if mesh != "pod16x16" or arch.startswith("kyiv"):
            continue
        assert any(fits), f"no fitting variant for {arch} x {shape} x {mesh}"
