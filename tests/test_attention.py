"""Attention cores vs a naive dense reference (GQA, causal, windowed,
decode, distinct v head_dim for MLA)."""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.models.layers.attention import (
    chunked_attention,
    decode_attention,
    local_attention,
)
from repro.models.layers.rope import apply_rope


def naive(q, k, v, causal=True, window=0, q_offset=0):
    b, sq, h, hd = q.shape
    skv, n_kv = k.shape[1], k.shape[2]
    g = h // n_kv
    qq = q.reshape(b, sq, n_kv, g, hd).astype(np.float32)
    s = np.einsum("bqkgd,bckd->bqkgc", qq, k.astype(np.float32)) * hd**-0.5
    qpos = q_offset + np.arange(sq)
    kpos = np.arange(skv)
    mask = np.ones((sq, skv), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window:
        mask &= kpos[None, :] > qpos[:, None] - window
    s = np.where(mask[None, :, None, None, :], s, -1e30)
    p = np.exp(s - s.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    o = np.einsum("bqkgc,bckd->bqkgd", p, v.astype(np.float32))
    return o.reshape(b, sq, h, v.shape[-1])


RNG = np.random.default_rng(0)


@pytest.mark.parametrize(
    "b,s,h,kv,hd,causal",
    [(2, 37, 4, 2, 16, True), (1, 128, 8, 8, 8, True),
     (2, 64, 4, 1, 16, False), (1, 200, 6, 3, 32, True)],
)
def test_chunked_attention(b, s, h, kv, hd, causal):
    q = RNG.standard_normal((b, s, h, hd)).astype(np.float32)
    k = RNG.standard_normal((b, s, kv, hd)).astype(np.float32)
    v = RNG.standard_normal((b, s, kv, hd)).astype(np.float32)
    out = chunked_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                            causal=causal, block_q=32, block_k=32)
    np.testing.assert_allclose(np.asarray(out), naive(q, k, v, causal=causal),
                               rtol=1e-5, atol=1e-5)


def test_chunked_attention_distinct_v_dim():
    b, s, h, kv, hd, hdv = 2, 40, 4, 2, 24, 16
    q = RNG.standard_normal((b, s, h, hd)).astype(np.float32)
    k = RNG.standard_normal((b, s, kv, hd)).astype(np.float32)
    v = RNG.standard_normal((b, s, kv, hdv)).astype(np.float32)
    out = chunked_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                            block_q=16, block_k=16)
    assert out.shape == (b, s, h, hdv)
    np.testing.assert_allclose(np.asarray(out), naive(q, k, v), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize(
    "b,s,w,blk", [(2, 100, 16, 16), (1, 256, 64, 32), (2, 77, 24, 32), (1, 64, 200, 16)]
)
def test_local_attention(b, s, w, blk):
    h, kv, hd = 4, 2, 16
    q = RNG.standard_normal((b, s, h, hd)).astype(np.float32)
    k = RNG.standard_normal((b, s, kv, hd)).astype(np.float32)
    v = RNG.standard_normal((b, s, kv, hd)).astype(np.float32)
    out = local_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                          window=w, block=blk)
    np.testing.assert_allclose(np.asarray(out), naive(q, k, v, causal=True, window=w),
                               rtol=1e-5, atol=1e-5)


def test_decode_attention_lengths_and_window():
    b, L, h, kv, hd = 3, 64, 4, 2, 16
    q = RNG.standard_normal((b, 1, h, hd)).astype(np.float32)
    kc = RNG.standard_normal((b, L, kv, hd)).astype(np.float32)
    vc = RNG.standard_normal((b, L, kv, hd)).astype(np.float32)
    lengths = np.array([10, 64, 33])
    out = decode_attention(jnp.asarray(q), jnp.asarray(kc), jnp.asarray(vc),
                           jnp.asarray(lengths))
    outw = decode_attention(jnp.asarray(q), jnp.asarray(kc), jnp.asarray(vc),
                            jnp.asarray(lengths), window=8)
    for i in range(b):
        ref = naive(q[i:i+1], kc[i:i+1, :lengths[i]], vc[i:i+1, :lengths[i]], causal=False)
        np.testing.assert_allclose(np.asarray(out)[i, 0], ref[0, 0], rtol=1e-5, atol=1e-5)
        lo = max(0, lengths[i] - 8)
        refw = naive(q[i:i+1], kc[i:i+1, lo:lengths[i]], vc[i:i+1, lo:lengths[i]], causal=False)
        np.testing.assert_allclose(np.asarray(outw)[i, 0], refw[0, 0], rtol=1e-5, atol=1e-5)


def test_rope_properties():
    b, s, h, hd = 1, 16, 2, 8
    x = RNG.standard_normal((b, s, h, hd)).astype(np.float32)
    pos = np.broadcast_to(np.arange(s), (b, s))
    out = np.asarray(apply_rope(jnp.asarray(x), jnp.asarray(pos), 10000.0))
    # rotation preserves norms
    np.testing.assert_allclose(
        np.linalg.norm(out, axis=-1), np.linalg.norm(x, axis=-1), rtol=1e-5
    )
    # position 0 is identity
    np.testing.assert_allclose(out[:, 0], x[:, 0], rtol=1e-6)
    # relative property: <rope(q,i), rope(k,j)> depends only on i-j
    q = RNG.standard_normal((1, 1, 1, hd)).astype(np.float32)
    k = RNG.standard_normal((1, 1, 1, hd)).astype(np.float32)

    def dot(i, j):
        qi = apply_rope(jnp.asarray(q), jnp.full((1, 1), i), 10000.0)
        kj = apply_rope(jnp.asarray(k), jnp.full((1, 1), j), 10000.0)
        return float(np.asarray(qi[0, 0, 0] @ kj[0, 0, 0].T))

    np.testing.assert_allclose(dot(5, 3), dot(12, 10), rtol=1e-4)
