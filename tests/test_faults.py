"""Chaos tests: the fault-injection harness driving the service's
robustness machinery.

Every scenario asserts convergence, not just survival: a killed/restarted
or degraded service must end up serving the same answer an undisturbed
cold ``mine()`` produces.
"""

import threading
import time

import numpy as np
import pytest

from repro.core import KyivConfig, mine
from repro.service import (
    DeadlineExceeded,
    DeviceFault,
    FaultInjector,
    KillPoint,
    MiningService,
    ResilienceConfig,
    placement_faults,
)


def _rand(seed, n, m, dom=4):
    return np.random.default_rng(seed).integers(0, dom, size=(n, m))


def _sets(result):
    return result.canonical_set()


FAST = ResilienceConfig(
    max_retries=2, backoff_s=0.001, failure_threshold=3, cooldown_s=60.0
)


# ---------------------------------------------------------------------------
# FaultInjector mechanics
# ---------------------------------------------------------------------------


def test_injector_times_and_after():
    inj = FaultInjector()
    inj.arm("site", action="raise", exc=DeviceFault("x"), times=2, after=1)
    inj.check("site")  # hit 1: skipped by after
    with pytest.raises(DeviceFault):
        inj.check("site")
    with pytest.raises(DeviceFault):
        inj.check("site")
    inj.check("site")  # fired out
    assert inj.hits("site") == 4 and inj.fired("site") == 2


def test_null_injector_refuses_arming():
    from repro.service.faults import NULL_INJECTOR

    with pytest.raises(RuntimeError):
        NULL_INJECTOR.arm("site")
    assert NULL_INJECTOR.check("anything") is None


# ---------------------------------------------------------------------------
# Kill mid-mine -> resume from level checkpoint
# ---------------------------------------------------------------------------


def test_kill_mid_mine_resumes_from_checkpoint(tmp_path):
    data = _rand(0, 150, 6, 4)
    cfg = dict(tau=2, kmax=4)
    undisturbed = mine(data, KyivConfig(**cfg))

    d = str(tmp_path / "wal")
    inj = FaultInjector()
    svc = MiningService(engine="numpy", wal_dir=d, fault_injector=inj)
    svc.append(data)
    # die at the second level boundary — after its checkpoint was saved
    inj.arm("mine.level_end", action="raise", exc=KillPoint("mid-mine"), after=1)
    with pytest.raises(KillPoint):
        svc.mine(**cfg)
    svc.close()

    # "restart": a fresh process over the same directory resumes the job
    svc2 = MiningService(engine="numpy", wal_dir=d)
    assert svc2.stats()["durability"]["resumed_jobs"] == 1
    r = svc2.mine(**cfg)  # coalesces onto the resumed run
    assert r.info.get("resumed_from_level", 0) >= 3
    assert _sets(r.result) == _sets(undisturbed)
    svc2.close()


def test_completed_job_leaves_no_checkpoints(tmp_path):
    import os

    d = str(tmp_path / "wal")
    svc = MiningService(engine="numpy", wal_dir=d)
    svc.append(_rand(0, 80, 5, 4))
    svc.mine(tau=2, kmax=3)
    jobs = os.path.join(d, "jobs")
    assert not os.path.isdir(jobs) or os.listdir(jobs) == []
    svc.close()


# ---------------------------------------------------------------------------
# Flaky / dead device -> retry, degrade, recover
# ---------------------------------------------------------------------------


def test_flaky_device_retries_then_succeeds():
    data = _rand(1, 100, 5, 4)
    inj = FaultInjector()
    svc = MiningService.from_dataset(
        data, engine="jnp", interpret=True, fault_injector=inj, resilience=FAST
    )
    with placement_faults(inj):
        inj.arm("placement.dispatch", exc=DeviceFault("transient"), times=1)
        r = svc.mine(tau=2, kmax=3)
    assert svc.device_retries == 1 and svc.degraded_mines == 0
    assert svc.breaker.state == "closed"
    assert _sets(r.result) == _sets(mine(data, KyivConfig(tau=2, kmax=3, engine="numpy")))
    svc.close()


def test_dead_device_degrades_to_host_and_breaker_opens():
    data = _rand(2, 100, 5, 4)
    inj = FaultInjector()
    svc = MiningService.from_dataset(
        data, engine="jnp", interpret=True, fault_injector=inj, resilience=FAST
    )
    with placement_faults(inj):
        inj.arm("placement.dispatch", exc=DeviceFault("dead"), times=10_000)
        r = svc.mine(tau=2, kmax=3)
        assert r.info.get("degraded") == "host"
        assert svc.breaker.state == "open"
        assert svc.readiness() == (False, "circuit_breaker_open")
        # with the breaker open, further requests go straight to the host
        # path without touching the device
        hits_before = inj.hits("placement.dispatch")
        r2 = svc.mine(tau=2, kmax=4)
        assert inj.hits("placement.dispatch") == hits_before
        assert r2.info.get("degraded") == "host"
    cold = mine(data, KyivConfig(tau=2, kmax=4, engine="numpy"))
    assert _sets(r2.result) == _sets(cold)
    stats = svc.stats()["resilience"]
    assert stats["state"] == "open" and stats["degraded_mines"] == 2
    svc.close()


def test_breaker_cooldown_allows_device_recovery():
    data = _rand(3, 90, 5, 4)
    inj = FaultInjector()
    res = ResilienceConfig(
        max_retries=1, backoff_s=0.001, failure_threshold=2, cooldown_s=0.05
    )
    svc = MiningService.from_dataset(
        data, engine="jnp", interpret=True, fault_injector=inj, resilience=res
    )
    with placement_faults(inj):
        inj.arm("placement.dispatch", exc=DeviceFault("dead"), times=10_000)
        svc.mine(tau=2, kmax=3)
        assert svc.breaker.state == "open"
        inj.disarm("placement.dispatch")  # the device "comes back"
        time.sleep(0.06)
        assert svc.breaker.state == "half_open"
        svc.cache.clear()
        r = svc.mine(tau=2, kmax=3)  # the probe: runs on-device, closes
    assert svc.breaker.state == "closed"
    assert r.info.get("degraded") is None
    assert svc.readiness() == (True, "ok")
    svc.close()


# ---------------------------------------------------------------------------
# Deadlines and cancellation
# ---------------------------------------------------------------------------


def test_deadline_returns_partial_and_does_not_wedge(tmp_path):
    data = _rand(4, 120, 6, 4)
    inj = FaultInjector()
    svc = MiningService(
        engine="numpy", wal_dir=str(tmp_path / "wal"), fault_injector=inj
    )
    svc.append(data)
    # each level boundary stalls 0.25s; a 0.1s deadline trips at the first
    # batch/level check after it expires
    inj.arm("mine.level_end", action="sleep", seconds=0.25, times=100)
    t0 = time.monotonic()
    r = svc.mine(tau=1, kmax=5, deadline_s=0.1)
    elapsed = time.monotonic() - t0
    assert r.source == "partial"
    assert r.info["interrupted"] == "deadline"
    assert not r.result.completed
    assert elapsed < 2.0  # deadline + one stalled boundary, not the full run
    # partial answers are never cached and the scheduler is not wedged
    inj.reset()
    r2 = svc.mine(tau=1, kmax=5)
    assert r2.source == "cold" and r2.result.completed
    undisturbed = mine(data, KyivConfig(tau=1, kmax=5))
    assert _sets(r2.result) == _sets(undisturbed)
    svc.close()


def test_cancel_stops_inflight_run(tmp_path):
    data = _rand(5, 120, 6, 4)
    inj = FaultInjector()
    svc = MiningService(
        engine="numpy", wal_dir=str(tmp_path / "wal"), fault_injector=inj
    )
    svc.append(data)
    inj.arm("mine.level_end", action="sleep", seconds=0.25, times=100)
    out = {}

    def run():
        out["resp"] = svc.mine(tau=1, kmax=5)

    t = threading.Thread(target=run)
    t.start()
    time.sleep(0.1)  # let the run reach its first stalled boundary
    assert svc.cancel(1, 5)["cancelled"] == 1
    t.join(timeout=10)
    assert out["resp"].source == "partial"
    assert out["resp"].info["interrupted"] == "cancelled"
    svc.close()


def test_coalesced_waiter_deadline(tmp_path):
    """A deadline-free initiator keeps its run; a coalesced waiter with a
    deadline gets DeadlineExceeded instead of blocking on the shared run."""
    data = _rand(6, 120, 6, 4)
    inj = FaultInjector()
    svc = MiningService(
        engine="numpy",
        wal_dir=str(tmp_path / "wal"),
        fault_injector=inj,
        deadline_grace_s=0.05,
    )
    svc.append(data)
    inj.arm("mine.level_end", action="sleep", seconds=0.4, times=3)
    out = {}

    def initiator():
        out["resp"] = svc.mine(tau=1, kmax=5)

    t = threading.Thread(target=initiator)
    t.start()
    time.sleep(0.1)
    with pytest.raises(DeadlineExceeded):
        svc.mine(tau=1, kmax=5, deadline_s=0.05)
    t.join(timeout=30)
    assert out["resp"].result.completed  # the initiator was unaffected
    svc.close()


def test_kill_mid_mine_then_recovery_converges_with_appends(tmp_path):
    """Full chaos loop: append, die mid-mine, restart, append more, mine —
    the final answer matches an undisturbed cold run over all the rows."""
    a, b = _rand(7, 100, 5, 4), _rand(8, 40, 5, 4)
    d = str(tmp_path / "wal")
    inj = FaultInjector()
    svc = MiningService(engine="numpy", wal_dir=d, fault_injector=inj)
    svc.append(a)
    inj.arm("mine.level_end", action="raise", exc=KillPoint("die"), after=1)
    with pytest.raises(KillPoint):
        svc.mine(tau=2, kmax=4)
    svc.close()

    svc2 = MiningService(engine="numpy", wal_dir=d)
    svc2.append(b)  # moves past the dead job's version
    r = svc2.mine(tau=2, kmax=4)
    undisturbed = mine(np.concatenate([a, b]), KyivConfig(tau=2, kmax=4))
    assert _sets(r.result) == _sets(undisturbed)
    svc2.close()
