"""Telemetry substrate: metrics registry, trace spans, logs, profiling.

Covers the observability contracts:

* registry semantics (types, labels, conflicts, collectors) and the
  Prometheus text exposition (linted by the same validator CI uses),
* span trees — nesting, sampling, the ring buffer, contextvar propagation
  across the scheduler's worker-thread hop,
* span timings agreeing with the per-level ``LevelStats`` clocks on the
  host and device paths (and on a forced 8-device mesh, in a subprocess),
* the un-tearable ``/stats``/scrape snapshot with a mine in flight,
* HTTP: ``/metrics`` (>= 20 families, lint-clean), ``X-Trace-Id``
  correlation, ``GET /trace``, JSON logs carrying the trace id,
* the opt-in profiling hook.
"""

import io
import json
import logging
import os
import re
import subprocess
import sys
import threading
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.obs import logs as obs_logs
from repro.obs import metrics as om
from repro.obs.metrics import MetricsRegistry, lint_exposition
from repro.obs.trace import TRACER, Tracer, current_trace_id

def _rand(seed, n, m, dom=5):
    return np.random.default_rng(seed).integers(0, dom, size=(n, m))


@pytest.fixture()
def tracer_reset():
    """Restore the process-wide tracer's config + ring buffer after a test."""
    yield TRACER
    TRACER.configure(max_traces=64, sample_every=1, sync_devices=False)
    TRACER.reset()


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_counter_gauge_histogram_basics():
    reg = MetricsRegistry()
    c = reg.counter("t_requests_total", "req", ("route",))
    c.inc(route="/mine")
    c.inc(2, route="/mine")
    c.inc(route="/stats")
    assert c.value(route="/mine") == 3
    assert c.value(route="/stats") == 1
    assert c.value(route="/never") == 0
    with pytest.raises(ValueError):
        c.inc(-1, route="/mine")
    with pytest.raises(ValueError):
        c.inc(path="/mine")  # wrong label name

    g = reg.gauge("t_depth", "depth")
    g.set(4)
    g.add(-1.5)
    assert g.value() == 2.5

    h = reg.histogram("t_latency_seconds", "lat", buckets=(0.1, 1.0, 10.0))
    for v in (0.05, 0.5, 0.5, 5.0, 50.0):
        h.observe(v)
    s = h.series()
    assert s["count"] == 5
    assert s["sum"] == pytest.approx(56.05)
    # cumulative per le: 0.1 -> 1, 1.0 -> 3, 10.0 -> 4, +Inf -> 5
    assert [c for _, c in s["buckets"]] == [1, 3, 4, 5]


def test_registry_rejects_conflicting_reregistration():
    reg = MetricsRegistry()
    reg.counter("t_x_total", "x")
    with pytest.raises(ValueError):
        reg.gauge("t_x_total", "x")  # same name, different type
    with pytest.raises(ValueError):
        reg.counter("t_x_total", "x", ("route",))  # different labels
    # identical re-registration returns the same family object
    assert reg.counter("t_x_total", "x") is reg.counter("t_x_total", "x")
    with pytest.raises(ValueError):
        reg.counter("0bad name", "x")


def test_render_is_lint_clean_and_snapshot_agrees():
    reg = MetricsRegistry()
    reg.counter("t_served_total", "served", ("route",)).inc(route="/mine")
    reg.gauge("t_ready", "ready").set(1)
    h = reg.histogram("t_wall_seconds", "wall", buckets=(0.01, 1.0))
    h.observe(0.5)
    text = reg.render()
    assert lint_exposition(text) == []
    assert '# TYPE t_served_total counter' in text
    assert 't_wall_seconds_bucket{le="+Inf"} 1' in text
    snap = reg.snapshot()
    assert snap["t_served_total"]["values"]["/mine"] == 1
    assert snap["t_wall_seconds"]["values"][""]["count"] == 1


def test_lint_catches_bad_expositions():
    assert lint_exposition("# TYPE bad_counter counter\nbad_counter 3\n")
    assert lint_exposition("orphan_sample 1\n")  # sample before TYPE
    assert lint_exposition(
        "# TYPE h histogram\n"
        'h_bucket{le="0.1"} 5\nh_bucket{le="+Inf"} 3\n'  # decreasing
    )


def test_named_collectors_replace_and_owner_checked_unregister():
    reg = MetricsRegistry()
    g = reg.gauge("t_mirror", "mirrored")
    calls = []

    def c1():
        calls.append("c1")
        g.set(1)

    def c2():
        calls.append("c2")
        g.set(2)

    reg.register_collector("svc", c1)
    reg.render()
    assert calls == ["c1"]
    reg.register_collector("svc", c2)  # replacement takes over the slot
    reg.render()
    assert calls == ["c1", "c2"]
    reg.unregister_collector("svc", c1)  # stale owner: must NOT evict c2
    reg.render()
    assert calls[-1] == "c2"
    reg.unregister_collector("svc", c2)
    calls.clear()
    reg.render()
    assert calls == []


def test_broken_collector_never_fails_the_scrape():
    reg = MetricsRegistry()

    def boom():
        raise RuntimeError("collector bug")

    reg.register_collector("bad", boom)
    assert lint_exposition(reg.render()) == []
    assert reg.collector_errors == 1


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------


def test_span_nesting_parent_ids_and_tree():
    tr = Tracer(max_traces=4)
    with tr.start("req") as root:
        with tr.span("outer", k=2) as outer:
            with tr.span("inner"):
                pass
        assert current_trace_id() == root.trace_id
    trace = tr.last(1)[0]
    outer_sp = trace.find("outer")[0]
    inner_sp = trace.find("inner")[0]
    assert outer_sp.parent_id == trace.root.span_id
    assert inner_sp.parent_id == outer_sp.span_id
    assert outer_sp.attrs == {"k": 2}
    d = trace.to_dict()
    assert d["spans"][0]["name"] == "req"
    assert d["spans"][0]["children"][0]["name"] == "outer"
    assert d["spans"][0]["children"][0]["children"][0]["name"] == "inner"
    assert tr.get(root.trace_id) is trace
    assert tr.get("nope") is None


def test_nested_start_trace_joins_the_outer_trace():
    tr = Tracer()
    with tr.start("outer"):
        with tr.start("inner") as sp:  # nests, does not mint a second trace
            sp.set(tag=1)
    assert len(tr.last(10)) == 1
    trace = tr.last(1)[0]
    assert [s.name for s in trace.find("inner")] == ["inner"]
    assert trace.find("inner")[0].parent_id == trace.root.span_id


def test_sampling_and_ring_buffer():
    tr = Tracer(max_traces=3, sample_every=2)
    for _ in range(8):
        with tr.start("req"):
            with tr.span("work"):
                pass
    st = tr.stats()
    assert st["started"] == 8 and st["sampled_out"] == 4
    assert st["stored"] == 3  # ring buffer keeps only the newest 3


def test_ring_overflow_counted_and_paging_never_duplicates():
    """Satellite regression: evictions are observable (``dropped``) and the
    seq-keyed pages of ``GET /trace`` neither overlap nor skip entries."""
    tr = Tracer(max_traces=4, sample_every=1)
    for i in range(10):
        with tr.start("req", meta={"i": i}):
            pass
    st = tr.stats()
    assert st["appended"] == 10 and st["dropped"] == 6 and st["stored"] == 4

    # newest-first pages keyed by seq: churn between page fetches must not
    # re-serve an already-seen trace
    page1, cursor = tr.page(2)
    assert [t.seq for t in page1] == [9, 8] and cursor == 8
    with tr.start("req"):  # churn evicts seq 6 between pages
        pass
    page2, cursor2 = tr.page(2, before=cursor)
    assert [t.seq for t in page2] == [7], [t.seq for t in page2]
    assert cursor2 is None  # ring exhausted: no further page
    seen = {t.seq for t in page1} | {t.seq for t in page2}
    assert len(seen) == 3  # no duplicates across pages

    tr.reset()
    assert tr.stats()["dropped"] == 0 and tr.stats()["appended"] == 0


def test_trace_dropped_counter_in_stats_and_scrape(tracer_reset):
    from repro.service import MiningService

    svc = MiningService.from_dataset(_rand(0, 60, 3))
    try:
        TRACER.configure(max_traces=2)
        for tau in (1, 2, 3, 1, 2):
            svc.mine(tau=tau, kmax=2)
        assert svc.stats()["obs"]["traces"]["dropped"] >= 3
        text = om.REGISTRY.render()
        assert lint_exposition(text) == []
        m = re.search(r"^repro_trace_dropped_total (\d+)", text, re.M)
        assert m and int(m.group(1)) >= 3
    finally:
        svc.close()


def test_span_is_noop_without_active_trace():
    tr = Tracer()
    assert current_trace_id() is None
    with tr.span("orphan") as sp:
        sp.set(ignored=True)  # must not raise
    assert tr.last(10) == []
    assert current_trace_id() is None


def test_scheduler_propagates_trace_context(tracer_reset):
    """The worker-thread hop must carry the active span (copy_context)."""
    from repro.service import RequestScheduler

    sched = RequestScheduler()
    try:
        with TRACER.start("req") as root:
            seen = sched.submit("k", lambda: current_trace_id()).result()
        assert seen == root.trace_id
        # outside any trace the worker sees none either
        assert sched.submit("k2", lambda: current_trace_id()).result() is None
    finally:
        sched.shutdown()


# ---------------------------------------------------------------------------
# span tree vs LevelStats clocks (host + device paths)
# ---------------------------------------------------------------------------


def _mine_traced(engine):
    from repro.core import KyivConfig, mine

    D = _rand(3, 300, 6)
    with TRACER.start("test.mine"):
        result = mine(D, KyivConfig(tau=1, kmax=3, engine=engine))
    return result, TRACER.last(1)[0]


def _check_spans_against_stats(result, trace):
    mine_span = trace.find("mine")[0]
    # the span tree must account for >=95% of the mine's wall time
    assert trace.coverage(mine_span) >= 0.95
    level_spans = sorted(trace.find("mine.level"), key=lambda s: s.t0)
    # level-1 singletons are classified during seeding (the "mine.seed"
    # span); every looped level k>=2 gets its own "mine.level" span
    stats_by_k = {ls.k: ls for ls in result.stats}
    looped = []
    for sp in level_spans:
        ls = stats_by_k[sp.attrs["k"]]
        looped.append(ls)
        # the span wraps the whole level iteration, including the LevelStats
        # bookkeeping itself, so it can only be >= the level's own clock
        assert sp.duration >= ls.time_total * 0.999
        # and it must stay in the same ballpark (not leak another level in)
        assert sp.duration <= ls.time_total * 1.35 + 0.15
    assert {ls.k for ls in looped} == {k for k in stats_by_k if k >= 2}
    # stage spans wrap exactly the regions the stage clocks time
    by_stage = {
        "frontier.candidates": sum(
            s.duration for s in trace.find("frontier.candidates")
        ),
        "intersect": sum(
            s.duration
            for s in trace.find("intersect.dispatch") + trace.find("intersect.sync")
        ),
        "classify": sum(s.duration for s in trace.find("level.classify")),
    }
    clocks = {
        "frontier.candidates": sum(ls.time_candidates for ls in looped),
        "intersect": sum(ls.time_intersect for ls in looped),
        "classify": sum(ls.time_classify for ls in looped),
    }
    for stage, spanned in by_stage.items():
        assert spanned >= clocks[stage] * 0.95 - 0.01, (stage, spanned, clocks)
        assert spanned <= clocks[stage] * 1.35 + 0.15, (stage, spanned, clocks)


def test_span_tree_matches_level_stats_host(tracer_reset):
    result, trace = _mine_traced("numpy")
    assert len(result.itemsets) > 0
    _check_spans_against_stats(result, trace)


def test_span_tree_matches_level_stats_device(tracer_reset):
    result, trace = _mine_traced("jnp")
    _check_spans_against_stats(result, trace)


_MESH_OBS_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, sys.argv[1])
import numpy as np
import jax
from repro.core import KyivConfig, MeshPlacement, mine
from repro.obs.trace import TRACER

rng = np.random.default_rng(13)
D = rng.integers(0, 5, size=(200, 7))
ref = mine(D, KyivConfig(tau=2, kmax=4, engine="numpy"))
mesh = jax.make_mesh((2, 4), ("data", "model"))
p = MeshPlacement(mesh, pair_axes=("data",), word_axis="model")
with TRACER.start("mesh.mine"):
    got = mine(D, KyivConfig(tau=2, kmax=4, placement=p))
assert sorted(got.itemsets) == sorted(ref.itemsets)
trace = TRACER.last(1)[0]
mine_span = trace.find("mine")[0]
assert trace.coverage(mine_span) >= 0.95, trace.coverage(mine_span)
levels = trace.find("mine.level")
by_k = {ls.k: ls for ls in got.stats}
assert {sp.attrs["k"] for sp in levels} == {k for k in by_k if k >= 2}
for sp in levels:
    assert sp.duration >= by_k[sp.attrs["k"]].time_total * 0.999
from repro.obs import metrics as om
assert om.REGISTRY.counter(
    "repro_placement_dispatch_total", "", ("site", "kind")
).value(site="dispatch", kind="mesh") > 0
print("MESH_OBS_OK")
"""


@pytest.mark.slow
def test_mesh_span_tree_8dev():
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", _MESH_OBS_SCRIPT, src],
        capture_output=True, text=True, timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "MESH_OBS_OK" in proc.stdout


# ---------------------------------------------------------------------------
# torn-counter regression: scrape with a mine in flight
# ---------------------------------------------------------------------------


def _hist_count_agrees(text):
    """Every histogram series' +Inf cumulative bucket equals its _count —
    the invariant a torn (unlocked) scrape breaks."""
    inf = {}
    counts = {}
    for line in text.splitlines():
        m = re.match(r"(\w+)_bucket\{(.*)le=\"\+Inf\"\}\s+(\d+)", line)
        if m:
            inf[(m.group(1), re.sub(r'le="[^"]*",?', "", m.group(2)))] = int(
                m.group(3)
            )
        m = re.match(r"(\w+)_count(\{.*\})?\s+(\d+)", line)
        if m:
            labels = (m.group(2) or "{}").strip("{}")
            counts[(m.group(1), labels + ("," if labels else ""))] = int(
                m.group(3)
            )
    assert inf, "no histogram series rendered"
    for key, v in inf.items():
        name, labels = key
        ck = (name, labels)
        assert ck in counts and counts[ck] == v, (key, v, counts.get(ck))


def test_stats_and_scrape_are_not_torn_with_mine_in_flight(tracer_reset):
    from repro.service import MiningService

    svc = MiningService.from_dataset(_rand(0, 400, 5))
    stop = threading.Event()
    errors = []

    def churn():
        tau = 1
        try:
            while not stop.is_set():
                svc.mine(tau=tau, kmax=2 + (tau % 2))
                tau = tau % 3 + 1
        except Exception as e:  # pragma: no cover - surfaced via errors
            errors.append(e)

    t = threading.Thread(target=churn)
    t.start()
    try:
        prev_runs = -1.0
        for _ in range(30):
            stats = svc.stats()
            assert "obs" in stats and "metrics" in stats["obs"]
            runs = sum(
                stats["obs"]["metrics"]["repro_mine_runs_total"]["values"].values()
            )
            assert runs >= prev_runs  # counters never go backwards
            prev_runs = runs
            text = om.REGISTRY.render()
            assert lint_exposition(text) == []
            _hist_count_agrees(text)
    finally:
        stop.set()
        t.join(timeout=30)
        svc.close()
    assert not errors, errors


# ---------------------------------------------------------------------------
# HTTP: /metrics, /trace, request correlation, /stats compatibility
# ---------------------------------------------------------------------------


@pytest.fixture()
def obs_http_service(tracer_reset):
    from repro.launch.serve_miner import make_server
    from repro.service import MiningService

    svc = MiningService.from_dataset(_rand(0, 200, 4))
    server = make_server(svc, port=0)
    thread = threading.Thread(target=server.serve_forever, daemon=True)
    thread.start()
    yield svc, server.server_address[1]
    server.shutdown()
    server.server_close()
    svc.close()


def _req(port, path, payload=None, headers=None):
    url = f"http://127.0.0.1:{port}{path}"
    data = json.dumps(payload).encode() if payload is not None else None
    resp = urllib.request.urlopen(
        urllib.request.Request(url, data=data, headers=headers or {}), timeout=60
    )
    return resp, resp.read()


def test_http_metrics_exposition(obs_http_service):
    _, port = obs_http_service
    _req(port, "/mine", {"tau": 1, "kmax": 3})  # populate mining families
    resp, body = _req(port, "/metrics")
    assert resp.headers["Content-Type"].startswith("text/plain; version=0.0.4")
    text = body.decode()
    assert lint_exposition(text) == []
    families = {
        line.split()[2] for line in text.splitlines() if line.startswith("# TYPE")
    }
    assert len(families) >= 20, sorted(families)
    for required in (
        "repro_mine_wall_seconds",
        "repro_mine_level_seconds",
        "repro_placement_dispatch_total",
        "repro_service_mine_requests_total",
        "repro_http_requests_total",
        "repro_exec_cache_hits_total",
        "repro_result_cache_entries",
    ):
        assert required in families, required


def test_http_trace_correlation_and_retrieval(obs_http_service):
    _, port = obs_http_service
    resp, body = _req(port, "/mine", {"tau": 1, "kmax": 3})
    j = json.loads(body)
    tid = j["trace_id"]
    assert resp.headers["X-Trace-Id"] == tid

    # a client-supplied id is honoured and echoed
    resp2, body2 = _req(
        port, "/mine", {"tau": 1, "kmax": 3}, headers={"X-Trace-Id": "cafe0123"}
    )
    assert json.loads(body2)["trace_id"] == "cafe0123"
    assert resp2.headers["X-Trace-Id"] == "cafe0123"

    # the cold mine's span tree is retrievable and accounts for the request
    _, tb = _req(port, f"/trace?id={tid}")
    tree = json.loads(tb)["trace"]
    assert tree["trace_id"] == tid
    assert tree["coverage"] >= 0.95
    names = set()

    def walk(node):
        names.add(node["name"])
        for c in node["children"]:
            walk(c)

    for root in tree["spans"]:
        walk(root)
    assert {"http /mine", "service.mine", "mine.cold", "mine",
            "mine.level"} <= names, names

    _, lb = _req(port, "/trace?n=5")
    listing = json.loads(lb)
    assert len(listing["traces"]) >= 2
    assert listing["tracer"]["started"] >= 2

    with pytest.raises(urllib.error.HTTPError) as e:
        _req(port, "/trace?id=doesnotexist")
    assert e.value.code == 404


def test_http_stats_shape_backward_compatible(obs_http_service):
    _, port = obs_http_service
    _req(port, "/mine", {"tau": 1, "kmax": 2})
    _, body = _req(port, "/stats")
    stats = json.loads(body)
    # pre-existing sections consumed by dashboards / older clients
    for section in ("store", "cache", "scheduler", "placement", "served",
                    "executables", "resilience", "http"):
        assert section in stats, section
    assert stats["store"]["n_rows"] == 200
    # new obs fold-in rides alongside, not instead
    assert "metrics" in stats["obs"] and "traces" in stats["obs"]
    assert stats["obs"]["traces"]["started"] >= 1


def test_metrics_exempt_from_backpressure_but_auth_gated():
    from repro.launch.serve_miner import make_server
    from repro.service import MiningService

    svc = MiningService.from_dataset(_rand(0, 60, 3))
    server = make_server(svc, port=0, auth_token="tok", max_inflight=1)
    thread = threading.Thread(target=server.serve_forever, daemon=True)
    thread.start()
    port = server.server_address[1]
    try:
        with pytest.raises(urllib.error.HTTPError) as e:
            _req(port, "/metrics")
        assert e.value.code == 401
        resp, body = _req(
            port, "/metrics", headers={"Authorization": "Bearer tok"}
        )
        assert resp.status == 200 and b"# TYPE" in body
    finally:
        server.shutdown()
        server.server_close()
        svc.close()


# ---------------------------------------------------------------------------
# structured logs
# ---------------------------------------------------------------------------


@pytest.fixture()
def clean_repro_logger():
    logger = logging.getLogger("repro")
    had = list(logger.handlers)
    yield logger
    for h in list(logger.handlers):
        logger.removeHandler(h)
    for h in had:
        logger.addHandler(h)
    logger.propagate = True
    logger.setLevel(logging.NOTSET)


def test_json_logs_carry_trace_id(tracer_reset, clean_repro_logger):
    buf = io.StringIO()
    log = obs_logs.setup(level="info", json_mode=True, stream=buf)
    with TRACER.start("req") as root:
        log.info("access", extra={"route": "/mine", "code": 200})
    log.warning("later")  # outside the trace: no trace_id field
    lines = [json.loads(line) for line in buf.getvalue().splitlines()]
    assert lines[0]["msg"] == "access"
    assert lines[0]["trace_id"] == root.trace_id
    assert lines[0]["route"] == "/mine" and lines[0]["code"] == 200
    assert lines[0]["level"] == "info"
    assert "trace_id" not in lines[1]


def test_text_logs_carry_trace_id(tracer_reset, clean_repro_logger):
    buf = io.StringIO()
    log = obs_logs.setup(level="debug", json_mode=False, stream=buf)
    with TRACER.start("req") as root:
        log.debug("hello", extra={"k": 3})
    line = buf.getvalue().strip()
    assert f"trace_id={root.trace_id}" in line and "k=3" in line


# ---------------------------------------------------------------------------
# profiling hook
# ---------------------------------------------------------------------------


def test_profile_records_gauges_and_cache_delta(tmp_path):
    from repro.core import KyivConfig, mine
    from repro.obs import profile as obs_profile

    reg = MetricsRegistry()
    with obs_profile.profile(str(tmp_path / "xplane"), registry=reg) as prof:
        result = mine(_rand(1, 200, 5), KyivConfig(tau=1, kmax=3, engine="jnp"))
        prof.set_result(result)
    assert prof.wall_s is not None and prof.wall_s > 0
    assert set(prof.exec_cache_delta) == {"hits", "misses", "entries"}
    assert reg.gauge("repro_profile_last_wall_seconds", "").value() == pytest.approx(
        prof.wall_s
    )
    assert reg.gauge("repro_profile_levels_retired", "").value() == len(result.stats)
    runs = reg.counter("repro_profile_runs_total", "", ("profiler",))
    assert runs.value(profiler="xplane") + runs.value(profiler="off") == 1


def test_profile_without_dump_dir_is_gauges_only():
    from repro.obs import profile as obs_profile

    reg = MetricsRegistry()
    with obs_profile.profile(registry=reg) as prof:
        pass
    assert prof.profiler_active is False
    assert prof.wall_s is not None
    assert reg.counter(
        "repro_profile_runs_total", "", ("profiler",)
    ).value(profiler="off") == 1
