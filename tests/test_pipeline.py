"""Pipeline parallelism: GPipe schedule == sequential stage stack (subprocess
with a 4-stage mesh), plus bubble-fraction math."""

import os
import subprocess
import sys

import pytest

from repro.distributed.pipeline import bubble_fraction


def test_bubble_fraction():
    assert bubble_fraction(1, 8) == 0.0
    assert abs(bubble_fraction(4, 13) - 3 / 16) < 1e-12
    assert bubble_fraction(4, 4) == 3 / 7


_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, sys.argv[1])
import numpy as np
import jax, jax.numpy as jnp
from repro.distributed.pipeline import pipeline_forward

mesh = jax.make_mesh((4,), ("stage",))
S, D = 4, 16
rng = np.random.default_rng(0)
stage_params = {"w": jnp.asarray(rng.standard_normal((S, D, D)), jnp.float32) * 0.3,
                "b": jnp.asarray(rng.standard_normal((S, D)), jnp.float32) * 0.1}

def stage_fn(p, x):
    return jnp.tanh(x @ p["w"] + p["b"])

x = jnp.asarray(rng.standard_normal((8 * 4, D)), jnp.float32)  # 8 microbatches
fwd = pipeline_forward(mesh, stage_fn, n_micro=8)
with jax.set_mesh(mesh):
    y = fwd(stage_params, x)

# sequential reference
ref = x
for s in range(S):
    ref = jnp.tanh(ref @ stage_params["w"][s] + stage_params["b"][s])
np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-5, atol=1e-5)
print("PIPELINE_OK")
"""


@pytest.mark.slow
def test_pipeline_matches_sequential_4dev():
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT, src],
        capture_output=True, text=True, timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "PIPELINE_OK" in proc.stdout
