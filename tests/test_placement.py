"""The placement layer: one factory, three placements, bit-identical mining.

Single-device coverage lives here (host + device placements, the factory,
store word-tile alignment, executable-bucket sharing); the mesh placement's
multi-device behaviour is exercised in subprocesses by
tests/test_sharded_driver.py and tests/test_mesh_service.py.
"""

import numpy as np
import pytest

from repro.core import (
    DevicePlacement,
    HostPlacement,
    KyivConfig,
    MeshPlacement,
    make_placement,
    mine,
    resolve_placement,
)
from repro.kernels.intersect import LevelPipeline, reset_executable_cache
from repro.kernels.intersect.ops import EXEC_CACHE
from repro.service import DatasetStore

RNG = np.random.default_rng(21)


# ---------------------------------------------------------------------------
# factory
# ---------------------------------------------------------------------------


def test_make_placement_kinds():
    assert make_placement("numpy").kind == "host"
    assert make_placement("host").kind == "host"
    for eng in ("jnp", "pallas"):
        p = make_placement(eng, interpret=True, indexed=False)
        assert (p.kind, p.engine, p.indexed) == ("device", eng, False)
    with pytest.raises(ValueError):
        make_placement("mesh")
    with pytest.raises(ValueError):
        DevicePlacement("numpy")


def test_resolve_placement_precedence():
    # engine string drives the default...
    assert resolve_placement(KyivConfig(engine="numpy")).kind == "host"
    assert resolve_placement(KyivConfig(engine="pallas")).engine == "pallas"
    # ...an explicit placement object wins over the engine...
    p = HostPlacement()
    assert resolve_placement(KyivConfig(engine="pallas", placement=p)) is p
    # ...and a placement *string* resolves through the same factory
    assert resolve_placement(KyivConfig(engine="numpy", placement="jnp")).engine == "jnp"


def test_describe_is_json_friendly():
    import json

    for p in (HostPlacement(), make_placement("jnp"), make_placement("pallas")):
        d = p.describe()
        assert d["kind"] in ("host", "device")
        json.dumps(d)  # /stats serialises this


# ---------------------------------------------------------------------------
# mining equivalence: every placement is bit-identical to the host reference
# ---------------------------------------------------------------------------


def _stat_tuple(s):
    return (s.k, s.candidates, s.support_pruned, s.bound_pruned,
            s.intersections, s.emitted, s.skipped_absent_uniform, s.stored)


@pytest.mark.parametrize("engine", ["numpy", "jnp", "pallas"])
def test_mine_with_explicit_placement_matches_engine_string(engine):
    D = RNG.integers(0, 4, size=(70, 5))
    cfg = KyivConfig(tau=2, kmax=3, engine=engine)
    via_engine = mine(D, cfg)
    via_placement = mine(D, KyivConfig(tau=2, kmax=3, placement=make_placement(engine)))
    assert sorted(via_engine.itemsets) == sorted(via_placement.itemsets)
    assert list(map(_stat_tuple, via_engine.stats)) == list(
        map(_stat_tuple, via_placement.stats)
    )


def test_placement_string_in_config():
    D = RNG.integers(0, 4, size=(60, 4))
    ref = mine(D, KyivConfig(tau=1, kmax=3))
    got = mine(D, KyivConfig(tau=1, kmax=3, placement="pallas"))
    assert sorted(ref.itemsets) == sorted(got.itemsets)


# ---------------------------------------------------------------------------
# LevelPipeline is placement-generic
# ---------------------------------------------------------------------------


def _mk_level(t=12, W=64, M=33):
    bits = RNG.integers(0, 2**32, size=(t, W), dtype=np.uint32) & RNG.integers(
        0, 2**32, size=(t, W), dtype=np.uint32
    )
    pairs = RNG.integers(0, t, size=(M, 2)).astype(np.int32)
    from repro.core.bitops import popcount_rows

    return bits, pairs, popcount_rows(bits)


def test_level_pipeline_placement_vs_engine_kwarg():
    """The legacy engine= kwarg and an explicit placement give identical
    batches (the compat path resolves through the same factory)."""
    bits, pairs, pc = _mk_level()
    for engine in ("numpy", "jnp", "pallas"):
        a = LevelPipeline(bits, pc, tau=3, engine=engine).submit(pairs, True).result()
        b = (
            LevelPipeline(bits, pc, tau=3, placement=make_placement(engine))
            .submit(pairs, True)
            .result()
        )
        assert np.array_equal(a[0], b[0])
        assert np.array_equal(a[1], b[1])
        assert np.array_equal(a[2], b[2])


def test_level_pipeline_has_no_engine_branches():
    """The pipeline's orchestration is placement-blind: everything
    engine-specific is reachable only through the placement object."""
    import inspect

    src = inspect.getsource(LevelPipeline)
    for needle in ('== "numpy"', '== "jnp"', '== "pallas"', "self.engine"):
        assert needle not in src, f"engine branch {needle} back in LevelPipeline"


def test_device_placement_shares_executable_buckets():
    """Two pipelines over same-shaped levels share EXEC_CACHE entries."""
    reset_executable_cache()
    bits, pairs, pc = _mk_level()
    LevelPipeline(bits, pc, tau=2, placement=make_placement("jnp")).submit(
        pairs, True
    ).result()
    first = EXEC_CACHE.stats()
    assert first["misses"] >= 1
    LevelPipeline(bits, pc, tau=2, placement=make_placement("jnp")).submit(
        pairs, True
    ).result()
    second = EXEC_CACHE.stats()
    assert second["hits"] > first["hits"]
    assert second["entries"] == first["entries"]


# ---------------------------------------------------------------------------
# store word-tile alignment
# ---------------------------------------------------------------------------


class _FakeShardedPlacement(HostPlacement):
    """Host semantics but a mesh-like word tile, so alignment is testable
    without multiple devices."""

    store_word_tile = 12


def test_store_aligns_word_tile_to_placement():
    store = DatasetStore(3, word_tile=8, placement=_FakeShardedPlacement())
    assert store.word_tile == 24  # lcm(8, 12)
    store.append(RNG.integers(0, 4, size=(40, 3)))
    assert store.n_words % 24 == 0
    # the resident copy is produced by the placement (host: numpy passthrough)
    dev = store.device_bits()
    assert isinstance(dev, np.ndarray) and dev.shape[1] == store.n_words


def test_store_device_bits_version_pinning():
    store = DatasetStore(3, placement=HostPlacement())
    store.append(RNG.integers(0, 4, size=(10, 3)))
    v = store.version
    assert store.device_bits(v) is not None
    store.append(RNG.integers(0, 4, size=(5, 3)))
    assert store.device_bits(v) is None  # stale pin -> caller re-snapshots


def test_mesh_from_spec_parsing():
    from repro.launch.mesh import mesh_from_spec

    assert dict(mesh_from_spec("1x1").shape) == {"data": 1, "model": 1}
    assert dict(mesh_from_spec("1").shape) == {"data": 1, "model": 1}
    for bad in ("4x", "x4", "0x1", "1x2x3", "", "axb"):
        with pytest.raises(ValueError):
            mesh_from_spec(bad)


def test_mesh_placement_describe_without_devices():
    """MeshPlacement metadata works on however many devices exist (1 here)."""
    import jax

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    p = MeshPlacement(mesh, pair_axes=("data",), word_axis="model")
    d = p.describe()
    assert d["kind"] == "mesh" and d["word_shards"] == 1 and d["pair_shards"] == 1
    assert p.store_word_tile == 1
    # degenerate 1x1 mesh still mines correctly through the generic pipeline
    D = RNG.integers(0, 4, size=(50, 4))
    ref = mine(D, KyivConfig(tau=1, kmax=3))
    got = mine(D, KyivConfig(tau=1, kmax=3, placement=p))
    assert sorted(ref.itemsets) == sorted(got.itemsets)
