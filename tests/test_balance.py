"""Load balancing (§4.4.4): Example 4.10 golden + balance properties."""

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.balance import balanced_blocks, greedy_assign, pair_work_per_unit
from repro.core.prefix import Level


def test_example_410_k2():
    """5 items at level 1, 3 threads -> T = {4, 3, 3}."""
    level = Level(
        k=1,
        itemsets=np.arange(5, dtype=np.int32)[:, None],
        counts=np.ones(5, np.int64),
        bits=None,
    )
    work = pair_work_per_unit(level.itemsets)
    assert work.tolist() == [4, 3, 2, 1, 0]
    _, loads = greedy_assign(work, 3)
    assert loads.tolist() == [4, 3, 3]


def test_example_410_k3():
    """9 2-itemsets in prefix groups of sizes 4/3/2 -> group work {6,3,1},
    3 threads -> T = {6, 3, 1}."""
    its = np.array(
        [[0, 1], [0, 2], [0, 3], [0, 4], [1, 2], [1, 3], [1, 4], [2, 3], [2, 4]],
        dtype=np.int32,
    )
    level = Level(k=2, itemsets=its, counts=np.ones(9, np.int64), bits=None)
    work = pair_work_per_unit(level.itemsets)
    assert work.tolist() == [6, 3, 1]
    _, loads = greedy_assign(work, 3)
    assert loads.tolist() == [6, 3, 1]


@given(st.lists(st.integers(0, 100), min_size=1, max_size=200), st.integers(1, 16))
@settings(max_examples=50, deadline=None)
def test_greedy_assign_properties(work, t):
    work = np.asarray(work)
    assignment, loads = greedy_assign(work, t)
    # conservation
    assert loads.sum() == work.sum()
    for w in range(t):
        assert loads[w] == work[assignment == w].sum()
    # greedy bound: max load <= ideal + max unit
    if work.sum() > 0:
        assert loads.max() <= work.sum() / t + work.max()


@given(st.integers(0, 10_000), st.integers(1, 512))
@settings(max_examples=100, deadline=None)
def test_balanced_blocks(m, shards):
    padded, block = balanced_blocks(m, shards)
    assert padded % shards == 0
    assert padded >= m
    assert block * shards == padded
    assert padded - m < shards * max(block, 1)
