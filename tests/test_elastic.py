"""Elasticity: restore a checkpoint onto a different mesh (subprocess)."""

import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, tempfile
sys.path.insert(0, sys.argv[1])
import numpy as np
import jax, jax.numpy as jnp
from repro.configs import ARCHS, reduced
from repro.models.zoo import build
from repro.distributed.checkpoint import CheckpointManager
from repro.distributed.elastic import redistribute, mesh_fingerprint
from repro.distributed.sharding import make_plan

cfg = reduced(ARCHS["glm4-9b"])
model = build(cfg)
params = model.init(jax.random.PRNGKey(0))

with tempfile.TemporaryDirectory() as d:
    cm = CheckpointManager(d)
    cm.save(1, {"params": jax.tree.map(np.asarray, params)}, {"arch": cfg.name})
    tree, meta = cm.restore()

    # "restart" on two different meshes; forward result must be identical
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (8, 16)), jnp.int32)
    labels = jnp.asarray(rng.integers(0, cfg.vocab, (8, 16)), jnp.int32)
    batch = {"tokens": tokens, "labels": labels}
    losses = []
    for shape in [(4, 2), (2, 2)]:
        mesh = jax.make_mesh(shape, ("data", "model"))
        plan = make_plan(mesh)
        print(mesh_fingerprint(mesh))
        p = redistribute(tree["params"], plan, kind="params")
        with jax.set_mesh(mesh):
            loss = jax.jit(lambda pp, b: model.train_loss(pp, plan.ctx(), b))(p, batch)
        losses.append(float(loss))
    ref = float(jax.jit(lambda pp, b: model.train_loss(pp, None, b))(params, batch))
    for l in losses:
        assert abs(l - ref) < 2e-3, (l, ref)
print("ELASTIC_OK")
"""


@pytest.mark.slow
def test_elastic_restore_different_mesh():
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT, src],
        capture_output=True, text=True, timeout=900,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "ELASTIC_OK" in proc.stdout
