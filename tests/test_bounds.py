"""Lemma 4.6 / Corollary 4.7 soundness: a bound-pruned pair is never
τ-infrequent (the bounds may only skip intersections whose result would have
been discarded)."""

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import itemize


@given(
    st.integers(8, 40), st.integers(3, 6), st.integers(2, 5),
    st.integers(0, 10_000), st.integers(1, 3),
)
@settings(max_examples=60, deadline=None)
def test_lemma_46_soundness(n, m, dom, seed, tau):
    """Direct statement: |R_I ∩ R_a| + |R_I ∩ R_b| > |R_I| + tau
    implies |R_{I∪{a,b}}| > tau."""
    rng = np.random.default_rng(seed)
    D = rng.integers(0, dom, size=(n, m))
    t = itemize(D)
    full = np.full(t.n_words, 0xFFFFFFFF, dtype=np.uint32)
    tail = n % 32
    if tail:
        full[-1] = np.uint32((1 << tail) - 1)

    def rows(ids):
        mask = full
        for i in ids:
            mask = mask & t.bits[i]
        return mask

    def card(mask):
        return int(np.bitwise_count(mask).sum())

    items = rng.choice(t.n_items, size=min(4, t.n_items), replace=False)
    if len(items) < 3:
        return
    I = tuple(items[:-2])
    a, b = int(items[-2]), int(items[-1])
    RI = rows(I)
    lhs = card(RI & t.bits[a]) + card(RI & t.bits[b])
    if lhs > card(RI) + tau:
        assert card(RI & t.bits[a] & t.bits[b]) > tau


@given(st.integers(8, 40), st.integers(4, 6), st.integers(2, 4), st.integers(0, 10_000))
@settings(max_examples=40, deadline=None)
def test_corollary_47_soundness(n, m, dom, seed):
    """Γ0 > min(Γ1, Γ2) + tau implies the k-itemset is not tau-infrequent."""
    tau = 1
    rng = np.random.default_rng(seed)
    D = rng.integers(0, dom, size=(n, m))
    t = itemize(D)
    full = np.full(t.n_words, 0xFFFFFFFF, dtype=np.uint32)
    tail = n % 32
    if tail:
        full[-1] = np.uint32((1 << tail) - 1)

    def card(ids):
        mask = full
        for i in ids:
            mask = mask & t.bits[i]
        return int(np.bitwise_count(mask).sum())

    k = 4
    if t.n_items < k:
        return
    a = rng.choice(t.n_items, size=k, replace=False).tolist()
    prefix = a[: k - 3]
    g0 = card(prefix + [a[-2], a[-1]])
    g1 = card(prefix + [a[-2]]) - card(prefix + [a[-3], a[-2]])
    g2 = card(prefix + [a[-1]]) - card(prefix + [a[-3], a[-1]])
    if g0 > min(g1, g2) + tau:
        assert card(a) > tau
