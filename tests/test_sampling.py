"""Sampled-mining fast path: sampler kernels, confidence classifier,
boundary recount, service/HTTP integration, and the chaos case.

The cross-engine convergence property sweep lives in
tests/test_sampling_prop.py (hypothesis); here are the deterministic
contracts: the word-tile sample gather vs an unpackbits reference, the
(version, ε, seed) reproducibility surface, exact boundary recounts vs
brute force on every engine, warm executable-bucket reuse, the approx →
refine → bit-identical-promotion lifecycle, and kill-mid-refinement →
restart → converge.
"""

import json
import os
import subprocess
import sys
import threading
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.core import KyivConfig, itemize, mine
from repro.core.items import WORD_BITS, bits_popcount
from repro.obs import metrics as om
from repro.sampling import (
    SamplingConfig,
    build_sample,
    classify_counts,
    derive_seed,
    gather_sample_bits,
    sample_item_table,
    sample_rows,
    sample_size,
    scaled_tau,
)
from repro.sampling.refine import recount_supports
from repro.service import (
    FaultInjector,
    KillPoint,
    MiningService,
    make_approx_key,
    make_key,
)
from repro.service.cache import CacheEntry, ResultCache


def _rand(seed, n, m, dom=5):
    return np.random.default_rng(seed).integers(0, dom, size=(n, m))


def _canonical(result):
    return sorted((tuple(sorted(ids)), int(c)) for ids, c in result.itemsets)


# a bound small enough that mid-sized test tables are strictly subsampled
SMALL = SamplingConfig(oversample=1.0, min_rows=64)


# ---------------------------------------------------------------------------
# sampler kernels
# ---------------------------------------------------------------------------


def test_gather_sample_bits_matches_unpackbits_reference():
    table = itemize(_rand(0, 333, 4, 5))
    rows = sample_rows(333, 100, seed=3)
    got = gather_sample_bits(table.bits, rows, word_tile=4)

    full = np.unpackbits(
        table.bits.view(np.uint8), axis=1, bitorder="little"
    )[:, :333]
    got_bits = np.unpackbits(
        got.view(np.uint8), axis=1, bitorder="little"
    )
    assert got.shape[1] % 4 == 0
    np.testing.assert_array_equal(got_bits[:, : len(rows)], full[:, rows])
    # padding words beyond the sample are zero
    assert not got_bits[:, len(rows):].any()


def test_gather_sample_bits_empty_and_identity():
    table = itemize(_rand(1, 70, 3, 4))
    empty = gather_sample_bits(table.bits, np.array([], dtype=np.int64))
    assert empty.shape == (table.n_items, 1) and not empty.any()
    ident = gather_sample_bits(table.bits, np.arange(70), word_tile=1)
    np.testing.assert_array_equal(ident, table.bits[:, : ident.shape[1]])


def test_sample_rows_sorted_unique_and_identity():
    rows = sample_rows(1000, 100, seed=7)
    assert rows.shape == (100,)
    assert (np.diff(rows) > 0).all()
    assert rows.min() >= 0 and rows.max() < 1000
    np.testing.assert_array_equal(sample_rows(50, 80, seed=7), np.arange(50))
    # deterministic in the seed
    np.testing.assert_array_equal(rows, sample_rows(1000, 100, seed=7))


def test_derive_seed_reproducible_per_tuple():
    s = derive_seed(3, 0.1, 0)
    assert s == derive_seed(3, 0.1, 0)
    assert s != derive_seed(4, 0.1, 0)
    assert s != derive_seed(3, 0.2, 0)
    assert s != derive_seed(3, 0.1, 1)


def test_sample_size_bound():
    assert sample_size(10**6, 8, 0.1) < 10**6  # genuinely sub-linear
    assert sample_size(100, 8, 0.1) == 100  # clamped to the table
    cfg = SamplingConfig(min_rows=512)
    assert sample_size(10**6, 2, 0.9, config=cfg) == 512  # floored
    # inverse in epsilon, increasing in column count
    assert sample_size(10**9, 8, 0.05) > sample_size(10**9, 8, 0.1)
    assert sample_size(10**9, 16, 0.1) > sample_size(10**9, 8, 0.1)
    with pytest.raises(ValueError):
        sample_size(1000, 8, 0.0)


def test_sample_item_table_matches_itemize_of_subset():
    data = _rand(2, 200, 3, 4)
    table = itemize(data)
    rows = sample_rows(200, 64, seed=5)
    st = sample_item_table(table, rows, word_tile=2)
    ref = itemize(data[rows])

    assert st.n_rows == 64
    assert st.n_words % 2 == 0
    np.testing.assert_array_equal(st.value, table.value)
    np.testing.assert_array_equal(st.col, table.col)
    np.testing.assert_array_equal(bits_popcount(st.bits), st.freq)

    ref_by_cv = {
        (int(ref.col[i]), int(ref.value[i])): (
            int(ref.freq[i]), int(ref.min_row[i]),
        )
        for i in range(ref.n_items)
    }
    for i in range(st.n_items):
        cv = (int(st.col[i]), int(st.value[i]))
        if cv in ref_by_cv:
            assert (int(st.freq[i]), int(st.min_row[i])) == ref_by_cv[cv]
        else:  # item absent from the sample keeps its id at frequency 0
            assert int(st.freq[i]) == 0
            assert int(st.min_row[i]) == np.iinfo(np.int64).max


def test_scaled_tau_and_classifier_bands():
    # floor(10 * 1.1 * 100/1000) = 1
    assert scaled_tau(10, 0.1, 1000, 100) == 1
    assert scaled_tau(1, 0.5, 10**6, 100) == 1  # floored at 1
    assert scaled_tau(7, 0.1, 500, 500) == 7  # full sample: unscaled

    est, boundary = classify_counts(
        np.array([0, 1, 2]), tau=10, epsilon=0.1, n_rows=1000, n_sample=100
    )
    np.testing.assert_array_equal(est, [0, 10, 20])
    # certain at est <= tau*(1-eps) = 9; boundary anywhere above
    np.testing.assert_array_equal(boundary, [False, True, True])

    est, boundary = classify_counts(
        np.array([3, 11]), tau=10, epsilon=0.1, n_rows=100, n_sample=100
    )
    np.testing.assert_array_equal(est, [3, 11])  # full sample: exact
    assert not boundary.any()


def test_build_sample_deterministic_per_version():
    table = itemize(_rand(3, 400, 4, 5))
    a = build_sample(table, version=1, tau=2, epsilon=0.1, config=SMALL)
    b = build_sample(table, version=1, tau=2, epsilon=0.1, config=SMALL)
    assert a.seed == b.seed
    np.testing.assert_array_equal(a.rows, b.rows)
    np.testing.assert_array_equal(a.table.bits, b.table.bits)
    assert 0 < a.rows.shape[0] < 400  # strict subsample at this config
    c = build_sample(table, version=2, tau=2, epsilon=0.1, config=SMALL)
    assert c.seed != a.seed


# ---------------------------------------------------------------------------
# exact boundary recount (every engine, warm buckets)
# ---------------------------------------------------------------------------


def _brute_counts(table, itemsets):
    out = []
    for ids in itemsets:
        acc = np.bitwise_and.reduce(table.bits[list(ids)], axis=0)
        out.append(int(bits_popcount(acc[None, :])[0]))
    return np.array(out, dtype=np.int64)


@pytest.mark.parametrize("engine", ["numpy", "jnp", "pallas"])
def test_recount_supports_matches_bruteforce(engine):
    data = _rand(4, 150, 4, 4)
    svc = MiningService.from_dataset(data, engine=engine, interpret=True)
    table = svc.store.item_table()
    per_col = {}
    for i in range(table.n_items):
        per_col.setdefault(int(table.col[i]), []).append(i)
    cols = sorted(per_col)
    itemsets = [
        (per_col[cols[0]][0],),
        (per_col[cols[0]][1],),
        (per_col[cols[0]][0], per_col[cols[1]][0]),
        (per_col[cols[0]][1], per_col[cols[1]][1]),
        (per_col[cols[0]][0], per_col[cols[1]][0], per_col[cols[2]][0]),
        (per_col[cols[0]][1], per_col[cols[1]][0], per_col[cols[3]][1]),
    ]
    counts, info = recount_supports(
        table, itemsets, placement=svc.placement, tau=2
    )
    np.testing.assert_array_equal(counts, _brute_counts(table, itemsets))
    assert info["recounted"] == len(itemsets)
    # arity-2 batch (1 dispatch) + arity-3 cascade (2 dispatches)
    assert info["dispatches"] == 3
    svc.close()


def test_recount_empty_is_noop():
    svc = MiningService.from_dataset(_rand(5, 60, 3, 4))
    counts, info = recount_supports(
        svc.store.item_table(), [], placement=svc.placement, tau=1
    )
    assert counts.shape == (0,) and info["dispatches"] == 0
    svc.close()


def test_recount_reuses_warm_buckets_on_device():
    svc = MiningService.from_dataset(_rand(6, 120, 4, 4), engine="jnp")
    table = svc.store.item_table()
    per_col = {}
    for i in range(table.n_items):
        per_col.setdefault(int(table.col[i]), []).append(i)
    cols = sorted(per_col)
    itemsets = [
        (per_col[cols[0]][j], per_col[cols[1]][k])
        for j in range(2)
        for k in range(2)
    ]
    _, first = recount_supports(
        table, itemsets, placement=svc.placement, tau=2
    )
    # first recount minted (or found) its buckets; an identical batch shape
    # must now run entirely on warm executables
    assert svc.placement.warm_buckets(
        table.n_words, fused=True, write_children=False
    )
    _, second = recount_supports(
        table, itemsets, placement=svc.placement, tau=2
    )
    assert second["bucket_misses"] == 0
    assert second["bucket_hits"] == second["dispatches"] > 0
    svc.close()


def test_host_and_mesh_have_no_bucket_cache():
    svc = MiningService.from_dataset(_rand(7, 80, 3, 4))
    assert svc.placement.warm_buckets(svc.store.n_words, fused=True,
                                      write_children=False) == ()
    svc.close()


# ---------------------------------------------------------------------------
# service lifecycle: approx -> refine -> promoted exact
# ---------------------------------------------------------------------------


def test_approx_mine_refines_to_exact(tmp_path):
    data = _rand(8, 900, 5, 6)
    cold = mine(data, KyivConfig(tau=3, kmax=3))
    svc = MiningService.from_dataset(data, sampling=SMALL)

    r = svc.mine(tau=3, kmax=3, mode="approx")
    assert r.source == "approx"
    info = r.info
    assert info["mode"] == "approx" and info["epsilon"] == 0.1
    assert info["refined"] is False
    assert 0.0 <= info["confidence"] <= 1.0
    assert info["sample_rows"] == sample_size(900, 5, 0.1, config=SMALL)
    assert info["seed"] == derive_seed(svc.store.version, 0.1, SMALL.seed)
    assert info["boundary_count"] >= 0

    drained = svc.scheduler.drain(timeout=120)
    assert drained["abandoned"] == 0

    r2 = svc.mine(tau=3, kmax=3, mode="approx")
    assert r2.source == "cache"
    assert r2.info["refined"] is True and r2.info["confidence"] == 1.0
    assert _canonical(r2.result) == _canonical(cold)

    # the promotion also populated the exact key: an exact request is warm
    assert svc.mine(tau=3, kmax=3).source == "cache"

    ss = svc.stats()["sampling"]
    assert ss["approx_served"] == 2
    assert ss["sampled_mines"] == 1
    assert ss["refinements"] == 1 and ss["refine_failures"] == 0
    assert ss["last"]["seed"] == info["seed"]
    assert ss["config"]["epsilon"] == 0.1

    text = om.render()
    for family in (
        "repro_sampling_mines_total",
        "repro_sampling_refinements_total",
        "repro_sampling_sample_mine_seconds",
        "repro_sampling_recounted_itemsets_total",
    ):
        assert family in text, family
    svc.close()


def test_approx_requests_coalesce_on_one_key():
    # same (version, epsilon) -> same derived seed -> same cache key
    assert make_approx_key(1, 2, 3, "ascending", 0.1) == make_approx_key(
        1, 2, 3, "ascending", 0.1
    )
    assert make_approx_key(1, 2, 3, "ascending", 0.1) != make_approx_key(
        1, 2, 3, "ascending", 0.2
    )
    assert make_approx_key(1, 2, 3, "ascending", 0.1) != make_key(
        1, 2, 3, "ascending"
    )

    svc = MiningService.from_dataset(_rand(9, 700, 4, 5), sampling=SMALL)
    first = svc.mine(tau=2, kmax=2, mode="approx")
    again = svc.mine(tau=2, kmax=2, mode="approx")
    assert first.info["seed"] == again.info["seed"]
    assert again.source == "cache"
    assert svc.stats()["sampling"]["sampled_mines"] == 1
    svc.close()


def test_approx_entries_never_serve_as_incremental_base():
    result = mine(_rand(10, 60, 3, 4), KyivConfig(tau=1, kmax=2))
    cache = ResultCache()
    cache.put(CacheEntry(
        key=make_approx_key(5, 1, 2, "ascending", 0.1),
        result=result, source="approx", info={},
    ))
    assert cache.latest_base(1, 2, "ascending", before_version=9) is None
    cache.put(CacheEntry(
        key=make_key(4, 1, 2, "ascending"),
        result=result, source="cold", info={},
    ))
    base = cache.latest_base(1, 2, "ascending", before_version=9)
    assert base is not None and base.key == make_key(4, 1, 2, "ascending")


def test_mode_and_epsilon_validation():
    svc = MiningService.from_dataset(_rand(11, 50, 3, 4))
    with pytest.raises(ValueError):
        svc.mine(tau=1, kmax=2, mode="fuzzy")
    with pytest.raises(ValueError):
        svc.mine(tau=1, kmax=2, mode="approx", epsilon=0.0)
    with pytest.raises(ValueError):
        svc.mine(tau=1, kmax=2, mode="approx", epsilon=1.5)
    svc.close()


# ---------------------------------------------------------------------------
# HTTP surface
# ---------------------------------------------------------------------------


@pytest.fixture()
def http_service():
    from repro.launch.serve_miner import make_server

    svc = MiningService.from_dataset(_rand(12, 800, 4, 5), sampling=SMALL)
    server = make_server(svc, port=0)
    thread = threading.Thread(target=server.serve_forever, daemon=True)
    thread.start()
    yield svc, server.server_address[1]
    server.shutdown()
    server.server_close()
    svc.close()


def _req(port, path, payload=None):
    url = f"http://127.0.0.1:{port}{path}"
    if payload is None:
        resp = urllib.request.urlopen(url, timeout=30)
    else:
        resp = urllib.request.urlopen(
            urllib.request.Request(
                url,
                data=json.dumps(payload).encode(),
                headers={"Content-Type": "application/json"},
            ),
            timeout=30,
        )
    return resp.status, json.loads(resp.read())


def test_http_approx_mine_and_stats(http_service):
    svc, port = http_service
    code, m = _req(port, "/mine?mode=approx&epsilon=0.2&tau=2&kmax=2")
    assert code == 200 and m["source"] == "approx"
    assert m["info"]["mode"] == "approx" and m["info"]["epsilon"] == 0.2
    assert "confidence" in m["info"] and "seed" in m["info"]

    svc.scheduler.drain(timeout=120)
    code, m2 = _req(port, "/mine", {"mode": "approx", "epsilon": 0.2,
                                    "tau": 2, "kmax": 2})
    assert m2["source"] == "cache" and m2["info"]["refined"] is True

    code, stats = _req(port, "/stats")
    ss = stats["sampling"]
    assert ss["sampled_mines"] == 1 and ss["approx_served"] == 2
    assert ss["last"]["epsilon"] == 0.2

    with pytest.raises(urllib.error.HTTPError) as e:
        _req(port, "/mine?mode=bogus")
    assert e.value.code == 400


# ---------------------------------------------------------------------------
# chaos: kill mid-refinement -> restart -> converge
# ---------------------------------------------------------------------------


def test_kill_mid_refinement_restart_converges(tmp_path):
    data = _rand(13, 150, 6, 4)
    undisturbed = mine(data, KyivConfig(tau=2, kmax=4))

    d = str(tmp_path / "wal")
    inj = FaultInjector()
    svc = MiningService(engine="numpy", wal_dir=d, fault_injector=inj)
    svc.append(data)
    # die at the refinement's second level boundary — after the exact
    # promotion run saved its first checkpoint
    inj.arm("mine.level_end", action="raise", exc=KillPoint("mid-refine"),
            after=1)
    r = svc.mine(tau=2, kmax=4, mode="approx")
    assert r.source == "approx"  # the sample answer itself is unaffected
    svc.scheduler.drain(timeout=120)
    ss = svc.stats()["sampling"]
    assert ss["refinements"] == 1 and ss["refine_failures"] == 1
    # the approx entry was not promoted
    r2 = svc.mine(tau=2, kmax=4, mode="approx")
    assert r2.info.get("promoted") is None
    svc.close()

    # "restart": recovery resumes the killed exact promotion from its
    # checkpoint; approx requests then converge on the exact answer
    svc2 = MiningService(engine="numpy", wal_dir=d)
    assert svc2.stats()["durability"]["resumed_jobs"] == 1
    exact = svc2.mine(tau=2, kmax=4)
    assert _canonical(exact.result) == _canonical(undisturbed)
    ra = svc2.mine(tau=2, kmax=4, mode="approx")
    assert ra.source == "cache"
    assert ra.info["confidence"] == 1.0 and ra.info["refined"] is True
    assert _canonical(ra.result) == _canonical(undisturbed)
    svc2.close()


# ---------------------------------------------------------------------------
# 8-device forced-host mesh (subprocess: XLA flags must precede jax init)
# ---------------------------------------------------------------------------

_MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, sys.argv[1])
import numpy as np
import jax
from repro.core import KyivConfig, MeshPlacement, mine
from repro.service import MiningService, SamplingConfig

mesh = jax.make_mesh((2, 4), ("data", "model"))
placement = MeshPlacement(mesh, pair_axes=("data",), word_axis="model")
data = np.random.default_rng(21).integers(0, 5, size=(900, 5))
cold = mine(data, KyivConfig(tau=2, kmax=3))

svc = MiningService.from_dataset(
    data, placement=placement,
    sampling=SamplingConfig(oversample=1.0, min_rows=64),
)
r = svc.mine(tau=2, kmax=3, mode="approx")
assert r.source == "approx", r.source
assert 0 < r.info["sample_rows"] < 900, r.info
svc.scheduler.drain(timeout=300)
r2 = svc.mine(tau=2, kmax=3, mode="approx")
assert r2.info["refined"] is True and r2.info["confidence"] == 1.0, r2.info
got = sorted((tuple(sorted(i)), int(c)) for i, c in r2.result.itemsets)
ref = sorted((tuple(sorted(i)), int(c)) for i, c in cold.itemsets)
assert got == ref, "mesh refinement diverged from the numpy cold mine"
svc.close()
print("MESH_SAMPLING_OK")
"""


@pytest.mark.slow
def test_mesh_approx_refines_bit_identical_8dev():
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", _MESH_SCRIPT, src],
        capture_output=True, text=True, timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "MESH_SAMPLING_OK" in proc.stdout
