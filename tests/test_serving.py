"""Serving correctness: prefill + N decode steps must reproduce the logits of
one full forward pass (per architecture family)."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ARCHS, reduced
from repro.models.zoo import build
from repro.serving.engine import generate, make_decode_step, make_prefill_step

KEY = jax.random.PRNGKey(1)

# one representative per family mechanism
FAMILIES = [
    "glm4-9b",  # global attention + GQA + bias
    "gemma3-4b",  # local:global pattern (ring caches)
    "deepseek-v2-lite-16b",  # MLA latent cache + MoE
    "mamba2-370m",  # SSD state
    "recurrentgemma-9b",  # RG-LRU + local hybrid
    "whisper-medium",  # enc-dec with cross-attention
]


def _last_logits_full(model, params, tokens, extra=None):
    """Logits at every position via prefix prefills (mode-consistent ref)."""
    from repro.models import lm as _lm

    cfg = model.cfg
    if cfg.family == "audio":
        from repro.models import encdec as _encdec

        memory = _encdec.encdec_encode(params, cfg, None, extra["frames"])
        dt = memory.dtype
        x = _encdec.embed_tokens(params["embed"], tokens, dt) * jnp.asarray(
            cfg.d_model**0.5, dt
        )
        x, _, _ = _encdec._run_decoder(params, cfg, None, x, memory, "train", None, None)
        from repro.models.layers.common import rms_norm

        x = rms_norm(x, params["final_norm"])
        return _encdec.logits_head(params["embed"], x, None)
    ex = extra.get("patches") if extra else None
    x = _lm._embed_inputs(params, cfg, tokens, ex, None)
    h, _ = _lm.lm_forward(params, cfg, None, x, mode="train")
    if ex is not None:
        h = h[:, ex.shape[1] :]
    from repro.models.layers.embeddings import logits_head

    return logits_head(params["embed"], h, None)


@pytest.mark.parametrize("name", FAMILIES)
def test_incremental_decode_matches_full(name):
    cfg = reduced(ARCHS[name])
    model = build(cfg)
    params = model.init(KEY)
    rng = np.random.default_rng(7)
    B, S, EXTRA_STEPS = 2, 12, 4
    total = S + EXTRA_STEPS
    tokens = jnp.asarray(rng.integers(1, cfg.vocab, (B, total)), jnp.int32)
    extra = {}
    if cfg.frontend == "audio_stub":
        extra["frames"] = jnp.asarray(rng.standard_normal((B, 8, cfg.d_model)), jnp.float32)
    elif cfg.frontend == "vision_stub":
        extra["patches"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_patches, cfg.d_model)), jnp.float32
        )

    full_logits = _last_logits_full(model, params, tokens, extra)  # (B, total, V)

    batch = dict(extra, tokens=tokens[:, :S])
    prefill = make_prefill_step(model)
    decode = make_decode_step(model)
    logits, cache = prefill(params, batch)
    np.testing.assert_allclose(
        np.asarray(logits[:, 0], np.float32),
        np.asarray(full_logits[:, S - 1], np.float32),
        rtol=2e-3, atol=2e-3,
    )
    # grow attention caches to fit the extra steps
    from repro.serving.engine import _grow_cache

    cache = _grow_cache(cache, S, total)
    for step in range(EXTRA_STEPS):
        pos = S + step
        dec = {"tokens": tokens[:, pos : pos + 1], "positions": jnp.full((B,), pos, jnp.int32)}
        logits, cache = decode(params, dec, cache)
        np.testing.assert_allclose(
            np.asarray(logits[:, 0], np.float32),
            np.asarray(full_logits[:, pos], np.float32),
            rtol=2e-3, atol=2e-3,
            err_msg=f"{name} diverged at decode step {step}",
        )


def test_generate_runs():
    cfg = reduced(ARCHS["glm4-9b"])
    model = build(cfg)
    params = model.init(KEY)
    prompts = np.random.default_rng(0).integers(1, cfg.vocab, (2, 6))
    out = generate(model, params, prompts, max_new=5)
    assert out.shape == (2, 5)
    out2 = generate(model, params, prompts, max_new=5)
    np.testing.assert_array_equal(out, out2)  # greedy is deterministic
