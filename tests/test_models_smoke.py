"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and finiteness (assignment requirement f)."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ARCHS, reduced
from repro.models.zoo import build

B, S = 2, 24
KEY = jax.random.PRNGKey(0)


def _batch(rng, cfg):
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    labels = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    batch = {"tokens": tokens, "labels": labels}
    if cfg.frontend == "audio_stub":
        batch["frames"] = jnp.asarray(rng.standard_normal((B, S, cfg.d_model)), jnp.float32)
    elif cfg.frontend == "vision_stub":
        batch["patches"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_patches, cfg.d_model)), jnp.float32
        )
        batch["tokens"] = tokens[:, : S - cfg.n_patches]
        batch["labels"] = labels[:, : S - cfg.n_patches]
    return batch


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_arch_smoke(name):
    cfg = reduced(ARCHS[name])
    model = build(cfg)
    rng = np.random.default_rng(hash(name) % 2**31)
    params = model.init(KEY)
    batch = _batch(rng, cfg)

    # train loss: finite scalar
    loss = jax.jit(lambda p, b: model.train_loss(p, None, b))(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss))

    # one full train step moves the loss
    from repro.training.optimizer import OptConfig
    from repro.training.train import make_train_step

    step = make_train_step(model, OptConfig(lr=1e-2, warmup_steps=1, total_steps=10))
    from repro.training.optimizer import adamw_init

    opt = adamw_init(params)
    p2, opt2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(opt2["step"]) == 1
    loss2 = jax.jit(lambda p, b: model.train_loss(p, None, b))(p2, batch)
    assert float(loss2) < float(loss), "one step on the same batch should descend"

    # prefill: logits shape + cache pytree; decode: one token
    logits, cache = jax.jit(lambda p, b: model.prefill(p, None, b))(params, batch)
    assert logits.shape[0] == B and logits.shape[1] == 1
    assert logits.shape[2] == cfg.vocab
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    n_text = batch["tokens"].shape[1]
    dec = {"tokens": batch["tokens"][:, :1],
           "positions": jnp.full((B,), n_text, jnp.int32)}
    logits2, cache2 = jax.jit(lambda p, b, c: model.decode(p, None, b, c))(params, dec, cache)
    assert logits2.shape == (B, 1, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits2, np.float32)))
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


def test_param_counts_full_configs():
    """Full-config parameter counts are in the right ballpark (names say
    9b/4b/110b/...) — catches config-entry typos without allocating."""
    expect = {
        "recurrentgemma-9b": (7e9, 12e9),
        "glm4-9b": (8e9, 12e9),
        "gemma3-4b": (3e9, 6e9),
        "qwen1.5-110b": (95e9, 125e9),
        "nemotron-4-15b": (12e9, 18e9),
        "deepseek-v2-lite-16b": (12e9, 18e9),
        "granite-moe-1b-a400m": (0.8e9, 1.8e9),
        "whisper-medium": (0.5e9, 1.2e9),
        "mamba2-370m": (0.25e9, 0.55e9),
        "internvl2-26b": (17e9, 26e9),
    }
    for name, (lo, hi) in expect.items():
        n = ARCHS[name].param_count()
        assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B outside [{lo/1e9}, {hi/1e9}]"
    # MoE active < total
    for name in ("granite-moe-1b-a400m", "deepseek-v2-lite-16b"):
        cfg = ARCHS[name]
        assert cfg.active_param_count() < cfg.param_count()
    assert 3e8 <= ARCHS["granite-moe-1b-a400m"].active_param_count() <= 6e8
