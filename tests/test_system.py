"""End-to-end behaviour tests: the full pipelines a user would run."""

import numpy as np
import pytest

from repro.core import KyivConfig, brute_force_minimal_infrequent, mine
from repro.data.synth import randomized_dataset
from repro.sdc.quasi import find_quasi_identifiers, k_anonymize_columns


def test_mining_pipeline_randomized():
    """Paper §5.2-style run (scaled): dataset -> Kyiv -> verified results."""
    D = randomized_dataset(n=400, m=6, seed=0)
    res = mine(D, KyivConfig(tau=1, kmax=3))
    assert len(res.itemsets) > 0
    # spot-verify against brute force on a slice of the data
    Ds = D[:60, :4]
    oracle = brute_force_minimal_infrequent(Ds, 1, 3)
    got = mine(Ds, KyivConfig(tau=1, kmax=3)).canonical_set()
    assert got == oracle
    # stats are coherent
    for s in res.stats:
        if s.k > 1:
            assert s.candidates == s.type_a + s.type_b + s.type_c + s.skipped_absent_uniform + (
                s.stored
            ) or s.candidates >= s.intersections


def test_sdc_pipeline():
    """§1.1 scenario: anonymise, re-mine, risk decreases."""
    rng = np.random.default_rng(0)
    table = np.stack(
        [rng.zipf(1.3, 800).clip(max=500), rng.integers(0, 8, 800),
         rng.integers(0, 2, 800)], axis=1)
    before = find_quasi_identifiers(table, tau=1, kmax=2)
    anon = k_anonymize_columns(table, k=5)
    after = find_quasi_identifiers(anon, tau=1, kmax=2)
    # single-column uniques must be (nearly) eliminated
    assert after.by_size().get(1, 0) <= max(1, before.by_size().get(1, 0) // 10)


def test_training_descends():
    """A few hundred steps of the tiny-LM example substrate: loss descends."""
    import jax
    import jax.numpy as jnp

    from repro.configs import ARCHS, reduced
    from repro.models.zoo import build
    from repro.training.optimizer import OptConfig, adamw_init
    from repro.training.train import make_train_step
    from repro.launch.train import synthetic_lm_batches

    cfg = reduced(ARCHS["glm4-9b"])
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step = make_train_step(model, OptConfig(lr=3e-3, warmup_steps=5, total_steps=60))
    batches = synthetic_lm_batches(cfg.vocab, 8, 32, seed=0)
    losses = []
    for i in range(60):
        params, opt, metrics = step(params, opt, next(batches))
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) * 0.7, (
        losses[:5], losses[-5:])


def test_grad_accum_matches_full_batch():
    """grad_accum=k on batch B == accum=1 on the same batch (same update)."""
    import jax
    import jax.numpy as jnp

    from repro.configs import ARCHS, reduced
    from repro.models.zoo import build
    from repro.training.optimizer import OptConfig, adamw_init
    from repro.training.train import make_train_step

    cfg = reduced(ARCHS["nemotron-4-15b"])
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 16)), jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (8, 16)), jnp.int32)}
    ocfg = OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    p1, _, m1 = make_train_step(model, ocfg)(params, opt, batch)
    p2, _, m2 = make_train_step(model, ocfg, grad_accum=4)(params, opt, batch)
    # microbatch losses average to the full-batch loss; grads likewise (all
    # microbatches equal length, mean-of-means == global mean). Tolerances
    # account for bf16 pre-cast grads (cast_bf16=True default).
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 5e-4
    diffs = [float(jnp.abs(a - b).max())
             for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2))]
    assert max(diffs) < 2e-3, max(diffs)
