"""SPMD sharded mining driver == sequential driver, on an 8-device host mesh.

XLA device count must be set before jax initialises, so the multi-device
check runs in a subprocess.
"""

import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, sys.argv[1])
import numpy as np
import jax
from repro.core import mine, KyivConfig, itemize, preprocess
from repro.core.kyiv import mine_preprocessed
from repro.core.sharded import make_sharded_intersect, make_sharded_pipeline

mesh = jax.make_mesh((4, 2), ("data", "model"))
rng = np.random.default_rng(11)
for word_axis in (None, "model"):
    fn = make_sharded_intersect(mesh, pair_axes=("data",), word_axis=word_axis)
    factory = make_sharded_pipeline(mesh, pair_axes=("data",), word_axis=word_axis)
    for trial in range(3):
        D = rng.integers(0, 4, size=(80, 6))
        cfg = KyivConfig(tau=2, kmax=4)
        seq = mine(D, cfg).canonical_set()
        prep = preprocess(itemize(D), cfg.tau)
        # legacy intersect_fn injection (host classification)
        shr = mine_preprocessed(prep, cfg, intersect_fn=fn).canonical_set()
        assert seq == shr, ("intersect_fn", word_axis, trial)
        # fused device-classified pipeline
        pip = mine_preprocessed(prep, cfg, pipeline_factory=factory).canonical_set()
        assert seq == pip, ("pipeline", word_axis, trial)
    # host-classified pipeline baseline (fused_classify=False)
    factory_host = make_sharded_pipeline(mesh, pair_axes=("data",),
                                         word_axis=word_axis, fused_classify=False)
    D = rng.integers(0, 4, size=(80, 6))
    cfg = KyivConfig(tau=2, kmax=4)
    prep = preprocess(itemize(D), cfg.tau)
    host = mine_preprocessed(prep, cfg, pipeline_factory=factory_host).canonical_set()
    assert host == mine(D, cfg).canonical_set(), ("pipeline-host", word_axis)
print("SHARDED_OK")
"""


@pytest.mark.slow
def test_sharded_equals_sequential_8dev():
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT, src],
        capture_output=True, text=True, timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "SHARDED_OK" in proc.stdout
