"""Durability layer: WAL framing, snapshot folding, crash recovery.

The contract under test is the one the README's Operations section
promises: an acknowledged append survives process death (fsync'd WAL
record), an unacknowledged torn tail is dropped, and a recovered store is
observably identical to the pre-crash one — same item ids, bitsets,
supports and version watermarks.
"""

import os
import pickle

import numpy as np
import pytest

from repro.core import bits_to_rows
from repro.distributed.checkpoint import CheckpointManager, save_pytree
from repro.service import (
    DatasetStore,
    DurableStore,
    FaultInjector,
    KillPoint,
    MiningService,
    WriteAheadLog,
)


def _rand(seed, n, m, dom=4):
    return np.random.default_rng(seed).integers(0, dom, size=(n, m))


def _store_fingerprint(store: DatasetStore):
    """Everything a client can observe about a store."""
    table = store.item_table()
    items = {
        (int(table.col[i]), int(table.value[i])): (
            int(table.freq[i]),
            int(table.min_row[i]),
            tuple(bits_to_rows(table.bits[i]).tolist()),
        )
        for i in range(table.n_items)
    }
    watermarks = {
        v: (store.rows_at(v), store.items_at(v))
        for v in range(1, store.version + 1)
        if store.has_version(v)
    }
    return (store.version, store.n_rows, store.n_items, items, watermarks)


# ---------------------------------------------------------------------------
# WriteAheadLog
# ---------------------------------------------------------------------------


def test_wal_roundtrip(tmp_path):
    wal = WriteAheadLog(str(tmp_path / "wal.log"))
    records = [{"version": i, "rows": _rand(i, 5, 3)} for i in range(1, 4)]
    for r in records:
        wal.append(r)
    got = wal.replay()
    assert len(got) == 3
    for want, have in zip(records, got):
        assert have["version"] == want["version"]
        np.testing.assert_array_equal(have["rows"], want["rows"])
    assert wal.truncated_bytes == 0


def test_wal_truncated_tail_dropped(tmp_path):
    path = str(tmp_path / "wal.log")
    wal = WriteAheadLog(path)
    wal.append({"version": 1, "rows": _rand(0, 5, 3)})
    wal.append({"version": 2, "rows": _rand(1, 5, 3)})
    wal.close()
    size = os.path.getsize(path)
    with open(path, "r+b") as f:  # tear the last frame mid-payload
        f.truncate(size - 7)
    wal2 = WriteAheadLog(path)
    got = wal2.replay()
    assert [r["version"] for r in got] == [1]
    assert wal2.truncated_bytes > 0
    # the torn tail is physically gone: a fresh append after recovery
    # produces a clean log
    wal2.append({"version": 2, "rows": _rand(1, 5, 3)})
    assert [r["version"] for r in wal2.replay()] == [1, 2]


def test_wal_corrupt_tail_bytes_dropped(tmp_path):
    path = str(tmp_path / "wal.log")
    wal = WriteAheadLog(path)
    wal.append({"version": 1, "rows": _rand(0, 5, 3)})
    wal.close()
    with open(path, "ab") as f:  # garbage after the good prefix
        f.write(b"\x00garbage-not-a-frame" * 3)
    wal2 = WriteAheadLog(path)
    assert [r["version"] for r in wal2.replay()] == [1]
    assert wal2.truncated_bytes > 0


def test_wal_flipped_bit_fails_crc(tmp_path):
    path = str(tmp_path / "wal.log")
    wal = WriteAheadLog(path)
    wal.append({"version": 1, "rows": _rand(0, 5, 3)})
    wal.close()
    data = bytearray(open(path, "rb").read())
    data[-3] ^= 0x40
    open(path, "wb").write(bytes(data))
    assert WriteAheadLog(path).replay() == []


def test_wal_reset(tmp_path):
    wal = WriteAheadLog(str(tmp_path / "wal.log"))
    wal.append({"version": 1, "rows": _rand(0, 5, 3)})
    wal.reset()
    assert wal.size() == 0 and wal.replay() == []
    wal.append({"version": 2, "rows": _rand(1, 5, 3)})
    assert [r["version"] for r in wal.replay()] == [2]


# ---------------------------------------------------------------------------
# DatasetStore state export
# ---------------------------------------------------------------------------


def test_export_from_state_identical():
    store = DatasetStore(4)
    for s in range(4):
        store.append(_rand(s, 30, 4, 5))
    rebuilt = DatasetStore.from_state(store.export_state())
    assert _store_fingerprint(rebuilt) == _store_fingerprint(store)
    # the rebuilt store keeps working: appends continue the version chain
    # and itemize against the recovered item-id table
    a, b = _rand(9, 20, 4, 5), _rand(9, 20, 4, 5)
    assert store.append(a) == rebuilt.append(b) == 5
    np.testing.assert_array_equal(a, b)
    assert _store_fingerprint(rebuilt) == _store_fingerprint(store)


def test_export_state_is_a_snapshot():
    store = DatasetStore(3)
    store.append(_rand(0, 25, 3, 4))
    state = store.export_state()
    store.append(_rand(1, 25, 3, 4))
    rebuilt = DatasetStore.from_state(state)
    assert rebuilt.version == 1 and rebuilt.n_rows == 25


# ---------------------------------------------------------------------------
# DurableStore: WAL + snapshots + recovery
# ---------------------------------------------------------------------------


def test_durable_store_recovers_from_wal_only(tmp_path):
    d = str(tmp_path / "wal")
    ds = DurableStore(d, snapshot_every=100)
    for s in range(3):
        ds.append(_rand(s, 20, 4, 5))
    want = _store_fingerprint(ds.store)
    ds.close()

    ds2 = DurableStore(d, snapshot_every=100)
    info = ds2.recover()
    assert info["replayed"] == 3 and info["snapshot_version"] == 0
    assert _store_fingerprint(ds2.store) == want


def test_durable_store_snapshot_folding(tmp_path):
    d = str(tmp_path / "wal")
    ds = DurableStore(d, snapshot_every=2)
    for s in range(5):
        ds.append(_rand(s, 20, 4, 5))
    assert ds.snapshots_taken == 2  # after appends 2 and 4
    assert ds.stats()["since_snapshot"] == 1
    want = _store_fingerprint(ds.store)
    ds.close()

    ds2 = DurableStore(d, snapshot_every=2)
    info = ds2.recover()
    assert info["snapshot_version"] == 4 and info["replayed"] == 1
    assert _store_fingerprint(ds2.store) == want


def test_kill_mid_append_recovers_to_last_ack(tmp_path):
    """The torn half-frame of a power cut mid-append is dropped: recovery
    lands on the last *acknowledged* version, exactly."""
    d = str(tmp_path / "wal")
    inj = FaultInjector()
    ds = DurableStore(d, snapshot_every=100, injector=inj)
    ds.append(_rand(0, 30, 4, 5))
    ds.append(_rand(1, 30, 4, 5))
    want = _store_fingerprint(ds.store)

    inj.arm("wal.append", action="partial")
    with pytest.raises(KillPoint):
        ds.append(_rand(2, 30, 4, 5))
    ds.close()

    ds2 = DurableStore(d, snapshot_every=100)
    info = ds2.recover()
    assert info["truncated_bytes"] > 0
    assert ds2.store.version == 2
    assert _store_fingerprint(ds2.store) == want
    # and the recovered store accepts the retried block normally
    assert ds2.append(_rand(2, 30, 4, 5)) == 3


def test_crash_between_snapshot_and_wal_reset_is_idempotent(tmp_path):
    """Records the snapshot already holds are skipped by version on replay —
    simulate the crash window by re-appending the WAL records the snapshot
    folded in."""
    d = str(tmp_path / "wal")
    ds = DurableStore(d, snapshot_every=2)
    blocks = [_rand(s, 20, 4, 5) for s in range(2)]
    for i, b in enumerate(blocks):
        ds.append(b)
    # snapshot at v2 just ran and reset the WAL; undo the reset
    for i, b in enumerate(blocks):
        ds.wal.append({"version": i + 1, "rows": b})
    want = _store_fingerprint(ds.store)
    ds.close()

    ds2 = DurableStore(d, snapshot_every=2)
    info = ds2.recover()
    assert info["skipped"] == 2 and info["replayed"] == 0
    assert _store_fingerprint(ds2.store) == want


# ---------------------------------------------------------------------------
# CheckpointManager hardening (restore fallback)
# ---------------------------------------------------------------------------


def test_manager_restore_falls_back_past_corrupt_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "ck"), keep=3)
    mgr.save(1, {"x": np.arange(3)})
    mgr.save(2, {"x": np.arange(4)})
    # corrupt the newest checkpoint's arrays
    with open(os.path.join(mgr._step_dir(2), "arrays.npz"), "wb") as f:
        f.write(b"not an npz")
    tree, meta = mgr.restore()
    assert meta["step"] == 1
    np.testing.assert_array_equal(tree["x"], np.arange(3))
    # the corrupt dir is quarantined, not rediscovered
    assert mgr.steps() == [1]
    assert os.path.exists(mgr._step_dir(2) + ".corrupt")


def test_manager_restore_none_when_all_corrupt(tmp_path):
    mgr = CheckpointManager(str(tmp_path / "ck"), keep=3)
    mgr.save(1, {"x": np.arange(3)})
    with open(os.path.join(mgr._step_dir(1), "arrays.npz"), "wb") as f:
        f.write(b"junk")
    assert mgr.restore() == (None, None)


# ---------------------------------------------------------------------------
# MiningService over a durable store
# ---------------------------------------------------------------------------


def test_service_restart_recovers_store_and_serves(tmp_path):
    d = str(tmp_path / "wal")
    svc = MiningService(engine="numpy", wal_dir=d, snapshot_every=3)
    for s in range(5):
        svc.append(_rand(s, 25, 4, 5))
    want = _store_fingerprint(svc.store)
    ref = svc.mine(tau=2, kmax=3)
    svc.close()

    svc2 = MiningService(engine="numpy", wal_dir=d, snapshot_every=3)
    assert svc2.ready
    assert _store_fingerprint(svc2.store) == want
    got = svc2.mine(tau=2, kmax=3)
    assert got.result.canonical_set() == ref.result.canonical_set()
    stats = svc2.stats()
    assert stats["durability"]["last_recovery"]["version"] == 5
    svc2.close()


def test_service_not_ready_rejects_until_recovered(tmp_path):
    from repro.service import NotReadyError

    d = str(tmp_path / "wal")
    svc = MiningService(engine="numpy", wal_dir=d)
    svc.append(_rand(0, 25, 4, 5))
    svc.close()

    svc2 = MiningService(engine="numpy", wal_dir=d, defer_recovery=True)
    assert not svc2.ready
    assert svc2.readiness() == (False, "recovering")
    with pytest.raises(NotReadyError):
        svc2.mine(tau=1, kmax=2)
    with pytest.raises(NotReadyError):
        svc2.append(_rand(1, 5, 4, 5))
    svc2.recover()
    assert svc2.ready
    assert svc2.mine(tau=1, kmax=2).result is not None
    svc2.close()


def test_compact_snapshots_durable_state(tmp_path):
    d = str(tmp_path / "wal")
    svc = MiningService(engine="numpy", wal_dir=d, snapshot_every=100)
    for s in range(4):
        svc.append(_rand(s, 20, 4, 5))
    svc.compact(keep_versions=1)
    want = _store_fingerprint(svc.store)
    svc.close()

    svc2 = MiningService(engine="numpy", wal_dir=d, snapshot_every=100)
    assert _store_fingerprint(svc2.store) == want
    assert svc2.store.compactions == 1
    svc2.close()
