"""Fused intersect-classify pipeline: device class codes vs host
classification, locality scheduling round-trips, and driver equivalence.

Deterministic (no hypothesis) so this file runs on minimal installs; every
check is an exact integer comparison."""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import KyivConfig, mine
from repro.core.bitops import popcount_rows, popcount_unpackbits
from repro.kernels.intersect import (
    CLASS_EMIT,
    CLASS_SKIP,
    CLASS_STORE,
    LevelPipeline,
    classify_counts_host,
    intersect_classify,
    locality_order,
)
from repro.kernels.intersect.ops import _largest_divisor_tile

RNG = np.random.default_rng(42)

ENGINES = ("numpy", "jnp", "pallas")


def _mk_level(t, W, M, density=0.08):
    """Random sparse parent level + pairs: sparse so every class occurs."""
    bits = (
        RNG.integers(0, 2**32, size=(t, W), dtype=np.uint32)
        & RNG.integers(0, 2**32, size=(t, W), dtype=np.uint32)
        & (RNG.random(size=(t, W)) < density * 8).astype(np.uint32) * np.uint32(0xFFFFFFFF)
    )
    bits[0] = 0  # an absent parent: every pair with it classifies SKIP
    bits[1] = bits[2]  # identical parents: uniform pair -> SKIP
    pairs = RNG.integers(0, t, size=(M, 2)).astype(np.int32)
    pairs[0] = (1, 2)
    pairs[1] = (0, 3)
    pc = popcount_rows(bits)
    return bits, pairs, pc


def _host_reference(bits, pairs, pc, tau):
    child = bits[pairs[:, 0]] & bits[pairs[:, 1]]
    counts = popcount_rows(child)
    minp = np.minimum(pc[pairs[:, 0]], pc[pairs[:, 1]])
    return child, counts, classify_counts_host(counts, minp, tau)


@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("write", [True, False])
@pytest.mark.parametrize("t,W,M", [(16, 128, 37), (32, 256, 300), (8, 384, 11)])
def test_fused_classify_matches_host(engine, write, t, W, M):
    """Fused class codes == host classification, incl. padded-bucket tails
    (M=37, 300, 11 are all non-bucket sizes)."""
    bits, pairs, pc = _mk_level(t, W, M)
    tau = 6
    ref_child, ref_counts, ref_cls = _host_reference(bits, pairs, pc, tau)
    assert {CLASS_SKIP, CLASS_STORE} <= set(ref_cls.tolist())  # classes exercised
    child, counts, classes = intersect_classify(
        bits, pairs, pc, tau=tau, write_children=write, engine=engine, interpret=True
    )
    assert np.array_equal(counts, ref_counts)
    assert np.array_equal(classes, ref_cls)
    if write:
        assert np.array_equal(child, ref_child)
    else:
        assert child is None


@pytest.mark.parametrize("write", [True, False])
def test_fused_classify_pallas_gathered(write):
    """The gathered (indexed=False) Pallas path classifies identically."""
    bits, pairs, pc = _mk_level(16, 256, 64)
    tau = 4
    _, ref_counts, ref_cls = _host_reference(bits, pairs, pc, tau)
    child, counts, classes = intersect_classify(
        bits, pairs, pc, tau=tau, write_children=write, engine="pallas",
        interpret=True, indexed=False,
    )
    assert np.array_equal(counts, ref_counts)
    assert np.array_equal(classes, ref_cls)


def test_emit_class_occurs():
    """A construction where CLASS_EMIT must appear, on every engine."""
    W = 128
    bits = np.zeros((4, W), dtype=np.uint32)
    bits[0, 0] = 0b11110000
    bits[1, 0] = 0b00110011
    bits[2, 0] = 0xFFFF
    bits[3, 0] = 0xFF00FF00
    pairs = np.array([[0, 1], [2, 3]], dtype=np.int32)
    pc = popcount_rows(bits)
    for engine in ENGINES:
        _, counts, classes = intersect_classify(
            bits, pairs, pc, tau=2, write_children=True, engine=engine, interpret=True
        )
        assert counts.tolist() == [2, 8]
        assert classes.tolist() == [CLASS_EMIT, CLASS_STORE]


def test_locality_order_roundtrip():
    """The pair-locality permutation round-trips exactly."""
    pairs = RNG.integers(0, 50, size=(1000, 2)).astype(np.int32)
    order, inverse = locality_order(pairs)
    assert order is not None  # random pairs are not i-monotone
    sorted_pairs = pairs[order]
    i = sorted_pairs[:, 0]
    assert np.all(i[1:] >= i[:-1])  # scheduled: parent runs are contiguous
    # within an i-run, j ascending (stable (i, j) order)
    same_i = i[1:] == i[:-1]
    assert np.all(sorted_pairs[1:][same_i, 1] >= sorted_pairs[:-1][same_i, 1])
    assert np.array_equal(sorted_pairs[inverse], pairs)  # exact round-trip
    payload = np.arange(len(pairs))
    assert np.array_equal(payload[order][inverse], payload)


def test_locality_order_sorted_is_noop():
    """i-monotone batches (the prefix-join generator's output) skip the sort."""
    pairs = np.stack(
        [np.repeat(np.arange(10), 3), np.tile(np.arange(3), 10)], axis=1
    ).astype(np.int32)
    order, inverse = locality_order(pairs)
    assert order is None and inverse is None


@pytest.mark.parametrize("engine", ENGINES)
def test_locality_sort_does_not_change_outputs(engine):
    bits, pairs, pc = _mk_level(24, 128, 111)
    for write in (True, False):
        a = intersect_classify(
            bits, pairs, pc, tau=3, write_children=write, engine=engine,
            interpret=True, locality_sort=True,
        )
        b = intersect_classify(
            bits, pairs, pc, tau=3, write_children=write, engine=engine,
            interpret=True, locality_sort=False,
        )
        assert np.array_equal(a[1], b[1]) and np.array_equal(a[2], b[2])
        if write:
            assert np.array_equal(a[0], b[0])


@pytest.mark.parametrize("engine", ENGINES)
def test_mine_fused_equals_host_classified(engine):
    """KyivConfig.fused_classify flips the classification location, never the
    mining result or the per-level counters."""
    rng = np.random.default_rng(7)
    for trial in range(3):
        D = rng.integers(0, 4, size=(60, 5))
        fused = mine(D, KyivConfig(tau=2, kmax=4, engine=engine, fused_classify=True))
        host = mine(D, KyivConfig(tau=2, kmax=4, engine=engine, fused_classify=False))
        assert fused.canonical_set() == host.canonical_set()
        assert sorted(fused.itemsets) == sorted(host.itemsets)
        for sf, sh in zip(fused.stats, host.stats):
            assert (sf.k, sf.candidates, sf.support_pruned, sf.bound_pruned,
                    sf.intersections, sf.emitted, sf.skipped_absent_uniform,
                    sf.stored) == \
                   (sh.k, sh.candidates, sh.support_pruned, sh.bound_pruned,
                    sh.intersections, sh.emitted, sh.skipped_absent_uniform,
                    sh.stored)


def test_mine_double_buffer_equivalence():
    rng = np.random.default_rng(13)
    D = rng.integers(0, 5, size=(80, 6))
    base = mine(D, KyivConfig(tau=1, kmax=4, double_buffer=False))
    dbuf = mine(D, KyivConfig(tau=1, kmax=4, double_buffer=True))
    assert base.canonical_set() == dbuf.canonical_set()
    # small chunks force many in-flight batches per level
    tiny = mine(D, KyivConfig(tau=1, kmax=4, max_pairs_per_chunk=8))
    assert base.canonical_set() == tiny.canonical_set()


def test_level_pipeline_empty_submit():
    bits = np.zeros((4, 128), dtype=np.uint32)
    pipe = LevelPipeline(bits, np.zeros(4, dtype=np.int64), tau=1, engine="numpy")
    child, counts, classes = pipe.submit(np.zeros((0, 2), np.int32), True).result()
    assert child.shape == (0, 128) and counts.shape == (0,) and classes.shape == (0,)


def test_largest_divisor_tile():
    """O(sqrt) divisor search agrees with the brute-force definition."""

    def brute(dim, preferred):
        t = min(preferred, dim)
        while dim % t:
            t -= 1
        return max(t, 1)

    cases = [(512, 512), (384, 512), (1, 8), (7, 8), (12, 8), (128, 100),
             (997, 512), (2 * 3 * 5 * 7 * 11, 100), (1 << 20, 512)]
    for dim, preferred in cases:
        assert _largest_divisor_tile(dim, preferred) == brute(dim, preferred), (dim, preferred)
    # pathological prime word counts: exact and instant
    import time
    big_prime = 1_000_003
    t0 = time.perf_counter()
    assert _largest_divisor_tile(big_prime, 512) == 1
    assert time.perf_counter() - t0 < 0.05


def test_popcount_fallback_matches_ufunc():
    """unpackbits fallback (numpy<2.0 path) is exact for every word dtype."""
    for dtype in (np.uint8, np.uint16, np.uint32, np.uint64):
        words = RNG.integers(0, np.iinfo(dtype).max, size=(13, 17), dtype=dtype)
        ref = np.bitwise_count(words)
        assert np.array_equal(popcount_unpackbits(words), ref)
