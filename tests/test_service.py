"""Resident mining service: store/cache/scheduler/API/HTTP behaviour.

The incremental-vs-cold equivalence property test lives in
tests/test_incremental.py (hypothesis); here are the deterministic
subsystem contracts plus targeted incremental edge cases.
"""

import json
import threading
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.core import KyivConfig, bits_to_rows, itemize, mine
from repro.data.loaders import read_csv
from repro.kernels.intersect import executable_cache_stats
from repro.service import (
    DatasetStore,
    IncrementalConfig,
    MiningService,
    RequestScheduler,
    ResultCache,
    make_key,
    mine_incremental,
)
from repro.service.cache import CacheEntry


def _value_sets(result):
    return {(frozenset(ids), c) for ids, c in result.as_value_sets()}


def _rand(seed, n, m, dom):
    return np.random.default_rng(seed).integers(0, dom, size=(n, m))


# ---------------------------------------------------------------------------
# DatasetStore
# ---------------------------------------------------------------------------


def test_store_incremental_itemization_matches_itemize():
    """Appending in blocks must produce the same items/supports/row sets as
    one-shot itemization of the concatenated table."""
    blocks = [_rand(s, 37, 4, 5) for s in range(3)]
    store = DatasetStore(4)
    for b in blocks:
        store.append(b)
    table = store.item_table()
    ref = itemize(np.concatenate(blocks))

    got = {
        (int(table.col[i]), int(table.value[i])): (
            int(table.freq[i]),
            int(table.min_row[i]),
            tuple(bits_to_rows(table.bits[i]).tolist()),
        )
        for i in range(table.n_items)
    }
    want = {
        (int(ref.col[i]), int(ref.value[i])): (
            int(ref.freq[i]),
            int(ref.min_row[i]),
            tuple(ref.rows_of(i).tolist()),
        )
        for i in range(ref.n_items)
    }
    assert got == want


def test_store_versioning_and_word_tile():
    store = DatasetStore(3, word_tile=8)
    assert store.version == 0 and store.n_rows == 0
    v1 = store.append(_rand(0, 10, 3, 4))
    v2 = store.append(_rand(1, 300, 3, 4))
    assert (v1, v2) == (1, 2)
    assert store.rows_at(1) == 10 and store.rows_at(2) == 310
    assert store.n_words % 8 == 0
    assert store.n_words >= (310 + 31) // 32
    # appending zero rows does not bump the version
    assert store.append(np.zeros((0, 3), dtype=np.int64)) == 2


def test_store_delta_bits_exact():
    a, b = _rand(0, 45, 3, 4), _rand(1, 21, 3, 4)
    store = DatasetStore.from_dataset(a)
    base = store.version
    store.append(b)
    dbits, word_lo = store.delta_bits(base)
    table = store.item_table()
    # delta support per item == support of the item within the appended rows
    ref = itemize(np.concatenate([a, b]))
    for i in range(table.n_items):
        key = (int(table.col[i]), int(table.value[i]))
        j = next(
            r
            for r in range(ref.n_items)
            if (int(ref.col[r]), int(ref.value[r])) == key
        )
        delta_rows = [r for r in ref.rows_of(j) if r >= 45]
        got_rows = [word_lo * 32 + r for r in bits_to_rows(dbits[i])]
        assert got_rows == delta_rows


def test_store_snapshot_immune_to_later_appends():
    store = DatasetStore.from_dataset(_rand(0, 20, 3, 4))
    version, table = store.snapshot()
    before = table.bits.copy()
    store.append(_rand(1, 40, 3, 4))
    assert version == 1
    np.testing.assert_array_equal(table.bits, before)


# ---------------------------------------------------------------------------
# read_csv
# ---------------------------------------------------------------------------


def test_read_csv_header_and_codebooks(tmp_path):
    p = tmp_path / "t.csv"
    p.write_text("city,plan\nkyiv,a\nlviv,b\nkyiv,a\nodesa,b\n")
    table, names, books = read_csv(str(p))
    assert names == ["city", "plan"]
    assert table.shape == (4, 2)
    decoded = [books[0][i] for i in table[:, 0]]
    assert decoded == ["kyiv", "lviv", "kyiv", "odesa"]
    # feeds the service directly
    svc = MiningService.from_dataset(table)
    assert svc.mine(tau=1, kmax=2).n_itemsets >= 1
    svc.close()


def test_read_csv_headerless(tmp_path):
    p = tmp_path / "t.csv"
    p.write_text("1,2\n1,3\n2,2\n")
    table, names, _ = read_csv(str(p), header=False)
    assert names == ["col0", "col1"]
    assert table.shape == (3, 2)


# ---------------------------------------------------------------------------
# ResultCache / RequestScheduler
# ---------------------------------------------------------------------------


def _entry(version, tau=1, kmax=3):
    key = make_key(version, tau, kmax, "ascending")
    return CacheEntry(key=key, result=None, source="cold", info={})


def test_cache_lru_eviction_and_latest_base():
    cache = ResultCache(capacity=2)
    cache.put(_entry(1))
    cache.put(_entry(2))
    assert cache.get(make_key(1, 1, 3, "ascending")) is not None  # 1 now MRU
    cache.put(_entry(3))  # evicts version 2
    assert cache.get(make_key(2, 1, 3, "ascending")) is None
    assert cache.get(make_key(1, 1, 3, "ascending")) is not None
    base = cache.latest_base(1, 3, "ascending", before_version=3)
    assert base is not None and base.version == 1
    # different mining params never serve as a base
    assert cache.latest_base(2, 3, "ascending", before_version=99) is None


def test_scheduler_coalesces_identical_requests():
    sched = RequestScheduler(max_workers=2)
    calls = []
    release = threading.Event()

    def work():
        calls.append(1)
        release.wait(timeout=5)
        return "done"

    f1 = sched.submit(("k",), work)
    f2 = sched.submit(("k",), work)  # coalesced onto f1
    assert f2 is f1
    release.set()
    assert f1.result(timeout=5) == "done"
    assert len(calls) == 1
    assert sched.stats()["coalesced"] == 1
    # after completion the key is free again
    f3 = sched.submit(("k",), lambda: "again")
    assert f3.result(timeout=5) == "again"
    sched.shutdown()


# ---------------------------------------------------------------------------
# MiningService: cold -> cache -> incremental
# ---------------------------------------------------------------------------


def test_service_cold_cache_incremental_equivalence():
    base, delta = _rand(0, 300, 5, 6), _rand(1, 25, 5, 6)
    svc = MiningService.from_dataset(base)
    r1 = svc.mine(tau=2, kmax=3)
    r2 = svc.mine(tau=2, kmax=3)
    assert (r1.source, r2.source) == ("cold", "cache")
    assert r2.result is r1.result

    svc.append(delta)
    r3 = svc.mine(tau=2, kmax=3)
    assert r3.source == "incremental"
    cold = mine(np.concatenate([base, delta]), KyivConfig(tau=2, kmax=3))
    assert _value_sets(r3.result) == _value_sets(cold)

    # and the incremental result is itself cached
    assert svc.mine(tau=2, kmax=3).source == "cache"
    svc.close()


def test_service_incremental_new_values_and_mirrors():
    """Delta introduces brand-new values, promotes old rare ones, and breaks
    a mirror pair (two columns identical in the base diverge in the delta)."""
    base = np.stack(
        [
            np.array([1, 1, 1, 1, 2, 2, 2, 3]),
            np.array([1, 1, 1, 1, 2, 2, 2, 3]),  # mirror of col 0 in the base
            np.array([5, 5, 6, 6, 5, 5, 6, 6]),
        ],
        axis=1,
    )
    delta = np.array(
        [
            [3, 1, 5],  # promotes value 3 in col 0; breaks the col0/col1 mirror
            [9, 9, 7],  # brand-new values 9 (cols 0, 1) and 7 (col 2)
            [3, 2, 6],
        ]
    )
    svc = MiningService.from_dataset(
        base, incremental=IncrementalConfig(max_delta_fraction=0.5)
    )
    svc.mine(tau=1, kmax=3)
    svc.append(delta)
    r = svc.mine(tau=1, kmax=3)
    assert r.source == "incremental"
    assert r.info["n_new_items"] >= 3
    cold = mine(np.concatenate([base, delta]), KyivConfig(tau=1, kmax=3))
    assert _value_sets(r.result) == _value_sets(cold)
    svc.close()


def test_service_fallback_on_large_delta():
    base, delta = _rand(0, 60, 4, 5), _rand(1, 60, 4, 5)
    svc = MiningService.from_dataset(base)
    svc.mine(tau=1, kmax=3)
    svc.append(delta)
    r = svc.mine(tau=1, kmax=3)  # delta = 50% > max_delta_fraction
    assert r.source == "cold"
    cold = mine(np.concatenate([base, delta]), KyivConfig(tau=1, kmax=3))
    assert _value_sets(r.result) == _value_sets(cold)
    svc.close()


def test_mine_incremental_direct_kmax1():
    base, delta = _rand(0, 80, 4, 4), _rand(3, 10, 4, 4)
    store = DatasetStore.from_dataset(base)
    cfg = KyivConfig(tau=2, kmax=1)
    base_res = mine(base, cfg)
    v1 = store.version
    store.append(delta)
    out = mine_incremental(store, base_res, v1, cfg, IncrementalConfig())
    assert out is not None
    result, _ = out
    cold = mine(np.concatenate([base, delta]), cfg)
    assert _value_sets(result) == _value_sets(cold)


def test_service_warm_executables_across_requests():
    """Repeated jnp mining requests reuse the process-wide executable
    buckets (the ops.EXEC_CACHE warm-start satellite) and mine through the
    store's device-resident bitsets — results must match the numpy engine."""
    a, b = _rand(0, 128, 4, 4), _rand(1, 128, 4, 4)
    svc = MiningService.from_dataset(a, engine="jnp")
    r1 = svc.mine(tau=1, kmax=3)
    before = executable_cache_stats()
    svc.append(b)  # doubles rows -> fallback cold remine
    r2 = svc.mine(tau=1, kmax=3)
    after = executable_cache_stats()
    assert after["hits"] > before["hits"]
    assert _value_sets(r1.result) == _value_sets(mine(a, KyivConfig(tau=1, kmax=3)))
    assert _value_sets(r2.result) == _value_sets(
        mine(np.concatenate([a, b]), KyivConfig(tau=1, kmax=3))
    )
    svc.close()


# ---------------------------------------------------------------------------
# HTTP endpoint
# ---------------------------------------------------------------------------


@pytest.fixture()
def http_service():
    from repro.launch.serve_miner import make_server

    svc = MiningService.from_dataset(_rand(0, 200, 4, 5))
    server = make_server(svc, port=0)
    thread = threading.Thread(target=server.serve_forever, daemon=True)
    thread.start()
    yield svc, server.server_address[1]
    server.shutdown()
    server.server_close()
    svc.close()


def _req(port, path, payload=None):
    url = f"http://127.0.0.1:{port}{path}"
    if payload is None:
        resp = urllib.request.urlopen(url, timeout=30)
    else:
        resp = urllib.request.urlopen(
            urllib.request.Request(
                url,
                data=json.dumps(payload).encode(),
                headers={"Content-Type": "application/json"},
            ),
            timeout=30,
        )
    return resp.status, json.loads(resp.read())


def test_http_mine_append_report_cycle(http_service):
    svc, port = http_service
    assert _req(port, "/healthz")[1] == {"ok": True}

    code, m1 = _req(port, "/mine", {"tau": 1, "kmax": 3, "max_itemsets": 5})
    assert code == 200 and m1["source"] == "cold" and len(m1["itemsets"]) <= 5

    code, m2 = _req(port, "/mine?tau=1&kmax=3")
    assert m2["source"] == "cache" and m2["n_itemsets"] == m1["n_itemsets"]

    rows = _rand(7, 15, 4, 5).tolist()
    code, a = _req(port, "/append", {"rows": rows})
    assert code == 200 and a["appended"] == 15 and a["version"] == 2

    code, m3 = _req(port, "/mine", {"tau": 1, "kmax": 3})
    assert m3["source"] in ("incremental", "cold") and m3["version"] == 2

    code, rep = _req(port, "/report?tau=1&kmax=3")
    assert code == 200
    assert rep["n_quasi_identifiers"] == m3["n_itemsets"]
    assert rep["n_rows"] == 215

    code, stats = _req(port, "/stats")
    assert stats["store"]["n_rows"] == 215
    assert stats["cache"]["hits"] >= 1


def test_http_error_handling(http_service):
    _, port = http_service
    with pytest.raises(urllib.error.HTTPError) as e:
        _req(port, "/nope")
    assert e.value.code == 404
    with pytest.raises(urllib.error.HTTPError) as e:
        _req(port, "/append", {"rows": []})
    assert e.value.code == 400


# ---------------------------------------------------------------------------
# Store compaction
# ---------------------------------------------------------------------------


def test_store_compaction_preserves_retained_versions():
    """Auto-compaction drops only old watermarks; every retained version's
    rows_at/delta_bits and the table content itself are unchanged."""
    blocks = [_rand(s, 30, 4, 5) for s in range(6)]
    store = DatasetStore(4, compact_threshold=4, keep_versions=2)
    ref = DatasetStore(4)
    for b in blocks:
        store.append(b)
        ref.append(b)
    assert store.compactions >= 1
    assert not store.has_version(1)  # consolidated into the base
    for v in range(store.version - 2, store.version + 1):  # retained window
        assert store.has_version(v)
        assert store.rows_at(v) == ref.rows_at(v)
        np.testing.assert_array_equal(store.delta_bits(v)[0], ref.delta_bits(v)[0])
    t, r = store.item_table(), itemize(np.concatenate(blocks))
    got = {
        (int(t.col[i]), int(t.value[i])): tuple(bits_to_rows(t.bits[i]).tolist())
        for i in range(t.n_items)
    }
    want = {
        (int(r.col[i]), int(r.value[i])): tuple(r.rows_of(i).tolist())
        for i in range(r.n_items)
    }
    assert got == want


def test_store_manual_compaction_trims_capacity():
    store = DatasetStore(3, word_tile=8)
    for s in range(5):
        store.append(_rand(s, 100, 3, 6))
    cap_before = store._bits.nbytes
    info = store.compact(keep_versions=2)
    assert info["dropped_versions"] >= 1
    assert store._bits.nbytes <= cap_before
    assert store.n_words % store.word_tile == 0
    t = store.item_table()
    assert t.n_rows == 500 and t.bits.shape[0] == t.n_items


def test_store_compaction_config_validation():
    # thrash guard: a threshold the retained watermarks can never get under
    with pytest.raises(ValueError):
        DatasetStore(3, compact_threshold=4, keep_versions=8)
    with pytest.raises(ValueError):
        DatasetStore(3).compact(keep_versions=0)


def test_store_auto_compaction_does_not_thrash():
    """Steady appends between compactions: each auto-compaction must drop
    something, not re-fire (and re-copy the matrix) on every append."""
    store = DatasetStore(4, compact_threshold=6, keep_versions=2)
    for s in range(20):
        store.append(_rand(s, 10, 4, 5))
    assert store.compactions <= 20 // (6 - (2 + 1)) + 1


def test_service_incremental_falls_back_cold_after_compaction():
    """A cached base whose version watermark was compacted away can no longer
    seed the delta miner — the service re-mines cold, bit-identically."""
    base, d1, d2 = _rand(0, 200, 4, 5), _rand(1, 10, 4, 5), _rand(2, 10, 4, 5)
    svc = MiningService.from_dataset(base)
    svc.mine(tau=1, kmax=3)  # cached at version 1
    svc.append(d1)
    svc.append(d2)
    svc.store.compact(keep_versions=1)  # drops versions 1 and 2
    assert not svc.store.has_version(1)
    r = svc.mine(tau=1, kmax=3)
    assert r.source == "cold"
    cold = mine(np.concatenate([base, d1, d2]), KyivConfig(tau=1, kmax=3))
    assert _value_sets(r.result) == _value_sets(cold)
    assert svc.stats()["store"]["compactions"] == 1
    svc.close()


# ---------------------------------------------------------------------------
# HTTP hardening: bearer auth + bounded in-flight queue
# ---------------------------------------------------------------------------


@pytest.fixture()
def hardened_http_service():
    from repro.launch.serve_miner import make_server

    svc = MiningService.from_dataset(_rand(0, 120, 4, 5))
    server = make_server(svc, port=0, auth_token="tok3n", max_inflight=2)
    thread = threading.Thread(target=server.serve_forever, daemon=True)
    thread.start()
    yield svc, server
    server.shutdown()
    server.server_close()
    svc.close()


def _req_auth(port, path, token=None, payload=None):
    headers = {"Content-Type": "application/json"}
    if token is not None:
        headers["Authorization"] = f"Bearer {token}"
    req = urllib.request.Request(
        f"http://127.0.0.1:{port}{path}",
        data=json.dumps(payload).encode() if payload is not None else None,
        headers=headers,
    )
    resp = urllib.request.urlopen(req, timeout=30)
    return resp.status, json.loads(resp.read())


def test_http_bearer_auth(hardened_http_service):
    _, server = hardened_http_service
    port = server.server_address[1]
    # liveness is never gated
    assert _req_auth(port, "/healthz")[1] == {"ok": True}
    # missing, wrong, and non-ASCII tokens -> 401 (never a 500 leak)
    for token in (None, "wrong", "café"):
        with pytest.raises(urllib.error.HTTPError) as e:
            _req_auth(port, "/mine?tau=1&kmax=2", token=token)
        assert e.value.code == 401
    code, body = _req_auth(port, "/mine?tau=1&kmax=2", token="tok3n")
    assert code == 200 and body["source"] == "cold"
    code, stats = _req_auth(port, "/stats", token="tok3n")
    assert stats["http"]["auth"] is True
    assert stats["http"]["unauthorized"] == 3
    assert stats["http"]["served"] >= 2
    assert stats["placement"]["kind"] == "host"
    assert "hits" in stats["executables"] and "misses" in stats["executables"]


def test_http_bounded_queue_returns_429(hardened_http_service):
    _, server = hardened_http_service
    port = server.server_address[1]
    sem = server.RequestHandlerClass.inflight
    # saturate the in-flight bound as two stuck requests would
    assert sem.acquire(blocking=False) and sem.acquire(blocking=False)
    try:
        with pytest.raises(urllib.error.HTTPError) as e:
            _req_auth(port, "/stats", token="tok3n")
        assert e.value.code == 429
        # liveness still answers while the queue is full
        assert _req_auth(port, "/healthz")[1] == {"ok": True}
    finally:
        sem.release()
        sem.release()
    code, stats = _req_auth(port, "/stats", token="tok3n")
    assert code == 200
    assert stats["http"]["rejected"] == 1
    assert stats["http"]["max_inflight"] == 2


def test_concurrent_http_requests_coalesce(http_service):
    svc, port = http_service
    svc.cache.clear()
    results = []

    def query():
        results.append(_req(port, "/mine", {"tau": 1, "kmax": 3})[1])

    threads = [threading.Thread(target=query) for _ in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    assert len(results) == 6
    assert len({r["n_itemsets"] for r in results}) == 1
    # exactly one cold run; everyone else hit the cache or coalesced onto it
    sched = svc.scheduler.stats()
    cache = svc.cache.stats()
    assert sched["scheduled"] + sched["coalesced"] + cache["hits"] >= 6
    assert sum(1 for r in results if r["source"] == "cold") >= 1


# ---------------------------------------------------------------------------
# Scheduler worker death + byte-bounded cache + readiness (robustness PR)
# ---------------------------------------------------------------------------


def test_scheduler_worker_death_fails_only_that_key():
    """A worker raising mid-job must deliver the exception to every waiter
    coalesced on that key — and nothing else: the key is released and
    subsequent requests (same or different key) run normally."""
    sched = RequestScheduler(max_workers=1)
    gate = threading.Event()

    def dies():
        gate.wait(5)
        raise RuntimeError("worker died")

    f1 = sched.submit(("k",), dies)
    f2 = sched.submit(("k",), dies)  # coalesces onto the doomed run
    assert f1 is f2
    gate.set()
    with pytest.raises(RuntimeError, match="worker died"):
        f1.result(timeout=10)
    with pytest.raises(RuntimeError, match="worker died"):
        f2.result(timeout=10)
    # the scheduler is not wedged: the same key runs again, fresh
    assert sched.submit(("k",), lambda: 42).result(timeout=10) == 42
    assert sched.submit(("other",), lambda: 7).result(timeout=10) == 7
    stats = sched.stats()
    assert stats["failed"] == 1 and stats["inflight"] == 0
    assert stats["scheduled"] == 3 and stats["coalesced"] == 1
    sched.shutdown()


def test_cache_bounded_by_bytes():
    data = _rand(0, 60, 4, 5)
    result = mine(data, KyivConfig(tau=1, kmax=2))
    per_entry = CacheEntry(
        key=make_key(1, 1, 2, "ascending"), result=result, source="cold", info={}
    ).nbytes()
    assert per_entry > 0
    cache = ResultCache(capacity=64, max_bytes=3 * per_entry)
    for v in range(1, 7):
        cache.put(
            CacheEntry(
                key=make_key(v, 1, 2, "ascending"),
                result=result,
                source="cold",
                info={},
            )
        )
    stats = cache.stats()
    assert stats["entries"] == 3  # byte bound, not the 64-entry capacity
    assert stats["bytes"] <= stats["max_bytes"]
    # LRU order: the newest versions survived
    assert cache.get(make_key(6, 1, 2, "ascending")) is not None
    assert cache.get(make_key(1, 1, 2, "ascending")) is None


def test_cache_oversized_entry_still_cached():
    data = _rand(0, 60, 4, 5)
    result = mine(data, KyivConfig(tau=1, kmax=2))
    cache = ResultCache(capacity=4, max_bytes=1)  # smaller than any entry
    entry = CacheEntry(
        key=make_key(1, 1, 2, "ascending"), result=result, source="cold", info={}
    )
    cache.put(entry)
    assert cache.get(entry.key) is entry  # newest is never evicted


def test_service_cache_bytes_in_stats():
    svc = MiningService.from_dataset(_rand(0, 80, 4, 5), cache_max_bytes=1 << 30)
    svc.mine(tau=1, kmax=2)
    stats = svc.stats()["cache"]
    assert stats["max_bytes"] == 1 << 30
    assert stats["bytes"] > 0
    svc.close()


def test_http_readyz_and_deadline(http_service):
    svc, port = http_service
    code, body = _req(port, "/readyz")
    assert code == 200 and body == {"ready": True, "reason": "ok"}
    # an already-expired deadline returns 499 with the partial body
    with pytest.raises(urllib.error.HTTPError) as e:
        _req(port, "/mine", {"tau": 1, "kmax": 4, "deadline_s": 0.0})
    assert e.value.code == 499
    body = json.loads(e.value.read())
    assert body["source"] == "partial" and body["info"]["interrupted"] == "deadline"
    # the failed deadline did not wedge anything
    code, m = _req(port, "/mine", {"tau": 1, "kmax": 4})
    assert code == 200 and m["source"] == "cold"
    code, c = _req(port, "/cancel", {"tau": 1, "kmax": 4})
    # data routes also carry the request-correlation trace_id
    assert code == 200 and c["cancelled"] == 0 and "trace_id" in c


def test_http_readyz_not_ready_returns_503():
    from repro.launch.serve_miner import make_server

    svc = MiningService(engine="numpy", defer_recovery=True)
    server = make_server(svc, port=0)
    thread = threading.Thread(target=server.serve_forever, daemon=True)
    thread.start()
    port = server.server_address[1]
    try:
        with pytest.raises(urllib.error.HTTPError) as e:
            _req(port, "/readyz")
        assert e.value.code == 503
        assert json.loads(e.value.read())["reason"] == "recovering"
        # liveness stays green while readiness is red
        assert _req(port, "/healthz")[1] == {"ok": True}
        # data routes 503 (retryable) instead of 500
        with pytest.raises(urllib.error.HTTPError) as e:
            _req(port, "/mine?tau=1&kmax=2")
        assert e.value.code == 503
        svc.recover()
        assert _req(port, "/readyz")[0] == 200
    finally:
        server.shutdown()
        server.server_close()
        svc.close()


def test_service_drain_counters():
    svc = MiningService.from_dataset(_rand(0, 80, 4, 5))
    svc.mine(tau=1, kmax=2)
    info = svc.drain(timeout=1.0)
    assert info == {"inflight": 0, "drained": 0, "abandoned": 0}
    stats = svc.stats()
    assert stats["drain"] == info and stats["served"] == 1
    svc.close()
