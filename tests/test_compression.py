"""int8 gradient compression: unbiasedness, bounded error, and the
compressed-DP train step (subprocess with 8 devices)."""

import os
import subprocess
import sys

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.training.compression import dequantize_int8, quantize_int8


def test_quantization_unbiased():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal(512).astype(np.float32))
    keys = jax.random.split(jax.random.PRNGKey(0), 256)
    qs = []
    for k in keys:
        q, scale = quantize_int8(g, k)
        qs.append(dequantize_int8(q, scale))
    mean = np.mean(np.stack(qs), axis=0)
    scale = float(np.abs(np.asarray(g)).max() / 127.0)
    # stochastic rounding is unbiased: mean error << one quantization step
    np.testing.assert_allclose(mean, np.asarray(g), atol=scale * 0.35)


def test_quantization_error_bounded():
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.standard_normal((64, 64)).astype(np.float32) * 5)
    q, scale = quantize_int8(g, jax.random.PRNGKey(1))
    back = dequantize_int8(q, scale)
    assert float(jnp.abs(back - g).max()) <= float(scale) * 1.0001


_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, sys.argv[1])
import numpy as np
import jax, jax.numpy as jnp
from repro.configs import ARCHS, reduced
from repro.models.zoo import build
from repro.training.optimizer import OptConfig, adamw_init
from repro.training.train import make_train_step, make_compressed_dp_step

cfg = reduced(ARCHS["glm4-9b"])
model = build(cfg)
params = model.init(jax.random.PRNGKey(0))
opt = adamw_init(params)
rng = np.random.default_rng(0)
B, S = 8, 16
batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
         "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)}
ocfg = OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)

exact = make_train_step(model, ocfg)
p1, o1, m1 = exact(params, opt, batch)

mesh = jax.make_mesh((8, 1), ("data", "model"))
comp = make_compressed_dp_step(model, ocfg, mesh, ("data",))
p2, o2, m2 = comp(params, opt, batch, jax.random.PRNGKey(42))

# losses identical (loss is computed before compression)
assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4, (m1["loss"], m2["loss"])
# parameters close: int8 grads perturb the update slightly but boundedly
diffs = [float(jnp.abs(a - b).max())
         for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2))]
assert max(diffs) < 5e-3, max(diffs)
# and the update actually moved the params
moved = [float(jnp.abs(a - b).max())
         for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2))]
assert max(moved) > 1e-6
print("COMPRESSED_OK", max(diffs))
"""


@pytest.mark.slow
def test_compressed_dp_step_8dev():
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT, src],
        capture_output=True, text=True, timeout=900,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "COMPRESSED_OK" in proc.stdout
