"""Full service cycle on an 8-device host-platform mesh == single-device.

The word-sharded ``DatasetStore`` (MeshPlacement-aligned tiles) must serve
append -> incremental mine -> report with answers bit-identical to the
single-device store, and mesh-placed cold mining must match the
numpy/jnp/pallas reference engines on itemsets, counts AND per-level stats.

XLA device count must be set before jax initialises, so the check runs in a
subprocess (same pattern as tests/test_sharded_driver.py).
"""

import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, sys.argv[1])
import numpy as np
import jax
from repro.core import KyivConfig, MeshPlacement, mine
from repro.service import IncrementalConfig, MiningService

mesh = jax.make_mesh((2, 4), ("data", "model"))
placement = MeshPlacement(mesh, pair_axes=("data",), word_axis="model")
rng = np.random.default_rng(19)
base = rng.integers(0, 5, size=(220, 5))
delta = rng.integers(0, 5, size=(18, 5))

def stat_tuple(s):
    return (s.k, s.candidates, s.support_pruned, s.bound_pruned,
            s.intersections, s.emitted, s.skipped_absent_uniform, s.stored)

# mesh-placed cold mining == every single-device reference engine,
# on itemsets, counts and the per-level counters
D = np.concatenate([base, delta])
mesh_cold = mine(D, KyivConfig(tau=2, kmax=3, placement=placement))
for engine in ("numpy", "jnp", "pallas"):
    ref = mine(D, KyivConfig(tau=2, kmax=3, engine=engine))
    assert sorted(ref.itemsets) == sorted(mesh_cold.itemsets), engine
    assert list(map(stat_tuple, ref.stats)) == list(map(stat_tuple, mesh_cold.stats)), engine

# full service cycle: append -> mine (cold) -> cache -> append ->
# incremental mine -> report, word-sharded store vs single-device store
svc = MiningService.from_dataset(
    base, placement=placement, incremental=IncrementalConfig(max_delta_fraction=0.5))
ref = MiningService.from_dataset(
    base, incremental=IncrementalConfig(max_delta_fraction=0.5))
assert svc.store.n_words % placement.word_shards == 0
assert svc.stats()["placement"]["word_shards"] == 4

m1, h1 = svc.mine(tau=2, kmax=3), ref.mine(tau=2, kmax=3)
assert (m1.source, h1.source) == ("cold", "cold")
assert sorted(m1.result.itemsets) == sorted(h1.result.itemsets)
assert svc.mine(tau=2, kmax=3).source == "cache"

svc.append(delta); ref.append(delta)
m2, h2 = svc.mine(tau=2, kmax=3), ref.mine(tau=2, kmax=3)
assert m2.source == "incremental", m2.source
assert sorted(m2.result.itemsets) == sorted(h2.result.itemsets)
assert sorted(m2.result.itemsets) == sorted(mesh_cold.itemsets)

rm, rh = svc.report(tau=2, kmax=3), ref.report(tau=2, kmax=3)
for key in ("n_quasi_identifiers", "n_rows", "by_size", "risky_columns",
            "unique_records", "top_risk_records", "risk_histogram"):
    assert rm[key] == rh[key], key

# record-risk profiles (coverage kernels) served from the mesh placement
# match the single-device service bit for bit
km, kh = svc.risk(tau=2, kmax=3), ref.risk(tau=2, kmax=3)
for key in ("records_at_risk", "max_risk", "mean_risk", "qi_total",
            "top_records", "histogram"):
    assert km[key] == kh[key], key
am, ah = svc.anonymize_plan(tau=2, kmax=3), ref.anonymize_plan(tau=2, kmax=3)
assert am["verified"] and ah["verified"]
assert am["cells_suppressed"] == ah["cells_suppressed"]
assert am["generalized_columns"] == ah["generalized_columns"]

svc.close(); ref.close()
print("MESH_SERVICE_OK")
"""


@pytest.mark.slow
def test_mesh_service_cycle_equals_single_device_8dev():
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT, src],
        capture_output=True, text=True, timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "MESH_SERVICE_OK" in proc.stdout
