"""Pallas intersection kernels vs the jnp oracle: shape/dtype sweeps in
interpret mode (CPU container; kernels target TPU BlockSpecs). Exact integer
op — zero tolerance."""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.kernels.intersect import (
    intersect_and_count,
    intersect_count_gathered,
    intersect_count_indexed,
    intersect_count_ref,
    intersect_pairs_ref,
    intersect_write_gathered,
    intersect_write_indexed,
    next_bucket,
)

RNG = np.random.default_rng(0)


def _mk(t, W, M, dtype=np.uint32):
    bits = RNG.integers(0, np.iinfo(dtype).max, size=(t, W), dtype=dtype)
    pairs = RNG.integers(0, t, size=(M, 2)).astype(np.int32)
    return bits, pairs


@pytest.mark.parametrize("t,W,M", [(4, 128, 8), (16, 256, 32), (64, 512, 128), (8, 1024, 16)])
def test_indexed_kernels_match_ref(t, W, M):
    bits, pairs = _mk(t, W, M)
    ref_child = bits[pairs[:, 0]] & bits[pairs[:, 1]]
    ref_cnt = np.bitwise_count(ref_child).sum(1)
    child, cnt = intersect_write_indexed(jnp.asarray(bits), jnp.asarray(pairs),
                                         block_words=128, interpret=True)
    assert np.array_equal(np.asarray(child), ref_child)
    assert np.array_equal(np.asarray(cnt), ref_cnt)
    cnt2 = intersect_count_indexed(jnp.asarray(bits), jnp.asarray(pairs),
                                   block_words=128, interpret=True)
    assert np.array_equal(np.asarray(cnt2), ref_cnt)


@pytest.mark.parametrize("bm,bw", [(1, 128), (8, 128), (4, 256), (8, 512)])
def test_gathered_kernels_block_sweep(bm, bw):
    M, W = 16, 512
    a = RNG.integers(0, 2**32, size=(M, W), dtype=np.uint32)
    b = RNG.integers(0, 2**32, size=(M, W), dtype=np.uint32)
    ref_child = a & b
    ref_cnt = np.bitwise_count(ref_child).sum(1)
    child, cnt = intersect_write_gathered(
        jnp.asarray(a), jnp.asarray(b), block_pairs=bm, block_words=bw, interpret=True
    )
    assert np.array_equal(np.asarray(child), ref_child)
    assert np.array_equal(np.asarray(cnt), ref_cnt)
    cnt2 = intersect_count_gathered(
        jnp.asarray(a), jnp.asarray(b), block_pairs=bm, block_words=bw, interpret=True
    )
    assert np.array_equal(np.asarray(cnt2), ref_cnt)


def test_ref_oracle_consistency():
    bits, pairs = _mk(10, 128, 20)
    child, cnt = intersect_pairs_ref(jnp.asarray(bits), jnp.asarray(pairs))
    assert np.array_equal(np.asarray(child), bits[pairs[:, 0]] & bits[pairs[:, 1]])
    cnt2 = intersect_count_ref(jnp.asarray(bits), jnp.asarray(pairs))
    assert np.array_equal(np.asarray(cnt), np.asarray(cnt2))


@pytest.mark.parametrize("engine", ["numpy", "jnp", "pallas"])
@pytest.mark.parametrize("write", [True, False])
def test_ops_wrapper_engines(engine, write):
    bits, pairs = _mk(12, 128, 37)  # non-power-of-2 M exercises padding
    child, cnt = intersect_and_count(
        bits, pairs, write_children=write, engine=engine, interpret=True
    )
    ref_child = bits[pairs[:, 0]] & bits[pairs[:, 1]]
    assert np.array_equal(cnt, np.bitwise_count(ref_child).sum(1))
    if write:
        assert np.array_equal(child, ref_child)
    else:
        assert child is None


def test_empty_pairs():
    bits, _ = _mk(4, 128, 1)
    child, cnt = intersect_and_count(
        bits, np.zeros((0, 2), np.int32), write_children=True, engine="numpy"
    )
    assert child.shape == (0, 128) and cnt.shape == (0,)


def test_next_bucket():
    assert next_bucket(1) == 256
    assert next_bucket(256) == 256
    assert next_bucket(257) == 512
    assert next_bucket(1 << 20) == 1 << 20


@pytest.mark.parametrize("dtype", [np.uint8, np.uint16, np.uint32])
def test_kernel_dtype_sweep(dtype):
    """Kernels are word-size agnostic: AND+popcount over u8/u16/u32 words."""
    t, W, M = 8, 128, 16
    bits = RNG.integers(0, np.iinfo(dtype).max, size=(t, W), dtype=dtype)
    pairs = RNG.integers(0, t, size=(M, 2)).astype(np.int32)
    ref_child = bits[pairs[:, 0]] & bits[pairs[:, 1]]
    ref_cnt = np.bitwise_count(ref_child).sum(1).astype(np.int32)
    child, cnt = intersect_write_indexed(jnp.asarray(bits), jnp.asarray(pairs),
                                         block_words=128, interpret=True)
    assert child.dtype == jnp.asarray(bits).dtype
    assert np.array_equal(np.asarray(child), ref_child)
    assert np.array_equal(np.asarray(cnt), ref_cnt)


@pytest.mark.parametrize("W", [128, 256, 384, 1024])
def test_kernel_word_width_sweep(W):
    t, M = 6, 12
    bits = RNG.integers(0, 2**32, size=(t, W), dtype=np.uint32)
    pairs = RNG.integers(0, t, size=(M, 2)).astype(np.int32)
    ref = np.bitwise_count(bits[pairs[:, 0]] & bits[pairs[:, 1]]).sum(1)
    cnt = intersect_count_indexed(jnp.asarray(bits), jnp.asarray(pairs),
                                  block_words=128, interpret=True)
    assert np.array_equal(np.asarray(cnt), ref)
