"""Fault tolerance: checkpoint manager semantics + mining/training resume."""

import os

import numpy as np
import pytest

from repro.core import KyivConfig, itemize, mine, preprocess
from repro.core.kyiv import mine_preprocessed
from repro.distributed.checkpoint import CheckpointManager, load_pytree, save_pytree


def test_pytree_roundtrip(tmp_path):
    tree = {
        "params": {"w": np.arange(6, dtype=np.float32).reshape(2, 3)},
        "step": 7,
        "lst": [np.ones(3), 2.5],
        "tup": (1, np.zeros(2, np.int64)),
        "name": "adamw",
    }
    p = str(tmp_path / "ck")
    save_pytree(p, tree, {"note": "x"})
    restored, meta = load_pytree(p)
    assert meta["note"] == "x"
    assert np.array_equal(restored["params"]["w"], tree["params"]["w"])
    assert isinstance(restored["lst"], list) and restored["lst"][1] == 2.5
    assert isinstance(restored["tup"], tuple) and restored["tup"][0] == 1
    assert restored["tup"][1].dtype == np.int64
    assert restored["name"] == "adamw"
    assert restored["step"] == 7


def test_corruption_detected(tmp_path):
    p = str(tmp_path / "ck")
    save_pytree(p, {"w": np.ones(4)})
    # flip a byte in the array payload
    npz = os.path.join(p, "arrays.npz")
    data = bytearray(open(npz, "rb").read())
    data[len(data) // 2] ^= 0xFF
    open(npz, "wb").write(bytes(data))
    with pytest.raises(Exception):
        load_pytree(p)


def test_manager_retention_and_async(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2)
    t = {"x": np.ones(3)}
    cm.save(1, t, blocking=False)
    cm.save(2, t)
    cm.save(5, t)
    cm.wait()
    assert cm.steps() == [2, 5]
    restored, meta = cm.restore()
    assert meta["step"] == 5
    restored2, meta2 = cm.restore(step=2)
    assert meta2["step"] == 2


def test_mining_resume_equivalence():
    """Kill after each level boundary; resume must reproduce the full run."""
    rng = np.random.default_rng(5)
    D = rng.integers(0, 5, size=(100, 7))
    cfg = KyivConfig(tau=2, kmax=4)
    full = mine(D, cfg).canonical_set()
    prep = preprocess(itemize(D), cfg.tau)

    for kill_at in (2, 3):
        saved = {}

        class Stop(Exception):
            pass

        def hook(k, state):
            if k == kill_at:
                saved.update(state)
                raise Stop

        with pytest.raises(Stop):
            mine_preprocessed(prep, cfg, on_level_end=hook)
        resumed = mine_preprocessed(prep, cfg, resume_state=saved).canonical_set()
        assert resumed == full, f"resume at level {kill_at} diverged"


def test_mining_resume_through_disk(tmp_path):
    """Same, but the state round-trips through the checkpoint files
    (simulating a node failure + restart)."""
    from repro.core.prefix import Level
    from repro.core.support import ItemsetIndex

    rng = np.random.default_rng(9)
    D = rng.integers(0, 4, size=(60, 6))
    cfg = KyivConfig(tau=1, kmax=3)
    prep = preprocess(itemize(D), cfg.tau)
    full = mine_preprocessed(prep, cfg).canonical_set()

    cm = CheckpointManager(str(tmp_path))

    class Stop(Exception):
        pass

    def hook(k, state):
        lvl = state["level"]
        cm.save(
            k,
            {
                "itemsets": lvl.itemsets,
                "counts": lvl.counts,
                "bits": lvl.bits,
                "results": [list(ids) for ids, _ in state["results"]],
                "result_counts": np.asarray([c for _, c in state["results"]], np.int64),
                "next_k": state["next_k"],
                "k": lvl.k,
            },
        )
        if k == 2:
            raise Stop

    with pytest.raises(Stop):
        mine_preprocessed(prep, cfg, on_level_end=hook)

    tree, meta = cm.restore()
    lvl = Level(k=int(tree["k"]), itemsets=tree["itemsets"], counts=tree["counts"],
                bits=tree["bits"])
    results = [
        (tuple(int(x) for x in ids), int(c))
        for ids, c in zip(tree["results"], tree["result_counts"])
    ]
    # rebuild grandparent index (level 1 = singletons) for bounds at kmax
    gp = ItemsetIndex(
        np.arange(prep.n_l, dtype=np.int32)[:, None], prep.l_freq, n_symbols=prep.n_l
    )
    state = {
        "results": results,
        "stats": [],
        "level": lvl,
        "grandparent_index": gp,
        "next_k": int(tree["next_k"]),
    }
    resumed = mine_preprocessed(prep, cfg, resume_state=state).canonical_set()
    assert resumed == full
