"""Black-box flight recorder + per-mine cost accounting forensics.

The durability suite (``test_durability.py``) proves the *data* survives a
crash; this suite proves the *explanation* does. Covers:

* frame/segment mechanics — CRC framing roundtrip, durable-kind inline
  flush, torn-tail truncation mirroring the WAL's discipline, rotation
  keeping total disk bounded, incarnation reaping,
* ``halt()`` as the simulated-instant-death seam (buffered events die with
  the process; only fsync'd history survives),
* LastCrashReport construction — open spans, last checkpoint, completed
  levels, active request keys, clean-shutdown detection,
* the chaos scenario: kill mid-mine, restart, and the crash report's
  in-flight ``mine.level`` span / checkpointed level agree with the job
  checkpoint the resumed mine actually continues from,
* cost envelopes on ``info.cost`` for every answer path, the slow-mine
  ring, exemplar-bearing histograms staying lint-clean,
* HTTP: ``/debug/lastcrash``, ``/debug/slowlog``, gzipped ``/debug/bundle``
  (auth-gated, backpressure-exempt).
"""

import gzip
import json
import os
import threading
import types
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.core import KyivConfig, mine
from repro.obs import flight as obs_flight
from repro.obs import metrics as om
from repro.obs.metrics import lint_exposition
from repro.service import (
    FaultInjector,
    KillPoint,
    MiningService,
)


def _rand(seed, n, m, dom=4):
    return np.random.default_rng(seed).integers(0, dom, size=(n, m))


def _sets(result):
    return result.canonical_set()


# a recorder whose cadence never fires during a test: only explicit
# flush() calls and durable kinds reach disk
SLOW = dict(fsync_interval_s=60.0)


def _segments(d, inc):
    return [os.path.join(d, f"inc{inc}.{s}") for s in ("a", "b")]


def _disk_events(d, inc):
    events, torn = [], 0
    for path in _segments(d, inc):
        evs, t = obs_flight.read_segment(path)
        events.extend(evs)
        torn += t
    return sorted(events, key=lambda e: e["seq"]), torn


# ---------------------------------------------------------------------------
# frame / segment mechanics
# ---------------------------------------------------------------------------


def test_record_flush_roundtrip(tmp_path):
    d = str(tmp_path)
    rec = obs_flight.FlightRecorder(d, **SLOW)
    rec.record("dispatch.failure", error="DeviceFault", attempt=1)
    rec.record("probe", value=np.int64(7), arr=(1, 2))
    assert _disk_events(d, rec.incarnation)[0] == []  # buffered, no I/O yet
    rec.flush()
    events, torn = _disk_events(d, rec.incarnation)
    assert torn == 0
    assert [e["kind"] for e in events] == ["dispatch.failure", "probe"]
    assert events[0]["error"] == "DeviceFault"
    assert events[1]["value"] == 7 and events[1]["arr"] == [1, 2]
    assert [e["seq"] for e in events] == [0, 1]
    rec.close()


def test_durable_kind_flushes_inline_carrying_buffer(tmp_path):
    d = str(tmp_path)
    rec = obs_flight.FlightRecorder(d, **SLOW)
    rec.record("span.open", name="mine.level", span_id="s1", attrs={"k": 2})
    assert _disk_events(d, rec.incarnation)[0] == []
    # the durable checkpoint fsyncs the buffered span-open along with itself
    rec.record("job.checkpoint", level=2, key=[2, 4, "exact"])
    events, _ = _disk_events(d, rec.incarnation)
    assert [e["kind"] for e in events] == ["span.open", "job.checkpoint"]
    assert rec.stats()["buffered"] == 0
    rec.close()


def test_torn_tail_truncated_like_wal(tmp_path):
    """Mirror of test_durability's torn-tail cases on the flight ring:
    garbage, a half-written frame, and a corrupted byte are each dropped
    without losing the valid prefix."""
    d = str(tmp_path)
    rec = obs_flight.FlightRecorder(d, **SLOW)
    for i in range(4):
        rec.record("ev", i=i)
    rec.flush()
    path = rec._segment_path(rec._side)
    rec.halt()

    good = open(path, "rb").read()
    # power cut mid-flush: half of a fifth frame reaches the platter
    payload = json.dumps({"kind": "ev", "i": 4, "seq": 4}).encode()
    import struct as _struct
    import zlib as _zlib

    frame = obs_flight._HEADER.pack(
        obs_flight.MAGIC, _zlib.crc32(payload), len(payload)
    ) + payload
    with open(path, "ab") as f:
        f.write(frame[: len(frame) // 2])
    events, torn = obs_flight.read_segment(path)
    assert [e["i"] for e in events] == [0, 1, 2, 3]
    assert torn == len(frame) // 2

    # plain garbage tail
    with open(path, "wb") as f:
        f.write(good + b"\x00garbage-tail")
    events, torn = obs_flight.read_segment(path)
    assert len(events) == 4 and torn == len(b"\x00garbage-tail")

    # one flipped byte inside the last frame: CRC rejects it
    corrupt = bytearray(good)
    corrupt[-3] ^= 0xFF
    with open(path, "wb") as f:
        f.write(bytes(corrupt))
    events, torn = obs_flight.read_segment(path)
    assert [e["i"] for e in events] == [0, 1, 2] and torn > 0

    # recover() tolerates the torn ring and still builds a report
    report = obs_flight.recover(d)
    assert report is not None and report.n_events == 3
    assert report.torn_bytes == torn and not report.clean_shutdown


def test_rotation_keeps_total_disk_bounded(tmp_path):
    d = str(tmp_path)
    rec = obs_flight.FlightRecorder(d, fsync_interval_s=60.0, max_bytes=4096)
    pad = "x" * 64
    for i in range(200):
        rec.record("ev", i=i, pad=pad, durable=True)  # one frame per flush
    st = rec.stats()
    assert st["rotations"] >= 2
    total = sum(
        os.path.getsize(p) for p in _segments(d, rec.incarnation)
        if os.path.exists(p)
    )
    # each segment stays under max_bytes//2 plus one in-flight frame
    assert total <= 4096 + 2 * 256
    # the newest events survived rotation; recovery sees the recent tail
    events, _ = _disk_events(d, rec.incarnation)
    assert events and events[-1]["i"] == 199
    rec.halt()
    report = obs_flight.recover(d)
    assert report.n_events == len(events) < 200


def test_incarnations_reaped_and_lastcrash_persisted(tmp_path):
    d = str(tmp_path)
    assert obs_flight.recover(d) is None  # first boot: nothing to report
    rec1 = obs_flight.FlightRecorder(d, **SLOW)
    rec1.record("config", config={"tau": 2})
    rec1.close()

    report = obs_flight.recover(d)
    assert report.incarnation == rec1.incarnation
    assert report.clean_shutdown and report.config == {"tau": 2}
    assert json.load(open(os.path.join(d, "lastcrash.json")))["clean_shutdown"]

    rec2 = obs_flight.FlightRecorder(d, **SLOW)
    assert rec2.incarnation == rec1.incarnation + 1
    # predecessors reaped: only the live incarnation's segments remain
    assert obs_flight.scan_incarnations(d) == [rec2.incarnation]
    rec2.close()


def test_halt_discards_buffered_events(tmp_path):
    d = str(tmp_path)
    rec = obs_flight.FlightRecorder(d, **SLOW)
    rec.record("job.checkpoint", level=3)  # durable -> on disk
    rec.record("span.close", name="mine.level", span_id="s9")  # buffered
    rec.halt()
    events, _ = _disk_events(d, rec.incarnation)
    assert [e["kind"] for e in events] == ["job.checkpoint"]
    rec.record("late", x=1)  # ignored after halt
    rec.flush()
    assert len(_disk_events(d, rec.incarnation)[0]) == 1


# ---------------------------------------------------------------------------
# span listener + report construction
# ---------------------------------------------------------------------------


def _span(name, span_id, **attrs):
    return types.SimpleNamespace(
        name=name, trace_id="t1", span_id=span_id, parent_id=None,
        attrs=attrs, duration=0.01,
    )


def test_span_listener_filters_and_report_names_in_flight_work(tmp_path):
    d = str(tmp_path)
    rec = obs_flight.FlightRecorder(d, **SLOW)
    mine_sp = _span("service.mine", "s1", key=[2, 3, "exact"])
    lvl2, lvl3 = _span("mine.level", "s2", k=2), _span("mine.level", "s3", k=3)
    for sp in (mine_sp, lvl2):
        rec.span_listener("open", sp, None)
    rec.span_listener("close", lvl2, None)
    rec.span_listener("open", lvl3, None)
    # hot-path micro-spans are filtered out of the ring
    rec.span_listener("open", _span("wal.append", "s4"), None)
    rec.record("job.checkpoint", level=2)
    rec.halt()

    report = obs_flight.recover(d)
    assert not report.clean_shutdown
    open_names = {(s["name"], s["attrs"].get("k")) for s in report.open_spans}
    assert open_names == {("service.mine", None), ("mine.level", 3)}
    assert report.last_completed_level == 2
    assert report.last_checkpoint["level"] == 2
    assert report.active_request_keys == [[2, 3, "exact"]]
    rec.close()


def test_clean_close_yields_clean_report(tmp_path):
    d = str(tmp_path)
    rec = obs_flight.FlightRecorder(d, **SLOW)
    sp = _span("service.mine", "s1")
    rec.span_listener("open", sp, None)
    rec.span_listener("close", sp, None)
    rec.close()
    report = obs_flight.recover(d)
    assert report.clean_shutdown and report.open_spans == []


# ---------------------------------------------------------------------------
# chaos: kill mid-mine -> crash report agrees with the resumed job
# ---------------------------------------------------------------------------


def test_kill_mid_mine_crash_report_matches_resume_checkpoint(tmp_path):
    data = _rand(0, 150, 6, 4)
    cfg = dict(tau=2, kmax=4)
    undisturbed = mine(data, KyivConfig(**cfg))

    d = str(tmp_path / "wal")
    inj = FaultInjector()
    # cadence far beyond the test: only durable checkpoint flushes persist,
    # exactly what a real power cut inside the fsync window leaves behind
    svc = MiningService(
        engine="numpy", wal_dir=d, fault_injector=inj, flight_fsync_s=60.0
    )
    svc.append(data)
    inj.arm("mine.level_end", action="raise", exc=KillPoint("mid-mine"), after=1)
    with pytest.raises(KillPoint):
        svc.mine(**cfg)
    # the KillPoint unwound the span stack (a real crash would not have) —
    # halt() discards those buffered closes, freezing the on-disk ring at
    # the instant of death
    svc.flight.halt()
    svc.close()

    svc2 = MiningService(engine="numpy", wal_dir=d)
    try:
        report = svc2.last_crash
        assert report is not None and not report.clean_shutdown
        assert svc2.last_crash_report() == report.to_dict()

        # the ring names the level that was in flight when the process died
        open_levels = [
            s["attrs"].get("k") for s in report.open_spans
            if s["name"] == "mine.level"
        ]
        assert len(open_levels) == 1
        in_flight = open_levels[0]
        assert report.last_completed_level == in_flight - 1
        assert report.last_checkpoint["level"] == in_flight
        assert report.active_request_keys  # the mine's cache key, captured

        # ...and the restarted service resumes from that same checkpoint
        assert svc2.stats()["durability"]["resumed_jobs"] == 1
        r = svc2.mine(**cfg)
        assert r.info["resumed_from_level"] == report.last_checkpoint["level"] + 1
        assert _sets(r.result) == _sets(undisturbed)

        fr = svc2.stats()["forensics"]
        assert fr["last_crash"]["clean_shutdown"] is False
        assert fr["last_crash"]["open_spans"] >= 1
        assert fr["flight"]["incarnation"] == report.incarnation + 1
    finally:
        svc2.close()

    # an orderly close is distinguishable from the crash
    svc3 = MiningService(engine="numpy", wal_dir=d)
    assert svc3.last_crash is not None and svc3.last_crash.clean_shutdown
    svc3.close()


# ---------------------------------------------------------------------------
# cost accounting on every answer path
# ---------------------------------------------------------------------------


def test_cost_envelope_per_answer_path(tmp_path):
    from repro.obs.cost import SLOW_MINES

    d = str(tmp_path / "wal")
    svc = MiningService(engine="numpy", wal_dir=d, slow_mine_threshold_s=0.0)
    slow_cold_before = SLOW_MINES.value(path="cold")
    try:
        svc.append(_rand(0, 150, 6, 4))
        r = svc.mine(tau=2, kmax=4)
        cost = r.info["cost"]
        assert cost["path"] == "cold"
        assert cost["rows_scanned"] > 0 and cost["candidate_pairs"] > 0
        assert cost["levels"] >= 2 and cost["itemsets_emitted"] > 0
        assert cost["executables_compiled"] >= 0
        assert cost["wall_s"] >= 0 and cost["trace_id"]

        r2 = svc.mine(tau=2, kmax=4)
        c2 = r2.info["cost"]
        assert c2["path"] == "cache" and c2["levels"] == 0
        assert c2["rows_scanned"] == 0  # a cache hit scans nothing

        svc.append(_rand(1, 30, 6, 4))
        r3 = svc.mine(tau=2, kmax=4)
        c3 = r3.info["cost"]
        assert c3["path"] == "incremental" and c3["levels"] >= 1
        assert c3["trace_id"] != cost["trace_id"]

        # every mine crossed the 0s slow threshold into the forensics ring
        entries = svc.slowlog_entries()
        assert len(entries) == 3
        assert entries[0]["path"] == "incremental"  # newest first
        assert all(e["trace_id"] for e in entries)
        assert svc.stats()["forensics"]["slowlog"]["total"] == 3

        # the counter is process-global — assert the delta, not the total
        assert SLOW_MINES.value(path="cold") == slow_cold_before + 1
        text = om.REGISTRY.render()
        assert lint_exposition(text) == []
        assert 'repro_slow_mines_total{path="cold"}' in text
        assert 'repro_mine_cost_candidate_pairs_bucket{path="cold"' in text
        # exemplar: the latency histogram links back to the mine's trace
        assert f'# {{trace_id="{cost["trace_id"]}"}}' in text
    finally:
        svc.close()


def test_cost_envelope_on_sampled_path():
    from repro.service import SamplingConfig

    svc = MiningService.from_dataset(
        _rand(2, 400, 5, 4),
        sampling=SamplingConfig(oversample=1.0, min_rows=64),
    )
    try:
        r = svc.mine(tau=3, kmax=3, mode="approx")
        cost = r.info["cost"]
        assert cost["path"] in ("approx", "refined")
        assert cost["rows_scanned"] > 0 and cost["trace_id"]
    finally:
        svc.close()


def test_slowlog_threshold_filters(tmp_path):
    svc = MiningService.from_dataset(
        _rand(0, 80, 5, 4), engine="numpy", slow_mine_threshold_s=1e9
    )
    try:
        svc.mine(tau=2, kmax=3)
        assert svc.slowlog_entries() == []  # nothing is that slow
        assert svc.stats()["forensics"]["slowlog"]["total"] == 0
    finally:
        svc.close()


# ---------------------------------------------------------------------------
# HTTP: /debug/lastcrash, /debug/slowlog, /debug/bundle
# ---------------------------------------------------------------------------


def _req(port, path, payload=None, headers=None):
    url = f"http://127.0.0.1:{port}{path}"
    data = json.dumps(payload).encode() if payload is not None else None
    resp = urllib.request.urlopen(
        urllib.request.Request(url, data=data, headers=headers or {}), timeout=60
    )
    return resp, resp.read()


@pytest.fixture()
def debug_http_service(tmp_path):
    from repro.launch.serve_miner import make_server

    svc = MiningService(
        engine="numpy", wal_dir=str(tmp_path / "wal"), slow_mine_threshold_s=0.0
    )
    svc.append(_rand(0, 120, 5, 4))
    server = make_server(svc, port=0)
    thread = threading.Thread(target=server.serve_forever, daemon=True)
    thread.start()
    yield svc, server.server_address[1]
    server.shutdown()
    server.server_close()
    svc.close()


def test_http_debug_endpoints_and_bundle(debug_http_service):
    _, port = debug_http_service
    resp, body = _req(port, "/debug/lastcrash")
    assert json.loads(body)["report"] is None  # first boot over this dir

    _req(port, "/mine", {"tau": 2, "kmax": 3})
    _req(port, "/mine", {"tau": 2, "kmax": 4})

    _, body = _req(port, "/debug/slowlog?n=1")
    j = json.loads(body)
    assert len(j["entries"]) == 1 and j["slowlog"]["total"] == 2
    assert j["entries"][0]["trace_id"] and "wall_s" in j["entries"][0]

    resp, body = _req(port, "/debug/bundle")
    assert resp.headers["Content-Encoding"] == "gzip"
    assert resp.headers["Content-Type"].startswith("application/json")
    bundle = json.loads(gzip.decompress(body))
    for key in ("generated_at", "config", "stats", "metrics", "traces",
                "slowlog", "lastcrash", "exec_cache_keys", "flight"):
        assert key in bundle, key
    assert bundle["config"]["slow_mine_threshold_s"] == 0.0
    assert bundle["stats"]["store"]["n_rows"] == 120
    assert "repro_service_mine_latency_seconds" in bundle["metrics"]
    assert len(bundle["slowlog"]) == 2
    assert any(t["spans"] for t in bundle["traces"])

    with pytest.raises(urllib.error.HTTPError) as e:
        _req(port, "/debug/nosuch")
    assert e.value.code == 404


def test_debug_routes_auth_gated_but_backpressure_exempt():
    from repro.launch.serve_miner import make_server

    svc = MiningService.from_dataset(_rand(0, 60, 3, 4), engine="numpy")
    server = make_server(svc, port=0, auth_token="tok", max_inflight=1)
    thread = threading.Thread(target=server.serve_forever, daemon=True)
    thread.start()
    port = server.server_address[1]
    try:
        with pytest.raises(urllib.error.HTTPError) as e:
            _req(port, "/debug/slowlog")
        assert e.value.code == 401
        resp, body = _req(
            port, "/debug/slowlog", headers={"Authorization": "Bearer tok"}
        )
        assert resp.status == 200 and "entries" in json.loads(body)
    finally:
        server.shutdown()
        server.server_close()
        svc.close()


def test_no_flight_flag_disables_recorder(tmp_path):
    svc = MiningService(
        engine="numpy", wal_dir=str(tmp_path / "wal"), flight_enabled=False
    )
    try:
        assert svc.flight is None and svc.last_crash is None
        svc.append(_rand(0, 40, 4, 4))
        r = svc.mine(tau=2, kmax=3)
        assert r.info["cost"]["path"] == "cold"  # cost accounting still on
        assert svc.stats()["forensics"]["flight"] is None
        assert not os.path.isdir(os.path.join(str(tmp_path / "wal"), "flight"))
    finally:
        svc.close()
