"""Sharding planner: spec correctness, divisibility fallbacks, cache chains.
Runs in a subprocess with a 16-device mesh (device count locks at jax init)."""

import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import sys
sys.path.insert(0, sys.argv[1])
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.configs import ARCHS, SHAPES, input_specs
from repro.distributed.sharding import make_plan
from repro.models.zoo import build

mesh = jax.make_mesh((4, 4), ("data", "model"))
plan = make_plan(mesh)
assert plan.dp == ("data",) and plan.tp == "model"

# params of a dense arch: every leaf gets a valid spec
arch = ARCHS["glm4-9b"]
model = build(arch)
aparams = model.abstract_params()
shardings = plan.param_shardings(aparams)
leaves = jax.tree.leaves(shardings)
assert len(leaves) == len(jax.tree.leaves(aparams))
import numpy as np
flat_p, _ = jax.tree_util.tree_flatten_with_path(aparams)
flat_s = jax.tree.leaves(shardings)
n_sharded = 0
for (path, leaf), sh in zip(flat_p, flat_s):
    spec = sh.spec
    # every named dim divides
    for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * 10):
        if ax is not None:
            size = np.prod([mesh.shape[a] for a in ((ax,) if isinstance(ax, str) else ax)])
            assert dim % size == 0, (path, leaf.shape, spec)
    if any(a is not None for a in spec):
        n_sharded += 1
assert n_sharded > len(flat_p) * 0.6, f"only {n_sharded}/{len(flat_p)} sharded"

# stacked group leaves: leading dim unsharded
from jax.tree_util import DictKey
for (path, leaf), sh in zip(flat_p, flat_s):
    names = [str(k.key) for k in path if isinstance(k, DictKey)]
    if "groups" in names and leaf.ndim >= 2:
        assert sh.spec[0] is None, (path, sh.spec)

# decode cache fallback chain: qwen kv=8 not divisible by 16 -> try on 4x4:
# kv=8 % 4 == 0 -> kv on tp
arch_q = ARCHS["qwen1.5-110b"]
model_q = build(arch_q)
acache = model_q.init_cache(8, 128, abstract=True)
cshard = plan.cache_shardings(acache)
flat_c, _ = jax.tree_util.tree_flatten_with_path(acache)
flat_cs = jax.tree.leaves(cshard)
for (path, leaf), sh in zip(flat_c, flat_cs):
    names = [str(k.key) for k in path if isinstance(k, DictKey)]
    if names[-1] in ("k", "v"):
        assert "model" in str(sh.spec), (names, sh.spec)

# MQA (recurrentgemma): kv=1 -> falls to head_dim 256 % 4 == 0
arch_r = ARCHS["recurrentgemma-9b"]
model_r = build(arch_r)
acache_r = model_r.init_cache(8, 64, abstract=True)
cs_r = plan.cache_shardings(acache_r)

# end-to-end: tiny sharded train step runs and matches unsharded numerics
from repro.configs import reduced
from repro.training.optimizer import OptConfig, adamw_init
from repro.training.train import make_train_step
import numpy as np
cfg = reduced(ARCHS["glm4-9b"])
m2 = build(cfg)
params = m2.init(jax.random.PRNGKey(0))
opt = adamw_init(params)
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 16)), jnp.int32),
         "labels": jnp.asarray(rng.integers(0, cfg.vocab, (8, 16)), jnp.int32)}
ocfg = OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
plain = make_train_step(m2, ocfg)
p_ref, _, met_ref = plain(params, opt, batch)

step_fn, shardings_for = make_train_step(m2, ocfg, plan)
ap = jax.eval_shape(lambda: m2.init(jax.random.PRNGKey(0)))
pspec, ospec = shardings_for(ap)
with jax.set_mesh(mesh):
    jitted = jax.jit(step_fn, in_shardings=(pspec, ospec, plan.batch_shardings(batch)),
                     out_shardings=(pspec, ospec, None))
    p_sh, _, met_sh = jitted(params, opt, batch)
assert abs(float(met_ref["loss"]) - float(met_sh["loss"])) < 2e-3
diffs = [float(jnp.abs(a - b).max()) for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_sh))]
assert max(diffs) < 2e-3, max(diffs)
print("PLAN_OK")
"""


@pytest.mark.slow
def test_sharding_plan_16dev():
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT, src],
        capture_output=True, text=True, timeout=900,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "PLAN_OK" in proc.stdout
