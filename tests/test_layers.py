"""Layer-level equivalence tests: scan forms vs naive recurrences, decode
steps vs full-sequence forms, MoE dispatch invariants."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import MoECfg, SSMCfg
from repro.models.layers.rglru import init_rglru, rglru_decode, rglru_train
from repro.models.layers.ssd import init_ssd, init_ssd_state, ssd_decode, ssd_scan, ssd_train
from repro.models.layers.moe import apply_moe, init_moe, moe_capacity

KEY = jax.random.PRNGKey(0)
RNG = np.random.default_rng(0)


def test_ssd_scan_equals_naive_recurrence():
    """Chunked SSD == step-by-step linear recurrence h' = h·exp(dt·A) + dt·B⊗x."""
    b, s, h, p, g, n = 2, 23, 4, 8, 2, 16
    x = RNG.standard_normal((b, s, h, p)).astype(np.float32)
    dt = np.abs(RNG.standard_normal((b, s, h))).astype(np.float32) * 0.5
    A = -np.abs(RNG.standard_normal(h)).astype(np.float32)
    B = RNG.standard_normal((b, s, g, n)).astype(np.float32)
    C = RNG.standard_normal((b, s, g, n)).astype(np.float32)

    y, final = ssd_scan(jnp.asarray(x), jnp.asarray(dt), jnp.asarray(A),
                        jnp.asarray(B), jnp.asarray(C), chunk=8)

    # naive reference
    hpg = h // g
    state = np.zeros((b, h, p, n), np.float32)
    y_ref = np.zeros((b, s, h, p), np.float32)
    for t in range(s):
        for bi in range(b):
            for hi in range(h):
                gi = hi // hpg
                decay = np.exp(dt[bi, t, hi] * A[hi])
                state[bi, hi] = state[bi, hi] * decay + dt[bi, t, hi] * np.outer(
                    x[bi, t, hi], B[bi, t, gi]
                )
                y_ref[bi, t, hi] = state[bi, hi] @ C[bi, t, gi]
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(final), state, rtol=2e-4, atol=2e-4)


def test_ssd_decode_continues_train():
    """prefill(S) state + decode(1) == train over S+1 (last output)."""
    ssm = SSMCfg(d_state=8, d_inner=32, head_dim=8, n_groups=1, chunk=4, d_conv=4)
    p = init_ssd(KEY, 16, ssm)
    b, s = 2, 9
    x_full = jnp.asarray(RNG.standard_normal((b, s + 1, 16)), jnp.float32)
    out_full = ssd_train(p, x_full, ssm)
    out_pre, cache = ssd_train(p, x_full[:, :s], ssm, return_state=True)
    out_step, _ = ssd_decode(p, x_full[:, s:], cache, ssm)
    np.testing.assert_allclose(
        np.asarray(out_step)[:, 0], np.asarray(out_full)[:, s], rtol=3e-4, atol=3e-4
    )
    np.testing.assert_allclose(
        np.asarray(out_pre), np.asarray(out_full)[:, :s], rtol=3e-4, atol=3e-4
    )


def test_rglru_decode_continues_train():
    p = init_rglru(KEY, 16, 24)
    b, s = 2, 11
    x_full = jnp.asarray(RNG.standard_normal((b, s + 1, 16)), jnp.float32)
    out_full = rglru_train(p, x_full)
    out_pre, cache = rglru_train(p, x_full[:, :s], return_state=True)
    out_step, cache2 = rglru_decode(p, x_full[:, s:], cache)
    np.testing.assert_allclose(
        np.asarray(out_step)[:, 0], np.asarray(out_full)[:, s], rtol=3e-4, atol=3e-4
    )
    assert cache2["h"].shape == cache["h"].shape
    assert cache2["conv"].shape == cache["conv"].shape


def test_rglru_state_decay_bounds():
    """RG-LRU gates keep |a| < 1 -> bounded state for bounded input."""
    p = init_rglru(jax.random.PRNGKey(3), 8, 8)
    x = jnp.asarray(RNG.standard_normal((1, 500, 8)), jnp.float32) * 10
    out, st = rglru_train(p, x, return_state=True)
    assert np.all(np.isfinite(np.asarray(out)))
    assert np.abs(np.asarray(st["h"])).max() < 1e4


def test_moe_capacity_and_determinism():
    cfg = MoECfg(n_experts=4, top_k=2, d_expert=16, n_shared=1, capacity_factor=10.0)
    p = init_moe(KEY, 8, cfg)
    x = jnp.asarray(RNG.standard_normal((2, 12, 8)), jnp.float32)
    y1 = apply_moe(p, x, cfg)
    y2 = apply_moe(p, x, cfg)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    assert y1.shape == x.shape
    assert np.all(np.isfinite(np.asarray(y1)))


def test_moe_huge_capacity_equals_dense_mixture():
    """With capacity >> tokens nothing is dropped: output == explicit top-k
    mixture of expert MLPs."""
    cfg = MoECfg(n_experts=4, top_k=2, d_expert=16, n_shared=0, capacity_factor=100.0)
    d = 8
    p = init_moe(KEY, d, cfg)
    x = jnp.asarray(RNG.standard_normal((1, 6, d)), jnp.float32)
    y = np.asarray(apply_moe(p, x, cfg))

    xt = np.asarray(x).reshape(-1, d)
    logits = xt @ np.asarray(p["router"])
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    ref = np.zeros_like(xt)
    for t in range(xt.shape[0]):
        top = np.argsort(-probs[t])[: cfg.top_k]
        w = probs[t][top] / probs[t][top].sum()
        for e, wi in zip(top, w):
            gate = xt[t] @ np.asarray(p["w_gate"][e])
            up = xt[t] @ np.asarray(p["w_up"][e])
            silu = gate / (1 + np.exp(-gate)) * up
            ref[t] += wi * (silu @ np.asarray(p["w_down"][e]))
    np.testing.assert_allclose(y.reshape(-1, d), ref, rtol=2e-4, atol=2e-4)


def test_moe_group_counts_match():
    """Different group counts change drop patterns but with ample capacity
    all groupings agree."""
    cfg = MoECfg(n_experts=4, top_k=2, d_expert=16, capacity_factor=50.0)
    p = init_moe(KEY, 8, cfg)
    x = jnp.asarray(RNG.standard_normal((2, 12, 8)), jnp.float32)
    y1 = np.asarray(apply_moe(p, x, cfg, n_groups=1))
    y2 = np.asarray(apply_moe(p, x, cfg, n_groups=4))
    np.testing.assert_allclose(y1, y2, rtol=2e-4, atol=2e-4)


def test_moe_capacity_rounding():
    assert moe_capacity(64, 4, 2, 1.0) % 8 == 0
    assert moe_capacity(1, 64, 1, 1.0) >= 8
