"""The device-resident level frontier: kernel parity, driver bit-identity,
eager retirement, and the unified executable cache.

The host reference path (``HostPlacement`` frontier methods) is the oracle:
every test asserts the device/mesh frontier produces identical results *and*
identical per-level counters. The 8-device mesh runs in a subprocess (XLA
device count must pre-date jax init); the hypothesis sweeps live in
tests/test_frontier_prop.py.
"""

import os
import subprocess
import sys

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import KyivConfig, exec_cache, mine
from repro.core.frontier import LevelFrontier
from repro.core.placement import DevicePlacement, make_placement
from repro.core.prefix import (
    Level,
    generate_candidates,
    group_reps,
    iter_group_spans,
    prefix_group_sizes,
)
from repro.core.support import ItemsetIndex, support_test
from repro.kernels.frontier import ops as fops
from repro.kernels.frontier import ref as fref
from repro.kernels.intersect import LevelPipeline

RNG = np.random.default_rng(77)


def _rand_level(t_target, k, n_symbols, seed):
    """A lex-sorted level table with realistic prefix groups (itemset rows
    are strictly increasing, as the prefix-tree invariant requires)."""
    rng = np.random.default_rng(seed)
    rows: set[tuple] = set()
    tries = 0
    while len(rows) < t_target and tries < 50 * t_target:
        tries += 1
        if k == 1:
            rows.add((int(rng.integers(0, n_symbols)),))
            continue
        prefix = tuple(sorted(int(x) for x in rng.choice(n_symbols, size=k - 1, replace=False)))
        for last in rng.choice(n_symbols, size=int(rng.integers(1, 6)), replace=False):
            if int(last) > prefix[-1]:
                rows.add(prefix + (int(last),))
    its = np.asarray(sorted(rows), dtype=np.int32)
    counts = rng.integers(1, 50, size=len(its)).astype(np.int64)
    return its, counts


def _stat_tuple(s):
    return (s.k, s.candidates, s.support_pruned, s.bound_pruned,
            s.intersections, s.emitted, s.skipped_absent_uniform, s.stored)


# ---------------------------------------------------------------------------
# kernel-level parity: gen / support / mask / partition
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("k,n_symbols", [(2, 40), (3, 300), (4, 70_000)])
def test_gen_support_matches_host(k, n_symbols):
    its, counts = _rand_level(60, k, n_symbols, seed=k)
    if its.shape[0] < 2:
        pytest.skip("degenerate level")
    level = Level(k=k, itemsets=its, counts=counts, bits=None)
    cand = generate_candidates(level)
    idx = ItemsetIndex(its, counts, n_symbols=n_symbols)
    ok_host = support_test(cand.itemsets, idx)

    dev = make_placement("jnp")
    state = dev.prepare_frontier(its, counts, n_symbols)
    sizes = prefix_group_sizes(its)
    got_i, got_j, got_ok = [], [], []
    for lo, hi, n_pairs in iter_group_spans(sizes, 1 << 22):
        if n_pairs == 0:
            continue
        pairs, ok = dev.frontier_dispatch(state, lo, hi, n_pairs)
        pairs, ok = np.asarray(pairs), np.asarray(ok)
        got_i.append(pairs[:n_pairs, 0])
        got_j.append(pairs[:n_pairs, 1])
        got_ok.append(ok[:n_pairs])
        assert not ok[n_pairs:].any(), "padding rows must be not-ok"
    dev.release(state)
    assert np.array_equal(np.concatenate(got_i), cand.i_idx)
    assert np.array_equal(np.concatenate(got_j), cand.j_idx)
    assert np.array_equal(np.concatenate(got_ok), ok_host)


def test_packed_key_lookup_matches_itemset_index():
    for n_symbols, k in ((17, 2), (1000, 3), (90_000, 4)):
        its, _ = _rand_level(80, k, n_symbols, seed=n_symbols)
        idx = ItemsetIndex(its, None, n_symbols=n_symbols)
        table = fref.key_table_np(its, n_symbols, fops.table_pad(its.shape[0]))
        rng = np.random.default_rng(1)
        present = its[rng.integers(0, its.shape[0], size=30)]
        absent = present.copy()
        absent[:, -1] = (absent[:, -1] + 1) % n_symbols
        for q in (present, absent):
            want = idx.lookup(q) >= 0
            got_np = fref.lookup_np(table, fref.pack_rows_np(q, n_symbols))
            assert np.array_equal(got_np, want)
            b, ipw, _ = fops.pack_params(n_symbols, k)
            from repro.kernels.frontier.frontier import lookup_keys, pack_cols

            queries = pack_cols([jnp.asarray(q[:, c]) for c in range(k)], b, ipw)
            got_dev = np.asarray(
                lookup_keys(jnp.asarray(table), queries, t_pad=table.shape[0])
            )
            assert np.array_equal(got_dev, want)


def test_partition_is_stable_class_argsort():
    part = fops.partition
    for seed in range(3):
        rng = np.random.default_rng(seed)
        classes = rng.integers(0, 3, size=512).astype(np.int32)
        order, n_emit, n_store = part(jnp.asarray(classes))
        ref_order, ref_e, ref_s = fref.partition_np(classes)
        assert np.array_equal(np.asarray(order), ref_order)
        assert (int(n_emit), int(n_store)) == (ref_e, ref_s)


def test_mask_pruned_neutralises_without_reorder():
    mask = fops.mask_pruned
    rng = np.random.default_rng(3)
    pairs = rng.integers(0, 9, size=(64, 2)).astype(np.int32)
    ok = rng.random(64) < 0.5
    out, n_ok = mask(jnp.asarray(pairs), jnp.asarray(ok))
    out = np.asarray(out)
    assert int(n_ok) == ok.sum()
    assert np.array_equal(out[ok], pairs[ok])  # survivors untouched, in place
    assert np.all(out[~ok, 0] == out[~ok, 1])  # pruned -> CLASS_SKIP self-pairs


def test_group_reps_matches_generate_candidates():
    its, _ = _rand_level(50, 3, 200, seed=9)
    reps = group_reps(its)
    cand = generate_candidates(Level(k=3, itemsets=its, counts=np.zeros(len(its)), bits=None))
    assert reps.sum() == cand.m
    assert np.array_equal(np.repeat(np.arange(len(its)), reps), cand.i_idx)


# ---------------------------------------------------------------------------
# driver bit-identity: device frontier == host reference, results AND stats
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("engine", ["jnp", "pallas"])
def test_mine_device_frontier_bit_identical(engine):
    D = RNG.integers(0, 5, size=(250, 7))
    for tau, kmax, use_bounds in ((1, 3, True), (2, 4, True), (2, 4, False)):
        ref = mine(D, KyivConfig(tau=tau, kmax=kmax, engine="numpy", use_bounds=use_bounds))
        got = mine(D, KyivConfig(tau=tau, kmax=kmax, engine=engine, use_bounds=use_bounds))
        off = mine(
            D,
            KyivConfig(
                tau=tau, kmax=kmax, engine=engine,
                use_bounds=use_bounds, device_frontier=False,
            ),
        )
        for other in (got, off):
            assert sorted(other.itemsets) == sorted(ref.itemsets)
            assert list(map(_stat_tuple, other.stats)) == list(map(_stat_tuple, ref.stats))


def test_mine_device_frontier_with_mirrors_and_paper_expansion():
    base = RNG.integers(0, 3, size=(60, 4))
    D = np.concatenate([base, base[:, :2]], axis=1)  # duplicate columns -> mirrors
    for expansion in ("full", "paper"):
        ref = mine(D, KyivConfig(tau=1, kmax=3, engine="numpy", expansion=expansion))
        got = mine(D, KyivConfig(tau=1, kmax=3, engine="jnp", expansion=expansion))
        assert sorted(got.itemsets) == sorted(ref.itemsets)
        assert list(map(_stat_tuple, got.stats)) == list(map(_stat_tuple, ref.stats))


def test_mine_resume_mid_run_with_device_frontier():
    D = RNG.integers(0, 5, size=(120, 7))
    cfg = KyivConfig(tau=2, kmax=4, engine="jnp")
    from repro.core import itemize, preprocess
    from repro.core.kyiv import mine_preprocessed

    prep = preprocess(itemize(D), cfg.tau)
    full = mine_preprocessed(prep, cfg)

    for kill_at in (2, 3):
        saved = {}

        class Stop(Exception):
            pass

        def hook(k, state):
            # checkpointed level bitsets are materialised host numpy even on
            # the device frontier (the states must stay picklable)
            assert state.level.bits is None or isinstance(state.level.bits, np.ndarray)
            if k == kill_at:
                saved.update(state)
                raise Stop

        with pytest.raises(Stop):
            mine_preprocessed(prep, cfg, on_level_end=hook)
        resumed = mine_preprocessed(prep, cfg, resume_state=saved)
        assert sorted(resumed.itemsets) == sorted(full.itemsets)
        assert list(map(_stat_tuple, resumed.stats)) == list(
            map(_stat_tuple, full.stats)
        )


def test_timing_breakdown_fields():
    D = RNG.integers(0, 4, size=(80, 5))
    res = mine(D, KyivConfig(tau=1, kmax=3, engine="jnp"))
    levels = res.timing_breakdown()
    assert levels and {"k", "host_busy", "device_busy", "candidates"} <= set(levels[0])
    assert res.total_candidate_time >= 0.0


# ---------------------------------------------------------------------------
# eager retirement
# ---------------------------------------------------------------------------


def test_level_pipeline_retire_releases_owned_buffers():
    bits = RNG.integers(0, 2**32, size=(10, 8), dtype=np.uint32)
    counts = np.ones(10, dtype=np.int64)
    pipe = LevelPipeline(bits, counts, tau=1, placement=make_placement("jnp"))
    state = pipe._state
    pipe.submit(np.asarray([[0, 1], [2, 3]], dtype=np.int32), True).result()
    pipe.retire()
    assert pipe._state is None
    assert state[0].is_deleted()  # numpy input -> placement-owned upload

    # resident (already-jax) bits are the caller's: never deleted
    dev_bits = jnp.asarray(bits)
    pipe2 = LevelPipeline(dev_bits, counts, tau=1, placement=make_placement("jnp"))
    pipe2.retire()
    assert not dev_bits.is_deleted()


def test_frontier_state_release():
    its, counts = _rand_level(30, 2, 50, seed=4)
    dev = DevicePlacement("jnp")
    state = dev.prepare_frontier(its, counts, 50)
    ids, keys = state["ids"], state["keys"]
    dev.release(state)
    assert ids.is_deleted() and keys.is_deleted()


def test_frontier_owns_bits_retire():
    f = LevelFrontier(
        k=2,
        itemsets=np.zeros((2, 2), np.int32),
        counts=np.zeros(2, np.int64),
        bits=jnp.zeros((2, 4), jnp.uint32),
        owns_bits=True,
    )
    arr = f.bits
    f.retire()
    assert f.bits is None and arr.is_deleted()
    # borrowed bits (store caches, resume states) stay alive
    borrowed = jnp.zeros((2, 4), jnp.uint32)
    f2 = LevelFrontier(
        k=2,
        itemsets=np.zeros((2, 2), np.int32),
        counts=np.zeros(2, np.int64),
        bits=borrowed,
        owns_bits=False,
    )
    f2.retire()
    assert not borrowed.is_deleted()


# ---------------------------------------------------------------------------
# unified executable cache
# ---------------------------------------------------------------------------


def test_unified_exec_cache_families():
    mine(RNG.integers(0, 4, size=(60, 4)), KyivConfig(tau=1, kmax=3, engine="jnp"))
    stats = exec_cache.stats()
    assert "frontier" in stats["families"] and "intersect" in stats["families"]
    assert stats["entries"] == sum(f["entries"] for f in stats["families"].values())
    fam = exec_cache.exec_family("frontier")
    assert fam.stats()["entries"] == stats["families"]["frontier"]["entries"]


def test_family_clear_is_isolated():
    from repro.kernels.frontier.ops import frontier_cache_stats, reset_frontier_cache
    from repro.kernels.intersect.ops import executable_cache_stats

    mine(RNG.integers(0, 4, size=(50, 4)), KyivConfig(tau=1, kmax=2, engine="jnp"))
    assert executable_cache_stats()["entries"] >= 1
    before_intersect = executable_cache_stats()["entries"]
    reset_frontier_cache()
    assert frontier_cache_stats()["entries"] == 0
    assert executable_cache_stats()["entries"] == before_intersect


# ---------------------------------------------------------------------------
# 8-device mesh frontier (subprocess — XLA device count must pre-date jax init)
# ---------------------------------------------------------------------------

_MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, sys.argv[1])
import numpy as np
import jax
from repro.core import KyivConfig, MeshPlacement, mine

def tup(s):
    return (s.k, s.candidates, s.support_pruned, s.bound_pruned,
            s.intersections, s.emitted, s.skipped_absent_uniform, s.stored)

rng = np.random.default_rng(13)
D = rng.integers(0, 5, size=(200, 7))
ref = mine(D, KyivConfig(tau=2, kmax=4, engine="numpy"))
for shape, axes, word in (((2, 4), ("data", "model"), "model"),
                          ((8,), ("data",), None)):
    mesh = jax.make_mesh(shape, axes)
    # device_frontier=True: opt in on the CPU mesh (off by default there —
    # emulated collectives stall; tpu/gpu default on)
    p = MeshPlacement(mesh, pair_axes=("data",), word_axis=word,
                      device_frontier=True)
    got = mine(D, KyivConfig(tau=2, kmax=4, placement=p))
    assert sorted(got.itemsets) == sorted(ref.itemsets), (shape, word)
    assert list(map(tup, got.stats)) == list(map(tup, ref.stats)), (shape, word)
    off = mine(D, KyivConfig(tau=2, kmax=4, placement=p, device_frontier=False))
    assert sorted(off.itemsets) == sorted(ref.itemsets)
    assert not MeshPlacement(mesh, pair_axes=("data",), word_axis=word).use_device_frontier, \
        "CPU mesh must default to the host frontier path"
from repro.kernels.frontier.ops import frontier_cache_stats
assert frontier_cache_stats()["entries"] > 0, "mesh frontier never engaged"
print("MESH_FRONTIER_OK")
"""


@pytest.mark.slow
def test_mesh_frontier_bit_identical_8dev():
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", _MESH_SCRIPT, src],
        capture_output=True, text=True, timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "MESH_FRONTIER_OK" in proc.stdout
