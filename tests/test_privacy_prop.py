"""Property tests (hypothesis) for the privacy coverage engine.

The coverage accumulator must be **bit-identical** across the numpy ground
truth, the jnp oracle, the Pallas-interpret kernel and every placement's
full engine path (width padding by repetition, batching, bucket padding
with weight-0 rows) — and the per-record conversion must match a scalar
brute-force recomputation. The planner's zero-residual invariant is also
swept here over random tables. Deterministic spot checks and the service /
HTTP / mesh coverage live in tests/test_privacy.py.
"""

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

import jax.numpy as jnp

from repro.core import KyivConfig, mine
from repro.core.placement import DevicePlacement, HostPlacement
from repro.kernels.coverage import (
    CoverageEngine,
    acc_to_record_counts,
    coverage_accumulate_host,
    coverage_accumulate_indexed,
    coverage_accumulate_ref,
)
from repro.privacy import apply_plan, mine_masked, plan_anonymization

PLACEMENTS = [
    HostPlacement(),
    DevicePlacement("jnp"),
    DevicePlacement("pallas", interpret=True),
]


def _brute_record_counts(bits, sets, weights, n_rows):
    out = np.zeros(n_rows, dtype=np.int64)
    for s in range(sets.shape[0]):
        mask = bits[sets[s, 0]].copy()
        for t in range(1, sets.shape[1]):
            mask &= bits[sets[s, t]]
        for r in range(n_rows):
            if (int(mask[r // 32]) >> (r % 32)) & 1:
                out[r] += int(weights[s])
    return out


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**32 - 1),
    t=st.integers(2, 24),
    n_words=st.sampled_from([1, 2, 4, 8]),
    m=st.integers(1, 40),
    k=st.integers(1, 4),
)
def test_coverage_accumulate_engines_bit_identical(seed, t, n_words, m, k):
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 2**32, size=(t, n_words), dtype=np.uint32)
    sets = rng.integers(0, t, size=(m, k)).astype(np.int32)
    weights = rng.integers(0, 3, size=m).astype(np.int32)

    host = coverage_accumulate_host(bits, sets, weights)
    ref = np.asarray(
        coverage_accumulate_ref(
            jnp.asarray(bits), jnp.asarray(sets), jnp.asarray(weights)
        )
    )
    pallas = np.asarray(
        coverage_accumulate_indexed(
            jnp.asarray(bits), jnp.asarray(sets), jnp.asarray(weights),
            block_words=n_words, interpret=True,
        )
    )
    assert np.array_equal(ref, host)
    assert np.array_equal(pallas, host)
    n_rows = n_words * 32
    assert np.array_equal(
        acc_to_record_counts(host, n_rows),
        _brute_record_counts(bits, sets, weights, n_rows),
    )


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**32 - 1),
    n=st.integers(5, 80),
    m=st.integers(2, 5),
    dom=st.integers(2, 6),
    tau=st.integers(1, 2),
)
def test_coverage_engine_placements_bit_identical(seed, n, m, dom, tau):
    D = np.random.default_rng(seed).integers(0, dom, size=(n, m))
    res = mine(D, KyivConfig(tau=tau, kmax=3))
    if not res.itemsets:
        return
    table = res.prep.table
    sets = np.asarray(
        [list(ids) + [ids[-1]] * (3 - len(ids)) for ids, _ in res.itemsets],
        dtype=np.int32,
    )
    ref = None
    for placement in PLACEMENTS:
        eng = CoverageEngine(
            table.bits, placement=placement, set_width=3, max_batch_sets=16
        )
        acc = eng.accumulate(sets)
        if ref is None:
            ref = acc
        assert np.array_equal(acc, ref), placement.kind


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2**32 - 1),
    n=st.integers(2, 60),
    m=st.integers(2, 4),
    dom=st.integers(2, 7),
    tau=st.integers(1, 2),
)
def test_planner_always_verifies_zero_residual(seed, n, m, dom, tau):
    D = np.random.default_rng(seed).integers(0, dom, size=(n, m))
    plan = plan_anonymization(D, tau=tau, kmax=3)
    assert plan.verified and plan.residual_qis == 0
    post = mine_masked(apply_plan(D, plan), KyivConfig(tau=tau, kmax=3))
    assert post is None or len(post.itemsets) == 0
