"""Multi-host mining fleet: sharded store, lockstep collectives, coordinator.

Three rings of coverage, innermost first:

* pure unit tests — stripe math of the process-sharded ``DatasetStore``,
  ``ResultBands`` near-boundary recounts, snapshot shard guards;
* in-process fleet simulation — N threads, each a "process" with its own
  sharded store and :class:`FleetPlacement`, joined by a barrier-backed
  collective. Mining, incremental mining and risk must be bit-identical to
  the single-process answer on every simulated process;
* real 2-process harness (``@pytest.mark.slow``) — ``jax.distributed``
  over localhost, the actual ``FleetCollective`` KV transport, the
  ``FleetFrontend``/peer-loop coordinator, and a peer-kill chaos case that
  must degrade to the shadow service with exact answers.
"""

import importlib
import json
import os
import socket
import subprocess
import sys
import threading

import numpy as np
import pytest

from repro.core.collective import Collective, FleetDesyncError, LoopbackCollective
from repro.core.fleet import FleetPlacement
from repro.core.kyiv import KyivConfig, mine, mine_preprocessed
from repro.core.placement import HostPlacement
from repro.service import (
    DatasetStore,
    FleetFrontend,
    IncrementalConfig,
    MiningService,
    ResultBands,
    mine_incremental,
)
from repro.service.incremental import delta_support
from repro.service.store import mask_delta_words_local

_pre = importlib.import_module("repro.core.preprocess")

NPROC = 2


# -- in-process fleet simulation ------------------------------------------


class ThreadCollective(Collective):
    """Barrier-backed collective for N threads posing as N processes."""

    def __init__(self, pid: int, shared: dict, nproc: int = NPROC):
        self.pid, self.nproc = pid, nproc
        self.sh = shared
        self._round = 0
        self.rounds = 0
        self.seconds = 0.0
        self.payload_bytes = 0

    def allgather(self, payload: bytes) -> list[bytes]:
        n = self._round
        self._round += 1
        self.sh["slots"][(n, self.pid)] = payload
        self.sh["barrier"].wait()
        out = [self.sh["slots"][(n, p)] for p in range(self.nproc)]
        self.sh["barrier"].wait()
        self.rounds += 1
        self.payload_bytes += sum(len(b) for b in out)
        return out


class _HookProxy:
    """Routes the module-global preprocess hook to each thread's collective."""

    def __init__(self, nproc: int = NPROC):
        self.by_thread: dict[int, ThreadCollective] = {}
        self.nproc = nproc

    def _mine(self) -> ThreadCollective:
        return self.by_thread[threading.get_ident()]

    def allgather(self, payload):
        return self._mine().allgather(payload)

    def allreduce_sum(self, arr):
        return self._mine().allreduce_sum(arr)


def _run_fleet(worker, nproc: int = NPROC):
    """Run ``worker(pid, collective)`` on nproc threads; returns results."""
    shared = {"slots": {}, "barrier": threading.Barrier(nproc)}
    proxy = _HookProxy(nproc)
    prev = _pre.set_row_group_collective(proxy)
    outs = [None] * nproc
    errs = [None] * nproc

    def run(p):
        try:
            tc = ThreadCollective(p, shared, nproc)
            proxy.by_thread[threading.get_ident()] = tc
            outs[p] = worker(p, tc)
        except Exception as exc:  # noqa: BLE001 - surfaced via errs
            errs[p] = exc
            try:
                shared["barrier"].abort()
            except Exception:
                pass

    threads = [threading.Thread(target=run, args=(p,)) for p in range(nproc)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    _pre.set_row_group_collective(prev)
    assert not any(errs), [e for e in errs if e]
    return outs


def _dataset(seed=3, n=400, d=130, cols=4, vals=5):
    rng = np.random.default_rng(seed)
    return (
        rng.integers(0, vals, size=(n, cols)),
        rng.integers(0, vals, size=(d, cols)),
    )


# -- sharded store stripe math --------------------------------------------


def test_sharded_store_reconstructs_global_bits():
    rows, delta = _dataset(seed=9)
    full = DatasetStore(4, word_tile=8)
    full.append(rows)
    full.append(delta)
    shards = []
    for p in range(NPROC):
        s = DatasetStore(4, word_tile=8, shard=(p, NPROC))
        s.append(rows)
        s.append(delta)
        shards.append(s)
    t_full = full.item_table()
    n_words_global = shards[0].stats()["n_words_global"]
    assert n_words_global >= t_full.n_words
    rebuilt = np.zeros((t_full.n_items, n_words_global), dtype=np.uint32)
    for s in shards:
        t = s.item_table()
        wm = s.word_map(t.n_words)
        rebuilt[:, wm] = t.bits
    assert np.array_equal(rebuilt[:, : t_full.n_words], t_full.bits)
    # trailing global pad words hold no bits
    assert not rebuilt[:, t_full.n_words :].any()
    # global metadata is replicated, not sharded
    for s in shards:
        t = s.item_table()
        assert np.array_equal(t.freq, t_full.freq)
        assert np.array_equal(t.value, t_full.value)
        assert s.version == full.version
        assert s.n_rows == full.n_rows


def test_sharded_delta_popcounts_sum_to_global():
    rows, delta = _dataset(seed=21)
    base_rows = len(rows)
    full = DatasetStore(4, word_tile=8)
    v1 = full.append(rows)
    full.append(delta)
    fbits, _ = full.delta_bits(v1)
    want = np.unpackbits(fbits.view(np.uint8), axis=1).sum(axis=1).astype(np.int64)
    got = np.zeros_like(want)
    for p in range(NPROC):
        s = DatasetStore(4, word_tile=8, shard=(p, NPROC))
        s.append(rows)
        s.append(delta)
        t = s.item_table()
        dbits = mask_delta_words_local(t.bits, base_rows, s.word_map(t.n_words))
        got += (
            np.unpackbits(dbits.view(np.uint8), axis=1).sum(axis=1).astype(np.int64)
        )
    assert np.array_equal(got, want)


def test_sharded_snapshot_rejects_foreign_shard():
    rows, _ = _dataset()
    s = DatasetStore(4, word_tile=8, shard=(0, NPROC))
    s.append(rows)
    state = s.export_state()
    restored = DatasetStore.from_state(state)  # same shard: fine
    assert restored.shard == (0, NPROC)
    with pytest.raises(ValueError, match="not transferable"):
        DatasetStore.from_state(state, shard=(1, NPROC))


def test_identity_shard_is_unsharded():
    rows, _ = _dataset()
    a = DatasetStore(4, word_tile=8)
    b = DatasetStore(4, word_tile=8, shard=(0, 1))
    a.append(rows)
    b.append(rows)
    ta, tb = a.item_table(), b.item_table()
    assert np.array_equal(ta.bits, tb.bits)
    assert a.watermark_digest() == b.watermark_digest()


# -- ResultBands: near-boundary recounts ----------------------------------


def test_result_bands_recount_matches_brute_force():
    rng = np.random.default_rng(5)
    for trial in range(6):
        rows = rng.integers(0, 4, size=(250, 4))
        delta = rng.integers(0, 4, size=(30, 4))
        tau = int(rng.integers(4, 40))
        cfg = KyivConfig(tau=tau, kmax=3)
        store = DatasetStore(4, word_tile=8)
        v1 = store.append(rows)
        base = mine(rows, cfg)
        store.append(delta)
        table = store.item_table()
        dbits, _ = store.delta_bits(v1)
        dfreq = (
            np.unpackbits(dbits.view(np.uint8), axis=1).sum(axis=1).astype(np.int64)
        )
        bands = ResultBands.from_result(base.itemsets)
        new_counts, stats = bands.recount(dbits, dfreq, tau, len(delta))
        dsup = delta_support(dbits, [ids for ids, _ in base.itemsets])
        for (ids, old), new, ds in zip(base.itemsets, new_counts, dsup):
            assert new == old + ds
        assert stats["n_recounted"] + stats["n_recount_skipped"] == len(
            base.itemsets
        )
        # skipped sets are exactly those whose members all miss the delta
        if stats["n_recount_skipped"]:
            for (ids, old), new in zip(base.itemsets, new_counts):
                if all(dfreq[i] == 0 for i in ids) and len(ids) > 1:
                    assert new == old


def test_result_bands_skip_shrinks_recount_floor():
    # a delta touching few items must leave most multi-item recounts skipped
    rng = np.random.default_rng(8)
    rows = rng.integers(0, 3, size=(600, 5))
    delta = rows[:8].copy()  # delta reuses existing value patterns
    delta[:, 4] = rows[:8, 4]
    cfg = KyivConfig(tau=30, kmax=3)
    store = DatasetStore(5, word_tile=8)
    v1 = store.append(rows)
    base = mine(rows, cfg)
    store.append(delta)
    table = store.item_table()
    dbits, _ = store.delta_bits(v1)
    dfreq = np.unpackbits(dbits.view(np.uint8), axis=1).sum(axis=1).astype(np.int64)
    bands = ResultBands.from_result(base.itemsets)
    _, stats = bands.recount(dbits, dfreq, cfg.tau, len(delta))
    multi = sum(1 for ids, _ in base.itemsets if len(ids) > 1)
    zero_ub = sum(
        1
        for ids, _ in base.itemsets
        if len(ids) > 1 and min(dfreq[i] for i in ids) == 0
    )
    assert stats["n_recount_skipped"] == zero_ub
    assert stats["n_recounted"] == len(base.itemsets) - zero_ub
    if zero_ub:
        assert stats["n_recounted"] < len(base.itemsets)
    assert multi >= zero_ub


def test_incremental_with_cached_bands_is_identical():
    rows, delta = _dataset(seed=31)
    cfg = KyivConfig(tau=25, kmax=3)
    store = DatasetStore(4, word_tile=8)
    v1 = store.append(rows)
    base = mine(rows, cfg)
    store.append(delta)
    cold = mine(np.concatenate([rows, delta]), cfg)
    with_bands = mine_incremental(
        store, base, v1, cfg, IncrementalConfig(),
        bands=ResultBands.from_result(base.itemsets),
    )
    without = mine_incremental(store, base, v1, cfg, IncrementalConfig())
    assert with_bands is not None and without is not None
    assert sorted(with_bands[0].itemsets) == sorted(cold.itemsets)
    assert sorted(without[0].itemsets) == sorted(cold.itemsets)
    assert with_bands[1]["n_recounted"] == without[1]["n_recounted"]


# -- lockstep fleet mining (thread-simulated processes) -------------------


@pytest.mark.parametrize("cfg", [dict(tau=8, kmax=4), dict(tau=40, kmax=3)])
def test_fleet_mining_bit_identical(cfg):
    rows, delta = _dataset()
    baseline = mine(np.concatenate([rows, delta]), KyivConfig(**cfg))

    def worker(p, tc):
        store = DatasetStore(4, word_tile=8, shard=(p, NPROC))
        store.append(rows)
        store.append(delta)
        placement = FleetPlacement(HostPlacement(), collective=tc)
        config = KyivConfig(placement=placement, **cfg)
        prep = _pre.preprocess(
            store.item_table(), config.tau, ordering=config.ordering,
            seed=config.seed,
        )
        return mine_preprocessed(prep, config)

    for out in _run_fleet(worker):
        assert out.itemsets == baseline.itemsets
        assert [s.emitted for s in out.stats] == [
            s.emitted for s in baseline.stats
        ]


def test_fleet_incremental_bit_identical():
    rows, delta = _dataset(seed=11, n=420, d=60)
    cfg = dict(tau=12, kmax=4)
    cold = mine(np.concatenate([rows, delta]), KyivConfig(**cfg))

    def worker(p, tc):
        store = DatasetStore(4, word_tile=8, shard=(p, NPROC))
        v1 = store.append(rows)
        placement = FleetPlacement(HostPlacement(), collective=tc)
        config = KyivConfig(placement=placement, **cfg)
        prep = _pre.preprocess(
            store.item_table(), config.tau, ordering=config.ordering,
            seed=config.seed,
        )
        base = mine_preprocessed(prep, config)
        store.append(delta)
        out = mine_incremental(
            store, base, v1, config, IncrementalConfig(),
            bands=ResultBands.from_result(base.itemsets),
        )
        assert out is not None
        return out

    outs = _run_fleet(worker)
    for res, info in outs:
        assert sorted(res.itemsets) == sorted(cold.itemsets)
        assert info["fleet"]["nproc"] == NPROC
    assert outs[0][1]["n_recounted"] == outs[1][1]["n_recounted"]


def test_fleet_risk_profile_bit_identical():
    from repro.privacy.risk import risk_profile

    rows, delta = _dataset(seed=29)
    cfg = dict(tau=20, kmax=3)
    all_rows = np.concatenate([rows, delta])
    base = mine(all_rows, KyivConfig(**cfg))
    ref = risk_profile(base)

    def worker(p, tc):
        store = DatasetStore(4, word_tile=8, shard=(p, NPROC))
        store.append(rows)
        store.append(delta)
        placement = FleetPlacement(HostPlacement(), collective=tc)
        config = KyivConfig(placement=placement, **cfg)
        prep = _pre.preprocess(
            store.item_table(), config.tau, ordering=config.ordering,
            seed=config.seed,
        )
        result = mine_preprocessed(prep, config)
        table = store.item_table()
        return risk_profile(
            result, placement=placement, word_map=store.word_map(table.n_words)
        )

    for prof in _run_fleet(worker):
        assert np.array_equal(prof.counts_by_size, ref.counts_by_size)
        assert np.allclose(prof.risk, ref.risk)
        assert prof.records_at_risk == ref.records_at_risk


def test_collective_agree_raises_on_divergence():
    def worker(p, tc):
        with pytest.raises(FleetDesyncError):
            tc.agree(f"value-{p}".encode(), what="digest")
        return True

    assert all(_run_fleet(worker))


# -- loopback frontend: coordinator semantics without processes -----------


def test_loopback_frontend_matches_plain_service():
    rows, delta = _dataset(seed=5, n=300, d=40, cols=5, vals=4)
    tc = LoopbackCollective()
    svc = MiningService(placement=FleetPlacement(HostPlacement(), collective=tc))
    shadow = MiningService(engine="numpy")
    front = FleetFrontend(svc, tc, shadow=shadow)
    plain = MiningService(engine="numpy")

    front.append(rows)
    plain.append(rows)
    assert (
        front.mine(tau=10, kmax=3).result.itemsets
        == plain.mine(tau=10, kmax=3).result.itemsets
    )
    front.append(delta)
    plain.append(delta)
    r = front.mine(tau=10, kmax=3)
    p = plain.mine(tau=10, kmax=3)
    assert r.result.itemsets == p.result.itemsets
    assert r.source == "incremental"
    rf, rp = front.risk(tau=10, kmax=3), plain.risk(tau=10, kmax=3)
    for k in ("records_at_risk", "max_risk", "qi_total", "top_records"):
        assert rf[k] == rp[k]
    # shadow tracked every append
    assert shadow.store.n_rows == len(rows) + len(delta)
    st = front.stats()
    fl = st["resilience"]["fleet"]
    assert fl["degraded"] is False and fl["replicated_ops"] == 5


def test_frontend_rejects_fleet_incompatible_modes():
    tc = LoopbackCollective()
    svc = MiningService(placement=FleetPlacement(HostPlacement(), collective=tc))
    front = FleetFrontend(svc, tc, shadow=MiningService(engine="numpy"))
    front.append(np.zeros((64, 3), dtype=np.int64))
    with pytest.raises(ValueError, match="approx"):
        front.mine(tau=1, kmax=2, mode="approx")
    with pytest.raises(ValueError, match="deadline"):
        front.mine(tau=1, kmax=2, deadline_s=1.0)


# -- mesh warm-bucket registry (FleetPlacement delegates to it) -----------


def test_mesh_warm_buckets_records_dispatched_shapes():
    import jax

    from repro.core.placement import MeshPlacement

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    placement = MeshPlacement(mesh, pair_axes=("data",), word_axis="model")
    rng = np.random.default_rng(0)
    bits = rng.integers(0, 2**32, size=(6, 8), dtype=np.uint32)
    counts = np.full(6, 64, dtype=np.int64)
    n_words = bits.shape[1]
    before = placement.warm_buckets(n_words, fused=False, write_children=False)
    state = placement.prepare(bits, counts, 3, fused_classify=False)
    m = placement.padded_size(4)
    pairs = np.zeros((m, 2), dtype=np.int32)
    pairs[:4] = [[0, 1], [0, 2], [1, 2], [3, 4]]
    placement.dispatch(state, pairs, False)
    placement.release(state)
    after = placement.warm_buckets(n_words, fused=False, write_children=False)
    assert m in after
    assert set(before) <= set(after)
    # the fleet wrapper reports its inner placement's warm shapes
    fleet = FleetPlacement(placement, collective=LoopbackCollective())
    assert fleet.warm_buckets(n_words, fused=True, write_children=False) == after


# -- real processes over jax.distributed (slow ring) ----------------------


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


_WORKER = r"""
import json, os, sys
import numpy as np
sys.path.insert(0, sys.argv[4])
pid, nproc, port = int(sys.argv[1]), int(sys.argv[2]), sys.argv[3]
chaos = len(sys.argv) > 5 and sys.argv[5] == "chaos"
import jax
jax.distributed.initialize(f"localhost:{port}", nproc, pid)
from repro.core.collective import FleetCollective
from repro.core.fleet import FleetPlacement
from repro.core.placement import HostPlacement
from repro.core.preprocess import set_row_group_collective
from repro.service import FleetFrontend, MiningService, serve_fleet_peer

fc = FleetCollective(timeout_s=4.0 if chaos else 30.0)
set_row_group_collective(fc)
svc = MiningService(placement=FleetPlacement(HostPlacement(), collective=fc))
rng = np.random.default_rng(17)
rows = rng.integers(0, 5, size=(360, 5))
delta = rng.integers(0, 5, size=(50, 5))

if pid != 0:
    out = serve_fleet_peer(svc, fc)
    print(json.dumps({"pid": pid, **out}), flush=True)
    if chaos:
        os._exit(0)  # skip the poisoned shutdown barrier
    sys.exit(0)  # clean exit: jax's atexit disconnect keeps p0 healthy

shadow = MiningService(engine="numpy")
front = FleetFrontend(svc, fc, shadow=shadow)
front.append(rows)
r1 = front.mine(tau=18, kmax=3)
if chaos:
    print("READY", flush=True)  # harness kills the peer now
    import time; time.sleep(2.0)
front.append(delta)
r2 = front.mine(tau=18, kmax=3)
risk = front.risk(tau=18, kmax=3)
st = front.stats()
fl = st["resilience"]["fleet"]
if not chaos:
    front.close()
print(json.dumps({
    "pid": 0,
    "r1": sorted([[list(map(int, i)), int(c)] for i, c in r1.result.itemsets]),
    "r2": sorted([[list(map(int, i)), int(c)] for i, c in r2.result.itemsets]),
    "r2_source": r2.source,
    "risk": {k: risk[k] for k in ("records_at_risk", "max_risk", "qi_total")},
    "degraded": fl["degraded"],
    "reason": fl["degraded_reason"],
    "rounds": fl["collective"]["rounds"],
}), flush=True)
if chaos:
    # skip the jax.distributed atexit shutdown barrier: with the peer
    # killed it can only fail fatally; output is flushed above
    os._exit(0)
"""

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _spawn(pid: int, port: int, mode: str = "") -> subprocess.Popen:
    env = dict(os.environ, JAX_PLATFORMS="cpu")
    env.pop("XLA_FLAGS", None)
    return subprocess.Popen(
        [sys.executable, "-c", _WORKER, str(pid), "2", str(port), _SRC, mode],
        stdout=subprocess.PIPE,
        stderr=subprocess.PIPE,
        env=env,
        text=True,
    )


def _single_process_baseline():
    rng = np.random.default_rng(17)
    rows = rng.integers(0, 5, size=(360, 5))
    delta = rng.integers(0, 5, size=(50, 5))
    svc = MiningService(engine="numpy")
    svc.append(rows)
    b1 = svc.mine(tau=18, kmax=3)
    svc.append(delta)
    b2 = svc.mine(tau=18, kmax=3)
    bk = svc.risk(tau=18, kmax=3)
    fmt = lambda r: sorted(
        [[list(map(int, i)), int(c)] for i, c in r.result.itemsets]
    )
    return fmt(b1), fmt(b2), bk


@pytest.mark.slow
def test_two_process_fleet_bit_identical_to_single():
    port = _free_port()
    procs = [_spawn(p, port) for p in range(2)]
    outs = []
    for p in procs:
        so, se = p.communicate(timeout=300)
        assert p.returncode == 0, se[-3000:]
        outs.append(json.loads(so.strip().splitlines()[-1]))
    o0 = next(o for o in outs if o["pid"] == 0)
    o1 = next(o for o in outs if o["pid"] == 1)
    base1, base2, bk = _single_process_baseline()
    assert o0["r1"] == base1
    assert o0["r2"] == base2
    assert o0["r2_source"] == "incremental"
    assert o0["risk"] == {
        k: bk[k] for k in ("records_at_risk", "max_risk", "qi_total")
    }
    assert o0["degraded"] is False
    assert o1["reason"] == "shutdown" and o1["executed"] == 5


@pytest.mark.slow
def test_two_process_peer_kill_degrades_to_shadow():
    port = _free_port()
    p0 = _spawn(0, port, "chaos")
    p1 = _spawn(1, port, "chaos")
    while True:
        line = p0.stdout.readline()
        if not line or line.startswith("READY"):
            break
    assert line.startswith("READY"), "frontend never reached READY"
    p1.kill()
    so, se = p0.communicate(timeout=300)
    p1.wait()
    assert p0.returncode == 0, se[-3000:]
    out = json.loads(so.strip().splitlines()[-1])
    assert out["degraded"] is True
    assert "FleetTimeout" in out["reason"]
    base1, base2, bk = _single_process_baseline()
    assert out["r1"] == base1  # mined by the healthy fleet
    assert out["r2"] == base2  # mined by the shadow after degradation
    assert out["risk"] == {
        k: bk[k] for k in ("records_at_risk", "max_risk", "qi_total")
    }
