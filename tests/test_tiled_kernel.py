"""Group-tiled count kernel (beyond-paper §Perf optimization) vs reference."""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.kernels.intersect.tiled import (
    build_group_tiles,
    counts_from_tiles,
    intersect_count_tiled,
)

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("bm,W", [(4, 128), (8, 128), (4, 256)])
def test_tiled_counts_match_pairwise(bm, W):
    group_sizes = np.array([5, 12, 3, 8, 1, 16])
    row_map, ti, tj = build_group_tiles(group_sizes, bm)
    t_orig = int(group_sizes.sum())
    bits_orig = RNG.integers(0, 2**32, size=(t_orig, W), dtype=np.uint32)
    bits_pad = np.zeros((len(row_map), W), dtype=np.uint32)
    for pos, orig in enumerate(row_map):
        if orig >= 0:
            bits_pad[pos] = bits_orig[orig]

    cnt = np.asarray(
        intersect_count_tiled(
            jnp.asarray(bits_pad), jnp.asarray(ti), jnp.asarray(tj),
            block_rows=bm, block_words=W, interpret=True,
        )
    )
    pairs, counts = counts_from_tiles(cnt, ti, tj, row_map, bm)

    expected = {}
    start = 0
    for g in group_sizes:
        for i in range(start, start + g):
            for j in range(i + 1, start + g):
                expected[(i, j)] = int(np.bitwise_count(bits_orig[i] & bits_orig[j]).sum())
        start += g
    got = {tuple(p): int(c) for p, c in zip(pairs, counts)}
    assert got == expected


def test_traffic_reduction_formula():
    """Tile traffic beats pairwise traffic roughly by bm/2 for large groups."""
    bm = 8
    g = 64
    group_sizes = np.array([g] * 16)
    row_map, ti, tj = build_group_tiles(group_sizes, bm)
    m_pairs = 16 * g * (g - 1) // 2
    W = 1
    pairwise = 2 * m_pairs * W
    tiled = 2 * len(ti) * bm * W
    assert pairwise / tiled > bm / 2 * 0.85


def test_alignment_error():
    bits = jnp.zeros((10, 128), jnp.uint32)  # 10 % 8 != 0
    with pytest.raises(ValueError):
        intersect_count_tiled(bits, jnp.zeros(1, jnp.int32), jnp.zeros(1, jnp.int32),
                              block_rows=8, interpret=True)
