"""ItemsetIndex (the §4.4.1 zero-cost support lookup): exact + hashed paths."""

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import ItemsetIndex
from repro.core.prefix import Level, generate_candidates, prefix_group_sizes


@given(st.integers(2, 40), st.integers(1, 4), st.integers(0, 10_000), st.booleans())
@settings(max_examples=60, deadline=None)
def test_index_lookup(t, k, seed, force_hash):
    rng = np.random.default_rng(seed)
    rows = rng.integers(0, 50, size=(t, k))
    rows = np.unique(rows, axis=0)
    rows = rows[np.lexsort(rows.T[::-1])]
    # force the hash path by lying about symbol count
    n_symbols = 2**40 if force_hash else 50
    idx = ItemsetIndex(rows, counts=np.arange(len(rows)), n_symbols=n_symbols)
    bits = max(1, (n_symbols - 1).bit_length())
    assert idx.exact == (k * bits <= 64)
    got = idx.lookup(rows)
    assert np.array_equal(got, np.arange(len(rows)))
    # absent queries return -1
    absent = rows.copy()
    absent[:, 0] += 100
    assert np.all(idx.lookup(absent) == -1)
    cnts = idx.lookup_counts(rows)
    assert np.array_equal(cnts, np.arange(len(rows)))


def test_candidate_generation_matches_bruteforce():
    rng = np.random.default_rng(1)
    for trial in range(20):
        t, k = int(rng.integers(2, 30)), int(rng.integers(1, 4))
        rows = np.unique(rng.integers(0, 6, size=(t, k)), axis=0)
        rows = rows[np.lexsort(rows.T[::-1])].astype(np.int32)
        lvl = Level(k=k, itemsets=rows, counts=np.ones(len(rows), np.int64), bits=None)
        cand = generate_candidates(lvl)
        # brute force join
        expected = set()
        for i in range(len(rows)):
            for j in range(i + 1, len(rows)):
                if np.array_equal(rows[i, : k - 1], rows[j, : k - 1]) and rows[i, k - 1] != rows[j, k - 1]:
                    expected.add((i, j))
        got = set(zip(cand.i_idx.tolist(), cand.j_idx.tolist()))
        assert got == expected
        # candidates are lexicographically sorted (needed for the next level)
        its = cand.itemsets
        for r in range(1, len(its)):
            assert tuple(its[r - 1]) < tuple(its[r])
        # group sizes partition the level
        assert prefix_group_sizes(rows).sum() == len(rows)


def test_streamed_batches_equal_single_shot():
    """iter_candidate_batches (§6.1 level streaming) == generate_candidates."""
    from repro.core.prefix import iter_candidate_batches

    rng = np.random.default_rng(7)
    for trial in range(10):
        t, k = int(rng.integers(4, 60)), int(rng.integers(1, 4))
        rows = np.unique(rng.integers(0, 7, size=(t, k)), axis=0)
        rows = rows[np.lexsort(rows.T[::-1])].astype(np.int32)
        lvl = Level(k=k, itemsets=rows, counts=np.ones(len(rows), np.int64), bits=None)
        full = generate_candidates(lvl)
        for budget in (1, 5, 1000):
            batches = list(iter_candidate_batches(lvl, budget))
            if full.m == 0:
                assert batches == []
                continue
            i_all = np.concatenate([b.i_idx for b in batches])
            j_all = np.concatenate([b.j_idx for b in batches])
            its = np.concatenate([b.itemsets for b in batches], axis=0)
            assert np.array_equal(i_all, full.i_idx), (trial, budget)
            assert np.array_equal(j_all, full.j_idx), (trial, budget)
            assert np.array_equal(its, full.itemsets), (trial, budget)
            if budget >= full.m:
                assert len(batches) == 1
