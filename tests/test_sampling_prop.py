"""Hypothesis sweeps: sampled mining always converges to the exact answer.

For arbitrary random tables, thresholds, depths and accuracies — on every
engine — the approx answer's background refinement must promote the cache
to a result bit-identical (itemsets AND counts) to an undisturbed cold
``mine()``; the sampler itself must be reproducible per
``(version, ε, seed)``; and a refinement killed mid-promotion must still
converge after a restart resumes it from the level checkpoint.

The 8-device forced-host mesh variant runs fixed seeds in a subprocess
(XLA's device-count flag must precede jax init, so hypothesis can't drive
it in-process); the in-process engine sweep is the hypothesis-driven part.
Gated in conftest.py when hypothesis is absent (deterministic coverage
lives in tests/test_sampling.py).
"""

import os
import shutil
import subprocess
import sys
import tempfile

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import KyivConfig, mine
from repro.sampling import SamplingConfig, build_sample
from repro.service import FaultInjector, KillPoint, MiningService

# small bound constants so mid-sized tables are strictly subsampled and
# the boundary band is actually exercised
SMALL = SamplingConfig(oversample=0.5, min_rows=32)

table_st = st.tuples(
    st.integers(120, 400),  # rows
    st.integers(3, 5),  # columns
    st.integers(3, 6),  # per-column domain
    st.integers(1, 4),  # tau
    st.integers(2, 4),  # kmax
    st.integers(0, 10_000),  # seed
    st.sampled_from([0.05, 0.1, 0.3, 0.5]),  # epsilon
)


def _canonical(result):
    return sorted((tuple(sorted(ids)), int(c)) for ids, c in result.itemsets)


@pytest.mark.parametrize("engine", ["numpy", "jnp", "pallas"])
@settings(max_examples=8, deadline=None)
@given(table_st)
def test_refinement_converges_to_cold_mine(engine, params):
    n, m, dom, tau, kmax, seed, eps = params
    data = np.random.default_rng(seed).integers(0, dom, size=(n, m))
    cold = mine(data, KyivConfig(tau=tau, kmax=kmax, engine="numpy"))

    svc = MiningService.from_dataset(
        data, engine=engine, interpret=True, sampling=SMALL
    )
    r = svc.mine(tau=tau, kmax=kmax, mode="approx", epsilon=eps)
    assert r.source == "approx"
    assert r.info["epsilon"] == eps
    assert 0.0 <= r.info["confidence"] <= 1.0
    drained = svc.scheduler.drain(timeout=300)
    assert drained["abandoned"] == 0

    refined = svc.mine(tau=tau, kmax=kmax, mode="approx", epsilon=eps)
    assert refined.info["refined"] is True
    assert refined.info["confidence"] == 1.0
    assert _canonical(refined.result) == _canonical(cold)
    # and the promoted exact entry answers exact requests identically
    exact = svc.mine(tau=tau, kmax=kmax)
    assert exact.source == "cache"
    assert _canonical(exact.result) == _canonical(cold)
    svc.close()


@settings(max_examples=10, deadline=None)
@given(table_st)
def test_sample_is_reproducible_per_version_tuple(params):
    n, m, dom, tau, kmax, seed, eps = params
    from repro.core import itemize

    table = itemize(np.random.default_rng(seed).integers(0, dom, size=(n, m)))
    a = build_sample(table, version=3, tau=tau, epsilon=eps, config=SMALL)
    b = build_sample(table, version=3, tau=tau, epsilon=eps, config=SMALL)
    assert a.seed == b.seed and a.tau_sample == b.tau_sample
    np.testing.assert_array_equal(a.rows, b.rows)
    np.testing.assert_array_equal(a.table.bits, b.table.bits)
    # a different version draws a different (but reproducible) sample
    c = build_sample(table, version=4, tau=tau, epsilon=eps, config=SMALL)
    assert c.seed != a.seed
    # the sampled view stays mineable: same items, positive row count
    assert c.table.n_items == table.n_items
    assert 0 < c.table.n_rows <= n


@settings(max_examples=6, deadline=None)
@given(table_st, st.integers(1, 2))
def test_killed_refinement_converges_after_restart(params, kill_after):
    n, m, dom, tau, kmax, seed, eps = params
    kmax = max(kmax, kill_after + 2)  # deep enough to die mid-promotion
    data = np.random.default_rng(seed).integers(0, dom, size=(n, m))
    undisturbed = mine(data, KyivConfig(tau=tau, kmax=kmax))

    d = tempfile.mkdtemp(prefix="sampling-chaos-")
    try:
        inj = FaultInjector()
        svc = MiningService(
            engine="numpy", wal_dir=d, fault_injector=inj, sampling=SMALL
        )
        svc.append(data)
        inj.arm("mine.level_end", action="raise",
                exc=KillPoint("mid-refine"), after=kill_after)
        r = svc.mine(tau=tau, kmax=kmax, mode="approx", epsilon=eps)
        assert r.source == "approx"
        svc.scheduler.drain(timeout=300)
        # the promotion died; the fast answer survived, unpromoted
        assert svc.stats()["sampling"]["refine_failures"] == 1
        svc.close()

        svc2 = MiningService(engine="numpy", wal_dir=d, sampling=SMALL)
        assert svc2.stats()["durability"]["resumed_jobs"] == 1
        exact = svc2.mine(tau=tau, kmax=kmax)
        assert _canonical(exact.result) == _canonical(undisturbed)
        approx = svc2.mine(tau=tau, kmax=kmax, mode="approx", epsilon=eps)
        assert approx.info["confidence"] == 1.0
        assert _canonical(approx.result) == _canonical(undisturbed)
        svc2.close()
    finally:
        shutil.rmtree(d, ignore_errors=True)


_MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, sys.argv[1])
import numpy as np
import jax
from repro.core import KyivConfig, MeshPlacement, mine
from repro.service import MiningService, SamplingConfig

mesh = jax.make_mesh((2, 4), ("data", "model"))
placement = MeshPlacement(mesh, pair_axes=("data",), word_axis="model")
for seed, tau, kmax, eps in ((3, 1, 3, 0.1), (11, 2, 3, 0.3), (27, 3, 2, 0.5)):
    data = np.random.default_rng(seed).integers(0, 5, size=(700, 4))
    cold = mine(data, KyivConfig(tau=tau, kmax=kmax))
    svc = MiningService.from_dataset(
        data, placement=placement,
        sampling=SamplingConfig(oversample=0.5, min_rows=32),
    )
    r = svc.mine(tau=tau, kmax=kmax, mode="approx", epsilon=eps)
    assert r.source == "approx", (seed, r.source)
    svc.scheduler.drain(timeout=300)
    r2 = svc.mine(tau=tau, kmax=kmax, mode="approx", epsilon=eps)
    assert r2.info["refined"] is True, (seed, r2.info)
    got = sorted((tuple(sorted(i)), int(c)) for i, c in r2.result.itemsets)
    ref = sorted((tuple(sorted(i)), int(c)) for i, c in cold.itemsets)
    assert got == ref, f"mesh refinement diverged at seed={seed}"
    svc.close()
print("MESH_SAMPLING_SWEEP_OK")
"""


@pytest.mark.slow
def test_mesh_refinement_sweep_8dev():
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", _MESH_SCRIPT, src],
        capture_output=True, text=True, timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "MESH_SAMPLING_SWEEP_OK" in proc.stdout
