"""Pre-processing (§4.1): partition properties, Example 4.3, orderings."""

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import itemize, preprocess


def paper_example_43():
    return np.array(
        [[1, 2, 3, 4, 8], [1, 2, 7, 4, 8], [1, 6, 3, 4, 8], [5, 2, 3, 4, 9]]
    )


def test_example_43_partition():
    t = itemize(paper_example_43())
    prep = preprocess(t, tau=1)
    # r_{A,tau} = the four unique items; U_A = {(4, col4)}
    assert len(prep.infrequent_items) == 4
    assert len(prep.uniform_items) == 1
    # L has 3 canonical items; item (8, col5) duplicates (1, col1)'s rows
    assert prep.n_l == 3
    mirrors = sum(len(v) for v in prep.mirror_of.values())
    assert mirrors == 1
    (canon,) = [c for c, v in prep.mirror_of.items() if v]
    v, j = t.describe(canon)
    assert (v, j) == (1, 0)
    mv, mj = t.describe(prep.mirror_of[canon][0])
    assert (mv, mj) == (8, 4)


dataset_st = st.integers(4, 40).flatmap(
    lambda n: st.integers(2, 6).flatmap(
        lambda m: st.lists(
            st.lists(st.integers(0, 4), min_size=m, max_size=m),
            min_size=n, max_size=n,
        )
    )
)


@given(dataset_st, st.integers(1, 3))
@settings(max_examples=50, deadline=None)
def test_partition_properties(rows, tau):
    D = np.asarray(rows)
    t = itemize(D)
    prep = preprocess(t, tau=tau)
    n = t.n_rows
    # (i) canonical rows pairwise distinct
    seen = set()
    for i, it in enumerate(prep.l_items):
        key = prep.l_bits[i].tobytes()
        assert key not in seen
        seen.add(key)
        # L items are neither uniform nor tau-infrequent
        assert tau < t.freq[it] < n
    # (ii) every dropped duplicate maps to a canonical with identical rows
    for canon, dups in prep.mirror_of.items():
        for d in dups:
            assert np.array_equal(t.bits[canon], t.bits[d])
    # partition covers everything exactly once
    covered = (
        set(prep.l_items.tolist())
        | {d for v in prep.mirror_of.values() for d in v}
        | set(prep.uniform_items.tolist())
        | set(prep.infrequent_items.tolist())
    )
    assert covered == set(range(t.n_items))


@given(dataset_st)
@settings(max_examples=20, deadline=None)
def test_ascending_order(rows):
    D = np.asarray(rows)
    t = itemize(D)
    prep = preprocess(t, tau=1, ordering="ascending")
    f = t.freq[prep.l_items]
    assert np.all(np.diff(f) >= 0)  # Def 4.5(i)
    desc = preprocess(t, tau=1, ordering="descending")
    assert np.all(np.diff(t.freq[desc.l_items]) <= 0)
    rnd1 = preprocess(t, tau=1, ordering="random", seed=1)
    rnd2 = preprocess(t, tau=1, ordering="random", seed=1)
    assert np.array_equal(rnd1.l_items, rnd2.l_items)  # deterministic per seed
