"""Data generators, FIMI IO, and the SDC (quasi-identifier) app layer."""

import numpy as np

from repro.data.loaders import encode_table, read_fimi, write_fimi
from repro.data.synth import (
    connect_like,
    poker_like,
    pumsb_like,
    randomized_dataset,
    uscensus_like,
)
from repro.sdc.quasi import find_quasi_identifiers, k_anonymize_columns


def test_randomized_dataset_matches_paper_generator():
    D = randomized_dataset(n=1000, m=25, seed=0)
    assert D.shape == (1000, 25)
    for j in range(25):
        vals = np.unique(D[:, j])
        assert vals.min() >= 1
        assert vals.max() <= 100  # domain drawn from {10..100}
    # different seeds differ
    D2 = randomized_dataset(n=1000, m=25, seed=1)
    assert not np.array_equal(D, D2)


def test_domain_generators_shapes():
    assert connect_like(n=500).shape == (500, 43)
    assert pumsb_like(n=300).shape == (300, 74)
    assert poker_like(n=400).shape == (400, 10)
    assert uscensus_like(n=200).shape == (200, 68)
    # poker: 5 distinct cards per hand
    P = poker_like(n=200)
    cards = (P[:, 0::2] - 1) * 13 + (P[:, 1::2] - 1)
    for row in cards:
        assert len(set(row.tolist())) == 5


def test_fimi_roundtrip(tmp_path):
    D = randomized_dataset(50, 8, seed=2)
    p = str(tmp_path / "t.dat")
    write_fimi(p, D)
    back = read_fimi(p)
    assert np.array_equal(D, back)


def test_encode_table():
    cols = [np.array(["a", "b", "a"]), np.array([10, 10, 3])]
    enc, books = encode_table(cols)
    assert enc.shape == (3, 2)
    assert list(books[0]) == ["a", "b"]
    assert np.array_equal(books[1][enc[:, 1]], [10, 10, 3])


def test_quasi_identifier_report():
    rng = np.random.default_rng(0)
    D = rng.integers(0, 3, size=(60, 5))
    rep = find_quasi_identifiers(D, tau=1, kmax=3)
    assert rep.n_quasi_identifiers == len(rep.result.itemsets)
    by_size = rep.by_size()
    assert sum(by_size.values()) == rep.n_quasi_identifiers
    assert 0 <= rep.unique_records() <= 60
    risky = rep.risky_columns()
    assert all(0 <= c < 5 for c in risky)


def test_k_anonymize_reduces_singletons():
    rng = np.random.default_rng(1)
    # heavy-tailed column with many singletons
    D = rng.zipf(1.5, size=(500, 3)).clip(max=10_000)
    anon = k_anonymize_columns(D, k=5)
    for j in range(3):
        _, counts = np.unique(anon[:, j], return_counts=True)
        # the transform drives (nearly) all values to >= k occurrences;
        # one residual bucket may fall short
        assert (counts < 5).sum() <= 1, f"col {j}: {sorted(counts)[:5]}"
