"""Hypothesis sweeps: device/mesh frontier == host reference, bit-identical
results AND per-level stats, for arbitrary random tables, thresholds and
depths — including resume from a mid-run checkpoint.

Gated in conftest.py when hypothesis is absent (the deterministic frontier
coverage lives in tests/test_frontier.py)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import KyivConfig, itemize, mine, preprocess
from repro.core.kyiv import mine_preprocessed

table_st = st.tuples(
    st.integers(8, 60),  # rows
    st.integers(2, 5),  # columns
    st.integers(2, 5),  # per-column domain
    st.integers(1, 3),  # tau
    st.integers(2, 4),  # kmax
    st.integers(0, 10_000),  # seed
)


def _stat_tuple(s):
    return (s.k, s.candidates, s.support_pruned, s.bound_pruned,
            s.intersections, s.emitted, s.skipped_absent_uniform, s.stored)


def _assert_same(ref, got):
    assert sorted(got.itemsets) == sorted(ref.itemsets)
    assert list(map(_stat_tuple, got.stats)) == list(map(_stat_tuple, ref.stats))


@pytest.mark.parametrize("engine", ["jnp", "pallas"])
@settings(max_examples=12, deadline=None)
@given(table_st)
def test_device_frontier_matches_host_reference(engine, params):
    n, m, dom, tau, kmax, seed = params
    rng = np.random.default_rng(seed)
    D = rng.integers(0, dom, size=(n, m))
    ref = mine(D, KyivConfig(tau=tau, kmax=kmax, engine="numpy"))
    got = mine(D, KyivConfig(tau=tau, kmax=kmax, engine=engine))
    _assert_same(ref, got)
    off = mine(D, KyivConfig(tau=tau, kmax=kmax, engine=engine, device_frontier=False))
    _assert_same(ref, off)


@settings(max_examples=8, deadline=None)
@given(table_st, st.integers(2, 3))
def test_device_frontier_resume_matches_full_run(params, kill_at):
    n, m, dom, tau, kmax, seed = params
    rng = np.random.default_rng(seed)
    D = rng.integers(0, dom, size=(n, m))
    cfg = KyivConfig(tau=tau, kmax=max(kmax, kill_at + 1), engine="jnp")
    prep = preprocess(itemize(D), cfg.tau)
    full = mine_preprocessed(prep, cfg)

    saved = {}

    class Stop(Exception):
        pass

    def hook(k, state):
        if k == kill_at:
            saved.update(state)
            raise Stop

    try:
        mine_preprocessed(prep, cfg, on_level_end=hook)
    except Stop:
        pass
    if not saved:  # run ended before the kill level — nothing to resume
        return
    resumed = mine_preprocessed(prep, cfg, resume_state=saved)
    _assert_same(full, resumed)
