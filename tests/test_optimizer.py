"""AdamW vs a plain numpy reference; schedule shape; clipping."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.training.optimizer import OptConfig, adamw_init, adamw_update, lr_at


def _numpy_adamw(params, grads, m, v, step, cfg, gnorm):
    scale = min(1.0, cfg.clip_norm / max(gnorm, 1e-9))
    lr = _lr(cfg, step)
    out_p, out_m, out_v = {}, {}, {}
    for k in params:
        g = grads[k] * scale
        m_new = cfg.b1 * m[k] + (1 - cfg.b1) * g
        v_new = cfg.b2 * v[k] + (1 - cfg.b2) * g * g
        mhat = m_new / (1 - cfg.b1**step)
        vhat = v_new / (1 - cfg.b2**step)
        wd = cfg.weight_decay if params[k].ndim >= 2 else 0.0
        out_p[k] = params[k] - lr * (mhat / (np.sqrt(vhat) + cfg.eps) + wd * params[k])
        out_m[k], out_v[k] = m_new, v_new
    return out_p, out_m, out_v


def _lr(cfg, step):
    if step < cfg.warmup_steps:
        return cfg.lr * step / max(cfg.warmup_steps, 1)
    prog = min(max((step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0), 1)
    return cfg.lr * (cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + np.cos(np.pi * prog)))


def test_adamw_matches_numpy_reference():
    rng = np.random.default_rng(0)
    cfg = OptConfig(lr=1e-3, warmup_steps=2, total_steps=100, clip_norm=10.0)
    params = {"w": rng.standard_normal((4, 5)).astype(np.float32),
              "b": rng.standard_normal(5).astype(np.float32)}
    jparams = jax.tree.map(jnp.asarray, params)
    opt = adamw_init(jparams)
    m = {k: np.zeros_like(v) for k, v in params.items()}
    v = {k: np.zeros_like(val) for k, val in params.items()}
    for step in range(1, 5):
        grads = {k: rng.standard_normal(val.shape).astype(np.float32)
                 for k, val in params.items()}
        gnorm = np.sqrt(sum((g**2).sum() for g in grads.values()))
        jparams, opt, metrics = adamw_update(
            jax.tree.map(jnp.asarray, grads), opt, jparams, cfg
        )
        params, m, v = _numpy_adamw(params, grads, m, v, step, cfg, gnorm)
        np.testing.assert_allclose(float(metrics["grad_norm"]), gnorm, rtol=1e-5)
        np.testing.assert_allclose(float(metrics["lr"]), _lr(cfg, step), rtol=1e-5)
        for k in params:
            np.testing.assert_allclose(np.asarray(jparams[k]), params[k],
                                       rtol=2e-5, atol=2e-6, err_msg=f"{k} step {step}")


def test_clipping_engages():
    cfg = OptConfig(lr=1e-3, clip_norm=0.5, warmup_steps=0, total_steps=10)
    params = {"w": jnp.ones((3, 3))}
    opt = adamw_init(params)
    big = {"w": jnp.full((3, 3), 100.0)}
    p1, _, m1 = adamw_update(big, opt, params, cfg)
    small = {"w": jnp.full((3, 3), 100.0) * 0.5 / float(m1["grad_norm"])}
    p2, _, _ = adamw_update(small, opt, params, cfg)
    np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p2["w"]), rtol=1e-5)


def test_lr_schedule_shape():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    lrs = [float(lr_at(cfg, jnp.asarray(s))) for s in range(0, 101, 5)]
    assert lrs[0] == 0.0
    assert abs(max(lrs) - 1.0) < 0.15
    assert abs(lrs[-1] - 0.1) < 1e-3
    assert all(b <= a + 1e-9 for a, b in zip(lrs[2:], lrs[3:]))  # decays after warmup
