"""Roofline machinery: the scan-undercount fact, HLO collective parsing with
trip-count multipliers, ring-collective math, analytic model sanity."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.roofline.analysis import (
    CollectiveOp,
    collective_seconds,
    parse_collectives,
    roofline_terms,
)
from repro.roofline.analytic import analytic_work
from repro.roofline.hw import V5E
from repro.configs import ARCHS, SHAPES


def test_cost_analysis_counts_scan_body_once():
    """The fact that motivates the analytic model (see roofline.analytic)."""
    n = 128

    def f_scan(w, x):
        out, _ = jax.lax.scan(lambda c, _: (c @ w, None), x, None, length=10)
        return out

    def f_once(w, x):
        return x @ w

    w = jax.ShapeDtypeStruct((n, n), jnp.float32)
    x = jax.ShapeDtypeStruct((n, n), jnp.float32)
    c_scan = jax.jit(f_scan).lower(w, x).compile().cost_analysis()
    c_once = jax.jit(f_once).lower(w, x).compile().cost_analysis()
    assert abs(c_scan["flops"] - c_once["flops"]) / c_once["flops"] < 0.05


def test_parse_collectives_trip_multiplier():
    hlo = """
HloModule jit_f

%add (a: f32[], b: f32[]) -> f32[] {
  ROOT %r = f32[] add(%a, %b)
}

%cond.1 (arg: (s32[], f32[8,16])) -> pred[] {
  %gte = s32[] get-tuple-element(%arg), index=0
  %c = s32[] constant(12)
  ROOT %cmp = pred[] compare(%gte, %c), direction=LT
}

%body.1 (arg: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %gte1 = f32[8,16]{1,0} get-tuple-element(%arg), index=1
  %ar = f32[8,16]{1,0} all-reduce(%gte1), replica_groups=[4,4]<=[16], to_apply=%add
  ROOT %t = (s32[], f32[8,16]) tuple(%gte0, %ar)
}

ENTRY %main (p: f32[8,16]) -> f32[8,16] {
  %ag = f32[8,64]{1,0} all-gather(%p), replica_groups=[4,4]<=[16], dimensions={1}
  %w = (s32[], f32[8,16]) while(%init), condition=%cond.1, body=%body.1
  ROOT %out = f32[8,16]{1,0} get-tuple-element(%w), index=1
}
"""
    ops = parse_collectives(hlo)
    kinds = {(o.kind, o.trip_mult) for o in ops}
    assert ("all-gather", 1) in kinds
    assert ("all-reduce", 12) in kinds
    ar = [o for o in ops if o.kind == "all-reduce"][0]
    assert ar.group_size == 4
    assert ar.bytes == 8 * 16 * 4


def test_collective_seconds_ring_model():
    # all-gather of global tensor G bytes over n shards: (n-1)/n * G per link-set
    op = CollectiveOp("all-gather", "f32", (16, 64), 4)
    t, wire = collective_seconds([op], V5E)
    expected_wire = 16 * 64 * 4 * 3 / 4
    assert wire == int(expected_wire)
    assert abs(t - expected_wire / (V5E.ici_link_bw * V5E.ici_links)) < 1e-12
    # all-reduce costs 2x its per-shard bytes * (n-1)/n
    op2 = CollectiveOp("all-reduce", "bf16", (8, 8), 8, trip_mult=3)
    _, wire2 = collective_seconds([op2], V5E)
    assert wire2 == int(2 * 8 * 8 * 2 * 7 / 8 * 3)


def test_real_program_collective_parse():
    """End-to-end: a sharded matmul's all-reduce is found with right bytes."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    devs = jax.devices()
    if len(devs) < 1:
        return
    mesh = jax.make_mesh((1,), ("model",))
    # single-device: no collectives expected — parser returns empty
    f = jax.jit(lambda a, b: a @ b)
    lowered = f.lower(
        jax.ShapeDtypeStruct((8, 8), jnp.float32),
        jax.ShapeDtypeStruct((8, 8), jnp.float32),
    )
    ops = parse_collectives(lowered.compile().as_text())
    assert ops == []


def test_analytic_model_sanity():
    """Analytic flops scale with tokens and are >= model flops (waste >= 0)."""
    for name in ("qwen1.5-110b", "granite-moe-1b-a400m", "mamba2-370m"):
        arch = ARCHS[name]
        train = analytic_work(arch, SHAPES["train_4k"], 256)
        decode = analytic_work(arch, SHAPES["decode_32k"], 256)
        n_active = arch.active_param_count()
        model_train = 6 * n_active * SHAPES["train_4k"].global_batch * SHAPES["train_4k"].seq_len / 256
        assert train.flops >= model_train * 0.9, name  # waste never negative
        assert train.flops > decode.flops * 100, name
        assert train.hbm_bytes > 0 and decode.hbm_bytes > 0


def test_roofline_report_fields():
    rep = roofline_terms({"flops": 1e12, "bytes accessed": 1e9}, "", V5E,
                         model_flops_per_dev=5e11)
    d = rep.to_dict()
    assert d["dominant"] == "compute"
    assert 0 < d["useful_flops_ratio"] <= 1
    assert d["raw_cost_analysis_flops"] == 1e12
