"""Property test: append-then-incremental-mine == cold mine on the
concatenated table, across all three engines.

The incremental miner (repro.service.incremental) recounts cached results on
the delta rows, expands promoted/near-boundary seeds, and classifies
delta-born itemsets; this file is the evidence that the union of those three
families is the *complete* answer — for arbitrary random tables, appends,
thresholds and depths, the result must be identical (itemsets and supports)
to cold-mining the concatenated table from scratch.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import KyivConfig, mine
from repro.service import DatasetStore, IncrementalConfig, mine_incremental

# keep tables tiny: the pallas engine runs interpreted on CPU
table_st = st.tuples(
    st.integers(4, 36),  # base rows
    st.integers(1, 18),  # delta rows
    st.integers(2, 4),  # columns
    st.integers(2, 6),  # per-column domain
    st.integers(0, 10_000),  # seed
)


def _value_sets(result):
    return {(frozenset(ids), c) for ids, c in result.as_value_sets()}


def _check(engine, n, d, m, dom, seed, tau, kmax):
    rng = np.random.default_rng(seed)
    base = rng.integers(0, dom, size=(n, m))
    delta = rng.integers(0, dom + 1, size=(d, m))  # dom -> new values can appear
    cfg = KyivConfig(tau=tau, kmax=kmax, engine=engine)

    store = DatasetStore.from_dataset(base)
    base_res = mine(base, cfg)
    # rebase the cold result's item ids onto the store's id space: ids are
    # assignment-order dependent, so map through (col, value)
    table = store.item_table()
    id_of = {
        (int(table.col[i]), int(table.value[i])): i for i in range(table.n_items)
    }
    ref = base_res.prep.table
    remap = {
        i: id_of[(int(ref.col[i]), int(ref.value[i]))] for i in range(ref.n_items)
    }
    base_res.itemsets = [
        (tuple(sorted(remap[i] for i in ids)), c) for ids, c in base_res.itemsets
    ]

    base_version = store.version
    store.append(delta)
    out = mine_incremental(
        store,
        base_res,
        base_version,
        cfg,
        IncrementalConfig(max_delta_fraction=1.0),
    )
    assert out is not None, "incremental path unexpectedly fell back"
    result, info = out
    cold = mine(np.concatenate([base, delta]), cfg)
    assert _value_sets(result) == _value_sets(cold), (
        f"incremental != cold for n={n} d={d} m={m} dom={dom} seed={seed} "
        f"tau={tau} kmax={kmax} info={info}"
    )


@given(table_st, st.integers(1, 3), st.integers(2, 4))
@settings(max_examples=40, deadline=None)
def test_incremental_equals_cold_numpy(shape, tau, kmax):
    n, d, m, dom, seed = shape
    _check("numpy", n, d, m, dom, seed, tau, kmax)


@given(table_st, st.integers(1, 2), st.integers(2, 3))
@settings(max_examples=10, deadline=None)
def test_incremental_equals_cold_jnp(shape, tau, kmax):
    n, d, m, dom, seed = shape
    _check("jnp", n, d, m, dom, seed, tau, kmax)


@given(table_st, st.integers(1, 2), st.integers(2, 3))
@settings(max_examples=6, deadline=None)
def test_incremental_equals_cold_pallas(shape, tau, kmax):
    n, d, m, dom, seed = shape
    _check("pallas", n, d, m, dom, seed, tau, kmax)


@pytest.mark.parametrize("engine", ["numpy", "jnp", "pallas"])
def test_incremental_regression_cases(engine):
    """Deterministic seeds that once exposed gaps (absent-born itemsets,
    promotions, new values) — kept as fast regressions per engine."""
    for n, d, m, dom, seed, tau, kmax in [
        (30, 10, 3, 4, 7, 1, 3),
        (24, 12, 4, 3, 11, 2, 3),
        (36, 6, 3, 5, 3, 1, 2),
    ]:
        _check(engine, n, d, m, dom, seed, tau, kmax)
