"""Itemization + bitset primitives (paper §3)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import itemize, bits_popcount, bits_to_rows, pack_rows_to_bits


def paper_example_36():
    return np.array([[1, 2, 3, 4], [1, 2, 7, 4], [1, 6, 3, 4], [5, 2, 3, 4]])


def test_example_36_items():
    """Golden test: Example 3.6's I_A, delta_A, U_A."""
    t = itemize(paper_example_36())
    assert t.n_items == 7
    got = {(int(t.value[i]), int(t.col[i]) + 1, tuple(t.rows_of(i) + 1)) for i in range(7)}
    expected = {
        (1, 1, (1, 2, 3)), (2, 2, (1, 2, 4)), (3, 3, (1, 3, 4)),
        (4, 4, (1, 2, 3, 4)), (5, 1, (4,)), (6, 2, (3,)), (7, 3, (2,)),
    }
    assert got == expected
    uniques = {i for i in range(7) if t.freq[i] == 1}
    assert {(int(t.value[i]), int(t.col[i]) + 1) for i in uniques} == {(5, 1), (6, 2), (7, 3)}
    uniform = {i for i in range(7) if t.freq[i] == t.n_rows}
    assert {(int(t.value[i]), int(t.col[i]) + 1) for i in uniform} == {(4, 4)}


dataset_st = st.integers(1, 40).flatmap(
    lambda n: st.integers(1, 6).flatmap(
        lambda m: st.lists(
            st.lists(st.integers(0, 5), min_size=m, max_size=m),
            min_size=n, max_size=n,
        )
    )
)


@given(dataset_st)
@settings(max_examples=50, deadline=None)
def test_itemize_properties(rows):
    D = np.asarray(rows)
    t = itemize(D)
    n, m = D.shape
    # every (col, value) pair appears exactly once
    pairs = list(zip(t.col.tolist(), t.value.tolist()))
    assert len(pairs) == len(set(pairs))
    # frequencies sum to n per column; bitsets match frequency and rows
    for j in range(m):
        items_j = np.nonzero(t.col == j)[0]
        assert t.freq[items_j].sum() == n
    pc = bits_popcount(t.bits)
    assert np.array_equal(pc, t.freq)
    for i in range(t.n_items):
        rows_i = t.rows_of(i)
        assert np.array_equal(D[rows_i, t.col[i]], np.full(len(rows_i), t.value[i]))
        assert t.min_row[i] == rows_i[0]


def test_pack_rows_roundtrip():
    rng = np.random.default_rng(0)
    n = 100
    sets = [np.sort(rng.choice(n, size=rng.integers(0, n), replace=False)) for _ in range(20)]
    bits = pack_rows_to_bits(sets, n)
    for i, s in enumerate(sets):
        assert np.array_equal(bits_to_rows(bits[i]), s)
    assert np.array_equal(bits_popcount(bits), [len(s) for s in sets])


def test_itemize_rejects_bad_shape():
    with pytest.raises(ValueError):
        itemize(np.zeros(5))
