"""Privacy risk engine: coverage kernels, record-risk profiles, planner.

Contracts under test:

* the coverage accumulator is **bit-identical** across every engine and
  placement (numpy ground truth vs jnp vs Pallas-interpret vs host/device
  placements; the 8-device mesh parity runs in the subprocess test below
  and in tests/test_mesh_service.py) — fixed-seed spot checks here, the
  hypothesis sweep in tests/test_privacy_prop.py;
* per-record risk numbers agree with a brute-force Python recomputation;
* the old ``sdc.quasi`` loop answers are reproduced exactly by the
  coverage-engine wrappers;
* ``plan_anonymization`` always converges: apply the plan, re-mine the
  masked table, get **zero** residual quasi-identifiers;
* the service/HTTP surface: /risk and /anonymize payloads, the privacy LRU,
  and the new /stats sections.
"""

import json
import os
import subprocess
import sys
import threading
import urllib.request

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import KyivConfig, mine
from repro.core.items import bits_to_rows, itemize
from repro.core.placement import DevicePlacement, HostPlacement
from repro.kernels.coverage import (
    CoverageEngine,
    acc_to_record_counts,
    coverage_accumulate_host,
    coverage_accumulate_indexed,
    coverage_accumulate_ref,
)
from repro.privacy import (
    GENERALIZED,
    MASKED,
    apply_plan,
    mine_masked,
    plan_anonymization,
    risk_profile,
    strip_masked_items,
)
from repro.privacy.risk import risk_scores
from repro.sdc.quasi import QuasiIdentifierReport, find_quasi_identifiers, report_as_dict
from repro.service import MiningService

PLACEMENTS = [
    HostPlacement(),
    DevicePlacement("jnp"),
    DevicePlacement("pallas", interpret=True),
]


def _rand(seed, n, m, dom):
    return np.random.default_rng(seed).integers(0, dom, size=(n, m))


def _brute_record_counts(bits, sets, weights, n_rows):
    """Scalar per-record recomputation of the coverage contract."""
    out = np.zeros(n_rows, dtype=np.int64)
    for s in range(sets.shape[0]):
        mask = bits[sets[s, 0]].copy()
        for t in range(1, sets.shape[1]):
            mask &= bits[sets[s, t]]
        for r in range(n_rows):
            if (int(mask[r // 32]) >> (r % 32)) & 1:
                out[r] += int(weights[s])
    return out


# ---------------------------------------------------------------------------
# Coverage kernel: engines bit-identical to the numpy ground truth
# (fixed-seed spot checks; the hypothesis sweep lives in test_privacy_prop.py)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "seed,t,n_words,m,k",
    [(0, 7, 1, 9, 1), (1, 24, 4, 40, 3), (2, 12, 8, 17, 4), (3, 2, 2, 1, 2)],
)
def test_coverage_accumulate_engines_bit_identical(seed, t, n_words, m, k):
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 2**32, size=(t, n_words), dtype=np.uint32)
    sets = rng.integers(0, t, size=(m, k)).astype(np.int32)
    weights = rng.integers(0, 3, size=m).astype(np.int32)  # 0-weights = padding

    host = coverage_accumulate_host(bits, sets, weights)
    ref = np.asarray(
        coverage_accumulate_ref(jnp.asarray(bits), jnp.asarray(sets), jnp.asarray(weights))
    )
    pallas = np.asarray(
        coverage_accumulate_indexed(
            jnp.asarray(bits), jnp.asarray(sets), jnp.asarray(weights),
            block_words=n_words, interpret=True,
        )
    )
    assert np.array_equal(ref, host)
    assert np.array_equal(pallas, host)
    n_rows = n_words * 32
    assert np.array_equal(
        acc_to_record_counts(host, n_rows),
        _brute_record_counts(bits, sets, weights, n_rows),
    )


@pytest.mark.parametrize("seed,n,m,dom,tau", [(5, 33, 3, 4, 1), (6, 80, 5, 6, 2)])
def test_coverage_engine_placements_bit_identical(seed, n, m, dom, tau):
    """The full engine path (width padding, batching, bucket padding with
    weight-0 rows) agrees across placements on real mined itemsets."""
    D = _rand(seed, n, m, dom)
    res = mine(D, KyivConfig(tau=tau, kmax=3))
    if not res.itemsets:
        pytest.skip("no QIs mined for this configuration")
    table = res.prep.table
    sets = np.asarray(
        [list(ids) + [ids[-1]] * (3 - len(ids)) for ids, _ in res.itemsets],
        dtype=np.int32,
    )
    ref = None
    for placement in PLACEMENTS:
        eng = CoverageEngine(
            table.bits, placement=placement, set_width=3, max_batch_sets=16
        )
        acc = eng.accumulate(sets)
        if ref is None:
            ref = acc
        assert np.array_equal(acc, ref), placement.kind


# ---------------------------------------------------------------------------
# Risk profile semantics
# ---------------------------------------------------------------------------


def test_risk_scores_formula():
    counts = np.array([[1, 0, 0, 0], [0, 1, 0, 2], [0, 0, 1, 0]])
    risk = risk_scores(counts)
    assert risk[0] == 1.0  # singleton QI pins the record
    assert risk[1] == pytest.approx(0.5)  # one size-2 QI
    assert risk[2] == pytest.approx(1 / 3)  # one size-3 QI
    assert risk[3] == pytest.approx(1 - 0.25)  # two size-2 QIs
    assert np.array_equal(risk == 0.0, counts.sum(0) == 0)


@pytest.mark.parametrize("placement", PLACEMENTS, ids=lambda p: repr(p))
def test_risk_profile_matches_brute_force(placement):
    D = _rand(11, 60, 4, 5)
    res = mine(D, KyivConfig(tau=1, kmax=3))
    prof = risk_profile(res, placement=placement)
    table = res.prep.table

    qi_count = np.zeros(60, dtype=np.int64)
    min_size = np.full(60, 99, dtype=np.int64)
    for ids, _ in res.itemsets:
        mask = table.bits[ids[0]].copy()
        for i in ids[1:]:
            mask &= table.bits[i]
        rows = bits_to_rows(mask)
        qi_count[rows] += 1
        min_size[rows] = np.minimum(min_size[rows], len(ids))
    min_size[qi_count == 0] = 0

    assert np.array_equal(prof.qi_count, qi_count)
    assert np.array_equal(prof.min_qi_size, min_size)
    assert prof.records_at_risk == int((qi_count > 0).sum())
    top = prof.top_records(5)
    assert all(top[i]["risk"] >= top[i + 1]["risk"] for i in range(len(top) - 1))
    hist = prof.histogram()
    assert sum(hist["counts"]) == 60


def test_risk_profile_empty_result():
    D = np.tile(np.array([[1, 2], [1, 2]]), (5, 1))  # every item frequent
    res = mine(D, KyivConfig(tau=1, kmax=2))
    prof = risk_profile(res)
    assert prof.records_at_risk == 0
    assert prof.risk.max(initial=0.0) == 0.0
    assert prof.top_records() == []


# ---------------------------------------------------------------------------
# sdc.quasi wrappers reproduce the legacy loop answers
# ---------------------------------------------------------------------------


def _legacy_unique_records(result):
    table = result.prep.table
    hit = np.zeros(table.n_rows, dtype=bool)
    for ids, _ in result.itemsets:
        m = table.bits[ids[0]].copy()
        for i in ids[1:]:
            m &= table.bits[i]
        hit[bits_to_rows(m)] = True
    return int(hit.sum())


def _legacy_risky_columns(result):
    table = result.prep.table
    out = {}
    for ids, _ in result.itemsets:
        for i in ids:
            c = int(table.col[i])
            out[c] = out.get(c, 0) + 1
    return out


@pytest.mark.parametrize("seed", [0, 7, 23])
def test_quasi_wrappers_match_legacy_loops(seed):
    report = find_quasi_identifiers(_rand(seed, 70, 4, 5), tau=1, kmax=3)
    assert report.unique_records() == _legacy_unique_records(report.result)
    assert report.risky_columns() == _legacy_risky_columns(report.result)


def test_report_as_dict_gains_risk_fields():
    report = find_quasi_identifiers(_rand(3, 50, 4, 4), tau=1, kmax=3)
    d = report_as_dict(report)
    assert {"top_risk_records", "risk_histogram"} <= set(d)
    assert sum(d["risk_histogram"]["counts"]) == 50
    if d["top_risk_records"]:
        r0 = d["top_risk_records"][0]
        assert {"row", "risk", "qi_count", "min_qi_size"} <= set(r0)
    json.dumps(d)  # JSON-serialisable end to end


# ---------------------------------------------------------------------------
# Anonymization planner: verified zero-residual plans
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "seed,n,m,dom,tau,kmax",
    [
        (0, 60, 4, 5, 1, 3),
        (1, 120, 5, 6, 1, 3),
        (2, 80, 4, 4, 2, 3),
        (3, 40, 3, 8, 1, 2),  # wide domain: many singleton QIs
    ],
)
def test_planner_zero_residual_qis(seed, n, m, dom, tau, kmax):
    D = _rand(seed, n, m, dom)
    plan = plan_anonymization(D, tau=tau, kmax=kmax)
    assert plan.verified and plan.residual_qis == 0
    masked = apply_plan(D, plan)
    post = mine_masked(masked, KyivConfig(tau=tau, kmax=kmax))
    assert post is None or len(post.itemsets) == 0
    # the plan actually edited something iff there were QIs to kill
    had_qis = plan.initial_qis > 0
    assert had_qis == bool(plan.suppressions or plan.generalized_columns)


def test_planner_noop_on_safe_table():
    D = np.tile(np.array([[1, 5], [2, 6]]), (10, 1))  # all supports = 10 > tau
    plan = plan_anonymization(D, tau=1, kmax=2)
    assert plan.verified and plan.initial_qis == 0
    assert plan.suppressions == [] and plan.generalized_columns == []
    assert np.array_equal(apply_plan(D, plan), D)


def test_planner_degenerate_tiny_table():
    D = np.array([[1, 2, 3]])  # n_rows <= tau: only full suppression works
    plan = plan_anonymization(D, tau=1, kmax=2)
    assert plan.verified
    assert sorted(plan.suppressions) == [(0, 0), (0, 1), (0, 2)]
    assert mine_masked(apply_plan(D, plan), KyivConfig(tau=1, kmax=2)) is None


def test_planner_rejects_sentinel_values():
    with pytest.raises(ValueError, match="sentinel"):
        plan_anonymization(np.array([[MASKED, 1]]), tau=1)


def test_planner_empty_shapes():
    for shape in ((0, 3), (5, 0)):
        plan = plan_anonymization(np.empty(shape, dtype=np.int64), tau=1)
        assert plan.verified and plan.suppressions == []


def test_strip_masked_items_and_generalized_are_frequent():
    D = _rand(5, 30, 3, 4)
    masked = D.copy().astype(np.int64)
    masked[0, 0] = MASKED
    masked[:, 2] = GENERALIZED
    table = strip_masked_items(itemize(masked))
    assert not (table.value == MASKED).any()
    gen_items = np.nonzero(table.value == GENERALIZED)[0]
    assert len(gen_items) == 1 and table.freq[gen_items[0]] == 30


def test_apply_plan_matches_planner_final_state():
    D = _rand(9, 50, 4, 5)
    plan = plan_anonymization(D, tau=1, kmax=3)
    masked = apply_plan(D, plan)
    for r, c in plan.suppressions:
        assert masked[r, c] in (MASKED, GENERALIZED)
    for c in plan.generalized_columns:
        assert (masked[:, c] == GENERALIZED).all()
    untouched = np.ones_like(D, dtype=bool)
    if plan.suppressions:
        rows, cols = zip(*plan.suppressions)
        untouched[list(rows), list(cols)] = False
    untouched[:, plan.generalized_columns] = False
    assert np.array_equal(masked[untouched], D.astype(np.int64)[untouched])


# ---------------------------------------------------------------------------
# Service + HTTP surface
# ---------------------------------------------------------------------------


def test_service_risk_and_plan_cached_per_version():
    svc = MiningService.from_dataset(_rand(13, 90, 4, 5))
    r1 = svc.risk(tau=1, kmax=3)
    r2 = svc.risk(tau=1, kmax=3)
    assert r1["source"] in ("cold", "incremental") and r2["source"] == "privacy-cache"
    assert r1["records_at_risk"] == r2["records_at_risk"]

    p1 = svc.anonymize_plan(tau=1, kmax=3)
    assert p1["verified"] and p1["residual_qis"] == 0
    assert svc.anonymize_plan(tau=1, kmax=3)["source"] == "privacy-cache"

    svc.append(_rand(14, 10, 4, 5))
    r3 = svc.risk(tau=1, kmax=3)
    assert r3["source"] != "privacy-cache" and r3["version"] == r1["version"] + 1

    stats = svc.stats()
    assert stats["privacy"]["hits"] >= 2
    assert "coverage" in stats["executables"]["families"]
    svc.close()


def test_service_plan_agrees_with_direct_planner():
    """The store's reconstructed dataset must round-trip: planning on it
    equals planning on the original rows."""
    D = _rand(21, 70, 4, 5)
    svc = MiningService.from_dataset(D)
    assert np.array_equal(svc.store.item_table().to_dataset(), D)
    p = svc.anonymize_plan(tau=1, kmax=3)
    direct = plan_anonymization(D, tau=1, kmax=3)
    assert p["cells_suppressed"] == direct.cells_suppressed
    assert p["generalized_columns"] == [int(c) for c in direct.generalized_columns]
    svc.close()


@pytest.fixture()
def http_service():
    from repro.launch.serve_miner import make_server

    svc = MiningService.from_dataset(_rand(0, 150, 4, 5))
    server = make_server(svc, port=0)
    thread = threading.Thread(target=server.serve_forever, daemon=True)
    thread.start()
    yield svc, server.server_address[1]
    server.shutdown()
    server.server_close()
    svc.close()


def _req(port, path):
    resp = urllib.request.urlopen(f"http://127.0.0.1:{port}{path}", timeout=30)
    return resp.status, json.loads(resp.read())


def test_http_risk_and_anonymize_endpoints(http_service):
    _, port = http_service
    code, risk = _req(port, "/risk?tau=1&kmax=3&top=3")
    assert code == 200 and risk["n_rows"] == 150
    assert len(risk["top_records"]) <= 3
    assert sum(risk["histogram"]["counts"]) == 150

    code, risk2 = _req(port, "/risk?tau=1&kmax=3&top=3")
    assert risk2["source"] == "privacy-cache"

    code, plan = _req(port, "/anonymize?tau=1&kmax=3")
    assert code == 200 and plan["verified"] and plan["residual_qis"] == 0

    code, rep = _req(port, "/report?tau=1&kmax=3")
    assert rep["unique_records"] == risk["records_at_risk"]

    code, stats = _req(port, "/stats")
    assert stats["privacy"]["entries"] >= 2
    assert "coverage" in stats["executables"]["families"]


# ---------------------------------------------------------------------------
# 8-device mesh parity (subprocess — XLA device count must pre-date jax init)
# ---------------------------------------------------------------------------

_MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, sys.argv[1])
import numpy as np
import jax
from repro.core import KyivConfig, MeshPlacement, mine
from repro.core.placement import HostPlacement
from repro.kernels.coverage import CoverageEngine, coverage_cache_stats
from repro.privacy import risk_profile

mesh = jax.make_mesh((2, 4), ("data", "model"))
placement = MeshPlacement(mesh, pair_axes=("data",), word_axis="model")
rng = np.random.default_rng(31)
bits = rng.integers(0, 2**32, size=(41, 10), dtype=np.uint32)  # W % shards != 0
sets = rng.integers(0, 41, size=(53, 3)).astype(np.int32)
wt = rng.integers(0, 2, size=53).astype(np.int32)

host = CoverageEngine(bits, placement=HostPlacement(), set_width=3).accumulate(sets, wt)
mesh_acc = CoverageEngine(bits, placement=placement, set_width=3).accumulate(sets, wt)
assert np.array_equal(mesh_acc, host), "mesh coverage accumulator != host"
assert coverage_cache_stats()["entries"] >= 1

D = rng.integers(0, 5, size=(210, 5))
res_mesh = mine(D, KyivConfig(tau=2, kmax=3, placement=placement))
res_host = mine(D, KyivConfig(tau=2, kmax=3))
pm = risk_profile(res_mesh)          # placement resolved from the config
ph = risk_profile(res_host)
assert np.array_equal(pm.counts_by_size, ph.counts_by_size)
assert np.array_equal(pm.qi_count, ph.qi_count)
assert np.array_equal(pm.min_qi_size, ph.min_qi_size)
assert np.allclose(pm.risk, ph.risk)
print("MESH_COVERAGE_OK")
"""


@pytest.mark.slow
def test_mesh_coverage_bit_identical_8dev():
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", _MESH_SCRIPT, src],
        capture_output=True,
        text=True,
        timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "MESH_COVERAGE_OK" in proc.stdout
