"""Algorithm 1 end-to-end: paper golden traces + oracle/MINIT agreement."""

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import (
    KyivConfig,
    brute_force_minimal_infrequent,
    mine,
    minit_minimal_infrequent,
)


def paper_example_48():
    """Example 4.8 dataset; * entries are globally unique values."""
    u = [100]

    def star():
        u[0] += 1
        return u[0]

    return np.array(
        [
            [star(), star(), star(), 4, star()],
            [1, 2, star(), 4, star()],
            [1, 2, 3, 4, star()],
            [1, 2, 3, 4, 5],
            [1, star(), 3, star(), 5],
            [star(), 2, 3, star(), 5],
            [star(), star(), star(), star(), 5],
        ]
    )


def test_example_48_results():
    """Golden: Kyiv prints {d,e} at k=2 and {a,b,e} at k=3 (values/cols)."""
    res = mine(paper_example_48(), KyivConfig(tau=1, kmax=3))
    multi = {s for s, _ in res.as_value_sets() if len(s) > 1}
    assert multi == {
        ((3, 4), (4, 5)),  # {d, e}: value 4 in col 4, value 5 in col 5
        ((0, 1), (1, 2), (4, 5)),  # {a, b, e}
    }


def test_example_48_pruning_trace():
    """Golden: at k=3 the paper reports 10 candidate pairs, 3 pruned by the
    support test, 4 by Lemma 4.6, 2 by Corollary 4.7, 1 intersection."""
    res = mine(paper_example_48(), KyivConfig(tau=1, kmax=3))
    s3 = [s for s in res.stats if s.k == 3][0]
    assert s3.candidates == 10
    assert s3.support_pruned == 3
    assert s3.bound_pruned == 6  # lemma(4) + corollary(2)
    assert s3.intersections == 1
    assert s3.emitted == 1
    # without bounds, the same 6 pairs cost intersections instead
    res_nb = mine(paper_example_48(), KyivConfig(tau=1, kmax=3, use_bounds=False))
    s3nb = [s for s in res_nb.stats if s.k == 3][0]
    assert s3nb.intersections == 7
    assert {i for i, _ in res_nb.itemsets} == {i for i, _ in res.itemsets}


dataset_st = st.tuples(
    st.integers(5, 25), st.integers(2, 5), st.integers(2, 5), st.integers(0, 10_000)
)


@given(dataset_st, st.integers(1, 3), st.integers(2, 4))
@settings(max_examples=40, deadline=None)
def test_kyiv_equals_oracle(dims, tau, kmax):
    n, m, dom, seed = dims
    D = np.random.default_rng(seed).integers(0, dom, size=(n, m))
    oracle = brute_force_minimal_infrequent(D, tau, kmax)
    got = mine(D, KyivConfig(tau=tau, kmax=kmax)).canonical_set()
    assert got == oracle


@given(dataset_st, st.integers(1, 2), st.integers(2, 4))
@settings(max_examples=25, deadline=None)
def test_minit_equals_oracle(dims, tau, kmax):
    n, m, dom, seed = dims
    D = np.random.default_rng(seed).integers(0, dom, size=(n, m))
    oracle = brute_force_minimal_infrequent(D, tau, kmax)
    assert minit_minimal_infrequent(D, tau, kmax) == oracle


@given(dataset_st)
@settings(max_examples=25, deadline=None)
def test_orderings_agree(dims):
    """§5.2.4: ordering changes work done, never the result set."""
    n, m, dom, seed = dims
    D = np.random.default_rng(seed).integers(0, dom, size=(n, m))
    results = {
        o: mine(D, KyivConfig(tau=2, kmax=3, ordering=o, seed=7)).canonical_set()
        for o in ("ascending", "descending", "random")
    }
    assert results["ascending"] == results["descending"] == results["random"]


@given(dataset_st, st.integers(1, 3))
@settings(max_examples=25, deadline=None)
def test_bounds_do_not_change_results(dims, tau):
    n, m, dom, seed = dims
    D = np.random.default_rng(seed).integers(0, dom, size=(n, m))
    with_b = mine(D, KyivConfig(tau=tau, kmax=4, use_bounds=True))
    without = mine(D, KyivConfig(tau=tau, kmax=4, use_bounds=False))
    assert with_b.canonical_set() == without.canonical_set()
    # bounds only ever remove intersections
    for sb, sn in zip(with_b.stats, without.stats):
        assert sb.intersections <= sn.intersections


@given(dataset_st)
@settings(max_examples=20, deadline=None)
def test_output_invariants(dims):
    """Every emitted itemset is tau-infrequent and minimal (Def. 3.7)."""
    n, m, dom, seed = dims
    tau = 2
    D = np.random.default_rng(seed).integers(0, dom, size=(n, m))
    res = mine(D, KyivConfig(tau=tau, kmax=3))
    t = res.prep.table
    full = np.full(t.n_words, 0xFFFFFFFF, dtype=np.uint32)
    tail = t.n_rows % 32
    if tail:
        full[-1] = np.uint32((1 << tail) - 1)

    def freq(ids):
        mask = full
        for i in ids:
            mask = mask & t.bits[i]
        return int(np.bitwise_count(mask).sum())

    seen = set()
    for ids, cnt in res.itemsets:
        assert ids not in seen, "duplicate emission"
        seen.add(ids)
        f = freq(ids)
        assert f == cnt
        assert 0 < f <= tau
        for drop in range(len(ids)):
            sub = ids[:drop] + ids[drop + 1 :]
            if sub:
                assert freq(sub) > tau, "non-minimal emission"


def test_paper_expansion_mode_is_subset():
    rng = np.random.default_rng(3)
    # duplicate a column to force mirrors
    base = rng.integers(0, 3, size=(20, 3))
    D = np.concatenate([base, base[:, :1]], axis=1)
    full = mine(D, KyivConfig(tau=1, kmax=3, expansion="full")).canonical_set()
    paper = mine(D, KyivConfig(tau=1, kmax=3, expansion="paper")).canonical_set()
    assert paper <= full
    oracle = brute_force_minimal_infrequent(D, 1, 3)
    assert full == oracle
