"""Sequence-sharded decode attention == single-device decode attention
(exact log-sum-exp combine), on an 8-device host mesh (subprocess)."""

import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, sys.argv[1])
import numpy as np
import jax, jax.numpy as jnp
from repro.models.layers.attention import decode_attention
from repro.serving.decode_attn import seq_sharded_decode_attention

mesh = jax.make_mesh((8,), ("data",))
rng = np.random.default_rng(0)
for (b, L, h, kv, hd, window) in [(2, 64, 4, 2, 16, 0), (1, 128, 8, 1, 8, 0),
                                  (2, 64, 4, 4, 16, 24)]:
    q = jnp.asarray(rng.standard_normal((b, 1, h, hd)), jnp.float32)
    kc = jnp.asarray(rng.standard_normal((b, L, kv, hd)), jnp.float32)
    vc = jnp.asarray(rng.standard_normal((b, L, kv, hd)), jnp.float32)
    lengths = jnp.asarray(rng.integers(L // 2, L + 1, b), jnp.int32)
    ref = decode_attention(q, kc, vc, lengths, window=window)
    fn = seq_sharded_decode_attention(mesh, seq_axis="data", window=window)
    with jax.set_mesh(mesh):
        out = fn(q, kc, vc, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
print("SEQ_SHARDED_OK")
"""


@pytest.mark.slow
def test_seq_sharded_decode_attention_8dev():
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT, src],
        capture_output=True, text=True, timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "SEQ_SHARDED_OK" in proc.stdout
