import os
import sys

# NOTE: no XLA_FLAGS here — smoke tests and benches must see 1 device.
# Multi-device tests spawn subprocesses that set the flag themselves.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Property-based test modules need hypothesis (declared in requirements.txt /
# pyproject's `test` extra). On minimal installs without it, skip those
# modules cleanly instead of erroring the whole collection; the deterministic
# suite (kernels, fused classify, drivers, system) still runs.
try:
    import hypothesis  # noqa: F401
except ImportError:
    collect_ignore = [
        "test_balance.py",
        "test_bounds.py",
        "test_frontier_prop.py",
        "test_incremental.py",
        "test_items.py",
        "test_kyiv.py",
        "test_preprocess.py",
        "test_privacy_prop.py",
        "test_sampling_prop.py",
        "test_support.py",
    ]
