"""Analytic per-device FLOP / HBM-byte model of the implemented steps.

Why analytic: XLA's ``HloCostAnalysis`` (what ``compiled.cost_analysis()``
reports) counts a ``while`` body **once**, not × trip count (verified in
``tests/test_roofline.py``). Every production-sized step here is scan-based
(layer groups, chunked attention, chunked cross-entropy, SSD chunks), so the
raw numbers undercount by the trip counts. This module counts the work the
implementation actually performs — including its *overheads* (full-rectangle
causal attention in the chunked kernel, MoE capacity factor, remat recompute,
f32 logit chunks), so ``model_flops / analytic_flops`` genuinely measures
implementation waste. Raw ``cost_analysis`` numbers are kept in the artifacts
for reference.

Conventions:
  * matmul flops = 2·M·N·K; backward of a matmul = 2× forward; remat („full“
    per-group checkpoint) adds ≈ 1× forward recompute → train multiplier 4
    on matmul-type work unless noted.
  * HBM bytes: parameter reads (per step, post-sharding), activation
    writes+reads at layer boundaries, attention KV traffic, cache
    read/write for decode, optimizer state traffic for train.
  * Everything is per *device*; dp/tp factor given by the mesh.
"""

from __future__ import annotations

import dataclasses

from ..configs.base import ArchConfig, ShapeConfig

__all__ = ["WorkModel", "analytic_work"]


@dataclasses.dataclass
class WorkModel:
    flops: float  # per device
    hbm_bytes: float  # per device
    detail: dict

    def to_dict(self) -> dict:
        return {"flops": self.flops, "hbm_bytes": self.hbm_bytes, "detail": self.detail}


def _attn_flops_train(cfg: ArchConfig, tokens: int, seq: int) -> tuple[float, float]:
    """(projection flops, score/value flops) for one full pass over all attn
    layers, forward only. Counts the implementation: chunked attention does
    the full S×S rectangle (causal masking by arithmetic); local attention
    does S × span with span = window rounded up to blocks (+1 block)."""
    proj = 0.0
    score = 0.0
    kinds = cfg.layer_types()
    for kind in kinds:
        if kind in ("attn", "local"):
            if cfg.mla is not None:
                m = cfg.mla
                qd = cfg.n_heads * (m.nope_head_dim + m.rope_head_dim)
                proj += 2 * tokens * cfg.d_model * qd
                proj += 2 * tokens * cfg.d_model * (m.kv_lora + m.rope_head_dim)
                proj += 2 * tokens * m.kv_lora * cfg.n_heads * (m.nope_head_dim + m.v_head_dim)
                proj += 2 * tokens * cfg.n_heads * m.v_head_dim * cfg.d_model
                qk_dim = m.nope_head_dim + m.rope_head_dim
                v_dim = m.v_head_dim
            else:
                hd = cfg.head_dim
                proj += 2 * tokens * cfg.d_model * cfg.n_heads * hd * 2  # q, o
                proj += 2 * tokens * cfg.d_model * cfg.n_kv_heads * hd * 2  # k, v
                qk_dim = hd
                v_dim = hd
            n_batch = tokens // seq
            if kind == "local" and cfg.window:
                blk = min(max(cfg.window // 2, 128), 1024)
                span = ((cfg.window + blk - 1) // blk + 1) * blk
                kv_len = min(span, seq)
            else:
                kv_len = seq  # full rectangle (implementation)
            score += 2 * n_batch * seq * kv_len * cfg.n_heads * (qk_dim + v_dim)
    return proj, score


def _mix_flops_other(cfg: ArchConfig, tokens: int) -> float:
    """ssd / rglru temporal-mixing flops, forward, all layers."""
    total = 0.0
    for kind in cfg.layer_types():
        if kind == "ssd":
            s = cfg.ssm
            proj_out = 2 * s.d_inner + 2 * s.n_groups * s.d_state + s.n_heads
            total += 2 * tokens * cfg.d_model * proj_out  # in proj
            total += 2 * tokens * s.d_inner * cfg.d_model  # out proj
            q = s.chunk
            h, p, n = s.n_heads, s.head_dim, s.d_state
            # intra-chunk quadratic: CB (q*q*n per group→heads) + y_diag (q*q*p)
            total += tokens * q * h * (2 * n + 2 * p)
            # states + y_off: q*n*p per chunk-token
            total += tokens * h * n * p * 4
            total += tokens * (s.d_inner + 2 * s.n_groups * s.d_state) * s.d_conv * 2
        elif kind == "rglru":
            r = cfg.rglru_dim
            total += 2 * tokens * cfg.d_model * r * 3  # gate, in, out
            total += 2 * tokens * r * r * 2  # W_a, W_x gates
            total += tokens * r * (4 * 2 + 10)  # conv(4) + scan combine ops
    return total


def _channel_flops(cfg: ArchConfig, tokens: int) -> float:
    """MLP / MoE flops, forward, all layers — counts capacity-factor waste."""
    total = 0.0
    d = cfg.d_model
    for i, kind in enumerate(cfg.layer_types()):
        if kind == "ssd":
            continue
        if cfg.moe is not None and i >= cfg.moe.first_dense:
            e = cfg.moe
            total += 2 * tokens * d * e.n_experts  # router
            # capacity buffers: E * C tokens actually multiplied
            eff_tokens = tokens * e.top_k * e.capacity_factor
            total += 2 * eff_tokens * d * e.d_expert * 3
            total += 2 * tokens * d * e.d_expert * e.n_shared * 3
        else:
            ff = cfg.d_ff
            if cfg.moe is not None and i < cfg.moe.first_dense:
                ff = cfg.moe.first_dense_ff or cfg.d_ff
            mult = 3 if cfg.mlp_act in ("swiglu", "geglu") else 2
            total += 2 * tokens * d * ff * mult
    return total


def _enc_flops(cfg: ArchConfig, tokens: int, seq: int) -> float:
    """Whisper encoder forward flops (non-causal full attention + MLP)."""
    if not cfg.enc_layers:
        return 0.0
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    n_batch = tokens // seq
    per_layer = (
        2 * tokens * d * h * hd * 4  # qkvo
        + 2 * n_batch * seq * seq * h * hd * 2  # scores + values
        + 2 * tokens * d * cfg.d_ff * 2  # gelu mlp
    )
    return per_layer * cfg.enc_layers


def _xent_flops(cfg: ArchConfig, tokens: int) -> float:
    return 2 * tokens * cfg.d_model * cfg.vocab


def analytic_work(cfg: ArchConfig, shape: ShapeConfig, n_devices: int) -> WorkModel:
    B, S = shape.global_batch, shape.seq_len
    act_bytes = 2 if cfg.dtype == "bfloat16" else 4
    n_params = cfg.param_count()
    detail: dict = {}

    if shape.kind in ("train", "prefill"):
        if cfg.frontend == "vision_stub":
            tokens = B * S  # patches + text both flow through the stack
        else:
            tokens = B * S
        proj, score = _attn_flops_train(cfg, tokens, S)
        mix = _mix_flops_other(cfg, tokens)
        chan = _channel_flops(cfg, tokens)
        enc = _enc_flops(cfg, tokens, S)
        head = _xent_flops(cfg, tokens) if shape.kind == "train" else 2 * B * cfg.d_model * cfg.vocab
        fwd = proj + score + mix + chan + enc + (head if shape.kind == "train" else 0)
        if shape.kind == "train":
            # bwd 2x + remat recompute ~1x fwd (checkpointed groups); the
            # xent chunk is also checkpointed (recompute once)
            total = 4 * fwd
            total += 20 * n_params  # adamw update elementwise ops
        else:
            total = fwd + head
        detail = {
            "proj": proj, "score": score, "mix": mix, "channel": chan,
            "encoder": enc, "head": head, "fwd_total": fwd,
        }

        # HBM bytes (per pass): params read (sharded) x (fwd+bwd+remat),
        # layer-boundary activations, optimizer state r/w for train.
        param_bytes_dev = 4 * n_params / n_devices  # f32 master, ZeRO-sharded
        act_boundary = cfg.n_layers * tokens * cfg.d_model * act_bytes * 2 / n_devices
        if shape.kind == "train":
            hbm = 3 * param_bytes_dev + 12 * n_params / n_devices * 2  # grads+opt
            hbm += 3 * act_boundary
        else:
            hbm = param_bytes_dev + 2 * act_boundary
    else:  # decode: one token per row
        tokens = B
        proj, _ = _attn_flops_train(cfg, tokens, 1)
        mix = _mix_flops_other(cfg, tokens)
        chan = _channel_flops(cfg, tokens)
        head = _xent_flops(cfg, tokens)
        # attention against the cache: per attn layer, q·K + p·V over L
        score = 0.0
        cache_bytes = 0.0
        for kind in cfg.layer_types():
            if kind == "attn":
                L = S
            elif kind == "local":
                L = min(cfg.window or S, S)
            else:
                if kind == "ssd":
                    s = cfg.ssm
                    cache_bytes += B * s.n_heads * s.head_dim * s.d_state * 4 * 2
                    score += 2 * B * s.n_heads * s.head_dim * s.d_state * 3
                elif kind == "rglru":
                    cache_bytes += B * cfg.rglru_dim * 4 * 2
                continue
            if cfg.mla is not None:
                m = cfg.mla
                # naive MLA: re-expand K,V from latent for the whole cache
                score += 2 * B * L * m.kv_lora * cfg.n_heads * (m.nope_head_dim + m.v_head_dim)
                score += 2 * B * L * cfg.n_heads * (m.nope_head_dim + m.rope_head_dim + m.v_head_dim)
                cache_bytes += B * L * (m.kv_lora + m.rope_head_dim) * act_bytes
            else:
                score += 2 * B * L * cfg.n_heads * cfg.head_dim * 2
                cache_bytes += B * L * cfg.n_kv_heads * cfg.head_dim * act_bytes * 2
        if cfg.enc_layers:  # whisper cross-attention reads
            score += 2 * B * cfg.cross_attn_len * cfg.n_heads * cfg.head_dim * 2 * cfg.n_layers
            cache_bytes += B * cfg.cross_attn_len * cfg.n_kv_heads * cfg.head_dim * act_bytes * 2 * cfg.n_layers
        total = proj + mix + chan + head + score
        detail = {"proj": proj, "score": score, "mix": mix, "channel": chan,
                  "head": head, "cache_bytes": cache_bytes}
        # decode HBM: every param read once (bf16 compute copy) + cache traffic
        hbm = 2 * n_params / n_devices + cache_bytes / n_devices
        hbm += B * cfg.d_model * act_bytes * 2 * cfg.n_layers / n_devices

    return WorkModel(
        flops=total / n_devices,
        hbm_bytes=hbm,
        detail={k: v / n_devices for k, v in detail.items()},
    )
