"""Target-hardware model: TPU v5e chip constants (per assignment)."""

from __future__ import annotations

import dataclasses

__all__ = ["HW", "V5E"]


@dataclasses.dataclass(frozen=True)
class HW:
    name: str
    peak_bf16_flops: float  # per chip, FLOP/s
    hbm_bw: float  # bytes/s
    ici_link_bw: float  # bytes/s per link
    ici_links: int  # links per chip participating in a collective (2D torus)
    hbm_bytes: float


V5E = HW(
    name="tpu-v5e",
    peak_bf16_flops=197e12,
    hbm_bw=819e9,
    ici_link_bw=50e9,
    ici_links=4,
    hbm_bytes=16e9,
)
