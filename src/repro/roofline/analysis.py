"""Roofline extraction from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), in seconds:

  t_compute    = flops_per_device / peak_bf16_flops
  t_memory     = hbm_bytes_per_device / hbm_bw
  t_collective = Σ_op collective_cost(op) ; ring-model per op:
                 all-gather / reduce-scatter move (n-1)/n of the *global*
                 tensor bytes through each device's links; all-reduce costs
                 2x reduce-scatter; all-to-all moves (n-1)/n of the local
                 shard; collective-permute moves the operand once.

``compiled.cost_analysis()`` on the SPMD-partitioned module reports
*per-device* flops / bytes (verified in tests), matching the per-device
formulation above (equivalent to the global/(chips·peak) form for balanced
shards). Collective operands are parsed from the optimized HLO text
(per-shard shapes); replica-group sizes come from the op's replica_groups
attribute.
"""

from __future__ import annotations

import dataclasses
import math
import re

from .hw import HW, V5E

__all__ = ["CollectiveOp", "parse_collectives", "roofline_terms", "RooflineReport"]

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_KINDS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# e.g.:  %all-gather.3 = bf16[16,512,8192]{2,1,0} all-gather(%param.5), ...
_OP_RE = re.compile(
    r"=\s*(?:\()?([a-z0-9]+)\[([\d,]*)\][^\s]*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
# computation headers: "%name (params...) -> type {" — params may nest parens
_COMPUTATION_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_WHILE_RE = re.compile(r"while\(.*?\),\s*condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CONST_RE = re.compile(r"=\s*s32\[\]\s*constant\((\d+)\)")


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    dtype: str
    shape: tuple[int, ...]
    group_size: int
    trip_mult: int = 1  # product of enclosing while-loop trip counts

    @property
    def bytes(self) -> int:
        n = 1
        for d in self.shape:
            n *= d
        return n * _DTYPE_BYTES.get(self.dtype, 4)


def _split_computations(hlo_text: str) -> dict[str, list[str]]:
    """Split optimized HLO into {computation_name: lines}."""
    comps: dict[str, list[str]] = {}
    cur: str | None = None
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = _COMPUTATION_RE.match(stripped)
        if m and stripped.endswith("{"):
            cur = m.group(1)
            comps[cur] = []
            continue
        if stripped.startswith("}"):
            cur = None
            continue
        if cur is not None:
            comps[cur].append(stripped)
    return comps


def _trip_count(cond_lines: list[str]) -> int:
    """Scan-loop conditions compare the counter against a constant bound."""
    consts = [int(m.group(1)) for ln in cond_lines for m in _CONST_RE.finditer(ln)]
    return max(consts) if consts else 1


def _multipliers(comps: dict[str, list[str]]) -> dict[str, int]:
    """Effective execution multiplier per computation (ENTRY = 1; while
    bodies multiply by their trip count; call/conditional multiply by 1)."""
    entry = None
    for name in comps:
        if "main" in name:
            entry = name
            break
    if entry is None and comps:
        entry = next(iter(comps))
    mult: dict[str, int] = {name: 0 for name in comps}
    if entry is None:
        return mult
    mult[entry] = 1
    # iterate to fixpoint (call graph is a DAG; few levels of nesting)
    for _ in range(12):
        changed = False
        for name, lines in comps.items():
            m = mult.get(name, 0)
            if m == 0:
                continue
            for ln in lines:
                wm = _WHILE_RE.search(ln)
                if wm:
                    cond, body = wm.group(1), wm.group(2)
                    trips = _trip_count(comps.get(cond, []))
                    for target in (body, cond):
                        new = m * trips if target == body else m
                        if target in mult and mult[target] < new:
                            mult[target] = new
                            changed = True
                for ref in re.finditer(r"(?:calls=|to_apply=|call\()\%?([\w.\-]+)", ln):
                    target = ref.group(1)
                    if target in mult and mult[target] < m:
                        mult[target] = m
                        changed = True
        if not changed:
            break
    return mult


def parse_collectives(hlo_text: str) -> list[CollectiveOp]:
    """Extract collective ops (per-shard output shapes) from optimized HLO,
    with while-loop trip-count multipliers (scan bodies execute trip times;
    a naive line scan would count them once)."""
    comps = _split_computations(hlo_text)
    if not comps:  # fall back: treat whole text as one computation
        comps = {"main": hlo_text.splitlines()}
    mults = _multipliers(comps)
    ops = []
    for name, lines in comps.items():
        m = mults.get(name, 1) or 1
        for line in lines:
            if not any(k in line for k in _COLL_KINDS):
                continue
            om = _OP_RE.search(line)
            if not om:
                continue
            dtype, dims, kind = om.group(1), om.group(2), om.group(3)
            shape = tuple(int(x) for x in dims.split(",") if x) if dims else ()
            gs = 1
            gm = _GROUPS_RE.search(line)
            if gm:
                gs = int(gm.group(2))  # [n_groups, group_size]
            else:
                gl = _GROUPS_LIST_RE.search(line)
                if gl:
                    gs = len([x for x in gl.group(1).split(",") if x.strip() != ""])
            ops.append(
                CollectiveOp(kind=kind, dtype=dtype, shape=shape, group_size=gs,
                             trip_mult=m)
            )
    return ops


def collective_seconds(ops: list[CollectiveOp], hw: HW = V5E) -> tuple[float, int]:
    """Ring-model serialization time and total wire bytes per device."""
    total_t = 0.0
    total_bytes = 0
    bw = hw.ici_link_bw * hw.ici_links
    for op in ops:
        n = max(op.group_size, 1)
        if n == 1:
            continue
        frac = (n - 1) / n
        if op.kind == "all-gather":
            # output is the gathered (global) tensor per shard
            wire = op.bytes * frac
        elif op.kind == "reduce-scatter":
            # output is the scattered shard; global = bytes * n
            wire = op.bytes * n * frac
        elif op.kind == "all-reduce":
            # reduce-scatter + all-gather over the same (per-shard) tensor
            wire = 2 * op.bytes * frac
        elif op.kind == "all-to-all":
            wire = op.bytes * frac
        else:  # collective-permute
            wire = op.bytes
        wire *= op.trip_mult
        total_t += wire / bw
        total_bytes += int(wire)
    return total_t, total_bytes


@dataclasses.dataclass
class RooflineReport:
    flops_per_dev: float
    hbm_bytes_per_dev: float
    collective_bytes_per_dev: int
    t_compute: float
    t_memory: float
    t_collective: float
    n_collectives: int
    model_flops: float = 0.0
    raw_flops: float = 0.0  # cost_analysis (scan bodies counted once)
    raw_bytes: float = 0.0

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """No-overlap upper bound used as the conservative roof."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the chip's peak the dominant-term-bound step achieves
        IF the model flops were run at peak: model_flops_time / step_time."""
        if self.step_time == 0:
            return 0.0
        return min(1.0, (self.model_flops / max(self.flops_per_dev, 1)) * self.t_compute / self.step_time)

    def to_dict(self) -> dict:
        return {
            "flops_per_dev": self.flops_per_dev,
            "hbm_bytes_per_dev": self.hbm_bytes_per_dev,
            "collective_bytes_per_dev": self.collective_bytes_per_dev,
            "t_compute": self.t_compute,
            "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "n_collectives": self.n_collectives,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_flops_ratio": (
                self.model_flops / self.flops_per_dev if self.flops_per_dev else 0.0
            ),
            "raw_cost_analysis_flops": self.raw_flops,
            "raw_cost_analysis_bytes": self.raw_bytes,
        }


def roofline_terms(
    cost: dict,
    hlo_text: str,
    hw: HW = V5E,
    model_flops_per_dev: float = 0.0,
    analytic=None,
) -> RooflineReport:
    """Three-term roofline. ``analytic`` (a ``WorkModel``) supplies
    trip-count-correct flops/bytes; the raw ``cost_analysis`` numbers (which
    count scan bodies once — see module docstring of roofline.analytic) are
    retained in ``raw_*`` fields for reference."""
    raw_flops = float(cost.get("flops", 0.0) or 0.0)
    raw_bytes = float(cost.get("bytes accessed", 0.0) or 0.0)
    flops = analytic.flops if analytic is not None else raw_flops
    bytes_acc = analytic.hbm_bytes if analytic is not None else raw_bytes
    ops = parse_collectives(hlo_text)
    t_coll, wire_bytes = collective_seconds(ops, hw)
    rep = RooflineReport(
        flops_per_dev=flops,
        hbm_bytes_per_dev=bytes_acc,
        collective_bytes_per_dev=wire_bytes,
        t_compute=flops / hw.peak_bf16_flops,
        t_memory=bytes_acc / hw.hbm_bw,
        t_collective=t_coll,
        n_collectives=len(ops),
        model_flops=model_flops_per_dev,
    )
    rep.raw_flops = raw_flops
    rep.raw_bytes = raw_bytes
    return rep
