"""GPipe-style pipeline parallelism over a ``stage`` mesh axis.

At 1000+ node scale, DP×TP alone runs out of useful width (TP is limited by
head/ff divisibility and ICI reach); a pipeline axis multiplies the usable
node count. This module implements the schedule as pure JAX under
``shard_map``:

  * layers are divided into S stages; stage s holds its layer slice
    (parameters sharded over the ``stage`` axis);
  * a microbatch stream of M chunks flows through the stages with
    ``collective_permute`` boundary transfers (ring neighbours);
  * the steady-state schedule is the classic GPipe loop of S + M - 1 ticks —
    each device computes its stage on tick t's resident microbatch, so
    bubble fraction = (S-1)/(S+M-1).

The forward here is a self-contained stage function (norm + MLP block) —
the production wiring would pass the model's group body; tests validate the
pipeline against the sequential execution of the same stage stack
(``tests/test_pipeline.py``) and the dry-run checks the schedule lowers on a
(stage, data) mesh.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

__all__ = ["pipeline_forward", "bubble_fraction"]


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    return (n_stages - 1) / (n_stages + n_micro - 1)


def pipeline_forward(
    mesh: Mesh,
    stage_fn,
    *,
    stage_axis: str = "stage",
    n_micro: int,
):
    """Build a pipelined forward: (stage_params, x) -> y.

    stage_params: pytree with leading dim = n_stages (sharded over stage_axis).
    x: (n_micro * micro_b, ...) batch, split into microbatches.
    stage_fn(params_slice, xb) -> yb must be shape-preserving.
    """
    n_stages = mesh.shape[stage_axis]
    axis_idx = lambda: jax.lax.axis_index(stage_axis)

    def pipelined(stage_params, x):
        # inside shard_map: stage_params has leading dim 1 (this stage's slice)
        params_here = jax.tree.map(lambda a: a[0], stage_params)
        micro = x.reshape(n_micro, x.shape[0] // n_micro, *x.shape[1:])
        sid = axis_idx()
        perm_fwd = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        n_ticks = n_stages + n_micro - 1
        buf = jnp.zeros_like(micro[0])  # resident microbatch
        outputs = jnp.zeros_like(micro)

        def tick(carry, t):
            buf, outputs = carry
            # stage 0 ingests microbatch t (while t < n_micro)
            ingest = jnp.where(t < n_micro, jnp.clip(t, 0, n_micro - 1), 0)
            incoming = micro[ingest]
            buf = jnp.where(sid == 0, jnp.where(t < n_micro, incoming, buf), buf)
            # compute this stage on the resident microbatch
            y = stage_fn(params_here, buf)
            # last stage emits microbatch (t - (S-1)) when valid
            emit_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            valid_emit = (t >= n_stages - 1) & (t - (n_stages - 1) < n_micro)
            outputs = jnp.where(
                (sid == n_stages - 1) & valid_emit,
                outputs.at[emit_idx].set(y),
                outputs,
            )
            # shift activations to the next stage (ring; stage S-1 -> 0 ignored)
            buf = jax.lax.ppermute(y, stage_axis, perm_fwd)
            return (buf, outputs), None

        (buf, outputs), _ = jax.lax.scan(tick, (buf, outputs), jnp.arange(n_ticks))
        # only the last stage holds real outputs; broadcast via psum of masked
        outputs = jnp.where(sid == n_stages - 1, outputs, jnp.zeros_like(outputs))
        outputs = jax.lax.psum(outputs, stage_axis)
        return outputs.reshape(x.shape[0], *x.shape[1:])

    return shard_map(
        pipelined,
        mesh=mesh,
        in_specs=(P(stage_axis), P()),
        out_specs=P(),
        check_rep=False,
    )
