"""Fault-tolerant checkpointing for mining and training.

Design for 1000+ nodes:
  * **atomicity** — state is written to a temp directory and renamed into
    place; a manifest (`manifest.json`) is the commit record and is written
    last. A crash mid-write leaves the previous checkpoint intact.
  * **async** — `save(..., blocking=False)` hands the serialized state to a
    background thread so the training/mining loop is not stalled by IO
    (double-buffered: at most one outstanding write; the next save joins it).
  * **retention** — keeps the last `keep` checkpoints, pruning older ones.
  * **elasticity** — state is stored logically (full arrays / host numpy),
    not per-device, so a restart may use a different mesh; the sharding
    planner re-distributes on load. (At true 1000-node scale one would write
    per-host shards; the manifest format has a `shards` field reserved for
    that layout.)
  * **integrity** — every array records shape/dtype + a CRC32 in the
    manifest; `load` verifies before handing state back.

State is a pytree of numpy/jax arrays + JSON-able leaves.
"""

from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
import time
import zlib

import numpy as np

__all__ = ["CheckpointManager", "save_pytree", "load_pytree"]


def _flatten(prefix: str, obj, out: dict):
    if isinstance(obj, dict):
        for k in sorted(obj):
            _flatten(f"{prefix}.{k}" if prefix else str(k), obj[k], out)
    elif isinstance(obj, (list, tuple)):
        out[f"{prefix}#type"] = "list" if isinstance(obj, list) else "tuple"
        for i, v in enumerate(obj):
            _flatten(f"{prefix}.{i}", v, out)
    else:
        out[prefix] = obj


def save_pytree(path: str, tree, extra_meta: dict | None = None) -> None:
    """Atomic write of a pytree of arrays/scalars to ``path`` (a directory)."""
    flat: dict = {}
    _flatten("", tree, flat)
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    manifest = {"arrays": {}, "scalars": {}, "meta": extra_meta or {}, "time": time.time()}
    arrays = {}
    for key, val in flat.items():
        if key.endswith("#type"):
            manifest["scalars"][key] = val
            continue
        if hasattr(val, "shape") and hasattr(val, "dtype"):
            arr = np.asarray(val)
            arrays[key] = arr
            manifest["arrays"][key] = {
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "crc32": zlib.crc32(arr.tobytes()),
            }
        else:
            manifest["scalars"][key] = val
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)


def _unflatten(flat_arrays: dict, flat_scalars: dict):
    tree: dict = {}
    types = {k[: -len("#type")]: v for k, v in flat_scalars.items() if k.endswith("#type")}
    items = {**flat_arrays, **{k: v for k, v in flat_scalars.items() if not k.endswith("#type")}}
    for key, val in items.items():
        parts = key.split(".")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val

    def fix(node, prefix=""):
        if isinstance(node, dict):
            keys = list(node.keys())
            fixed = {k: fix(node[k], f"{prefix}.{k}" if prefix else k) for k in keys}
            t = types.get(prefix)
            if t in ("list", "tuple"):
                seq = [fixed[str(i)] for i in range(len(fixed))]
                return seq if t == "list" else tuple(seq)
            return fixed
        return node

    return fix(tree)


def load_pytree(path: str, verify: bool = True):
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(path, "arrays.npz")) as z:
        arrays = {k: z[k] for k in z.files}
    if verify:
        for k, meta in manifest["arrays"].items():
            arr = arrays[k]
            if list(arr.shape) != meta["shape"] or str(arr.dtype) != meta["dtype"]:
                raise IOError(f"checkpoint corrupt: {k} shape/dtype mismatch")
            if zlib.crc32(arr.tobytes()) != meta["crc32"]:
                raise IOError(f"checkpoint corrupt: {k} CRC mismatch")
    return _unflatten(arrays, manifest["scalars"]), manifest["meta"]


@dataclasses.dataclass
class CheckpointManager:
    """Step/level-indexed checkpoints with retention and async writes."""

    directory: str
    keep: int = 3

    def __post_init__(self):
        os.makedirs(self.directory, exist_ok=True)
        self._pending: threading.Thread | None = None

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"ckpt_{step:010d}")

    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.directory):
            if (
                name.startswith("ckpt_")
                and not name.endswith(".tmp")
                and not name.endswith(".corrupt")
            ):
                if os.path.exists(os.path.join(self.directory, name, "manifest.json")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def save(self, step: int, tree, meta: dict | None = None, blocking: bool = True) -> None:
        meta = dict(meta or {}, step=step)
        self.wait()
        # snapshot arrays on the caller's thread (cheap host copies) so the
        # async writer never races live buffers
        if not blocking:
            def work():
                save_pytree(self._step_dir(step), tree, meta)
                self._prune()

            self._pending = threading.Thread(target=work, daemon=True)
            self._pending.start()
        else:
            save_pytree(self._step_dir(step), tree, meta)
            self._prune()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def restore(self, step: int | None = None):
        """Load a checkpoint. With an explicit ``step``, corruption raises.
        With ``step=None`` (latest), a corrupt/truncated newest checkpoint is
        quarantined (renamed ``*.corrupt``) and restore falls back to the
        next older intact one — a crash mid-write of a non-atomic filesystem,
        or a torn disk, costs one checkpoint interval, never the run."""
        self.wait()
        if step is not None:
            return load_pytree(self._step_dir(step))
        for s in reversed(self.steps()):
            path = self._step_dir(s)
            try:
                return load_pytree(path)
            except Exception:
                quarantine = path + ".corrupt"
                shutil.rmtree(quarantine, ignore_errors=True)
                try:
                    os.rename(path, quarantine)
                except OSError:
                    shutil.rmtree(path, ignore_errors=True)
        return None, None

    def destroy(self) -> None:
        """Remove the whole checkpoint directory (e.g. a completed mining
        job whose resume states are no longer needed)."""
        self.wait()
        shutil.rmtree(self.directory, ignore_errors=True)

    def _prune(self) -> None:
        steps = self.steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
