"""Elastic re-partitioning: resume work on a different mesh than it was
checkpointed from.

Checkpoints store *logical* (full) arrays (see ``checkpoint.py``), so
elasticity reduces to re-distributing on load: ``redistribute`` places a
restored pytree onto a new mesh with the plan's shardings; for the miner,
``rebalance_pairs`` re-blocks the pair stream to the new shard count at the
next level boundary. A node-failure drill (kill -> restart on a smaller
mesh -> identical results) is exercised in tests/test_elastic.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .sharding import Plan

__all__ = ["redistribute", "mesh_fingerprint"]


def mesh_fingerprint(mesh) -> dict:
    return {"shape": dict(zip(mesh.axis_names, mesh.devices.shape)),
            "n_devices": int(mesh.devices.size)}


def redistribute(tree, plan: Plan, kind: str = "params"):
    """Place a host/logical pytree onto ``plan.mesh`` with planner shardings.

    kind: params | batch | cache — selects the planner rule family.
    """
    if kind == "params":
        shardings = plan.param_shardings(jax.tree.map(jnp.asarray, tree))
    elif kind == "batch":
        shardings = plan.batch_shardings(jax.tree.map(jnp.asarray, tree))
    elif kind == "cache":
        shardings = plan.cache_shardings(jax.tree.map(jnp.asarray, tree))
    else:
        raise ValueError(kind)
    return jax.tree.map(
        lambda a, s: jax.device_put(jnp.asarray(a), s), tree, shardings
    )
