"""Sharding planner: maps every parameter / batch / cache leaf to a
PartitionSpec given the mesh, with per-dim divisibility fallback.

Logical rules (MaxText-style, adapted to this zoo's param naming):

  * "in" matrices  (D, X)  — wq wk wv w_gate w_up w_in router w_dkv sh_* w_a
    w_x lm_head:            P(fsdp, tp)   (X = heads*hd / ff / vocab ...)
  * "out" matrices (X, D)  — wo w_out w_down sh_down w_uk w_uv:
                             P(tp, fsdp)
  * embedding (V, D):       P(tp, fsdp)   (vocab on tensor axis)
  * expert tensors (E, D, F) / (E, F, D): expert dim on tp (EP), D on fsdp
  * 1-D biases (X,):        P(tp);  norms / scalars: replicated
  * conv (K, C):            P(None, tp)
  * stacked layer params (leading n_groups / n_layers dim): same rule with a
    leading None.

``fsdp`` = the data axes (ZeRO-style weight sharding over DP); any dim not
divisible by its assigned axes falls back to replicated for that dim — the
planner records these fallbacks so the dry-run can report them.

Batch: leading batch dim over dp. Caches: KV-head dim on tp when divisible,
else head_dim on tp, else sequence on tp (the fallback chain keeps big decode
caches distributed even when n_kv < |tp|, e.g. MQA).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.tree_util import DictKey, SequenceKey

from ..models.layers.common import ShardCtx

__all__ = ["Plan", "make_plan"]

_IN_MATS = {
    "wq", "wk", "wv", "w_gate", "w_up", "w_in", "router", "w_dkv",
    "sh_gate", "sh_up", "w_a", "w_x", "lm_head",
}
_OUT_MATS = {"wo", "w_out", "w_down", "sh_down", "w_uk", "w_uv"}
_STACKED_MARKERS = {"groups", "enc_layers", "dec_layers"}


def _path_names(path) -> list[str]:
    names = []
    for k in path:
        if isinstance(k, DictKey):
            names.append(str(k.key))
        elif isinstance(k, SequenceKey):
            names.append(f"[{k.idx}]")
        else:
            names.append(str(k))
    return names


@dataclasses.dataclass
class Plan:
    mesh: Mesh
    dp: tuple[str, ...]
    tp: str
    fallbacks: list[str] = dataclasses.field(default_factory=list)
    # serve mode: weights are inference-only (bf16, no optimizer state), so
    # the FSDP dim is dropped (dp -> replicated) whenever the model fits —
    # removing the per-step weight all-gathers that otherwise dominate the
    # decode collective term (EXPERIMENTS.md §Perf, recurrentgemma decode).
    serve: bool = False

    # -- helpers -----------------------------------------------------------
    def _size(self, axes) -> int:
        if axes is None:
            return 1
        axes = (axes,) if isinstance(axes, str) else axes
        n = 1
        for a in axes:
            n *= self.mesh.shape[a]
        return n

    def _fit(self, dim: int, axes, leaf: str, dim_idx: int):
        if axes is None:
            return None
        if dim % self._size(axes) == 0:
            return axes
        self.fallbacks.append(f"{leaf}[dim{dim_idx}]={dim} !% {axes}")
        return None

    def ctx(self) -> ShardCtx:
        return ShardCtx(mesh=self.mesh, dp=self.dp, tp=self.tp)

    # -- parameters --------------------------------------------------------
    def param_spec(self, path, leaf) -> P:
        names = _path_names(path)
        name = names[-1]
        stacked = any(m in names for m in _STACKED_MARKERS)
        shape = leaf.shape
        core = shape[1:] if stacked else shape
        spec = self._param_core_spec(name, core)
        if stacked:
            spec = (None,) + spec
        return P(*spec)

    def _param_core_spec(self, name: str, shape) -> tuple:
        nd = len(shape)
        lbl = name
        if self.serve:
            spec = self._param_core_spec_train(name, shape)
            return tuple(None if s == self.dp or s == tuple(self.dp) else s for s in spec)
        return self._param_core_spec_train(name, shape)

    def _param_core_spec_train(self, name: str, shape) -> tuple:
        nd = len(shape)
        lbl = name
        if nd == 0:
            return ()
        if nd == 1:
            if name in ("norm1", "norm2", "norm_x", "final_norm", "enc_norm",
                        "kv_norm", "gate_norm", "lam", "A_log", "D", "dt_bias",
                        "b_a", "b_x"):
                return (None,)
            return (self._fit(shape[0], self.tp, lbl, 0),)
        if nd == 2:
            if name == "embedding":  # (V, D)
                return (
                    self._fit(shape[0], self.tp, lbl, 0),
                    self._fit(shape[1], self.dp, lbl, 1),
                )
            if name == "conv_w":  # (K, C)
                return (None, self._fit(shape[1], self.tp, lbl, 1))
            if name in _OUT_MATS:  # (X, D)
                return (
                    self._fit(shape[0], self.tp, lbl, 0),
                    self._fit(shape[1], self.dp, lbl, 1),
                )
            # default "in" matrix (D, X)
            return (
                self._fit(shape[0], self.dp, lbl, 0),
                self._fit(shape[1], self.tp, lbl, 1),
            )
        if nd == 3:  # experts (E, D, F) or (E, F, D)
            if name in _OUT_MATS:
                return (
                    self._fit(shape[0], self.tp, lbl, 0),
                    None,
                    self._fit(shape[2], self.dp, lbl, 2),
                )
            return (
                self._fit(shape[0], self.tp, lbl, 0),
                self._fit(shape[1], self.dp, lbl, 1),
                None,
            )
        return tuple([None] * nd)

    def param_shardings(self, abstract_params):
        return jax.tree_util.tree_map_with_path(
            lambda p, l: NamedSharding(self.mesh, self.param_spec(p, l)),
            abstract_params,
        )

    # -- batches -----------------------------------------------------------
    def batch_spec(self, path, leaf) -> P:
        shape = leaf.shape
        lbl = _path_names(path)[-1]
        first = self._fit(shape[0], self.dp, lbl, 0)
        return P(*((first,) + (None,) * (len(shape) - 1)))

    def batch_shardings(self, abstract_batch):
        return jax.tree_util.tree_map_with_path(
            lambda p, l: NamedSharding(self.mesh, self.batch_spec(p, l)),
            abstract_batch,
        )

    # -- caches ------------------------------------------------------------
    def cache_spec(self, path, leaf) -> P:
        names = _path_names(path)
        name = names[-1]
        shape = leaf.shape
        # stacked (leading layer dim) is detected by rank: each state kind has
        # a fixed core rank; +1 means a stacked layer axis (scan layout).
        core_rank = {"k": 4, "v": 4, "c_kv": 3, "k_rope": 3, "state": 4,
                     "h": 2, "conv": 3}.get(name, len(shape))
        stacked = len(shape) == core_rank + 1
        core = shape[1:] if stacked else shape
        spec: list = []
        if name in ("k", "v"):  # (B, L, KV, hd)
            b, L, kv, hd = core
            spec = [self._fit(b, self.dp, name, 0), None, None, None]
            if kv % self._size(self.tp) == 0:
                spec[2] = self.tp
            elif hd % self._size(self.tp) == 0:
                spec[3] = self.tp
            elif L % self._size(self.tp) == 0:
                spec[1] = self.tp  # sequence-sharded KV (MQA / long context)
        elif name in ("c_kv", "k_rope"):  # (B, L, R)
            b, L, r = core
            spec = [self._fit(b, self.dp, name, 0), None, self._fit(r, self.tp, name, 2)]
            if spec[2] is None and L % self._size(self.tp) == 0:
                spec[1] = self.tp
        elif name == "state":  # ssd (B, H, P, N)
            b, h, pdim, n = core
            spec = [self._fit(b, self.dp, name, 0), self._fit(h, self.tp, name, 1), None, None]
        elif name == "h":  # rglru (B, R)
            b, r = core
            spec = [self._fit(b, self.dp, name, 0), self._fit(r, self.tp, name, 1)]
        elif name == "conv":  # (B, K-1, C)
            b, kk, c = core
            spec = [self._fit(b, self.dp, name, 0), None, self._fit(c, self.tp, name, 2)]
        else:
            spec = [self._fit(core[0], self.dp, name, 0)] + [None] * (len(core) - 1)
        if stacked:
            spec = [None] + spec
        return P(*spec)

    def cache_shardings(self, abstract_cache):
        return jax.tree_util.tree_map_with_path(
            lambda p, l: NamedSharding(self.mesh, self.cache_spec(p, l)),
            abstract_cache,
        )

    def replicated(self):
        return NamedSharding(self.mesh, P())


def make_plan(mesh: Mesh, multi_pod: bool | None = None, serve: bool = False) -> Plan:
    """Build the plan from mesh axis names ((pod,)data,model)."""
    names = mesh.axis_names
    if "model" not in names:
        raise ValueError(f"mesh must have a 'model' axis, got {names}")
    dp = tuple(a for a in names if a != "model")
    return Plan(mesh=mesh, dp=dp, tp="model", serve=serve)
