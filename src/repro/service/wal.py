"""Durability layer under :class:`repro.service.store.DatasetStore`.

Two pieces:

:class:`WriteAheadLog`
    An append-only log of CRC-framed, fsync'd records. Each ``/append``
    block is logged *before* it is itemized into the in-memory store, so an
    acknowledged append survives a crash. Replay walks the longest valid
    prefix — a torn final frame (power cut mid-write) is detected by its
    CRC/length and truncated away, never propagated.

:class:`DurableStore`
    Owns the :class:`DatasetStore` plus its WAL and periodic snapshots.
    Every ``snapshot_every`` appends the full store state
    (:meth:`DatasetStore.export_state`) is folded into an atomic
    :class:`~repro.distributed.checkpoint.CheckpointManager` checkpoint and
    the WAL is reset, bounding both replay time and log size.
    :meth:`DurableStore.recover` rebuilds the store bit-identically —
    same item ids, bitsets, version watermarks — from the newest intact
    snapshot plus an idempotent WAL replay.

The frame format is ``KWAL | crc32(payload) | len(payload) | payload``
with the payload a pickled ``{"version": v, "rows": ndarray}`` dict.
Version numbers make replay idempotent: records at or below the snapshot's
version are skipped, so a crash *between* snapshot and WAL reset cannot
double-apply a block.

What fsync buys (and doesn't): an acknowledged append survives process
death and OS crash on a journaling filesystem; it does not survive the
disk itself lying about flushes, and the final un-acked frame may be torn
— recovery drops it, which is exactly the client-visible contract (no ack,
no append).
"""

from __future__ import annotations

import os
import pickle
import struct
import threading
import time
import zlib

import numpy as np

from ..distributed.checkpoint import CheckpointManager
from ..obs import metrics as _om
from ..obs.trace import span as _obs_span
from .faults import NULL_INJECTOR, FaultInjector
from .store import DatasetStore

__all__ = ["WriteAheadLog", "DurableStore"]

_WAL_APPENDS = _om.counter(
    "repro_wal_appends_total", "Durably fsync'd WAL frames."
)
_WAL_BYTES = _om.counter(
    "repro_wal_bytes_written_total", "WAL frame bytes written (incl. header)."
)
_WAL_FSYNC = _om.histogram(
    "repro_wal_append_seconds", "Frame+fsync latency of one WAL append."
)
_WAL_TRUNCATED = _om.counter(
    "repro_wal_truncated_bytes_total",
    "Torn-tail bytes dropped during WAL replay.",
)
_SNAPSHOTS = _om.counter(
    "repro_store_snapshots_total", "Durable store snapshots taken."
)
_SNAPSHOT_SECONDS = _om.histogram(
    "repro_store_snapshot_seconds", "Snapshot (export+checkpoint+reset) time."
)
_RECOVERIES = _om.counter(
    "repro_store_recoveries_total", "Durable store recoveries completed."
)
_REPLAYED = _om.counter(
    "repro_wal_records_replayed_total",
    "WAL records re-applied during recovery.",
)

MAGIC = b"KWAL"
_HEADER = struct.Struct("<4sII")  # magic, crc32(payload), len(payload)


class WriteAheadLog:
    """CRC-framed fsync'd append log with torn-tail recovery."""

    def __init__(self, path: str, injector: FaultInjector = NULL_INJECTOR):
        self.path = path
        self.injector = injector
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self._lock = threading.Lock()
        self._fh = open(path, "ab")
        self.appended = 0
        self.truncated_bytes = 0

    def append(self, record: dict) -> None:
        """Frame, write, fsync. Returns only once the record is durable."""
        payload = pickle.dumps(record, protocol=pickle.HIGHEST_PROTOCOL)
        frame = _HEADER.pack(MAGIC, zlib.crc32(payload), len(payload)) + payload
        t0 = time.perf_counter()
        with self._lock, _obs_span("wal.append", bytes=len(frame)):
            action = self.injector.check("wal.append")
            if action == "partial":
                # simulate a power cut mid-write: half the frame reaches the
                # platter, then the process dies
                self._fh.write(frame[: len(frame) // 2])
                self._fh.flush()
                os.fsync(self._fh.fileno())
                from .faults import KillPoint

                raise KillPoint("wal.append:partial")
            self._fh.write(frame)
            self._fh.flush()
            os.fsync(self._fh.fileno())
            self.appended += 1
        # durable appends only — a simulated torn write never acks, so it
        # never counts (the client-visible contract the metrics mirror)
        _WAL_APPENDS.inc()
        _WAL_BYTES.inc(len(frame))
        _WAL_FSYNC.observe(time.perf_counter() - t0)

    def replay(self) -> list[dict]:
        """Decode the longest valid prefix; a corrupt/truncated tail is
        truncated off the file (it was never acknowledged)."""
        records: list[dict] = []
        good_end = 0
        with self._lock:
            self._fh.flush()
            with open(self.path, "rb") as f:
                data = f.read()
            off = 0
            while off + _HEADER.size <= len(data):
                magic, crc, length = _HEADER.unpack_from(data, off)
                body = data[off + _HEADER.size : off + _HEADER.size + length]
                if magic != MAGIC or len(body) < length or zlib.crc32(body) != crc:
                    break
                try:
                    records.append(pickle.loads(body))
                except Exception:
                    break
                off += _HEADER.size + length
                good_end = off
            self.truncated_bytes = len(data) - good_end
            if self.truncated_bytes:
                _WAL_TRUNCATED.inc(self.truncated_bytes)
                self._truncate_locked(good_end)
        return records

    def _truncate_locked(self, size: int) -> None:
        self._fh.close()
        with open(self.path, "r+b") as f:
            f.truncate(size)
        self._fh = open(self.path, "ab")

    def reset(self) -> None:
        """Drop all records (they were folded into a snapshot)."""
        with self._lock:
            self._truncate_locked(0)

    def size(self) -> int:
        with self._lock:
            self._fh.flush()
            return os.path.getsize(self.path)

    def close(self) -> None:
        with self._lock:
            self._fh.close()


class DurableStore:
    """A :class:`DatasetStore` that survives process death.

    Appends are WAL-logged before itemization; every ``snapshot_every``
    appends the store state is checkpointed and the WAL reset. A fresh
    ``DurableStore`` over the same directory + :meth:`recover` yields a
    store observably identical to the pre-crash one at its last
    acknowledged version.
    """

    def __init__(
        self,
        directory: str,
        *,
        placement=None,
        snapshot_every: int = 8,
        injector: FaultInjector = NULL_INJECTOR,
        recorder=None,
        **store_kw,
    ):
        self.directory = directory
        os.makedirs(directory, exist_ok=True)
        self.placement = placement
        self.snapshot_every = max(1, int(snapshot_every))
        self.injector = injector
        # optional FlightRecorder: snapshot/recover milestones go into the
        # crash-persistent ring (durable kinds — fsync'd inline)
        self.recorder = recorder
        self._store_kw = dict(store_kw)
        self._store_kw["placement"] = placement
        self.wal = WriteAheadLog(os.path.join(directory, "wal.log"), injector)
        self.snapshots = CheckpointManager(
            os.path.join(directory, "snapshots"), keep=2
        )
        self.store: DatasetStore | None = None
        self._since_snapshot = 0
        self.snapshots_taken = 0
        self._lock = threading.RLock()

    def _ensure_store(self, n_cols: int) -> DatasetStore:
        if self.store is None:
            self.store = DatasetStore(n_cols, **self._store_kw)
        return self.store

    def append(self, rows: np.ndarray) -> int:
        """Durably append a block: WAL first, then itemize. The version
        returned is only handed back (acknowledged) once the record is on
        disk; a crash between the two leaves the WAL ahead of the store and
        replay closes the gap."""
        rows = np.ascontiguousarray(np.asarray(rows, dtype=np.int64))
        with self._lock:
            store = self._ensure_store(rows.shape[1])
            self.wal.append({"version": store.version + 1, "rows": rows})
            version = store.append(rows)
            self._since_snapshot += 1
            if self._since_snapshot >= self.snapshot_every:
                self.snapshot()
        return version

    def snapshot(self) -> int | None:
        """Fold store state into an atomic checkpoint and reset the WAL.
        Order matters: the snapshot commits (atomic rename) *before* the
        WAL resets, so a crash in between merely replays records the
        snapshot already holds — replay skips them by version."""
        t0 = time.perf_counter()
        with self._lock, _obs_span("store.snapshot"):
            if self.store is None:
                return None
            state = self.store.export_state()
            self.snapshots.save(
                self.store.version,
                state,
                meta={"kind": "dataset_store"},
                blocking=True,
            )
            self.wal.reset()
            self._since_snapshot = 0
            self.snapshots_taken += 1
            version = self.store.version
        # metrics outside the store lock: scrape collectors read stats()
        # under the registry lock (reverse acquisition order)
        _SNAPSHOTS.inc()
        _SNAPSHOT_SECONDS.observe(time.perf_counter() - t0)
        if self.recorder is not None:
            self.recorder.record("store.snapshot", version=version)
        return version

    def recover(self) -> dict:
        """Rebuild the store from newest intact snapshot + WAL replay.

        Returns an info dict (snapshot version, records replayed/skipped,
        torn-tail bytes truncated) for ``/stats`` and logs.
        """
        with self._lock, _obs_span("store.recover"):
            state, _meta = self.snapshots.restore()
            snapshot_version = 0
            if state is not None:
                self.store = DatasetStore.from_state(
                    state,
                    placement=self.placement,
                    compact_threshold=self._store_kw.get("compact_threshold"),
                    keep_versions=self._store_kw.get("keep_versions", 8),
                    # configured process shard: local stripes must be
                    # recovered by the process that wrote them
                    shard=self._store_kw.get("shard"),
                )
                snapshot_version = self.store.version
            replayed = skipped = 0
            for record in self.wal.replay():
                rows = np.asarray(record["rows"], dtype=np.int64)
                store = self._ensure_store(rows.shape[1])
                if record["version"] <= store.version:
                    skipped += 1
                    continue
                got = store.append(rows)
                if got != record["version"]:
                    raise IOError(
                        f"WAL replay divergence: expected version "
                        f"{record['version']}, store produced {got}"
                    )
                replayed += 1
            self._since_snapshot = replayed
            _RECOVERIES.inc()
            _REPLAYED.inc(replayed)
            info = {
                "snapshot_version": snapshot_version,
                "replayed": replayed,
                "skipped": skipped,
                "truncated_bytes": self.wal.truncated_bytes,
                "version": self.store.version if self.store is not None else 0,
            }
            if self.recorder is not None:
                self.recorder.record("store.recover", **info)
            return info

    def stats(self) -> dict:
        with self._lock:
            return {
                "directory": self.directory,
                "wal_bytes": self.wal.size(),
                "wal_appends": self.wal.appended,
                "snapshot_every": self.snapshot_every,
                "snapshots_taken": self.snapshots_taken,
                "since_snapshot": self._since_snapshot,
                "latest_snapshot": self.snapshots.latest_step(),
            }

    def close(self) -> None:
        self.wal.close()
