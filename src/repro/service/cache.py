"""LRU result cache for the resident mining service.

Keys are ``(dataset_version, tau, kmax, ordering)`` — everything that
determines a mining answer on the store (the engine only changes *how* the
answer is computed; engines are validated bit-identical, so results are
shared across them). Entries keep the full :class:`MiningResult`, which
serves three roles:

* repeat queries at the current version return instantly (the ≥20x warm
  path in ``benchmarks/bench_service.py``);
* the newest entry for the same ``(tau, kmax, ordering)`` at an *older*
  version is the base the incremental miner recounts against after appends;
* quasi-identifier reports are derived from cached results without
  re-mining.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import OrderedDict

from ..core.kyiv import MiningResult
from ..obs import metrics as _om

__all__ = [
    "CacheKey",
    "CacheEntry",
    "ResultCache",
    "make_key",
    "make_approx_key",
]

# process-wide event counter beside the per-instance hit/miss attributes
# (tests assert on fresh-instance counts; /stats keeps the instance view)
_CACHE_REQUESTS = _om.counter(
    "repro_result_cache_requests_total",
    "Result-cache lookups by outcome.",
    ("outcome",),
)

CacheKey = tuple  # (version, tau, kmax, ordering) — exact entries only


def make_key(version: int, tau: int, kmax: int, ordering: str) -> CacheKey:
    return (int(version), int(tau), int(kmax), str(ordering))


def make_approx_key(
    version: int, tau: int, kmax: int, ordering: str, epsilon: float
) -> CacheKey:
    """Cache key of a sampled (ε-approximate) answer.

    Deliberately a different key *shape* (6-tuple, with ε folded in): an
    approx entry must never be confused with — or returned in place of —
    the exact entry at the same parameters, and :meth:`ResultCache.
    latest_base` skips non-4-tuple keys, so approx entries can never
    serve as incremental recount bases."""
    return (
        int(version),
        int(tau),
        int(kmax),
        str(ordering),
        "approx",
        round(float(epsilon), 9),
    )


@dataclasses.dataclass
class CacheEntry:
    key: CacheKey
    result: MiningResult
    source: str  # "cold" | "incremental" | "partial" | "approx" | "refined"
    info: dict
    created_at: float = dataclasses.field(default_factory=time.time)
    hits: int = 0
    # near-boundary recount companion (service.incremental.ResultBands):
    # count-sorted per-arity matrices persisted beside the result so an
    # append-burst recount touches only the (tau, tau+d] band instead of
    # rebuilding the sort for all cached itemsets on every delta
    bands: object | None = None

    @property
    def version(self) -> int:
        return self.key[0]

    def nbytes(self) -> int:
        """Approximate payload footprint for byte-bounded eviction.

        Counts the itemset lists plus any prep arrays the entry's info
        references (``l_bits`` / table bits). Shared preps across entries
        are counted once per entry — deliberately conservative: the bound
        overestimates, never undercounts."""
        if self.result is None:
            return 0
        total = 0
        for ids, _cnt in self.result.itemsets:
            total += 16 + 8 * len(ids)
        prep = getattr(self.result, "prep", None)
        arr = getattr(prep, "l_bits", None)
        if arr is not None and hasattr(arr, "nbytes"):
            total += int(arr.nbytes)
        bits = getattr(getattr(prep, "table", None), "bits", None)
        if bits is not None and hasattr(bits, "nbytes"):
            total += int(bits.nbytes)
        if self.bands is not None:
            total += int(self.bands.nbytes())
        return total


class ResultCache:
    """Thread-safe LRU over mining results."""

    def __init__(self, capacity: int = 64, max_bytes: int | None = None):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        if max_bytes is not None and max_bytes <= 0:
            raise ValueError(f"max_bytes must be positive, got {max_bytes}")
        self.capacity = capacity
        self.max_bytes = max_bytes
        self._entries: "OrderedDict[CacheKey, CacheEntry]" = OrderedDict()
        self._bytes: dict[CacheKey, int] = {}
        self._total_bytes = 0
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def get(self, key: CacheKey) -> CacheEntry | None:
        with self._lock:
            entry = self._entries.get(key)
            if entry is None:
                self.misses += 1
            else:
                self._entries.move_to_end(key)
                self.hits += 1
                entry.hits += 1
        _CACHE_REQUESTS.inc(outcome="miss" if entry is None else "hit")
        return entry

    def put(self, entry: CacheEntry) -> None:
        nbytes = entry.nbytes()
        with self._lock:
            if entry.key in self._bytes:
                self._total_bytes -= self._bytes[entry.key]
            self._entries[entry.key] = entry
            self._bytes[entry.key] = nbytes
            self._total_bytes += nbytes
            self._entries.move_to_end(entry.key)
            self._evict_locked()

    def _evict_locked(self) -> None:
        # evict LRU-first while over either bound, but never the entry just
        # touched — a single oversized result still gets cached (the bound
        # is a budget for the tail, not a hard admission gate)
        def over() -> bool:
            if len(self._entries) > self.capacity:
                return True
            return self.max_bytes is not None and self._total_bytes > self.max_bytes

        while len(self._entries) > 1 and over():
            key, _ = self._entries.popitem(last=False)
            self._total_bytes -= self._bytes.pop(key, 0)

    def latest_base(
        self, tau: int, kmax: int, ordering: str, before_version: int
    ) -> CacheEntry | None:
        """Newest entry with the same mining parameters at an older dataset
        version — the incremental miner's recount base."""
        best: CacheEntry | None = None
        with self._lock:
            for entry in self._entries.values():
                if len(entry.key) != 4:
                    # approx/refined entries (make_approx_key) are scaled
                    # estimates — never a base to recount exactly against
                    continue
                v, t, k, o = entry.key
                if (t, k, o) == (tau, kmax, ordering) and v < before_version:
                    if best is None or v > best.version:
                        best = entry
        return best

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def stats(self) -> dict:
        with self._lock:
            return {
                "entries": len(self._entries),
                "capacity": self.capacity,
                "bytes": self._total_bytes,
                "max_bytes": self.max_bytes,
                "hits": self.hits,
                "misses": self.misses,
            }

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self._bytes.clear()
            self._total_bytes = 0
            self.hits = 0
            self.misses = 0
