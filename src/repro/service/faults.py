"""Fault injection for chaos-testing the durable mining service.

A :class:`FaultInjector` is a registry of named *sites* — well-known points
in the service where real deployments fail: the WAL write path
(``wal.append``), device/mesh dispatch (``placement.dispatch``), and the
level loop of a mine run (``mine.level_end``). Production code calls
``injector.check(site)`` at each site; with nothing armed this is a dict
lookup and a no-op, so the seams stay in release builds.

Armed actions:

``raise``
    Raise the configured exception. With :class:`KillPoint` this simulates
    the process dying at that instant — tests then build a *fresh* service
    over the same directory and assert recovery.
``partial``
    Only meaningful for write sites (``wal.append``): the site performs a
    torn half-write of the frame, fsyncs it, then raises :class:`KillPoint`
    — the on-disk state a real power cut leaves behind.
``sleep``
    Block for ``seconds`` at the site — used to hold a mine run open long
    enough for a concurrent cancel/deadline to land deterministically.

Faults fire ``times`` times after skipping the first ``after`` hits, so a
test can say "the 3rd dispatch fails, twice" and exercise retry paths.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
import time

from ..core import placement as _placement

__all__ = [
    "FaultInjector",
    "KillPoint",
    "DeviceFault",
    "placement_faults",
    "NULL_INJECTOR",
]


class KillPoint(RuntimeError):
    """Simulated process death. Never caught by the service — it unwinds the
    whole request like a crash would, and tests recover from disk."""


class DeviceFault(RuntimeError):
    """Simulated accelerator failure; classified by
    :func:`repro.core.placement.is_device_failure` and therefore eligible
    for retry/degradation, unlike :class:`KillPoint`."""

    is_device_failure = True


@dataclasses.dataclass
class _Fault:
    action: str
    exc: BaseException | None
    times: int
    after: int
    seconds: float
    hits: int = 0
    fired: int = 0


class FaultInjector:
    """Thread-safe registry of armed faults keyed by site name."""

    def __init__(self):
        self._lock = threading.Lock()
        self._faults: dict[str, _Fault] = {}
        self._hits: dict[str, int] = {}

    def arm(
        self,
        site: str,
        *,
        action: str = "raise",
        exc: BaseException | None = None,
        times: int = 1,
        after: int = 0,
        seconds: float = 0.0,
    ) -> None:
        """Arm ``site``. The fault fires on hits ``after+1 .. after+times``;
        later hits pass through untouched."""
        if action not in ("raise", "partial", "sleep"):
            raise ValueError(f"unknown fault action {action!r}")
        if action in ("raise", "partial") and exc is None:
            exc = KillPoint(site)
        with self._lock:
            self._faults[site] = _Fault(action, exc, times, after, seconds)

    def disarm(self, site: str) -> None:
        with self._lock:
            self._faults.pop(site, None)

    def reset(self) -> None:
        with self._lock:
            self._faults.clear()
            self._hits.clear()

    def hits(self, site: str) -> int:
        """How many times ``site`` was reached (armed or not)."""
        with self._lock:
            return self._hits.get(site, 0)

    def check(self, site: str) -> str | None:
        """Called by production code at a fault site. Returns the action the
        site must carry out itself (``"partial"``), performs ``sleep``
        in-line, raises for ``raise`` — or returns None when nothing fires."""
        with self._lock:
            self._hits[site] = self._hits.get(site, 0) + 1
            fault = self._faults.get(site)
            if fault is None:
                return None
            fault.hits += 1
            if fault.hits <= fault.after or fault.fired >= fault.times:
                return None
            fault.fired += 1
            action, exc, seconds = fault.action, fault.exc, fault.seconds
        if action == "sleep":
            time.sleep(seconds)
            return None
        if action == "raise":
            raise exc
        return action  # "partial": the site does the torn write itself

    def fired(self, site: str) -> int:
        with self._lock:
            fault = self._faults.get(site)
            return fault.fired if fault is not None else 0


class _NullInjector(FaultInjector):
    """Default injector: arming is a programming error, checking is free."""

    def arm(self, *a, **kw):  # pragma: no cover - guard rail
        raise RuntimeError("arm faults on a dedicated FaultInjector, not the default")

    def check(self, site: str) -> None:
        return None


NULL_INJECTOR = _NullInjector()


@contextlib.contextmanager
def placement_faults(injector: FaultInjector):
    """Route the process-global placement fault hook into ``injector``.

    Every device/mesh dispatch site in :mod:`repro.core.placement`
    (``dispatch``/``frontier``/``coverage``) funnels into the single
    ``placement.dispatch`` injector site — chaos tests care that *an*
    accelerator call failed, not which one. Restores the previous hook on
    exit so parallel test modules cannot leak faults into each other.
    """
    prev = _placement.set_fault_hook(lambda site: injector.check("placement.dispatch"))
    try:
        yield injector
    finally:
        _placement.set_fault_hook(prev)
