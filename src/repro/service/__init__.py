"""Resident mining service: device-resident dataset store, incremental
append mining, and a request-batched quasi-identifier API.

The one-shot ``repro.core.mine`` answers a single question about a static
table. This package turns the miner into a *service* over a growing table:
``DatasetStore`` keeps the itemized bitsets live and versioned across
row-block appends, ``mine_incremental`` exploits support monotonicity to
re-answer after appends at delta cost, ``ResultCache``/``RequestScheduler``
make repeat and concurrent traffic cheap, and ``MiningService`` is the
facade the HTTP endpoint (``repro.launch.serve_miner``) exposes.
"""

from .api import MineResponse, MiningService
from .cache import CacheEntry, ResultCache, make_key
from .incremental import IncrementalConfig, delta_support, mine_incremental
from .scheduler import RequestScheduler
from .store import DatasetStore

__all__ = [
    "CacheEntry",
    "DatasetStore",
    "IncrementalConfig",
    "MineResponse",
    "MiningService",
    "RequestScheduler",
    "ResultCache",
    "delta_support",
    "make_key",
    "mine_incremental",
]
