"""Resident mining service: device-resident dataset store, incremental
append mining, and a request-batched quasi-identifier API.

The one-shot ``repro.core.mine`` answers a single question about a static
table. This package turns the miner into a *service* over a growing table:
``DatasetStore`` keeps the itemized bitsets live and versioned across
row-block appends, ``mine_incremental`` exploits support monotonicity to
re-answer after appends at delta cost, ``ResultCache``/``RequestScheduler``
make repeat and concurrent traffic cheap, and ``MiningService`` is the
facade the HTTP endpoint (``repro.launch.serve_miner``) exposes.

The durability layer (``wal.DurableStore``) makes the store survive process
death, ``resilience`` degrades device failures to the host placement behind
a circuit breaker, and ``faults`` is the chaos-test injection harness.
"""

from ..sampling import SamplingConfig
from .api import DeadlineExceeded, MineResponse, MiningService, NotReadyError
from .cache import CacheEntry, ResultCache, make_approx_key, make_key
from .faults import DeviceFault, FaultInjector, KillPoint, placement_faults
from .fleet import FleetFrontend, FleetOpError, serve_fleet_peer
from .incremental import (
    IncrementalConfig,
    ResultBands,
    delta_support,
    mine_incremental,
)
from .resilience import CircuitBreaker, ResilienceConfig
from .scheduler import RequestScheduler
from .store import DatasetStore
from .wal import DurableStore, WriteAheadLog

__all__ = [
    "CacheEntry",
    "CircuitBreaker",
    "DatasetStore",
    "DeadlineExceeded",
    "DeviceFault",
    "DurableStore",
    "FaultInjector",
    "FleetFrontend",
    "FleetOpError",
    "IncrementalConfig",
    "KillPoint",
    "MineResponse",
    "MiningService",
    "NotReadyError",
    "RequestScheduler",
    "ResilienceConfig",
    "ResultBands",
    "ResultCache",
    "SamplingConfig",
    "WriteAheadLog",
    "delta_support",
    "make_approx_key",
    "make_key",
    "mine_incremental",
    "placement_faults",
    "serve_fleet_peer",
]
