"""Incremental re-mining after row-block appends (support monotonicity).

Appending rows can only *grow* an itemset's support. That single fact pins
down exactly how the answer set evolves between a cached base result and the
current store version:

* A minimal τ-infrequent itemset of the base table stays minimal as long as
  its own support stays ≤ τ — its proper subsets were frequent and frequency
  is append-monotone. So every cached result only needs a **recount on the
  appended rows** (``DatasetStore.delta_bits``): new support = old support +
  delta support, at a cost proportional to the delta block, not the table.
* A cached result whose support crossed τ is **promoted** to frequent. Any
  *new* minimal itemset ``S`` (one not in the base answer) was τ-infrequent
  in the base table too (monotonicity), hence contained a base-minimal
  subset; that subset is a proper subset of ``S``, is frequent now, and was
  therefore promoted. New items (values first seen in the delta) are the one
  exception — they had no base support at all; frequent new singletons seed
  the same way. So the full frontier of change is::

      seeds = promoted base results  ∪  frequent brand-new singleton items

  and every new minimal itemset is a strict superset of a seed.
* Seeds sit *near the τ boundary by construction*: a promoted itemset has
  new support ≤ τ + d (d = appended rows), so its frequent supersets live in
  the thin band (τ, τ + d] — the expansion work shrinks with the delta.
* One family has no base-minimal subset to seed from: itemsets that were
  **absent** (support 0) in the base table. Cold Kyiv skips absent
  candidates, so nothing about them is cached. But support 0 at the base
  means their entire support lies in the delta block — every such itemset
  is a subset of some appended row's items, so ``_delta_born`` enumerates
  the ≤kmax column combinations of each appended row (cost per row is a
  function of table *width*, not history) and classifies them directly.

``_expand_seeds`` explores exactly that band: a BFS over supersets of each
seed within the frequent item universe, pruning any infrequent node (an
infrequent proper subset disqualifies every superset from minimality) and
verifying minimality of emitted sets directly against the store bitsets.
Mirror items need no special casing — the BFS enumerates concrete item ids,
which is precisely the ``expansion="full"`` closure the cold miner produces
(incremental mining therefore requires ``KyivConfig.expansion == "full"``,
the default).

Past a configurable delta fraction — or if the boundary band turns out not
to be thin (expansion budget exhausted) — ``mine_incremental`` signals the
caller to fall back to a cold ``mine()``; the result is bit-identical either
way (property-tested against cold mining in ``tests/test_incremental.py``).

Two refinements ride on top of the base scheme:

* **Near-boundary bands** (:class:`ResultBands`): the result cache persists
  per-arity, count-sorted matrices of the cached itemsets. At recount time
  the per-item delta frequencies bound each cached itemset's delta support
  from above (``ub = min dfreq over members``); ``ub == 0`` proves the delta
  support is exactly 0, so only itemsets whose *every* member actually
  appears in the appended rows pay the bitset AND — the recount floor is
  delta-proportional instead of O(|cached results|), and the promotion scan
  is confined to the ``(τ - d, τ]`` band the sorted counts expose.
* **Fleet mode**: when the store is process-sharded and ``placement`` is a
  :class:`~repro.core.fleet.FleetPlacement`, every popcount in this module
  is a partial sum over local word stripes. All count vectors funnel
  through one ``allreduce_sum`` per stage (recount, expansion minimality,
  delta-born classification), delta-born candidates are unioned by one
  all-gather (each process only sees its own delta rows), and budget
  decisions are taken on the *global* pool so every process falls back —
  or doesn't — in lockstep.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from ..core.bitops import popcount_rows
from ..core.items import ItemTable
from ..core.kyiv import KyivConfig, LevelStats, MiningResult
from ..core.preprocess import Preprocessed
from .store import DatasetStore, mask_delta_words, mask_delta_words_local

__all__ = ["IncrementalConfig", "ResultBands", "mine_incremental", "delta_support"]


@dataclasses.dataclass
class IncrementalConfig:
    """Knobs for the append-delta mining path."""

    # past this appended-rows fraction of the current table, recounting +
    # boundary expansion loses to simply re-mining cold
    max_delta_fraction: float = 0.25
    # frontier-node limit for the boundary expansion BFS; exhausted => the
    # boundary band is not thin, fall back to cold mining
    expansion_budget: int = 4096
    # cap on deduplicated delta-born candidate itemsets (subsets of appended
    # rows); exhausted => fall back to cold mining
    delta_candidate_budget: int = 262_144
    enabled: bool = True


def _delta_bits_of(
    table: ItemTable, base_rows: int, word_map: np.ndarray | None = None
) -> np.ndarray:
    """Delta-row bitsets derived from an immutable snapshot table (same
    contract as ``DatasetStore.delta_bits``, but safe against appends that
    land while this mining request is running). ``word_map`` marks the
    snapshot as process-sharded: delta words are scattered round-robin, so
    the full local width is kept and pre-existing rows are zeroed in place."""
    if word_map is not None:
        return mask_delta_words_local(table.bits, base_rows, word_map)
    return mask_delta_words(table.bits, base_rows)[0]


def delta_support(
    dbits: np.ndarray, itemsets: list[tuple[int, ...]]
) -> np.ndarray:
    """Support of each itemset restricted to the delta rows.

    ``dbits`` is the ``DatasetStore.delta_bits`` slice: (n_items, W_delta).
    Itemsets are grouped by arity and AND-reduced vectorised; total cost is
    O(sum_k r_k * k * W_delta).
    """
    out = np.zeros(len(itemsets), dtype=np.int64)
    by_k: dict[int, list[int]] = {}
    for idx, ids in enumerate(itemsets):
        by_k.setdefault(len(ids), []).append(idx)
    for k, idxs in by_k.items():
        mat = np.asarray([itemsets[i] for i in idxs], dtype=np.int64)  # (r, k)
        inter = np.bitwise_and.reduce(dbits[mat], axis=1)  # (r, Wd)
        out[idxs] = popcount_rows(inter)
    return out


@dataclasses.dataclass
class ResultBands:
    """Per-arity, count-sorted views of a cached result set.

    Built once when a mining result enters the cache and persisted beside
    it (``CacheEntry.bands``), so an append burst pays only the recount this
    structure admits: ``recount`` bounds each itemset's delta support by the
    minimum delta frequency of its members and runs the exact bitset AND
    only where that bound is non-zero; the ascending base counts confine
    promotion candidates to the thin ``(τ - d, τ]`` boundary band.
    """

    mats: dict[int, np.ndarray]  # arity -> (r, k) int64 ids, count-ascending
    counts: dict[int, np.ndarray]  # arity -> (r,) int64 base counts, ascending
    index: dict[int, np.ndarray]  # arity -> (r,) position in cached order

    @classmethod
    def from_result(cls, itemsets: list[tuple[tuple[int, ...], int]]) -> "ResultBands":
        by_k: dict[int, list[tuple[int, tuple[int, ...], int]]] = {}
        for pos, (ids, cnt) in enumerate(itemsets):
            by_k.setdefault(len(ids), []).append((pos, ids, cnt))
        mats, counts, index = {}, {}, {}
        for k, rows in by_k.items():
            cnt = np.asarray([c for _, _, c in rows], dtype=np.int64)
            order = np.argsort(cnt, kind="stable")
            mats[k] = np.asarray([ids for _, ids, _ in rows], dtype=np.int64)[order]
            counts[k] = cnt[order]
            index[k] = np.asarray([p for p, _, _ in rows], dtype=np.int64)[order]
        return cls(mats=mats, counts=counts, index=index)

    def nbytes(self) -> int:
        return sum(
            a.nbytes
            for d in (self.mats, self.counts, self.index)
            for a in d.values()
        )

    def recount(
        self,
        dbits: np.ndarray,
        dfreq: np.ndarray,
        tau: int,
        d: int,
        reduce_fn=None,
    ) -> tuple[np.ndarray, dict]:
        """New (base + delta) support of every cached itemset, in cached
        order, touching bitsets only where the ``dfreq`` upper bound admits a
        non-zero delta. ``dfreq`` must be the *global* per-item delta
        frequency; under a fleet ``reduce_fn`` sums the partial popcounts
        (one collective for all arities — the upper-bound filter is computed
        from global values, so every process recounts the identical rows).
        Returns ``(new_counts, stats)``."""
        total = sum(len(c) for c in self.counts.values())
        new = np.zeros(total, dtype=np.int64)
        n_recounted = 0
        n_band = 0
        chunks: list[tuple[int, np.ndarray, np.ndarray]] = []
        for k in sorted(self.mats):
            mat, cnt = self.mats[k], self.counts[k]
            if len(cnt) == 0:
                continue
            # ascending base counts: everything past this point could cross τ
            n_band += len(cnt) - int(np.searchsorted(cnt, tau - d, side="right"))
            if k == 1:
                # singleton delta support IS the delta frequency — no AND
                new[self.index[k]] = cnt + dfreq[mat[:, 0]]
                continue
            ub = dfreq[mat].min(axis=1)
            need = np.nonzero(ub > 0)[0]
            n_recounted += len(need)
            new[self.index[k]] = cnt  # ub == 0 rows are exact as-is
            if len(need):
                inter = np.bitwise_and.reduce(dbits[mat[need]], axis=1)
                chunks.append((k, need, popcount_rows(inter).astype(np.int64)))
        if chunks:
            flat = np.concatenate([c for _, _, c in chunks])
            if reduce_fn is not None:
                flat = reduce_fn(flat)
            off = 0
            for k, need, c in chunks:
                new[self.index[k][need]] += flat[off : off + len(need)]
                off += len(need)
        stats = {
            "n_recounted": n_recounted,
            "n_recount_skipped": total - n_recounted,
            "n_promotion_band": n_band,
        }
        return new, stats


def _itemset_support(bits: np.ndarray, ids: tuple[int, ...]) -> int:
    inter = np.bitwise_and.reduce(bits[list(ids)], axis=0)
    return int(popcount_rows(inter[None, :])[0])


def _is_minimal(
    bits: np.ndarray, freq: np.ndarray, ids: tuple[int, ...], tau: int
) -> bool:
    """All (|S|-1)-subsets frequent? (Sufficient: infrequency is superset-
    monotone, so a deeper infrequent subset implies an infrequent
    (|S|-1)-subset.)"""
    if len(ids) == 1:
        return True
    if len(ids) == 2:
        return bool(freq[ids[0]] > tau and freq[ids[1]] > tau)
    for drop in range(len(ids)):
        sub = ids[:drop] + ids[drop + 1 :]
        if _itemset_support(bits, sub) <= tau:
            return False
    return True


def _filter_minimal(
    table: ItemTable, cands: dict[frozenset, int], tau: int, reduce_fn=None
) -> dict[frozenset, int]:
    """Keep the minimal members of a τ-infrequent candidate pool, batched.

    Every distinct (|S|-1)-subset across all arity ≥ 3 candidates is counted
    once in one vectorised pass — under a fleet that is a single partial-
    popcount all-reduce instead of one per leave-one-out probe (the
    per-candidate ``_is_minimal`` would be a collective per subset).
    Arity 1 is minimal by definition; arity 2 checks global frequencies.
    """
    freq = table.freq
    bits = table.bits
    sub_index: dict[tuple[int, ...], int] = {}
    sub_list: list[tuple[int, ...]] = []
    refs_of: dict[frozenset, list[int]] = {}
    for cs in cands:
        if len(cs) <= 2:
            continue
        ids = tuple(sorted(cs))
        refs = []
        for drop in range(len(ids)):
            sub = ids[:drop] + ids[drop + 1 :]
            ix = sub_index.get(sub)
            if ix is None:
                ix = len(sub_list)
                sub_index[sub] = ix
                sub_list.append(sub)
            refs.append(ix)
        refs_of[cs] = refs
    sup = np.zeros(len(sub_list), dtype=np.int64)
    if sub_list:
        by_k: dict[int, list[int]] = {}
        for ix, sub in enumerate(sub_list):
            by_k.setdefault(len(sub), []).append(ix)
        parts = []
        for kk in sorted(by_k):
            idxs = by_k[kk]
            mat = np.asarray([sub_list[i] for i in idxs], dtype=np.int64)
            inter = np.bitwise_and.reduce(bits[mat], axis=1)
            parts.append((idxs, popcount_rows(inter).astype(np.int64)))
        flat = np.concatenate([p for _, p in parts])
        if reduce_fn is not None:
            flat = reduce_fn(flat)
        off = 0
        for idxs, p in parts:
            sup[idxs] = flat[off : off + len(p)]
            off += len(p)
    out: dict[frozenset, int] = {}
    for cs, cnt in cands.items():
        if len(cs) == 1:
            ok = True
        elif len(cs) == 2:
            a, b = tuple(cs)
            ok = bool(freq[a] > tau and freq[b] > tau)
        else:
            ok = all(sup[ix] > tau for ix in refs_of[cs])
        if ok:
            out[cs] = cnt
    return out


def _expand_seeds(
    table: ItemTable,
    seeds: list[tuple[int, ...]],
    tau: int,
    kmax: int,
    budget: int,
    *,
    placement=None,
    resident_bits=None,
    reduce_fn=None,
) -> dict[frozenset, int] | None:
    """All minimal τ-infrequent strict supersets of any seed, up to kmax.

    Level-synchronous BFS over the **resident frontier**: each wave's
    (node × extension-item) support counts are one batched intersect
    dispatch through the service's :class:`~repro.kernels.intersect.ops.LevelPipeline`
    — the extension items gather from the store's placement-resident bitset
    matrix (``resident_bits``) instead of re-gathering host levels, so the
    hot popcount loop runs wherever mining itself runs (host numpy, one
    device, or the mesh). Only surviving nodes' bitsets are re-derived on
    the host (two-row ANDs) to seed the next wave.

    Returns None when the explored node count exceeds ``budget`` (caller
    re-mines cold). Every wave node is a *frequent* superset of a seed; an
    infrequent node is classified once (emit if minimal) and never extended,
    because its supersets all contain an infrequent proper subset.
    """
    from ..kernels.intersect.ops import LevelPipeline

    n = table.n_rows
    freq = table.freq
    bits = table.bits
    ext_universe = np.nonzero((freq > tau) & (freq < n))[0].astype(np.int64)
    found: dict[frozenset, int] = {}
    if len(ext_universe) == 0:
        return found
    visited: set[frozenset] = set()
    wave: list[tuple[frozenset, np.ndarray]] = []
    for ids in seeds:
        fs = frozenset(int(i) for i in ids)
        if len(fs) >= kmax or fs in visited:
            continue
        visited.add(fs)
        wave.append((fs, np.bitwise_and.reduce(bits[list(fs)], axis=0)))

    if placement is None:
        from ..core.placement import HostPlacement

        placement = HostPlacement()
    on_device = (
        getattr(placement, "kind", "host") != "host" and resident_bits is not None
    )
    ext_host = bits[ext_universe]  # host copy: seeds the next wave's bits
    if on_device and resident_bits is not None:
        import jax.numpy as jnp

        ext_res = jnp.asarray(resident_bits)[jnp.asarray(ext_universe)]
    else:
        ext_res = ext_host

    e_count, w_words = ext_host.shape
    # two budgets: nodes whose bitsets join the resident matrix per segment
    # (the extension block is re-placed once per *segment* — usually once
    # per wave; placing it exactly once per call would need a two-block
    # pair addressing scheme the placement API doesn't speak, and waves are
    # shallow by the thin-boundary-band premise), and rows per submit
    # bounding the dispatch working set: the host placement materialises
    # both gathered operands plus the AND, ~3 * pairs * W words per submit
    seg_nodes = max(1, (1 << 24) // max(w_words, 1))
    rows_per_submit = max(1, (1 << 23) // max(e_count * max(w_words, 1), 1))
    popped = 0
    while wave:
        popped += len(wave)
        if popped > budget:
            return None
        next_wave: list[tuple[frozenset, np.ndarray]] = []
        for s0 in range(0, len(wave), seg_nodes):
            seg = wave[s0 : s0 + seg_nodes]
            f_count = len(seg)
            wave_bits = np.stack([wb for _, wb in seg])
            if on_device:
                import jax.numpy as jnp

                mat = jnp.concatenate([ext_res, jnp.asarray(wave_bits)], axis=0)
            else:
                mat = np.concatenate([ext_res, wave_bits], axis=0)
            pipe = LevelPipeline(
                mat,
                np.zeros(e_count + f_count, dtype=np.int64),
                tau=0,
                placement=placement,
                fused_classify=False,
                locality_sort=False,
            )
            for s in range(0, f_count, rows_per_submit):
                chunk = seg[s : s + rows_per_submit]
                c_count = len(chunk)
                fi = (
                    np.repeat(np.arange(s, s + c_count, dtype=np.int64), e_count)
                    + e_count
                )
                ei = np.tile(np.arange(e_count, dtype=np.int64), c_count)
                pairs = np.stack([fi, ei], axis=1).astype(np.int32)
                _, counts, _ = pipe.submit(pairs, False).result()
                counts = counts.reshape(c_count, e_count)
                for fidx, (fs, fb) in enumerate(chunk):
                    # absent extensions (the overwhelming majority in sparse
                    # data) die before any set building
                    for eidx in np.nonzero(counts[fidx])[0]:
                        x = int(ext_universe[eidx])
                        if x in fs:
                            continue
                        cs = fs | {x}
                        if cs in visited:
                            continue
                        visited.add(cs)
                        cnt = int(counts[fidx, eidx])
                        if cnt > tau:
                            if len(cs) < kmax:
                                next_wave.append((cs, fb & ext_host[eidx]))
                        else:
                            # minimality is deferred: one batched subset-
                            # support pass after the BFS (a single collective
                            # under a fleet) replaces per-emission probes
                            found[cs] = cnt
            pipe.retire()
        wave = next_wave
    return _filter_minimal(table, found, tau, reduce_fn)


def _delta_born(
    table: ItemTable,
    dbits: np.ndarray,
    base_rows: int,
    tau: int,
    kmax: int,
    budget: int,
    *,
    word_map: np.ndarray | None = None,
    coll=None,
) -> dict[frozenset, int] | None:
    """Minimal τ-infrequent itemsets whose base support was 0.

    Their whole support lies in the appended rows, so every one is a subset
    of the items of at least one delta row. Delta rows are reconstructed
    from the item-major delta bitsets, each row's items are filtered to the
    frequent non-uniform universe (an infrequent or uniform member disquali-
    fies minimality immediately), and the surviving ≤kmax combinations are
    counted vectorised against the full-width bitsets and checked for
    minimality directly. Returns None when the deduplicated candidate pool
    exceeds ``budget``.

    Under a fleet (``word_map`` + ``coll``) each process reconstructs only
    the delta rows living in its own word stripes, so the candidate pools
    are unioned by one all-gather and the budget verdict is taken on the
    *global* pool — either every process falls back to cold mining or none
    does. Support counts and the minimality filter reduce partial popcounts.
    """
    import itertools

    n = table.n_rows
    freq = table.freq
    bits = table.bits
    d = n - base_rows
    if d <= 0 or kmax < 2:
        return {}
    # item-major delta bits -> per-row item lists (delta-scaled unpack)
    flat = np.unpackbits(
        np.ascontiguousarray(dbits).view(np.uint8), axis=1, bitorder="little"
    )  # (n_items, W*32); column j of word w = that word's row (w*32 + j)
    if word_map is None:
        lo = (base_rows // 32) * 32
        row_items = flat[:, base_rows - lo : n - lo]  # (n_items, d)
    else:
        # sharded width: column c covers global row word_map[c // 32]*32 +
        # c % 32; keep this process's columns inside the delta row range
        wm = np.asarray(word_map, dtype=np.int64)
        grow = wm.repeat(32) * 32 + np.tile(np.arange(32, dtype=np.int64), len(wm))
        row_items = flat[:, (grow >= base_rows) & (grow < n)]
    keep = (freq > tau) & (freq < n)

    cands: set[tuple[int, ...]] = set()
    overflow = False
    for r in range(row_items.shape[1]):
        items = np.nonzero(row_items[:, r])[0]
        items = items[keep[items]]
        for k in range(2, min(kmax, len(items)) + 1):
            for combo in itertools.combinations(items.tolist(), k):
                cands.add(combo)
                if len(cands) > budget:
                    if coll is None:
                        return None
                    overflow = True  # verdict deferred to the global union
                    break
            if overflow:
                break
        if overflow:
            break
    if coll is not None:
        pools = coll.allgather_obj((sorted(cands), overflow))
        if any(o for _, o in pools):
            return None
        union: set[tuple[int, ...]] = set()
        for pool, _ in pools:
            union.update(tuple(c) for c in pool)
        if len(union) > budget:
            return None
        cands = union

    reduce_fn = coll.allreduce_sum if coll is not None else None
    pre: dict[frozenset, int] = {}
    by_k: dict[int, list[tuple[int, ...]]] = {}
    for c in sorted(cands):
        by_k.setdefault(len(c), []).append(c)
    parts = []
    for k in sorted(by_k):
        sets_k = by_k[k]
        mat = np.asarray(sets_k, dtype=np.int64)  # (r, k)
        counts = popcount_rows(np.bitwise_and.reduce(bits[mat], axis=1))
        dcounts = popcount_rows(np.bitwise_and.reduce(dbits[mat], axis=1))
        parts.append((sets_k, counts.astype(np.int64), dcounts.astype(np.int64)))
    if parts and reduce_fn is not None:
        # one collective for all arities: [counts | dcounts] concatenated
        flat_counts = np.concatenate(
            [np.concatenate([c, dc]) for _, c, dc in parts]
        )
        flat_counts = reduce_fn(flat_counts)
        off = 0
        fixed = []
        for sets_k, c, dc in parts:
            r = len(sets_k)
            fixed.append((sets_k, flat_counts[off : off + r], flat_counts[off + r : off + 2 * r]))
            off += 2 * r
        parts = fixed
    for sets_k, counts, dcounts in parts:
        for ids, cnt, dcnt in zip(sets_k, counts, dcounts):
            cnt = int(cnt)
            # cnt == dcnt <=> base support 0: itemsets present at the base are
            # exactly the family already covered by recount + seed expansion
            if 1 <= cnt <= tau and cnt == int(dcnt):
                pre[frozenset(ids)] = cnt
    return _filter_minimal(table, pre, tau, reduce_fn)


def _light_prep(table: ItemTable, tau: int) -> Preprocessed:
    """A Preprocessed container for incremental results: correct item
    partitions and ordering metadata, but no mirror hashing and no l_bits
    gather — the incremental path never re-enters the level miner, and
    skipping the O(items * W) work keeps its cost delta-dominated."""
    freq = table.freq
    n = table.n_rows
    uniform = np.nonzero(freq == n)[0]
    infrequent = np.nonzero(freq <= tau)[0]
    keep = np.nonzero((freq > tau) & (freq < n))[0]
    order = np.lexsort((table.min_row[keep], table.col[keep], freq[keep]))
    l_items = keep[order]
    return Preprocessed(
        table=table,
        tau=tau,
        uniform_items=uniform,
        infrequent_items=infrequent,
        l_items=l_items,
        mirror_of={},
        l_bits=np.zeros((0, table.n_words), dtype=np.uint32),
        l_freq=freq[l_items].astype(np.int64),
    )


def mine_incremental(
    store: DatasetStore,
    base_result: MiningResult,
    base_version: int,
    config: KyivConfig,
    inc_config: IncrementalConfig | None = None,
    *,
    table: ItemTable | None = None,
    placement=None,
    resident_bits=None,
    bands: "ResultBands | None" = None,
) -> tuple[MiningResult, dict] | None:
    """Delta-mine the store against a cached base result.

    ``table`` is an optional immutable snapshot (``DatasetStore.item_table``)
    to mine; when omitted one is taken now. Only the historical watermarks of
    ``store`` are consulted otherwise, so concurrent appends cannot skew the
    delta. ``placement``/``resident_bits`` route the promoted/new-item seed
    expansion through the service's placement and the store's
    device-resident bitsets (``DatasetStore.device_bits``) instead of
    rebuilding host levels; omitted, the expansion runs on host numpy —
    results are bit-identical either way. ``bands`` is the cached
    :class:`ResultBands` companion of ``base_result`` (built on the fly when
    absent, so callers without a cache still get the shrunken recount). A
    ``FleetPlacement`` switches every stage into its collective form (see
    module docstring). Returns ``(result, info)`` or ``None`` when the
    caller should fall back to a cold mine (delta too large, expansion
    budget exhausted, or a config the incremental invariants don't cover).
    """
    inc = inc_config or IncrementalConfig()
    if not inc.enabled or config.expansion != "full" or config.kmax < 1:
        return None
    try:
        base_rows = store.rows_at(base_version)
        base_items = store.items_at(base_version)
    except KeyError:
        return None  # base watermark compacted away -> cold remine
    if base_rows == 0:
        return None
    t0 = time.perf_counter()
    if table is None:
        table = store.item_table()
    n = table.n_rows
    delta_rows = n - base_rows
    if delta_rows <= 0:
        return None
    if delta_rows > inc.max_delta_fraction * n:
        return None

    tau, kmax = config.tau, config.kmax

    # fleet mode: partial popcounts over local word stripes, reduced through
    # the placement's collective; every budget/branch decision below is a
    # function of global values so the processes stay in lockstep
    if placement is None:
        placement = getattr(config, "placement", None)
    fleet = getattr(placement, "kind", None) == "fleet"
    coll = placement.collective if fleet else None
    reduce_fn = coll.allreduce_sum if fleet else None
    shard = tuple(getattr(store, "shard", (0, 1)))
    word_map = store.word_map(table.n_words) if shard[1] > 1 else None

    # 1. recount cached results on the appended rows — only where the
    # per-item delta-frequency bound admits a non-zero delta support
    dbits = _delta_bits_of(table, base_rows, word_map)
    dfreq = popcount_rows(dbits).astype(np.int64)
    if reduce_fn is not None:
        dfreq = reduce_fn(dfreq)
    if bands is None:
        bands = ResultBands.from_result(base_result.itemsets)
    old_sets = [ids for ids, _ in base_result.itemsets]
    new_counts, band_stats = bands.recount(
        dbits, dfreq, tau, delta_rows, reduce_fn
    )

    results: list[tuple[tuple[int, ...], int]] = []
    seeds: list[tuple[int, ...]] = []
    for ids, cnt in zip(old_sets, new_counts):
        if cnt <= tau:
            results.append((ids, int(cnt)))
        else:
            seeds.append(ids)
    n_promoted = len(seeds)

    # 2. brand-new items (values first seen in the delta)
    freq = table.freq
    n_new_items = table.n_items - base_items
    for a in range(base_items, table.n_items):
        if freq[a] <= tau:
            results.append(((a,), int(freq[a])))
        elif freq[a] < n:
            seeds.append((a,))

    # 3. boundary expansion: previously-present new minimal itemsets are
    # strict supersets of a seed, explored through the resident frontier
    expanded = _expand_seeds(
        table,
        seeds,
        tau,
        kmax,
        inc.expansion_budget,
        placement=placement,
        resident_bits=resident_bits,
        reduce_fn=reduce_fn,
    )
    if expanded is None:
        return None

    # 4. delta-born itemsets: absent at the base (support 0 is never cached),
    # supported entirely inside the appended block
    born = _delta_born(
        table,
        dbits,
        base_rows,
        tau,
        kmax,
        inc.delta_candidate_budget,
        word_map=word_map,
        coll=coll,
    )
    if born is None:
        return None
    n_expanded = len(expanded)
    expanded.update(born)

    # no dedup needed: kept results had base support >= 1 and support <= tau,
    # expansion finds only sets with a base-infrequent (promoted) proper
    # subset, and delta-born sets had base support 0 — the families are
    # pairwise disjoint (expansion/delta-born overlap merged in `expanded`)
    for cs, cnt in sorted(expanded.items(), key=lambda e: (len(e[0]), sorted(e[0]))):
        results.append((tuple(sorted(cs)), cnt))

    stats = []
    by_size: dict[int, int] = {}
    for ids, _ in results:
        by_size[len(ids)] = by_size.get(len(ids), 0) + 1
    for k in range(1, kmax + 1):
        stats.append(LevelStats(k=k, emitted=by_size.get(k, 0)))
    elapsed = time.perf_counter() - t0
    stats[0].time_total = elapsed

    result = MiningResult(
        itemsets=results,
        stats=stats,
        prep=_light_prep(table, tau),
        config=config,
        wall_time=elapsed,
    )
    info = {
        "delta_rows": int(delta_rows),
        "n_promoted": n_promoted,
        "n_new_items": int(n_new_items),
        "n_seeds": len(seeds),
        "n_expanded": n_expanded,
        "n_delta_born": len(born),
        "n_cached": len(old_sets),
        **band_stats,
    }
    if fleet:
        info["fleet"] = coll.stats()
    return result, info
