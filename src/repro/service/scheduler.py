"""Request scheduler: coalesce identical concurrent mining requests.

Under burst traffic many clients ask the same ``(version, tau, kmax,
ordering)`` question at once. Mining it once is both mandatory (one device)
and sufficient (the answer is deterministic), so the scheduler keeps a map
of in-flight futures keyed like the result cache: the first request
schedules the work on a small worker pool, every concurrent duplicate rides
the same future ("request batching"), and all of them share the warm
``LevelPipeline`` executable buckets in ``kernels.intersect.ops.EXEC_CACHE``
because the work runs in one process-wide pool.

``max_workers`` defaults to 1: level mining saturates the device, so
distinct requests queue FIFO rather than thrash it.
"""

from __future__ import annotations

import contextvars
import threading
import time
from concurrent.futures import CancelledError, Future, ThreadPoolExecutor
from concurrent.futures import TimeoutError as FutureTimeoutError
from typing import Callable, TypeVar

__all__ = ["RequestScheduler"]

T = TypeVar("T")


class RequestScheduler:
    def __init__(self, max_workers: int = 1):
        self._pool = ThreadPoolExecutor(
            max_workers=max_workers, thread_name_prefix="miner"
        )
        self._lock = threading.Lock()
        self._inflight: dict[tuple, Future] = {}
        self.scheduled = 0
        self.coalesced = 0
        self.failed = 0

    def submit(self, key: tuple, fn: Callable[[], T]) -> "Future[T]":
        """Run ``fn`` for ``key``, or join the in-flight run for the same key."""
        with self._lock:
            future = self._inflight.get(key)
            if future is not None:
                self.coalesced += 1
                return future
            # carry the submitter's context (the active obs trace span) across
            # the worker-thread hop, so the run's spans join the request's
            # trace tree; coalesced waiters ride the first submitter's trace
            ctx = contextvars.copy_context()
            future = self._pool.submit(ctx.run, fn)
            self._inflight[key] = future
            self.scheduled += 1

        def _done(f: Future, key=key) -> None:
            with self._lock:
                if self._inflight.get(key) is f:
                    del self._inflight[key]
                try:
                    failed = f.exception() is not None
                except CancelledError:
                    failed = True
                if failed:
                    # the exception is delivered to every coalesced waiter
                    # via the shared future; here we only count it — a dead
                    # worker run must never wedge the key for later requests
                    self.failed += 1

        future.add_done_callback(_done)
        return future

    def drain(self, timeout: float | None = None) -> dict:
        """Wait for in-flight work to finish (graceful shutdown).

        New submissions are still accepted during the drain — the HTTP
        layer stops feeding the scheduler before calling this. Returns
        counts of runs drained vs. abandoned at the deadline."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._lock:
            pending = list(self._inflight.values())
        drained = abandoned = 0
        for fut in pending:
            remaining = (
                None if deadline is None else max(0.0, deadline - time.monotonic())
            )
            try:
                fut.exception(timeout=remaining)
                drained += 1
            except FutureTimeoutError:
                abandoned += 1
            except (CancelledError, Exception):
                drained += 1
        return {"inflight": len(pending), "drained": drained, "abandoned": abandoned}

    def stats(self) -> dict:
        with self._lock:
            return {
                "scheduled": self.scheduled,
                "coalesced": self.coalesced,
                "failed": self.failed,
                "inflight": len(self._inflight),
            }

    def shutdown(self) -> None:
        self._pool.shutdown(wait=True)
