"""Request scheduler: coalesce identical concurrent mining requests.

Under burst traffic many clients ask the same ``(version, tau, kmax,
ordering)`` question at once. Mining it once is both mandatory (one device)
and sufficient (the answer is deterministic), so the scheduler keeps a map
of in-flight futures keyed like the result cache: the first request
schedules the work on a small worker pool, every concurrent duplicate rides
the same future ("request batching"), and all of them share the warm
``LevelPipeline`` executable buckets in ``kernels.intersect.ops.EXEC_CACHE``
because the work runs in one process-wide pool.

``max_workers`` defaults to 1: level mining saturates the device, so
distinct requests queue FIFO rather than thrash it.
"""

from __future__ import annotations

import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Callable, TypeVar

__all__ = ["RequestScheduler"]

T = TypeVar("T")


class RequestScheduler:
    def __init__(self, max_workers: int = 1):
        self._pool = ThreadPoolExecutor(
            max_workers=max_workers, thread_name_prefix="miner"
        )
        self._lock = threading.Lock()
        self._inflight: dict[tuple, Future] = {}
        self.scheduled = 0
        self.coalesced = 0

    def submit(self, key: tuple, fn: Callable[[], T]) -> "Future[T]":
        """Run ``fn`` for ``key``, or join the in-flight run for the same key."""
        with self._lock:
            future = self._inflight.get(key)
            if future is not None:
                self.coalesced += 1
                return future
            future = self._pool.submit(fn)
            self._inflight[key] = future
            self.scheduled += 1

        def _done(f: Future, key=key) -> None:
            with self._lock:
                if self._inflight.get(key) is f:
                    del self._inflight[key]

        future.add_done_callback(_done)
        return future

    def stats(self) -> dict:
        with self._lock:
            return {
                "scheduled": self.scheduled,
                "coalesced": self.coalesced,
                "inflight": len(self._inflight),
            }

    def shutdown(self) -> None:
        self._pool.shutdown(wait=True)
