"""Retry/degradation policy for device-backed mining.

When a device or mesh dispatch fails with an accelerator-shaped error
(:func:`repro.core.placement.is_device_failure`), the service retries with
exponential backoff; once failures persist the :class:`CircuitBreaker`
opens and requests are served from the Host placement instead — slower but
bit-identical results (the placements share one reference semantics, see
``tests/test_placement.py``). After ``cooldown_s`` the breaker lets one
request probe the device path again (implicit half-open): success closes
it, failure re-opens and restarts the cooldown.

Everything time-/sleep-shaped is injectable so chaos tests run in
milliseconds.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable

from ..obs import metrics as _om

__all__ = ["ResilienceConfig", "CircuitBreaker"]

# process-wide beside the per-instance ``trips`` attribute (chaos tests
# assert on fresh-instance counts; /stats keeps the instance view)
_BREAKER_TRIPS = _om.counter(
    "repro_breaker_trips_total", "Circuit-breaker opens across all services."
)
_BREAKER_FAILURES = _om.counter(
    "repro_breaker_failures_total", "Device failures recorded by breakers."
)


@dataclasses.dataclass(frozen=True)
class ResilienceConfig:
    """Knobs for device-failure handling."""

    max_retries: int = 2          # device attempts after the first failure
    backoff_s: float = 0.05       # initial backoff; doubles per retry
    failure_threshold: int = 3    # consecutive failures that open the breaker
    cooldown_s: float = 30.0      # open duration before a probe is allowed
    sleep: Callable[[float], None] = time.sleep


class CircuitBreaker:
    """Consecutive-failure breaker with implicit half-open probing."""

    def __init__(self, failure_threshold: int = 3, cooldown_s: float = 30.0,
                 clock: Callable[[], float] = time.monotonic):
        self.failure_threshold = max(1, failure_threshold)
        self.cooldown_s = cooldown_s
        self._clock = clock
        self._lock = threading.Lock()
        self._failures = 0
        self._opened_at: float | None = None
        self.trips = 0
        # optional ``fn(state: str)`` fired outside the breaker lock on
        # open/closed transitions (the flight recorder); must never raise
        # into the mining path
        self.on_transition: Callable[[str], None] | None = None

    @property
    def state(self) -> str:
        with self._lock:
            if self._opened_at is None:
                return "closed"
            if self._clock() - self._opened_at >= self.cooldown_s:
                return "half_open"
            return "open"

    def allow(self) -> bool:
        """May the device path be attempted right now?"""
        return self.state != "open"

    def record_success(self) -> None:
        with self._lock:
            self._failures = 0
            reopened = self._opened_at is not None
            self._opened_at = None
        if reopened:
            self._fire("closed")

    def record_failure(self) -> None:
        _BREAKER_FAILURES.inc()
        tripped = False
        with self._lock:
            self._failures += 1
            if self._failures >= self.failure_threshold:
                if self._opened_at is None:
                    self.trips += 1
                    tripped = True
                self._opened_at = self._clock()
        if tripped:
            # outside the breaker lock: the registry's scrape collectors read
            # breaker.stats() under the registry lock (reverse order)
            _BREAKER_TRIPS.inc()
            self._fire("open")

    def _fire(self, state: str) -> None:
        cb = self.on_transition
        if cb is not None:
            try:
                cb(state)
            except Exception:
                pass

    def stats(self) -> dict:
        with self._lock:
            opened = self._opened_at
            state = (
                "closed"
                if opened is None
                else (
                    "half_open"
                    if self._clock() - opened >= self.cooldown_s
                    else "open"
                )
            )
            return {
                "state": state,
                "consecutive_failures": self._failures,
                "failure_threshold": self.failure_threshold,
                "cooldown_s": self.cooldown_s,
                "trips": self.trips,
            }
