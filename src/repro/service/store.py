"""Versioned, device-resident dataset store for the resident mining service.

The one-shot pipeline re-itemizes the whole table on every ``mine()`` call.
A data custodian's table instead *grows*: the AOL-style workload is a stream
of row-block appends interleaved with quasi-identifier queries. This store
keeps the itemized representation — the ``(n_items, W)`` uint32 bitset matrix
the intersection kernels consume — **live across requests**:

* Item bitsets are stored in the kernels' word-tile layout: the word
  dimension is padded to a multiple of ``word_tile`` so that the padded width
  (and hence the Pallas BlockSpec tiling and the executable buckets in
  ``kernels.intersect.ops.EXEC_CACHE``) stays stable while rows accumulate
  inside a tile, and only steps tile-by-tile afterwards. When the store is
  built for a ``repro.core.placement.BitsetPlacement``, the tile is aligned
  to the placement's ``store_word_tile`` (the word-shard count on a mesh), so
  append blocks itemize **directly into per-shard word tiles** — placing the
  matrix on the mesh never re-packs or re-pads it.
* ``append(rows)`` itemizes *only the appended block*: existing items get new
  bits OR-ed into their rows, new ``(column, value)`` pairs get fresh item
  ids. History is never re-itemized; both the item and word axes grow by
  amortised doubling.
* Every append bumps an integer ``version`` and records the row/item
  watermarks, so result caches can key on ``version`` and the incremental
  miner can ask for ``delta_bits(base_version)`` — each item's row set
  restricted to the appended rows, at a cost proportional to the delta, not
  the history.
* ``device_bits()`` keeps the current full bitset matrix resident on the JAX
  device(s) (one placement per version, through the placement's
  ``put_bits`` — single-device upload or mesh word-sharding), so
  back-to-back mining requests at the same version skip the host->device
  transfer.
* Long-lived streams accumulate append-block bookkeeping (one version
  watermark per append, capacity slack from amortised doubling);
  ``compact()`` coalesces them into a consolidated base — old watermarks
  beyond ``keep_versions`` are dropped and the backing arrays are trimmed to
  snug tile-aligned capacity. ``delta_bits``/``rows_at`` semantics are
  preserved for every retained version; the incremental miner falls back to
  a cold mine when its base version was compacted away (``has_version``).

Item ids are append-ordered and **stable across versions** — a mined
itemset's ids stay meaningful after later appends, which is what lets cached
results be recounted instead of re-derived.

Process sharding (the multi-host fleet)
---------------------------------------

With ``shard=(pid, nproc)`` each process stores only the word **stripes** it
owns: the global word axis is cut into ``word_tile``-wide stripes assigned
round-robin (stripe ``s`` belongs to process ``s % nproc``), and the global
padded width is kept a multiple of ``word_tile * nproc`` so every process
holds exactly ``1/nproc`` of the words — identical local shapes keep the
lockstep mining loop's batch sizing process-invariant. ``append`` receives
the full fanned-out row block on every process (metadata — item ids, freq,
min_row, watermarks — is computed globally and bit-identically everywhere)
but **itemizes only its own row range** into local tiles; per-host
WAL/snapshot durability (``export_state``) persists local stripes only.
Popcounts over local bits are partial supports; the fleet placement's
all-reduce over the DCN axis is the only cross-host mining collective.
``word_map()`` exposes the local->global word mapping consumers need to
translate bit positions back to row ids.
"""

from __future__ import annotations

import math
import threading

import numpy as np

from ..core.items import WORD_BITS, ItemTable

__all__ = ["DatasetStore", "mask_delta_words", "mask_delta_words_local"]

_MIN_ITEM_CAP = 64
_MIN_WORD_CAP = 8


def mask_delta_words(bits: np.ndarray, base_rows: int) -> tuple[np.ndarray, int]:
    """Slice a (t, W) bitset matrix down to the words covering rows >=
    ``base_rows``, masking off the straddling word's pre-existing bits.

    Returns ``(delta bits (t, W_delta) uint32, word_lo)``; popcounts over the
    result are exact delta supports. Shared by :meth:`DatasetStore.delta_bits`
    (live store) and the incremental miner (immutable snapshots)."""
    word_lo = base_rows // WORD_BITS
    sub = bits[:, word_lo:].copy()
    keep = base_rows % WORD_BITS
    if keep:
        sub[:, 0] &= np.uint32(0xFFFFFFFF) << np.uint32(keep)
    return sub, word_lo


def mask_delta_words_local(
    bits: np.ndarray, base_rows: int, word_map: np.ndarray
) -> np.ndarray:
    """Sharded-store analogue of :func:`mask_delta_words`: zero the bits of
    rows below ``base_rows`` **in place of slicing** — a process-sharded
    matrix keeps its full local width because delta words are scattered
    round-robin across processes, not contiguous. ``word_map`` is the
    store's local->global word mapping; popcounts over the result are exact
    *partial* delta supports (sum across the fleet for the global count)."""
    word_map = np.asarray(word_map)
    boundary = base_rows // WORD_BITS
    sub = np.ascontiguousarray(bits).copy()
    sub[:, word_map < boundary] = 0
    keep = base_rows % WORD_BITS
    if keep:
        sub[:, word_map == boundary] &= np.uint32(0xFFFFFFFF) << np.uint32(keep)
    return sub


class DatasetStore:
    """Append-only itemized dataset with versioned snapshots.

    Thread-safe for interleaved appends and reads (one lock; appends are
    rare and cheap relative to mining).
    """

    def __init__(
        self,
        n_cols: int,
        *,
        word_tile: int = _MIN_WORD_CAP,
        placement=None,
        compact_threshold: int | None = None,
        keep_versions: int = 8,
        shard: tuple[int, int] | None = None,
    ):
        if n_cols <= 0:
            raise ValueError(f"n_cols must be positive, got {n_cols}")
        if word_tile <= 0:
            raise ValueError(f"word_tile must be positive, got {word_tile}")
        if shard is not None:
            pid, nproc = int(shard[0]), int(shard[1])
            if nproc <= 0 or not (0 <= pid < nproc):
                raise ValueError(f"shard must be (pid, nproc) with 0 <= pid < nproc, got {shard}")
            shard = (pid, nproc)
        if keep_versions <= 0:
            raise ValueError(f"keep_versions must be positive, got {keep_versions}")
        if compact_threshold is not None and compact_threshold <= keep_versions + 1:
            # a compaction retains keep_versions+1 watermarks; a smaller
            # threshold would re-trigger on every append (compaction thrash)
            raise ValueError(
                f"compact_threshold must exceed keep_versions + 1 "
                f"({keep_versions + 1}), got {compact_threshold}"
            )
        self.n_cols = int(n_cols)
        self.placement = placement
        if placement is not None:
            # itemize straight into per-shard word tiles: the padded width is
            # always placeable (mesh word-sharding) with zero re-packing
            ptile = int(getattr(placement, "store_word_tile", 1) or 1)
            word_tile = word_tile * ptile // math.gcd(word_tile, ptile)
        self.word_tile = int(word_tile)
        # (pid, nproc) stripe ownership; (0, 1) is the identity sharding —
        # deliberately the same code path, so loopback fleets exercise the
        # stripe math in ordinary single-process tests
        self.shard = shard or (0, 1)
        self.compact_threshold = compact_threshold
        self.keep_versions = int(keep_versions)
        self.compactions = 0
        self.n_rows = 0
        self.version = 0
        self._n_items = 0
        self._n_words = 0  # current LOCAL padded width (multiple of word_tile)
        self._n_words_global = 0  # nproc * local width (== local unsharded)
        self._id_of: dict[tuple[int, int], int] = {}  # (col, value) -> item id
        cap = _MIN_ITEM_CAP
        self._value = np.zeros(cap, dtype=np.int64)
        self._col = np.zeros(cap, dtype=np.int64)
        self._freq = np.zeros(cap, dtype=np.int64)
        self._min_row = np.zeros(cap, dtype=np.int64)
        self._bits = np.zeros((cap, word_tile), dtype=np.uint32)
        # version -> (n_rows, n_items) watermarks; version 0 = empty store
        self._watermarks: dict[int, tuple[int, int]] = {0: (0, 0)}
        self._device: dict[int, object] = {}  # version -> device bits
        self._lock = threading.RLock()

    @classmethod
    def from_dataset(cls, dataset: np.ndarray, **kw) -> "DatasetStore":
        dataset = np.asarray(dataset)
        if dataset.ndim != 2:
            raise ValueError(f"dataset must be 2-D, got shape {dataset.shape}")
        store = cls(dataset.shape[1], **kw)
        store.append(dataset)
        return store

    @property
    def n_items(self) -> int:
        return self._n_items

    @property
    def n_words(self) -> int:
        return self._n_words

    def nbytes(self) -> int:
        return self._bits.nbytes

    def stats(self) -> dict:
        """One locked read of every ``/stats`` store field — an in-flight
        append can't tear the view (version bumped but n_rows not yet, a
        row count from one version and a byte count from another)."""
        with self._lock:
            return {
                "version": self.version,
                "n_rows": self.n_rows,
                "n_items": self._n_items,
                "n_words": self._n_words,
                "word_tile": self.word_tile,
                "bitset_bytes": self._bits.nbytes,
                "compactions": self.compactions,
                "shard": list(self.shard),
                "n_words_global": self._n_words_global,
            }

    def word_map(self, n_words: int | None = None) -> np.ndarray:
        """Local->global word index mapping (int64, length ``n_words``).

        Entry ``lw`` is the global word index this process's local word ``lw``
        holds; under the identity shard (0, 1) this is ``arange(n_words)``.
        The mapping is a pure function of the index (prefix-stable as the
        store grows), so callers holding an older snapshot pass that
        snapshot's ``n_words``. Consumers use it to translate local bit
        positions back to row ids and to mask delta words
        (:func:`mask_delta_words_local`)."""
        with self._lock:
            pid, nproc = self.shard
            lw = np.arange(
                self._n_words if n_words is None else int(n_words), dtype=np.int64
            )
            stripe = lw // self.word_tile
            return (stripe * nproc + pid) * self.word_tile + lw % self.word_tile

    def watermark_digest(self) -> bytes:
        """Cheap process-invariant digest of the version watermarks.

        Every fleet process computes this from purely global metadata
        (versions, row/item watermarks — never the local bits), so after a
        fanned-out append the coordinator can all-gather digests and assert
        the processes agree before mining against the new version."""
        with self._lock:
            versions = sorted(self._watermarks)
            payload = np.asarray(
                [self.version, self.n_rows, self._n_items, self._n_words_global]
                + [x for v in versions for x in (v, *self._watermarks[v])],
                dtype=np.int64,
            )
            return payload.tobytes()

    # -- growth -------------------------------------------------------------

    def _grow(self, items_needed: int, words_needed: int) -> None:
        item_cap, word_cap = self._bits.shape
        new_items = item_cap
        while new_items < items_needed:
            new_items *= 2
        new_words = max(word_cap, _MIN_WORD_CAP)
        while new_words < words_needed:
            new_words *= 2
        if new_items == item_cap and new_words == word_cap:
            return
        bits = np.zeros((new_items, new_words), dtype=np.uint32)
        bits[:item_cap, :word_cap] = self._bits
        self._bits = bits
        if new_items != item_cap:
            for name in ("_value", "_col", "_freq", "_min_row"):
                arr = getattr(self, name)
                grown = np.zeros(new_items, dtype=arr.dtype)
                grown[:item_cap] = arr
                setattr(self, name, grown)

    # -- append -------------------------------------------------------------

    def append(self, rows: np.ndarray) -> int:
        """Append a row block; itemizes only the block. Returns the new version."""
        rows = np.asarray(rows)
        if rows.ndim == 1:
            rows = rows[None, :]
        if rows.ndim != 2 or rows.shape[1] != self.n_cols:
            raise ValueError(
                f"rows must be (d, {self.n_cols}), got shape {rows.shape}"
            )
        d = rows.shape[0]
        if d == 0:
            return self.version
        with self._lock:
            base = self.n_rows
            total = base + d
            pid, nproc = self.shard
            # the global width stays a multiple of word_tile * nproc so the
            # round-robin stripes divide exactly: every process's local
            # width is identical, keeping lockstep batch sizing in sync
            unit = self.word_tile * nproc
            words_exact = (total + WORD_BITS - 1) // WORD_BITS
            n_words_global = ((words_exact + unit - 1) // unit) * unit
            n_words = n_words_global // nproc

            global_rows = base + np.arange(d, dtype=np.int64)
            gw = global_rows // WORD_BITS
            gb = (global_rows % WORD_BITS).astype(np.uint32)
            stripe = gw // self.word_tile
            # row-range ownership: this process itemizes only rows landing
            # in its own stripes ((0, 1) shards own everything)
            own = (stripe % nproc) == pid
            lw = (stripe // nproc) * self.word_tile + gw % self.word_tile

            for j in range(self.n_cols):
                colv = rows[:, j]
                uniq, inverse, counts = np.unique(
                    colv, return_inverse=True, return_counts=True
                )
                ids = np.empty(len(uniq), dtype=np.int64)
                for u, v in enumerate(uniq):
                    key = (j, int(v))
                    item = self._id_of.get(key)
                    if item is None:
                        item = self._n_items
                        self._grow(item + 1, n_words)
                        self._id_of[key] = item
                        self._n_items = item + 1
                        self._value[item] = int(v)
                        self._col[item] = j
                        self._freq[item] = 0
                        self._min_row[item] = np.iinfo(np.int64).max
                    ids[u] = item
                self._grow(self._n_items, n_words)
                item_ids = ids[inverse]  # (d,)
                np.bitwise_or.at(
                    self._bits, (item_ids[own], lw[own]), np.uint32(1) << gb[own]
                )
                self._freq[ids] += counts
                # first occurrence per unique value within this block
                order = np.argsort(inverse, kind="stable")
                starts = np.zeros(len(uniq), dtype=np.int64)
                starts[1:] = np.cumsum(counts)[:-1]
                first_rows = global_rows[order][starts]
                self._min_row[ids] = np.minimum(self._min_row[ids], first_rows)

            self._n_words = max(self._n_words, n_words)
            self._n_words_global = max(self._n_words_global, n_words_global)
            self.n_rows = total
            self.version += 1
            self._watermarks[self.version] = (self.n_rows, self._n_items)
            self._device.clear()
            if (
                self.compact_threshold is not None
                and len(self._watermarks) > self.compact_threshold
            ):
                self._compact_locked(self.keep_versions)
            return self.version

    # -- compaction ---------------------------------------------------------

    def compact(self, keep_versions: int | None = None) -> dict:
        """Coalesce accumulated append blocks into a consolidated base.

        Retains the newest ``keep_versions`` append versions plus one
        consolidated base watermark; everything older is folded into the base
        (those per-version deltas are no longer addressable — ``has_version``
        turns False and the incremental miner re-mines cold). Doubling-growth
        capacity slack of the backing arrays is trimmed back to snug
        tile-aligned sizes when at least a quarter of the allocation is
        slack (so steady append streams never realloc-thrash). Everything
        observable about the *retained* versions — ``rows_at``/``items_at``
        watermarks, ``delta_bits`` masks, item ids, supports — is unchanged.
        """
        if keep_versions is not None and keep_versions <= 0:
            raise ValueError(f"keep_versions must be positive, got {keep_versions}")
        with self._lock:
            return self._compact_locked(
                self.keep_versions if keep_versions is None else keep_versions
            )

    def _compact_locked(self, keep: int) -> dict:
        floor = self.version - keep
        dropped = [v for v in self._watermarks if v < floor]
        for v in dropped:
            del self._watermarks[v]
        freed = 0
        item_cap, word_cap = self._bits.shape
        snug_items = max(_MIN_ITEM_CAP, self._n_items)
        snug_words = max(self.word_tile, self._n_words)
        if snug_items * snug_words <= (item_cap * word_cap * 3) // 4:
            bits = np.zeros((snug_items, snug_words), dtype=np.uint32)
            bits[: self._n_items, : self._n_words] = self._bits[
                : self._n_items, : self._n_words
            ]
            freed = self._bits.nbytes - bits.nbytes
            self._bits = bits
            if snug_items < item_cap:
                for name in ("_value", "_col", "_freq", "_min_row"):
                    setattr(self, name, getattr(self, name)[:snug_items].copy())
        # only the current version's placement cache stays warm
        self._device = {
            v: d for v, d in self._device.items() if v == self.version
        }
        self.compactions += 1
        return {
            "dropped_versions": len(dropped),
            "retained_versions": len(self._watermarks),
            "freed_bytes": int(freed),
        }

    def has_version(self, version: int) -> bool:
        """Is this version's watermark still addressable (not compacted away)?"""
        with self._lock:
            return version in self._watermarks

    # -- durability (snapshot state for repro.service.wal) -------------------

    def export_state(self) -> dict:
        """Everything observable about the store as a flat pytree of arrays
        and scalars — the payload a durable snapshot persists. Capacity
        slack from amortised doubling is deliberately not captured (it is
        not observable); ``from_state`` rebuilds snug arrays."""
        with self._lock:
            t, w = self._n_items, self._n_words
            versions = sorted(self._watermarks)
            return {
                "n_cols": int(self.n_cols),
                "word_tile": int(self.word_tile),
                "n_rows": int(self.n_rows),
                "version": int(self.version),
                "n_items": int(t),
                "n_words": int(w),
                "n_words_global": int(self._n_words_global),
                "shard_pid": int(self.shard[0]),
                "shard_nproc": int(self.shard[1]),
                "compactions": int(self.compactions),
                "value": self._value[:t].copy(),
                "col": self._col[:t].copy(),
                "freq": self._freq[:t].copy(),
                "min_row": self._min_row[:t].copy(),
                "bits": self._bits[:t, :w].copy(),
                "wm_version": np.asarray(versions, dtype=np.int64),
                "wm_rows": np.asarray(
                    [self._watermarks[v][0] for v in versions], dtype=np.int64
                ),
                "wm_items": np.asarray(
                    [self._watermarks[v][1] for v in versions], dtype=np.int64
                ),
            }

    @classmethod
    def from_state(
        cls,
        state: dict,
        *,
        placement=None,
        compact_threshold: int | None = None,
        keep_versions: int = 8,
        shard: tuple[int, int] | None = None,
    ) -> "DatasetStore":
        """Rebuild a store from :meth:`export_state` output. The recovered
        store is observably identical: item ids, bitsets, supports,
        version/watermarks — the recovery path of the durable service.

        ``placement`` must be layout-compatible with the snapshot (its word
        tile has to divide the snapshot's padded width); recovering a store
        onto a placement with a coarser word tile raises rather than
        silently re-pack bits. A process-sharded snapshot holds local
        stripes only and must be recovered by the same ``shard`` — each
        fleet host replays its own WAL/snapshot."""
        snap_shard = (int(state.get("shard_pid", 0)), int(state.get("shard_nproc", 1)))
        if shard is None:
            shard = snap_shard
        elif tuple(shard) != snap_shard:
            raise ValueError(
                f"snapshot was taken by shard {snap_shard} but recovery "
                f"requested shard {tuple(shard)} — local stripes are not "
                "transferable between processes"
            )
        store = cls(
            int(state["n_cols"]),
            word_tile=int(state["word_tile"]),
            placement=placement,
            compact_threshold=compact_threshold,
            keep_versions=keep_versions,
            shard=shard,
        )
        t, w = int(state["n_items"]), int(state["n_words"])
        if w % store.word_tile != 0:
            raise ValueError(
                f"snapshot word width {w} is not a multiple of the "
                f"placement-aligned word tile {store.word_tile} — the store "
                "was snapshotted under an incompatible placement"
            )
        store._grow(max(t, 1), max(w, store.word_tile))
        store._n_items = t
        store._n_words = w
        store._n_words_global = int(state.get("n_words_global", w))
        store.n_rows = int(state["n_rows"])
        store.version = int(state["version"])
        store.compactions = int(state["compactions"])
        for name in ("value", "col", "freq", "min_row"):
            getattr(store, f"_{name}")[:t] = np.asarray(state[name], dtype=np.int64)
        store._bits[:t, :w] = np.asarray(state["bits"], dtype=np.uint32)
        store._id_of = {
            (int(store._col[i]), int(store._value[i])): i for i in range(t)
        }
        store._watermarks = {
            int(v): (int(r), int(it))
            for v, r, it in zip(
                np.asarray(state["wm_version"]),
                np.asarray(state["wm_rows"]),
                np.asarray(state["wm_items"]),
            )
        }
        return store

    # -- snapshots ----------------------------------------------------------

    def item_table(self, *, snapshot: bool = True) -> ItemTable:
        """Current table as the miner's :class:`ItemTable`.

        ``snapshot=True`` (default) copies under the store lock, so the
        returned table is immutable even while later appends mutate the
        store in place — that is what lets a long mining run proceed
        concurrently with ``/append`` traffic. ``snapshot=False`` returns
        zero-copy views for read-only single-threaded use (tests, benches).

        ``n_words`` is the padded tile width; the pad words are zero, which
        every consumer (popcount, AND, preprocess hashing) treats as "row
        absent", so padding is semantically invisible.
        """
        with self._lock:
            t, w = self._n_items, self._n_words
            take = (lambda a: a.copy()) if snapshot else (lambda a: a)
            return ItemTable(
                n_rows=self.n_rows,
                n_cols=self.n_cols,
                n_words=w,
                value=take(self._value[:t]),
                col=take(self._col[:t]),
                freq=take(self._freq[:t]),
                min_row=take(self._min_row[:t]),
                bits=take(self._bits[:t, :w]),
            )

    def snapshot(self) -> tuple[int, ItemTable]:
        """Atomic ``(version, immutable item table)`` pair — the unit a
        mining request operates on, immune to appends landing mid-run."""
        with self._lock:
            return self.version, self.item_table(snapshot=True)

    def rows_at(self, version: int) -> int:
        return self._watermarks[version][0]

    def items_at(self, version: int) -> int:
        return self._watermarks[version][1]

    def delta_bits(self, base_version: int) -> tuple[np.ndarray, int]:
        """Per-item bitsets restricted to rows appended after ``base_version``.

        Returns ``(bits (n_items, W_delta) uint32, word_lo)`` where
        ``word_lo`` is the first word index covered. Bits belonging to rows
        that already existed at ``base_version`` are masked off, so popcounts
        over the returned slice are exact delta supports. Cost is
        O(n_items * W_delta) — proportional to the appended rows, not to the
        history.
        """
        with self._lock:
            base_rows = self.rows_at(base_version)
            view = self._bits[: self._n_items, : self._n_words]
            if self.shard[1] > 1:
                # sharded words are round-robin striped, not contiguous:
                # keep the full local width (word_lo = 0) and zero the
                # pre-existing rows' words instead of slicing them off
                return mask_delta_words_local(view, base_rows, self.word_map()), 0
            return mask_delta_words(view, base_rows)

    def device_bits(self, version: int | None = None):
        """Full bitset matrix placed for the store's placement, once per
        version and shared by every mining request at that version (the
        device placements' level-1 bits are a device-side gather of this
        array). With a ``MeshPlacement`` this is the word-sharded resident
        copy — the store's tile alignment guarantees zero re-packing.

        ``version`` pins the expected store version: if appends have already
        moved the store past it, returns None and the caller falls back to
        uploading its own snapshot.
        """
        with self._lock:
            if version is not None and version != self.version:
                return None
            cached = self._device.get(self.version)
            if cached is None:
                view = self._bits[: self._n_items, : self._n_words]
                if self.placement is not None:
                    cached = self.placement.put_bits(view)
                else:
                    import jax.numpy as jnp

                    cached = jnp.asarray(view)
                self._device[self.version] = cached
            return cached
