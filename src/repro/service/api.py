"""The resident mining service facade.

``MiningService`` glues the subsystem together into the workflow the paper
motivates (a custodian continuously vetting a growing table):

    service = MiningService.from_dataset(D, engine="numpy")
    service.mine(tau=1, kmax=3)          # cold: preprocess + Algorithm 1
    service.mine(tau=1, kmax=3)          # warm: LRU hit on (version, ...)
    service.append(new_rows)             # itemizes only the block
    service.mine(tau=1, kmax=3)          # incremental: recount + boundary
    service.report(tau=1, kmax=3)        # sdc quasi-identifier summary
    service.risk(tau=1, kmax=3)          # per-record risk (coverage kernels)
    service.anonymize_plan(tau=1)        # verified zero-QI masking plan

Request flow for ``mine``: snapshot the store (atomic version + immutable
table) -> result-cache lookup -> request scheduler (concurrent identical
requests coalesce onto one run) -> incremental delta mine against the
newest cached base for the same parameters, falling back to a cold
``mine_preprocessed`` when the delta invariants don't hold. Preprocessed
tables are themselves cached per ``(version, tau, ordering, seed)`` so a
cold run at a warm version skips §4.1 preprocessing, and all runs share the
process-wide executable buckets (``kernels.intersect.ops.EXEC_CACHE``).
"""

from __future__ import annotations

import dataclasses
import os
import pickle
import threading
import time
from collections import OrderedDict
from concurrent.futures import TimeoutError as FutureTimeoutError

import numpy as np

from ..core.items import ItemTable
from ..core.kyiv import KyivConfig, MiningResult, RunControl, mine_preprocessed
from ..core.placement import HostPlacement, is_device_failure, resolve_placement
from ..core.preprocess import preprocess
from ..core import exec_cache
from ..obs import cost as _obs_cost
from ..obs import flight as _obs_flight
from ..obs import metrics as _om
from ..obs.trace import TRACER as _obs_tracer
from ..obs.trace import current_trace_id as _obs_current_trace_id
from ..obs.trace import span as _obs_span
from ..obs.trace import start_trace as _obs_start_trace
from ..distributed.checkpoint import CheckpointManager
from ..kernels.intersect import LevelPipeline
from ..sampling import SamplingConfig, build_sample, classify_counts
from ..sampling.refine import recount_supports
from ..sdc.quasi import QuasiIdentifierReport, report_as_dict
from .cache import CacheEntry, ResultCache, make_approx_key, make_key
from .faults import NULL_INJECTOR
from .incremental import IncrementalConfig, ResultBands, mine_incremental
from .resilience import CircuitBreaker, ResilienceConfig
from .scheduler import RequestScheduler
from .store import DatasetStore
from .wal import DurableStore

__all__ = [
    "MineResponse",
    "MiningService",
    "NotReadyError",
    "DeadlineExceeded",
]

_PREP_CACHE_CAPACITY = 8

_MINE_REQUESTS = _om.counter(
    "repro_service_mine_requests_total",
    "Answered mine requests by answer source.",
    ("source",),
)
_MINE_LATENCY = _om.histogram(
    "repro_service_mine_latency_seconds",
    "End-to-end mine request latency by answer source.",
    ("source",),
)
_APPENDS = _om.counter(
    "repro_service_appends_total", "Dataset append requests served."
)
_APPENDED_ROWS = _om.counter(
    "repro_service_appended_rows_total", "Rows appended to the store."
)
_PREPROCESS_SECONDS = _om.histogram(
    "repro_service_preprocess_seconds",
    "Cold §4.1 preprocessing time (prep-cache misses only).",
)
_SAMPLING_MINES = _om.counter(
    "repro_sampling_mines_total",
    "Approx mine requests answered, by answer source.",
    ("source",),
)
_SAMPLING_SAMPLE_SECONDS = _om.histogram(
    "repro_sampling_sample_mine_seconds",
    "Sample-mine wall time (sampling + preprocess + level mining).",
)
_SAMPLING_SAMPLE_ROWS = _om.histogram(
    "repro_sampling_sample_rows", "Rows drawn per sample mine."
)
_SAMPLING_BOUNDARY = _om.counter(
    "repro_sampling_boundary_itemsets_total",
    "Sample-mined itemsets classified into the undecidable boundary band.",
)
_SAMPLING_REFINEMENTS = _om.counter(
    "repro_sampling_refinements_total",
    "Background exact refinements, by outcome.",
    ("status",),
)
_SAMPLING_REFINE_SECONDS = _om.histogram(
    "repro_sampling_refine_seconds",
    "Background refinement wall time (boundary recount + exact promotion).",
)


class NotReadyError(RuntimeError):
    """The service is still recovering (WAL replay / job resume) — liveness
    is fine, readiness is not; HTTP maps this to 503."""


class DeadlineExceeded(TimeoutError):
    """A coalesced waiter's deadline expired before the shared run finished.
    The run itself keeps going for waiters without a deadline; HTTP maps
    this to 499."""


class _LruCache:
    """Tiny thread-safe LRU for derived privacy payloads (risk profiles and
    anonymization plans), keyed beside the mining result cache on
    ``(kind, version, tau, kmax, ordering)`` — cheap to rebuild relative to
    mining, so it stays separate from (and smaller than) the result LRU."""

    def __init__(self, capacity: int = 32):
        self.capacity = capacity
        self._entries: "OrderedDict[tuple, object]" = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def get(self, key: tuple):
        with self._lock:
            value = self._entries.get(key)
            if value is None:
                self.misses += 1
                return None
            self._entries.move_to_end(key)
            self.hits += 1
            return value

    def put(self, key: tuple, value) -> None:
        with self._lock:
            self._entries[key] = value
            self._entries.move_to_end(key)
            while len(self._entries) > self.capacity:
                self._entries.popitem(last=False)

    def stats(self) -> dict:
        with self._lock:
            return {
                "entries": len(self._entries),
                "capacity": self.capacity,
                "hits": self.hits,
                "misses": self.misses,
            }


@dataclasses.dataclass
class MineResponse:
    """One answered mining request."""

    version: int
    tau: int
    kmax: int
    ordering: str
    source: str  # "cache" | "incremental" | "cold"
    latency_s: float
    result: MiningResult
    info: dict

    @property
    def n_itemsets(self) -> int:
        return len(self.result.itemsets)

    def to_json(self, max_itemsets: int | None = None) -> dict:
        sets = self.result.as_value_sets()
        truncated = max_itemsets is not None and len(sets) > max_itemsets
        if truncated:
            sets = sets[:max_itemsets]
        return {
            "version": self.version,
            "tau": self.tau,
            "kmax": self.kmax,
            "ordering": self.ordering,
            "source": self.source,
            "latency_s": self.latency_s,
            "n_itemsets": self.n_itemsets,
            "truncated": truncated,
            "itemsets": [
                {"items": [[int(c), int(v)] for c, v in ids], "count": int(cnt)}
                for ids, cnt in sets
            ],
            "info": self.info,
        }


class MiningService:
    """Thread-safe facade over store + cache + scheduler + miners."""

    def __init__(
        self,
        n_cols: int | None = None,
        *,
        config: KyivConfig | None = None,
        incremental: IncrementalConfig | None = None,
        placement=None,
        cache_capacity: int = 64,
        cache_max_bytes: int | None = None,
        max_workers: int = 1,
        word_tile: int = 8,
        compact_threshold: int | None = None,
        keep_versions: int = 8,
        wal_dir: str | None = None,
        snapshot_every: int = 8,
        job_checkpoint_levels: int = 1,
        deadline_grace_s: float = 2.0,
        fault_injector=None,
        resilience: ResilienceConfig | None = None,
        defer_recovery: bool = False,
        profile_dir: str | None = None,
        sampling: SamplingConfig | None = None,
        slow_mine_threshold_s: float = 1.0,
        slow_log_size: int = 64,
        flight_enabled: bool = True,
        flight_fsync_s: float = 0.25,
        flight_max_bytes: int = 1 << 20,
        **config_kw,
    ):
        self.config = config or KyivConfig(**config_kw)
        if placement is not None:
            self.config = dataclasses.replace(self.config, placement=placement)
        # one resolved placement per service: the store tiles its words for
        # it and every mining request's LevelPipeline dispatches through it
        self.placement = resolve_placement(self.config)
        self.config = dataclasses.replace(self.config, placement=self.placement)
        self.incremental = incremental or IncrementalConfig()
        self.word_tile = word_tile
        self._store_kw = dict(
            word_tile=word_tile,
            placement=self.placement,
            compact_threshold=compact_threshold,
            keep_versions=keep_versions,
            # fleet placements carry (pid, nproc): the store keeps only this
            # process's word stripes and global padding stays process-invariant
            shard=getattr(self.placement, "shard", None),
        )
        self.injector = fault_injector or NULL_INJECTOR
        self.resilience = resilience or ResilienceConfig()
        self.breaker = CircuitBreaker(
            self.resilience.failure_threshold, self.resilience.cooldown_s
        )
        self.wal_dir = wal_dir
        self.job_checkpoint_levels = max(1, int(job_checkpoint_levels))
        self.deadline_grace_s = deadline_grace_s
        # forensics: parse the *previous* incarnation's flight ring into a
        # LastCrashReport before opening this incarnation's (which reaps the
        # old segment files), then hook the recorder into the tracer and the
        # breaker. No wal_dir -> no ring (the recorder is crash forensics;
        # an in-memory service has nothing to survive into).
        self.slowlog = _obs_cost.SlowMineLog(slow_mine_threshold_s, slow_log_size)
        self.flight: _obs_flight.FlightRecorder | None = None
        self.last_crash: _obs_flight.LastCrashReport | None = None
        if wal_dir is not None and flight_enabled:
            flight_dir = os.path.join(wal_dir, "flight")
            self.last_crash = _obs_flight.recover(flight_dir)
            self.flight = _obs_flight.FlightRecorder(
                flight_dir,
                fsync_interval_s=flight_fsync_s,
                max_bytes=flight_max_bytes,
            )
            _obs_tracer.add_listener(self.flight.span_listener)
            self.breaker.on_transition = (
                lambda state: self._flight_record("breaker.transition", state=state)
            )
        self._durable: DurableStore | None = (
            DurableStore(
                wal_dir,
                snapshot_every=snapshot_every,
                injector=self.injector,
                recorder=self.flight,
                **self._store_kw,
            )
            if wal_dir is not None
            else None
        )
        self._store: DatasetStore | None = (
            DatasetStore(n_cols, **self._store_kw)
            if n_cols and self._durable is None
            else None
        )
        self.cache = ResultCache(cache_capacity, max_bytes=cache_max_bytes)
        self.scheduler = RequestScheduler(max_workers=max_workers)
        self._preps: "OrderedDict[tuple, object]" = OrderedDict()
        self._privacy = _LruCache()
        self._last_mine_timing: dict | None = None
        self._lock = threading.Lock()
        self._ready = threading.Event()
        self._controls: dict[tuple, RunControl] = {}
        self._recovery_info: dict | None = None
        self._drain_info: dict | None = None
        self.served = 0
        self.device_retries = 0
        self.degraded_mines = 0
        self.resumed_jobs = 0
        self.sampling = sampling or SamplingConfig()
        # plain-int counters + a last-request snapshot dict: written under
        # self._lock, read lock-free by /stats and the scrape collector
        self._sampling_stats = {
            "approx_served": 0,
            "sampled_mines": 0,
            "refinements": 0,
            "refine_failures": 0,
            "recount_bucket_hits": 0,
            "recount_bucket_misses": 0,
            "last": None,
        }
        self.profile_dir = profile_dir
        # scrape-time mirror of the component stats dicts into the one
        # registry; named, so the newest service instance owns the slot
        self._collector_fn = self._collect_metrics
        _om.REGISTRY.register_collector("service", self._collector_fn)
        exec_cache.publish_metrics()
        if self.flight is not None:
            # first durable event: the resolved config this incarnation runs
            # with — the postmortem's "what was it configured to do"
            self.flight.record("config", config=self._resolved_config())
            if self.last_crash is not None and not self.last_crash.clean_shutdown:
                from ..obs import logs as _obs_logs

                _obs_logs.get_logger("repro.service").warning(
                    "previous incarnation died uncleanly: %d open span(s), "
                    "last checkpointed level %s — GET /debug/lastcrash for "
                    "the full report",
                    len(self.last_crash.open_spans),
                    (self.last_crash.last_checkpoint or {}).get("level"),
                )
        if not defer_recovery:
            self.recover()

    def _flight_record(self, kind: str, **fields) -> None:
        if self.flight is not None:
            self.flight.record(kind, **fields)

    def _account_cost(
        self,
        env: _obs_cost.CostEnvelope,
        source: str,
        version: int,
        tau: int,
        kmax: int,
        latency: float,
    ) -> dict:
        """Finish a request's envelope: stamp the serving path, publish the
        per-path cost histograms (trace_id as exemplar) and offer the entry
        to the slow-mine log. Returns the ``info.cost`` dict."""
        env.note(path=source, version=int(version))
        env.finish()
        env.wall_s = latency
        _obs_cost.publish(env)
        self.slowlog.offer(env, tau=int(tau), kmax=int(kmax))
        return env.to_dict()

    def _resolved_config(self) -> dict:
        """The effective configuration this incarnation serves with — the
        flight ring's startup event and the debug bundle's config section."""
        cfg = {
            f.name: getattr(self.config, f.name)
            for f in dataclasses.fields(self.config)
        }
        cfg["placement"] = self.placement.kind
        return {
            "mining": cfg,
            "wal_dir": self.wal_dir,
            "job_checkpoint_levels": self.job_checkpoint_levels,
            "deadline_grace_s": self.deadline_grace_s,
            "cache": {
                "capacity": self.cache.capacity,
                "max_bytes": self.cache.max_bytes,
            },
            "resilience": {
                "max_retries": self.resilience.max_retries,
                "failure_threshold": self.resilience.failure_threshold,
                "cooldown_s": self.resilience.cooldown_s,
            },
            "sampling": {
                "epsilon": self.sampling.epsilon,
                "delta": self.sampling.delta,
                "seed": self.sampling.seed,
            },
            "slow_mine_threshold_s": self.slowlog.threshold_s,
            "flight": (
                {
                    "fsync_interval_s": self.flight.fsync_interval_s,
                    "max_bytes": self.flight.max_bytes,
                    "incarnation": self.flight.incarnation,
                }
                if self.flight is not None
                else None
            ),
        }

    @classmethod
    def from_dataset(cls, dataset: np.ndarray, **kw) -> "MiningService":
        dataset = np.asarray(dataset)
        service = cls(dataset.shape[1], **kw)
        service.append(dataset)
        return service

    # -- readiness / recovery ------------------------------------------------

    @property
    def ready(self) -> bool:
        return self._ready.is_set()

    def readiness(self) -> tuple[bool, str]:
        """(ready, reason). Not ready while recovering, and while the
        circuit breaker is open (the service still *answers*, degraded to
        host — but load balancers should prefer healthy replicas)."""
        if not self._ready.is_set():
            return False, "recovering"
        if self.breaker.state == "open":
            return False, "circuit_breaker_open"
        return True, "ok"

    def _require_ready(self) -> None:
        if not self._ready.is_set():
            raise NotReadyError("service is recovering — retry shortly")

    def recover(self) -> dict | None:
        """Replay durability state (WAL + snapshots), resume interrupted
        mine jobs, then flip ready. Without a ``wal_dir`` this just marks
        the service ready."""
        info = None
        if self._durable is not None:
            info = self._durable.recover()
            with self._lock:
                self._store = self._durable.store
            info["resumed_jobs"] = self._resume_jobs()
            self._recovery_info = info
        self._ready.set()
        return info

    # -- store --------------------------------------------------------------

    @property
    def store(self) -> DatasetStore:
        if self._store is None:
            raise RuntimeError("service has no data yet — append rows first")
        return self._store

    def append(self, rows: np.ndarray) -> dict:
        self._require_ready()
        rows = np.asarray(rows)
        if rows.ndim == 1:
            rows = rows[None, :]
        with _obs_span("service.append", rows=int(rows.shape[0])):
            if self._durable is not None:
                version = self._durable.append(rows)
                with self._lock:
                    self._store = self._durable.store
            else:
                with self._lock:
                    if self._store is None:
                        self._store = DatasetStore(rows.shape[1], **self._store_kw)
                version = self.store.append(rows)
        _APPENDS.inc()
        _APPENDED_ROWS.inc(int(rows.shape[0]))
        return {
            "version": version,
            "appended": int(rows.shape[0]),
            "n_rows": self.store.n_rows,
            "n_items": self.store.n_items,
        }

    # -- mining -------------------------------------------------------------

    def _request_config(self, tau: int, kmax: int, ordering: str) -> KyivConfig:
        return dataclasses.replace(
            self.config, tau=tau, kmax=kmax, ordering=ordering
        )

    def _prep_for(self, version: int, table: ItemTable, config: KyivConfig):
        key = (version, config.tau, config.ordering, config.seed)
        with self._lock:
            prep = self._preps.get(key)
            if prep is not None:
                self._preps.move_to_end(key)
                return prep
        t0 = time.perf_counter()
        with _obs_span("mine.preprocess", version=version, tau=config.tau):
            prep = preprocess(
                table, config.tau, ordering=config.ordering, seed=config.seed
            )
        _PREPROCESS_SECONDS.observe(time.perf_counter() - t0)
        with self._lock:
            self._preps[key] = prep
            while len(self._preps) > _PREP_CACHE_CAPACITY:
                self._preps.popitem(last=False)
        return prep

    def _warm_pipeline_factory(self, version: int, prep, config: KyivConfig):
        """Level-pipeline factory backed by the store's per-version resident
        bitsets: level 1 becomes a device-side gather of the placed array
        (single-device upload or mesh word-sharding) instead of a fresh
        host->device transfer per request. Returns None (driver default) for
        the host placement or when appends already moved the store past
        ``version``."""
        placement = self.placement
        if placement.kind == "host":
            return None
        dev = self.store.device_bits(version)
        if dev is None:
            return None
        import jax.numpy as jnp

        l_bits_dev = dev[jnp.asarray(prep.l_items)]

        def factory(bits, counts, tau):
            if bits is prep.l_bits:  # level 1: the resident gather, bit-equal
                bits = l_bits_dev
            return LevelPipeline(
                bits,
                counts,
                tau=tau,
                placement=placement,
                fused_classify=config.fused_classify,
                locality_sort=config.locality_sort,
            )

        return factory

    # -- resumable jobs ------------------------------------------------------

    def _job_manager(self, key: tuple) -> CheckpointManager | None:
        """Per-(version, tau, kmax, ordering) mid-run checkpoint manager —
        only when the service is durable (a crash-only concern)."""
        if self._durable is None:
            return None
        version, tau, kmax, ordering = key
        name = f"v{version}_t{tau}_k{kmax}_{ordering}"
        return CheckpointManager(
            os.path.join(self.wal_dir, "jobs", name), keep=2
        )

    def _resume_jobs(self) -> int:
        """Re-issue mine runs that had level checkpoints when the process
        died. Jobs at a stale store version are dropped (their answer is no
        longer the current-version answer anyone will ask for)."""
        jobs_root = os.path.join(self.wal_dir, "jobs")
        if not os.path.isdir(jobs_root):
            return 0
        resumed = 0
        current = self._store.version if self._store is not None else 0
        for name in sorted(os.listdir(jobs_root)):
            try:
                vs, ts, ks, ordering = name.split("_", 3)
                version, tau, kmax = int(vs[1:]), int(ts[1:]), int(ks[1:])
            except (ValueError, IndexError):
                continue
            mgr = CheckpointManager(os.path.join(jobs_root, name), keep=2)
            if version != current or mgr.latest_step() is None:
                mgr.destroy()
                continue
            snap_version, table = self.store.snapshot()
            if snap_version != version:
                mgr.destroy()
                continue
            key = make_key(version, tau, kmax, ordering)
            self.scheduler.submit(key, lambda k=key, t=table: self._compute(k, t))
            resumed += 1
        self.resumed_jobs += resumed
        return resumed

    def _mine_cold(
        self,
        key: tuple,
        table: ItemTable,
        config: KyivConfig,
        control: RunControl | None,
    ) -> tuple[MiningResult, dict]:
        """Cold mine with device retries, circuit-breaker degradation to the
        host placement, and (when durable) level checkpoints for resume."""
        version, tau, kmax, ordering = key
        prep = self._prep_for(version, table, config)
        info: dict = {"n_rows": table.n_rows, "n_items": table.n_items}

        mgr = self._job_manager(key)
        on_level_end = None
        resume_state = None
        if mgr is not None:
            state_tree, _meta = mgr.restore()
            if state_tree is not None:
                resume_state = pickle.loads(
                    np.asarray(state_tree["state"], dtype=np.uint8).tobytes()
                )
                info["resumed_from_level"] = int(resume_state.next_k)

            def on_level_end(level, state, _mgr=mgr):
                if level % self.job_checkpoint_levels == 0:
                    blob = pickle.dumps(state, protocol=pickle.HIGHEST_PROTOCOL)
                    _mgr.save(
                        level,
                        {"state": np.frombuffer(blob, dtype=np.uint8)},
                        blocking=True,
                    )
                    # durable flight event — its inline fsync also carries
                    # every buffered span-open to disk, so a death right
                    # after the checkpoint still yields a ring that names
                    # the in-flight level
                    self._flight_record(
                        "job.checkpoint", level=int(level), key=list(key)
                    )
                # the kill-mid-mine seam fires *after* the save — simulated
                # death leaves the checkpoint the restart resumes from
                self.injector.check("mine.level_end")

        def run(cfg, factory):
            if self.profile_dir:
                # opt-in device profiling: xplane traces land under
                # profile_dir and the repro_profile_* gauges record the run
                from ..obs import profile as obs_profile

                with obs_profile.profile(self.profile_dir) as prof:
                    result = mine_preprocessed(
                        prep,
                        cfg,
                        pipeline_factory=factory,
                        on_level_end=on_level_end,
                        resume_state=resume_state,
                        control=control,
                    )
                    prof.set_result(result)
                return result
            return mine_preprocessed(
                prep,
                cfg,
                pipeline_factory=factory,
                on_level_end=on_level_end,
                resume_state=resume_state,
                control=control,
            )

        result: MiningResult | None = None
        if self.placement.kind != "host" and self.breaker.allow():
            delay = self.resilience.backoff_s
            attempt = 0
            while True:
                try:
                    result = run(
                        config, self._warm_pipeline_factory(version, prep, config)
                    )
                    self.breaker.record_success()
                    break
                except Exception as exc:
                    if not is_device_failure(exc):
                        raise
                    self._flight_record(
                        "dispatch.failure",
                        error=f"{type(exc).__name__}: {exc}",
                        attempt=attempt,
                        key=list(key),
                    )
                    self.breaker.record_failure()
                    attempt += 1
                    if attempt > self.resilience.max_retries or not self.breaker.allow():
                        info["device_error"] = f"{type(exc).__name__}: {exc}"
                        break
                    self.device_retries += 1
                    self.resilience.sleep(delay)
                    delay *= 2

        if result is None:
            # degraded (or plain host) path: same answer, host placement
            host_config = dataclasses.replace(
                config, placement=HostPlacement(), engine="numpy"
            )
            result = run(host_config, None)
            if self.placement.kind != "host":
                self.degraded_mines += 1
                info["degraded"] = "host"

        if mgr is not None:
            # run finished (complete or deliberately interrupted) — resume
            # state is only for crashes, which never reach this line
            mgr.destroy()
        return result, info

    def _compute(
        self, key: tuple, table: ItemTable, control: RunControl | None = None
    ) -> CacheEntry:
        # a coalesced predecessor may have finished between the caller's
        # cache miss and this run being scheduled
        entry = self.cache.get(key)
        if entry is not None:
            return entry
        version, tau, kmax, ordering = key
        config = self._request_config(tau, kmax, ordering)
        if control is not None:
            with self._lock:
                self._controls[key] = control
        # compile-vs-reuse attribution: the envelope rode the context copy
        # into this worker thread (same object the submitter holds)
        _env = _obs_cost.current()
        _xs0 = exec_cache.stats() if _env is not None else None
        try:
            # the incremental path dispatches through the device placement;
            # with the breaker open it would fail the same way the cold path
            # just did, so skip straight to the (degradable) cold path
            base = (
                self.cache.latest_base(tau, kmax, ordering, version)
                if self.placement.kind == "host" or self.breaker.allow()
                else None
            )
            if base is not None:
                try:
                    with _obs_span("mine.incremental", base_version=base.version):
                        inc = mine_incremental(
                            self.store,
                            base.result,
                            base.version,
                            config,
                            self.incremental,
                            table=table,
                            # seed expansion runs through this service's
                            # placement, over the store's resident bitsets
                            # (None -> falls back to a host snapshot gather;
                            # bit-identical either way). Host placements skip
                            # the resident copy entirely.
                            placement=self.placement,
                            resident_bits=(
                                self.store.device_bits(version)
                                if self.placement.kind != "host"
                                and self.incremental.enabled
                                else None
                            ),
                            # count-sorted recount companion persisted with
                            # the base entry: recounting touches only the
                            # near-boundary band, not all cached itemsets
                            bands=base.bands,
                        )
                except Exception as exc:
                    if not is_device_failure(exc):
                        raise
                    self._flight_record(
                        "dispatch.failure",
                        error=f"{type(exc).__name__}: {exc}",
                        site="incremental",
                        key=list(key),
                    )
                    self.breaker.record_failure()
                    inc = None
                if inc is not None:
                    result, info = inc
                    if _env is not None:
                        # the delta path never enters mine_levels, so fold
                        # its own work shape into the envelope: recounts
                        # scan the delta rows, seed expansion the full table
                        _env.add(
                            levels=len(result.stats),
                            rows_scanned=(
                                info["delta_rows"] * info["n_recounted"]
                                + result.prep.table.n_rows
                                * info["n_expanded"]
                            ),
                            candidate_pairs=info["n_seeds"],
                            itemsets_emitted=len(result.itemsets),
                        )
                    entry = CacheEntry(
                        key=key,
                        result=result,
                        source="incremental",
                        info=info,
                        bands=ResultBands.from_result(result.itemsets),
                    )
                    self.cache.put(entry)
                    return entry

            # the request key rides the span's *open* attrs so the flight
            # ring can name the active requests at death
            with _obs_span("mine.cold", version=version, key=list(key)):
                result, info = self._mine_cold(key, table, config, control)
            # per-level host-busy vs device-busy split of the last cold run —
            # the /stats view of what the device frontier buys per level
            self._last_mine_timing = {
                "version": version,
                "tau": tau,
                "kmax": kmax,
                "wall_time": result.wall_time,
                "levels": result.timing_breakdown(),
            }
            if not result.completed:
                # valid-but-incomplete answer: hand it to this run's waiters,
                # never cache it and never let the incremental miner build on it
                info["interrupted"] = result.interrupted
                return CacheEntry(key=key, result=result, source="partial", info=info)
            entry = CacheEntry(
                key=key,
                result=result,
                source="cold",
                info=info,
                bands=ResultBands.from_result(result.itemsets),
            )
            self.cache.put(entry)
            return entry
        finally:
            if _env is not None and _xs0 is not None:
                _xs1 = exec_cache.stats()
                _env.add(
                    executables_compiled=max(
                        0, _xs1.get("misses", 0) - _xs0.get("misses", 0)
                    ),
                    executables_reused=max(
                        0, _xs1.get("hits", 0) - _xs0.get("hits", 0)
                    ),
                )
            if control is not None:
                with self._lock:
                    self._controls.pop(key, None)

    def cancel(self, tau: int, kmax: int, ordering: str = "ascending") -> dict:
        """Cancel in-flight runs matching ``(tau, kmax, ordering)`` at any
        version. The run stops at its next batch boundary and its waiters
        receive the partial result."""
        cancelled = 0
        with self._lock:
            for key, ctrl in self._controls.items():
                if key[1:] == (int(tau), int(kmax), str(ordering)):
                    ctrl.cancel()
                    cancelled += 1
        return {"cancelled": cancelled}

    def mine(
        self,
        tau: int = 1,
        kmax: int = 3,
        ordering: str = "ascending",
        deadline_s: float | None = None,
        mode: str = "exact",
        epsilon: float | None = None,
    ) -> MineResponse:
        if mode not in ("exact", "approx"):
            raise ValueError(f"mode must be 'exact' or 'approx', got {mode!r}")
        if mode == "approx":
            return self._mine_approx(
                tau, kmax, ordering, deadline_s,
                self.sampling.epsilon if epsilon is None else float(epsilon),
            )
        self._require_ready()
        t0 = time.perf_counter()
        # root of the request's span tree when called directly; a child span
        # when the HTTP layer (or a planner re-mine) already opened a trace.
        # The cost envelope binds alongside it: the scheduler's context copy
        # carries the same object into the worker, so the level loop's
        # counters land here no matter which thread mines.
        with _obs_start_trace(
            "service.mine", meta={"tau": int(tau), "kmax": int(kmax)}
        ) as _tsp, _obs_cost.attach() as _cenv:
            _cenv.note(trace_id=_obs_current_trace_id())
            # warm path first: a version read + dict lookup, no snapshot copy
            version = self.store.version
            key = make_key(version, tau, kmax, ordering)
            entry = self.cache.get(key)
            source = "cache"
            if entry is None:
                # miss: take the immutable snapshot the computation will run
                # on (its version may have advanced past the first read)
                version, table = self.store.snapshot()
                key = make_key(version, tau, kmax, ordering)
                control = (
                    RunControl.with_timeout(deadline_s)
                    if deadline_s is not None
                    else RunControl()
                )
                future = self.scheduler.submit(
                    key, lambda: self._compute(key, table, control)
                )
                if deadline_s is None:
                    entry = future.result()
                else:
                    # if this request coalesced onto an earlier run, that
                    # run's control (not ours) governs it — bound the wait:
                    # the run stops within one batch of *its* deadline, and a
                    # deadline-free run releases us with DeadlineExceeded
                    try:
                        entry = future.result(
                            timeout=deadline_s + self.deadline_grace_s
                        )
                    except FutureTimeoutError:
                        _MINE_REQUESTS.inc(source="deadline")
                        raise DeadlineExceeded(
                            f"mine(tau={tau}, kmax={kmax}) exceeded "
                            f"{deadline_s}s"
                        ) from None
                source = entry.source
            self.served += 1
            latency = time.perf_counter() - t0
            _tsp.set(source=source, version=version)
            _MINE_REQUESTS.inc(source=source)
            info = dict(entry.info)
            info["cost"] = self._account_cost(
                _cenv, source, version, tau, kmax, latency
            )
            _MINE_LATENCY.observe(
                latency,
                exemplar=(
                    {"trace_id": _cenv.trace_id} if _cenv.trace_id else None
                ),
                source=source,
            )
            return MineResponse(
                version=version,
                tau=tau,
                kmax=kmax,
                ordering=ordering,
                source=source,
                latency_s=latency,
                result=entry.result,
                info=info,
            )

    # -- sampled (approximate) mining ---------------------------------------

    def _mine_approx(
        self,
        tau: int,
        kmax: int,
        ordering: str,
        deadline_s: float | None,
        epsilon: float,
    ) -> MineResponse:
        """The ε-confident fast path: mine a deterministic uniform sample,
        answer immediately with per-itemset confidence, and schedule a
        background refinement that recounts the boundary band and promotes
        the cache entry to the exact answer."""
        self._require_ready()
        if not (0.0 < epsilon < 1.0):
            raise ValueError(f"epsilon must be in (0, 1), got {epsilon}")
        t0 = time.perf_counter()
        with _obs_start_trace(
            "service.mine",
            meta={"tau": int(tau), "kmax": int(kmax), "mode": "approx"},
        ) as _tsp, _obs_cost.attach() as _cenv:
            _cenv.note(trace_id=_obs_current_trace_id())
            version = self.store.version
            akey = make_approx_key(version, tau, kmax, ordering, epsilon)
            entry = self.cache.get(akey)
            if entry is None:
                # an already-promoted exact answer at this version is
                # strictly better than re-sampling — serve it as-is
                entry = self.cache.get(make_key(version, tau, kmax, ordering))
            source = "cache"
            if entry is None:
                version, table = self.store.snapshot()
                akey = make_approx_key(version, tau, kmax, ordering, epsilon)
                future = self.scheduler.submit(
                    akey, lambda: self._compute_approx(akey, table)
                )
                if deadline_s is None:
                    entry = future.result()
                else:
                    try:
                        entry = future.result(
                            timeout=deadline_s + self.deadline_grace_s
                        )
                    except FutureTimeoutError:
                        _SAMPLING_MINES.inc(source="deadline")
                        raise DeadlineExceeded(
                            f"mine(tau={tau}, kmax={kmax}, mode=approx) "
                            f"exceeded {deadline_s}s"
                        ) from None
                source = entry.source
            self.served += 1
            with self._lock:
                self._sampling_stats["approx_served"] += 1
            latency = time.perf_counter() - t0
            _tsp.set(source=source, version=version, mode="approx")
            _MINE_REQUESTS.inc(source="approx")
            _SAMPLING_MINES.inc(source=source)
            _MINE_LATENCY.observe(
                latency,
                exemplar=(
                    {"trace_id": _cenv.trace_id} if _cenv.trace_id else None
                ),
                source="approx",
            )
            info = dict(entry.info)
            info["cost"] = self._account_cost(
                _cenv,
                "approx" if source not in ("cache", "refined") else source,
                version, tau, kmax, latency,
            )
            if "mode" not in info:
                # exact entry answering an approx request: full confidence
                info.update(
                    mode="approx", epsilon=float(epsilon), confidence=1.0,
                    boundary_count=0, refined=True,
                )
            return MineResponse(
                version=version,
                tau=tau,
                kmax=kmax,
                ordering=ordering,
                source=source,
                latency_s=latency,
                result=entry.result,
                info=info,
            )

    def _compute_approx(self, key: tuple, table: ItemTable) -> CacheEntry:
        """Sample-mine one snapshot (scheduler-side of an approx request).

        Mines the ε-sized sample with the standard level pipeline (same
        placement/engine as exact requests — the sampled table's word axis
        is padded for it), classifies every emitted itemset into certain
        vs boundary, caches the scaled-estimate answer under the approx
        key, and schedules the background refinement under the *exact* key
        so concurrent exact requests coalesce onto the promotion run."""
        entry = self.cache.get(key)
        if entry is not None:
            return entry
        version, tau, kmax, ordering = key[0], key[1], key[2], key[3]
        epsilon = float(key[5])
        t0 = time.perf_counter()
        with _obs_span(
            "mine.sample", version=version, tau=int(tau), epsilon=epsilon
        ):
            plan = build_sample(
                table,
                version=version,
                tau=tau,
                epsilon=epsilon,
                config=self.sampling,
                word_tile=int(getattr(self.placement, "store_word_tile", 1) or 1),
            )
            config = dataclasses.replace(
                self._request_config(tau, kmax, ordering), tau=plan.tau_sample
            )
            prep = preprocess(
                plan.table, plan.tau_sample, ordering=ordering, seed=config.seed
            )
            sample_result = mine_preprocessed(prep, config)
            raw = np.asarray(
                [cnt for _, cnt in sample_result.itemsets], dtype=np.int64
            )
            est, boundary = classify_counts(
                raw,
                tau=int(tau),
                epsilon=epsilon,
                n_rows=plan.n_rows_full,
                n_sample=int(plan.rows.shape[0]),
            )
            itemsets = [
                (ids, int(e))
                for (ids, _), e in zip(sample_result.itemsets, est)
            ]
            boundary_sets = [
                ids
                for (ids, _), b in zip(sample_result.itemsets, boundary)
                if b
            ]
            result = dataclasses.replace(sample_result, itemsets=itemsets)
            n_total = len(itemsets)
            info = {
                "mode": "approx",
                "epsilon": epsilon,
                "confidence": (
                    1.0 if not n_total
                    else (n_total - len(boundary_sets)) / n_total
                ),
                "boundary_count": len(boundary_sets),
                "seed": plan.seed,
                "sample_rows": int(plan.rows.shape[0]),
                "n_rows": plan.n_rows_full,
                "tau_sample": plan.tau_sample,
                "scale": plan.scale,
                "refined": False,
            }
            entry = CacheEntry(key=key, result=result, source="approx", info=info)
            self.cache.put(entry)
        sample_s = time.perf_counter() - t0
        _SAMPLING_SAMPLE_SECONDS.observe(sample_s)
        _SAMPLING_SAMPLE_ROWS.observe(int(plan.rows.shape[0]))
        _SAMPLING_BOUNDARY.inc(len(boundary_sets))
        with self._lock:
            ss = self._sampling_stats
            ss["sampled_mines"] += 1
            ss["last"] = {
                "version": int(version),
                "tau": int(tau),
                "kmax": int(kmax),
                "epsilon": epsilon,
                "seed": plan.seed,
                "sample_rows": int(plan.rows.shape[0]),
                "boundary_count": len(boundary_sets),
                "confidence": info["confidence"],
                "sample_mine_s": sample_s,
            }
        ekey = make_key(version, tau, kmax, ordering)
        self.scheduler.submit(
            ekey, lambda: self._refine(key, ekey, table, boundary_sets)
        )
        return entry

    def _refine(
        self,
        akey: tuple,
        ekey: tuple,
        table: ItemTable,
        boundary_sets: list[tuple[int, ...]],
    ) -> CacheEntry:
        """Background refinement of one approx answer, in two stages.

        Stage 1 recounts the boundary band exactly against the full table
        (padded to warm executable buckets — see ``sampling.refine``) and
        re-caches the approx entry with those counts resolved. Stage 2
        promotes to the bit-exact answer through the standard ``_compute``
        path, so job checkpoints, retries/degradation and request
        coalescing all apply — a crash mid-promotion leaves a level
        checkpoint that restart recovery resumes. Runs under the exact
        cache key: concurrent exact requests coalesce onto this run and
        receive the returned exact entry."""
        version, tau, kmax, ordering = ekey
        t0 = time.perf_counter()
        status = "ok"
        try:
            with _obs_span(
                "mine.refine",
                version=int(version),
                tau=int(tau),
                boundary=len(boundary_sets),
            ):
                base = self.cache.get(akey)
                if boundary_sets and base is not None:
                    counts, rinfo = recount_supports(
                        table,
                        boundary_sets,
                        placement=self.placement,
                        tau=int(tau),
                        fused_classify=self.config.fused_classify,
                    )
                    exact_of = dict(
                        zip(boundary_sets, (int(c) for c in counts))
                    )
                    kept = []
                    for ids, est in base.result.itemsets:
                        exact = exact_of.get(ids)
                        if exact is None:
                            kept.append((ids, est))
                        elif exact <= tau:
                            kept.append((ids, exact))
                        # else: boundary itemset proven frequent — drop it
                    result = dataclasses.replace(base.result, itemsets=kept)
                    info = dict(
                        base.info,
                        boundary_count=0,
                        recount=rinfo,
                        refined="recount",
                    )
                    self.cache.put(
                        CacheEntry(
                            key=akey, result=result, source="approx", info=info
                        )
                    )
                    with self._lock:
                        ss = self._sampling_stats
                        ss["recount_bucket_hits"] += rinfo["bucket_hits"]
                        ss["recount_bucket_misses"] += rinfo["bucket_misses"]
                entry = self._compute(ekey, table)
                if entry.source != "partial":
                    base = self.cache.get(akey)
                    info = dict(
                        base.info if base is not None else {},
                        confidence=1.0,
                        boundary_count=0,
                        refined=True,
                        promoted=True,
                    )
                    self.cache.put(
                        CacheEntry(
                            key=akey,
                            result=entry.result,
                            source="refined",
                            info=info,
                        )
                    )
                return entry
        except BaseException:
            status = "error"
            raise
        finally:
            _SAMPLING_REFINEMENTS.inc(status=status)
            _SAMPLING_REFINE_SECONDS.observe(time.perf_counter() - t0)
            with self._lock:
                self._sampling_stats["refinements"] += 1
                if status == "error":
                    self._sampling_stats["refine_failures"] += 1

    # -- reports ------------------------------------------------------------

    def _risk_profile_for(self, resp: MineResponse) -> tuple[object, str]:
        """The response's record-risk profile, via the privacy LRU; returns
        ``(profile, source)`` where source is "privacy-cache" on a hit."""
        from ..privacy.risk import risk_profile

        key = ("risk", resp.version, resp.tau, resp.kmax, resp.ordering)
        profile = self._privacy.get(key)
        if profile is not None:
            return profile, "privacy-cache"
        store = self.store
        shard = tuple(getattr(store, "shard", (0, 1)))
        profile = risk_profile(
            resp.result,
            placement=self.placement,
            # process-sharded store: the coverage accumulator is local-width;
            # the fleet placement scatters it to global rows via this map
            word_map=store.word_map() if shard[1] > 1 else None,
        )
        self._privacy.put(key, profile)
        return profile, resp.source

    def report(
        self,
        tau: int = 1,
        kmax: int = 3,
        ordering: str = "ascending",
    ) -> dict:
        """Quasi-identifier report (sdc.quasi) over the current version,
        served from the result cache when warm (the record-risk fields reuse
        the privacy LRU's profile rather than re-running the coverage
        kernels)."""
        resp = self.mine(tau=tau, kmax=kmax, ordering=ordering)
        profile, _ = self._risk_profile_for(resp)
        rep = QuasiIdentifierReport(
            result=resp.result, tau=tau, kmax=kmax, _profile=profile
        )
        out = report_as_dict(rep)
        out.update(version=resp.version, source=resp.source, latency_s=resp.latency_s)
        return out

    # -- privacy risk engine -------------------------------------------------

    def risk(
        self,
        tau: int = 1,
        kmax: int = 3,
        ordering: str = "ascending",
        *,
        top: int = 10,
    ) -> dict:
        """Record-level risk profile of the current version (coverage kernels
        over the resident bitsets), cached per (version, tau, kmax) beside
        the result LRU."""
        t0 = time.perf_counter()
        resp = self.mine(tau=tau, kmax=kmax, ordering=ordering)
        profile, source = self._risk_profile_for(resp)
        out = profile.summary(top=top)
        out.update(
            version=resp.version,
            source=source,
            latency_s=time.perf_counter() - t0,
        )
        return out

    def anonymize_plan(
        self,
        tau: int = 1,
        kmax: int = 3,
        ordering: str = "ascending",
        *,
        max_rounds: int = 12,
        max_suppressions: int | None = 200,
    ) -> dict:
        """Verified masking plan (zero residual quasi-identifiers) for the
        current version. The table is reconstructed from the resident item
        bitsets; the planner's verification re-mines reuse this service's
        placement and warm executable buckets."""
        from ..privacy.planner import plan_anonymization

        t0 = time.perf_counter()
        resp = self.mine(tau=tau, kmax=kmax, ordering=ordering)
        key = ("plan", resp.version, tau, kmax, ordering, max_rounds)
        plan = self._privacy.get(key)
        source = "privacy-cache"
        if plan is None:
            dataset = resp.result.prep.table.to_dataset()
            plan = plan_anonymization(
                dataset,
                tau=tau,
                kmax=kmax,
                config=self._request_config(tau, kmax, ordering),
                max_rounds=max_rounds,
                base_result=resp.result,
            )
            self._privacy.put(key, plan)
            source = resp.source
        out = plan.as_dict(max_suppressions=max_suppressions)
        out.update(
            version=resp.version,
            source=source,
            latency_s=time.perf_counter() - t0,
        )
        return out

    # -- forensics ----------------------------------------------------------

    def last_crash_report(self) -> dict | None:
        """The previous incarnation's parsed flight ring (``None`` on first
        boot or without a flight recorder) — ``GET /debug/lastcrash``."""
        return self.last_crash.to_dict() if self.last_crash is not None else None

    def slowlog_entries(self, n: int | None = None) -> list[dict]:
        """Newest-first slow-mine envelopes — ``GET /debug/slowlog``."""
        return self.slowlog.entries(n)

    def debug_bundle(self) -> dict:
        """One-shot postmortem snapshot — ``GET /debug/bundle`` (gzipped).

        Privacy: carries no row data — itemset ids, counters and timings
        only (same exposure as /metrics + /trace + /stats).
        """
        bundle = {
            "generated_at": time.time(),
            "config": self._resolved_config(),
            "stats": self.stats(),
            "metrics": _om.REGISTRY.render(),
            "traces": [t.to_dict() for t in _obs_tracer.last(16)],
            "slowlog": self.slowlog_entries(),
            "lastcrash": self.last_crash_report(),
            "exec_cache_keys": {
                fam: [list(map(str, k)) for k in exec_cache.SHARED_EXEC_CACHE.keys(fam)]
                for fam in exec_cache.stats()["families"]
            },
            "flight": self.flight.stats() if self.flight is not None else None,
        }
        return bundle

    # -- observability ------------------------------------------------------

    def _collect_metrics(self) -> None:
        """Scrape-time mirror of component-local stats into the registry.

        Runs under the registry lock, so it must only read values whose
        writers never hold their own lock while recording registry metrics
        (lock-ordering: component lock -> registry lock is forbidden for
        anything read here; plain attribute reads are always safe).
        """
        reg = _om.REGISTRY
        g = reg.gauge
        c = reg.counter

        c("repro_service_served_total", "Requests answered.").set_total(self.served)
        c(
            "repro_service_degraded_mines_total",
            "Mines degraded to the host placement.",
        ).set_total(self.degraded_mines)
        c(
            "repro_service_device_retries_total", "Device mine retries."
        ).set_total(self.device_retries)
        c(
            "repro_service_resumed_jobs_total", "Mine jobs resumed at recovery."
        ).set_total(self.resumed_jobs)
        g("repro_service_ready", "1 when ready (recovered, breaker closed).").set(
            1.0 if self.readiness()[0] else 0.0
        )

        cache = self.cache.stats()
        g("repro_result_cache_entries", "Cached mining results.").set(cache["entries"])
        g("repro_result_cache_bytes", "Approximate result-cache footprint.").set(
            cache["bytes"]
        )
        c("repro_result_cache_hits_total", "Result-cache hits.").set_total(
            cache["hits"]
        )
        c("repro_result_cache_misses_total", "Result-cache misses.").set_total(
            cache["misses"]
        )

        priv = self._privacy.stats()
        g("repro_privacy_cache_entries", "Cached privacy payloads.").set(
            priv["entries"]
        )
        c("repro_privacy_cache_hits_total", "Privacy-LRU hits.").set_total(
            priv["hits"]
        )
        c("repro_privacy_cache_misses_total", "Privacy-LRU misses.").set_total(
            priv["misses"]
        )

        sched = self.scheduler.stats()
        c("repro_scheduler_scheduled_total", "Runs scheduled.").set_total(
            sched["scheduled"]
        )
        c(
            "repro_scheduler_coalesced_total",
            "Requests coalesced onto an in-flight run.",
        ).set_total(sched["coalesced"])
        c("repro_scheduler_failed_total", "Runs that raised.").set_total(
            sched["failed"]
        )
        g("repro_scheduler_inflight", "Runs currently executing.").set(
            sched["inflight"]
        )

        br = self.breaker.stats()
        g(
            "repro_breaker_open",
            "1 while the circuit breaker rejects the device path.",
        ).set(1.0 if br["state"] == "open" else 0.0)
        g(
            "repro_breaker_consecutive_failures",
            "Consecutive device failures recorded.",
        ).set(br["consecutive_failures"])

        store = self._store
        if store is not None:
            st = store.stats()
            g("repro_store_version", "Current dataset version.").set(st["version"])
            g("repro_store_rows", "Rows in the store.").set(st["n_rows"])
            g("repro_store_items", "Distinct items in the store.").set(
                st["n_items"]
            )
            g("repro_store_bitset_bytes", "Resident bitset bytes.").set(
                st["bitset_bytes"]
            )
            c("repro_store_compactions_total", "Store compactions.").set_total(
                st["compactions"]
            )

        durable = self._durable
        if durable is not None:
            # plain attribute reads only — DurableStore's lock is held while
            # WAL metrics record, so taking it here would invert lock order
            g(
                "repro_store_snapshots_taken", "Snapshots taken (this store)."
            ).set(durable.snapshots_taken)

        ss = self._sampling_stats
        c(
            "repro_sampling_approx_served_total",
            "Approx mine requests answered.",
        ).set_total(ss["approx_served"])
        c(
            "repro_sampling_refine_failures_total",
            "Background refinements that raised.",
        ).set_total(ss["refine_failures"])
        last = ss["last"]
        if last is not None:
            g(
                "repro_sampling_last_confidence",
                "Certain fraction of the most recent sample mine.",
            ).set(last["confidence"])
            g(
                "repro_sampling_last_sample_rows",
                "Rows drawn by the most recent sample mine.",
            ).set(last["sample_rows"])

        ts = _obs_tracer.stats()
        c("repro_traces_started_total", "Traces started.").set_total(ts["started"])
        c(
            "repro_traces_sampled_out_total", "Traces dropped by sampling."
        ).set_total(ts["sampled_out"])
        g("repro_traces_stored", "Traces in the ring buffer.").set(ts["stored"])
        c(
            "repro_trace_dropped_total",
            "Finished traces evicted from the ring by newer arrivals.",
        ).set_total(ts["dropped"])

    def stats(self) -> dict:
        store = self._store
        ready, reason = self.readiness()
        return {
            "ready": ready,
            "ready_reason": reason,
            "served": self.served,
            "durability": (
                dict(
                    self._durable.stats(),
                    last_recovery=self._recovery_info,
                    job_checkpoint_levels=self.job_checkpoint_levels,
                    resumed_jobs=self.resumed_jobs,
                )
                if self._durable is not None
                else None
            ),
            "resilience": dict(
                self.breaker.stats(),
                device_retries=self.device_retries,
                degraded_mines=self.degraded_mines,
                max_retries=self.resilience.max_retries,
            ),
            "drain": self._drain_info,
            # one locked read — an in-flight append can't tear this section
            "store": (
                store.stats()
                if store
                else {
                    "version": 0,
                    "n_rows": 0,
                    "n_items": 0,
                    "n_words": 0,
                    "word_tile": self.word_tile,
                    "bitset_bytes": 0,
                    "compactions": 0,
                }
            ),
            "placement": self.placement.describe(),
            # the sampled-mining fast path: request/refinement counters,
            # the reproducibility surface (derived seed, ε, sample size) of
            # the most recent sample mine, and boundary-recount bucket reuse
            "sampling": dict(
                self._sampling_stats,
                config={
                    "epsilon": self.sampling.epsilon,
                    "delta": self.sampling.delta,
                    "oversample": self.sampling.oversample,
                    "min_rows": self.sampling.min_rows,
                    "seed": self.sampling.seed,
                },
            ),
            "cache": self.cache.stats(),
            "privacy": self._privacy.stats(),
            "scheduler": self.scheduler.stats(),
            # one unified section for every kernel family's executable
            # buckets (intersect / coverage / frontier) — per-family
            # counters under "families", process totals at the top level
            "executables": exec_cache.stats(),
            # per-level timing split of the most recent cold mine (host
            # candidate/classify work vs device dispatch+sync)
            "last_mine": self._last_mine_timing,
            # registry fold-in: every metric family in one consistent
            # (single-lock) snapshot, plus the tracer's ring-buffer state.
            # The sections above keep their historical shapes; this is the
            # one place new telemetry lands without reshaping them.
            "obs": {
                "metrics": _om.REGISTRY.snapshot(),
                "traces": _obs_tracer.stats(),
            },
            # crash forensics + per-request cost surfaces (PR 9): the flight
            # ring's write-side counters, the slow-mine log, and whether the
            # previous incarnation died cleanly
            "forensics": {
                "flight": self.flight.stats() if self.flight is not None else None,
                "slowlog": self.slowlog.stats(),
                "last_crash": (
                    {
                        "clean_shutdown": self.last_crash.clean_shutdown,
                        "open_spans": len(self.last_crash.open_spans),
                        "last_checkpoint": self.last_crash.last_checkpoint,
                    }
                    if self.last_crash is not None
                    else None
                ),
            },
        }

    def compact(self, keep_versions: int | None = None) -> dict:
        """Manually coalesce the store's append blocks (see
        :meth:`DatasetStore.compact`). On a durable service the compacted
        state is snapshotted immediately — compaction is not WAL-logged, so
        folding it into a snapshot (which also resets the WAL) is what keeps
        recovery consistent."""
        out = self.store.compact(keep_versions)
        if self._durable is not None:
            self._durable.snapshot()
        return out

    def snapshot_store(self) -> int | None:
        """Force a durable snapshot (graceful shutdown calls this so restart
        recovery is a snapshot load, not a WAL replay)."""
        if self._durable is None:
            return None
        return self._durable.snapshot()

    def drain(self, timeout: float | None = None) -> dict:
        """Graceful-shutdown drain: wait for in-flight runs up to
        ``timeout``, then cancel stragglers (they stop at their next batch
        boundary and their waiters get partial results) and give them a
        short grace to unwind."""
        info = self.scheduler.drain(timeout)
        with self._lock:
            stragglers = list(self._controls.values())
        for ctrl in stragglers:
            ctrl.cancel()
        if info["abandoned"]:
            grace = self.scheduler.drain(min(2.0, timeout if timeout else 2.0))
            info["drained_after_cancel"] = grace["drained"]
        self._drain_info = info
        return info

    def close(self) -> None:
        self.scheduler.shutdown()
        if self._durable is not None:
            self._durable.close()
        if self.flight is not None:
            # orderly shutdown leaves a clean-shutdown marker in the ring —
            # the next incarnation's LastCrashReport reads "nothing to see"
            _obs_tracer.remove_listener(self.flight.span_listener)
            self.flight.close()
        # drop the scrape collector only if this instance still owns the
        # slot (a newer service may have replaced it)
        _om.REGISTRY.unregister_collector("service", self._collector_fn)
