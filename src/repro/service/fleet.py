"""Multi-host fan-out/merge coordinator for the mining fleet.

The fleet runs one :class:`~repro.service.api.MiningService` per process
over a process-sharded store (``shard=(pid, nproc)`` word stripes) and a
:class:`~repro.core.fleet.FleetPlacement`. Mining is *lockstep*: every
process executes the identical request and the partial popcounts meet in
one all-reduce per batch — so "fan-out" here is command replication, and
"merge" is digest agreement, not result stitching. Three pieces:

* :func:`replicate` — the command bus. One collective round broadcasts the
  frontend's ``(op, args)`` to every process (peers contribute a ready
  marker and take process 0's entry); each process then executes the op on
  its local service, and a final round all-gathers the outcome digest —
  raising :class:`~repro.core.collective.FleetDesyncError` if the fleet
  disagrees, and re-raising remote errors locally so every process stays
  round-aligned even when one fails deterministically.
* :class:`FleetFrontend` — what process 0 binds HTTP to, following the
  ``is_main()`` discipline in ``launch.mesh``. Replicated ops (append /
  mine / report / risk) go through the bus under a global op lock (the
  collective is one strictly-ordered round sequence; two interleaved ops
  would shear it). Everything else (stats, readiness, slowlog, drain)
  reads local state and delegates via ``__getattr__``.
* :func:`serve_fleet_peer` — the peer loop (processes 1..P-1): block on
  the next command round, execute, repeat until the frontend broadcasts
  shutdown or a peer failure poisons the fleet.

Degradation: a :class:`~repro.core.collective.FleetTimeout` anywhere in a
replicated op (a peer died or stalled past its deadline) trips the fleet
breaker **permanently** — stripes held by a dead peer are unrecoverable
without re-itemizing, so the frontend fails over to its *shadow*: a plain
single-process service over an unsharded copy of the data, kept in sync on
every append. Subsequent requests are served single-host (slower, still
exact); ``/stats.resilience.fleet`` makes the switch operator-visible.
Restarting the fleet is the only way back — rejoin-in-place would need
stripe re-replication, which the store deliberately refuses (local stripes
are not transferable between processes).
"""

from __future__ import annotations

import hashlib
import json
import pickle
import threading
import time

import numpy as np

from ..core.collective import Collective, FleetDesyncError, FleetTimeout
from ..obs import metrics as _om

__all__ = [
    "FleetFrontend",
    "FleetOpError",
    "replicate",
    "serve_fleet_peer",
]

_FLEET_OPS = _om.counter(
    "repro_fleet_ops_total",
    "Replicated fleet operations by op and outcome.",
    ("op", "outcome"),
)

# ops every process executes in lockstep; anything else is local-only.
# Every op that can reach a mining collective MUST be here — a collective
# issued outside the command bus pairs against the peers' command round
# and shears the fleet's round sequence. Digests pin bit-identity of the
# *deterministic* part of each answer — wall-clock fields vary per process
# and are excluded.
REPLICATED_OPS = ("append", "mine", "report", "risk", "anonymize_plan")

_VOLATILE_KEYS = ("latency_s", "source", "wall_time", "info")


class FleetOpError(RuntimeError):
    """A replicated op failed on at least one process (deterministically —
    validation errors and the like). Raised on *every* process so the round
    sequence stays aligned; carries the per-process error strings."""

    def __init__(self, op: str, errors: dict[int, str]):
        self.op = op
        self.errors = errors
        super().__init__(f"fleet op {op!r} failed: {errors}")


def _scrub(obj):
    """Canonical form for digesting: drop per-process wall-clock fields,
    coerce numpy scalars/arrays, sort mapping keys."""
    if isinstance(obj, dict):
        return {
            k: _scrub(v)
            for k, v in sorted(obj.items())
            if k not in _VOLATILE_KEYS
        }
    if isinstance(obj, (list, tuple)):
        return [_scrub(v) for v in obj]
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    if isinstance(obj, np.generic):
        return obj.item()
    return obj


def _digest_of(op: str, service, out) -> bytes:
    if op == "append":
        # the store's watermark digest covers version/rows/items/width —
        # stronger than the append response alone
        return service.store.watermark_digest()
    if op == "mine":
        payload = (out.version, tuple(out.result.itemsets))
        return hashlib.sha256(pickle.dumps(payload)).digest()
    # report / risk: JSON-shaped dicts with volatile fields scrubbed
    blob = json.dumps(_scrub(out), sort_keys=True, default=repr).encode()
    return hashlib.sha256(blob).digest()


def _jsonable(out):
    return out.to_json() if hasattr(out, "to_json") else out


def replicate(service, collective: Collective, op: str, kw: dict):
    """Execute one replicated op on the local service and agree on its
    digest. Must be called by **every** process with the same ``(op, kw)``
    in the same round (the command bus guarantees this). Returns the local
    result; raises :class:`FleetOpError` fleet-wide if any process failed,
    :class:`FleetDesyncError` if digests diverge, :class:`FleetTimeout`
    if a peer vanished."""
    out = err = None
    try:
        out = getattr(service, op)(**kw)
    except FleetTimeout:
        raise  # a dead peer is a fleet event, not an op error
    except Exception as exc:  # deterministic op failure: exchange, re-raise
        err = f"{type(exc).__name__}: {exc}"
    outcome = ("err", err) if err is not None else ("ok", _digest_of(op, service, out))
    outcomes = collective.allgather_obj(outcome)
    errors = {p: o[1] for p, o in enumerate(outcomes) if o[0] == "err"}
    if errors:
        _FLEET_OPS.inc(op=op, outcome="error")
        raise FleetOpError(op, errors)
    digests = {o[1] for o in outcomes}
    if len(digests) != 1:
        _FLEET_OPS.inc(op=op, outcome="desync")
        raise FleetDesyncError(
            f"fleet op {op!r} produced {len(digests)} distinct digests"
        )
    _FLEET_OPS.inc(op=op, outcome="ok")
    return out


_SHUTDOWN = {"op": "__shutdown__", "kw": {}}


class FleetFrontend:
    """Process 0's request facade: replicates mining ops across the fleet,
    serves everything else from the local (sharded) service, and fails over
    to ``shadow`` — a single-process full-copy service — when a peer dies.

    Duck-types the slice of :class:`MiningService` the HTTP layer calls;
    unknown attributes delegate to whichever service is currently active.
    """

    def __init__(self, service, collective: Collective, *, shadow=None):
        self.service = service
        self.collective = collective
        self.shadow = shadow
        self.degraded = False
        self.degraded_reason: str | None = None
        self.degraded_at: float | None = None
        # the collective is one global round sequence: replicated ops are
        # serialised fleet-wide by this lock (HTTP threads would interleave)
        self._op_lock = threading.RLock()
        self._ops = 0

    # -- degradation ---------------------------------------------------------

    def _degrade(self, exc: Exception):
        self.degraded = True
        self.degraded_reason = f"{type(exc).__name__}: {exc}"
        self.degraded_at = time.time()
        _FLEET_OPS.inc(op="*", outcome="degraded")
        # the preprocess row-group rendezvous is a module-level hook: left
        # installed it would drag the *shadow's* cold mines into collective
        # rounds against a dead fleet
        from ..core.preprocess import set_row_group_collective

        set_row_group_collective(None)
        if self.shadow is None:
            raise RuntimeError(
                "fleet degraded with no shadow service configured"
            ) from exc

    @property
    def active(self):
        return self.shadow if self.degraded else self.service

    # -- replicated ops ------------------------------------------------------

    def _replicated(self, op: str, **kw):
        with self._op_lock:
            if self.degraded:
                return getattr(self.shadow, op)(**kw)
            self._ops += 1
            try:
                # command round: peers block on this and mirror the call
                self.collective.allgather_obj({"op": op, "kw": kw})
                return replicate(self.service, self.collective, op, kw)
            except FleetTimeout as exc:
                self._degrade(exc)
                return getattr(self.shadow, op)(**kw)

    def append(self, rows):
        rows = np.ascontiguousarray(np.asarray(rows, dtype=np.int64))
        with self._op_lock:
            out = self._replicated("append", rows=rows)
            # the shadow ingests every append while the fleet is healthy —
            # at degradation time it must already hold the full table (a
            # dead peer's stripes cannot be reconstructed from survivors).
            # Sync strictly *after* the replicated op: if it degraded
            # mid-call the fallback already applied this block to the
            # shadow, and a second application would fork the row count.
            if not self.degraded and self.shadow is not None:
                self.shadow.append(rows)
            return out

    def mine(self, **kw):
        if not self.degraded:
            # both features are wall-clock-driven and therefore process-
            # divergent: a deadline can expire on one host and not another
            # (partial results would desync the digest), and sampled mining
            # draws row subsets a sharded store cannot materialise
            if kw.get("mode") == "approx":
                raise ValueError(
                    "mode='approx' is not supported on a multi-process fleet"
                )
            if kw.get("deadline_s") is not None:
                raise ValueError(
                    "per-request deadlines are not supported on a fleet; "
                    "use --fleet-timeout-s"
                )
        return self._replicated("mine", **kw)

    def report(self, **kw):
        return self._replicated("report", **kw)

    def risk(self, **kw):
        return self._replicated("risk", **kw)

    def anonymize_plan(self, **kw):
        return self._replicated("anonymize_plan", **kw)

    # -- local views ---------------------------------------------------------

    def fleet_stats(self) -> dict:
        return {
            "nproc": self.collective.nproc,
            "pid": self.collective.pid,
            "degraded": self.degraded,
            "degraded_reason": self.degraded_reason,
            "degraded_at": self.degraded_at,
            "replicated_ops": self._ops,
            "collective": self.collective.stats(),
            "shadow": self.shadow is not None,
        }

    def stats(self) -> dict:
        s = self.active.stats()
        res = dict(s.get("resilience") or {})
        res["fleet"] = self.fleet_stats()
        s["resilience"] = res
        return s

    def shutdown_fleet(self) -> None:
        """Broadcast shutdown to the peer loops (healthy fleets only)."""
        with self._op_lock:
            if not self.degraded and self.collective.nproc > 1:
                try:
                    self.collective.allgather_obj(_SHUTDOWN)
                except FleetTimeout:
                    pass  # peers already gone

    def close(self) -> None:
        self.shutdown_fleet()
        self.service.close()
        if self.shadow is not None:
            self.shadow.close()

    def __getattr__(self, name):
        return getattr(self.active, name)


def serve_fleet_peer(service, collective: Collective) -> dict:
    """Peer-process main loop: execute replicated commands until shutdown.

    Returns a summary dict. A :class:`FleetTimeout` (frontend died) or
    :class:`FleetDesyncError` terminates the loop — the fleet is broken
    and this process cannot rejoin without a restart.
    """
    executed = 0
    reason = "shutdown"
    while True:
        try:
            msgs = collective.allgather_obj({"op": None})
            cmd = msgs[0]  # the frontend is always process 0
            if cmd.get("op") in (None, "__shutdown__"):
                if cmd.get("op") == "__shutdown__":
                    break
                # frontend round without a command — protocol violation
                reason = "bad-command"
                break
            replicate(service, collective, cmd["op"], cmd["kw"])
            executed += 1
        except FleetOpError:
            continue  # deterministic failure, fleet still aligned
        except (FleetTimeout, FleetDesyncError) as exc:
            reason = f"{type(exc).__name__}: {exc}"
            break
    return {"executed": executed, "reason": reason}
