# Fleet launch environment for serve_miner (source before launching).
#
# Allocator + XLA flag idiom for multi-host runs: tcmalloc for the
# host-side bitset churn, latency-hiding scheduling and fat collective
# combining for the DCN popcount psum. `repro.launch.mesh.launch_env_summary`
# records the resulting environment into bench JSON rows so every perf
# number names the flags that produced it.
#
# Usage:
#   source launch/env.sh
#   python -m repro.launch.serve_miner --mesh 2x4x1 \
#     --coordinator-address host0:9911 --num-processes 2 --process-id $ID

# faster malloc for the append/itemize hot path; skip silently if absent
_TCMALLOC=/usr/lib/x86_64-linux-gnu/libtcmalloc.so.4
if [ -f "$_TCMALLOC" ]; then
  export LD_PRELOAD="$_TCMALLOC"
fi
# no numpy large-alloc warnings on multi-GB bitset matrices
export TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD=60000000000
export TF_CPP_MIN_LOG_LEVEL=4

# Overlap the word-axis popcount psum with the next pair gather, and combine
# small DCN all-reduces into fat transfers (count vectors are per-batch and
# tiny individually). Harmless no-ops off-GPU; TPU equivalents ride defaults.
export XLA_FLAGS="${XLA_FLAGS:-} \
--xla_gpu_enable_latency_hiding_scheduler=true \
--xla_gpu_all_reduce_combine_threshold_bytes=134217728 \
--xla_gpu_all_gather_combine_threshold_bytes=1073741824"
