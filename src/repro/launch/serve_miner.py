"""Resident mining service over HTTP (stdlib only).

  PYTHONPATH=src python -m repro.launch.serve_miner --port 8750 \
      --preload randomized --n 2000 --m 10

Endpoints (JSON in / JSON out):

  POST /append   {"rows": [[...], ...]}                 -> version watermarks
  POST /mine     {"tau": 1, "kmax": 3, "ordering": "ascending",
                  "max_itemsets": 100}                  -> itemsets + source
  GET  /mine?tau=1&kmax=3                               -> same, query form
  GET  /report?tau=1&kmax=3                             -> sdc quasi-id report
  GET  /stats                                           -> cache/store/exec stats
  GET  /healthz                                         -> liveness

``source`` in the /mine response is "cold", "incremental" or "cache" — the
CI smoke job asserts a repeated query comes back "cache".
"""

from __future__ import annotations

import argparse
import json
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from urllib.parse import parse_qs, urlparse

import numpy as np

from ..service import IncrementalConfig, MiningService

__all__ = ["make_server", "main"]


def _mine_params(payload: dict) -> dict:
    return {
        "tau": int(payload.get("tau", 1)),
        "kmax": int(payload.get("kmax", 3)),
        "ordering": str(payload.get("ordering", "ascending")),
    }


class MinerHandler(BaseHTTPRequestHandler):
    service: MiningService  # bound by make_server
    quiet: bool = True

    def log_message(self, fmt, *args):  # noqa: D102
        if not self.quiet:
            super().log_message(fmt, *args)

    def _send(self, code: int, payload: dict) -> None:
        body = json.dumps(payload).encode()
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _body(self) -> dict:
        length = int(self.headers.get("Content-Length") or 0)
        if not length:
            return {}
        return json.loads(self.rfile.read(length) or b"{}")

    def _query(self) -> dict:
        qs = parse_qs(urlparse(self.path).query)
        return {k: v[0] for k, v in qs.items()}

    def _handle(self, payload: dict) -> None:
        route = urlparse(self.path).path
        if route == "/healthz":
            self._send(200, {"ok": True})
        elif route == "/stats":
            self._send(200, self.service.stats())
        elif route == "/append":
            rows = np.asarray(payload.get("rows", []), dtype=np.int64)
            if rows.size == 0:
                self._send(400, {"error": "append requires non-empty 'rows'"})
                return
            self._send(200, self.service.append(rows))
        elif route == "/mine":
            max_itemsets = payload.get("max_itemsets")
            resp = self.service.mine(**_mine_params(payload))
            self._send(
                200,
                resp.to_json(
                    max_itemsets=int(max_itemsets) if max_itemsets is not None else None
                ),
            )
        elif route == "/report":
            self._send(200, self.service.report(**_mine_params(payload)))
        else:
            self._send(404, {"error": f"unknown route {route}"})

    def do_GET(self):  # noqa: N802
        try:
            self._handle(self._query())
        except Exception as e:  # service must survive bad requests
            self._send(500, {"error": f"{type(e).__name__}: {e}"})

    def do_POST(self):  # noqa: N802
        try:
            self._handle(self._body())
        except Exception as e:
            self._send(500, {"error": f"{type(e).__name__}: {e}"})


def make_server(
    service: MiningService, host: str = "127.0.0.1", port: int = 8750, *, quiet: bool = True
) -> ThreadingHTTPServer:
    handler = type(
        "BoundMinerHandler", (MinerHandler,), {"service": service, "quiet": quiet}
    )
    return ThreadingHTTPServer((host, port), handler)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8750)
    ap.add_argument("--engine", default="numpy", choices=["numpy", "jnp", "pallas"])
    ap.add_argument("--cache-capacity", type=int, default=64)
    ap.add_argument("--max-delta-fraction", type=float, default=0.25)
    ap.add_argument("--preload", default=None,
                    help="'randomized' for a synthetic table, or a path: "
                         "*.csv via data.loaders.read_csv, else FIMI format")
    ap.add_argument("--n", type=int, default=2000, help="--preload randomized rows")
    ap.add_argument("--m", type=int, default=10, help="--preload randomized columns")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args()

    service = MiningService(
        engine=args.engine,
        cache_capacity=args.cache_capacity,
        incremental=IncrementalConfig(max_delta_fraction=args.max_delta_fraction),
    )
    if args.preload == "randomized":
        from ..data.synth import randomized_dataset

        service.append(randomized_dataset(args.n, args.m, seed=args.seed))
    elif args.preload and args.preload.endswith(".csv"):
        from ..data.loaders import read_csv

        service.append(read_csv(args.preload)[0])
    elif args.preload:
        from ..data.loaders import read_fimi

        service.append(read_fimi(args.preload))

    server = make_server(service, args.host, args.port, quiet=not args.verbose)
    store = service._store
    print(
        f"serve_miner on http://{args.host}:{args.port} "
        f"(engine={args.engine}, rows={store.n_rows if store else 0}, "
        f"items={store.n_items if store else 0})",
        flush=True,
    )
    try:
        server.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        server.server_close()
        service.close()


if __name__ == "__main__":
    main()
