"""Resident mining service over HTTP (stdlib only).

  PYTHONPATH=src python -m repro.launch.serve_miner --port 8750 \
      --preload randomized --n 2000 --m 10

  # word-sharded store over an 8-device mesh (pairs x words = 2x4):
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python -m repro.launch.serve_miner --mesh 2x4

Endpoints (JSON in / JSON out):

  POST /append   {"rows": [[...], ...]}                 -> version watermarks
  POST /mine     {"tau": 1, "kmax": 3, "ordering": "ascending",
                  "max_itemsets": 100}                  -> itemsets + source
  GET  /mine?tau=1&kmax=3                               -> same, query form
  GET  /report?tau=1&kmax=3                             -> sdc quasi-id report
  GET  /risk?tau=1&kmax=3&top=10                        -> per-record risk profile
  GET  /anonymize?tau=1&kmax=3                          -> verified masking plan
  GET  /stats                                           -> store/placement/cache/http stats,
                                                           durability/resilience sections,
                                                           unified executables, last_mine timing
  GET  /healthz                                         -> liveness (never gated)
  GET  /readyz                                          -> readiness: 503 while recovering
                                                           (WAL replay / job resume) or while
                                                           the device circuit breaker is open
  POST /cancel   {"tau": 1, "kmax": 3}                  -> cancel in-flight matching runs

``source`` in the /mine response is "cold", "incremental" or "cache" — the
CI smoke job asserts a repeated query comes back "cache". A ``deadline_s``
on /mine bounds the request: an exceeded deadline returns ``499`` with the
partial result mined so far (``"source": "partial"``).

Durability (``--wal-dir DIR``): appends are WAL-logged and fsync'd before
itemization, snapshots fold the log every ``--snapshot-every`` appends, and
a restarted server recovers the store to the exact pre-crash version (and
resumes interrupted mine jobs from their last checkpointed level). SIGTERM
drains in-flight requests (bounded by ``--drain-timeout``), snapshots the
store, and exits 0.

Hardening (ROADMAP "authn and backpressure"):

* ``--auth-token TOKEN`` (or env ``MINER_AUTH_TOKEN``) requires
  ``Authorization: Bearer TOKEN`` on every route except ``/healthz``;
  constant-time comparison, 401 on mismatch.
* ``--max-inflight N`` bounds concurrently served requests; when the bound
  is hit new requests get an immediate ``429 {"error": ...}`` instead of
  piling onto the mining worker (liveness stays exempt so probes never 429).
"""

from __future__ import annotations

import argparse
import hmac
import json
import os
import signal
import sys
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from urllib.parse import parse_qs, urlparse

import numpy as np

from ..service import (
    DeadlineExceeded,
    IncrementalConfig,
    MiningService,
    NotReadyError,
)

__all__ = ["make_server", "main"]


def _mine_params(payload: dict) -> dict:
    return {
        "tau": int(payload.get("tau", 1)),
        "kmax": int(payload.get("kmax", 3)),
        "ordering": str(payload.get("ordering", "ascending")),
    }


class MinerHandler(BaseHTTPRequestHandler):
    service: MiningService  # bound by make_server
    quiet: bool = True
    auth_token: str | None = None
    inflight: threading.BoundedSemaphore | None = None
    http_stats: dict  # shared counters, bound by make_server
    _stats_lock = threading.Lock()

    def log_message(self, fmt, *args):  # noqa: D102
        if not self.quiet:
            super().log_message(fmt, *args)

    def _count(self, key: str) -> None:
        with self._stats_lock:
            self.http_stats[key] = self.http_stats.get(key, 0) + 1

    def _send(self, code: int, payload: dict) -> None:
        body = json.dumps(payload).encode()
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _body(self) -> dict:
        length = int(self.headers.get("Content-Length") or 0)
        if not length:
            return {}
        return json.loads(self.rfile.read(length) or b"{}")

    def _query(self) -> dict:
        qs = parse_qs(urlparse(self.path).query)
        return {k: v[0] for k, v in qs.items()}

    def _authorized(self) -> bool:
        if not self.auth_token:
            return True
        # compare bytes: compare_digest on str raises TypeError for
        # non-ASCII, and header bytes are attacker-controlled
        header = self.headers.get("Authorization", "").encode("utf-8")
        return hmac.compare_digest(header, f"Bearer {self.auth_token}".encode("utf-8"))

    def _handle(self, payload: dict) -> None:
        route = urlparse(self.path).path
        if route == "/healthz":  # liveness: never auth-gated, never queued
            self._send(200, {"ok": True})
            return
        if route == "/readyz":  # readiness: also probe-exempt, but honest
            ready, reason = self.service.readiness()
            self._send(200 if ready else 503, {"ready": ready, "reason": reason})
            return
        if not self._authorized():
            self._count("unauthorized")
            self._send(401, {"error": "missing or invalid bearer token"})
            return
        if self.inflight is not None and not self.inflight.acquire(blocking=False):
            self._count("rejected")
            self._send(429, {"error": "request queue full, retry later"})
            return
        try:
            self._count("served")
            self._dispatch(route, payload)
        finally:
            if self.inflight is not None:
                self.inflight.release()

    def _dispatch(self, route: str, payload: dict) -> None:
        if route == "/stats":
            stats = self.service.stats()
            with self._stats_lock:
                stats["http"] = dict(self.http_stats)
            stats["http"]["auth"] = bool(self.auth_token)
            stats["http"]["max_inflight"] = (
                self.inflight._initial_value if self.inflight is not None else None
            )
            self._send(200, stats)
        elif route == "/append":
            rows = np.asarray(payload.get("rows", []), dtype=np.int64)
            if rows.size == 0:
                self._send(400, {"error": "append requires non-empty 'rows'"})
                return
            self._send(200, self.service.append(rows))
        elif route == "/mine":
            max_itemsets = payload.get("max_itemsets")
            deadline_s = payload.get("deadline_s")
            resp = self.service.mine(
                **_mine_params(payload),
                deadline_s=float(deadline_s) if deadline_s is not None else None,
            )
            # 499 (client-timeout convention): the run stopped at a batch
            # boundary; the body still carries the valid partial answer
            code = 499 if resp.source == "partial" else 200
            if code == 499:
                self._count("deadline_exceeded")
            self._send(
                code,
                resp.to_json(
                    max_itemsets=int(max_itemsets) if max_itemsets is not None else None
                ),
            )
        elif route == "/cancel":
            self._send(
                200,
                self.service.cancel(
                    int(payload.get("tau", 1)),
                    int(payload.get("kmax", 3)),
                    str(payload.get("ordering", "ascending")),
                ),
            )
        elif route == "/report":
            self._send(200, self.service.report(**_mine_params(payload)))
        elif route == "/risk":
            top = int(payload.get("top", 10))
            self._send(200, self.service.risk(**_mine_params(payload), top=top))
        elif route == "/anonymize":
            max_sup = payload.get("max_suppressions")
            self._send(
                200,
                self.service.anonymize_plan(
                    **_mine_params(payload),
                    max_suppressions=int(max_sup) if max_sup is not None else 200,
                ),
            )
        else:
            self._send(404, {"error": f"unknown route {route}"})

    def _run(self, payload: dict) -> None:
        try:
            self._handle(payload)
        except NotReadyError as e:
            self._send(503, {"error": str(e), "retry": True})
        except DeadlineExceeded as e:
            # a coalesced waiter timed out; the shared run keeps going for
            # the waiters that imposed no deadline
            self._count("deadline_exceeded")
            self._send(499, {"error": str(e)})
        except Exception as e:  # service must survive bad requests
            self._send(500, {"error": f"{type(e).__name__}: {e}"})

    def do_GET(self):  # noqa: N802
        self._run(self._query())

    def do_POST(self):  # noqa: N802
        try:
            payload = self._body()
        except Exception as e:
            self._send(400, {"error": f"{type(e).__name__}: {e}"})
            return
        self._run(payload)


def make_server(
    service: MiningService,
    host: str = "127.0.0.1",
    port: int = 8750,
    *,
    quiet: bool = True,
    auth_token: str | None = None,
    max_inflight: int | None = None,
) -> ThreadingHTTPServer:
    sem = None
    if max_inflight is not None:
        if max_inflight <= 0:
            raise ValueError(f"max_inflight must be positive, got {max_inflight}")
        sem = threading.BoundedSemaphore(max_inflight)
    handler = type(
        "BoundMinerHandler",
        (MinerHandler,),
        {
            "service": service,
            "quiet": quiet,
            "auth_token": auth_token,
            "inflight": sem,
            "http_stats": {},
        },
    )
    return ThreadingHTTPServer((host, port), handler)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8750)
    ap.add_argument("--engine", default="numpy", choices=["numpy", "jnp", "pallas"])
    ap.add_argument("--mesh", default=None, metavar="DATAxMODEL",
                    help="serve from a word-sharded mesh store, e.g. '2x4' "
                         "(pair shards x word shards over the visible devices)")
    ap.add_argument("--cache-capacity", type=int, default=64)
    ap.add_argument("--cache-max-bytes", type=int, default=None,
                    help="bound the result cache by payload bytes, not just "
                         "entry count")
    ap.add_argument("--wal-dir", default=None,
                    help="durability directory (write-ahead log + snapshots); "
                         "a restarted server recovers the store from it")
    ap.add_argument("--snapshot-every", type=int, default=8,
                    help="fold the WAL into a snapshot every N appends")
    ap.add_argument("--drain-timeout", type=float, default=10.0,
                    help="seconds SIGTERM waits for in-flight requests before "
                         "cancelling them")
    ap.add_argument("--max-delta-fraction", type=float, default=0.25)
    ap.add_argument("--compact-threshold", type=int, default=None,
                    help="auto-compact the store when this many append "
                         "versions accumulate")
    ap.add_argument("--auth-token", default=os.environ.get("MINER_AUTH_TOKEN"),
                    help="require 'Authorization: Bearer <token>' "
                         "(default: $MINER_AUTH_TOKEN)")
    ap.add_argument("--max-inflight", type=int, default=64,
                    help="429 when this many requests are already in flight "
                         "(0 disables the bound)")
    ap.add_argument("--preload", default=None,
                    help="'randomized' for a synthetic table, or a path: "
                         "*.csv via data.loaders.read_csv, else FIMI format")
    ap.add_argument("--n", type=int, default=2000, help="--preload randomized rows")
    ap.add_argument("--m", type=int, default=10, help="--preload randomized columns")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args()

    placement = None
    if args.mesh:
        from ..core.placement import MeshPlacement
        from .mesh import mesh_from_spec

        placement = MeshPlacement(
            mesh_from_spec(args.mesh), pair_axes=("data",), word_axis="model"
        )

    service = MiningService(
        engine=args.engine,
        placement=placement,
        cache_capacity=args.cache_capacity,
        cache_max_bytes=args.cache_max_bytes,
        compact_threshold=args.compact_threshold,
        wal_dir=args.wal_dir,
        snapshot_every=args.snapshot_every,
        incremental=IncrementalConfig(max_delta_fraction=args.max_delta_fraction),
    )
    if args.preload == "randomized":
        from ..data.synth import randomized_dataset

        service.append(randomized_dataset(args.n, args.m, seed=args.seed))
    elif args.preload and args.preload.endswith(".csv"):
        from ..data.loaders import read_csv

        service.append(read_csv(args.preload)[0])
    elif args.preload:
        from ..data.loaders import read_fimi

        service.append(read_fimi(args.preload))

    server = make_server(
        service,
        args.host,
        args.port,
        quiet=not args.verbose,
        auth_token=args.auth_token,
        max_inflight=args.max_inflight or None,
    )
    store = service._store
    print(
        f"serve_miner on http://{args.host}:{args.port} "
        f"(placement={service.placement.describe()}, "
        f"rows={store.n_rows if store else 0}, "
        f"items={store.n_items if store else 0}, "
        f"auth={'on' if args.auth_token else 'off'}, "
        f"max_inflight={args.max_inflight or 'unbounded'}, "
        f"wal={args.wal_dir or 'off'})",
        flush=True,
    )

    # graceful shutdown: the server loop runs in a thread; the main thread
    # waits on the signal, stops accepting, drains in-flight work (bounded),
    # snapshots the durable store, and exits 0 so supervisors see a clean stop
    stop = threading.Event()

    def _on_signal(signum, frame):
        stop.set()

    signal.signal(signal.SIGTERM, _on_signal)
    signal.signal(signal.SIGINT, _on_signal)

    thread = threading.Thread(target=server.serve_forever, daemon=True)
    thread.start()
    try:
        while not stop.wait(0.2):
            pass
    except KeyboardInterrupt:
        pass
    print("serve_miner draining...", flush=True)
    server.shutdown()
    thread.join()
    drain = service.drain(args.drain_timeout)
    snapshot = service.snapshot_store()
    server.server_close()
    service.close()
    print(
        f"serve_miner stopped (drained={drain['drained']}, "
        f"abandoned={drain['abandoned']}, "
        f"snapshot={'v%d' % snapshot if snapshot is not None else 'none'})",
        flush=True,
    )
    sys.exit(0)


if __name__ == "__main__":
    main()
