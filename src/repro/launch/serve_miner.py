"""Resident mining service over HTTP (stdlib only).

  PYTHONPATH=src python -m repro.launch.serve_miner --port 8750 \
      --preload randomized --n 2000 --m 10

  # word-sharded store over an 8-device mesh (pairs x words = 2x4):
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python -m repro.launch.serve_miner --mesh 2x4

Endpoints (JSON in / JSON out):

  POST /append   {"rows": [[...], ...]}                 -> version watermarks
  POST /mine     {"tau": 1, "kmax": 3, "ordering": "ascending",
                  "max_itemsets": 100}                  -> itemsets + source
  GET  /mine?tau=1&kmax=3                               -> same, query form
  GET  /mine?tau=1&kmax=3&mode=approx&epsilon=0.1       -> ε-confident sampled
                                                           answer: scaled counts +
                                                           confidence/epsilon/seed/
                                                           boundary_count in "info";
                                                           exact refinement runs in
                                                           the background
  GET  /report?tau=1&kmax=3                             -> sdc quasi-id report
  GET  /risk?tau=1&kmax=3&top=10                        -> per-record risk profile
  GET  /anonymize?tau=1&kmax=3                          -> verified masking plan
  GET  /stats                                           -> store/placement/cache/http stats,
                                                           durability/resilience sections,
                                                           unified executables, last_mine timing
  GET  /healthz                                         -> liveness (never gated)
  GET  /readyz                                          -> readiness: 503 while recovering
                                                           (WAL replay / job resume) or while
                                                           the device circuit breaker is open
  POST /cancel   {"tau": 1, "kmax": 3}                  -> cancel in-flight matching runs
  GET  /metrics                                         -> Prometheus text exposition
                                                           (auth-gated, backpressure-exempt)
  GET  /trace?n=10 | /trace?id=TRACE_ID                 -> recent mining-trace span trees;
                                                           &before=SEQ pages backwards
                                                           without duplicates (the response
                                                           carries "next_before")
  GET  /debug/lastcrash                                 -> the previous incarnation's
                                                           parsed flight ring (in-flight
                                                           spans at death, last checkpointed
                                                           level, active request keys)
  GET  /debug/slowlog?n=20                              -> newest-first slow-mine cost
                                                           envelopes (--slow-mine-threshold-s)
  GET  /debug/bundle                                    -> one gzipped JSON postmortem
                                                           bundle: metrics, traces, slowlog,
                                                           lastcrash, stats, exec-cache keys,
                                                           resolved config

Request correlation: every data route runs under a trace. Clients may send
``X-Trace-Id``; the id (incoming or freshly minted) is echoed in the
``X-Trace-Id`` response header and as ``"trace_id"`` in JSON bodies, and the
span tree is retrievable at ``GET /trace?id=...``. ``--log-json`` switches
logs to one-JSON-object-per-line carrying the same ``trace_id``.

``source`` in the /mine response is "cold", "incremental" or "cache" — the
CI smoke job asserts a repeated query comes back "cache". A ``deadline_s``
on /mine bounds the request: an exceeded deadline returns ``499`` with the
partial result mined so far (``"source": "partial"``). With
``mode=approx`` the source is "approx" (sample-mined), "refined" (already
promoted to exact) or "cache"; ``/stats`` carries a ``sampling`` section
with the derived sampler seed and refinement counters.

Durability (``--wal-dir DIR``): appends are WAL-logged and fsync'd before
itemization, snapshots fold the log every ``--snapshot-every`` appends, and
a restarted server recovers the store to the exact pre-crash version (and
resumes interrupted mine jobs from their last checkpointed level). SIGTERM
drains in-flight requests (bounded by ``--drain-timeout``), snapshots the
store, and exits 0.

Hardening (ROADMAP "authn and backpressure"):

* ``--auth-token TOKEN`` (or env ``MINER_AUTH_TOKEN``) requires
  ``Authorization: Bearer TOKEN`` on every route except ``/healthz``;
  constant-time comparison, 401 on mismatch.
* ``--max-inflight N`` bounds concurrently served requests; when the bound
  is hit new requests get an immediate ``429 {"error": ...}`` instead of
  piling onto the mining worker (liveness stays exempt so probes never 429).
"""

from __future__ import annotations

import argparse
import gzip
import hmac
import json
import os
import signal
import sys
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from urllib.parse import parse_qs, urlparse

import numpy as np

from ..obs import logs as obs_logs
from ..obs import metrics as _om
from ..obs.trace import TRACER as _obs_tracer
from ..obs.trace import current_trace_id as _current_trace_id
from ..obs.trace import span as _obs_span
from ..service import (
    DeadlineExceeded,
    IncrementalConfig,
    MiningService,
    NotReadyError,
)

__all__ = ["make_server", "main"]

_log = obs_logs.get_logger()

# routes are a small fixed set, so route is a safe label; anything else is
# bucketed as "other" to bound cardinality against path scanning
_KNOWN_ROUTES = frozenset(
    {"/append", "/mine", "/report", "/risk", "/anonymize", "/stats",
     "/cancel", "/healthz", "/readyz", "/metrics", "/trace",
     "/debug/lastcrash", "/debug/slowlog", "/debug/bundle"}
)
# data routes run under a trace; probes and the obs endpoints themselves
# don't (a scrape must never displace a mining trace in the ring buffer)
_TRACED_ROUTES = frozenset(
    {"/append", "/mine", "/report", "/risk", "/anonymize", "/cancel"}
)

_HTTP_REQUESTS = _om.counter(
    "repro_http_requests_total",
    "HTTP requests served by route and status code.",
    ("route", "code"),
)
_HTTP_LATENCY = _om.histogram(
    "repro_http_request_seconds",
    "Wall time spent handling one HTTP request.",
    labelnames=("route",),
)


def _mine_params(payload: dict) -> dict:
    return {
        "tau": int(payload.get("tau", 1)),
        "kmax": int(payload.get("kmax", 3)),
        "ordering": str(payload.get("ordering", "ascending")),
    }


class MinerHandler(BaseHTTPRequestHandler):
    service: MiningService  # bound by make_server
    quiet: bool = True
    auth_token: str | None = None
    inflight: threading.BoundedSemaphore | None = None
    http_stats: dict  # shared counters, bound by make_server
    _stats_lock = threading.Lock()
    _trace_id: str | None = None  # per-request, set by _run
    _last_code: int = 0

    def log_message(self, fmt, *args):  # noqa: D102
        if not self.quiet:
            super().log_message(fmt, *args)

    def _count(self, key: str) -> None:
        with self._stats_lock:
            self.http_stats[key] = self.http_stats.get(key, 0) + 1

    def _send(self, code: int, payload: dict) -> None:
        if self._trace_id and isinstance(payload, dict):
            payload.setdefault("trace_id", self._trace_id)
        body = json.dumps(payload).encode()
        self._last_code = code
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        if self._trace_id:
            self.send_header("X-Trace-Id", self._trace_id)
        self.end_headers()
        self.wfile.write(body)

    def _send_text(self, code: int, text: str,
                   content_type: str = "text/plain; version=0.0.4; charset=utf-8") -> None:
        body = text.encode("utf-8")
        self._last_code = code
        self.send_response(code)
        self.send_header("Content-Type", content_type)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _send_gzip_json(self, code: int, payload: dict) -> None:
        body = gzip.compress(json.dumps(payload, default=str).encode("utf-8"))
        self._last_code = code
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Encoding", "gzip")
        self.send_header("Content-Length", str(len(body)))
        if self._trace_id:
            self.send_header("X-Trace-Id", self._trace_id)
        self.end_headers()
        self.wfile.write(body)

    def _body(self) -> dict:
        length = int(self.headers.get("Content-Length") or 0)
        if not length:
            return {}
        return json.loads(self.rfile.read(length) or b"{}")

    def _query(self) -> dict:
        qs = parse_qs(urlparse(self.path).query)
        return {k: v[0] for k, v in qs.items()}

    def _authorized(self) -> bool:
        if not self.auth_token:
            return True
        # compare bytes: compare_digest on str raises TypeError for
        # non-ASCII, and header bytes are attacker-controlled
        header = self.headers.get("Authorization", "").encode("utf-8")
        return hmac.compare_digest(header, f"Bearer {self.auth_token}".encode("utf-8"))

    def _handle(self, payload: dict) -> None:
        route = urlparse(self.path).path
        if route == "/healthz":  # liveness: never auth-gated, never queued
            self._send(200, {"ok": True})
            return
        if route == "/readyz":  # readiness: also probe-exempt, but honest
            ready, reason = self.service.readiness()
            self._send(200 if ready else 503, {"ready": ready, "reason": reason})
            return
        if not self._authorized():
            self._count("unauthorized")
            self._send(401, {"error": "missing or invalid bearer token"})
            return
        if route == "/metrics":
            # backpressure-exempt: a saturated server is exactly when the
            # scrape matters most (still auth-gated — internals leak here)
            self._count("scrapes")
            self._send_text(200, _om.REGISTRY.render())
            return
        if route == "/trace":
            self._handle_trace(payload)
            return
        if route.startswith("/debug/"):
            # forensic snapshots are backpressure-exempt for the same reason
            # /metrics is: a saturated or just-crashed server is exactly when
            # operators need them (still auth-gated — internals leak here)
            self._handle_debug(route, payload)
            return
        if self.inflight is not None and not self.inflight.acquire(blocking=False):
            self._count("rejected")
            self._send(429, {"error": "request queue full, retry later"})
            return
        try:
            self._count("served")
            self._dispatch(route, payload)
        finally:
            if self.inflight is not None:
                self.inflight.release()

    def _dispatch(self, route: str, payload: dict) -> None:
        if route == "/stats":
            stats = self.service.stats()
            with self._stats_lock:
                stats["http"] = dict(self.http_stats)
            stats["http"]["auth"] = bool(self.auth_token)
            stats["http"]["max_inflight"] = (
                self.inflight._initial_value if self.inflight is not None else None
            )
            self._send(200, stats)
        elif route == "/append":
            rows = np.asarray(payload.get("rows", []), dtype=np.int64)
            if rows.size == 0:
                self._send(400, {"error": "append requires non-empty 'rows'"})
                return
            self._send(200, self.service.append(rows))
        elif route == "/mine":
            max_itemsets = payload.get("max_itemsets")
            deadline_s = payload.get("deadline_s")
            mode = str(payload.get("mode", "exact"))
            if mode not in ("exact", "approx"):
                self._send(
                    400, {"error": f"mode must be 'exact' or 'approx', got {mode!r}"}
                )
                return
            epsilon = payload.get("epsilon")
            resp = self.service.mine(
                **_mine_params(payload),
                deadline_s=float(deadline_s) if deadline_s is not None else None,
                mode=mode,
                epsilon=float(epsilon) if epsilon is not None else None,
            )
            # 499 (client-timeout convention): the run stopped at a batch
            # boundary; the body still carries the valid partial answer
            code = 499 if resp.source == "partial" else 200
            if code == 499:
                self._count("deadline_exceeded")
            # itemset decode + JSON encode is real wall time on a cold mine;
            # span it so the trace tree accounts for the full request
            with _obs_span("http.respond"):
                self._send(
                    code,
                    resp.to_json(
                        max_itemsets=int(max_itemsets)
                        if max_itemsets is not None
                        else None
                    ),
                )
        elif route == "/cancel":
            self._send(
                200,
                self.service.cancel(
                    int(payload.get("tau", 1)),
                    int(payload.get("kmax", 3)),
                    str(payload.get("ordering", "ascending")),
                ),
            )
        elif route == "/report":
            self._send(200, self.service.report(**_mine_params(payload)))
        elif route == "/risk":
            top = int(payload.get("top", 10))
            self._send(200, self.service.risk(**_mine_params(payload), top=top))
        elif route == "/anonymize":
            max_sup = payload.get("max_suppressions")
            self._send(
                200,
                self.service.anonymize_plan(
                    **_mine_params(payload),
                    max_suppressions=int(max_sup) if max_sup is not None else 200,
                ),
            )
        else:
            self._send(404, {"error": f"unknown route {route}"})

    def _handle_trace(self, payload: dict) -> None:
        trace_id = payload.get("id")
        if trace_id:
            trace = _obs_tracer.get(str(trace_id))
            if trace is None:
                self._send(404, {"error": f"no stored trace {trace_id!r}"})
                return
            self._send(200, {"trace": trace.to_dict()})
            return
        n = int(payload.get("n", 10))
        before = payload.get("before")
        traces, next_before = _obs_tracer.page(
            n, before=int(before) if before is not None else None
        )
        self._send(
            200,
            {
                "traces": [t.to_dict() for t in traces],
                "next_before": next_before,
                "tracer": _obs_tracer.stats(),
            },
        )

    def _handle_debug(self, route: str, payload: dict) -> None:
        if route == "/debug/lastcrash":
            self._count("debug")
            self._send(
                200, {"report": self.service.last_crash_report()}
            )
        elif route == "/debug/slowlog":
            self._count("debug")
            n = payload.get("n")
            self._send(
                200,
                {
                    "entries": self.service.slowlog_entries(
                        int(n) if n is not None else None
                    ),
                    "slowlog": self.service.slowlog.stats(),
                },
            )
        elif route == "/debug/bundle":
            self._count("debug")
            self._send_gzip_json(200, self.service.debug_bundle())
        else:
            self._send(404, {"error": f"unknown route {route}"})

    def _run(self, payload: dict) -> None:
        try:
            self._handle(payload)
        except NotReadyError as e:
            self._send(503, {"error": str(e), "retry": True})
        except DeadlineExceeded as e:
            # a coalesced waiter timed out; the shared run keeps going for
            # the waiters that imposed no deadline
            self._count("deadline_exceeded")
            self._send(499, {"error": str(e)})
        except Exception as e:  # service must survive bad requests
            self._send(500, {"error": f"{type(e).__name__}: {e}"})

    def _serve(self, payload: dict) -> None:
        route = urlparse(self.path).path
        t0 = time.perf_counter()
        self._trace_id = None
        if route in _TRACED_ROUTES:
            incoming = self.headers.get("X-Trace-Id") or None
            with _obs_tracer.start(
                "http " + route, trace_id=incoming, meta={"route": route}
            ) as sp:
                # sampled-out requests still echo a client-supplied id so
                # upstream correlation survives sampling
                self._trace_id = _current_trace_id() or incoming
                self._run(payload)
                sp.set(code=self._last_code)
        else:
            self._run(payload)
        dt = time.perf_counter() - t0
        label = route if route in _KNOWN_ROUTES else "other"
        _HTTP_REQUESTS.inc(route=label, code=str(self._last_code))
        _HTTP_LATENCY.observe(dt, route=label)
        # probes poll constantly; keep them out of info-level access logs
        log = _log.debug if route in ("/healthz", "/readyz") else _log.info
        log(
            "%s %s %d %.1fms", self.command, route, self._last_code, dt * 1e3,
            extra={"route": label, "code": self._last_code,
                   "duration_ms": round(dt * 1e3, 2)},
        )

    def do_GET(self):  # noqa: N802
        self._serve(self._query())

    def do_POST(self):  # noqa: N802
        try:
            payload = self._body()
        except Exception as e:
            self._send(400, {"error": f"{type(e).__name__}: {e}"})
            return
        self._serve(payload)


def make_server(
    service: MiningService,
    host: str = "127.0.0.1",
    port: int = 8750,
    *,
    quiet: bool = True,
    auth_token: str | None = None,
    max_inflight: int | None = None,
) -> ThreadingHTTPServer:
    sem = None
    if max_inflight is not None:
        if max_inflight <= 0:
            raise ValueError(f"max_inflight must be positive, got {max_inflight}")
        sem = threading.BoundedSemaphore(max_inflight)
    handler = type(
        "BoundMinerHandler",
        (MinerHandler,),
        {
            "service": service,
            "quiet": quiet,
            "auth_token": auth_token,
            "inflight": sem,
            "http_stats": {},
        },
    )
    return ThreadingHTTPServer((host, port), handler)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8750)
    ap.add_argument("--engine", default="numpy", choices=["numpy", "jnp", "pallas"])
    ap.add_argument("--mesh", default=None, metavar="DATAxMODEL",
                    help="serve from a word-sharded mesh store, e.g. '2x4' "
                         "(pair shards x word shards over the visible devices)")
    ap.add_argument("--cache-capacity", type=int, default=64)
    ap.add_argument("--cache-max-bytes", type=int, default=None,
                    help="bound the result cache by payload bytes, not just "
                         "entry count")
    ap.add_argument("--wal-dir", default=None,
                    help="durability directory (write-ahead log + snapshots); "
                         "a restarted server recovers the store from it")
    ap.add_argument("--snapshot-every", type=int, default=8,
                    help="fold the WAL into a snapshot every N appends")
    ap.add_argument("--drain-timeout", type=float, default=10.0,
                    help="seconds SIGTERM waits for in-flight requests before "
                         "cancelling them")
    ap.add_argument("--max-delta-fraction", type=float, default=0.25)
    ap.add_argument("--compact-threshold", type=int, default=None,
                    help="auto-compact the store when this many append "
                         "versions accumulate")
    ap.add_argument("--auth-token", default=os.environ.get("MINER_AUTH_TOKEN"),
                    help="require 'Authorization: Bearer <token>' "
                         "(default: $MINER_AUTH_TOKEN)")
    ap.add_argument("--max-inflight", type=int, default=64,
                    help="429 when this many requests are already in flight "
                         "(0 disables the bound)")
    ap.add_argument("--preload", default=None,
                    help="'randomized' for a synthetic table, or a path: "
                         "*.csv via data.loaders.read_csv, else FIMI format")
    ap.add_argument("--n", type=int, default=2000, help="--preload randomized rows")
    ap.add_argument("--m", type=int, default=10, help="--preload randomized columns")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--verbose", action="store_true")
    ap.add_argument("--log-level", default="info",
                    choices=["debug", "info", "warning", "error"],
                    help="minimum level for structured logs")
    ap.add_argument("--log-json", action="store_true",
                    help="emit logs as one JSON object per line (with "
                         "trace_id correlation)")
    ap.add_argument("--profile-dir", default=None,
                    help="wrap cold mines in jax.profiler and dump xplane "
                         "traces into this directory")
    ap.add_argument("--trace-max", type=int, default=64,
                    help="ring-buffer size for finished traces (GET /trace)")
    ap.add_argument("--trace-sample", type=int, default=1,
                    help="trace 1 in N requests (1 = every request)")
    ap.add_argument("--slow-mine-threshold-s", type=float, default=1.0,
                    help="mines slower than this land in GET /debug/slowlog "
                         "with their full cost envelope")
    ap.add_argument("--no-flight", action="store_true",
                    help="disable the crash-persistent flight recorder "
                         "(only meaningful with --wal-dir)")
    ap.add_argument("--coordinator-address", default=None,
                    help="host:port rendezvous for the multi-host fleet "
                         "(jax.distributed.initialize)")
    ap.add_argument("--num-processes", type=int, default=1,
                    help="fleet size; each process stores only its word "
                         "stripes and process 0 binds HTTP")
    ap.add_argument("--process-id", type=int, default=0,
                    help="this process's fleet index (0 = coordinator)")
    ap.add_argument("--fleet-timeout-s", type=float, default=60.0,
                    help="deadline for a peer's collective round; a miss "
                         "degrades the fleet to single-host")
    ap.add_argument("--no-shadow", action="store_true",
                    help="skip the coordinator's full-copy shadow service "
                         "(halves its memory; peer death then fails "
                         "requests instead of degrading)")
    ap.add_argument("--flight-fsync-s", type=float, default=0.25,
                    help="flight-recorder flush/fsync cadence; checkpoints "
                         "and config events always fsync inline")
    ap.add_argument("--flight-max-bytes", type=int, default=1 << 20,
                    help="on-disk bound for the flight event ring")
    args = ap.parse_args()

    obs_logs.setup(level=args.log_level, json_mode=args.log_json)
    _obs_tracer.configure(
        max_traces=args.trace_max, sample_every=args.trace_sample
    )

    # multi-host fleet bootstrap (before any jax device use): join the
    # rendezvous, then wrap the in-host placement into a FleetPlacement so
    # every popcount batch all-reduces over the DCN collective
    from .mesh import distributed_init

    pid, nproc = distributed_init(
        args.coordinator_address, args.num_processes, args.process_id
    )

    placement = None
    if args.mesh:
        from ..core.placement import MeshPlacement
        from .mesh import mesh_from_spec

        placement = MeshPlacement(
            mesh_from_spec(args.mesh), pair_axes=("data",), word_axis="model"
        )

    fleet_collective = None
    if nproc > 1:
        from ..core.collective import FleetCollective
        from ..core.fleet import FleetPlacement
        from ..core.placement import resolve_placement
        from ..core.preprocess import set_row_group_collective
        from ..core.kyiv import KyivConfig

        fleet_collective = FleetCollective(timeout_s=args.fleet_timeout_s)
        set_row_group_collective(fleet_collective)
        inner = placement or resolve_placement(KyivConfig(engine=args.engine))
        placement = FleetPlacement(inner, collective=fleet_collective)

    # per-host durability: each process journals and snapshots only its own
    # stripes; a fleet restart recovers every shard locally, in parallel
    wal_dir = args.wal_dir
    if wal_dir is not None and nproc > 1:
        wal_dir = os.path.join(wal_dir, f"p{pid}")

    service = MiningService(
        engine=args.engine,
        placement=placement,
        cache_capacity=args.cache_capacity,
        cache_max_bytes=args.cache_max_bytes,
        compact_threshold=args.compact_threshold,
        wal_dir=wal_dir,
        snapshot_every=args.snapshot_every,
        incremental=IncrementalConfig(max_delta_fraction=args.max_delta_fraction),
        profile_dir=args.profile_dir,
        slow_mine_threshold_s=args.slow_mine_threshold_s,
        flight_enabled=not args.no_flight,
        flight_fsync_s=args.flight_fsync_s,
        flight_max_bytes=args.flight_max_bytes,
    )

    if nproc > 1:
        from ..service.fleet import FleetFrontend, serve_fleet_peer

        if pid != 0:
            # peer process: no HTTP, no preload — rows and requests arrive
            # over the command bus until the coordinator broadcasts shutdown
            _log.info(
                "fleet peer p%d/%d entering command loop", pid, nproc,
                extra={"event": "fleet-peer", "pid": pid},
            )
            summary = serve_fleet_peer(service, fleet_collective)
            service.close()
            _log.info(
                "fleet peer p%d stopped (%s, %d ops)",
                pid, summary["reason"], summary["executed"],
                extra={"event": "fleet-peer-stop", **summary},
            )
            return
        shadow = None
        if not args.no_shadow:
            shadow = MiningService(
                engine=args.engine,
                cache_capacity=args.cache_capacity,
                incremental=IncrementalConfig(
                    max_delta_fraction=args.max_delta_fraction
                ),
            )
        service = FleetFrontend(service, fleet_collective, shadow=shadow)

    if args.preload == "randomized":
        from ..data.synth import randomized_dataset

        service.append(randomized_dataset(args.n, args.m, seed=args.seed))
    elif args.preload and args.preload.endswith(".csv"):
        from ..data.loaders import read_csv

        service.append(read_csv(args.preload)[0])
    elif args.preload:
        from ..data.loaders import read_fimi

        service.append(read_fimi(args.preload))

    server = make_server(
        service,
        args.host,
        args.port,
        quiet=not args.verbose,
        auth_token=args.auth_token,
        max_inflight=args.max_inflight or None,
    )
    store = service._store
    _log.info(
        "serve_miner on http://%s:%d (placement=%s, rows=%d, items=%d, "
        "auth=%s, max_inflight=%s, wal=%s, profile=%s)",
        args.host, args.port, service.placement.describe(),
        store.n_rows if store else 0, store.n_items if store else 0,
        "on" if args.auth_token else "off",
        args.max_inflight or "unbounded", args.wal_dir or "off",
        args.profile_dir or "off",
        extra={"event": "startup", "port": args.port},
    )

    # graceful shutdown: the server loop runs in a thread; the main thread
    # waits on the signal, stops accepting, drains in-flight work (bounded),
    # snapshots the durable store, and exits 0 so supervisors see a clean stop
    stop = threading.Event()

    def _on_signal(signum, frame):
        stop.set()

    signal.signal(signal.SIGTERM, _on_signal)
    signal.signal(signal.SIGINT, _on_signal)

    thread = threading.Thread(target=server.serve_forever, daemon=True)
    thread.start()
    try:
        while not stop.wait(0.2):
            pass
    except KeyboardInterrupt:
        pass
    _log.info("serve_miner draining...", extra={"event": "drain"})
    server.shutdown()
    thread.join()
    drain = service.drain(args.drain_timeout)
    snapshot = service.snapshot_store()
    server.server_close()
    service.close()
    _log.info(
        "serve_miner stopped (drained=%d, abandoned=%d, snapshot=%s)",
        drain["drained"], drain["abandoned"],
        "v%d" % snapshot if snapshot is not None else "none",
        extra={"event": "shutdown", "drained": drain["drained"],
               "abandoned": drain["abandoned"]},
    )
    sys.exit(0)


if __name__ == "__main__":
    main()
