import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes, record memory/cost analysis + collective schedule +
roofline terms.

MUST keep the two lines above first — jax locks the device count on first
initialization.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --mesh both          # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1.5-110b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --mining             # paper-technique rows
  PYTHONPATH=src python -m repro.launch.dryrun --list               # show cells

Artifacts: one JSON per cell under artifacts/dryrun/.
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from ..configs import ARCHS, SHAPES, cells, input_specs
from ..distributed.sharding import make_plan
from ..models.zoo import build
from ..roofline.analysis import parse_collectives, roofline_terms
from ..roofline.analytic import analytic_work
from ..roofline.hw import V5E
from ..training.optimizer import OptConfig, adamw_init
from ..training.train import make_train_step
from .mesh import make_production_mesh

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "../../../artifacts/dryrun")


def _mesh_tag(multi_pod: bool) -> str:
    return "pod2x16x16" if multi_pod else "pod16x16"


def _abstract_opt_state(aparams):
    return jax.eval_shape(adamw_init, aparams)


def _model_flops(arch, shape) -> float:
    n_active = arch.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch  # decode: 1 token per row


def lower_cell(arch_name: str, shape_name: str, multi_pod: bool, grad_accum: int = 1,
               unroll_decode: bool = False):
    """Lower+compile one cell; returns the result record."""
    arch = ARCHS[arch_name]
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    plan = make_plan(mesh)
    model = build(arch)
    specs = input_specs(arch, shape)

    t0 = time.perf_counter()
    aparams = model.abstract_params()
    if shape.kind in ("prefill", "decode"):
        # serving weights are inference-only bf16; drop the FSDP dim when the
        # model fits tp-only (kills per-step weight all-gathers — §Perf it.4)
        aparams = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, jnp.bfloat16)
            if a.dtype == jnp.float32 else a,
            aparams,
        )
        serve_tp_only = arch.param_count() * 2 / mesh.shape["model"] < 8e9
        plan = make_plan(mesh, serve=serve_tp_only)
    pshard = plan.param_shardings(aparams)
    bshard = plan.batch_shardings(specs)

    if shape.kind == "train":
        # ZeRO-1-style option: when params+moments fit tp-only, drop the FSDP
        # dim for weights — removes all per-layer weight gathers (grad
        # all-reduce over dp remains). Same rule family as serve mode.
        if os.environ.get("REPRO_TRAIN_TP_ONLY") == "1":
            plan = make_plan(mesh, serve=True)
        step_fn, shardings_for = make_train_step(
            model, OptConfig(), plan, grad_accum=grad_accum
        )
        aopt = _abstract_opt_state(aparams)
        pspec, ospec = shardings_for(aparams)
        jitted = jax.jit(
            step_fn,
            in_shardings=(pspec, ospec, bshard),
            out_shardings=(pspec, ospec, None),
            donate_argnums=(0, 1),  # params/opt updated in place (aliasing)
        )
        with jax.set_mesh(mesh):
            lowered = jitted.lower(aparams, aopt, specs)
    elif shape.kind == "prefill":
        ctx = plan.ctx()

        def prefill_fn(params, batch):
            return model.prefill(params, ctx, batch)

        jitted = jax.jit(prefill_fn, in_shardings=(pshard, bshard))
        with jax.set_mesh(mesh):
            lowered = jitted.lower(aparams, specs)
    else:  # decode
        ctx = plan.ctx()
        stacked = not unroll_decode
        if unroll_decode:
            acache = model.init_cache(shape.global_batch, shape.seq_len,
                                      abstract=True, stacked=False)
        else:
            acache = model.init_cache(shape.global_batch, shape.seq_len, abstract=True)
        cshard = plan.cache_shardings(acache)

        def decode_fn(params, batch, cache):
            if unroll_decode:
                return model.decode(params, ctx, batch, cache, unroll_groups=True)
            return model.decode(params, ctx, batch, cache)

        jitted = jax.jit(decode_fn, in_shardings=(pshard, bshard, cshard),
                         out_shardings=(None, cshard),
                         donate_argnums=(2,))  # KV cache updated in place
        with jax.set_mesh(mesh):
            lowered = jitted.lower(aparams, specs, acache)

    t_lower = time.perf_counter() - t0
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    n_dev = 512 if multi_pod else 256
    work = analytic_work(arch, shape, n_dev)
    report = roofline_terms(
        cost, hlo, V5E,
        model_flops_per_dev=_model_flops(arch, shape) / n_dev,
        analytic=work,
    )
    colls = parse_collectives(hlo)
    by_kind: dict[str, int] = {}
    for c in colls:
        by_kind[c.kind] = by_kind.get(c.kind, 0) + 1

    record = {
        "arch": arch_name,
        "shape": shape_name,
        "mesh": _mesh_tag(multi_pod),
        "kind": shape.kind,
        "status": "ok",
        "t_lower_s": round(t_lower, 2),
        "t_compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_estimate_bytes": mem.argument_size_in_bytes + mem.temp_size_in_bytes,
            "hbm_per_chip": V5E.hbm_bytes,
            "fits": (mem.argument_size_in_bytes - mem.alias_size_in_bytes
                     + mem.temp_size_in_bytes + mem.output_size_in_bytes) < V5E.hbm_bytes,
        },
        "roofline": report.to_dict(),
        "collectives": by_kind,
        "sharding_fallbacks": plan.fallbacks,
        "params": arch.param_count(),
        "active_params": arch.active_param_count(),
        "grad_accum": grad_accum,
        "unroll_decode": unroll_decode,
    }
    return record


def lower_mining(multi_pod: bool, *, t_parents=32768, n_words=262144, m_pairs_count=1 << 20,
                 m_pairs_write=1 << 16):
    """Lower the paper-technique workload: sharded Kyiv level step."""
    from ..core.sharded import sharded_level_count_step, sharded_level_step

    mesh = make_production_mesh(multi_pod=multi_pod)
    pair_axes = ("pod", "data") if multi_pod else ("data",)
    out = []

    # beyond-paper variant: group-tiled count kernel (kernels/intersect/tiled.py).
    # Same pairs/FLOPs; HBM traffic drops from 2·M·W·4 (two row fetches per
    # pair) to 2·T·bm·W·4 (one fetch per row block per block-pair). With
    # groups of ~64 rows and bm=8 that is ~bm/2 = 4x off the dominant
    # (memory) term. Reported analytically — the Pallas kernel's VMEM reuse
    # is structural, not visible to the CPU interpret lowering.
    bm = 8
    g = 64  # representative prefix-group size at the level equator
    n_groups_ = t_parents // g
    tiles_per_group = (g // bm) * (g // bm + 1) // 2
    T_tiles = n_groups_ * tiles_per_group
    from ..roofline.hw import V5E as _hw

    pairwise_bytes = 2 * m_pairs_count * n_words * 4 / (256 if not multi_pod else 512)
    tiled_bytes = 2 * T_tiles * bm * n_words * 4 / (256 if not multi_pod else 512)
    out.append({
        "arch": "kyiv-mining-count-tiled",
        "shape": f"t{t_parents}_W{n_words}_M{m_pairs_count}_bm{bm}",
        "mesh": _mesh_tag(multi_pod),
        "kind": "mining",
        "status": "ok",
        "analytic_only": True,
        "memory": {"fits": True},
        "roofline": {
            "flops_per_dev": 0.0,
            "hbm_bytes_per_dev": tiled_bytes,
            "collective_bytes_per_dev": 0,
            "t_compute": 3.27e-05,  # unchanged vs gather-based count step
            "t_memory": tiled_bytes / _hw.hbm_bw,
            "t_collective": 0.0,
            "n_collectives": 0,
            "dominant": "memory",
            "model_flops": 0.0,
            "useful_flops_ratio": 0.0,
            "baseline_t_memory": pairwise_bytes / _hw.hbm_bw,
            "traffic_reduction": pairwise_bytes / tiled_bytes,
        },
        "collectives": {},
    })

    for variant, m_pairs in (("count", m_pairs_count), ("write", m_pairs_write)):
        t0 = time.perf_counter()
        with jax.set_mesh(mesh):
            if variant == "count":
                fn, in_specs, _ = sharded_level_count_step(
                    mesh, pair_axes=pair_axes, word_axis="model"
                )
            else:
                fn, in_specs, _ = sharded_level_step(
                    mesh, pair_axes=pair_axes, word_axis="model"
                )
            bits = jax.ShapeDtypeStruct((t_parents, n_words), jnp.uint32)
            pairs = jax.ShapeDtypeStruct((m_pairs, 2), jnp.int32)
            lowered = fn.lower(bits, pairs)
            compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        report = roofline_terms(cost, hlo, V5E)
        out.append({
            "arch": f"kyiv-mining-{variant}",
            "shape": f"t{t_parents}_W{n_words}_M{m_pairs}",
            "mesh": _mesh_tag(multi_pod),
            "kind": "mining",
            "status": "ok",
            "t_compile_s": round(time.perf_counter() - t0, 2),
            "memory": {
                "argument_bytes": mem.argument_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "fits": (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                         + mem.output_size_in_bytes) < V5E.hbm_bytes,
            },
            "roofline": report.to_dict(),
            "collectives": {
                c.kind: sum(1 for x in parse_collectives(hlo) if x.kind == c.kind)
                for c in parse_collectives(hlo)
            },
        })
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id or 'all'")
    ap.add_argument("--shape", default=None, help="shape id or 'all'")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--mining", action="store_true", help="run the mining rows only")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--out", default=ARTIFACT_DIR)
    ap.add_argument("--accum", type=int, default=1, help="grad accumulation steps")
    ap.add_argument("--unroll-decode", action="store_true",
                    help="unrolled decode layers + per-layer donated caches")
    ap.add_argument("--tag", default="", help="suffix for artifact filenames")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    if args.list:
        for arch, shape, skipped in cells(include_skipped=True):
            mark = "SKIP(long-context n/a)" if skipped else ""
            print(f"{arch.name:25s} x {shape.name:12s} {mark}")
        return

    if args.mining:
        for mp in meshes:
            for rec in lower_mining(mp):
                path = os.path.join(args.out, f"{rec['arch']}__{rec['mesh']}.json")
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                r = rec["roofline"]
                print(f"[ok] {rec['arch']:22s} {rec['mesh']:10s} "
                      f"tc={r['t_compute']:.2e} tm={r['t_memory']:.2e} "
                      f"tcoll={r['t_collective']:.2e} dom={r['dominant']}")
        return

    todo = []
    for arch, shape, skipped in cells(include_skipped=True):
        if args.arch and args.arch != "all" and arch.name != args.arch:
            continue
        if args.shape and args.shape != "all" and shape.name != args.shape:
            continue
        todo.append((arch.name, shape.name, skipped))

    failures = 0
    for arch_name, shape_name, skipped in todo:
        for mp in meshes:
            tag = f"{arch_name}__{shape_name}__{_mesh_tag(mp)}" + (
                f"__{args.tag}" if args.tag else ""
            )
            path = os.path.join(args.out, tag + ".json")
            if skipped:
                rec = {
                    "arch": arch_name, "shape": shape_name, "mesh": _mesh_tag(mp),
                    "status": "skipped",
                    "reason": "long_500k n/a for pure full-attention arch "
                              "(noted in DESIGN.md §5)",
                }
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                print(f"[skip] {tag}")
                continue
            try:
                rec = lower_cell(arch_name, shape_name, mp, grad_accum=args.accum,
                                 unroll_decode=args.unroll_decode)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                r = rec["roofline"]
                m = rec["memory"]
                print(
                    f"[ok] {tag:55s} compile={rec['t_compile_s']:7.1f}s "
                    f"mem={(m['argument_bytes'] + m['temp_bytes']) / 1e9:6.2f}GB "
                    f"fits={m['fits']} tc={r['t_compute']:.2e} tm={r['t_memory']:.2e} "
                    f"tcoll={r['t_collective']:.2e} dom={r['dominant']}",
                    flush=True,
                )
            except Exception as e:  # record failure, keep going
                failures += 1
                rec = {
                    "arch": arch_name, "shape": shape_name, "mesh": _mesh_tag(mp),
                    "status": "error", "error": repr(e),
                    "traceback": traceback.format_exc()[-4000:],
                }
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                print(f"[FAIL] {tag}: {e!r}", flush=True)
    if failures:
        raise SystemExit(f"{failures} cells failed")
    print("dry-run complete")


if __name__ == "__main__":
    main()
