"""Training driver: config -> data -> sharded train loop with checkpointing.

Usage (CPU-scale example, reduced config):
  PYTHONPATH=src python -m repro.launch.train --arch glm4-9b --reduced \
      --steps 100 --batch 8 --seq 64 --ckpt-dir /tmp/ck --ckpt-every 20

On a real cluster the same driver runs with --mesh production (16x16) or
--mesh multipod; this container lowers those only via dryrun.py.
"""

from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from ..configs import get_arch, reduced as reduce_cfg
from ..distributed.checkpoint import CheckpointManager
from ..distributed.sharding import make_plan
from ..models.zoo import build
from ..training.optimizer import OptConfig, adamw_init
from ..training.train import make_train_step
from .mesh import make_host_mesh, make_production_mesh


def synthetic_lm_batches(vocab: int, batch: int, seq: int, seed: int = 0):
    """Deterministic synthetic LM stream: structured (learnable) sequences —
    token t+1 = (token_t * 31 + column) % vocab with random starts."""
    rng = np.random.default_rng(seed)
    while True:
        start = rng.integers(1, vocab, size=(batch, 1))
        idx = np.arange(seq + 1)[None, :]
        toks = (start * 31 + idx * 131) % max(vocab - 1, 1) + 1
        yield {
            "tokens": jnp.asarray(toks[:, :-1], jnp.int32),
            "labels": jnp.asarray(toks[:, 1:], jnp.int32),
        }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--mesh", default="none", choices=["none", "host", "production", "multipod"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    model = build(cfg)

    plan = None
    if args.mesh != "none":
        mesh = {
            "host": make_host_mesh,
            "production": lambda: make_production_mesh(multi_pod=False),
            "multipod": lambda: make_production_mesh(multi_pod=True),
        }[args.mesh]()
        plan = make_plan(mesh)

    opt_cfg = OptConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                        total_steps=args.steps)
    params = model.init(jax.random.PRNGKey(args.seed))
    opt_state = adamw_init(params)
    start_step = 0

    cm = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    if cm and args.resume and cm.latest_step() is not None:
        tree, meta = cm.restore()
        params, opt_state = tree["params"], tree["opt"]
        params = jax.tree.map(jnp.asarray, params)
        opt_state = jax.tree.map(jnp.asarray, opt_state)
        start_step = int(meta["step"])
        print(f"resumed from step {start_step}")

    if plan is None:
        step_fn = make_train_step(model, opt_cfg, grad_accum=args.grad_accum)
    else:
        fn, shardings_for = make_train_step(model, opt_cfg, plan,
                                            grad_accum=args.grad_accum)
        aparams = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(args.seed)))
        pspec, ospec = shardings_for(aparams)
        step_fn = jax.jit(fn, in_shardings=(pspec, ospec, None),
                          out_shardings=(pspec, ospec, None))

    batches = synthetic_lm_batches(cfg.vocab, args.batch, args.seq, args.seed)
    t0 = time.perf_counter()
    losses = []
    for step in range(start_step, args.steps):
        batch = next(batches)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.perf_counter() - t0
            print(f"step {step:5d} loss {losses[-1]:.4f} lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.3f} ({dt:.1f}s)", flush=True)
        if cm and (step + 1) % args.ckpt_every == 0:
            cm.save(step + 1,
                    {"params": jax.tree.map(np.asarray, params),
                     "opt": jax.tree.map(np.asarray, opt_state)},
                    {"arch": cfg.name}, blocking=False)
    if cm:
        cm.wait()
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")


if __name__ == "__main__":
    main()
