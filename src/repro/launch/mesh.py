"""Production meshes and multi-host launch plumbing.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state. Single-pod: 16x16 = 256
chips, axes (data, model). Multi-pod: 2 pods x 256 = 512 chips with a leading
"pod" axis — the pod axis extends data parallelism across the inter-pod
links (DCN in practice; the dry-run proves the sharding is coherent).

Multi-host specs add a leading **dcn** axis over processes:
``mesh_from_spec("2x4x1")`` is 2-way DCN data parallelism x 4-way in-host
pair sharding x 1-way word sharding. ``distributed_init`` wires the
process into the fleet (`jax.distributed.initialize`), ``is_main`` is the
HomebrewNLP-Jax-style coordinator gate (only process 0 binds HTTP / owns
artifact writes), and ``launch_env_summary`` snapshots the launch/XLA flag
environment (``launch/env.sh``) into bench JSONs so perf rows stay
reproducible.
"""

from __future__ import annotations

import os

import jax

__all__ = [
    "make_production_mesh",
    "make_host_mesh",
    "mesh_from_spec",
    "distributed_init",
    "is_main",
    "launch_env_summary",
]


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes
    )


def mesh_from_spec(spec: str) -> jax.sharding.Mesh:
    """Parse a ``--mesh`` CLI spec into a mesh.

    ``"4x2"`` -> 4-way pair sharding x 2-way word sharding over axes
    ``(data, model)``; a bare ``"8"`` means pure word sharding ``(1, 8)`` —
    the row-parallel layout for tables whose bitset rows exceed one device.
    A three-part spec ``"2x4x1"`` adds the leading **dcn** axis over
    processes — axes ``(dcn, data, model)`` — for hybrid DCN x ICI fleets:
    pair batches shard over ``(dcn, data)``, words over ``model``
    (``jax.make_mesh`` orders devices process-major, so the dcn axis falls
    on the slow inter-host links exactly like MaxText's DCN data axis).
    """
    raw = spec.lower().replace("×", "x").split("x")
    if not all(p.isdigit() for p in raw):  # '4x' must error, not flip axes
        raise ValueError(
            f"--mesh spec must be 'MODEL', 'DATAxMODEL' or 'DCNxDATAxMODEL', got {spec!r}"
        )
    parts = [int(p) for p in raw]
    if len(parts) == 1:
        parts = [1, parts[0]]
    if len(parts) == 2:
        axes = ("data", "model")
    elif len(parts) == 3:
        axes = ("dcn", "data", "model")
    else:
        raise ValueError(
            f"--mesh spec must be 'MODEL', 'DATAxMODEL' or 'DCNxDATAxMODEL', got {spec!r}"
        )
    if any(p <= 0 for p in parts):
        raise ValueError(
            f"--mesh spec must be 'MODEL', 'DATAxMODEL' or 'DCNxDATAxMODEL', got {spec!r}"
        )
    return jax.make_mesh(tuple(parts), axes)


def make_host_mesh(data: int = 4, model: int = 2) -> jax.sharding.Mesh:
    """Small mesh over however many (host) devices exist — tests/examples."""
    n = len(jax.devices())
    if data * model > n:
        data, model = max(1, n // 2), min(2, n) if n > 1 else 1
        if data * model > n:
            data, model = n, 1
    return jax.make_mesh(
        (data, model), ("data", "model"),
    )


def distributed_init(
    coordinator_address: str | None,
    num_processes: int,
    process_id: int,
) -> tuple[int, int]:
    """Join the mining fleet: ``jax.distributed.initialize`` on the given
    coordinator rendezvous. A ``num_processes <= 1`` launch is a no-op (the
    single-host path never pays distributed bootstrap); returns the
    effective ``(process_id, num_processes)`` either way."""
    if num_processes <= 1:
        return 0, 1
    if not coordinator_address:
        raise ValueError("--num-processes > 1 requires --coordinator-address")
    jax.distributed.initialize(
        coordinator_address=coordinator_address,
        num_processes=num_processes,
        process_id=process_id,
    )
    return int(jax.process_index()), int(jax.process_count())


def is_main() -> bool:
    """Coordinator gate (HomebrewNLP-Jax ``is_main()`` discipline): exactly
    one process — index 0 — binds the HTTP listener, owns artifact writes
    and merges fleet answers; everyone else runs the peer command loop."""
    return int(jax.process_index()) == 0


def launch_env_summary() -> dict:
    """The launch environment that shaped this process's performance:
    recorded verbatim into bench JSON rows (``benchmarks/bench_mesh.py``)
    so every multi-host perf claim carries the XLA/allocator config that
    produced it (see ``launch/env.sh``)."""
    return {
        "backend": jax.default_backend(),
        "process_count": int(jax.process_count()),
        "local_devices": int(jax.local_device_count()),
        "xla_flags": os.environ.get("XLA_FLAGS", ""),
        "ld_preload": os.environ.get("LD_PRELOAD", ""),
        "jax_platforms": os.environ.get("JAX_PLATFORMS", ""),
        "tcmalloc_report_threshold": os.environ.get(
            "TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD", ""
        ),
    }
