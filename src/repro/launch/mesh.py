"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state. Single-pod: 16x16 = 256
chips, axes (data, model). Multi-pod: 2 pods x 256 = 512 chips with a leading
"pod" axis — the pod axis extends data parallelism across the inter-pod
links (DCN in practice; the dry-run proves the sharding is coherent).
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh", "mesh_from_spec"]


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes
    )


def mesh_from_spec(spec: str) -> jax.sharding.Mesh:
    """Parse a ``--mesh`` CLI spec into a (data, model) mesh.

    ``"4x2"`` -> 4-way pair sharding x 2-way word sharding; a bare ``"8"``
    means pure word sharding ``(1, 8)`` — the row-parallel layout for tables
    whose bitset rows exceed one device.
    """
    raw = spec.lower().replace("×", "x").split("x")
    if not all(p.isdigit() for p in raw):  # '4x' must error, not flip axes
        raise ValueError(f"--mesh spec must be 'DATAxMODEL' or 'MODEL', got {spec!r}")
    parts = [int(p) for p in raw]
    if len(parts) == 1:
        parts = [1, parts[0]]
    if len(parts) != 2 or any(p <= 0 for p in parts):
        raise ValueError(f"--mesh spec must be 'DATAxMODEL' or 'MODEL', got {spec!r}")
    return jax.make_mesh(tuple(parts), ("data", "model"))


def make_host_mesh(data: int = 4, model: int = 2) -> jax.sharding.Mesh:
    """Small mesh over however many (host) devices exist — tests/examples."""
    n = len(jax.devices())
    if data * model > n:
        data, model = max(1, n // 2), min(2, n) if n > 1 else 1
        if data * model > n:
            data, model = n, 1
    return jax.make_mesh(
        (data, model), ("data", "model"),
    )
