"""Serving driver: batched prefill + decode over a model-zoo architecture.

  PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --reduced \
      --batch 4 --prompt-len 16 --max-new 16
"""

from __future__ import annotations

import argparse
import time

import numpy as np

import jax

from ..configs import get_arch, reduced as reduce_cfg
from ..models.zoo import build
from ..serving.engine import generate


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(1, cfg.vocab, (args.batch, args.prompt_len))

    extra = {}
    if cfg.frontend == "audio_stub":
        extra["frames"] = jax.numpy.asarray(
            rng.standard_normal((args.batch, 32, cfg.d_model)), jax.numpy.float32)
    elif cfg.frontend == "vision_stub":
        extra["patches"] = jax.numpy.asarray(
            rng.standard_normal((args.batch, cfg.n_patches, cfg.d_model)),
            jax.numpy.float32)

    t0 = time.perf_counter()
    out = generate(model, params, prompts, max_new=args.max_new,
                   temperature=args.temperature, seed=args.seed,
                   extra=extra or None)
    dt = time.perf_counter() - t0
    total_tokens = args.batch * args.max_new
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} "
          f"new={args.max_new}")
    print(f"generated {total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens / dt:.1f} tok/s incl. prefill+compile)")
    print("first row:", out[0].tolist())


if __name__ == "__main__":
    main()
