"""Mining driver: dataset -> Kyiv -> quasi-identifier report, with optional
multi-device sharding and level checkpointing.

  PYTHONPATH=src python -m repro.launch.mine --dataset randomized --n 2000 \
      --m 10 --tau 1 --kmax 4 --engine numpy
  PYTHONPATH=src python -m repro.launch.mine --fimi path/to/connect.dat ...
"""

from __future__ import annotations

import argparse
import json

import numpy as np

from ..core import KyivConfig, itemize, mine, preprocess
from ..core.kyiv import mine_preprocessed
from ..data.loaders import read_fimi
from ..data.synth import DATASETS
from ..distributed.checkpoint import CheckpointManager


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="randomized", choices=sorted(DATASETS))
    ap.add_argument("--fimi", default=None, help="path to a FIMI-format file")
    ap.add_argument("--n", type=int, default=2000)
    ap.add_argument("--m", type=int, default=10)
    ap.add_argument("--tau", type=int, default=1)
    ap.add_argument("--kmax", type=int, default=3)
    ap.add_argument("--ordering", default="ascending")
    ap.add_argument("--no-bounds", action="store_true")
    ap.add_argument("--engine", default="numpy", choices=["numpy", "jnp", "pallas"])
    ap.add_argument("--no-fused-classify", action="store_true",
                    help="classify on the host (pre-fusion baseline path)")
    ap.add_argument("--sharded", action="store_true", help="shard over local devices")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None, help="write results JSON here")
    args = ap.parse_args()

    if args.fimi:
        D = read_fimi(args.fimi)
    else:
        gen = DATASETS[args.dataset]
        if args.dataset == "randomized":
            D = gen(args.n, args.m, seed=args.seed)
        else:
            D = gen(n=args.n, seed=args.seed)

    cfg = KyivConfig(tau=args.tau, kmax=args.kmax, ordering=args.ordering,
                     use_bounds=not args.no_bounds, engine=args.engine,
                     fused_classify=not args.no_fused_classify)
    prep = preprocess(itemize(D), cfg.tau, ordering=cfg.ordering, seed=cfg.seed)

    pipeline_factory = None
    if args.sharded:
        from ..core.sharded import make_sharded_pipeline
        from .mesh import make_host_mesh

        mesh = make_host_mesh()
        pipeline_factory = make_sharded_pipeline(mesh, pair_axes=("data",),
                                                 word_axis="model",
                                                 fused_classify=cfg.fused_classify)
        print(f"sharded over mesh {dict(zip(mesh.axis_names, mesh.devices.shape))}")

    hook = None
    if args.ckpt_dir:
        cm = CheckpointManager(args.ckpt_dir)

        def hook(k, state):
            lvl = state["level"]
            cm.save(k, {"itemsets": lvl.itemsets, "counts": lvl.counts,
                        "bits": lvl.bits, "next_k": state["next_k"]},
                    {"tau": cfg.tau, "kmax": cfg.kmax})

    res = mine_preprocessed(prep, cfg, pipeline_factory=pipeline_factory,
                            on_level_end=hook)

    print(f"dataset {D.shape}, |L| = {prep.n_l}, tau={cfg.tau}, kmax={cfg.kmax}")
    print(f"minimal tau-infrequent itemsets: {len(res.itemsets)}")
    for s in res.stats:
        print(f"  k={s.k}: candidates={s.candidates} B={s.type_b} "
              f"intersections={s.intersections} emitted={s.emitted} "
              f"stored={s.stored} t={s.time_total:.3f}s")
    print(f"wall time {res.wall_time:.3f}s "
          f"(intersect {res.total_intersect_time:.3f}s = "
          f"{res.total_intersect_time / max(res.wall_time, 1e-9):.0%})")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(
                {"itemsets": [{"items": list(ids), "count": c} for ids, c in res.itemsets],
                 "stats": [vars(s) for s in res.stats]},
                f, indent=1, default=str)


if __name__ == "__main__":
    main()
