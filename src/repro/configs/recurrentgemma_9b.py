"""recurrentgemma-9b [hybrid]: RG-LRU + local attention, pattern 1 attn : 2
recurrent (Griffin, arXiv:2402.19427). 38L d_model=4096 16H (MQA kv=1)
d_ff=12288 vocab=256000, local window 2048."""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab=256_000,
    pattern=("rglru", "rglru", "local"),
    window=2048,
    mlp_act="geglu",
    rglru_dim=4096,
    rope_theta=10000.0,
    supports_long_context=True,  # RG-LRU state + bounded local window
)
