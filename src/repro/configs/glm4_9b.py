"""glm4-9b [dense]: RoPE + GQA kv=2 (hf:THUDM/glm-4-9b). 40L d_model=4096
32H d_ff=13696 vocab=151552, SwiGLU."""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="glm4-9b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab=151_552,
    pattern=("attn",),
    mlp_act="swiglu",
    qkv_bias=True,  # GLM uses QKV bias
    rope_theta=10000.0,
)
