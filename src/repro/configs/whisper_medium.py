"""whisper-medium [audio]: encoder-decoder transformer (arXiv:2212.04356).
24L encoder + 24L decoder, d_model=1024 16H (MHA) d_ff=4096 vocab=51865.
The conv frontend is a STUB: ``input_specs()`` provides precomputed frame
embeddings (B, S_frames, d_model)."""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,  # decoder depth
    enc_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab=51_865,
    pattern=("attn",),
    mlp_act="gelu",
    rope_theta=10000.0,
    frontend="audio_stub",
    cross_attn_len=1500,
    tie_embeddings=False,
)
