"""granite-moe-1b-a400m [moe]: 32 experts top-8
(hf:ibm-granite/granite-3.0-1b-a400m-base). 24L d_model=1024 16H (GQA kv=8)
expert d_ff=512 vocab=49155."""

from .base import ArchConfig, MoECfg

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab=49_155,
    pattern=("attn",),
    mlp_act="swiglu",
    rope_theta=10000.0,
    moe=MoECfg(n_experts=32, top_k=8, d_expert=512, n_shared=0, first_dense=0),
)
