"""Config registry: ``get_arch(id)`` / ``ARCHS`` plus shape registry."""

from .base import (
    ArchConfig,
    MLACfg,
    MoECfg,
    SSMCfg,
    ShapeConfig,
    SHAPES,
    input_specs,
    reduced,
    step_kind,
)
from .recurrentgemma_9b import CONFIG as recurrentgemma_9b
from .glm4_9b import CONFIG as glm4_9b
from .gemma3_4b import CONFIG as gemma3_4b
from .qwen15_110b import CONFIG as qwen15_110b
from .nemotron4_15b import CONFIG as nemotron4_15b
from .deepseek_v2_lite_16b import CONFIG as deepseek_v2_lite_16b
from .granite_moe_1b import CONFIG as granite_moe_1b
from .whisper_medium import CONFIG as whisper_medium
from .mamba2_370m import CONFIG as mamba2_370m
from .internvl2_26b import CONFIG as internvl2_26b

ARCHS: dict[str, ArchConfig] = {
    c.name: c
    for c in (
        recurrentgemma_9b,
        glm4_9b,
        gemma3_4b,
        qwen15_110b,
        nemotron4_15b,
        deepseek_v2_lite_16b,
        granite_moe_1b,
        whisper_medium,
        mamba2_370m,
        internvl2_26b,
    )
}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def cells(include_skipped: bool = False):
    """All assigned (arch × shape) cells; long_500k only where applicable."""
    out = []
    for arch in ARCHS.values():
        for shape in SHAPES.values():
            skipped = shape.name == "long_500k" and not arch.supports_long_context
            if skipped and not include_skipped:
                continue
            out.append((arch, shape, skipped))
    return out


__all__ = [
    "ArchConfig",
    "MLACfg",
    "MoECfg",
    "SSMCfg",
    "ShapeConfig",
    "SHAPES",
    "input_specs",
    "reduced",
    "step_kind",
    "ARCHS",
    "get_arch",
    "cells",
]
