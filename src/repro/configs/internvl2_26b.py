"""internvl2-26b [vlm]: InternViT + InternLM2-20B backbone (arXiv:2404.16821).
Backbone: 48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553. The vision
frontend is a STUB: ``input_specs()`` provides precomputed patch embeddings
(B, n_patches, d_model) prepended to the text tokens."""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab=92_553,
    pattern=("attn",),
    mlp_act="swiglu",
    rope_theta=1_000_000.0,
    frontend="vision_stub",
    n_patches=256,
    tie_embeddings=False,
)
