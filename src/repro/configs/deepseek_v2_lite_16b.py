"""deepseek-v2-lite-16b [moe]: MLA (kv_lora=512) + fine-grained MoE
(arXiv:2405.04434). 27L d_model=2048 16H, 64 routed experts top-6 + 2 shared,
expert d_ff=1408, vocab=102400. First layer dense (d_ff 10944), per the
published config. The assignment line also mentions "160 routed" (that is
the full DeepSeek-V2); we follow the structured field ``MoE 64e top-6`` —
noted in DESIGN.md §5."""

from .base import ArchConfig, MLACfg, MoECfg

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,  # nope 128 (+64 rope) per MLA config below
    d_ff=1408,
    vocab=102_400,
    pattern=("attn",),
    mlp_act="swiglu",
    rope_theta=10000.0,
    moe=MoECfg(
        n_experts=64,
        top_k=6,
        d_expert=1408,
        n_shared=2,
        first_dense=1,
        first_dense_ff=10944,
    ),
    mla=MLACfg(kv_lora=512, rope_head_dim=64, nope_head_dim=128, v_head_dim=128),
)
