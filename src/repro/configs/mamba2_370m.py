"""mamba2-370m [ssm]: SSD / state-space duality (arXiv:2405.21060),
attention-free. 48L d_model=1024, d_inner=2048, headdim=64 (32 heads),
ssm_state=128, vocab=50280."""

from .base import ArchConfig, SSMCfg

CONFIG = ArchConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=32,  # = d_inner / head_dim
    n_kv_heads=32,
    head_dim=64,
    d_ff=0,  # mamba2 blocks have no separate MLP
    vocab=50_280,
    pattern=("ssd",),
    ssm=SSMCfg(d_state=128, d_inner=2048, head_dim=64, n_groups=1, chunk=256, d_conv=4),
    supports_long_context=True,  # O(1) recurrent state
)
