"""gemma3-4b [dense]: 5 local : 1 global attention pattern, 128k context
(hf:google/gemma-3 family). 34L d_model=2560 8H (GQA kv=4) d_ff=10240
vocab=262144, head_dim 256, local window 1024."""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-4b",
    family="dense",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab=262_144,
    pattern=("local", "local", "local", "local", "local", "attn"),
    window=1024,
    mlp_act="geglu",
    rope_theta=1_000_000.0,
    supports_long_context=True,  # 5/6 of layers are windowed; global layers
    # use the sequence-sharded decode attention path
)
