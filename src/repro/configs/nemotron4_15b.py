"""nemotron-4-15b [dense]: GQA kv=8, squared-ReLU MLP (arXiv:2402.16819).
32L d_model=6144 48H d_ff=24576 vocab=256000."""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-15b",
    family="dense",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab=256_000,
    pattern=("attn",),
    mlp_act="squared_relu",
    rope_theta=10000.0,
    tie_embeddings=False,
)
