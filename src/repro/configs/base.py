"""Architecture + shape configuration system.

Every assigned architecture is a frozen :class:`ArchConfig`; the four
assigned input shapes are :class:`ShapeConfig` entries in ``SHAPES``.
``input_specs(arch, shape)`` produces ``jax.ShapeDtypeStruct`` stand-ins for
every model input of the corresponding step — the dry-run lowers against
these (no allocation).

Reduced configs for CPU smoke tests come from :func:`reduced`, which scales
depth/width/vocab down while preserving the family-defining structure
(pattern, MoE routing, MLA shapes, SSD state, etc.).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "MoECfg",
    "MLACfg",
    "SSMCfg",
    "ArchConfig",
    "ShapeConfig",
    "SHAPES",
    "reduced",
    "input_specs",
    "step_kind",
]


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_expert: int
    n_shared: int = 0
    first_dense: int = 0  # leading dense layers (DeepSeek-V2 style)
    first_dense_ff: int = 0
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class MLACfg:
    kv_lora: int = 512
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    d_state: int = 128
    d_inner: int = 2048
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256
    d_conv: int = 4

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    pattern: tuple[str, ...] = ("attn",)  # cycled block types per layer
    window: int = 0  # local-attention window
    qkv_bias: bool = False
    mlp_act: str = "swiglu"  # swiglu | geglu | gelu | squared_relu
    rope_theta: float = 10000.0
    moe: MoECfg | None = None
    mla: MLACfg | None = None
    ssm: SSMCfg | None = None
    rglru_dim: int = 0  # recurrent branch width for "rglru" blocks
    enc_layers: int = 0  # encoder depth for enc-dec (n_layers = decoder depth)
    n_patches: int = 0  # vlm: patch tokens prepended
    frontend: str | None = None  # audio_stub | vision_stub
    cross_attn_len: int = 1500  # enc-dec decode: encoder memory length
    tie_embeddings: bool = True
    dtype: str = "bfloat16"
    # which shape ids this arch supports (long_500k only for sub-quadratic)
    supports_long_context: bool = False

    @property
    def sub_quadratic(self) -> bool:
        return self.supports_long_context

    def layer_types(self) -> list[str]:
        return [self.pattern[i % len(self.pattern)] for i in range(self.n_layers)]

    def param_count(self) -> int:
        """Approximate parameter count N (for MODEL_FLOPS = 6*N*D)."""
        d, v = self.d_model, self.vocab
        total = v * d  # embeddings (tied head)
        if not self.tie_embeddings:
            total += v * d
        for t in self.layer_types():
            total += 2 * d  # norms
            if t in ("attn", "local"):
                if self.mla is not None:
                    m = self.mla
                    qd = self.n_heads * (m.nope_head_dim + m.rope_head_dim)
                    total += d * qd
                    total += d * (m.kv_lora + m.rope_head_dim)
                    total += m.kv_lora * self.n_heads * (m.nope_head_dim + m.v_head_dim)
                    total += self.n_heads * m.v_head_dim * d
                else:
                    total += d * self.n_heads * self.head_dim  # q
                    total += 2 * d * self.n_kv_heads * self.head_dim  # kv
                    total += self.n_heads * self.head_dim * d  # out
            elif t == "rglru":
                r = self.rglru_dim
                total += 2 * d * r + r * d + 3 * r + r * (self.window and 4 or 4)
            elif t == "ssd":
                s = self.ssm
                proj_in = 2 * s.d_inner + 2 * s.n_groups * s.d_state + s.n_heads
                total += d * proj_in + s.d_inner * d + 3 * s.n_heads
            # channel mixing
            if t == "ssd":
                continue  # mamba2 blocks have no separate MLP
            if self.moe is not None:
                e = self.moe
                total += d * e.n_experts  # router
                total += e.n_experts * 3 * d * e.d_expert
                total += e.n_shared * 3 * d * e.d_expert
            else:
                mult = 3 if self.mlp_act in ("swiglu", "geglu") else 2
                total += mult * d * self.d_ff
        if self.enc_layers:
            for _ in range(self.enc_layers):
                total += 2 * self.d_model
                total += 4 * d * self.n_heads * self.head_dim
                mult = 3 if self.mlp_act in ("swiglu", "geglu") else 2
                total += mult * d * self.d_ff
            # decoder cross-attention
            total += self.n_layers * 4 * d * self.n_heads * self.head_dim
        return int(total)

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: only routed top-k + shared)."""
        if self.moe is None:
            return self.param_count()
        e = self.moe
        d = self.d_model
        dense_all = self.param_count()
        inactive = (e.n_experts - e.top_k) * 3 * d * e.d_expert * (
            self.n_layers - e.first_dense
        )
        return int(dense_all - inactive)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def step_kind(shape: ShapeConfig) -> str:
    return shape.kind


def reduced(cfg: ArchConfig, *, layers: int | None = None) -> ArchConfig:
    """Family-preserving reduced config for CPU smoke tests."""
    pat = len(cfg.pattern)
    n_layers = layers if layers is not None else max(pat, 2 if pat == 1 else pat)
    d_model = 64
    n_heads = 4
    n_kv = max(1, min(cfg.n_kv_heads, 2))
    head_dim = 16
    kw: dict[str, Any] = dict(
        n_layers=n_layers,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=head_dim,
        d_ff=128,
        vocab=128,
        window=min(cfg.window, 16) if cfg.window else 0,
        enc_layers=min(cfg.enc_layers, 2) if cfg.enc_layers else 0,
        n_patches=min(cfg.n_patches, 4) if cfg.n_patches else 0,
        cross_attn_len=16,
        rglru_dim=64 if cfg.rglru_dim else 0,
        dtype="float32",
    )
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe,
            n_experts=4,
            top_k=min(cfg.moe.top_k, 2),
            d_expert=32,
            n_shared=min(cfg.moe.n_shared, 1),
            first_dense=min(cfg.moe.first_dense, 1),
            first_dense_ff=64 if cfg.moe.first_dense else 0,
        )
    if cfg.mla is not None:
        kw["mla"] = MLACfg(kv_lora=32, rope_head_dim=8, nope_head_dim=16, v_head_dim=16)
    if cfg.ssm is not None:
        kw["ssm"] = SSMCfg(d_state=16, d_inner=128, head_dim=32, n_groups=1, chunk=8, d_conv=4)
    return dataclasses.replace(cfg, **kw)


def _dp_batch(global_batch: int) -> int:
    return global_batch


def input_specs(cfg: ArchConfig, shape: ShapeConfig, dtype=jnp.int32) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every input of the step for (cfg, shape).

    Train:    tokens/labels (B, S)   [+ frontend embeddings for audio/vlm]
    Prefill:  tokens (B, S)
    Decode:   tokens (B, 1) + cache specs are constructed by the serving layer.
    """
    B, S = shape.global_batch, shape.seq_len
    act_dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    specs: dict[str, Any] = {}
    if shape.kind == "train":
        if cfg.frontend == "audio_stub":
            specs["frames"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), act_dtype)
            specs["tokens"] = jax.ShapeDtypeStruct((B, S), dtype)
            specs["labels"] = jax.ShapeDtypeStruct((B, S), dtype)
        elif cfg.frontend == "vision_stub":
            n_text = S - cfg.n_patches
            specs["patches"] = jax.ShapeDtypeStruct((B, cfg.n_patches, cfg.d_model), act_dtype)
            specs["tokens"] = jax.ShapeDtypeStruct((B, n_text), dtype)
            specs["labels"] = jax.ShapeDtypeStruct((B, n_text), dtype)
        else:
            specs["tokens"] = jax.ShapeDtypeStruct((B, S), dtype)
            specs["labels"] = jax.ShapeDtypeStruct((B, S), dtype)
    elif shape.kind == "prefill":
        if cfg.frontend == "audio_stub":
            specs["frames"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), act_dtype)
            specs["tokens"] = jax.ShapeDtypeStruct((B, min(S, 448)), dtype)
        elif cfg.frontend == "vision_stub":
            specs["patches"] = jax.ShapeDtypeStruct((B, cfg.n_patches, cfg.d_model), act_dtype)
            specs["tokens"] = jax.ShapeDtypeStruct((B, S - cfg.n_patches), dtype)
        else:
            specs["tokens"] = jax.ShapeDtypeStruct((B, S), dtype)
    else:  # decode
        specs["tokens"] = jax.ShapeDtypeStruct((B, 1), dtype)
        specs["positions"] = jax.ShapeDtypeStruct((B,), dtype)
    return specs
