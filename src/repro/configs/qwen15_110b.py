"""qwen1.5-110b [dense]: GQA kv=8 with QKV bias (hf:Qwen/Qwen1.5 family).
80L d_model=8192 64H d_ff=49152 vocab=152064."""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-110b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=49152,
    vocab=152_064,
    pattern=("attn",),
    qkv_bias=True,
    mlp_act="swiglu",
    rope_theta=1_000_000.0,
)
