"""Dataset IO: FIMI transaction format and CSV categorical tables.

``read_fimi`` ingests the http://fimi.ua.ac.be format used by the paper's
Connect/Pumsb files (one transaction per line, space-separated item ids) into
the tabular (n, m) form the miner consumes — FIMI transactions with a fixed
arity per line (Connect: 43, Pumsb: 74) map 1:1 onto table columns; ragged
files are padded with a per-line sentinel column value.

``encode_table`` densifies arbitrary categorical/string tables to the int64
matrix the itemizer expects, returning the codebooks for result decoding.
"""

from __future__ import annotations

import numpy as np

__all__ = ["read_fimi", "write_fimi", "encode_table"]


def read_fimi(path: str, pad_value: int = -1) -> np.ndarray:
    rows: list[list[int]] = []
    width = 0
    with open(path) as f:
        for line in f:
            parts = [int(x) for x in line.split()]
            if parts:
                rows.append(parts)
                width = max(width, len(parts))
    out = np.full((len(rows), width), pad_value, dtype=np.int64)
    for i, r in enumerate(rows):
        out[i, : len(r)] = r
    return out


def write_fimi(path: str, table: np.ndarray, pad_value: int = -1) -> None:
    with open(path, "w") as f:
        for row in np.asarray(table):
            f.write(" ".join(str(int(x)) for x in row if x != pad_value) + "\n")


def encode_table(columns: list[np.ndarray]) -> tuple[np.ndarray, list[np.ndarray]]:
    """Encode arbitrary per-column data to dense ints; returns codebooks."""
    encoded = []
    books = []
    for col in columns:
        uniq, inv = np.unique(np.asarray(col), return_inverse=True)
        encoded.append(inv.astype(np.int64))
        books.append(uniq)
    return np.stack(encoded, axis=1), books
