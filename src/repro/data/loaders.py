"""Dataset IO: FIMI transaction format and CSV categorical tables.

``read_fimi`` ingests the http://fimi.ua.ac.be format used by the paper's
Connect/Pumsb files (one transaction per line, space-separated item ids) into
the tabular (n, m) form the miner consumes — FIMI transactions with a fixed
arity per line (Connect: 43, Pumsb: 74) map 1:1 onto table columns; ragged
files are padded with a per-line sentinel column value.

``encode_table`` densifies arbitrary categorical/string tables to the int64
matrix the itemizer expects, returning the codebooks for result decoding.
``read_csv`` wraps it for real categorical CSV files (the service's
``--preload`` path), so string-valued tables feed the miner without manual
densification.
"""

from __future__ import annotations

import csv

import numpy as np

__all__ = ["read_fimi", "write_fimi", "encode_table", "read_csv"]


def read_fimi(path: str, pad_value: int = -1) -> np.ndarray:
    rows: list[list[int]] = []
    width = 0
    with open(path) as f:
        for line in f:
            parts = [int(x) for x in line.split()]
            if parts:
                rows.append(parts)
                width = max(width, len(parts))
    out = np.full((len(rows), width), pad_value, dtype=np.int64)
    for i, r in enumerate(rows):
        out[i, : len(r)] = r
    return out


def write_fimi(path: str, table: np.ndarray, pad_value: int = -1) -> None:
    with open(path, "w") as f:
        for row in np.asarray(table):
            f.write(" ".join(str(int(x)) for x in row if x != pad_value) + "\n")


def read_csv(
    path: str, *, header: bool | None = None, delimiter: str = ","
) -> tuple[np.ndarray, list[str], list[np.ndarray]]:
    """Load a categorical CSV as a dense int table via :func:`encode_table`.

    Args:
      path: CSV file; every cell is treated as a categorical token (strings,
        mixed types and numerics all work — values are densified per column).
      header: True/False to force, None to sniff with ``csv.Sniffer`` (pass
        explicitly when the file is small or ambiguous — a mis-sniff would
        silently shift every support by one row).
      delimiter: CSV delimiter.
    Returns:
      (table (n, m) int64, column names, per-column codebooks) — decode cell
      ``table[i, j]`` back with ``codebooks[j][table[i, j]]``.
    """
    with open(path, newline="") as f:
        sample = f.read()
    rows = [r for r in csv.reader(sample.splitlines(), delimiter=delimiter) if r]
    if not rows:
        raise ValueError(f"{path}: empty CSV")
    width = len(rows[0])
    if any(len(r) != width for r in rows):
        raise ValueError(f"{path}: ragged CSV (expected {width} columns)")
    if header is None:
        try:
            header = csv.Sniffer().has_header(sample)
        except csv.Error:
            header = False
    if header:
        names, data = list(rows[0]), rows[1:]
    else:
        names, data = [f"col{j}" for j in range(width)], rows
    if not data:
        raise ValueError(f"{path}: no data rows")
    columns = [np.asarray([r[j] for r in data]) for j in range(width)]
    table, books = encode_table(columns)
    return table, names, books


def encode_table(columns: list[np.ndarray]) -> tuple[np.ndarray, list[np.ndarray]]:
    """Encode arbitrary per-column data to dense ints; returns codebooks."""
    encoded = []
    books = []
    for col in columns:
        uniq, inv = np.unique(np.asarray(col), return_inverse=True)
        encoded.append(inv.astype(np.int64))
        books.append(uniq)
    return np.stack(encoded, axis=1), books
