"""Dataset generators for the paper's experiments.

``randomized_dataset`` follows §5.2.1 exactly: each column's domain size D is
drawn i.i.d. uniform from {10..100} and elements are drawn i.i.d. uniform
from {1..D}. The paper uses 50,000 x 25; benchmarks scale (n, m) down/up.

The domain-specific datasets (§5.3.1) are not downloadable in this offline
container, so structural analogues are generated with matching shape and
density character; each generator documents what is matched and what is not.
Wall-clock comparisons against MINIT are therefore *self-consistent*
(same data for both algorithms) rather than byte-identical to the paper's.
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "randomized_dataset",
    "exposed_dataset",
    "connect_like",
    "pumsb_like",
    "poker_like",
    "uscensus_like",
    "DATASETS",
]


def exposed_dataset(
    n: int,
    m: int = 6,
    base_domain: int = 5,
    exposed_frac: float = 0.1,
    pair_domains: tuple[int, int] = (120, 127),
    seed: int = 0,
) -> np.ndarray:
    """Frequent background with planted rare structure — the privacy-risk
    stress shape (§1's AOL exposure, controllable at any row count).

    A ``base_domain``-ary random table (every item frequent) in which an
    ``exposed_frac`` fraction of rows is made re-identifiable:

    * half carry a **unique value** in column 0 — singleton quasi-identifiers;
    * half carry an engineered value **pair** in columns 1-2: values cycle
      through coprime domains, so each *value* occurs ~``e / domain`` times
      (frequent, for τ below that) while each *combination* occurs at most
      ``ceil(e / (P * Q))`` times — minimal infrequent pairs.

    Unlike ``randomized_dataset`` (where QI counts explode with n at τ=1),
    the number of planted QIs scales linearly and mining stays cheap, so
    record-coverage and planner benchmarks can run at paper-scale row counts.
    """
    rng = np.random.default_rng(seed)
    out = rng.integers(0, base_domain, size=(n, m)).astype(np.int64)
    e = int(n * exposed_frac)
    if e == 0 or m < 3:
        return out
    rows = rng.choice(n, size=e, replace=False)
    half = e // 2
    out[rows[:half], 0] = 10_000 + np.arange(half)
    pair_rows = rows[half:]
    k = len(pair_rows)
    p, q = pair_domains
    out[pair_rows, 1] = 10_000 + (np.arange(k) % p)
    out[pair_rows, 2] = 10_000 + (np.arange(k) % q)
    return out


def randomized_dataset(
    n: int = 50_000,
    m: int = 25,
    d_low: int = 10,
    d_high: int = 100,
    seed: int = 0,
) -> np.ndarray:
    """§5.2.1 randomised dataset: per-column domain D ~ U{d_low..d_high}."""
    rng = np.random.default_rng(seed)
    cols = []
    for _ in range(m):
        d = int(rng.integers(d_low, d_high + 1))
        cols.append(rng.integers(1, d + 1, size=n))
    return np.stack(cols, axis=1).astype(np.int64)


def connect_like(n: int = 67_557, m: int = 43, seed: int = 0) -> np.ndarray:
    """Connect-4 analogue: 42 board columns with 3 values (x/o/blank) whose
    marginals are position-dependent (edges mostly blank), plus an outcome
    column with 3 skewed values. Matches: shape 67557x43, 129 items, high
    density/low domain. Does not match: true game-tree correlations."""
    rng = np.random.default_rng(seed)
    cols = []
    for j in range(m - 1):
        row_depth = j % 6  # connect-4 boards fill bottom-up: deeper = fuller
        p_blank = 0.15 + 0.13 * row_depth
        p_blank = min(p_blank, 0.9)
        rem = 1.0 - p_blank
        cols.append(rng.choice(3, size=n, p=[p_blank, rem * 0.5, rem * 0.5]))
    cols.append(rng.choice(3, size=n, p=[0.65, 0.25, 0.10]))  # win/lose/draw
    return np.stack(cols, axis=1).astype(np.int64)


def pumsb_like(n: int = 49_046, m: int = 74, seed: int = 0) -> np.ndarray:
    """PUMS census analogue: 74 columns with Zipf-ish marginals and domain
    sizes drawn to land near the paper's ~1,958 items (~26 values/column)."""
    rng = np.random.default_rng(seed)
    cols = []
    for _ in range(m):
        d = int(rng.integers(4, 50))
        # Zipf-like marginal over d values
        w = 1.0 / np.arange(1, d + 1) ** 1.1
        w /= w.sum()
        cols.append(rng.choice(d, size=n, p=w))
    return np.stack(cols, axis=1).astype(np.int64)


def poker_like(n: int = 1_000_000, m: int = 10, seed: int = 0) -> np.ndarray:
    """Poker-hand analogue: 5 cards x (suit in {1..4}, rank in {1..13}),
    drawn without replacement within a hand — 117 items like the original."""
    rng = np.random.default_rng(seed)
    # sample 5 distinct cards out of 52 per row, vectorised
    cards = np.argsort(rng.random((n, 52)), axis=1)[:, :5]
    suit = cards // 13 + 1
    rank = cards % 13 + 1
    out = np.empty((n, 10), dtype=np.int64)
    out[:, 0::2] = suit
    out[:, 1::2] = rank
    return out[:, :m]


def uscensus_like(n: int = 200_000, m: int = 68, seed: int = 0) -> np.ndarray:
    """USCensus1990 analogue: wide, many items (~8k in the original). Mix of
    small-domain flags and large-domain codes with heavy skew."""
    rng = np.random.default_rng(seed)
    cols = []
    for j in range(m):
        if j % 3 == 0:
            d = int(rng.integers(2, 6))  # flags
            w = 1.0 / np.arange(1, d + 1) ** 0.8
        else:
            d = int(rng.integers(50, 400))  # detailed codes
            w = 1.0 / np.arange(1, d + 1) ** 1.3
        w = w / w.sum()
        cols.append(rng.choice(d, size=n, p=w))
    return np.stack(cols, axis=1).astype(np.int64)


DATASETS = {
    "randomized": randomized_dataset,
    "connect": connect_like,
    "pumsb": pumsb_like,
    "poker": poker_like,
    "uscensus": uscensus_like,
}
