"""Per-record re-identification risk profiles (paper §1, the AOL workload).

A mining result lists the quasi-identifiers — minimal attribute
combinations occurring ≤ τ times (Def. 3.3 used as Motwani & Nabar use it).
The *actionable* question is record-level: which rows do those combinations
pinpoint, how tightly, and how exposed is each one? Bettini et al. argue
this record-level semantics is the one k-anonymity actually cares about.

On the bitset substrate the answer is a coverage query: a QI's record set
is the AND of its item bitsets, and a record's exposure is how many QI
masks have its bit set. :func:`risk_profile` batches every mined QI through
``kernels.coverage.CoverageEngine`` (numpy / jnp / Pallas / mesh via the
``BitsetPlacement`` of the mining config) grouped by itemset size, and
derives per record:

* ``qi_count``     — how many quasi-identifiers cover the record;
* ``min_qi_size``  — the smallest covering QI (fewer attributes = easier to
  learn externally = worse), 0 when uncovered;
* ``risk``         — a scalar in [0, 1]: modelling each covering QI of size
  k as an independent 1/k chance of re-identification,

      risk = 1 - prod_k (1 - 1/k)^{count_k}

  so a size-1 QI (a unique-ish value) forces risk 1.0, and risk grows
  monotonically with coverage multiplicity and shrinks with QI size.

The numbers feed ``sdc.quasi.report_as_dict`` (top records + histogram),
``MiningService.risk()`` / the ``/risk`` endpoint, and the anonymization
planner's column prioritisation.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..core.kyiv import MiningResult
from ..core.placement import resolve_placement
from ..kernels.coverage import CoverageEngine, acc_to_record_counts

__all__ = ["RiskProfile", "risk_profile", "risk_scores"]


def risk_scores(counts_by_size: np.ndarray) -> np.ndarray:
    """Scalar risk per record from the (kmax, n) per-size coverage counts:
    ``1 - prod_k (1 - 1/k)^{c_k}`` with the k=1 factor collapsing to 0."""
    counts_by_size = np.asarray(counts_by_size)
    kmax, n = counts_by_size.shape
    log_survival = np.zeros(n, dtype=np.float64)
    for k in range(2, kmax + 1):
        log_survival += counts_by_size[k - 1] * np.log1p(-1.0 / k)
    risk = -np.expm1(log_survival)
    if kmax >= 1:
        risk = np.where(counts_by_size[0] > 0, 1.0, risk)
    return risk


@dataclasses.dataclass
class RiskProfile:
    """Record-level risk of one mined table: everything the coverage kernels
    produce, plus the derived scalar scores."""

    n_rows: int
    tau: int
    kmax: int
    counts_by_size: np.ndarray  # (kmax, n_rows) int64: QIs of size k covering r
    qi_count: np.ndarray  # (n_rows,) int64
    min_qi_size: np.ndarray  # (n_rows,) int64, 0 = uncovered
    risk: np.ndarray  # (n_rows,) float64 in [0, 1]

    @property
    def records_at_risk(self) -> int:
        """Rows pinpointed by at least one τ-infrequent combination."""
        return int((self.qi_count > 0).sum())

    def top_records(self, n: int = 10) -> list[dict]:
        """The n most exposed records, ordered by (risk, coverage) desc."""
        if self.n_rows == 0:
            return []
        order = np.lexsort(
            (np.arange(self.n_rows), -self.qi_count, -self.risk)
        )
        out = []
        for r in order[:n]:
            if self.qi_count[r] == 0:
                break
            out.append(
                {
                    "row": int(r),
                    "risk": round(float(self.risk[r]), 6),
                    "qi_count": int(self.qi_count[r]),
                    "min_qi_size": int(self.min_qi_size[r]),
                }
            )
        return out

    def histogram(self, bins: int = 10) -> dict:
        """Risk histogram over all records: {"edges": [...], "counts": [...]}."""
        edges = np.linspace(0.0, 1.0, bins + 1)
        counts, _ = np.histogram(self.risk, bins=edges)
        return {
            "edges": [round(float(e), 6) for e in edges],
            "counts": [int(c) for c in counts],
        }

    def summary(self, top: int = 10) -> dict:
        """JSON-serialisable digest — the /risk endpoint payload body."""
        at_risk = self.records_at_risk
        return {
            "tau": self.tau,
            "kmax": self.kmax,
            "n_rows": self.n_rows,
            "records_at_risk": at_risk,
            "at_risk_fraction": round(at_risk / self.n_rows, 6) if self.n_rows else 0.0,
            "max_risk": round(float(self.risk.max(initial=0.0)), 6),
            "mean_risk": round(float(self.risk.mean()), 6) if self.n_rows else 0.0,
            "qi_total": int(self.counts_by_size.sum()),
            "top_records": self.top_records(top),
            "histogram": self.histogram(),
        }


def risk_profile(
    result: MiningResult,
    *,
    placement=None,
    max_batch_sets: int | None = None,
    word_map=None,
) -> RiskProfile:
    """Compute the record-risk profile of a mining result.

    Mined itemsets are grouped by size and streamed through one
    :class:`CoverageEngine` (one executable bucket per arity); per-size
    record counts come back from one kernel accumulator each. ``placement``
    defaults to
    the mining config's own (``resolve_placement``), so service calls reuse
    the already-resident placement.

    Under a fleet placement the table bits are process-local word stripes,
    so the accumulator is local too; a placement exposing
    ``record_counts_from_acc`` (``core.fleet.FleetPlacement``) turns it into
    global per-record counts — scatter through the store's ``word_map``
    plus one all-reduce per arity. All derived scores are then global and
    identical on every process.
    """
    table = result.prep.table
    config = result.config
    n = table.n_rows
    kmax = max(1, int(config.kmax))
    counts_by_size = np.zeros((kmax, n), dtype=np.int64)

    if result.itemsets and n:
        sets_by_size: dict[int, list[tuple[int, ...]]] = {}
        for ids, _cnt in result.itemsets:
            sets_by_size.setdefault(len(ids), []).append(ids)
        if placement is None:
            placement = resolve_placement(config)
        engine = CoverageEngine(
            table.bits,
            placement=placement,
            set_width=kmax,
            max_batch_sets=max_batch_sets,
        )
        to_global = getattr(placement, "record_counts_from_acc", None)
        for k, sets in sorted(sets_by_size.items()):
            acc = engine.accumulate(np.asarray(sets, dtype=np.int32))
            if to_global is not None:
                counts_by_size[k - 1] = to_global(acc, n, word_map)
            else:
                counts_by_size[k - 1] = acc_to_record_counts(acc, n)

    qi_count = counts_by_size.sum(axis=0)
    min_qi_size = np.zeros(n, dtype=np.int64)
    for k in range(kmax, 0, -1):
        min_qi_size = np.where(counts_by_size[k - 1] > 0, k, min_qi_size)
    return RiskProfile(
        n_rows=n,
        tau=int(config.tau),
        kmax=int(config.kmax),
        counts_by_size=counts_by_size,
        qi_count=qi_count,
        min_qi_size=min_qi_size,
        risk=risk_scores(counts_by_size),
    )
