"""Privacy risk engine: record-level risk scoring and anonymization planning
served from the mining substrate.

``risk`` turns a mining result (itemset-level quasi-identifiers) into
per-record exposure via the device coverage kernels; ``planner`` turns it
into a verified masking plan (cell suppressions + column generalizations)
with zero residual quasi-identifiers.
"""

from .planner import (
    GENERALIZED,
    MASKED,
    AnonymizationPlan,
    apply_plan,
    mine_masked,
    plan_anonymization,
    strip_masked_items,
)
from .risk import RiskProfile, risk_profile, risk_scores

__all__ = [
    "MASKED",
    "GENERALIZED",
    "AnonymizationPlan",
    "apply_plan",
    "mine_masked",
    "plan_anonymization",
    "strip_masked_items",
    "RiskProfile",
    "risk_profile",
    "risk_scores",
]
