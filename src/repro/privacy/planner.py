"""Anonymization planner: kill every quasi-identifier with minimal damage.

Motwani & Nabar's suppression objective, run on top of the miner: given the
minimal τ-infrequent itemsets of a table, choose **cell suppressions**
(single values replaced by the ``MASKED`` wildcard) and **column
generalizations** (a whole column coarsened to one bucket, the degenerate
top of a generalization hierarchy) so that the masked table has *zero*
quasi-identifiers, preferring cheap edits.

Per planning round the choice is a **weighted set cover**: the universe is
every (QI, covered row) incidence — a QI is dead only when each row it
pinpoints has lost at least one of the QI's attribute values — candidate
sets are

* ``cell (r, c)``: weight 1, covers the incidences of every current QI that
  covers row ``r`` through column ``c``;
* ``generalize c``: weight ``generalize_cost`` (default: the column's
  ``n_rows`` cells), covers every incidence of every QI touching column
  ``c`` — generalizing replaces the column by a single value occurring
  ``n_rows > τ`` times, which provably removes all QIs using the column and
  can never create new ones (a frequent item extends no *minimal*
  infrequent itemset).

Greedy picks the best coverage-per-weight set until the round's QIs are all
dead. Because suppressions lower supports, previously-frequent itemsets can
*become* infrequent — so the planner runs a **verification loop**: apply the
round's edits, re-mine the masked table (``MASKED`` items are wildcards,
excluded from itemization), and plan again over the residual QIs. The last
rounds fall back to generalizing every residual column, which guarantees
convergence to zero QIs; degenerate tables with ``n_rows <= tau`` (where
*any* non-empty combination is infrequent) are handled upfront by
suppressing everything. ``plan_anonymization`` therefore always returns a
verified plan, and the re-mine of :func:`apply_plan`'s output is asserted
zero-QI in the tests and the CI smoke job.

Re-mines run through the same ``KyivConfig`` (placement included) as the
original request, so a service-side plan reuses the warm executable buckets
of the resident pipeline.
"""

from __future__ import annotations

import dataclasses
import heapq

import numpy as np

from ..core.items import ItemTable, itemize
from ..core.kyiv import KyivConfig, MiningResult, mine_preprocessed
from ..core.preprocess import preprocess

__all__ = [
    "MASKED",
    "GENERALIZED",
    "AnonymizationPlan",
    "plan_anonymization",
    "apply_plan",
    "mine_masked",
    "strip_masked_items",
]

# Sentinels outside any sane categorical domain. MASKED cells are wildcards
# (they match nothing: their items are dropped before mining); GENERALIZED is
# the single bucket a generalized column collapses to (a regular, frequent
# value). Input tables must not already contain them (validated).
MASKED = int(np.iinfo(np.int64).min)
GENERALIZED = int(np.iinfo(np.int64).min + 1)


def _rows_of_mask(mask: np.ndarray) -> np.ndarray:
    """Set-bit row indices of one (W,) uint32 bitset row, vectorised."""
    words = np.ascontiguousarray(np.asarray(mask, dtype=np.uint32)).astype("<u4")
    return np.nonzero(np.unpackbits(words.view(np.uint8), bitorder="little"))[0]


def strip_masked_items(table: ItemTable) -> ItemTable:
    """Drop the MASKED wildcard items from an item table (suppressed cells
    contribute to no combination)."""
    keep = table.value != MASKED
    if bool(keep.all()):
        return table
    idx = np.nonzero(keep)[0]
    return ItemTable(
        n_rows=table.n_rows,
        n_cols=table.n_cols,
        n_words=table.n_words,
        value=table.value[idx],
        col=table.col[idx],
        freq=table.freq[idx],
        min_row=table.min_row[idx],
        bits=table.bits[idx],
    )


def mine_masked(masked: np.ndarray, config: KyivConfig) -> MiningResult | None:
    """Mine a masked table: itemize, drop MASKED wildcard items, run Alg. 1.

    Returns None when nothing is left to mine (everything suppressed) —
    trivially zero quasi-identifiers.
    """
    table = strip_masked_items(itemize(masked))
    if table.n_items == 0:
        return None
    prep = preprocess(table, config.tau, ordering=config.ordering, seed=config.seed)
    return mine_preprocessed(prep, config)


@dataclasses.dataclass
class AnonymizationPlan:
    """A verified set of masking edits for one table."""

    n_rows: int
    n_cols: int
    tau: int
    kmax: int
    suppressions: list[tuple[int, int]]  # (row, col) cell suppressions
    generalized_columns: list[int]
    rounds: int
    initial_qis: int
    residual_qis: int  # after the final verification re-mine (0 = success)

    @property
    def verified(self) -> bool:
        return self.residual_qis == 0

    @property
    def cells_suppressed(self) -> int:
        return len(self.suppressions)

    @property
    def cells_masked_total(self) -> int:
        """Cells whose value is lost: suppressions + generalized columns."""
        return self.cells_suppressed + len(self.generalized_columns) * self.n_rows

    def as_dict(self, max_suppressions: int | None = 200) -> dict:
        sup = [[int(r), int(c)] for r, c in self.suppressions]
        truncated = max_suppressions is not None and len(sup) > max_suppressions
        total_cells = self.n_rows * self.n_cols
        return {
            "n_rows": self.n_rows,
            "n_cols": self.n_cols,
            "tau": self.tau,
            "kmax": self.kmax,
            "initial_qis": self.initial_qis,
            "residual_qis": self.residual_qis,
            "verified": self.verified,
            "rounds": self.rounds,
            "cells_suppressed": self.cells_suppressed,
            "generalized_columns": [int(c) for c in self.generalized_columns],
            "masked_fraction": (
                round(self.cells_masked_total / total_cells, 6) if total_cells else 0.0
            ),
            "suppressions": sup[:max_suppressions] if truncated else sup,
            "suppressions_truncated": truncated,
        }


def apply_plan(dataset: np.ndarray, plan: AnonymizationPlan) -> np.ndarray:
    """Masked copy of the dataset: suppressions -> MASKED, generalized
    columns -> GENERALIZED (column generalization wins where both apply,
    matching the planner's final state)."""
    masked = np.array(dataset, dtype=np.int64, copy=True)
    if plan.suppressions:
        rows, cols = zip(*plan.suppressions)
        masked[list(rows), list(cols)] = MASKED
    for c in plan.generalized_columns:
        masked[:, c] = GENERALIZED
    return masked


def _greedy_cover_round(
    result: MiningResult,
    *,
    allow_generalize: bool,
    generalize_cost: float,
    already_generalized: set[int],
) -> tuple[list[tuple[int, int]], list[int]]:
    """One weighted-set-cover round over the current QIs.

    Returns (cell suppressions, columns to generalize) that together cover
    every (QI, row) incidence of ``result.itemsets``.
    """
    table = result.prep.table
    qis: list[tuple[np.ndarray, list[int]]] = []
    for ids, _cnt in result.itemsets:
        mask = table.bits[ids[0]].copy()
        for i in ids[1:]:
            mask &= table.bits[i]
        rows = _rows_of_mask(mask)
        cols = sorted({int(table.col[i]) for i in ids})
        qis.append((rows, cols))

    uncovered: list[set[int]] = [set(int(r) for r in rows) for rows, _ in qis]
    cell_cover: dict[tuple[int, int], set[int]] = {}
    col_cover: dict[int, set[int]] = {}
    for q, (rows, cols) in enumerate(qis):
        for c in cols:
            if c in already_generalized:
                continue  # its items are gone next round anyway
            col_cover.setdefault(c, set()).add(q)
            for r in rows:
                cell_cover.setdefault((int(r), c), set()).add(q)

    def cell_gain(rc: tuple[int, int]) -> int:
        r = rc[0]
        return sum(1 for q in cell_cover[rc] if r in uncovered[q])

    def col_gain(c: int) -> int:
        return sum(len(uncovered[q]) for q in col_cover[c])

    # lazy-decrement greedy: scores only ever shrink as incidences get
    # covered, so a popped entry whose recomputed score still tops the heap
    # is the true argmax — the standard O(picks log C) set-cover greedy.
    heap: list[tuple[float, int, str, tuple]] = []
    tick = 0
    for rc in cell_cover:
        heap.append((-float(cell_gain(rc)), tick := tick + 1, "cell", rc))
    if allow_generalize:
        for c in col_cover:
            heap.append(
                (-col_gain(c) / generalize_cost, tick := tick + 1, "generalize", (c,))
            )
    heapq.heapify(heap)

    cells: list[tuple[int, int]] = []
    gen_cols: list[int] = []
    killed_cols: set[int] = set()
    remaining = sum(len(u) for u in uncovered)
    while remaining and heap:
        neg_score, _, kind, payload = heapq.heappop(heap)
        c = payload[-1] if kind == "cell" else payload[0]
        if c in killed_cols:
            continue
        if kind == "cell":
            score = float(cell_gain(payload))
        else:
            score = col_gain(payload[0]) / generalize_cost
        if score <= 0.0:
            continue
        if heap and -score > heap[0][0]:  # stale — reinsert with fresh score
            heapq.heappush(heap, (-score, tick := tick + 1, kind, payload))
            continue
        if kind == "cell":
            r, c = payload
            cells.append((r, c))
            for q in cell_cover[payload]:
                if r in uncovered[q]:
                    uncovered[q].discard(r)
                    remaining -= 1
        else:
            gen_cols.append(payload[0])
            killed_cols.add(payload[0])
            for q in col_cover[payload[0]]:
                remaining -= len(uncovered[q])
                uncovered[q].clear()
    return cells, gen_cols


def plan_anonymization(
    dataset: np.ndarray,
    tau: int = 1,
    kmax: int = 3,
    *,
    config: KyivConfig | None = None,
    max_rounds: int = 12,
    generalize_cost: float | None = None,
    base_result: MiningResult | None = None,
) -> AnonymizationPlan:
    """Plan (and verify) masking edits until the table has zero QIs.

    ``base_result`` short-circuits the first mine when the caller already
    holds the table's mining result (the resident service's cached answer);
    it must have been mined at exactly (tau, kmax) on ``dataset``.
    """
    dataset = np.asarray(dataset)
    if dataset.ndim != 2:
        raise ValueError(f"dataset must be 2-D, got shape {dataset.shape}")
    n, m = dataset.shape
    if n == 0 or m == 0:
        return AnonymizationPlan(n, m, tau, kmax, [], [], 0, 0, 0)
    if int(dataset.min()) <= GENERALIZED:
        raise ValueError(
            "dataset contains reserved sentinel values (MASKED/GENERALIZED)"
        )
    config = config or KyivConfig()
    config = dataclasses.replace(config, tau=tau, kmax=kmax)

    if n <= tau:
        # degenerate: every non-empty combination is τ-infrequent, so the
        # only zero-QI masking suppresses every cell
        suppressions = [(r, c) for r in range(n) for c in range(m)]
        initial = base_result if base_result is not None else mine_masked(
            np.array(dataset, dtype=np.int64), config
        )
        n_initial = len(initial.itemsets) if initial is not None else 0
        return AnonymizationPlan(
            n, m, tau, kmax, suppressions, [], 1, n_initial, 0
        )

    masked = np.array(dataset, dtype=np.int64, copy=True)
    suppressions: list[tuple[int, int]] = []
    generalized: list[int] = []
    gen_cost = float(generalize_cost) if generalize_cost is not None else float(n)

    result = base_result if base_result is not None else mine_masked(masked, config)
    initial_qis = 0 if result is None else len(result.itemsets)
    # leave the last two rounds for the guaranteed-convergent fallback
    cell_rounds = max(1, max_rounds - 2)
    rounds = 0
    while result is not None and result.itemsets and rounds < max_rounds:
        rounds += 1
        if rounds > cell_rounds:
            # fallback: generalize every column a residual QI touches — kills
            # them all and creates none, so the next re-mine converges
            table = result.prep.table
            gen = sorted(
                {int(table.col[i]) for ids, _ in result.itemsets for i in ids}
                - set(generalized)
            )
            cells = []
        else:
            cells, gen = _greedy_cover_round(
                result,
                allow_generalize=True,
                generalize_cost=gen_cost,
                already_generalized=set(generalized),
            )
        for r, c in cells:
            if masked[r, c] != MASKED:
                suppressions.append((r, c))
                masked[r, c] = MASKED
        for c in gen:
            if c not in generalized:
                generalized.append(c)
                masked[:, c] = GENERALIZED
        result = mine_masked(masked, config)

    residual = 0 if result is None else len(result.itemsets)
    return AnonymizationPlan(
        n_rows=n,
        n_cols=m,
        tau=tau,
        kmax=kmax,
        suppressions=suppressions,
        generalized_columns=generalized,
        rounds=rounds,
        initial_qis=initial_qis,
        residual_qis=residual,
    )
