"""ε-confident uniform row sampling over word-tiled bitsets.

Hildebrant et al. (arXiv 2211.13882) prove that a uniform row sample of
size Õ(m/ε) certifies quasi-identifiers to ε-separation accuracy. This
module turns that bound into the sampled-mining fast path:

* :func:`sample_size` — the Õ(m/ε) bound with explicit constants
  (``oversample`` / ``delta`` knobs, clamped to the table size);
* :func:`derive_seed` — one deterministic sampler seed per
  ``(dataset_version, epsilon, base_seed)`` tuple, so repeated approx
  requests at the same version draw the *same* sample (and therefore
  coalesce on one cache key) and results are reproducible across runs;
* :func:`gather_sample_bits` — extracts the sampled bitset view straight
  from the store's ``(n_items, W)`` word tiles: one vectorized word
  gather + shift per item row, then a ``np.packbits`` repack into the
  sample's own word tiles. No per-row host loop, and the output width is
  padded to any placement's ``store_word_tile`` so the sampled table is
  directly placeable under Host/Device/Mesh;
* :func:`build_sample` — the request-facing bundle: sampled
  :class:`~repro.core.items.ItemTable` (same item ids as the full table,
  which is what lets boundary itemsets be recounted against the full
  store later) plus the scaled sample-space threshold;
* :func:`classify_counts` — the per-itemset confidence classifier:
  scaled support estimates split into *certain* (clearly ≤ tau or
  clearly > tau) vs the undecidable ``(tau·(1−ε), tau·(1+ε)]`` boundary
  band that only an exact recount can resolve.

Import discipline: this package sits beside ``core`` (it imports only
``repro.core``) so the service, launch and benchmark layers can all use
it without cycles.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from ..core.items import WORD_BITS, ItemTable, bits_popcount

__all__ = [
    "SamplingConfig",
    "SamplePlan",
    "sample_size",
    "derive_seed",
    "sample_rows",
    "gather_sample_bits",
    "sample_item_table",
    "scaled_tau",
    "classify_counts",
    "build_sample",
]


@dataclasses.dataclass(frozen=True)
class SamplingConfig:
    """Knobs of the ε-separation sample-size bound.

    ``epsilon`` is the default accuracy when a request doesn't pass its
    own; ``oversample`` is the leading constant of the Õ(m/ε) bound;
    ``delta`` the union-bound failure budget; ``min_rows`` a floor so
    tiny tables never sample below statistical usefulness; ``seed`` the
    base entropy mixed into every per-version sampler seed.
    """

    epsilon: float = 0.1
    delta: float = 1e-3
    oversample: float = 8.0
    min_rows: int = 256
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class SamplePlan:
    """One deterministic sample of a table version, ready to mine.

    ``table`` reuses the full table's item ids/columns/values — only the
    row axis (and hence bitset words, freqs, min_rows) is resampled — so
    itemsets mined on the sample are directly comparable to, and
    recountable against, the full store.
    """

    table: ItemTable
    rows: np.ndarray  # sorted sampled row indices into the full table
    seed: int  # derived sampler seed (reproducibility surface)
    epsilon: float
    n_rows_full: int
    tau_sample: int  # sample-space mining threshold
    scale: float  # n_rows_full / len(rows)


def sample_size(
    n_rows: int,
    n_cols: int,
    epsilon: float,
    *,
    config: SamplingConfig | None = None,
) -> int:
    """The Õ(m/ε) ε-separation sample-size bound, clamped to the table.

    ``oversample * (m + log2(1/delta)) / epsilon`` rows: linear in the
    column count (the union-bound dimension of 2211.13882), logarithmic
    in the failure budget, inverse in the accuracy.
    """
    cfg = config or SamplingConfig()
    if not (0.0 < epsilon < 1.0):
        raise ValueError(f"epsilon must be in (0, 1), got {epsilon}")
    bound = cfg.oversample * (n_cols + math.log2(1.0 / cfg.delta)) / epsilon
    return int(min(n_rows, max(cfg.min_rows, math.ceil(bound))))


def derive_seed(version: int, epsilon: float, base_seed: int = 0) -> int:
    """Deterministic sampler seed for a ``(version, epsilon, seed)`` tuple.

    Same tuple -> same seed -> same sample -> same approx cache key, so
    repeated approx requests coalesce; a new dataset version (or a
    different ε) draws a fresh, but still reproducible, sample.
    """
    ss = np.random.SeedSequence(
        [int(base_seed), int(version), int(round(float(epsilon) * 1e9))]
    )
    return int(ss.generate_state(1, np.uint32)[0])


def sample_rows(n_rows: int, size: int, seed: int) -> np.ndarray:
    """``size`` distinct row indices drawn uniformly, sorted ascending."""
    if size >= n_rows:
        return np.arange(n_rows, dtype=np.int64)
    rng = np.random.default_rng(int(seed))
    rows = rng.choice(n_rows, size=int(size), replace=False)
    return np.sort(rows.astype(np.int64))


def gather_sample_bits(
    bits: np.ndarray, rows: np.ndarray, *, word_tile: int = 1
) -> np.ndarray:
    """Extract sampled columns of a ``(t, W)`` uint32 bitset matrix.

    Bit ``j`` of the output corresponds to full-table row ``rows[j]``.
    Fully vectorized: one fancy-indexed word gather, one shift/mask, one
    little-endian ``packbits`` repack — the word-tile analogue of a row
    gather, with no Python loop over rows or items. The output width is
    padded (with zero words) to a multiple of ``word_tile`` so a mesh
    placement's word-sharding applies without re-packing.
    """
    rows = np.asarray(rows, dtype=np.int64)
    s = int(rows.shape[0])
    word_tile = max(1, int(word_tile))
    w_exact = (s + WORD_BITS - 1) // WORD_BITS
    tiles = max(1, (w_exact + word_tile - 1) // word_tile)
    n_words = tiles * word_tile
    if s == 0:
        return np.zeros((bits.shape[0], n_words), dtype=np.uint32)
    gw = rows // WORD_BITS
    gb = (rows % WORD_BITS).astype(np.uint32)
    # (t, s) 0/1 matrix of the sampled bits — one gather + shift, no loop
    sampled = ((bits[:, gw] >> gb[None, :]) & np.uint32(1)).astype(np.uint8)
    pad = n_words * WORD_BITS - s
    if pad:
        sampled = np.pad(sampled, ((0, 0), (0, pad)))
    packed = np.packbits(sampled, axis=1, bitorder="little")
    return np.ascontiguousarray(packed).view("<u4").astype(np.uint32)


def sample_item_table(
    table: ItemTable, rows: np.ndarray, *, word_tile: int = 1
) -> ItemTable:
    """The sampled view of an item table: same items, sampled row axis.

    Item ids (array positions), columns and values are preserved
    verbatim; bitsets, frequencies and min-rows are recomputed on the
    sample. Items absent from the sample keep their ids with frequency 0
    — the classifier treats their estimate as any other scaled count.
    """
    rows = np.asarray(rows, dtype=np.int64)
    bits = gather_sample_bits(table.bits, rows, word_tile=word_tile)
    freq = bits_popcount(bits).astype(np.int64)
    s = int(rows.shape[0])
    if s:
        sampled = ((table.bits[:, rows // WORD_BITS]
                    >> (rows % WORD_BITS).astype(np.uint32)[None, :])
                   & np.uint32(1))
        first = np.argmax(sampled, axis=1)
        present = sampled.any(axis=1)
        min_row = np.where(present, first, np.iinfo(np.int64).max).astype(np.int64)
    else:
        min_row = np.full(table.bits.shape[0], np.iinfo(np.int64).max, np.int64)
    return ItemTable(
        n_rows=s,
        n_cols=table.n_cols,
        n_words=int(bits.shape[1]),
        value=table.value,
        col=table.col,
        freq=freq,
        min_row=min_row,
        bits=bits,
    )


def scaled_tau(tau: int, epsilon: float, n_rows: int, n_sample: int) -> int:
    """Sample-space mining threshold covering the full boundary band.

    An itemset whose scaled estimate could still be ≤ tau·(1+ε) must be
    emitted by the sample mine, so the sample threshold is
    ``floor(tau·(1+ε)·s/n)`` — floored at 1 because the miner requires a
    positive threshold (integer flooring slack is re-checked by the
    classifier, which pushes over-covered emissions into the boundary
    band rather than calling them certain).
    """
    if n_sample >= n_rows:
        return int(tau)
    t = math.floor(tau * (1.0 + epsilon) * n_sample / n_rows)
    return max(1, int(t))


def classify_counts(
    counts: np.ndarray,
    *,
    tau: int,
    epsilon: float,
    n_rows: int,
    n_sample: int,
) -> tuple[np.ndarray, np.ndarray]:
    """Scale sample supports to full-table estimates and split confidence.

    Returns ``(estimates, boundary_mask)``. An estimate is *certain*
    when it lands clearly at or below tau — at most ``tau·(1−ε)`` — and
    *boundary* (undecidable by the sample) anywhere above that: the
    ``(tau·(1−ε), tau·(1+ε)]`` band proper, plus any emission the integer
    sample threshold over-covered past the band, which the sample is by
    construction also unsure about. Boundary itemsets are exactly the
    set the background refinement recounts against the full table.
    """
    counts = np.asarray(counts, dtype=np.int64)
    scale = 1.0 if n_sample >= n_rows else n_rows / max(1, n_sample)
    est = np.rint(counts * scale).astype(np.int64)
    if n_sample >= n_rows:
        boundary = np.zeros(counts.shape[0], dtype=bool)
    else:
        boundary = est > tau * (1.0 - epsilon)
    return est, boundary


def build_sample(
    table: ItemTable,
    *,
    version: int,
    tau: int,
    epsilon: float,
    config: SamplingConfig | None = None,
    word_tile: int = 1,
) -> SamplePlan:
    """Deterministic sample of one table version, ready for the miner."""
    cfg = config or SamplingConfig()
    seed = derive_seed(version, epsilon, cfg.seed)
    size = sample_size(table.n_rows, table.n_cols, epsilon, config=cfg)
    rows = sample_rows(table.n_rows, size, seed)
    sampled = sample_item_table(table, rows, word_tile=word_tile)
    return SamplePlan(
        table=sampled,
        rows=rows,
        seed=seed,
        epsilon=float(epsilon),
        n_rows_full=table.n_rows,
        tau_sample=scaled_tau(tau, epsilon, table.n_rows, int(rows.shape[0])),
        scale=(
            1.0
            if rows.shape[0] >= table.n_rows
            else table.n_rows / max(1, int(rows.shape[0]))
        ),
    )
