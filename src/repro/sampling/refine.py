"""Exact boundary-band recount for the sampled-mining fast path.

The sample mine classifies every emitted itemset as *certain* or
*boundary* (``sampler.classify_counts``); refinement resolves the
boundary band by recounting those itemsets against the full table. The
recount dispatches through the same placement / ``LevelPipeline`` /
``EXEC_CACHE`` machinery as a real mine, not a private numpy loop, for
two reasons:

* bit-identical semantics under every placement (host, device, mesh) —
  the recount is just AND + popcount cascades over the full-width word
  tiles;
* executable reuse. Device executables are keyed by ``(…, n_words,
  bucket, …)``: the sample mine's buckets live at the *sample's* word
  count and can never serve the full table, so a naive recount would
  mint a fresh single-use bucket per batch size. Instead the recount
  pads its pair batches to a bucket size already bound for the full
  table's signature (``BitsetPlacement.warm_buckets``) — warmed by the
  exact promotion mine, by prior exact requests, or by earlier recounts
  — so refinements register as hits in ``/stats.executables`` instead
  of growing the cache.
"""

from __future__ import annotations

import numpy as np

from ..core.items import ItemTable, bits_popcount
from ..kernels.intersect import LevelPipeline
from ..kernels.intersect.ops import _pad_pairs, next_bucket
from ..obs import metrics as _om

__all__ = ["recount_supports", "pick_bucket"]

_RECOUNT_BUCKETS = _om.counter(
    "repro_sampling_recount_buckets_total",
    "Boundary-recount dispatches by executable-bucket outcome.",
    ("outcome",),
)
_RECOUNT_SETS = _om.counter(
    "repro_sampling_recounted_itemsets_total",
    "Boundary itemsets recounted exactly against the full table.",
)

# don't chase a warm bucket that would multiply the dispatch width past
# this factor of the natural power-of-two bucket — padding work is real
_MAX_BUCKET_STRETCH = 4


def pick_bucket(
    placement, m: int, n_words: int, *, fused: bool, write_children: bool
) -> tuple[int, bool]:
    """Choose the dispatch bucket for ``m`` recount pairs.

    Prefers the smallest already-warm executable bucket for this
    placement signature that fits ``m`` (within a bounded stretch);
    falls back to the standard power-of-two bucket. Returns
    ``(bucket, was_warm)``.
    """
    natural = next_bucket(m)
    for b in placement.warm_buckets(
        n_words, fused=fused, write_children=write_children
    ):
        if m <= b <= natural * _MAX_BUCKET_STRETCH:
            return int(b), True
    return natural, False


def recount_supports(
    table: ItemTable,
    itemsets: list[tuple[int, ...]],
    *,
    placement,
    tau: int,
    fused_classify: bool = True,
) -> tuple[np.ndarray, dict]:
    """Exact full-table supports for ``itemsets`` (tuples of item ids).

    Cascades pairwise ANDs through a :class:`LevelPipeline` per arity
    group: partials for ``(i0, …, i_{p})`` are intersected with column
    ``p+1``'s bitsets in one padded batch. Returns ``(counts, info)``
    with ``counts`` aligned to ``itemsets`` order and ``info`` recording
    the executable-bucket reuse achieved.
    """
    counts = np.zeros(len(itemsets), dtype=np.int64)
    info = {"recounted": len(itemsets), "bucket_hits": 0, "bucket_misses": 0,
            "dispatches": 0}
    if not itemsets:
        return counts, info

    by_arity: dict[int, list[int]] = {}
    for pos, ids in enumerate(itemsets):
        by_arity.setdefault(len(ids), []).append(pos)

    for arity, positions in sorted(by_arity.items()):
        if arity == 1:
            items = np.fromiter(
                (itemsets[p][0] for p in positions), dtype=np.int64
            )
            counts[positions] = table.freq[items]
            continue
        mat = np.asarray([itemsets[p] for p in positions], dtype=np.int64)
        r = mat.shape[0]
        partial = table.bits[mat[:, 0]]
        for pos in range(1, arity):
            stacked = np.concatenate([partial, table.bits[mat[:, pos]]], axis=0)
            write = pos < arity - 1
            pipe = LevelPipeline(
                stacked,
                bits_popcount(stacked).astype(np.int64),
                tau=tau,
                placement=placement,
                fused_classify=fused_classify,
                locality_sort=False,
            )
            pairs = np.stack(
                [np.arange(r), np.arange(r) + r], axis=1
            ).astype(np.int32)
            if placement.kind == "device":
                bucket, warm = pick_bucket(
                    placement, r, int(stacked.shape[1]),
                    fused=fused_classify, write_children=write,
                )
                handle = pipe.submit_padded(_pad_pairs(pairs, bucket), r, write)
                outcome = "hit" if warm else "miss"
                info["bucket_hits" if warm else "bucket_misses"] += 1
                _RECOUNT_BUCKETS.inc(outcome=outcome)
            else:
                handle = pipe.submit(pairs, write)
            info["dispatches"] += 1
            child, batch_counts, _ = handle.result()
            pipe.retire()
            if write:
                partial = child
            else:
                counts[positions] = batch_counts
    _RECOUNT_SETS.inc(len(itemsets))
    return counts, info
