"""Sampled-mining fast path: ε-confident answers from uniform row samples.

``sampler`` draws deterministic uniform row samples straight from the
word-tiled bitsets and classifies sample-mined itemsets into certain vs
boundary confidence bands; ``refine`` recounts the boundary band exactly
against the full table through the shared placement/executable-cache
machinery. The mining service composes the two into
``mine(mode="approx")`` + background exact refinement.
"""

from .sampler import (
    SamplePlan,
    SamplingConfig,
    build_sample,
    classify_counts,
    derive_seed,
    gather_sample_bits,
    sample_item_table,
    sample_rows,
    sample_size,
    scaled_tau,
)
from .refine import pick_bucket, recount_supports

__all__ = [
    "SamplePlan",
    "SamplingConfig",
    "build_sample",
    "classify_counts",
    "derive_seed",
    "gather_sample_bits",
    "pick_bucket",
    "recount_supports",
    "sample_item_table",
    "sample_rows",
    "sample_size",
    "scaled_tau",
]
