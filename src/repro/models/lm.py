"""Decoder-only LM assembly covering dense / MoE / MLA / SSM / hybrid / VLM
architectures.

Layers are organised as ``prefix`` (unrolled, e.g. DeepSeek's leading dense
layer) + ``groups`` (a ``lax.scan`` over repeats of ``cfg.pattern`` with
stacked parameters — keeps the HLO one-pattern-long regardless of depth) +
``suffix`` (unrolled remainder). Three modes share the block bodies:

  * ``train``  — full-sequence causal, remat (``jax.checkpoint``) per group;
  * ``prefill``— full-sequence causal, emits per-layer caches;
  * ``decode`` — one token against caches (attention KV / ring-buffer KV /
                 RG-LRU state / SSD state).

Caches are pytrees mirroring the prefix/groups/suffix layout, so the same
scan machinery threads them.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers.attention import chunked_attention, decode_attention, local_attention
from .layers.common import ShardCtx, cast, dense_init, rms_norm, shard
from .layers.embeddings import chunked_xent, embed_tokens, init_embed, logits_head
from .layers.mla import init_mla, mla_decode, mla_train_prefill
from .layers.mlp import apply_mlp, init_mlp
from .layers.moe import apply_moe, init_moe
from .layers.rglru import init_rglru, init_rglru_state, rglru_decode, rglru_train
from .layers.ssd import init_ssd, init_ssd_state, ssd_decode, ssd_train

__all__ = ["init_lm", "lm_forward", "lm_train_loss", "lm_prefill", "lm_decode", "init_cache", "layout"]


# ---------------------------------------------------------------- layout


def layout(cfg: ArchConfig) -> tuple[int, int, int]:
    """(prefix_len, n_groups, suffix_len) over cfg.n_layers."""
    prefix = cfg.moe.first_dense if cfg.moe else 0
    glen = len(cfg.pattern)
    remaining = cfg.n_layers - prefix
    n_groups = remaining // glen
    suffix = remaining - n_groups * glen
    return prefix, n_groups, suffix


def _layer_kinds(cfg: ArchConfig) -> list[str]:
    return cfg.layer_types()


# ---------------------------------------------------------------- init


def _init_attn(key, cfg: ArchConfig) -> dict:
    ks = jax.random.split(key, 4)
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "wq": dense_init(ks[0], (d, h * hd)),
        "wk": dense_init(ks[1], (d, kv * hd)),
        "wv": dense_init(ks[2], (d, kv * hd)),
        "wo": dense_init(ks[3], (h * hd, d)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), jnp.float32)
        p["bk"] = jnp.zeros((kv * hd,), jnp.float32)
        p["bv"] = jnp.zeros((kv * hd,), jnp.float32)
    return p


def _init_block(key, cfg: ArchConfig, kind: str, layer_idx: int) -> dict:
    ks = jax.random.split(key, 3)
    d = cfg.d_model
    bp: dict[str, Any] = {"norm1": jnp.zeros((d,), jnp.float32)}
    if kind in ("attn", "local"):
        if cfg.mla is not None:
            bp["attn"] = init_mla(ks[0], d, cfg.n_heads, cfg.mla)
        else:
            bp["attn"] = _init_attn(ks[0], cfg)
    elif kind == "rglru":
        bp["rglru"] = init_rglru(ks[0], d, cfg.rglru_dim)
    elif kind == "ssd":
        bp["ssd"] = init_ssd(ks[0], d, cfg.ssm)
        return bp  # mamba2 block: mixer only, no MLP
    else:
        raise ValueError(f"unknown block kind {kind!r}")
    bp["norm2"] = jnp.zeros((d,), jnp.float32)
    if cfg.moe is not None and layer_idx >= cfg.moe.first_dense:
        bp["moe"] = init_moe(ks[1], d, cfg.moe)
    else:
        ff = cfg.d_ff
        if cfg.moe is not None and layer_idx < cfg.moe.first_dense:
            ff = cfg.moe.first_dense_ff or cfg.d_ff
        bp["mlp"] = init_mlp(ks[1], d, ff, cfg.mlp_act)
    return bp


def _stack_trees(trees: list):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_lm(key, cfg: ArchConfig) -> dict:
    kinds = _layer_kinds(cfg)
    prefix, n_groups, suffix = layout(cfg)
    glen = len(cfg.pattern)
    keys = jax.random.split(key, cfg.n_layers + 2)
    params: dict[str, Any] = {
        "embed": init_embed(keys[-1], cfg.vocab, cfg.d_model, cfg.tie_embeddings),
        "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    params["prefix"] = [
        _init_block(keys[i], cfg, kinds[i], i) for i in range(prefix)
    ]
    group_params = []
    for pos in range(glen):
        per_group = []
        for gi in range(n_groups):
            li = prefix + gi * glen + pos
            per_group.append(_init_block(keys[li], cfg, kinds[li], li))
        group_params.append(_stack_trees(per_group) if per_group else None)
    params["groups"] = group_params
    base = prefix + n_groups * glen
    params["suffix"] = [
        _init_block(keys[base + i], cfg, kinds[base + i], base + i)
        for i in range(suffix)
    ]
    return params


# ---------------------------------------------------------------- block body


def _attn_apply(bp, cfg, ctx, x, kind, mode, state, lengths):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    if cfg.mla is not None:
        if mode == "train":
            return mla_train_prefill(bp["attn"], x, h, cfg.mla, cfg.rope_theta, ctx), None
        if mode == "prefill":
            out, cache = mla_train_prefill(
                bp["attn"], x, h, cfg.mla, cfg.rope_theta, ctx, return_cache=True
            )
            return out, cache
        return mla_decode(bp["attn"], x, state, lengths, h, cfg.mla, cfg.rope_theta, ctx)

    from .layers.rope import apply_rope

    p = bp["attn"]
    b, s, _ = x.shape
    dt = x.dtype
    q = x @ p["wq"].astype(dt)
    k = x @ p["wk"].astype(dt)
    v = x @ p["wv"].astype(dt)
    if "bq" in p:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, kv, hd)
    v = v.reshape(b, s, kv, hd)
    q = shard(ctx, q, ("dp", None, "tp", None))
    k = shard(ctx, k, ("dp", None, "tp", None))

    if mode in ("train", "prefill"):
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        if kind == "local" and cfg.window:
            out = local_attention(q, k, v, window=cfg.window)
        else:
            out = chunked_attention(q, k, v, causal=True)
        new_state = None
        if mode == "prefill":
            if kind == "local" and cfg.window and s > cfg.window:
                L = cfg.window
                slot = jnp.arange(L)
                pos_of_slot = slot + ((s - 1 - slot) // L) * L  # ring layout p % L
                new_state = {"k": k[:, pos_of_slot], "v": v[:, pos_of_slot]}
            else:
                new_state = {"k": k, "v": v}
    else:  # decode
        positions = lengths[:, None]
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        L = state["k"].shape[1]
        is_ring = kind == "local" and cfg.window and L <= cfg.window
        idx = (lengths % L) if is_ring else jnp.minimum(lengths, L - 1)
        bi = jnp.arange(b)
        k_cache = state["k"].at[bi, idx].set(k[:, 0])
        v_cache = state["v"].at[bi, idx].set(v[:, 0])
        attn_len = jnp.minimum(lengths + 1, L) if is_ring else (lengths + 1)
        win = 0 if is_ring else (cfg.window if kind == "local" else 0)
        out = decode_attention(q, k_cache, v_cache, attn_len, window=win)
        new_state = {"k": k_cache, "v": v_cache}

    out = out.reshape(b, s, h * hd) @ p["wo"].astype(dt)
    return out, new_state


def _apply_block(bp, kind, cfg, ctx, x, mode, state, lengths):
    # sequence-parallel boundary spec: constraining the *projection outputs*
    # (before the residual add) to this layout lets SPMD emit reduce-scatters
    # for the tensor-parallel partial sums instead of all-reduce + slice —
    # 2x the wire bytes saved on the dominant train collective
    # (EXPERIMENTS.md §Perf iteration 8).
    sp_spec = ("dp", "tp" if ctx and ctx.sp and mode == "train" else None, None)
    h = rms_norm(x, bp["norm1"])
    h = shard(ctx, h, sp_spec)
    if kind in ("attn", "local"):
        mix, new_state = _attn_apply(bp, cfg, ctx, h, kind, mode, state, lengths)
    elif kind == "rglru":
        if mode == "train":
            mix, new_state = rglru_train(bp["rglru"], h, ctx), None
        elif mode == "prefill":
            mix, new_state = rglru_train(bp["rglru"], h, ctx, return_state=True)
        else:
            mix, new_state = rglru_decode(bp["rglru"], h, state, ctx)
    elif kind == "ssd":
        if mode == "train":
            mix, new_state = ssd_train(bp["ssd"], h, cfg.ssm, ctx), None
        elif mode == "prefill":
            mix, new_state = ssd_train(bp["ssd"], h, cfg.ssm, ctx, return_state=True)
        else:
            mix, new_state = ssd_decode(bp["ssd"], h, state, cfg.ssm, ctx)
    else:
        raise ValueError(kind)
    x = x + shard(ctx, mix, sp_spec)
    if "mlp" in bp or "moe" in bp:
        h2 = rms_norm(x, bp["norm2"])
        if "moe" in bp:
            x = x + shard(ctx, apply_moe(bp["moe"], h2, cfg.moe, ctx), sp_spec)
        else:
            x = x + shard(ctx, apply_mlp(bp["mlp"], h2, cfg.mlp_act, ctx), sp_spec)
    x = shard(ctx, x, ("dp", "tp" if ctx and ctx.sp else None, None))
    return x, new_state


# ---------------------------------------------------------------- forward


def lm_forward(
    params: dict,
    cfg: ArchConfig,
    ctx: ShardCtx | None,
    inputs_embeds: jax.Array,
    mode: str = "train",
    cache: dict | None = None,
    lengths: jax.Array | None = None,
    unroll_groups: bool = False,
):
    """Run the block stack. Returns (hidden (B,S,D), new_cache | None).

    ``unroll_groups`` replaces the group scan with a Python loop. For decode
    with *unstacked* caches (``init_cache(..., stacked=False)``) this lets
    XLA alias every donated per-layer cache leaf in place — the scan form
    double-buffers the stacked cache (xs + ys copies), which for a 110B
    32k-decode cache is the difference between fitting HBM and not
    (EXPERIMENTS.md §Perf iteration 3).
    """
    kinds = _layer_kinds(cfg)
    prefix, n_groups, suffix = layout(cfg)
    glen = len(cfg.pattern)
    x = inputs_embeds
    new_cache: dict[str, Any] = {"prefix": [], "groups": None, "suffix": []}

    for i in range(prefix):
        st = cache["prefix"][i] if cache else None
        x, ns = _apply_block(params["prefix"][i], kinds[i], cfg, ctx, x, mode, st, lengths)
        new_cache["prefix"].append(ns)

    if n_groups > 0 and unroll_groups:
        groups_out = []
        cache_groups = cache["groups"] if cache else None
        for gi in range(n_groups):
            new_states = []
            for pos in range(glen):
                gp = jax.tree.map(lambda a: a[gi], params["groups"][pos])
                if cache_groups is None:
                    st = None
                elif isinstance(cache_groups, (list,)):  # unstacked: [group][pos]
                    st = cache_groups[gi][pos]
                else:  # stacked pytree: slice
                    st = jax.tree.map(lambda a: a[gi], cache_groups[pos])
                x, ns = _apply_block(gp, cfg.pattern[pos], cfg, ctx, x, mode, st, lengths)
                new_states.append(ns)
            groups_out.append(tuple(new_states))
        new_cache["groups"] = groups_out
    elif n_groups > 0 and mode == "decode":
        # decode: the stacked caches ride in the scan CARRY and are updated
        # with dynamic_update_index_in_dim — XLA aliases loop-carried state
        # in place, so the (donated) cache exists exactly once in HBM. The
        # xs/ys form double-buffers it (input stack + output stack), which
        # for a 110B 32k cache is ~2x cache size of extra temp
        # (EXPERIMENTS.md §Perf iteration 3).
        group_states = cache["groups"]

        def group_body(carry, xs):
            xc, caches = carry
            gi, gparams = xs
            new_states = []
            states = jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(a, gi, 0, keepdims=False),
                caches,
            )
            for pos in range(glen):
                kind = cfg.pattern[pos]
                xc, ns = _apply_block(
                    gparams[pos], kind, cfg, ctx, xc, mode, states[pos], lengths
                )
                new_states.append(ns)
            caches = jax.tree.map(
                lambda buf, ns: jax.lax.dynamic_update_index_in_dim(buf, ns, gi, 0),
                caches,
                tuple(new_states),
            )
            return (xc, caches), None

        xs = (jnp.arange(n_groups), tuple(params["groups"]))
        (x, updated), _ = jax.lax.scan(group_body, (x, tuple(group_states)), xs)
        new_cache["groups"] = updated
    elif n_groups > 0:
        group_states = cache["groups"] if cache else tuple([None] * glen)

        def group_body(xc, xs):
            gparams, gstates = xs
            new_states = []
            for pos in range(glen):
                kind = cfg.pattern[pos]
                xc, ns = _apply_block(
                    gparams[pos], kind, cfg, ctx, xc, mode, gstates[pos], lengths
                )
                new_states.append(ns)
            return xc, tuple(new_states)

        body = jax.checkpoint(group_body) if mode == "train" else group_body
        xs = (tuple(params["groups"]), tuple(group_states))
        x, stacked_states = jax.lax.scan(body, x, xs)
        new_cache["groups"] = stacked_states

    base = prefix + n_groups * glen
    for i in range(suffix):
        st = cache["suffix"][i] if cache else None
        x, ns = _apply_block(
            params["suffix"][i], kinds[base + i], cfg, ctx, x, mode, st, lengths
        )
        new_cache["suffix"].append(ns)

    x = rms_norm(x, params["final_norm"])
    return x, (new_cache if mode in ("prefill", "decode") else None)


def _embed_inputs(params, cfg, tokens, extra_embeds, ctx):
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    tok = embed_tokens(params["embed"], tokens, dt)
    if extra_embeds is not None:
        tok = jnp.concatenate([extra_embeds.astype(dt), tok], axis=1)
    tok = tok * jnp.asarray(cfg.d_model**0.5, dt)
    return shard(ctx, tok, ("dp", None, None))


def lm_train_loss(params, cfg, ctx, tokens, labels, extra_embeds=None):
    x = _embed_inputs(params, cfg, tokens, extra_embeds, ctx)
    h, _ = lm_forward(params, cfg, ctx, x, mode="train")
    if extra_embeds is not None:  # vlm: loss over text positions only
        h = h[:, extra_embeds.shape[1] :]
    return chunked_xent(params["embed"], h, labels, ctx)


def lm_prefill(params, cfg, ctx, tokens, extra_embeds=None):
    x = _embed_inputs(params, cfg, tokens, extra_embeds, ctx)
    h, cache = lm_forward(params, cfg, ctx, x, mode="prefill")
    logits = logits_head(params["embed"], h[:, -1:], ctx)
    return logits, cache


def lm_decode(params, cfg, ctx, tokens, positions, cache, unroll_groups: bool = False):
    x = _embed_inputs(params, cfg, tokens, None, ctx)
    h, new_cache = lm_forward(params, cfg, ctx, x, mode="decode", cache=cache,
                              lengths=positions, unroll_groups=unroll_groups)
    logits = logits_head(params["embed"], h, ctx)
    return logits, new_cache


# ---------------------------------------------------------------- caches


def _block_state_specs(cfg: ArchConfig, kind: str, batch: int, max_len: int, dtype):
    """ShapeDtypeStruct pytree of one layer's decode state (no allocation)."""
    S = jax.ShapeDtypeStruct
    if kind in ("attn", "local"):
        if cfg.mla is not None:
            return {
                "c_kv": S((batch, max_len, cfg.mla.kv_lora), dtype),
                "k_rope": S((batch, max_len, cfg.mla.rope_head_dim), dtype),
            }
        L = min(cfg.window, max_len) if (kind == "local" and cfg.window) else max_len
        shp = (batch, L, cfg.n_kv_heads, cfg.head_dim)
        return {"k": S(shp, dtype), "v": S(shp, dtype)}
    if kind == "rglru":
        return {
            "h": S((batch, cfg.rglru_dim), jnp.float32),
            "conv": S((batch, 3, cfg.rglru_dim), dtype),
        }
    if kind == "ssd":
        s = cfg.ssm
        conv_dim = s.d_inner + 2 * s.n_groups * s.d_state
        return {
            "state": S((batch, s.n_heads, s.head_dim, s.d_state), jnp.float32),
            "conv": S((batch, s.d_conv - 1, conv_dim), dtype),
        }
    raise ValueError(kind)


def init_cache(
    cfg: ArchConfig,
    batch: int,
    max_len: int,
    abstract: bool = False,
    stacked: bool = True,
) -> dict:
    """Decode cache pytree; ``abstract=True`` returns ShapeDtypeStructs only
    (the dry-run path — production decode caches would not fit one host).
    ``stacked=False`` emits per-layer leaves ([group][pos] lists) for the
    unrolled decode path, where each leaf donates/aliases independently."""
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    kinds = _layer_kinds(cfg)
    prefix, n_groups, suffix = layout(cfg)
    glen = len(cfg.pattern)

    def mk(kind):
        return _block_state_specs(cfg, kind, batch, max_len, dt)

    cache: dict[str, Any] = {
        "prefix": [mk(kinds[i]) for i in range(prefix)],
        "suffix": [
            mk(kinds[prefix + n_groups * glen + i]) for i in range(suffix)
        ],
    }
    if stacked:
        groups = []
        for pos in range(glen):
            if n_groups == 0:
                groups.append(None)
                continue
            one = mk(cfg.pattern[pos])
            groups.append(
                jax.tree.map(
                    lambda a: jax.ShapeDtypeStruct((n_groups,) + a.shape, a.dtype), one
                )
            )
        cache["groups"] = tuple(groups)
    else:
        cache["groups"] = [
            tuple(mk(cfg.pattern[pos]) for pos in range(glen)) for _ in range(n_groups)
        ]
    if not abstract:
        cache = jax.tree.map(lambda a: jnp.zeros(a.shape, a.dtype), cache)
    return cache
