"""Model zoo dispatcher: one uniform step API over all 10 architectures.

``build(cfg)`` returns a :class:`Model` with ``init`` / ``train_loss`` /
``prefill`` / ``decode`` / ``init_cache`` — decoder-only families route to
``models.lm``, the audio family to ``models.encdec``. The launcher, trainer,
server, smoke tests and dry-run all consume this interface only.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers.common import ShardCtx
from . import encdec as _encdec
from . import lm as _lm

__all__ = ["Model", "build"]


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    init: Callable[..., Any]
    train_loss: Callable[..., Any]
    prefill: Callable[..., Any]
    decode: Callable[..., Any]
    init_cache: Callable[..., Any]

    def abstract_params(self, seed: int = 0):
        """Parameter shapes without allocation (dry-run)."""
        return jax.eval_shape(lambda: self.init(jax.random.PRNGKey(seed)))


def build(cfg: ArchConfig) -> Model:
    if cfg.family == "audio":
        def train_loss(params, ctx, batch):
            return _encdec.encdec_train_loss(
                params, cfg, ctx, batch["frames"], batch["tokens"], batch["labels"]
            )

        def prefill(params, ctx, batch):
            return _encdec.encdec_prefill(params, cfg, ctx, batch["frames"], batch["tokens"])

        def decode(params, ctx, batch, cache):
            return _encdec.encdec_decode(
                params, cfg, ctx, batch["tokens"], batch["positions"], cache
            )

        return Model(
            cfg=cfg,
            init=lambda key: _encdec.init_encdec(key, cfg),
            train_loss=train_loss,
            prefill=prefill,
            decode=decode,
            init_cache=lambda batch, max_len, abstract=False: _encdec.init_encdec_cache(
                cfg, batch, max_len, abstract
            ),
        )

    def extra(batch):
        if cfg.frontend == "vision_stub":
            return batch["patches"]
        return None

    def train_loss(params, ctx, batch):
        return _lm.lm_train_loss(
            params, cfg, ctx, batch["tokens"], batch["labels"], extra_embeds=extra(batch)
        )

    def prefill(params, ctx, batch):
        return _lm.lm_prefill(params, cfg, ctx, batch["tokens"], extra_embeds=extra(batch))

    def decode(params, ctx, batch, cache, unroll_groups=False):
        return _lm.lm_decode(params, cfg, ctx, batch["tokens"], batch["positions"],
                             cache, unroll_groups=unroll_groups)

    return Model(
        cfg=cfg,
        init=lambda key: _lm.init_lm(key, cfg),
        train_loss=train_loss,
        prefill=prefill,
        decode=decode,
        init_cache=lambda batch, max_len, abstract=False, stacked=True: _lm.init_cache(
            cfg, batch, max_len, abstract, stacked=stacked
        ),
    )
