"""Encoder-decoder transformer (Whisper-style, arXiv:2212.04356).

Encoder: non-causal attention over (stubbed) audio-frame embeddings, scan
over stacked layers. Decoder: causal self-attention + cross-attention into
the encoder memory + MLP, scan over stacked layers. The conv frontend is a
stub per the assignment — ``input_specs`` supplies frame embeddings already
at ``d_model``.

Decode caches: per decoder layer, self-attn KV cache plus the (static)
cross-attn K/V projected from the encoder memory once at prefill.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from .layers.attention import chunked_attention, decode_attention
from .layers.common import ShardCtx, dense_init, rms_norm, shard
from .layers.embeddings import chunked_xent, embed_tokens, init_embed, logits_head
from .layers.mlp import apply_mlp, init_mlp
from .layers.rope import apply_rope

__all__ = [
    "init_encdec",
    "encdec_train_loss",
    "encdec_encode",
    "encdec_prefill",
    "encdec_decode",
    "init_encdec_cache",
]


def _init_attn(key, cfg: ArchConfig) -> dict:
    ks = jax.random.split(key, 4)
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return {
        "wq": dense_init(ks[0], (d, h * hd)),
        "wk": dense_init(ks[1], (d, kv * hd)),
        "wv": dense_init(ks[2], (d, kv * hd)),
        "wo": dense_init(ks[3], (h * hd, d)),
    }


def _init_enc_layer(key, cfg):
    ks = jax.random.split(key, 2)
    return {
        "norm1": jnp.zeros((cfg.d_model,), jnp.float32),
        "attn": _init_attn(ks[0], cfg),
        "norm2": jnp.zeros((cfg.d_model,), jnp.float32),
        "mlp": init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_act),
    }


def _init_dec_layer(key, cfg):
    ks = jax.random.split(key, 3)
    return {
        "norm1": jnp.zeros((cfg.d_model,), jnp.float32),
        "self_attn": _init_attn(ks[0], cfg),
        "norm_x": jnp.zeros((cfg.d_model,), jnp.float32),
        "cross_attn": _init_attn(ks[1], cfg),
        "norm2": jnp.zeros((cfg.d_model,), jnp.float32),
        "mlp": init_mlp(ks[2], cfg.d_model, cfg.d_ff, cfg.mlp_act),
    }


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_encdec(key, cfg: ArchConfig) -> dict:
    ke, kd, kemb = jax.random.split(key, 3)
    enc_keys = jax.random.split(ke, cfg.enc_layers)
    dec_keys = jax.random.split(kd, cfg.n_layers)
    return {
        "embed": init_embed(kemb, cfg.vocab, cfg.d_model, cfg.tie_embeddings),
        "enc_layers": _stack([_init_enc_layer(k, cfg) for k in enc_keys]),
        "dec_layers": _stack([_init_dec_layer(k, cfg) for k in dec_keys]),
        "enc_norm": jnp.zeros((cfg.d_model,), jnp.float32),
        "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
    }


def _qkv(p, x, cfg, ctx, rope_positions=None):
    b, s, _ = x.shape
    dt = x.dtype
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"].astype(dt)).reshape(b, s, h, hd)
    k = (x @ p["wk"].astype(dt)).reshape(b, s, kv, hd)
    v = (x @ p["wv"].astype(dt)).reshape(b, s, kv, hd)
    if rope_positions is not None:
        q = apply_rope(q, rope_positions, cfg.rope_theta)
        k = apply_rope(k, rope_positions, cfg.rope_theta)
    q = shard(ctx, q, ("dp", None, "tp", None))
    k = shard(ctx, k, ("dp", None, "tp", None))
    return q, k, v


def encdec_encode(params, cfg: ArchConfig, ctx, frames: jax.Array) -> jax.Array:
    """frames (B, S_enc, D) -> encoder memory (B, S_enc, D)."""
    x = shard(ctx, frames, ("dp", None, None))
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def body(xc, lp):
        h = rms_norm(xc, lp["norm1"])
        q, k, v = _qkv(lp["attn"], h, cfg, ctx, positions)
        o = chunked_attention(q, k, v, causal=False)
        o = o.reshape(b, s, -1) @ lp["attn"]["wo"].astype(xc.dtype)
        xc = xc + o
        h2 = rms_norm(xc, lp["norm2"])
        xc = xc + apply_mlp(lp["mlp"], h2, cfg.mlp_act, ctx)
        return shard(ctx, xc, ("dp", None, None)), None

    x, _ = jax.lax.scan(jax.checkpoint(body), x, params["enc_layers"])
    return rms_norm(x, params["enc_norm"])


def _dec_layer(lp, cfg, ctx, x, memory, mode, state, lengths):
    b, s, _ = x.shape
    dt = x.dtype
    h_heads, hd = cfg.n_heads, cfg.head_dim
    # self attention
    h = rms_norm(x, lp["norm1"])
    if mode in ("train", "prefill"):
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        q, k, v = _qkv(lp["self_attn"], h, cfg, ctx, positions)
        o = chunked_attention(q, k, v, causal=True)
        new_self = {"k": k, "v": v} if mode == "prefill" else None
    else:
        positions = lengths[:, None]
        q, k, v = _qkv(lp["self_attn"], h, cfg, ctx, positions)
        L = state["k"].shape[1]
        bi = jnp.arange(b)
        idx = jnp.minimum(lengths, L - 1)
        k_cache = state["k"].at[bi, idx].set(k[:, 0])
        v_cache = state["v"].at[bi, idx].set(v[:, 0])
        o = decode_attention(q, k_cache, v_cache, lengths + 1)
        new_self = {"k": k_cache, "v": v_cache}
    x = x + o.reshape(b, s, -1) @ lp["self_attn"]["wo"].astype(dt)

    # cross attention (memory: either raw encoder states or cached K/V)
    hx = rms_norm(x, lp["norm_x"])
    qx = (hx @ lp["cross_attn"]["wq"].astype(dt)).reshape(b, s, h_heads, hd)
    if isinstance(memory, dict):  # pre-projected cache
        km, vm = memory["k"], memory["v"]
    else:
        mb, ms, _ = memory.shape
        km = (memory @ lp["cross_attn"]["wk"].astype(dt)).reshape(mb, ms, cfg.n_kv_heads, hd)
        vm = (memory @ lp["cross_attn"]["wv"].astype(dt)).reshape(mb, ms, cfg.n_kv_heads, hd)
    ox = chunked_attention(qx, km, vm, causal=False)
    x = x + ox.reshape(b, s, -1) @ lp["cross_attn"]["wo"].astype(dt)

    h2 = rms_norm(x, lp["norm2"])
    x = x + apply_mlp(lp["mlp"], h2, cfg.mlp_act, ctx)
    x = shard(ctx, x, ("dp", None, None))
    new_cross = {"k": km, "v": vm} if mode == "prefill" else None
    return x, new_self, new_cross


def _run_decoder(params, cfg, ctx, x, memory, mode, cache, lengths):
    def body(xc, xs):
        lp, st, mem = xs
        xc, new_self, new_cross = _dec_layer(lp, cfg, ctx, xc, mem, mode, st, lengths)
        return xc, (new_self, new_cross)

    n_layers = cfg.n_layers
    if cache is not None:
        states_xs = cache["self"]
        mems = cache["cross"]
    else:
        states_xs = None  # empty pytree: _dec_layer sees st=None in train/prefill
        mems = jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n_layers,) + a.shape), memory
        )
    body_fn = jax.checkpoint(body) if mode == "train" else body
    x, (new_self, new_cross) = jax.lax.scan(
        body_fn, x, (params["dec_layers"], states_xs, mems)
    )
    return x, new_self, new_cross


def encdec_train_loss(params, cfg, ctx, frames, tokens, labels):
    memory = encdec_encode(params, cfg, ctx, frames)
    dt = memory.dtype
    x = embed_tokens(params["embed"], tokens, dt) * jnp.asarray(cfg.d_model**0.5, dt)
    x = shard(ctx, x, ("dp", None, None))
    x, _, _ = _run_decoder(params, cfg, ctx, x, memory, "train", None, None)
    x = rms_norm(x, params["final_norm"])
    return chunked_xent(params["embed"], x, labels, ctx)


def encdec_prefill(params, cfg, ctx, frames, tokens):
    """Encode + decoder prefill; returns (last logits, cache)."""
    memory = encdec_encode(params, cfg, ctx, frames)
    dt = memory.dtype
    x = embed_tokens(params["embed"], tokens, dt) * jnp.asarray(cfg.d_model**0.5, dt)
    x, new_self, new_cross = _run_decoder(params, cfg, ctx, x, memory, "prefill", None, None)
    x = rms_norm(x, params["final_norm"])
    logits = logits_head(params["embed"], x[:, -1:], ctx)
    return logits, {"self": new_self, "cross": new_cross}


def encdec_decode(params, cfg, ctx, tokens, positions, cache):
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    x = embed_tokens(params["embed"], tokens, dt) * jnp.asarray(cfg.d_model**0.5, dt)
    x, new_self, new_cross = _run_decoder(
        params, cfg, ctx, x, None, "decode", cache, positions
    )
    x = rms_norm(x, params["final_norm"])
    logits = logits_head(params["embed"], x, ctx)
    return logits, {"self": new_self, "cross": cache["cross"]}


def init_encdec_cache(cfg: ArchConfig, batch: int, max_len: int, abstract: bool = False):
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    S = jax.ShapeDtypeStruct
    kvshape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    xshape = (cfg.n_layers, batch, cfg.cross_attn_len, cfg.n_kv_heads, cfg.head_dim)
    cache = {
        "self": {"k": S(kvshape, dt), "v": S(kvshape, dt)},
        "cross": {"k": S(xshape, dt), "v": S(xshape, dt)},
    }
    if not abstract:
        cache = jax.tree.map(lambda a: jnp.zeros(a.shape, a.dtype), cache)
    return cache
