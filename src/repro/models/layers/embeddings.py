"""Token embedding and the chunked softmax cross-entropy head.

At production shapes the full (B, S, V) logits tensor does not fit
(16 × 4096 × 152k bf16 ≈ 20 GB per device) — the loss is computed by a
``lax.scan`` over sequence chunks: per chunk, logits -> logsumexp -> label
logit, accumulating scalar loss; the full logits never materialise. The
vocab dim of each chunk shards over the tensor axis.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ShardCtx, dense_init, shard

__all__ = ["init_embed", "embed_tokens", "logits_head", "chunked_xent"]


def init_embed(key, vocab: int, d_model: int, tie: bool) -> dict:
    ks = jax.random.split(key, 2)
    p = {"embedding": dense_init(ks[0], (vocab, d_model), in_axis=1)}
    if not tie:
        p["lm_head"] = dense_init(ks[1], (d_model, vocab))
    return p


def embed_tokens(p: dict, tokens: jax.Array, dtype) -> jax.Array:
    return jnp.take(p["embedding"], tokens, axis=0).astype(dtype)


def _head_matrix(p: dict, dtype):
    if "lm_head" in p:
        return p["lm_head"].astype(dtype)
    return p["embedding"].T.astype(dtype)


def logits_head(p: dict, h: jax.Array, ctx: ShardCtx | None = None) -> jax.Array:
    """(B, S, D) -> (B, S, V) logits (decode-sized inputs only)."""
    logits = h @ _head_matrix(p, h.dtype)
    return shard(ctx, logits, ("dp", None, "tp"))


def chunked_xent(
    p: dict,
    h: jax.Array,
    labels: jax.Array,
    ctx: ShardCtx | None = None,
    chunk: int = 512,
    mask: jax.Array | None = None,
) -> jax.Array:
    """Mean next-token cross-entropy without materialising full logits.

    h: (B, S, D) final hidden states; labels: (B, S) int32 (-1 = ignore).
    """
    b, s, d = h.shape
    w = _head_matrix(p, h.dtype)
    c = min(chunk, s)
    pad = (-s) % c
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
        if mask is not None:
            mask = jnp.pad(mask, ((0, 0), (0, pad)))
    nc = h.shape[1] // c
    hc = h.reshape(b, nc, c, d).swapaxes(0, 1)  # (nc, B, c, D)
    lc = labels.reshape(b, nc, c).swapaxes(0, 1)
    mc = None if mask is None else mask.reshape(b, nc, c).swapaxes(0, 1)

    @jax.checkpoint  # recompute chunk logits on backward: without this the
    # scan stores (nc, B, c, V) f32 logits residuals — tens of GB per device
    def step(carry, inp):
        loss_sum, count = carry
        if mc is None:
            hb, lb = inp
            valid = lb >= 0
        else:
            hb, lb, vb = inp
            valid = (lb >= 0) & vb
        logits = (hb @ w).astype(jnp.float32)  # (B, c, V)
        logits = shard(ctx, logits, ("dp", None, "tp"))
        lse = jax.nn.logsumexp(logits, axis=-1)
        lbl = jnp.take_along_axis(logits, lb.clip(0)[..., None], axis=-1)[..., 0]
        nll = jnp.where(valid, lse - lbl, 0.0)
        return (loss_sum + nll.sum(), count + valid.sum()), None

    xs = (hc, lc) if mc is None else (hc, lc, mc)
    (loss_sum, count), _ = jax.lax.scan(step, (jnp.float32(0.0), jnp.int32(0)), xs)
    return loss_sum / jnp.maximum(count, 1)
