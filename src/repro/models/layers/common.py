"""Shared layer utilities: sharding context, norms, initializers.

``ShardCtx`` carries the logical->mesh axis mapping through the forward pass;
``shard(ctx, x, names)`` applies ``with_sharding_constraint`` with per-dim
divisibility fallback (a non-divisible dim silently replicates — the planner
reports these in the dry-run log). With ``ctx.mesh is None`` everything is a
no-op, so the same model code runs un-sharded on CPU for smoke tests.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["ShardCtx", "shard", "rms_norm", "dense_init", "zeros_init", "cast"]


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Logical sharding context.

    dp: data-parallel mesh axes (e.g. ("data",) or ("pod", "data")).
    tp: tensor-parallel axis name (e.g. "model") or None.
    sp: shard sequence dim of block-boundary activations over tp
        (sequence parallelism; saves activation memory under remat).
    """

    mesh: Mesh | None = None
    dp: tuple[str, ...] = ()
    tp: str | None = None
    sp: bool = True

    def axis_size(self, logical: str | tuple[str, ...] | None) -> int:
        if self.mesh is None or logical is None:
            return 1
        axes = (logical,) if isinstance(logical, str) else logical
        size = 1
        for a in axes:
            size *= self.mesh.shape[a]
        return size

    def resolve(self, name) -> tuple[str, ...] | str | None:
        if name is None:
            return None
        if name == "dp":
            return self.dp if self.dp else None
        if name == "tp":
            return self.tp
        raise ValueError(f"unknown logical axis {name!r}")


def shard(ctx: ShardCtx | None, x: jax.Array, names: tuple) -> jax.Array:
    """Constrain ``x`` sharding; per-dim divisibility fallback to replicated."""
    if ctx is None or ctx.mesh is None:
        return x
    spec = []
    for dim, name in zip(x.shape, names):
        axes = ctx.resolve(name)
        if axes is None or dim % ctx.axis_size(axes) != 0:
            spec.append(None)
        else:
            spec.append(axes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, P(*spec)))


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    normed = xf * jax.lax.rsqrt(var + eps)
    return (normed * (1.0 + scale.astype(jnp.float32))).astype(dt)


def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32) -> jax.Array:
    """Truncated-normal fan-in init (LeCun-ish), fp32 master weights."""
    fan_in = shape[in_axis]
    std = fan_in ** -0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape) * std).astype(dtype)


def zeros_init(shape, dtype=jnp.float32) -> jax.Array:
    return jnp.zeros(shape, dtype=dtype)


def cast(x: jax.Array, dtype_str: str) -> jax.Array:
    return x.astype(jnp.bfloat16 if dtype_str == "bfloat16" else jnp.float32)
