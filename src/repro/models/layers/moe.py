"""Mixture-of-experts channel mixing with grouped capacity-based dispatch.

Routing: softmax router -> top-k experts per token, weights renormalised over
the selected k. Tokens are processed in **groups** (GShard semantics): the
token axis is reshaped to (G, t_g) with G aligned to the data-parallel mesh
axes, and each group scatters its tokens into a per-group capacity buffer
``(G, E, C_g, d)`` (assignments beyond ``C_g = ceil(t_g·k/E · factor)`` are
dropped — standard GShard/Switch capacity semantics).

Sharding: the buffer is double-sharded — groups over dp, experts over tp
(expert parallelism); the scatter from token-sharded to expert-sharded layout
is the MoE dispatch collective, inserted by SPMD. No (tokens, E, C) one-hot
intermediate is ever materialised: dispatch is a scatter, combine is a
gather + segment-sum, so the footprint stays at buffer size / (dp·tp).

Shared experts (DeepSeek-V2 style) run densely for every token.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ShardCtx, dense_init, shard

__all__ = ["init_moe", "apply_moe", "moe_capacity"]


def moe_capacity(tokens_per_group: int, n_experts: int, top_k: int, factor: float) -> int:
    c = int(tokens_per_group * top_k / n_experts * factor) + 1
    return max(8, -(-c // 8) * 8)  # round up to 8 for TPU-friendly shapes


def init_moe(key, d_model: int, cfg) -> dict:
    ks = jax.random.split(key, 8)
    e, f = cfg.n_experts, cfg.d_expert
    p = {
        "router": dense_init(ks[0], (d_model, e)),
        "w_gate": dense_init(ks[1], (e, d_model, f), in_axis=1),
        "w_up": dense_init(ks[2], (e, d_model, f), in_axis=1),
        "w_down": dense_init(ks[3], (e, f, d_model), in_axis=1),
    }
    if cfg.n_shared:
        fs = f * cfg.n_shared
        p["sh_gate"] = dense_init(ks[4], (d_model, fs))
        p["sh_up"] = dense_init(ks[5], (d_model, fs))
        p["sh_down"] = dense_init(ks[6], (fs, d_model))
    return p


def _n_groups(t: int, ctx: ShardCtx | None) -> int:
    g = ctx.axis_size(ctx.dp) if (ctx is not None and ctx.mesh is not None) else 1
    while t % g:
        g -= 1
    return max(g, 1)


def apply_moe(
    p: dict,
    x: jax.Array,
    cfg,
    ctx: ShardCtx | None = None,
    n_groups: int | None = None,
) -> jax.Array:
    """x: (B, S, D) -> (B, S, D). cfg: configs.base.MoECfg."""
    dt = x.dtype
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    G = n_groups or _n_groups(t, ctx)
    tg = t // G
    cap = moe_capacity(tg, e, k, cfg.capacity_factor)
    xg = x.reshape(G, tg, d)
    xg = shard(ctx, xg, ("dp", None, None))

    logits = (xg @ p["router"].astype(dt)).astype(jnp.float32)  # (G, tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)  # (G, tg, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    def dispatch_combine(xt, flat_e, gates):
        # xt: (tg, d); flat_e: (tg*k,); gates: (tg*k,)
        onehot_cum = jnp.cumsum(jax.nn.one_hot(flat_e, e, dtype=jnp.int32), axis=0)
        pos = onehot_cum[jnp.arange(tg * k), flat_e] - 1
        keep = pos < cap
        tok_idx = jnp.repeat(jnp.arange(tg), k)
        scatter_e = jnp.where(keep, flat_e, e)  # out-of-range row -> dropped
        pos_c = jnp.where(keep, pos, 0)
        buf = jnp.zeros((e, cap, d), dt).at[scatter_e, pos_c].set(
            xt[tok_idx], mode="drop"
        )
        return buf, (scatter_e, pos_c, keep, tok_idx, gates)

    buf, meta = jax.vmap(dispatch_combine)(
        xg, expert_ids.reshape(G, tg * k), gate_vals.reshape(G, tg * k).astype(dt)
    )
    # (G, E, C, d): groups over dp, experts over tp — EP x DP double sharding
    buf = shard(ctx, buf, ("dp", "tp", None, None))

    h_gate = jnp.einsum("gecd,edf->gecf", buf, p["w_gate"].astype(dt))
    h_up = jnp.einsum("gecd,edf->gecf", buf, p["w_up"].astype(dt))
    h = jax.nn.silu(h_gate) * h_up
    h = shard(ctx, h, ("dp", "tp", None, None))
    out_buf = jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(dt))
    out_buf = shard(ctx, out_buf, ("dp", "tp", None, None))

    def combine(out_b, meta):
        scatter_e, pos_c, keep, tok_idx, gates = meta
        gathered = out_b[jnp.minimum(scatter_e, e - 1), pos_c]  # (tg*k, d)
        gathered = jnp.where(keep[:, None], gathered, 0.0)
        weighted = gathered * gates[:, None]
        return jax.ops.segment_sum(weighted, tok_idx, num_segments=tg)

    out = jax.vmap(combine)(out_buf, meta)  # (G, tg, d)
    out = shard(ctx, out, ("dp", None, None))
    out = out.reshape(b, s, d)

    if "sh_gate" in p:
        xt = x.reshape(t, d)
        sh = jax.nn.silu(xt @ p["sh_gate"].astype(dt)) * (xt @ p["sh_up"].astype(dt))
        out = out + (sh @ p["sh_down"].astype(dt)).reshape(b, s, d)
    return out
