"""Mamba-2 SSD (state-space duality, arXiv:2405.21060) — chunked dual form.

The sequence is split into chunks of length Q. Within a chunk the output is
the masked "attention-like" quadratic form (C Bᵀ ⊙ decay) x; across chunks a
recurrent state (H, P, N) is passed through a ``lax.scan``. This is the
published minimal SSD algorithm, expressed with einsums so XLA maps it onto
the MXU.

Decode maintains the (B, H, P, N) state and a depthwise-conv ring of the last
``d_conv - 1`` inputs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ShardCtx, dense_init, shard

__all__ = ["init_ssd", "ssd_scan", "ssd_train", "ssd_decode", "init_ssd_state"]


def init_ssd(key, d_model: int, ssm) -> dict:
    ks = jax.random.split(key, 4)
    di, g, n, h = ssm.d_inner, ssm.n_groups, ssm.d_state, ssm.n_heads
    conv_dim = di + 2 * g * n
    proj_out = 2 * di + 2 * g * n + h  # z, x, B, C, dt
    return {
        "w_in": dense_init(ks[0], (d_model, proj_out)),
        "conv_w": dense_init(ks[1], (ssm.d_conv, conv_dim)),
        "A_log": jnp.zeros((h,), jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "gate_norm": jnp.zeros((di,), jnp.float32),
        "w_out": dense_init(ks[2], (di, d_model)),
    }


def _split_proj(proj, ssm):
    di, g, n, h = ssm.d_inner, ssm.n_groups, ssm.d_state, ssm.n_heads
    z = proj[..., :di]
    x = proj[..., di : 2 * di]
    B = proj[..., 2 * di : 2 * di + g * n]
    C = proj[..., 2 * di + g * n : 2 * di + 2 * g * n]
    dt = proj[..., 2 * di + 2 * g * n :]
    return z, x, B, C, dt


def _causal_conv(u: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv: u (B, S, C), w (K, C)."""
    k = w.shape[0]
    up = jnp.pad(u, ((0, 0), (k - 1, 0), (0, 0)))
    # sum of shifted slices — K is tiny (4), unrolled adds beat a conv op here
    out = jnp.zeros_like(u)
    s = u.shape[1]
    for i in range(k):
        out = out + up[:, i : i + s, :] * w[i][None, None, :]
    return out


def ssd_scan(x, dt, A, B, C, chunk: int, initial_state=None):
    """Chunked SSD. x: (b,s,h,p); dt: (b,s,h) (post-softplus); A: (h,) < 0;
    B, C: (b,s,g,n). Returns (y (b,s,h,p), final_state (b,h,p,n))."""
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    q = min(chunk, s)
    pad = (-s) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = x.shape[1] // q
    hpg = h // g  # heads per B/C group

    xc = x.reshape(b, nc, q, h, p)
    dtc = dt.reshape(b, nc, q, h)
    Bc = B.reshape(b, nc, q, g, n)
    Cc = C.reshape(b, nc, q, g, n)

    dA = dtc * A[None, None, None, :]  # (b,nc,q,h) log-decay per step
    cs = jnp.cumsum(dA, axis=2)  # within-chunk cumulative
    seg_total = cs[:, :, -1, :]  # (b,nc,h)

    # intra-chunk (diagonal block): L[i,j] = exp(cs_i - cs_j) for j <= i
    diff = cs[:, :, :, None, :] - cs[:, :, None, :, :]  # (b,nc,q,q,h)
    mask = jnp.tril(jnp.ones((q, q), bool))
    L = jnp.where(mask[None, None, :, :, None], jnp.exp(diff), 0.0)
    # scores: C_i · B_j within chunk, per head group
    CB = jnp.einsum("bcign,bcjgn->bcijg", Cc, Bc)  # (b,nc,q,q,g)
    CB = jnp.repeat(CB, hpg, axis=-1)  # (b,nc,q,q,h)
    y_diag = jnp.einsum("bcijh,bcjh,bcjhp->bcihp", CB * L, dtc, xc)

    # chunk states: S_c = sum_j exp(seg_total - cs_j) * dt_j * B_j ⊗ x_j
    decay_states = jnp.exp(seg_total[:, :, None, :] - cs)  # (b,nc,q,h)
    Bh = jnp.repeat(Bc, hpg, axis=-2) if g != h else Bc  # (b,nc,q,h,n)
    states = jnp.einsum("bcqh,bcqh,bcqhn,bcqhp->bchpn", decay_states, dtc, Bh, xc)

    # inter-chunk recurrence over nc
    init = (
        jnp.zeros((b, h, p, n), x.dtype)
        if initial_state is None
        else initial_state.astype(x.dtype)
    )

    def step(carry, inp):
        st_c, seg_c = inp  # (b,h,p,n), (b,h)
        prev = carry
        new = prev * jnp.exp(seg_c)[:, :, None, None] + st_c
        return new, prev  # emit state *entering* the chunk

    final, prev_states = jax.lax.scan(
        step, init, (states.swapaxes(0, 1), seg_total.swapaxes(0, 1))
    )
    prev_states = prev_states.swapaxes(0, 1)  # (b,nc,h,p,n)

    # inter-chunk contribution: y_off_i = (C_i · prev_state) * exp(cs_i)
    Ch = jnp.repeat(Cc, hpg, axis=-2) if g != h else Cc  # (b,nc,q,h,n)
    y_off = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp", Ch, prev_states, jnp.exp(cs))
    y = (y_diag + y_off).reshape(b, nc * q, h, p)[:, :s]
    return y, final


def ssd_train(p: dict, x: jax.Array, ssm, ctx: ShardCtx | None = None,
              return_state: bool = False):
    """Full mamba2 mixer block body (after the pre-norm): (B,S,D) -> (B,S,D)."""
    b, s, d = x.shape
    dt_ = x.dtype
    proj = x @ p["w_in"].astype(dt_)
    z, xi, B, C, dt = _split_proj(proj, ssm)
    conv_in = jnp.concatenate([xi, B, C], axis=-1)
    conv_out = jax.nn.silu(_causal_conv(conv_in, p["conv_w"].astype(dt_)))
    di = ssm.d_inner
    g, n, h = ssm.n_groups, ssm.d_state, ssm.n_heads
    xi = conv_out[..., :di].reshape(b, s, h, ssm.head_dim)
    B = conv_out[..., di : di + g * n].reshape(b, s, g, n)
    C = conv_out[..., di + g * n :].reshape(b, s, g, n)
    dt_act = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (b,s,h)
    A = -jnp.exp(p["A_log"])  # (h,) negative
    xi = shard(ctx, xi, ("dp", None, "tp", None))
    y, final = ssd_scan(xi.astype(jnp.float32), dt_act, A, B.astype(jnp.float32),
                        C.astype(jnp.float32), ssm.chunk)
    y = y + xi.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(b, s, di).astype(dt_)
    # gated RMSNorm (mamba2)
    from .common import rms_norm

    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"])
    out = y @ p["w_out"].astype(dt_)
    if return_state:
        conv_tail = conv_in[:, -(ssm.d_conv - 1) :, :]  # last K-1 raw conv inputs
        return out, {"state": final, "conv": conv_tail}
    return out


def init_ssd_state(batch: int, ssm, dtype=jnp.float32) -> dict:
    h, pdim, n = ssm.n_heads, ssm.head_dim, ssm.d_state
    conv_dim = ssm.d_inner + 2 * ssm.n_groups * ssm.d_state
    return {
        "state": jnp.zeros((batch, h, pdim, n), dtype),
        "conv": jnp.zeros((batch, ssm.d_conv - 1, conv_dim), dtype),
    }


def ssd_decode(p: dict, x: jax.Array, cache: dict, ssm, ctx: ShardCtx | None = None):
    """One-step decode: x (B, 1, D) -> (B, 1, D), updated cache."""
    b, _, d = x.shape
    dt_ = x.dtype
    proj = x @ p["w_in"].astype(dt_)
    z, xi, B, C, dt = _split_proj(proj, ssm)
    conv_in_new = jnp.concatenate([xi, B, C], axis=-1)  # (b,1,conv_dim)
    window = jnp.concatenate([cache["conv"].astype(dt_), conv_in_new], axis=1)  # (b,K,conv)
    w = p["conv_w"].astype(dt_)
    conv_out = jax.nn.silu(jnp.einsum("bkc,kc->bc", window, w))[:, None, :]
    di, g, n, h = ssm.d_inner, ssm.n_groups, ssm.d_state, ssm.n_heads
    xi = conv_out[..., :di].reshape(b, h, ssm.head_dim)
    Bv = conv_out[..., di : di + g * n].reshape(b, g, n)
    Cv = conv_out[..., di + g * n :].reshape(b, g, n)
    hpg = h // g
    Bh = jnp.repeat(Bv, hpg, axis=1)  # (b,h,n)
    Ch = jnp.repeat(Cv, hpg, axis=1)
    dt_act = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (b,h)
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt_act * A[None, :])  # (b,h)
    state = cache["state"].astype(jnp.float32)
    new_state = state * decay[:, :, None, None] + jnp.einsum(
        "bh,bhn,bhp->bhpn", dt_act, Bh.astype(jnp.float32), xi.astype(jnp.float32)
    )
    y = jnp.einsum("bhn,bhpn->bhp", Ch.astype(jnp.float32), new_state)
    y = y + xi.astype(jnp.float32) * p["D"][None, :, None]
    y = y.reshape(b, 1, di).astype(dt_)
    from .common import rms_norm

    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"])
    out = y @ p["w_out"].astype(dt_)
    new_conv = window[:, 1:, :]
    return out, {"state": new_state, "conv": new_conv}
