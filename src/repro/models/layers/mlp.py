"""Channel-mixing blocks: gated (SwiGLU/GeGLU) and plain (GELU/squared-ReLU) MLPs."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ShardCtx, dense_init, shard

__all__ = ["init_mlp", "apply_mlp", "ACTIVATIONS"]

ACTIVATIONS = ("swiglu", "geglu", "gelu", "squared_relu")


def _act(name: str, x: jax.Array) -> jax.Array:
    if name == "gelu":
        return jax.nn.gelu(x)
    if name == "squared_relu":  # Primer / Nemotron-4
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(name)


def init_mlp(key, d_model: int, d_ff: int, act: str) -> dict:
    ks = jax.random.split(key, 3)
    if act in ("swiglu", "geglu"):
        return {
            "w_gate": dense_init(ks[0], (d_model, d_ff)),
            "w_up": dense_init(ks[1], (d_model, d_ff)),
            "w_down": dense_init(ks[2], (d_ff, d_model)),
        }
    return {
        "w_up": dense_init(ks[0], (d_model, d_ff)),
        "w_down": dense_init(ks[1], (d_ff, d_model)),
    }


def apply_mlp(p: dict, x: jax.Array, act: str, ctx: ShardCtx | None = None) -> jax.Array:
    dt = x.dtype
    if act in ("swiglu", "geglu"):
        gate = x @ p["w_gate"].astype(dt)
        up = x @ p["w_up"].astype(dt)
        gate = shard(ctx, gate, ("dp", None, "tp"))
        h = (jax.nn.silu(gate) if act == "swiglu" else jax.nn.gelu(gate)) * up
    else:
        h = _act(act, x @ p["w_up"].astype(dt))
        h = shard(ctx, h, ("dp", None, "tp"))
    return h @ p["w_down"].astype(dt)
