"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

KV is compressed into a per-token latent ``c_kv`` of rank ``kv_lora`` plus a
decoupled RoPE key of ``rope_head_dim`` — that pair is all the KV cache
stores (the MLA memory win). Keys/values are re-expanded from the latent by
up-projections at attention time. Queries have a decoupled (nope, rope) split
matching the keys.

This is the *naive* (non-absorbed) MLA: cache-optimal, recompute-heavy. The
weight-absorption decode trick (folding W_uk into the query projection) is a
documented §Perf candidate.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .attention import chunked_attention, decode_attention
from .common import ShardCtx, dense_init, rms_norm, shard
from .rope import apply_rope

__all__ = ["init_mla", "mla_train_prefill", "mla_decode", "expand_kv"]


def init_mla(key, d_model: int, n_heads: int, mla) -> dict:
    ks = jax.random.split(key, 6)
    qd = n_heads * (mla.nope_head_dim + mla.rope_head_dim)
    return {
        "wq": dense_init(ks[0], (d_model, qd)),
        "w_dkv": dense_init(ks[1], (d_model, mla.kv_lora + mla.rope_head_dim)),
        "kv_norm": jnp.zeros((mla.kv_lora,), jnp.float32),
        "w_uk": dense_init(ks[2], (mla.kv_lora, n_heads * mla.nope_head_dim)),
        "w_uv": dense_init(ks[3], (mla.kv_lora, n_heads * mla.v_head_dim)),
        "wo": dense_init(ks[4], (n_heads * mla.v_head_dim, d_model)),
    }


def _project_q(p, x, n_heads, mla, positions, theta):
    b, s, _ = x.shape
    q = (x @ p["wq"].astype(x.dtype)).reshape(b, s, n_heads, mla.nope_head_dim + mla.rope_head_dim)
    q_nope = q[..., : mla.nope_head_dim]
    q_rope = apply_rope(q[..., mla.nope_head_dim :], positions, theta)
    return q_nope, q_rope


def _compress_kv(p, x, mla, positions, theta):
    ckv_full = x @ p["w_dkv"].astype(x.dtype)  # (b, s, kv_lora + rope_hd)
    c_kv = rms_norm(ckv_full[..., : mla.kv_lora], p["kv_norm"])
    # decoupled rope key is shared across heads (one head's worth), per paper
    k_rope = apply_rope(ckv_full[..., mla.kv_lora :][:, :, None, :], positions, theta)
    return c_kv, k_rope[:, :, 0, :]


def expand_kv(p, c_kv, n_heads, mla):
    """Latent (b, s, kv_lora) -> k_nope, v: (b, s, H, nope/v head dims)."""
    b, s, _ = c_kv.shape
    k_nope = (c_kv @ p["w_uk"].astype(c_kv.dtype)).reshape(b, s, n_heads, mla.nope_head_dim)
    v = (c_kv @ p["w_uv"].astype(c_kv.dtype)).reshape(b, s, n_heads, mla.v_head_dim)
    return k_nope, v


def mla_train_prefill(
    p: dict,
    x: jax.Array,
    n_heads: int,
    mla,
    theta: float,
    ctx: ShardCtx | None = None,
    return_cache: bool = False,
):
    b, s, d = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q_nope, q_rope = _project_q(p, x, n_heads, mla, positions, theta)
    c_kv, k_rope = _compress_kv(p, x, mla, positions, theta)
    k_nope, v = expand_kv(p, c_kv, n_heads, mla)
    # concatenate nope+rope per head; rope part broadcasts over heads
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_rope_h = jnp.broadcast_to(k_rope[:, :, None, :], (b, s, n_heads, mla.rope_head_dim))
    k_full = jnp.concatenate([k_nope, k_rope_h], axis=-1)
    q_full = shard(ctx, q_full, ("dp", None, "tp", None))
    k_full = shard(ctx, k_full, ("dp", None, "tp", None))
    out = chunked_attention(q_full, k_full, v, causal=True)
    out = out.reshape(b, s, n_heads * mla.v_head_dim) @ p["wo"].astype(x.dtype)
    if return_cache:
        return out, {"c_kv": c_kv, "k_rope": k_rope}
    return out


def mla_decode(
    p: dict,
    x: jax.Array,
    cache: dict,
    lengths: jax.Array,
    n_heads: int,
    mla,
    theta: float,
    ctx: ShardCtx | None = None,
):
    """One-step decode. cache: c_kv (B, L, kv_lora), k_rope (B, L, rope_hd)."""
    b, one, d = x.shape
    positions = lengths[:, None]  # (B, 1) current absolute position
    q_nope, q_rope = _project_q(p, x, n_heads, mla, positions, theta)
    c_kv_new, k_rope_new = _compress_kv(p, x, mla, positions, theta)
    cache_ckv = _update_cache(cache["c_kv"], c_kv_new, lengths)
    cache_krope = _update_cache(cache["k_rope"], k_rope_new, lengths)
    # expand the whole cache (naive MLA): (B, L, H, ...)
    k_nope, v = expand_kv(p, cache_ckv, n_heads, mla)
    L = cache_ckv.shape[1]
    k_rope_h = jnp.broadcast_to(
        cache_krope[:, :, None, :], (b, L, n_heads, mla.rope_head_dim)
    )
    k_full = jnp.concatenate([k_nope, k_rope_h], axis=-1)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    out = decode_attention(q_full, k_full, v, lengths + 1)
    out = out.reshape(b, 1, n_heads * mla.v_head_dim) @ p["wo"].astype(x.dtype)
    return out, {"c_kv": cache_ckv, "k_rope": cache_krope}


def _update_cache(cache: jax.Array, new: jax.Array, lengths: jax.Array) -> jax.Array:
    """Write new (B, 1, ...) at position lengths[b] per batch row."""
    b = cache.shape[0]
    idx = lengths.astype(jnp.int32)
    return cache.at[jnp.arange(b), idx].set(new[:, 0])
