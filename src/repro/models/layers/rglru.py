"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Block structure: gate branch (linear -> GeLU) ∥ recurrent branch (linear ->
causal depthwise conv1d(4) -> RG-LRU) -> elementwise product -> output linear.

RG-LRU recurrence (per channel):
    r_t = σ(W_a ξ_t + b_a)          recurrence gate
    i_t = σ(W_x ξ_t + b_x)          input gate
    log a_t = -c * softplus(Λ) ⊙ r_t           (c = 8)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ ξ_t)

The sequence form runs as a ``jax.lax.associative_scan`` over (a, b) pairs —
O(log S) depth, the TPU-native mapping of a linear recurrence. Decode is the
single-step update on an (B, R) state + conv ring buffer.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ShardCtx, dense_init, shard

__all__ = ["init_rglru", "rglru_train", "rglru_decode", "init_rglru_state"]

_C = 8.0


def init_rglru(key, d_model: int, r_dim: int, d_conv: int = 4) -> dict:
    ks = jax.random.split(key, 6)
    return {
        "w_gate": dense_init(ks[0], (d_model, r_dim)),
        "w_in": dense_init(ks[1], (d_model, r_dim)),
        "conv_w": dense_init(ks[2], (d_conv, r_dim)),
        "w_a": dense_init(ks[3], (r_dim, r_dim)),
        "b_a": jnp.zeros((r_dim,), jnp.float32),
        "w_x": dense_init(ks[4], (r_dim, r_dim)),
        "b_x": jnp.zeros((r_dim,), jnp.float32),
        # Λ init so that softplus(Λ) gives a ~ U(0.9, 0.999) at r=1 (paper)
        "lam": jnp.full((r_dim,), 0.7, jnp.float32),
        "w_out": dense_init(ks[5], (r_dim, d_model)),
    }


def _causal_conv(u: jax.Array, w: jax.Array) -> jax.Array:
    k = w.shape[0]
    up = jnp.pad(u, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(u)
    s = u.shape[1]
    for i in range(k):
        out = out + up[:, i : i + s, :] * w[i][None, None, :]
    return out


def _gates(p, xi):
    r = jax.nn.sigmoid(xi @ p["w_a"].astype(xi.dtype) + p["b_a"].astype(xi.dtype))
    i = jax.nn.sigmoid(xi @ p["w_x"].astype(xi.dtype) + p["b_x"].astype(xi.dtype))
    log_a = (-_C * jax.nn.softplus(p["lam"].astype(jnp.float32))) * r.astype(jnp.float32)
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (
        i.astype(jnp.float32) * xi.astype(jnp.float32)
    )
    return a, b


def rglru_train(
    p: dict,
    x: jax.Array,
    ctx: ShardCtx | None = None,
    initial_state: jax.Array | None = None,
    return_state: bool = False,
):
    """(B, S, D) -> (B, S, D) [+ state (B, R) and conv tail]."""
    dt = x.dtype
    gate = jax.nn.gelu(x @ p["w_gate"].astype(dt))
    xi_pre = x @ p["w_in"].astype(dt)
    xi = _causal_conv(xi_pre, p["conv_w"].astype(dt))
    xi = shard(ctx, xi, ("dp", None, "tp"))
    a, b = _gates(p, xi)

    def combine(lhs, rhs):
        a_l, b_l = lhs
        a_r, b_r = rhs
        return a_l * a_r, b_l * a_r + b_r

    cum_a, cum_b = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = cum_b
    if initial_state is not None:
        h = h + cum_a * initial_state[:, None, :].astype(jnp.float32)
    out = (gate.astype(jnp.float32) * h).astype(dt) @ p["w_out"].astype(dt)
    if return_state:
        d_conv = p["conv_w"].shape[0]
        return out, {"h": h[:, -1, :], "conv": xi_pre[:, -(d_conv - 1) :, :]}
    return out


def init_rglru_state(batch: int, r_dim: int, d_conv: int = 4, dtype=jnp.float32) -> dict:
    return {
        "h": jnp.zeros((batch, r_dim), jnp.float32),
        "conv": jnp.zeros((batch, d_conv - 1, r_dim), dtype),
    }


def rglru_decode(p: dict, x: jax.Array, cache: dict, ctx: ShardCtx | None = None):
    """One-step decode: x (B, 1, D) -> (B, 1, D), updated cache."""
    dt = x.dtype
    gate = jax.nn.gelu(x @ p["w_gate"].astype(dt))  # (B,1,R)
    xi_pre = x @ p["w_in"].astype(dt)  # (B,1,R)
    window = jnp.concatenate([cache["conv"].astype(dt), xi_pre], axis=1)  # (B,K,R)
    w = p["conv_w"].astype(dt)
    xi = jnp.einsum("bkr,kr->br", window, w)[:, None, :]
    a, b = _gates(p, xi)  # (B,1,R) f32
    h_new = a[:, 0] * cache["h"].astype(jnp.float32) + b[:, 0]
    out = (gate.astype(jnp.float32) * h_new[:, None, :]).astype(dt) @ p["w_out"].astype(dt)
    return out, {"h": h_new, "conv": window[:, 1:, :]}
