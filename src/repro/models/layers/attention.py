"""Memory-efficient attention cores (pure JAX, scan-based).

Three paths, all GQA-aware (query heads grouped over KV heads):

* :func:`chunked_attention` — online-softmax double scan over (q blocks ×
  kv blocks); never materialises an (S, S) score matrix. Used for train and
  prefill of *global* layers. Causal masking is block-exact: strictly-upper
  blocks are skipped arithmetically (their contribution multiplies to zero)
  — FLOP waste relative to a triangular schedule is a known §Perf item.

* :func:`local_attention` — sliding-window attention computed per q-block
  against a static window of kv blocks gathered with ``dynamic_slice``; cost
  is O(S · window), genuinely sub-quadratic (gemma3 local layers,
  recurrentgemma local layers, long-context serving).

* :func:`decode_attention` — single-query attention against a KV cache with
  explicit length masking (and window masking for local layers).

Accumulation is float32 regardless of input dtype.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = ["chunked_attention", "local_attention", "decode_attention"]

_NEG = -1e30


def _group(q: jax.Array, n_kv: int) -> jax.Array:
    """(B, S, H, hd) -> (B, S, KV, G, hd) with H = KV * G."""
    b, s, h, hd = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, hd)


def chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    q_offset: int = 0,
    block_q: int = 1024,
    block_k: int = 1024,
) -> jax.Array:
    """Online-softmax attention. q: (B,Sq,H,hd); k,v: (B,Skv,KV,hd)."""
    b, sq, h, hd = q.shape
    skv, n_kv = k.shape[1], k.shape[2]
    hdv = v.shape[-1]
    scale = hd ** -0.5
    bq = min(block_q, sq)
    bk = min(block_k, skv)
    pad_q = (-sq) % bq
    pad_k = (-skv) % bk
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0))) if pad_q else q
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else k
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else v
    nq, nk = qp.shape[1] // bq, kp.shape[1] // bk

    qg = _group(qp, n_kv)  # (B, Sq, KV, G, hd)
    g = qg.shape[3]
    qb = qg.reshape(b, nq, bq, n_kv, g, hd)
    kb = kp.reshape(b, nk, bk, n_kv, hd)
    vb = vp.reshape(b, nk, bk, n_kv, hdv)

    q_pos_base = jnp.arange(bq)
    k_pos_base = jnp.arange(bk)

    def q_block(qi, q_blk):
        # q_blk: (B, bq, KV, G, hd)
        acc0 = jnp.zeros((b, bq, n_kv, g, hdv), jnp.float32)
        m0 = jnp.full((b, bq, n_kv, g), _NEG, jnp.float32)
        l0 = jnp.zeros((b, bq, n_kv, g), jnp.float32)

        def kv_step(carry, ki):
            acc, m, l = carry
            k_blk = jax.lax.dynamic_index_in_dim(kb, ki, 1, keepdims=False)
            v_blk = jax.lax.dynamic_index_in_dim(vb, ki, 1, keepdims=False)
            s = jnp.einsum(
                "bqkgd,bckd->bqkgc", q_blk.astype(jnp.float32), k_blk.astype(jnp.float32)
            ) * scale  # (B, bq, KV, G, bk)
            qpos = q_offset + qi * bq + q_pos_base  # (bq,)
            kpos = ki * bk + k_pos_base  # (bk,)
            mask = kpos[None, :] <= qpos[:, None] if causal else jnp.ones((bq, bk), bool)
            mask = mask & (kpos[None, :] < skv)  # kv padding
            s = jnp.where(mask[None, :, None, None, :], s, _NEG)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bqkgc,bckd->bqkgd", p, v_blk.astype(jnp.float32)
            )
            l = l * alpha + p.sum(axis=-1)
            return (acc, m_new, l), None

        (acc, m, l), _ = jax.lax.scan(kv_step, (acc0, m0, l0), jnp.arange(nk))
        return acc / jnp.maximum(l[..., None], 1e-37)

    out = jax.lax.map(lambda args: q_block(*args), (jnp.arange(nq), qb.swapaxes(0, 1)))
    # out: (nq, B, bq, KV, G, hd) -> (B, Sq, H, hd)
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(b, nq * bq, h, hdv)
    return out[:, :sq].astype(q.dtype)


def local_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    window: int,
    q_offset: int = 0,
    block: int | None = None,
) -> jax.Array:
    """Sliding-window causal attention, O(S * window).

    Each q block attends to the kv blocks covering [pos - window + 1, pos].
    """
    b, sq, h, hd = q.shape
    skv, n_kv = k.shape[1], k.shape[2]
    scale = hd ** -0.5
    blk = block or min(max(window // 2, 128), 1024)
    blk = min(blk, sq)
    pad_q = (-sq) % blk
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0))) if pad_q else q
    nq = qp.shape[1] // blk
    # kv span per q block: window + blk rounded up to blocks
    span = ((window + blk - 1) // blk + 1) * blk
    # left-pad by span (so the first block's slice is in range) and right-pad
    # by pad_q (so padded q blocks never force dynamic_slice clamping, which
    # would silently shift positions).
    kp = jnp.pad(k, ((0, 0), (span, pad_q), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (span, pad_q), (0, 0), (0, 0)))

    qg = _group(qp, n_kv)
    g = qg.shape[3]
    qb = qg.reshape(b, nq, blk, n_kv, g, hd)

    def q_block(qi, q_blk):
        q_end = q_offset + (qi + 1) * blk  # one past the last absolute q pos
        # unpadded kv start = q_end - span; +span for the left pad = q_end
        start = q_end
        k_span = jax.lax.dynamic_slice_in_dim(kp, start, span, axis=1)
        v_span = jax.lax.dynamic_slice_in_dim(vp, start, span, axis=1)
        s = jnp.einsum(
            "bqkgd,bckd->bqkgc", q_blk.astype(jnp.float32), k_span.astype(jnp.float32)
        ) * scale
        qpos = q_offset + qi * blk + jnp.arange(blk)  # absolute q positions
        kpos = (q_end - span) + jnp.arange(span)  # absolute kv positions (may be <0 = pad)
        valid = (
            (kpos[None, :] <= qpos[:, None])
            & (kpos[None, :] > qpos[:, None] - window)
            & (kpos[None, :] >= 0)
            & (kpos[None, :] < skv)
        )
        s = jnp.where(valid[None, :, None, None, :], s, _NEG)
        m = s.max(axis=-1, keepdims=True)
        p = jnp.exp(s - m)
        o = jnp.einsum("bqkgc,bckd->bqkgd", p, v_span.astype(jnp.float32))
        return o / jnp.maximum(p.sum(axis=-1)[..., None], 1e-37)

    out = jax.lax.map(lambda args: q_block(*args), (jnp.arange(nq), qb.swapaxes(0, 1)))
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(b, nq * blk, h, hd)
    return out[:, :sq].astype(q.dtype)


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    lengths: jax.Array,
    *,
    window: int = 0,
    ring_offset: jax.Array | None = None,
) -> jax.Array:
    """Single-step attention against a cache.

    q: (B, 1, H, hd); k/v_cache: (B, L, KV, hd); lengths: (B,) valid entries
    (cache positions < lengths are attended). For windowed layers the cache
    is a ring buffer of size L = window: all L slots are valid once full and
    recency masking is positional via ``lengths`` only.
    """
    b, _, h, hd = q.shape
    L, n_kv = k_cache.shape[1], k_cache.shape[2]
    hdv = v_cache.shape[-1]
    scale = hd ** -0.5
    qg = _group(q, n_kv)[:, 0]  # (B, KV, G, hd)
    s = jnp.einsum(
        "bkgd,blkd->bkgl", qg.astype(jnp.float32), k_cache.astype(jnp.float32)
    ) * scale
    slot = jnp.arange(L)[None, :]  # (1, L)
    valid = slot < lengths[:, None]
    if window:
        valid = valid & (slot >= lengths[:, None] - window)
    s = jnp.where(valid[:, None, None, :], s, _NEG)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    o = jnp.einsum("bkgl,blkd->bkgd", p, v_cache.astype(jnp.float32))
    o = o / jnp.maximum(p.sum(axis=-1)[..., None], 1e-37)
    return o.reshape(b, 1, h, hdv).astype(q.dtype)
