"""Rotary position embeddings (RoPE), half-split formulation."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["rope_freqs", "apply_rope"]


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """(head_dim//2,) inverse frequencies."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotate ``x`` (..., S, H, hd) by position; positions (..., S) int.

    Half-split convention: pairs are (x[..., :hd/2], x[..., hd/2:]).
    """
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)  # (hd/2,)
    ang = positions.astype(jnp.float32)[..., None] * inv  # (..., S, hd/2)
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, hd/2) broadcast over heads
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
