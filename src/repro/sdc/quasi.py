"""Statistical disclosure control application layer (paper §1, §1.1).

Wraps the miner into the quasi-identifier workflow the paper motivates with
the AOL incident: given a categorical table, report every minimal attribute
combination occurring ≤ τ times — the quasi-identifiers — plus k-anonymity
risk summaries, and the grouping transform of §1.1 (bucket values so each
value occurs at least k times).

Record-level numbers (``unique_records`` and the risk fields of
``report_as_dict``) are served by the privacy coverage engine
(``repro.privacy.risk`` over the ``kernels.coverage`` kernels) — the old
per-itemset Python loops remain only as thin signature-compatible wrappers.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..core import KyivConfig, MiningResult, mine

__all__ = [
    "QuasiIdentifierReport",
    "find_quasi_identifiers",
    "k_anonymize_columns",
    "report_as_dict",
]


@dataclasses.dataclass
class QuasiIdentifierReport:
    result: MiningResult
    tau: int
    kmax: int
    _profile: "object | None" = dataclasses.field(
        default=None, repr=False, compare=False
    )

    @property
    def n_quasi_identifiers(self) -> int:
        return len(self.result.itemsets)

    def profile(self):
        """The record-level :class:`repro.privacy.risk.RiskProfile`, computed
        once through the coverage kernels (placement from the mining config)."""
        if self._profile is None:
            from ..privacy.risk import risk_profile

            self._profile = risk_profile(self.result)
        return self._profile

    def by_size(self) -> dict[int, int]:
        out: dict[int, int] = {}
        for ids, _ in self.result.itemsets:
            out[len(ids)] = out.get(len(ids), 0) + 1
        return out

    def risky_columns(self) -> dict[int, int]:
        """How many quasi-identifiers touch each column — prioritises masking."""
        table = self.result.prep.table
        if not self.result.itemsets:
            return {}
        ids = np.fromiter(
            (i for itemset, _ in self.result.itemsets for i in itemset),
            dtype=np.int64,
        )
        counts = np.bincount(table.col[ids], minlength=table.n_cols)
        return {int(c): int(n) for c, n in enumerate(counts) if n}

    def unique_records(self) -> int:
        """Rows pinpointed by at least one τ-infrequent combination (thin
        wrapper over the coverage engine's record counts)."""
        return self.profile().records_at_risk


def find_quasi_identifiers(
    dataset: np.ndarray, tau: int = 1, kmax: int = 3, **config_kw
) -> QuasiIdentifierReport:
    res = mine(dataset, KyivConfig(tau=tau, kmax=kmax, **config_kw))
    return QuasiIdentifierReport(result=res, tau=tau, kmax=kmax)


def report_as_dict(report: QuasiIdentifierReport, *, top: int = 10) -> dict:
    """JSON-serialisable summary of a report — the payload of the resident
    mining service's ``/report`` endpoint."""
    prof = report.profile()
    return {
        "tau": report.tau,
        "kmax": report.kmax,
        "n_quasi_identifiers": report.n_quasi_identifiers,
        "by_size": {str(k): v for k, v in sorted(report.by_size().items())},
        "risky_columns": {str(k): v for k, v in sorted(report.risky_columns().items())},
        "unique_records": report.unique_records(),
        "top_risk_records": prof.top_records(top),
        "risk_histogram": prof.histogram(),
        "n_rows": report.result.prep.table.n_rows,
    }


def k_anonymize_columns(dataset: np.ndarray, k: int = 5, seed: int = 0) -> np.ndarray:
    """§1.1 grouping transform: per column, bucket values occurring < k times
    into groups of >= k occurrences (values are replaced by a group id)."""
    rng = np.random.default_rng(seed)
    out = np.array(dataset, copy=True)
    n, m = out.shape
    for j in range(m):
        uniq, inv, counts = np.unique(out[:, j], return_inverse=True, return_counts=True)
        rare = np.nonzero(counts < k)[0]
        if len(rare) == 0:
            continue
        order = rng.permutation(rare)
        group_of = np.arange(len(uniq))
        # pack rare values into buckets whose total occurrence count >= k
        bucket, bucket_count, next_gid = [], 0, len(uniq)
        for v in order:
            bucket.append(v)
            bucket_count += counts[v]
            if bucket_count >= k:
                for b in bucket:
                    group_of[b] = next_gid
                next_gid += 1
                bucket, bucket_count = [], 0
        for b in bucket:  # leftover: merge into the last bucket
            group_of[b] = next_gid - 1 if next_gid > len(uniq) else len(uniq)
        out[:, j] = group_of[inv]
    return out
