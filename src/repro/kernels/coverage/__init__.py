from .coverage import coverage_accumulate_indexed
from .ops import (
    EXEC_CACHE,
    CoverageEngine,
    build_coverage_dispatch,
    coverage_cache_stats,
    reset_coverage_cache,
)
from .ref import (
    acc_to_record_counts,
    coverage_accumulate_host,
    coverage_accumulate_ref,
)

__all__ = [
    "coverage_accumulate_indexed",
    "coverage_accumulate_host",
    "coverage_accumulate_ref",
    "acc_to_record_counts",
    "CoverageEngine",
    "build_coverage_dispatch",
    "coverage_cache_stats",
    "reset_coverage_cache",
    "EXEC_CACHE",
]
