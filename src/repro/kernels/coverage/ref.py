"""Reference engines for the record-coverage accumulation.

The coverage primitive consumed by the privacy risk engine: given the item
bitset matrix ``bits (t, W) uint32``, a batch of itemsets ``sets (M, K)
int32`` (rows of item indices, short itemsets padded by *repeating* an index
— AND with itself is the identity) and per-set integer ``weights (M,)``
(padding rows carry weight 0), produce the accumulator

    acc[b, w] = sum_m weights[m] * bit b of (AND_t bits[sets[m, t]])[w]

i.e. for every record ``r = w * 32 + b``, how many (weighted) itemsets of
the batch cover record ``r``. The ``(32, W)`` layout is the kernel-native
form — per-*word-block* accumulation instead of a scalar per-record scatter
— and converts to per-record counts with :func:`acc_to_record_counts`.

``coverage_accumulate_host`` is the numpy ground truth every engine and
placement is property-tested bit-identical against;
``coverage_accumulate_ref`` is the identical jnp computation (jit it once at
the call site, see ``ops``).
"""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from ..intersect.ops import _popcount_rows

__all__ = [
    "coverage_accumulate_host",
    "coverage_accumulate_ref",
    "acc_to_record_counts",
]


def _batched_rows(sub: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Set-bit rows of every row of a (A, W) uint32 matrix, in one pass.

    Returns ``(rows, counts)``: ``rows`` holds each matrix row's set-bit
    indices ascending, concatenated in row order; ``counts[i]`` how many
    belong to row i. Only the nonzero *words* are unpacked, so cost is
    O(A * W) scan + O(total set bits) unpack — never a dense (A, W*32)
    boolean expansion.
    """
    nz_i, nz_w = np.nonzero(sub)
    vals = np.ascontiguousarray(sub[nz_i, nz_w]).astype("<u4")
    up = np.unpackbits(vals.view(np.uint8), bitorder="little").reshape(-1, 32)
    pos_r, pos_b = np.nonzero(up)
    rows = nz_w[pos_r] * 32 + pos_b
    counts = np.bincount(nz_i[pos_r], minlength=sub.shape[0]).astype(np.int64)
    return rows, counts


def _accumulate_dense(mask: np.ndarray, wt: np.ndarray) -> np.ndarray:
    """32-bit-plane sweep over a materialised (M, W) mask — mirrors the
    jnp/pallas kernels; the dense fallback and the test oracle's shape."""
    acc = np.empty((32, mask.shape[1]), dtype=np.int32)
    for b in range(32):
        sel = ((mask >> np.uint32(b)) & np.uint32(1)).astype(np.int32)
        acc[b] = (sel * wt[:, None]).sum(axis=0, dtype=np.int32)
    return acc


def coverage_accumulate_host(
    bits: np.ndarray, sets: np.ndarray, weights: np.ndarray
) -> np.ndarray:
    """Numpy engine: (32, W) int32 weighted per-bit coverage counts.

    Two exact paths, picked by how much work each would touch:

    * **anchor enumeration** — a quasi-identifier's record set is no larger
      than its rarest member's, and mined QIs have tiny supports (<= τ for
      emitted ones). Each set is anchored at its minimum-popcount item, only
      the anchor's rows are enumerated, and the other members' membership
      bits are gathered per (set, row) pair — O(sum of anchor supports)
      word lookups instead of O(M * W) full-width ANDs.
    * **bit-plane sweep** — when the anchor supports are not small relative
      to M * W (dense random inputs, huge τ), materialise the AND masks and
      sweep the 32 bit planes, exactly like the jnp/pallas kernels.
    """
    bits = np.asarray(bits, dtype=np.uint32)
    sets = np.asarray(sets)
    wt = np.asarray(weights, dtype=np.int32)
    m, width = sets.shape
    n_words = bits.shape[1]

    item_pc = _popcount_rows(bits)
    anchor_col = np.argmin(item_pc[sets], axis=1)
    anchor_item = sets[np.arange(m), anchor_col]
    total_pairs = int(item_pc[anchor_item].sum())
    if total_pairs * 8 > m * n_words:
        mask = bits[sets[:, 0]]  # fancy index -> fresh array, safe as out=
        for t in range(1, width):
            np.bitwise_and(mask, bits[sets[:, t]], out=mask)
        return _accumulate_dense(mask, wt)

    # anchor path: candidate (set, row) pairs from each set's rarest item
    uniq_anchors, inverse = np.unique(anchor_item, return_inverse=True)
    anchor_rows, anchor_counts = _batched_rows(bits[uniq_anchors])
    offsets = np.cumsum(anchor_counts) - anchor_counts
    counts = anchor_counts[inverse]
    set_idx = np.repeat(np.arange(m), counts)
    # ragged gather: each set's rows are one contiguous anchor_rows range
    within = np.arange(len(set_idx)) - np.repeat(
        np.cumsum(counts) - counts, counts
    )
    row_idx = anchor_rows[np.repeat(offsets[inverse], counts) + within]
    alive = np.ones(len(set_idx), dtype=bool)
    w_idx = row_idx // 32
    b_idx = (row_idx % 32).astype(np.uint32)
    for t in range(width):
        member = sets[set_idx, t]
        check = member != anchor_item[set_idx]  # anchor rows trivially pass
        if not check.any():
            continue
        words = bits[member[check], w_idx[check]]
        alive[check] &= ((words >> b_idx[check]) & np.uint32(1)).astype(bool)
    acc_records = np.zeros(n_words * 32, dtype=np.int32)
    np.add.at(acc_records, row_idx[alive], wt[set_idx[alive]])
    return np.ascontiguousarray(acc_records.reshape(n_words, 32).T)


def coverage_accumulate_ref(bits, sets, weights):
    """jnp oracle — same math as :func:`coverage_accumulate_host`.

    The 32 bit positions unroll statically, so the working set per step is
    one (M, W) int32 temporary, never the (M, 32, W) cube.
    """
    mask = bits[sets[:, 0]]
    for t in range(1, sets.shape[1]):
        mask = jnp.bitwise_and(mask, bits[sets[:, t]])
    wt = weights.astype(jnp.int32)[:, None]
    rows = []
    for b in range(32):
        sel = (jnp.right_shift(mask, jnp.uint32(b)) & jnp.uint32(1)).astype(jnp.int32)
        rows.append(jnp.sum(sel * wt, axis=0))
    return jnp.stack(rows, axis=0)


def acc_to_record_counts(acc: np.ndarray, n_rows: int) -> np.ndarray:
    """Convert a (32, W) accumulator into per-record counts (n_rows,) int64.

    Record ``r`` lives at word ``r // 32``, bit ``r % 32`` — i.e.
    ``acc.T`` flattened row-major is exactly record order.
    """
    acc = np.asarray(acc)
    return acc.T.reshape(-1)[:n_rows].astype(np.int64)
