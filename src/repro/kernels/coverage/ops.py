"""Engine dispatch + batching for the coverage kernels.

Mirrors the structure of ``kernels.intersect.ops`` at a smaller scale: the
engine-specific binding lives in :func:`build_coverage_dispatch` (one bound
callable per executable bucket, shared process-wide through
:data:`EXEC_CACHE` so warm service requests never re-bind), and the generic
orchestration — batch splitting, bucket padding with weight-0 rows,
cross-batch accumulation — lives once in
:class:`CoverageEngine`, which is placement-generic: a
``repro.core.placement.BitsetPlacement`` supplies residency
(``prepare_coverage``) and per-batch execution (``coverage_dispatch``), so
host numpy, single-device jnp/pallas and the word-sharded mesh all serve the
same record-risk queries bit-identically.
"""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from ...core.exec_cache import exec_family
from ...obs import metrics as _om
from ...obs.trace import span as _obs_span
from ..intersect.ops import _largest_divisor_tile
from . import coverage as _k
from .ref import acc_to_record_counts, coverage_accumulate_ref

_COV_BATCHES = _om.counter(
    "repro_coverage_batches_total",
    "Coverage accumulator batches dispatched through the placement.",
)

__all__ = [
    "EXEC_CACHE",
    "CoverageEngine",
    "build_coverage_dispatch",
    "coverage_cache_stats",
    "reset_coverage_cache",
]

# Coverage executables are the ``coverage`` family of the process-wide
# ``repro.core.exec_cache`` registry — one shared cache, per-family counters,
# one ``executables`` section in /stats.
EXEC_CACHE = exec_family("coverage")

_JIT_COVERAGE_REF = None  # bound lazily so importing this module stays cheap


def _jit_coverage_ref():
    global _JIT_COVERAGE_REF
    if _JIT_COVERAGE_REF is None:
        import jax

        _JIT_COVERAGE_REF = jax.jit(coverage_accumulate_ref)
    return _JIT_COVERAGE_REF


def coverage_cache_stats() -> dict:
    """Snapshot of the coverage executable-bucket cache (entries/hits/misses)."""
    return EXEC_CACHE.stats()


def reset_coverage_cache() -> None:
    EXEC_CACHE.clear()


def build_coverage_dispatch(
    engine: str,
    *,
    n_words: int,
    block_words: int,
    interpret: bool,
):
    """Bind one coverage executable bucket for a single-device engine:
    ``fn(bits, sets_j, weights_j) -> acc (32, W) int32`` (device array)."""
    if engine == "jnp":
        fn = _jit_coverage_ref()
        return lambda bits, sets_j, wt_j: fn(bits, sets_j, wt_j)
    if engine != "pallas":
        raise ValueError(f"engine must be jnp|pallas, got {engine!r}")
    bw = _largest_divisor_tile(n_words, block_words)
    return lambda bits, sets_j, wt_j: _k.coverage_accumulate_indexed(
        bits, sets_j, wt_j, block_words=bw, interpret=interpret
    )


class CoverageEngine:
    """Placement-generic batched coverage accumulation over one bitset matrix.

    Construction hands the item bitsets to the placement once
    (``placement.prepare_coverage`` — host array, single-device upload, or
    mesh word-sharding); every :meth:`accumulate` call then ships only the
    (tiny) itemset index batch. ``set_width`` bounds the itemset arity
    (normally ``kmax``); device executables bind per (arity, bucket) — at
    most ``kmax`` times a handful of buckets — so singleton batches never
    pay for k-way gathers.
    """

    def __init__(
        self,
        bits,
        *,
        placement,
        set_width: int,
        max_batch_sets: int | None = None,
    ):
        self.placement = placement
        self.set_width = max(1, int(set_width))
        self.n_words = int(bits.shape[1])
        # cap the per-dispatch working set (M * W int32 temporaries on the
        # jnp path) while keeping batches large enough to amortize dispatch
        self.max_batch_sets = max_batch_sets or max(
            256, (1 << 26) // max(self.n_words, 1)
        )
        self._state = placement.prepare_coverage(bits)

    def accumulate(
        self, sets: np.ndarray, weights: np.ndarray | None = None
    ) -> np.ndarray:
        """Weighted coverage accumulator over a batch of itemsets.

        ``sets`` is (M, k) int with k <= set_width; ``weights`` defaults to
        all-ones. Returns acc (32, n_words) int64, summed across dispatch
        batches.
        """
        sets = np.asarray(sets, dtype=np.int32)
        if sets.ndim != 2 or sets.shape[1] > self.set_width:
            raise ValueError(
                f"sets must be (M, <= {self.set_width}), got shape {sets.shape}"
            )
        m = sets.shape[0]
        total = np.zeros((32, self.n_words), dtype=np.int64)
        if m == 0:
            return total
        wt = (
            np.ones(m, dtype=np.int32)
            if weights is None
            else np.asarray(weights, dtype=np.int32)
        )
        with _obs_span("coverage.accumulate", sets=m):
            for s in range(0, m, self.max_batch_sets):
                chunk = sets[s : s + self.max_batch_sets]
                wchunk = wt[s : s + self.max_batch_sets]
                padded_m = self.placement.padded_size(chunk.shape[0])
                if padded_m != chunk.shape[0]:
                    pad = padded_m - chunk.shape[0]
                    chunk = np.pad(chunk, ((0, pad), (0, 0)), mode="edge")
                    wchunk = np.pad(wchunk, (0, pad))  # weight-0 padding rows
                _COV_BATCHES.inc()
                acc = self.placement.coverage_dispatch(self._state, chunk, wchunk)
                # mesh placements may pad the word axis; the pad words carry
                # no record bits, so slicing back to n_words is lossless
                total += np.asarray(acc)[:, : self.n_words].astype(np.int64)
        return total

    def record_counts(
        self, sets: np.ndarray, n_rows: int, weights: np.ndarray | None = None
    ) -> np.ndarray:
        """Per-record coverage counts (n_rows,) int64 for one itemset batch."""
        return acc_to_record_counts(self.accumulate(sets, weights), n_rows)
