"""Pallas TPU coverage kernel: batched itemset-AND + per-word record-bit
accumulation.

The privacy risk engine asks, for every record, how many quasi-identifiers
cover it. The host formulation is a scalar scatter (expand each QI's row
bitset to indices, bump a counter per row) — exactly the shape of loop the
paper's bitset substrate exists to avoid. This kernel keeps the whole
question in the word domain:

* the itemset batch ``sets (M, K)`` rides in **scalar prefetch** (SMEM),
  like the indexed intersect kernels: each grid step's BlockSpec
  ``index_map`` reads the K item indices of set ``m`` and DMAs exactly those
  K parent bitset rows from HBM into VMEM — the gather is fused into the
  block fetch, no gathered (M, K, W) operand ever exists in HBM;
* the K-way AND produces the set's record mask in VMEM;
* instead of a scalar popcount, the mask is *transposed into bit planes*:
  a ``(32, bw)`` int32 accumulator tile (32 sublanes = the 32 bit positions
  of a word, bw lanes = the word block) accumulates ``(mask >> b) & 1``
  weighted by the set's int32 weight, summed over the M grid steps.

The output ``acc (32, W)`` is the per-record coverage count in word-major
layout (record ``r`` = word ``r // 32``, bit ``r % 32``); padding rows in
the batch carry weight 0 and therefore contribute nothing. The grid is
``(W // bw, M)`` — the set axis iterates fastest, so each output tile is
revisited on consecutive grid steps (the TPU accumulation contract, same as
the word-block loop of the intersect kernels).

Runs under ``interpret=True`` on CPU; the BlockSpecs target real TPU VMEM
tiling (bw a multiple of 128 lanes, the accumulator a full 32-sublane tile).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["coverage_accumulate_indexed"]


def _make_coverage_kernel(n_set_items: int):
    """Kernel body for a K-way AND: arity depends on the (static) set width."""

    def kernel(sets_ref, wt_ref, *refs):
        acc_ref = refs[-1]
        rows = refs[:-1]
        m = pl.program_id(1)
        w = rows[0][0, :]
        for r in rows[1:]:
            w = jnp.bitwise_and(w, r[0, :])

        @pl.when(m == 0)
        def _init():
            acc_ref[...] = jnp.zeros_like(acc_ref)

        bitpos = jax.lax.broadcasted_iota(jnp.uint32, (32, w.shape[0]), 0)
        sel = (jnp.right_shift(w[None, :], bitpos) & jnp.uint32(1)).astype(jnp.int32)
        acc_ref[...] += sel * wt_ref[m]

    return kernel


def _row_spec(t: int, bw: int) -> pl.BlockSpec:
    # one parent bitset row per set item, fetched by scalar-prefetched index
    return pl.BlockSpec((1, bw), lambda j, m, sets, wt, t=t: (sets[m, t], j))


@functools.partial(jax.jit, static_argnames=("block_words", "interpret"))
def coverage_accumulate_indexed(
    bits: jax.Array,
    sets: jax.Array,
    weights: jax.Array,
    *,
    block_words: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """acc[b, w] = sum_m weights[m] * bit b of (AND_t bits[sets[m, t]])[w].

    Args:
      bits: (t, W) uint32 item bitsets in HBM. W % block_words == 0.
      sets: (M, K) int32 item indices; short sets padded by repetition.
      weights: (M,) int32 per-set weight (0 for batch-padding rows).
      block_words: word-dimension VMEM tile (multiple of 128 on real TPU).
    Returns:
      acc (32, W) int32 — per-record coverage counts in word-major layout.
    """
    t, W = bits.shape
    M, K = sets.shape
    bw = min(block_words, W)
    if W % bw:
        raise ValueError(f"W={W} not divisible by block_words={bw}")
    grid = (W // bw, M)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[_row_spec(t_, bw) for t_ in range(K)],
        out_specs=[pl.BlockSpec((32, bw), lambda j, m, sets, wt: (0, j))],
    )
    (acc,) = pl.pallas_call(
        _make_coverage_kernel(K),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((32, W), jnp.int32)],
        interpret=interpret,
    )(sets.astype(jnp.int32), weights.astype(jnp.int32), *([bits] * K))
    return acc
