"""Group-tiled count kernel — the beyond-paper optimization of the Kyiv
bottleneck.

Baseline analysis (EXPERIMENTS.md §Perf): the k = k_max count-only step is
HBM-bound — every candidate pair fetches its two parent bitset rows, so
traffic is ``2·M·W·4`` bytes for M pairs even though only ``t·W·4`` bytes of
distinct parent data exist (each parent participates in ~g pairs within its
prefix group).

This kernel exploits the prefix-group structure *created by the paper's own
BFS*: candidate pairs at a level are exactly the within-group pairs, so they
tile into (bm × bm) block-pairs of parent rows. Each grid step loads two
row blocks into VMEM **once** and emits the full bm×bm popcount cross
matrix:

    traffic_tiled  ≈ 2·(g/bm)²·bm·W·4 = traffic_pairwise / (bm/2)

i.e. an ~bm/2× cut of the dominant roofline term (bm = 8 default → 4×;
validated against the dry-run in the §Perf log). FLOPs are unchanged — each
pair's AND+popcount happens exactly once.

Layout contract: the caller supplies a *group-aligned* parent matrix (each
prefix group zero-padded to a multiple of bm — ``build_group_tiles``), so
BlockSpec indices stay block-aligned. Zero padding rows yield zero counts
and are masked by the caller.
"""

from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["intersect_count_tiled", "build_group_tiles", "counts_from_tiles"]


def _tiled_kernel(ti_ref, tj_ref, a_ref, b_ref, cnt_ref, *, bm: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    b = b_ref[...]  # (bm, bw)
    for i in range(bm):  # static unroll: row i of A against all rows of B
        w = jnp.bitwise_and(a_ref[i, :][None, :], b)
        pc = jnp.sum(jax.lax.population_count(w).astype(jnp.int32), axis=1)
        cnt_ref[0, i, :] += pc


@functools.partial(jax.jit, static_argnames=("block_rows", "block_words", "interpret"))
def intersect_count_tiled(
    bits: jax.Array,
    tile_i: jax.Array,
    tile_j: jax.Array,
    *,
    block_rows: int = 8,
    block_words: int = 1024,
    interpret: bool = False,
) -> jax.Array:
    """Popcount cross-matrices for block-pairs of parent rows.

    bits: (t, W) uint32, t % block_rows == 0 (group-aligned, zero-padded).
    tile_i/tile_j: (T,) int32 *block* indices (row block r covers rows
    [r*block_rows, (r+1)*block_rows)).
    Returns (T, bm, bm) int32: counts[t, a, b] = |rows(tile_i[t]*bm+a) ∩
    rows(tile_j[t]*bm+b)|.
    """
    t, W = bits.shape
    bm = block_rows
    if t % bm:
        raise ValueError(f"t={t} not group-aligned to block_rows={bm}")
    bw = min(block_words, W)
    if W % bw:
        raise ValueError(f"W={W} not divisible by block_words={bw}")
    T = tile_i.shape[0]
    grid = (T, W // bw)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bw), lambda tt, j, ti, tj: (ti[tt], j)),
            pl.BlockSpec((bm, bw), lambda tt, j, ti, tj: (tj[tt], j)),
        ],
        out_specs=[
            pl.BlockSpec((1, bm, bm), lambda tt, j, ti, tj: (tt, 0, 0)),
        ],
    )
    cnt = pl.pallas_call(
        functools.partial(_tiled_kernel, bm=bm),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((T, bm, bm), jnp.int32)],
        interpret=interpret,
    )(tile_i.astype(jnp.int32), tile_j.astype(jnp.int32), bits, bits)[0]
    return cnt


def build_group_tiles(group_sizes: np.ndarray, bm: int = 8):
    """Group-aligned layout + tile list for a level's prefix groups.

    Returns:
      row_map: (t_padded,) original row index per padded row (-1 = padding)
      tile_i, tile_j: (T,) block indices (upper-triangular block pairs)
    """
    block_starts = []
    total_padded = 0
    for g in np.asarray(group_sizes, dtype=np.int64):
        padded = -(-g // bm) * bm
        block_starts.append((total_padded // bm, padded // bm, int(g)))
        total_padded += padded
    out_map = np.full(total_padded, -1, dtype=np.int64)
    cursor = 0
    for start_block, nb, g in block_starts:
        pos = start_block * bm
        out_map[pos : pos + g] = np.arange(cursor, cursor + g)
        cursor += g
    tiles_i, tiles_j = [], []
    for start_block, nb, g in block_starts:
        for a in range(nb):
            for b in range(a, nb):
                tiles_i.append(start_block + a)
                tiles_j.append(start_block + b)
    return (
        out_map,
        np.asarray(tiles_i, dtype=np.int32),
        np.asarray(tiles_j, dtype=np.int32),
    )


def counts_from_tiles(
    cnt_tiles: np.ndarray,
    tile_i: np.ndarray,
    tile_j: np.ndarray,
    row_map: np.ndarray,
    bm: int = 8,
):
    """Flatten tile cross-matrices back to (pair -> count) for the valid
    within-group pairs (i < j, both real rows). Returns (pairs (M,2) original
    row ids, counts (M,))."""
    pairs, counts = [], []
    for t in range(cnt_tiles.shape[0]):
        bi, bj = int(tile_i[t]), int(tile_j[t])
        for a in range(bm):
            ra = row_map[bi * bm + a]
            if ra < 0:
                continue
            for b in range(bm):
                rb = row_map[bj * bm + b]
                if rb < 0 or rb <= ra:
                    continue
                pairs.append((ra, rb))
                counts.append(int(cnt_tiles[t, a, b]))
    return np.asarray(pairs, dtype=np.int64).reshape(-1, 2), np.asarray(counts, dtype=np.int64)
