"""Pure-jnp oracle for the bitset intersection kernels.

``R_W = R_I ∩ R_J`` on bitset rows is a bitwise AND; ``|R_W|`` is a popcount
reduce. These references define the exact semantics the Pallas kernels must
reproduce (tests sweep shapes/dtypes and assert exact equality — the op is
integer, so tolerance is zero).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "intersect_pairs_ref",
    "intersect_count_ref",
    "intersect_gathered_ref",
    "popcount_rows_ref",
    "classify_counts_ref",
    "intersect_classify_ref",
    "intersect_classify_count_ref",
    "CLASS_SKIP",
    "CLASS_EMIT",
    "CLASS_STORE",
]

# Per-pair class codes of the fused intersect-classify step (Alg. 1 lines
# 32-41). SKIP = absent (|R_W| = 0) or uniform (|R_W| = min parent count, so
# W's row set equals a parent's and W is non-minimal); EMIT = minimal
# τ-infrequent (0 < |R_W| <= τ); STORE = survives to the next level.
CLASS_SKIP = 0
CLASS_EMIT = 1
CLASS_STORE = 2


def popcount_rows_ref(bits: jax.Array) -> jax.Array:
    """(t, W) uint bitsets -> (t,) int32 population counts."""
    return jnp.sum(jax.lax.population_count(bits).astype(jnp.int32), axis=-1)


def intersect_gathered_ref(a: jax.Array, b: jax.Array) -> tuple[jax.Array, jax.Array]:
    """AND + popcount of two aligned (M, W) bitset matrices."""
    child = jnp.bitwise_and(a, b)
    return child, popcount_rows_ref(child)


def intersect_pairs_ref(bits: jax.Array, pairs: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Gather rows ``pairs[:, 0]``/``pairs[:, 1]`` of (t, W) ``bits``, AND, popcount.

    Returns (child_bits (M, W), counts (M,) int32).
    """
    a = bits[pairs[:, 0]]
    b = bits[pairs[:, 1]]
    return intersect_gathered_ref(a, b)


def intersect_count_ref(bits: jax.Array, pairs: jax.Array) -> jax.Array:
    """Count-only variant (k = k_max path): no child bitset is produced."""
    a = bits[pairs[:, 0]]
    b = bits[pairs[:, 1]]
    return popcount_rows_ref(jnp.bitwise_and(a, b))


def classify_counts_ref(counts: jax.Array, minp: jax.Array, tau: jax.Array) -> jax.Array:
    """Alg. 1 lines 32-41 on device: counts + min parent counts -> class codes.

    ``minp`` is ``min(|R_I|, |R_J|)`` per pair; ``tau`` a scalar (traced, so
    one executable serves every threshold).
    """
    counts = counts.astype(jnp.int32)
    minp = minp.astype(jnp.int32)
    skip = (counts == 0) | (counts == minp)
    emit = jnp.logical_not(skip) & (counts <= jnp.asarray(tau, jnp.int32))
    return jnp.where(skip, CLASS_SKIP, jnp.where(emit, CLASS_EMIT, CLASS_STORE)).astype(
        jnp.int32
    )


def intersect_classify_ref(
    bits: jax.Array, pairs: jax.Array, parent_counts: jax.Array, tau: jax.Array
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused oracle: child bitsets + popcounts + per-pair class codes."""
    child, counts = intersect_pairs_ref(bits, pairs)
    minp = jnp.minimum(parent_counts[pairs[:, 0]], parent_counts[pairs[:, 1]])
    return child, counts, classify_counts_ref(counts, minp, tau)


def intersect_classify_count_ref(
    bits: jax.Array, pairs: jax.Array, parent_counts: jax.Array, tau: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Fused count-only oracle (k = k_max): no child bitset is produced."""
    counts = intersect_count_ref(bits, pairs)
    minp = jnp.minimum(parent_counts[pairs[:, 0]], parent_counts[pairs[:, 1]])
    return counts, classify_counts_ref(counts, minp, tau)
