"""Pure-jnp oracle for the bitset intersection kernels.

``R_W = R_I ∩ R_J`` on bitset rows is a bitwise AND; ``|R_W|`` is a popcount
reduce. These references define the exact semantics the Pallas kernels must
reproduce (tests sweep shapes/dtypes and assert exact equality — the op is
integer, so tolerance is zero).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "intersect_pairs_ref",
    "intersect_count_ref",
    "intersect_gathered_ref",
    "popcount_rows_ref",
]


def popcount_rows_ref(bits: jax.Array) -> jax.Array:
    """(t, W) uint bitsets -> (t,) int32 population counts."""
    return jnp.sum(jax.lax.population_count(bits).astype(jnp.int32), axis=-1)


def intersect_gathered_ref(a: jax.Array, b: jax.Array) -> tuple[jax.Array, jax.Array]:
    """AND + popcount of two aligned (M, W) bitset matrices."""
    child = jnp.bitwise_and(a, b)
    return child, popcount_rows_ref(child)


def intersect_pairs_ref(bits: jax.Array, pairs: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Gather rows ``pairs[:, 0]``/``pairs[:, 1]`` of (t, W) ``bits``, AND, popcount.

    Returns (child_bits (M, W), counts (M,) int32).
    """
    a = bits[pairs[:, 0]]
    b = bits[pairs[:, 1]]
    return intersect_gathered_ref(a, b)


def intersect_count_ref(bits: jax.Array, pairs: jax.Array) -> jax.Array:
    """Count-only variant (k = k_max path): no child bitset is produced."""
    a = bits[pairs[:, 0]]
    b = bits[pairs[:, 1]]
    return popcount_rows_ref(jnp.bitwise_and(a, b))
