"""Jit'd public wrappers around the intersection kernels, with engine selection
and bucket padding.

The mining driver calls :func:`intersect_and_count` with ragged pair lists;
this module pads to shape buckets (so device executables are reused across
levels), dispatches to one of the engines and strips padding:

* ``numpy``  — host vectorised ``np.bitwise_and`` + ``np.bitwise_count``;
  fastest on this CPU-only container, used by the wall-clock benchmarks.
* ``jnp``    — the jnp oracle under jit (XLA CPU/TPU).
* ``pallas`` — the Pallas kernels (``interpret=True`` on CPU; compiled on TPU).

Padding contract: pair index rows added for padding point at row 0 twice; the
returned arrays are sliced back to the true count, so callers never observe
padding.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from . import intersect as _k
from . import ref as _ref

__all__ = ["intersect_and_count", "next_bucket", "ENGINES"]

ENGINES = ("numpy", "jnp", "pallas")

_MIN_BUCKET = 256


def next_bucket(m: int, minimum: int = _MIN_BUCKET) -> int:
    """Smallest power-of-two bucket >= m (>= minimum) — bounds executable count."""
    b = minimum
    while b < m:
        b <<= 1
    return b


def _pad_pairs(pairs: np.ndarray, bucket: int) -> np.ndarray:
    m = pairs.shape[0]
    if m == bucket:
        return pairs
    out = np.zeros((bucket, 2), dtype=pairs.dtype)
    out[:m] = pairs
    return out


def intersect_and_count(
    bits,
    pairs: np.ndarray,
    *,
    write_children: bool,
    engine: str = "numpy",
    interpret: bool = True,
    indexed: bool = True,
    block_pairs: int = 8,
    block_words: int = 512,
    pad_buckets: bool = True,
):
    """Compute ``child = bits[i] & bits[j]`` and/or ``counts = |child|``.

    Args:
      bits: (t, W) uint32 parent bitsets (numpy or jax array).
      pairs: (M, 2) integer row indices.
      write_children: False selects the count-only k=k_max path.
      engine: one of ``numpy`` / ``jnp`` / ``pallas``.
      interpret: Pallas interpret mode (True on CPU).
      indexed: Pallas path — scalar-prefetch gather (True) vs pre-gathered.
    Returns:
      (child (M, W) uint32 | None, counts (M,) int64 numpy array)
    """
    if engine not in ENGINES:
        raise ValueError(f"engine must be one of {ENGINES}, got {engine!r}")
    m = int(pairs.shape[0])
    if m == 0:
        W = bits.shape[1]
        empty = np.zeros((0, W), dtype=np.uint32) if write_children else None
        return empty, np.zeros(0, dtype=np.int64)

    if engine == "numpy":
        bits_np = np.asarray(bits)
        a = bits_np[pairs[:, 0]]
        b = bits_np[pairs[:, 1]]
        child = np.bitwise_and(a, b)
        counts = np.bitwise_count(child).sum(axis=1).astype(np.int64)
        return (child if write_children else None), counts

    pairs = np.asarray(pairs, dtype=np.int32)
    bucket = next_bucket(m) if pad_buckets else m
    padded = _pad_pairs(pairs, bucket)
    bits_j = jnp.asarray(bits)
    pairs_j = jnp.asarray(padded)

    if engine == "jnp":
        if write_children:
            child, cnt = jax.jit(_ref.intersect_pairs_ref)(bits_j, pairs_j)
        else:
            child, cnt = None, jax.jit(_ref.intersect_count_ref)(bits_j, pairs_j)
    else:  # pallas
        W = bits_j.shape[1]
        bw = _largest_divisor_tile(W, block_words)
        if indexed:
            if write_children:
                child, cnt = _k.intersect_write_indexed(
                    bits_j, pairs_j, block_words=bw, interpret=interpret
                )
            else:
                child = None
                cnt = _k.intersect_count_indexed(
                    bits_j, pairs_j, block_words=bw, interpret=interpret
                )
        else:
            a = bits_j[pairs_j[:, 0]]
            b = bits_j[pairs_j[:, 1]]
            bm = _largest_divisor_tile(bucket, block_pairs)
            if write_children:
                child, cnt = _k.intersect_write_gathered(
                    a, b, block_pairs=bm, block_words=bw, interpret=interpret
                )
            else:
                child = None
                cnt = _k.intersect_count_gathered(
                    a, b, block_pairs=bm, block_words=bw, interpret=interpret
                )

    counts = np.asarray(cnt)[:m].astype(np.int64)
    child_np = None
    if write_children:
        child_np = np.asarray(child)[:m]
    return child_np, counts


def _largest_divisor_tile(dim: int, preferred: int) -> int:
    """Largest tile <= preferred that divides dim (dims here are powers of two
    times small factors; fall back to scanning)."""
    tile = min(preferred, dim)
    while dim % tile:
        tile -= 1
    return max(tile, 1)
