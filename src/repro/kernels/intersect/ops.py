"""Jit'd public wrappers around the intersection kernels, engine selection,
bucket padding, and the device-resident level pipeline.

The mining driver hands this module ragged pair lists; it pads them to shape
buckets (so device executables are reused across levels and batches),
dispatches to one of the engines and strips padding:

* ``numpy``  — host vectorised ``np.bitwise_and`` + popcount (``np.bitwise_count``
  on numpy>=2.0, an exact ``unpackbits`` fallback otherwise); fastest on this
  CPU-only container, used by the wall-clock benchmarks.
* ``jnp``    — the jnp oracle under jit (XLA CPU/TPU).
* ``pallas`` — the Pallas kernels (``interpret=True`` on CPU; compiled on TPU).

Two dispatch surfaces:

* :func:`intersect_and_count` / :func:`intersect_classify` — one-shot calls.
  The ``classify`` variant is the fused path: it also takes the parent
  popcounts and τ and returns per-pair class codes (``CLASS_SKIP`` /
  ``CLASS_EMIT`` / ``CLASS_STORE``) computed on the engine, so the driver
  never re-derives the classification masks on the host.
* :class:`LevelPipeline` — the batch pipeline used by ``repro.core.kyiv``.
  It is **placement-generic**: a ``repro.core.placement.BitsetPlacement``
  supplies residency (parent bitsets + popcounts placed once per level),
  padding (executable buckets; per-shard blocks on a mesh) and dispatch
  (host numpy, single-device kernels, or shard_map bodies), while this class
  owns the generic orchestration — locality sort, async handles
  (``submit`` returns immediately; blocking only when ``result()`` converts
  to numpy), padding strips and inverse permutation. Host candidate
  generation / support tests of batch *n+1* therefore overlap the device
  intersection of batch *n* when the driver double-buffers. Engine-specific
  kernel binding lives in :func:`build_engine_dispatch` (bound once per
  bucket shape through :data:`EXEC_CACHE`); on accelerator backends the
  gathered write path donates its gathered operand so XLA aliases the child
  output onto it.

Locality-aware pair scheduling: :func:`locality_order` sorts a batch's pairs
by ``(i, j)`` so the indexed kernel's scalar-prefetch DMA re-fetches each
parent row once per *run* of equal ``i`` instead of once per pair; outputs
are un-permuted before the caller sees them. The default candidate generator
already emits ``i``-sorted batches, so the common case is a single O(M)
monotonicity check — the sort only triggers for externally supplied pair
lists (sharded re-balancing, resumed checkpoints, tests).

Padding contract: pair index rows added for padding point at row 0 twice; a
self-pair is *uniform* (count == min parent count), so fused classify marks
padding ``CLASS_SKIP``. All returned arrays are sliced back to the true
count, so callers never observe padding either way.
"""

from __future__ import annotations

import math

import numpy as np

import jax
import jax.numpy as jnp

from . import intersect as _k
from . import ref as _ref
from ...obs import metrics as _om

_PIPE_BATCHES = _om.counter(
    "repro_intersect_batches_total",
    "Pair batches dispatched through the level pipeline.",
    ("mode",),
)
_PIPE_PAIRS = _om.counter(
    "repro_intersect_pairs_total",
    "Pairs dispatched through the level pipeline (padding included for "
    "mode=padded).",
    ("mode",),
)
_LEVELS_RETIRED = _om.counter(
    "repro_intersect_levels_retired_total",
    "Level residencies eagerly retired by the driver.",
)
from .ref import CLASS_EMIT, CLASS_SKIP, CLASS_STORE

__all__ = [
    "intersect_and_count",
    "intersect_classify",
    "classify_counts_host",
    "build_engine_dispatch",
    "locality_order",
    "next_bucket",
    "LevelPipeline",
    "BatchHandle",
    "ENGINES",
    "ExecutableCache",
    "EXEC_CACHE",
    "executable_cache_stats",
    "reset_executable_cache",
    "CLASS_SKIP",
    "CLASS_EMIT",
    "CLASS_STORE",
]

ENGINES = ("numpy", "jnp", "pallas")

_MIN_BUCKET = 256

# numpy<2.0 has no bitwise_count; degrade to an exact unpackbits popcount
# (mirrors repro.core.bitops, duplicated here because kernels must not
# import core — core imports kernels).
if hasattr(np, "bitwise_count"):

    def _popcount_rows(words: np.ndarray) -> np.ndarray:
        return np.bitwise_count(words).sum(axis=-1).astype(np.int64)

else:

    def _popcount_rows(words: np.ndarray) -> np.ndarray:
        words = np.ascontiguousarray(words)
        u8 = words.view(np.uint8)
        return np.unpackbits(u8, axis=-1).sum(axis=-1, dtype=np.int64)


def next_bucket(m: int, minimum: int = _MIN_BUCKET) -> int:
    """Smallest power-of-two bucket >= m (>= minimum) — bounds executable count."""
    b = minimum
    while b < m:
        b <<= 1
    return b


def _pad_pairs(pairs: np.ndarray, bucket: int) -> np.ndarray:
    m = pairs.shape[0]
    if m == bucket:
        return pairs
    out = np.zeros((bucket, 2), dtype=pairs.dtype)
    out[:m] = pairs
    return out


def _largest_divisor_tile(dim: int, preferred: int) -> int:
    """Largest tile <= preferred that divides dim, in O(sqrt(dim)).

    The old implementation decremented from ``preferred`` until a divisor was
    hit — O(dim) for prime word counts (a 4M-word prime spent milliseconds
    here per dispatch). Fast paths: ``dim <= preferred`` and
    ``gcd(dim, preferred) == preferred``; otherwise enumerate divisor pairs
    up to sqrt(dim) and keep the largest <= preferred.
    """
    if dim <= preferred:
        return max(dim, 1)
    if preferred >= 1 and math.gcd(dim, preferred) == preferred:
        return preferred
    best = 1
    d = 1
    while d * d <= dim:
        if dim % d == 0:
            if d <= preferred and d > best:
                best = d
            co = dim // d
            if co <= preferred and co > best:
                best = co
        d += 1
    return best


def locality_order(pairs: np.ndarray) -> tuple[np.ndarray | None, np.ndarray | None]:
    """Locality-aware pair schedule: stable sort by ``(i, j)``.

    Returns ``(order, inverse)`` such that ``pairs[order]`` is sorted and
    ``out[inverse]`` restores the caller's order, or ``(None, None)`` when the
    pairs are already ``i``-monotone (the common case — the prefix-join
    generator emits sorted batches), so the fast path is one O(M) check.
    """
    i = pairs[:, 0]
    if len(i) < 2 or bool(np.all(i[1:] >= i[:-1])):
        return None, None
    order = np.lexsort((pairs[:, 1], i))
    inverse = np.empty_like(order)
    inverse[order] = np.arange(len(order), dtype=order.dtype)
    return order, inverse


def classify_counts_host(
    counts: np.ndarray, minp: np.ndarray, tau: int
) -> np.ndarray:
    """Host reference of the device classification (Alg. 1 lines 32-41)."""
    counts = np.asarray(counts)
    skip = (counts == 0) | (counts == minp)
    emit = ~skip & (counts <= tau)
    return np.where(skip, CLASS_SKIP, np.where(emit, CLASS_EMIT, CLASS_STORE)).astype(
        np.int32
    )


# Module-level jit wrappers: a fresh ``jax.jit(f)`` per call would re-trace;
# binding once keeps the executable cache warm across batches and levels.
_JIT_PAIRS_REF = jax.jit(_ref.intersect_pairs_ref)
_JIT_COUNT_REF = jax.jit(_ref.intersect_count_ref)
_JIT_CLASSIFY_REF = jax.jit(_ref.intersect_classify_ref)
_JIT_CLASSIFY_COUNT_REF = jax.jit(_ref.intersect_classify_count_ref)


def executable_cache_stats() -> dict:
    """Snapshot of this family's executable-bucket cache (entries/hits/
    misses). The cache itself is the ``intersect`` family of the process-wide
    ``repro.core.exec_cache`` registry — one hit/miss surface per kernel
    family, one ``executables`` section in ``/stats``."""
    return EXEC_CACHE.stats()


def reset_executable_cache() -> None:
    EXEC_CACHE.clear()


def intersect_and_count(
    bits,
    pairs: np.ndarray,
    *,
    write_children: bool,
    engine: str = "numpy",
    interpret: bool = True,
    indexed: bool = True,
    block_pairs: int = 8,
    block_words: int = 512,
    pad_buckets: bool = True,
):
    """Compute ``child = bits[i] & bits[j]`` and/or ``counts = |child|``.

    Args:
      bits: (t, W) uint32 parent bitsets (numpy or jax array).
      pairs: (M, 2) integer row indices.
      write_children: False selects the count-only k=k_max path.
      engine: one of ``numpy`` / ``jnp`` / ``pallas``.
      interpret: Pallas interpret mode (True on CPU).
      indexed: Pallas path — scalar-prefetch gather (True) vs pre-gathered.
    Returns:
      (child (M, W) uint32 | None, counts (M,) int64 numpy array)
    """
    if engine not in ENGINES:
        raise ValueError(f"engine must be one of {ENGINES}, got {engine!r}")
    m = int(pairs.shape[0])
    if m == 0:
        W = bits.shape[1]
        empty = np.zeros((0, W), dtype=np.uint32) if write_children else None
        return empty, np.zeros(0, dtype=np.int64)

    if engine == "numpy":
        bits_np = np.asarray(bits)
        a = bits_np[pairs[:, 0]]
        b = bits_np[pairs[:, 1]]
        child = np.bitwise_and(a, b)
        counts = _popcount_rows(child)
        return (child if write_children else None), counts

    pairs = np.asarray(pairs, dtype=np.int32)
    bucket = next_bucket(m) if pad_buckets else m
    padded = _pad_pairs(pairs, bucket)
    bits_j = jnp.asarray(bits)
    pairs_j = jnp.asarray(padded)

    if engine == "jnp":
        if write_children:
            child, cnt = _JIT_PAIRS_REF(bits_j, pairs_j)
        else:
            child, cnt = None, _JIT_COUNT_REF(bits_j, pairs_j)
    else:  # pallas
        W = bits_j.shape[1]
        bw = _largest_divisor_tile(W, block_words)
        if indexed:
            if write_children:
                child, cnt = _k.intersect_write_indexed(
                    bits_j, pairs_j, block_words=bw, interpret=interpret
                )
            else:
                child = None
                cnt = _k.intersect_count_indexed(
                    bits_j, pairs_j, block_words=bw, interpret=interpret
                )
        else:
            a = bits_j[pairs_j[:, 0]]
            b = bits_j[pairs_j[:, 1]]
            bm = _largest_divisor_tile(bucket, block_pairs)
            if write_children:
                child, cnt = _k.intersect_write_gathered(
                    a, b, block_pairs=bm, block_words=bw, interpret=interpret
                )
            else:
                child = None
                cnt = _k.intersect_count_gathered(
                    a, b, block_pairs=bm, block_words=bw, interpret=interpret
                )

    counts = np.asarray(cnt)[:m].astype(np.int64)
    child_np = None
    if write_children:
        child_np = np.asarray(child)[:m]
    return child_np, counts


def intersect_classify(
    bits,
    pairs: np.ndarray,
    parent_counts: np.ndarray,
    *,
    tau: int,
    write_children: bool,
    engine: str = "numpy",
    interpret: bool = True,
    indexed: bool = True,
    block_pairs: int = 8,
    block_words: int = 512,
    pad_buckets: bool = True,
    locality_sort: bool = True,
):
    """Fused intersect + classify: one-shot convenience over :class:`LevelPipeline`.

    Returns ``(child | None, counts (M,) int64, classes (M,) int32)`` with
    classes in {CLASS_SKIP, CLASS_EMIT, CLASS_STORE}.
    """
    pipe = LevelPipeline(
        bits,
        parent_counts,
        tau=tau,
        engine=engine,
        interpret=interpret,
        indexed=indexed,
        block_pairs=block_pairs,
        block_words=block_words,
        pad_buckets=pad_buckets,
        locality_sort=locality_sort,
        fused_classify=True,
    )
    return pipe.submit(pairs, write_children).result()


class BatchHandle:
    """Future-like handle for one dispatched batch.

    ``result()`` blocks (device->host transfer) and returns
    ``(child | None, counts int64, classes int32 | None)`` in the caller's
    original pair order. ``raw()`` returns the placement-native (still
    padded, possibly device-resident) ``(child, counts, classes)`` without
    any host transfer — the device frontier consumes batches this way so
    stored children never leave the device.
    """

    def __init__(self, materialize, raw=None):
        self._materialize = materialize
        self._raw = raw
        self._out = None
        self._done = False

    def result(self):
        if not self._done:
            self._out = self._materialize()
            self._materialize = None
            self._done = True
        return self._out

    def raw(self):
        if self._raw is None:
            raise ValueError("batch was not dispatched with raw outputs")
        return self._raw


def build_engine_dispatch(
    engine: str,
    *,
    indexed: bool,
    fused_classify: bool,
    write_children: bool,
    n_words: int,
    bucket: int,
    block_pairs: int,
    block_words: int,
    interpret: bool,
    donate: bool,
):
    """Bind one executable bucket for a single-device engine: a callable
    ``fn(bits, pairs_j, pc, tau) -> (child | None, cnt, cls | None)``.

    Everything static — engine branch, kernel variant, tile sizes — is
    resolved here, once per bucket shape; ``DevicePlacement`` shares the
    bound closure process-wide through :data:`EXEC_CACHE`.
    """
    if engine == "jnp":
        if fused_classify:
            if write_children:
                return lambda bits, pairs_j, pc, tau: _JIT_CLASSIFY_REF(
                    bits, pairs_j, pc, tau
                )
            return lambda bits, pairs_j, pc, tau: (
                None,
                *_JIT_CLASSIFY_COUNT_REF(bits, pairs_j, pc, tau),
            )
        if write_children:
            return lambda bits, pairs_j, pc, tau: (
                *_JIT_PAIRS_REF(bits, pairs_j),
                None,
            )
        return lambda bits, pairs_j, pc, tau: (
            None,
            _JIT_COUNT_REF(bits, pairs_j),
            None,
        )
    if engine != "pallas":
        raise ValueError(f"engine must be jnp|pallas, got {engine!r}")

    # pallas
    bw = _largest_divisor_tile(n_words, block_words)
    if indexed:
        if fused_classify:
            if write_children:
                return lambda bits, pairs_j, pc, tau: _k.intersect_classify_write_indexed(
                    bits, pairs_j, pc, tau, block_words=bw, interpret=interpret
                )
            return lambda bits, pairs_j, pc, tau: (
                None,
                *_k.intersect_classify_count_indexed(
                    bits, pairs_j, pc, tau, block_words=bw, interpret=interpret
                ),
            )
        if write_children:
            return lambda bits, pairs_j, pc, tau: (
                *_k.intersect_write_indexed(
                    bits, pairs_j, block_words=bw, interpret=interpret
                ),
                None,
            )
        return lambda bits, pairs_j, pc, tau: (
            None,
            _k.intersect_count_indexed(
                bits, pairs_j, block_words=bw, interpret=interpret
            ),
            None,
        )

    # gathered pallas path
    bm = _largest_divisor_tile(bucket, block_pairs)
    if fused_classify:
        if write_children:
            kern = (
                _k.intersect_classify_write_gathered_donating
                if donate
                else _k.intersect_classify_write_gathered
            )

            def dispatch(bits, pairs_j, pc, tau):
                a = bits[pairs_j[:, 0]]
                b = bits[pairs_j[:, 1]]
                minp = jnp.minimum(pc[pairs_j[:, 0]], pc[pairs_j[:, 1]])
                return kern(
                    a, b, minp, tau,
                    block_pairs=bm, block_words=bw, interpret=interpret,
                )

            return dispatch

        def dispatch(bits, pairs_j, pc, tau):
            a = bits[pairs_j[:, 0]]
            b = bits[pairs_j[:, 1]]
            minp = jnp.minimum(pc[pairs_j[:, 0]], pc[pairs_j[:, 1]])
            cnt, cls = _k.intersect_classify_count_gathered(
                a, b, minp, tau,
                block_pairs=bm, block_words=bw, interpret=interpret,
            )
            return None, cnt, cls

        return dispatch
    if write_children:

        def dispatch(bits, pairs_j, pc, tau):
            a = bits[pairs_j[:, 0]]
            b = bits[pairs_j[:, 1]]
            child, cnt = _k.intersect_write_gathered(
                a, b, block_pairs=bm, block_words=bw, interpret=interpret
            )
            return child, cnt, None

        return dispatch

    def dispatch(bits, pairs_j, pc, tau):
        a = bits[pairs_j[:, 0]]
        b = bits[pairs_j[:, 1]]
        cnt = _k.intersect_count_gathered(
            a, b, block_pairs=bm, block_words=bw, interpret=interpret
        )
        return None, cnt, None

    return dispatch


class LevelPipeline:
    """Placement-generic, bucket-padded batch dispatcher for one BFS level.

    Construction hands the parent bitsets and popcounts to the placement
    once (``placement.prepare``); every ``submit`` then ships only the
    (tiny) pair list. Device/mesh placements dispatch asynchronously, so
    the host can generate and support-test the next candidate batch while
    the device intersects the current one; ``BatchHandle.result()`` is the
    only synchronisation point. The host placement computes eagerly inside
    ``submit`` (same contract, no async).

    This class owns only placement-independent orchestration: the empty-batch
    shortcut, locality-aware pair scheduling (+ inverse permutation of the
    outputs), padding to the placement's executable bucket, and stripping
    padding on materialization. Where the bitsets live and how a padded
    batch executes is entirely the placement's business — there are no
    engine-string branches here.

    ``placement`` is any ``repro.core.placement.BitsetPlacement``; passing
    the legacy ``engine=...`` string instead resolves one through
    ``repro.core.placement.make_placement`` (kept so existing callers and
    the ``KyivConfig.engine`` path keep working unchanged).

    With ``fused_classify=True`` the per-pair class codes are produced by the
    placement itself (device classification for jnp/pallas/mesh); with
    ``False`` the handle returns ``classes=None`` and the caller re-derives
    the masks on the host — kept as the comparison baseline for
    ``benchmarks/bench_fused_pipeline.py``.
    """

    def __init__(
        self,
        bits,
        parent_counts,
        *,
        tau: int,
        placement=None,
        engine: str | None = None,
        interpret: bool = True,
        indexed: bool = True,
        fused_classify: bool = True,
        locality_sort: bool = True,
        block_pairs: int = 8,
        block_words: int = 512,
        pad_buckets: bool = True,
    ):
        if placement is None:
            # deferred import: core imports kernels, never the reverse at
            # module scope — this only runs for legacy engine-string callers
            from ...core.placement import make_placement

            placement = make_placement(
                engine or "numpy",
                interpret=interpret,
                indexed=indexed,
                block_pairs=block_pairs,
                block_words=block_words,
            )
        self.placement = placement
        self.tau = int(tau)
        self.fused_classify = fused_classify
        self.locality_sort = locality_sort
        self.pad_buckets = pad_buckets
        self.n_words = int(bits.shape[1])
        self._state = placement.prepare(
            bits, parent_counts, self.tau, fused_classify=fused_classify
        )

    def retire(self) -> None:
        """Eagerly drop this level's prepared residency (device buffers the
        placement uploaded itself — see ``BitsetPlacement.release``). The
        driver calls this once a level's last batch has been consumed, so
        peak device memory tracks the two live levels of a transition
        instead of every parent level mined so far."""
        state, self._state = self._state, None
        if state is not None:
            _LEVELS_RETIRED.inc()
            release = getattr(self.placement, "release", None)
            if release is not None:
                release(state)

    def submit_padded(self, pairs, m: int, write_children: bool) -> BatchHandle:
        """Dispatch one *pre-padded* batch of device-generated pair indices.

        The device frontier hands bucket-padded, locality-ordered pair
        arrays straight from candidate generation — no host ``np.stack``,
        no locality sort, no re-padding. ``m`` is the true pair count for
        ``result()``'s strip; ``raw()`` exposes the padded placement-native
        outputs for device-side partitioning.
        """
        _PIPE_BATCHES.inc(mode="padded")
        _PIPE_PAIRS.inc(int(pairs.shape[0]), mode="padded")
        child_d, cnt_d, cls_d = self.placement.dispatch(self._state, pairs, write_children)
        n_words = self.n_words

        def materialize():
            counts = np.asarray(cnt_d)[:m].astype(np.int64)
            child = np.asarray(child_d)[:m, :n_words] if child_d is not None else None
            classes = np.asarray(cls_d)[:m].astype(np.int32) if cls_d is not None else None
            return child, counts, classes

        return BatchHandle(materialize, raw=(child_d, cnt_d, cls_d))

    def submit(self, pairs: np.ndarray, write_children: bool) -> BatchHandle:
        """Dispatch one batch of pair intersections; non-blocking on device placements."""
        m = int(pairs.shape[0])
        if m == 0:
            W = self.n_words
            child = np.zeros((0, W), dtype=np.uint32) if write_children else None
            classes = np.zeros(0, dtype=np.int32) if self.fused_classify else None
            out = (child, np.zeros(0, dtype=np.int64), classes)
            return BatchHandle(lambda: out)

        _PIPE_BATCHES.inc(mode="host")
        _PIPE_PAIRS.inc(m, mode="host")
        pairs = np.ascontiguousarray(pairs, dtype=np.int32)
        order = inverse = None
        if self.locality_sort:
            order, inverse = locality_order(pairs)
            if order is not None:
                pairs = pairs[order]

        padded = _pad_pairs(pairs, self.placement.padded_size(m, pad_buckets=self.pad_buckets))
        child_d, cnt_d, cls_d = self.placement.dispatch(self._state, padded, write_children)
        n_words = self.n_words

        def materialize():
            counts = np.asarray(cnt_d)[:m].astype(np.int64)
            child = np.asarray(child_d)[:m, :n_words] if child_d is not None else None
            classes = np.asarray(cls_d)[:m].astype(np.int32) if cls_d is not None else None
            if inverse is not None:
                counts = counts[inverse]
                if child is not None:
                    child = child[inverse]
                if classes is not None:
                    classes = classes[inverse]
            return child, counts, classes

        return BatchHandle(materialize)


class LegacyIntersectPipeline:
    """Adapter: wrap an ``intersect_fn(bits, pairs, write_children)`` callable
    (the pre-pipeline injection contract, still used by the sharded tests) in
    the pipeline interface. Classification stays on the host
    (``classes=None``)."""

    def __init__(self, intersect_fn, bits):
        self._fn = intersect_fn
        self._bits = bits

    def submit(self, pairs: np.ndarray, write_children: bool) -> BatchHandle:
        child, counts = self._fn(self._bits, pairs, write_children)
        out = (child, counts, None)
        return BatchHandle(lambda: out)


# EXEC_CACHE binds at the module *bottom*: importing ``repro.core.exec_cache``
# runs ``repro.core.__init__``, which re-enters this (still-executing) module
# for LevelPipeline and friends — by this line every name core needs is
# already defined. Keep this import below every definition, and keep
# ``core/exec_cache.py`` itself a stdlib-only leaf (see its import
# discipline note).
from ...core.exec_cache import FamilyCache as ExecutableCache  # noqa: E402
from ...core.exec_cache import exec_family as _exec_family  # noqa: E402

EXEC_CACHE = _exec_family("intersect")
