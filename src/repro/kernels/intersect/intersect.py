"""Pallas TPU kernels for the Kyiv row-intersection bottleneck (Alg. 1 line 31).

Two data paths, each with a *write* and a *count-only* variant:

1. **Indexed** (`*_indexed`): the pair list ``(M, 2)`` rides in scalar-prefetch
   (SMEM); each grid step's BlockSpec ``index_map`` reads the pair indices and
   DMAs exactly the two parent bitset rows it needs from HBM into VMEM. The
   row *gather* is thereby fused into the block fetch — no gathered copy of
   the parent level is ever materialised in HBM. This is the TPU analogue of
   the paper's "intersection directly on the stored level".

2. **Gathered** (`*_gathered`): operates on pre-gathered ``(M, W)`` operand
   matrices with ``(block_pairs, block_words)`` VMEM tiles — the layout- and
   lane-aligned path (word dim tiles are multiples of 128 uint32 lanes) used
   when the same parent row feeds many pairs and XLA's gather has already
   amortised.

The count-only variants implement the k = k_max fusion: the AND happens in
VMEM and only ``(M,)`` int32 counts are written back — the child bitset never
touches HBM. Combined with the Lemma 4.6 / Corollary 4.7 host-side pruning
this realises (and strengthens) the paper's "avoid the intersection at the
last level": on TPU the expensive part is the HBM write, and it is gone.

All kernels run under ``interpret=True`` on CPU for validation; the BlockSpecs
target real TPU VMEM tiling.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "intersect_write_indexed",
    "intersect_count_indexed",
    "intersect_write_gathered",
    "intersect_count_gathered",
]

_LANES = 128  # uint32 lanes per VPU register row
_SUBLANES = 8


def _write_indexed_kernel(idx_ref, a_ref, b_ref, child_ref, cnt_ref):
    a = a_ref[0, :]
    b = b_ref[0, :]
    w = jnp.bitwise_and(a, b)
    child_ref[0, :] = w
    pc = jnp.sum(jax.lax.population_count(w).astype(jnp.int32))
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        cnt_ref[0, 0] = 0

    cnt_ref[0, 0] += pc


def _count_indexed_kernel(idx_ref, a_ref, b_ref, cnt_ref):
    w = jnp.bitwise_and(a_ref[0, :], b_ref[0, :])
    pc = jnp.sum(jax.lax.population_count(w).astype(jnp.int32))
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        cnt_ref[0, 0] = 0

    cnt_ref[0, 0] += pc


@functools.partial(jax.jit, static_argnames=("block_words", "interpret"))
def intersect_write_indexed(
    bits: jax.Array,
    pairs: jax.Array,
    *,
    block_words: int = 1024,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """child = bits[pairs[:,0]] & bits[pairs[:,1]]; counts = popcount(child).

    Args:
      bits: (t, W) uint32 parent-level bitsets in HBM. W % 128 == 0.
      pairs: (M, 2) int32 row indices.
      block_words: word-dimension VMEM tile (multiple of 128).
    Returns:
      (child (M, W) uint32, counts (M,) int32)
    """
    t, W = bits.shape
    M = pairs.shape[0]
    bw = min(block_words, W)
    if W % bw:
        raise ValueError(f"W={W} not divisible by block_words={bw}")
    grid = (M, W // bw)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bw), lambda m, j, idx: (idx[m, 0], j)),
            pl.BlockSpec((1, bw), lambda m, j, idx: (idx[m, 1], j)),
        ],
        out_specs=[
            pl.BlockSpec((1, bw), lambda m, j, idx: (m, j)),
            pl.BlockSpec((1, 1), lambda m, j, idx: (m, 0)),
        ],
    )
    child, cnt = pl.pallas_call(
        _write_indexed_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((M, W), bits.dtype),
            jax.ShapeDtypeStruct((M, 1), jnp.int32),
        ],
        interpret=interpret,
    )(pairs.astype(jnp.int32), bits, bits)
    return child, cnt[:, 0]


@functools.partial(jax.jit, static_argnames=("block_words", "interpret"))
def intersect_count_indexed(
    bits: jax.Array,
    pairs: jax.Array,
    *,
    block_words: int = 2048,
    interpret: bool = False,
) -> jax.Array:
    """Count-only k=k_max path: popcount(bits[i] & bits[j]) with no HBM child write."""
    t, W = bits.shape
    M = pairs.shape[0]
    bw = min(block_words, W)
    if W % bw:
        raise ValueError(f"W={W} not divisible by block_words={bw}")
    grid = (M, W // bw)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bw), lambda m, j, idx: (idx[m, 0], j)),
            pl.BlockSpec((1, bw), lambda m, j, idx: (idx[m, 1], j)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda m, j, idx: (m, 0)),
        ],
    )
    cnt = pl.pallas_call(
        _count_indexed_kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((M, 1), jnp.int32)],
        interpret=interpret,
    )(pairs.astype(jnp.int32), bits, bits)[0]
    return cnt[:, 0]


def _write_gathered_kernel(a_ref, b_ref, child_ref, cnt_ref):
    w = jnp.bitwise_and(a_ref[...], b_ref[...])
    child_ref[...] = w
    pc = jnp.sum(jax.lax.population_count(w).astype(jnp.int32), axis=1, keepdims=True)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    cnt_ref[...] += pc


def _count_gathered_kernel(a_ref, b_ref, cnt_ref):
    w = jnp.bitwise_and(a_ref[...], b_ref[...])
    pc = jnp.sum(jax.lax.population_count(w).astype(jnp.int32), axis=1, keepdims=True)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    cnt_ref[...] += pc


@functools.partial(jax.jit, static_argnames=("block_pairs", "block_words", "interpret"))
def intersect_write_gathered(
    a: jax.Array,
    b: jax.Array,
    *,
    block_pairs: int = 8,
    block_words: int = 512,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """AND + popcount over aligned (M, W) operands with (bm, bw) VMEM tiles."""
    M, W = a.shape
    bm = min(block_pairs, M)
    bw = min(block_words, W)
    if M % bm or W % bw:
        raise ValueError(f"(M={M}, W={W}) not divisible by ({bm}, {bw})")
    grid = (M // bm, W // bw)
    child, cnt = pl.pallas_call(
        _write_gathered_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bw), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bw), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bw), lambda i, j: (i, j)),
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M, W), a.dtype),
            jax.ShapeDtypeStruct((M, 1), jnp.int32),
        ],
        interpret=interpret,
    )(a, b)
    return child, cnt[:, 0]


@functools.partial(jax.jit, static_argnames=("block_pairs", "block_words", "interpret"))
def intersect_count_gathered(
    a: jax.Array,
    b: jax.Array,
    *,
    block_pairs: int = 8,
    block_words: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Count-only variant over aligned (M, W) operands."""
    M, W = a.shape
    bm = min(block_pairs, M)
    bw = min(block_words, W)
    if M % bm or W % bw:
        raise ValueError(f"(M={M}, W={W}) not divisible by ({bm}, {bw})")
    grid = (M // bm, W // bw)
    cnt = pl.pallas_call(
        _count_gathered_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bw), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bw), lambda i, j: (i, j)),
        ],
        out_specs=[pl.BlockSpec((bm, 1), lambda i, j: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((M, 1), jnp.int32)],
        interpret=interpret,
    )(a, b)[0]
    return cnt[:, 0]
