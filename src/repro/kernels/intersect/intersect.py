"""Pallas TPU kernels for the Kyiv row-intersection bottleneck (Alg. 1 line 31).

Two data paths, each with a *write* and a *count-only* variant:

1. **Indexed** (`*_indexed`): the pair list ``(M, 2)`` rides in scalar-prefetch
   (SMEM); each grid step's BlockSpec ``index_map`` reads the pair indices and
   DMAs exactly the two parent bitset rows it needs from HBM into VMEM. The
   row *gather* is thereby fused into the block fetch — no gathered copy of
   the parent level is ever materialised in HBM. This is the TPU analogue of
   the paper's "intersection directly on the stored level".

2. **Gathered** (`*_gathered`): operates on pre-gathered ``(M, W)`` operand
   matrices with ``(block_pairs, block_words)`` VMEM tiles — the layout- and
   lane-aligned path (word dim tiles are multiples of 128 uint32 lanes) used
   when the same parent row feeds many pairs and XLA's gather has already
   amortised.

The count-only variants implement the k = k_max fusion: the AND happens in
VMEM and only ``(M,)`` int32 counts are written back — the child bitset never
touches HBM. Combined with the Lemma 4.6 / Corollary 4.7 host-side pruning
this realises (and strengthens) the paper's "avoid the intersection at the
last level": on TPU the expensive part is the HBM write, and it is gone.

**Fused classify** (`*_classify_*`): the third pipeline stage. On top of the
AND + popcount, these kernels take the parent popcounts (scalar-prefetch for
the indexed path, a pre-gathered ``(M, 1)`` min-parent vector for the
gathered path) plus the threshold ``τ`` and emit a per-pair **class code**
computed in VMEM on the final word-block of each pair:

  * ``CLASS_SKIP``  (0) — absent (``|R_W| = 0``) or uniform
    (``|R_W| = min(|R_I|, |R_J|)``), Alg. 1 line 32;
  * ``CLASS_EMIT``  (1) — minimal τ-infrequent (``0 < |R_W| <= τ``),
    Alg. 1 lines 34-38;
  * ``CLASS_STORE`` (2) — survives to level k+1, Alg. 1 line 41.

This moves the driver's per-batch host classification (a ``(M,)`` gather +
three comparisons + boolean reductions in numpy) into the same VMEM pass
that already holds the popcount, so the host only receives ``(M,)`` codes it
can ``nonzero`` directly — the classify contract consumed by
``repro.core.kyiv`` when ``KyivConfig.fused_classify`` is on.

All kernels run under ``interpret=True`` on CPU for validation; the BlockSpecs
target real TPU VMEM tiling.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ref import CLASS_EMIT, CLASS_SKIP, CLASS_STORE

__all__ = [
    "intersect_write_indexed",
    "intersect_count_indexed",
    "intersect_write_gathered",
    "intersect_count_gathered",
    "intersect_classify_write_indexed",
    "intersect_classify_count_indexed",
    "intersect_classify_write_gathered",
    "intersect_classify_write_gathered_donating",
    "intersect_classify_count_gathered",
]

_LANES = 128  # uint32 lanes per VPU register row
_SUBLANES = 8


def _write_indexed_kernel(idx_ref, a_ref, b_ref, child_ref, cnt_ref):
    a = a_ref[0, :]
    b = b_ref[0, :]
    w = jnp.bitwise_and(a, b)
    child_ref[0, :] = w
    pc = jnp.sum(jax.lax.population_count(w).astype(jnp.int32))
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        cnt_ref[0, 0] = 0

    cnt_ref[0, 0] += pc


def _count_indexed_kernel(idx_ref, a_ref, b_ref, cnt_ref):
    w = jnp.bitwise_and(a_ref[0, :], b_ref[0, :])
    pc = jnp.sum(jax.lax.population_count(w).astype(jnp.int32))
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        cnt_ref[0, 0] = 0

    cnt_ref[0, 0] += pc


@functools.partial(jax.jit, static_argnames=("block_words", "interpret"))
def intersect_write_indexed(
    bits: jax.Array,
    pairs: jax.Array,
    *,
    block_words: int = 1024,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """child = bits[pairs[:,0]] & bits[pairs[:,1]]; counts = popcount(child).

    Args:
      bits: (t, W) uint32 parent-level bitsets in HBM. W % 128 == 0.
      pairs: (M, 2) int32 row indices.
      block_words: word-dimension VMEM tile (multiple of 128).
    Returns:
      (child (M, W) uint32, counts (M,) int32)
    """
    t, W = bits.shape
    M = pairs.shape[0]
    bw = min(block_words, W)
    if W % bw:
        raise ValueError(f"W={W} not divisible by block_words={bw}")
    grid = (M, W // bw)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bw), lambda m, j, idx: (idx[m, 0], j)),
            pl.BlockSpec((1, bw), lambda m, j, idx: (idx[m, 1], j)),
        ],
        out_specs=[
            pl.BlockSpec((1, bw), lambda m, j, idx: (m, j)),
            pl.BlockSpec((1, 1), lambda m, j, idx: (m, 0)),
        ],
    )
    child, cnt = pl.pallas_call(
        _write_indexed_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((M, W), bits.dtype),
            jax.ShapeDtypeStruct((M, 1), jnp.int32),
        ],
        interpret=interpret,
    )(pairs.astype(jnp.int32), bits, bits)
    return child, cnt[:, 0]


@functools.partial(jax.jit, static_argnames=("block_words", "interpret"))
def intersect_count_indexed(
    bits: jax.Array,
    pairs: jax.Array,
    *,
    block_words: int = 2048,
    interpret: bool = False,
) -> jax.Array:
    """Count-only k=k_max path: popcount(bits[i] & bits[j]) with no HBM child write."""
    t, W = bits.shape
    M = pairs.shape[0]
    bw = min(block_words, W)
    if W % bw:
        raise ValueError(f"W={W} not divisible by block_words={bw}")
    grid = (M, W // bw)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bw), lambda m, j, idx: (idx[m, 0], j)),
            pl.BlockSpec((1, bw), lambda m, j, idx: (idx[m, 1], j)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda m, j, idx: (m, 0)),
        ],
    )
    cnt = pl.pallas_call(
        _count_indexed_kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((M, 1), jnp.int32)],
        interpret=interpret,
    )(pairs.astype(jnp.int32), bits, bits)[0]
    return cnt[:, 0]


def _write_gathered_kernel(a_ref, b_ref, child_ref, cnt_ref):
    w = jnp.bitwise_and(a_ref[...], b_ref[...])
    child_ref[...] = w
    pc = jnp.sum(jax.lax.population_count(w).astype(jnp.int32), axis=1, keepdims=True)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    cnt_ref[...] += pc


def _count_gathered_kernel(a_ref, b_ref, cnt_ref):
    w = jnp.bitwise_and(a_ref[...], b_ref[...])
    pc = jnp.sum(jax.lax.population_count(w).astype(jnp.int32), axis=1, keepdims=True)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    cnt_ref[...] += pc


@functools.partial(jax.jit, static_argnames=("block_pairs", "block_words", "interpret"))
def intersect_write_gathered(
    a: jax.Array,
    b: jax.Array,
    *,
    block_pairs: int = 8,
    block_words: int = 512,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """AND + popcount over aligned (M, W) operands with (bm, bw) VMEM tiles."""
    M, W = a.shape
    bm = min(block_pairs, M)
    bw = min(block_words, W)
    if M % bm or W % bw:
        raise ValueError(f"(M={M}, W={W}) not divisible by ({bm}, {bw})")
    grid = (M // bm, W // bw)
    child, cnt = pl.pallas_call(
        _write_gathered_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bw), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bw), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bw), lambda i, j: (i, j)),
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M, W), a.dtype),
            jax.ShapeDtypeStruct((M, 1), jnp.int32),
        ],
        interpret=interpret,
    )(a, b)
    return child, cnt[:, 0]


@functools.partial(jax.jit, static_argnames=("block_pairs", "block_words", "interpret"))
def intersect_count_gathered(
    a: jax.Array,
    b: jax.Array,
    *,
    block_pairs: int = 8,
    block_words: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Count-only variant over aligned (M, W) operands."""
    M, W = a.shape
    bm = min(block_pairs, M)
    bw = min(block_words, W)
    if M % bm or W % bw:
        raise ValueError(f"(M={M}, W={W}) not divisible by ({bm}, {bw})")
    grid = (M // bm, W // bw)
    cnt = pl.pallas_call(
        _count_gathered_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bw), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bw), lambda i, j: (i, j)),
        ],
        out_specs=[pl.BlockSpec((bm, 1), lambda i, j: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((M, 1), jnp.int32)],
        interpret=interpret,
    )(a, b)[0]
    return cnt[:, 0]


# ---------------------------------------------------------------------------
# Fused intersect + classify (Alg. 1 lines 31-41 in one device pass)
# ---------------------------------------------------------------------------


def _classify_scalar(cnt, minp, tau):
    """Class code for one accumulated popcount (scalar / (bm,1) tile)."""
    skip = (cnt == 0) | (cnt == minp)
    emit = jnp.logical_not(skip) & (cnt <= tau)
    return jnp.where(skip, CLASS_SKIP, jnp.where(emit, CLASS_EMIT, CLASS_STORE)).astype(
        jnp.int32
    )


def _classify_write_indexed_kernel(
    idx_ref, pc_ref, tau_ref, a_ref, b_ref, child_ref, cnt_ref, cls_ref
):
    m = pl.program_id(0)
    j = pl.program_id(1)
    w = jnp.bitwise_and(a_ref[0, :], b_ref[0, :])
    child_ref[0, :] = w
    pc = jnp.sum(jax.lax.population_count(w).astype(jnp.int32))

    @pl.when(j == 0)
    def _init():
        cnt_ref[0, 0] = 0

    cnt_ref[0, 0] += pc

    # classification runs once, on the pair's final word-block, when the
    # accumulated popcount is complete — the codes never leave VMEM/SMEM
    # until this single int32 store.
    @pl.when(j == pl.num_programs(1) - 1)
    def _classify():
        minp = jnp.minimum(pc_ref[idx_ref[m, 0]], pc_ref[idx_ref[m, 1]])
        cls_ref[0, 0] = _classify_scalar(cnt_ref[0, 0], minp, tau_ref[0])


def _classify_count_indexed_kernel(idx_ref, pc_ref, tau_ref, a_ref, b_ref, cnt_ref, cls_ref):
    m = pl.program_id(0)
    j = pl.program_id(1)
    w = jnp.bitwise_and(a_ref[0, :], b_ref[0, :])
    pc = jnp.sum(jax.lax.population_count(w).astype(jnp.int32))

    @pl.when(j == 0)
    def _init():
        cnt_ref[0, 0] = 0

    cnt_ref[0, 0] += pc

    @pl.when(j == pl.num_programs(1) - 1)
    def _classify():
        minp = jnp.minimum(pc_ref[idx_ref[m, 0]], pc_ref[idx_ref[m, 1]])
        cls_ref[0, 0] = _classify_scalar(cnt_ref[0, 0], minp, tau_ref[0])


@functools.partial(jax.jit, static_argnames=("block_words", "interpret"))
def intersect_classify_write_indexed(
    bits: jax.Array,
    pairs: jax.Array,
    parent_counts: jax.Array,
    tau: jax.Array,
    *,
    block_words: int = 1024,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused child + popcount + class code, gather via scalar-prefetch.

    Args:
      bits: (t, W) uint32 parent-level bitsets in HBM. W % block_words == 0.
      pairs: (M, 2) int32 row indices.
      parent_counts: (t,) int32 parent popcounts |R_I| (rides in SMEM).
      tau: scalar int32 threshold (traced — one executable per bucket).
    Returns:
      (child (M, W) uint32, counts (M,) int32, classes (M,) int32)
    """
    t, W = bits.shape
    M = pairs.shape[0]
    bw = min(block_words, W)
    if W % bw:
        raise ValueError(f"W={W} not divisible by block_words={bw}")
    grid = (M, W // bw)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bw), lambda m, j, idx, pc, tau: (idx[m, 0], j)),
            pl.BlockSpec((1, bw), lambda m, j, idx, pc, tau: (idx[m, 1], j)),
        ],
        out_specs=[
            pl.BlockSpec((1, bw), lambda m, j, idx, pc, tau: (m, j)),
            pl.BlockSpec((1, 1), lambda m, j, idx, pc, tau: (m, 0)),
            pl.BlockSpec((1, 1), lambda m, j, idx, pc, tau: (m, 0)),
        ],
    )
    child, cnt, cls = pl.pallas_call(
        _classify_write_indexed_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((M, W), bits.dtype),
            jax.ShapeDtypeStruct((M, 1), jnp.int32),
            jax.ShapeDtypeStruct((M, 1), jnp.int32),
        ],
        interpret=interpret,
    )(
        pairs.astype(jnp.int32),
        parent_counts.astype(jnp.int32),
        jnp.asarray(tau, jnp.int32).reshape(1),
        bits,
        bits,
    )
    return child, cnt[:, 0], cls[:, 0]


@functools.partial(jax.jit, static_argnames=("block_words", "interpret"))
def intersect_classify_count_indexed(
    bits: jax.Array,
    pairs: jax.Array,
    parent_counts: jax.Array,
    tau: jax.Array,
    *,
    block_words: int = 2048,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Fused count-only k=k_max path: (counts, classes), no HBM child write."""
    t, W = bits.shape
    M = pairs.shape[0]
    bw = min(block_words, W)
    if W % bw:
        raise ValueError(f"W={W} not divisible by block_words={bw}")
    grid = (M, W // bw)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bw), lambda m, j, idx, pc, tau: (idx[m, 0], j)),
            pl.BlockSpec((1, bw), lambda m, j, idx, pc, tau: (idx[m, 1], j)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda m, j, idx, pc, tau: (m, 0)),
            pl.BlockSpec((1, 1), lambda m, j, idx, pc, tau: (m, 0)),
        ],
    )
    cnt, cls = pl.pallas_call(
        _classify_count_indexed_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((M, 1), jnp.int32),
            jax.ShapeDtypeStruct((M, 1), jnp.int32),
        ],
        interpret=interpret,
    )(
        pairs.astype(jnp.int32),
        parent_counts.astype(jnp.int32),
        jnp.asarray(tau, jnp.int32).reshape(1),
        bits,
        bits,
    )
    return cnt[:, 0], cls[:, 0]


def _classify_write_gathered_kernel(tau_ref, a_ref, b_ref, minp_ref, child_ref, cnt_ref, cls_ref):
    j = pl.program_id(1)
    w = jnp.bitwise_and(a_ref[...], b_ref[...])
    child_ref[...] = w
    pc = jnp.sum(jax.lax.population_count(w).astype(jnp.int32), axis=1, keepdims=True)

    @pl.when(j == 0)
    def _init():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    cnt_ref[...] += pc

    @pl.when(j == pl.num_programs(1) - 1)
    def _classify():
        cls_ref[...] = _classify_scalar(cnt_ref[...], minp_ref[...], tau_ref[0])


def _classify_count_gathered_kernel(tau_ref, a_ref, b_ref, minp_ref, cnt_ref, cls_ref):
    j = pl.program_id(1)
    w = jnp.bitwise_and(a_ref[...], b_ref[...])
    pc = jnp.sum(jax.lax.population_count(w).astype(jnp.int32), axis=1, keepdims=True)

    @pl.when(j == 0)
    def _init():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    cnt_ref[...] += pc

    @pl.when(j == pl.num_programs(1) - 1)
    def _classify():
        cls_ref[...] = _classify_scalar(cnt_ref[...], minp_ref[...], tau_ref[0])


def _intersect_classify_write_gathered(
    a: jax.Array,
    b: jax.Array,
    minp: jax.Array,
    tau: jax.Array,
    *,
    block_pairs: int = 8,
    block_words: int = 512,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused AND + popcount + classify over pre-gathered aligned operands.

    ``minp`` is the (M,) int32 per-pair min parent popcount (pre-gathered on
    the same path that gathered ``a``/``b``).
    """
    M, W = a.shape
    bm = min(block_pairs, M)
    bw = min(block_words, W)
    if M % bm or W % bw:
        raise ValueError(f"(M={M}, W={W}) not divisible by ({bm}, {bw})")
    grid = (M // bm, W // bw)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bw), lambda i, j, tau: (i, j)),
            pl.BlockSpec((bm, bw), lambda i, j, tau: (i, j)),
            pl.BlockSpec((bm, 1), lambda i, j, tau: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bw), lambda i, j, tau: (i, j)),
            pl.BlockSpec((bm, 1), lambda i, j, tau: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i, j, tau: (i, 0)),
        ],
    )
    child, cnt, cls = pl.pallas_call(
        _classify_write_gathered_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((M, W), a.dtype),
            jax.ShapeDtypeStruct((M, 1), jnp.int32),
            jax.ShapeDtypeStruct((M, 1), jnp.int32),
        ],
        interpret=interpret,
    )(
        jnp.asarray(tau, jnp.int32).reshape(1),
        a,
        b,
        minp.astype(jnp.int32).reshape(-1, 1),
    )
    return child, cnt[:, 0], cls[:, 0]


_CLS_W_GATHERED_STATICS = ("block_pairs", "block_words", "interpret")
intersect_classify_write_gathered = jax.jit(
    _intersect_classify_write_gathered, static_argnames=_CLS_W_GATHERED_STATICS
)
# Accelerator variant: donating the gathered `a` operand lets XLA alias the
# (same-shape, same-dtype) child output onto its buffer — the write path then
# allocates no extra HBM for the children. CPU backends do not support
# donation (warning + copy), so ops.LevelPipeline selects this variant only
# on tpu/gpu.
intersect_classify_write_gathered_donating = jax.jit(
    _intersect_classify_write_gathered,
    static_argnames=_CLS_W_GATHERED_STATICS,
    donate_argnums=(0,),
)


@functools.partial(jax.jit, static_argnames=("block_pairs", "block_words", "interpret"))
def intersect_classify_count_gathered(
    a: jax.Array,
    b: jax.Array,
    minp: jax.Array,
    tau: jax.Array,
    *,
    block_pairs: int = 8,
    block_words: int = 512,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Fused count-only classify variant over pre-gathered operands."""
    M, W = a.shape
    bm = min(block_pairs, M)
    bw = min(block_words, W)
    if M % bm or W % bw:
        raise ValueError(f"(M={M}, W={W}) not divisible by ({bm}, {bw})")
    grid = (M // bm, W // bw)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bw), lambda i, j, tau: (i, j)),
            pl.BlockSpec((bm, bw), lambda i, j, tau: (i, j)),
            pl.BlockSpec((bm, 1), lambda i, j, tau: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, 1), lambda i, j, tau: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i, j, tau: (i, 0)),
        ],
    )
    cnt, cls = pl.pallas_call(
        _classify_count_gathered_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((M, 1), jnp.int32),
            jax.ShapeDtypeStruct((M, 1), jnp.int32),
        ],
        interpret=interpret,
    )(
        jnp.asarray(tau, jnp.int32).reshape(1),
        a,
        b,
        minp.astype(jnp.int32).reshape(-1, 1),
    )
    return cnt[:, 0], cls[:, 0]
