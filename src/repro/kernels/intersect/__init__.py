from .intersect import (
    intersect_count_gathered,
    intersect_count_indexed,
    intersect_write_gathered,
    intersect_write_indexed,
)
from .ops import ENGINES, intersect_and_count, next_bucket
from .ref import (
    intersect_count_ref,
    intersect_gathered_ref,
    intersect_pairs_ref,
    popcount_rows_ref,
)

__all__ = [
    "intersect_count_gathered",
    "intersect_count_indexed",
    "intersect_write_gathered",
    "intersect_write_indexed",
    "ENGINES",
    "intersect_and_count",
    "next_bucket",
    "intersect_count_ref",
    "intersect_gathered_ref",
    "intersect_pairs_ref",
    "popcount_rows_ref",
]
