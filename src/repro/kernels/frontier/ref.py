"""Numpy mirrors of the frontier device bodies (kernel-level parity oracle).

Same packing, same bisection, same partition semantics as
``frontier.py`` — used by the frontier tests to check the traced bodies
op-by-op (the end-to-end oracle is the host mining path itself, which never
goes through these ops)."""

from __future__ import annotations

import numpy as np

from .frontier import SENTINEL, pack_params

__all__ = [
    "pack_rows_np",
    "key_table_np",
    "lookup_np",
    "gen_pairs_np",
    "partition_np",
]


def pack_rows_np(itemsets: np.ndarray, n_symbols: int) -> np.ndarray:
    """Pack a (T, k) int table into (T, w) int32 key words (big-endian)."""
    t, k = itemsets.shape
    b, ipw, w = pack_params(n_symbols, k)
    out = np.zeros((t, w), dtype=np.int64)
    for c in range(k):
        jw, s = divmod(c, ipw)
        out[:, jw] |= itemsets[:, c].astype(np.int64) << (b * (ipw - 1 - s))
    return out.astype(np.int32)


def key_table_np(itemsets: np.ndarray, n_symbols: int, t_pad: int) -> np.ndarray:
    """Sorted packed parent key table, sentinel-padded to ``t_pad`` rows.

    The parent level is lexicographically sorted already, and the packing is
    order-preserving, so no sort happens here (or on device)."""
    packed = pack_rows_np(itemsets, n_symbols)
    table = np.full((t_pad, packed.shape[1]), SENTINEL, dtype=np.int32)
    table[: packed.shape[0]] = packed
    return table


def lookup_np(table: np.ndarray, queries: np.ndarray) -> np.ndarray:
    """Exact membership via the same power-of-two bisection as the device."""
    t_pad, w = table.shape
    pos = np.zeros(queries.shape[0], dtype=np.int64)
    step = t_pad >> 1
    while step >= 1:
        cand = pos + step
        row = table[cand - 1]
        lt = np.zeros(queries.shape[0], dtype=bool)
        eq = np.ones(queries.shape[0], dtype=bool)
        for wi in range(w):
            lt |= eq & (row[:, wi] < queries[:, wi])
            eq &= row[:, wi] == queries[:, wi]
        pos = np.where(lt, cand, pos)
        step >>= 1
    row = table[np.minimum(pos, t_pad - 1)]
    return np.all(row == queries, axis=-1)


def gen_pairs_np(reps_b: np.ndarray, lo: int, mb: int, bucket: int):
    """Numpy mirror of ``gen_pairs_body`` (same padding semantics)."""
    p = np.arange(bucket, dtype=np.int64)
    cum = np.cumsum(reps_b.astype(np.int64))
    i_loc = np.searchsorted(cum, p, side="right")
    i_cl = np.minimum(i_loc, len(reps_b) - 1)
    off = cum[i_cl] - reps_b[i_cl]
    j_loc = p - off + i_cl + 1
    valid = p < mb
    i = np.where(valid, lo + i_cl, lo)
    j = np.where(valid, lo + j_loc, lo)
    return i.astype(np.int32), j.astype(np.int32), valid


def partition_np(classes: np.ndarray):
    order = np.argsort(classes, kind="stable")
    return order, int((classes == 1).sum()), int((classes == 2).sum())
