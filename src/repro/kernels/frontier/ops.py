"""Jit binding, bucketing and the executable-cache family for the frontier ops.

Mirrors ``kernels.intersect.ops`` at a smaller scale: everything static —
the parent width ``k``, the packing geometry, the padded table size, the row
and pair buckets — is resolved once per executable bucket in the
``build_*`` functions, and the bound jitted callables are shared
process-wide through the ``frontier`` family of the unified
``repro.core.exec_cache`` registry (so warm service requests and successive
levels of similar size never re-trace).

Bucketing: parent-level tables pad to a power of two (``table_pad``) so the
bisection step count is static and executables are reused across levels of
similar size; batch row/pair counts pad to the same power-of-two buckets the
intersect pipeline uses (``next_bucket``).
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from ...core.exec_cache import exec_family
from ...obs import metrics as _om
from ..intersect.ops import next_bucket
from . import frontier as _f
from .frontier import SENTINEL, pack_params
from .ref import key_table_np

__all__ = [
    "EXEC_CACHE",
    "frontier_cache_stats",
    "reset_frontier_cache",
    "table_pad",
    "build_gen",
    "build_gen_support",
    "mask_pruned",
    "partition",
    "make_level_tables",
    "pad_reps",
    "gen_buckets",
]

EXEC_CACHE = exec_family("frontier")


def frontier_cache_stats() -> dict:
    """Snapshot of the frontier executable-bucket family (entries/hits/misses)."""
    return EXEC_CACHE.stats()


def reset_frontier_cache() -> None:
    EXEC_CACHE.clear()


def table_pad(t: int, minimum: int = 16) -> int:
    """Power-of-two padded table size with at least one sentinel row."""
    p = minimum
    while p < t + 1:
        p <<= 1
    return p


_LEVEL_TABLES = _om.counter(
    "repro_frontier_tables_total",
    "Per-level frontier id/key tables built for device candidate generation.",
)


def make_level_tables(itemsets: np.ndarray, n_symbols: int):
    """Host-side per-level prep for the device frontier: the padded id table
    and the packed sorted parent key table (both tiny next to the bitsets —
    ``(t, k)`` ints, uploaded once per level by the placement)."""
    _LEVEL_TABLES.inc()
    t, k = itemsets.shape
    tp = table_pad(t)
    ids = np.zeros((tp, k), dtype=np.int32)
    ids[:t] = itemsets
    keys = key_table_np(itemsets, n_symbols, tp)
    return ids, keys, tp


def build_gen(*, bucket: int):
    """Bind the pair-generation-only body (the mesh path generates pairs
    unsharded, then shards them over the pair axes for the support test)."""

    def body(reps_b, lo, mb):
        i, j, valid = _f.gen_pairs_body(reps_b, lo, mb, bucket=bucket)
        return jnp.stack([i, j], axis=1), valid

    return jax.jit(body)


def build_gen_support(
    *, k: int, n_symbols: int, t_pad: int, row_bucket: int, bucket: int
):
    """Bind one gen+support executable bucket:
    ``fn(itemsets_dev, key_table_dev, reps_b, lo, mb) -> (pairs, ok)``."""
    bits, ipw, _ = pack_params(n_symbols, k)

    def body(itemsets, key_table, reps_b, lo, mb):
        return _f.gen_support_body(
            itemsets,
            key_table,
            reps_b,
            lo,
            mb,
            k=k,
            bucket=bucket,
            t_pad=t_pad,
            bits=bits,
            ipw=ipw,
        )

    return jax.jit(body)


# The mask and partition bodies have no static parameters — one module-level
# jitted callable each (jit re-traces per shape), rather than a builder per
# bucket, keeps the /stats executable counters meaningful.
mask_pruned = jax.jit(_f.mask_pruned_body)
partition = jax.jit(_f.partition_body)


def pad_reps(reps: np.ndarray, row_bucket: int) -> np.ndarray:
    """Zero-pad a batch's run-length slice to its row bucket."""
    out = np.zeros(row_bucket, dtype=np.int32)
    out[: len(reps)] = reps
    return out


def gen_buckets(n_rows: int, n_pairs: int) -> tuple[int, int]:
    """(row bucket, pair bucket) for one frontier batch."""
    return next_bucket(n_rows, 16), next_bucket(n_pairs)
