"""Device bodies for the BFS level frontier (Alg. 1 lines 11-29 on device).

Three ops make a level transition device-to-device:

1. **Candidate-pair generation** (:func:`gen_support_body`): the prefix-join
   pair list of a batch of prefix groups is materialised from the groups'
   run lengths with ``repeat``/``cumsum`` arithmetic — the device analogue
   of ``core.prefix.generate_candidates``, bit-identical in pair order
   (pairs are emitted in lexicographic candidate order).
2. **Support-itemset test** (same fused body): every candidate's prefix-drop
   subsets are packed into multiword int31 keys and binary-searched against
   the packed **parent key table** — the device analogue of
   ``core.support.ItemsetIndex``'s ``searchsorted``. Both are exact, so the
   boolean verdicts are identical. Support-pruned pairs are then
   neutralised in place (:func:`mask_pruned_body`: self-pairs, which the
   fused classifier marks CLASS_SKIP) — no reorder, so pair order stays
   candidate order end to end.
3. **Emit/store partitioning** (:func:`partition_body`): one compaction
   pass (stable per-class ranks via ``cumsum`` + scatter — no sort) groups
   a classified batch into [skip | emit | store] segments preserving
   candidate order, so the host drains the emit segment (a few ints per
   emitted itemset) and the store segment's child bitsets never leave the
   device.

Key packing: items are positions into ``L^<`` (``n_symbols`` of them), each
``b = bit_length(n_symbols - 1)`` bits. ``31 // b`` items pack big-endian
into each int32 word (no item straddles words, so word-wise lexicographic
order equals itemset lexicographic order, and the parent table — already
lex-sorted by construction — needs no device sort). Sentinel padding rows
are ``INT32_MAX`` in every word; a real subset query can never equal a
sentinel because itemsets have strictly increasing members, so an all-max
query row is impossible for the widths (>= 2) the support test sees.

Everything here is pure traced jnp — jit binding, bucketing and the
executable cache live in ``ops.py``; the numpy mirrors used for kernel-level
parity tests live in ``ref.py``.
"""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

__all__ = [
    "SENTINEL",
    "pack_params",
    "pack_cols",
    "lower_bound",
    "lookup_keys",
    "gen_pairs_body",
    "support_ok_body",
    "gen_support_body",
    "mask_pruned_body",
    "partition_body",
]

SENTINEL = np.int32(2**31 - 1)


def pack_params(n_symbols: int, k: int) -> tuple[int, int, int]:
    """``(bits per item, items per word, words)`` for width-``k`` keys."""
    b = max(1, int(n_symbols - 1).bit_length()) if n_symbols > 1 else 1
    ipw = max(1, 31 // b)
    w = (k + ipw - 1) // ipw
    return b, ipw, w


def pack_cols(cols, b: int, ipw: int):
    """Pack ``k`` item columns (list of (M,) int32 arrays, lexicographic
    order) into ``(M, w)`` int32 key words, big-endian within each word."""
    k = len(cols)
    words = []
    for jw in range((k + ipw - 1) // ipw):
        seg = cols[jw * ipw : (jw + 1) * ipw]
        word = jnp.zeros_like(cols[0])
        for s, col in enumerate(seg):
            word = word | (col.astype(jnp.int32) << jnp.int32(b * (ipw - 1 - s)))
        words.append(word)
    return jnp.stack(words, axis=1)


def _lex_lt(a, q, w: int):
    """Lexicographic ``a < q`` over ``(…, w)`` word vectors (unrolled)."""
    lt = jnp.zeros(a.shape[:-1], dtype=bool)
    eq = jnp.ones(a.shape[:-1], dtype=bool)
    for wi in range(w):
        lt = lt | (eq & (a[..., wi] < q[..., wi]))
        eq = eq & (a[..., wi] == q[..., wi])
    return lt


def lower_bound(table, queries, *, t_pad: int):
    """First index whose key >= query, per query row.

    ``table`` is ``(t_pad, w)`` sorted (sentinel-padded to a power of two);
    the classic branchless bisection runs ``log2(t_pad)`` gather+compare
    steps, all on device.
    """
    w = table.shape[1]
    pos = jnp.zeros(queries.shape[0], dtype=jnp.int32)
    step = t_pad >> 1
    while step >= 1:
        cand = pos + jnp.int32(step)
        row = table[cand - 1]
        pos = jnp.where(_lex_lt(row, queries, w), cand, pos)
        step >>= 1
    return pos


def lookup_keys(table, queries, *, t_pad: int):
    """Exact membership of each query key in the sorted table."""
    pos = lower_bound(table, queries, t_pad=t_pad)
    row = table[jnp.minimum(pos, jnp.int32(t_pad - 1))]
    return jnp.all(row == queries, axis=-1)


def gen_pairs_body(reps_b, lo, mb, *, bucket: int):
    """Candidate (i, j) pair indices for one prefix-group batch.

    ``reps_b`` is the zero-padded run-length slice ``reps[lo:hi]`` (row ``r``
    of the batch is the *I* of ``reps_b[r]`` joins); the batch's ``mb``
    pairs are enumerated with ``repeat``/``cumsum`` — row indices repeat by
    their run lengths, and each pair's *J* offset is its rank within the
    row's run. Rows ``p >= mb`` are padding and masked invalid (their
    indices collapse to the in-range ``lo``).
    """
    p = jnp.arange(bucket, dtype=jnp.int32)
    reps_i = reps_b.astype(jnp.int32)
    cum = jnp.cumsum(reps_i)
    rows = jnp.arange(reps_b.shape[0], dtype=jnp.int32)
    # row index per pair: the repeat/cumsum enumeration (padding past the
    # batch's mb pairs repeats the final row, masked below)
    i_cl = jnp.repeat(rows, reps_i, total_repeat_length=bucket)
    off = cum[i_cl] - reps_i[i_cl]
    j_loc = p - off + i_cl + 1
    valid = p < mb
    i = jnp.where(valid, lo + i_cl, lo)
    j = jnp.where(valid, lo + j_loc, lo)
    return i, j, valid


def support_ok_body(
    itemsets,
    key_table,
    pairs,
    valid,
    *,
    k: int,
    t_pad: int,
    bits: int,
    ipw: int,
):
    """Support-itemset test (Alg. 1 line 23) for generated pairs.

    The candidate of pair ``(i, j)`` is ``itemsets[i] + last(itemsets[j])``;
    the two subsets dropping one of the joined parents are stored by
    construction, so only the ``k-1`` prefix-drop subsets need lookups
    (candidate width ``k+1 >= 3``). Shard-friendly: ``pairs``/``valid`` may
    be a pair shard while ``itemsets``/``key_table`` are replicated — this is
    what ``core.sharded.sharded_frontier_support_step`` maps over the mesh's
    pair axes. Verdicts are identical to ``core.support.support_test``.
    """
    i, j = pairs[:, 0], pairs[:, 1]
    prefix = itemsets[i]  # (m, k) — the I parent supplies the prefix
    last_j = itemsets[j, k - 1]  # J's last item completes the candidate
    ok = valid
    if k >= 2:
        cand_cols = [prefix[:, c] for c in range(k)] + [last_j]
        for drop in range(k - 1):
            sub_cols = [cand_cols[c] for c in range(k + 1) if c != drop]
            queries = pack_cols(sub_cols, bits, ipw)
            ok = ok & lookup_keys(key_table, queries, t_pad=t_pad)
    return ok


def gen_support_body(
    itemsets,
    key_table,
    reps_b,
    lo,
    mb,
    *,
    k: int,
    bucket: int,
    t_pad: int,
    bits: int,
    ipw: int,
):
    """Fused candidate generation + support-itemset test for one batch.

    ``itemsets`` is the (padded) parent id table, ``key_table`` the packed
    sorted parent keys. Returns ``(pairs (bucket, 2) int32, ok (bucket,)
    bool)`` where ``ok`` is False for padding rows and for candidates with a
    missing (k-1)-subset.
    """
    i, j, valid = gen_pairs_body(reps_b, lo, mb, bucket=bucket)
    pairs = jnp.stack([i, j], axis=1)
    ok = support_ok_body(
        itemsets, key_table, pairs, valid, k=k, t_pad=t_pad, bits=bits, ipw=ipw
    )
    return pairs, ok


def mask_pruned_body(pairs, ok):
    """Neutralise support-pruned candidates in place (no reorder).

    Pruned (and padding) rows become self-pairs of the batch's first row,
    which the fused classifier marks CLASS_SKIP (count == min parent count)
    — so the intersect kernel never *classifies* a pruned candidate, pair
    order stays candidate order (the partition pass therefore yields
    candidate-ordered emit/store segments), and the op is purely
    elementwise. Returns ``(pairs, n_ok)`` with ``n_ok`` a device scalar —
    the host only syncs on it for the stats counters, after the batch is
    dispatched.
    """
    fill = pairs[0, 0]
    i = jnp.where(ok, pairs[:, 0], fill)
    j = jnp.where(ok, pairs[:, 1], fill)
    return jnp.stack([i, j], axis=1), jnp.sum(ok).astype(jnp.int32)


def partition_body(classes):
    """One compaction pass over fused-classify codes: stable ranks per class
    (``cumsum`` + scatter, no sort) group the batch into [skip | emit |
    store] segments, each preserving candidate order (so host emission order
    matches the host reference path bit-for-bit). Returns ``(order, n_emit,
    n_store)`` where ``order`` lists original batch indices segment by
    segment — exactly a stable argsort by class code."""
    emit = classes == 1
    store = classes == 2
    e_i = emit.astype(jnp.int32)
    s_i = store.astype(jnp.int32)
    n_emit = jnp.sum(e_i)
    n_store = jnp.sum(s_i)
    b = classes.shape[0]
    n_skip = b - n_emit - n_store
    skip_i = 1 - e_i - s_i
    pos = jnp.where(
        emit,
        n_skip + jnp.cumsum(e_i) - 1,
        jnp.where(
            store,
            n_skip + n_emit + jnp.cumsum(s_i) - 1,
            jnp.cumsum(skip_i) - 1,
        ),
    )
    order = (
        jnp.zeros(b, dtype=jnp.int32)
        .at[pos]
        .set(jnp.arange(b, dtype=jnp.int32))
    )
    return order, n_emit, n_store
