"""Structural tracing for mining requests (stdlib only — this module is a
leaf).

A :class:`Trace` is one request's tree of :class:`Span` intervals
(trace_id / span_id / parent_id, wall-clock timing via ``perf_counter``),
threaded through ``MiningService`` → scheduler → ``mine_levels``'s
level/batch loop → placement dispatch and the WAL/snapshot path by plain
``with span("name"):`` blocks at the sites that already keep stage clocks.
Trace context propagates through ``contextvars`` — across the scheduler's
worker-thread hop via ``contextvars.copy_context()`` (see
``repro.service.scheduler``).

When no trace is active every ``span(...)`` is a no-op costing one
context-variable read, so library callers that never start a trace pay
nothing. Finished traces land in a ring buffer (:meth:`Tracer.last` /
:meth:`Tracer.get`) served by ``GET /trace``.

Optional device-sync timing: :func:`device_sync` blocks on device arrays
inside a span *only* when ``TRACER.sync_devices`` is enabled, so a span's
wall time then includes the device work it dispatched (off by default —
syncing defeats the double-buffered pipeline and is a debugging mode).
"""

from __future__ import annotations

import contextvars
import itertools
import threading
import time
import uuid
from collections import deque
from contextlib import contextmanager

__all__ = [
    "Span",
    "Trace",
    "Tracer",
    "TRACER",
    "span",
    "start_trace",
    "current_trace_id",
    "current_span",
    "device_sync",
]

_CTX: "contextvars.ContextVar[tuple | None]" = contextvars.ContextVar(
    "repro_obs_trace", default=None
)  # (Trace, Span) of the innermost open span

_ids = itertools.count(1)


def _new_span_id() -> str:
    return f"{next(_ids):08x}"


class Span:
    """One timed interval in a trace tree."""

    __slots__ = ("trace_id", "span_id", "parent_id", "name", "t0", "t1", "attrs")

    def __init__(self, trace_id: str, parent_id: str | None, name: str,
                 attrs: dict | None = None):
        self.trace_id = trace_id
        self.span_id = _new_span_id()
        self.parent_id = parent_id
        self.name = name
        self.t0 = time.perf_counter()
        self.t1: float | None = None
        self.attrs = attrs or {}

    @property
    def duration(self) -> float:
        return (self.t1 if self.t1 is not None else time.perf_counter()) - self.t0

    def set(self, **attrs) -> None:
        self.attrs.update(attrs)

    def to_dict(self) -> dict:
        return {
            "span_id": self.span_id,
            "parent_id": self.parent_id,
            "name": self.name,
            "start": self.t0,
            "duration_s": self.duration,
            "attrs": dict(self.attrs),
        }


class Trace:
    """One request's span tree. ``spans`` holds finished spans in
    completion order (a flat list; :meth:`tree` rebuilds nesting)."""

    def __init__(self, trace_id: str, name: str, meta: dict | None = None):
        self.trace_id = trace_id
        self.name = name
        self.meta = meta or {}
        self.started_at = time.time()
        self.spans: list[Span] = []
        self.root: Span | None = None
        # ring position, assigned when the finished trace is appended to the
        # Tracer's buffer — the stable cursor `GET /trace?before=` pages on
        self.seq: int | None = None
        self._lock = threading.Lock()

    def add(self, span: Span) -> None:
        with self._lock:
            self.spans.append(span)

    def find(self, name: str) -> list[Span]:
        with self._lock:
            return [s for s in self.spans if s.name == name]

    def children_of(self, span: Span) -> list[Span]:
        with self._lock:
            return [s for s in self.spans if s.parent_id == span.span_id]

    def coverage(self, span: Span | None = None) -> float:
        """Fraction of ``span``'s (default: root's) wall time covered by its
        direct children — the "is the tree accounting for the run" metric."""
        top = span or self.root
        if top is None or not top.duration:
            return 0.0
        covered = sum(s.duration for s in self.children_of(top))
        return min(1.0, covered / top.duration)

    def _node(self, span: Span, by_parent: dict) -> dict:
        kids = by_parent.get(span.span_id, [])
        d = span.to_dict()
        d["self_time_s"] = max(0.0, span.duration - sum(k.duration for k in kids))
        d["children"] = [self._node(k, by_parent) for k in kids]
        return d

    def to_dict(self) -> dict:
        with self._lock:
            spans = list(self.spans)
        by_parent: dict[str | None, list[Span]] = {}
        for s in spans:
            by_parent.setdefault(s.parent_id, []).append(s)
        for kids in by_parent.values():
            kids.sort(key=lambda s: s.t0)
        roots = by_parent.get(None, [])
        return {
            "trace_id": self.trace_id,
            "seq": self.seq,
            "name": self.name,
            "started_at": self.started_at,
            "meta": dict(self.meta),
            "n_spans": len(spans),
            "duration_s": self.root.duration if self.root is not None else None,
            "coverage": self.coverage(),
            "spans": [self._node(r, by_parent) for r in roots],
        }


class _NullSpan:
    __slots__ = ()

    def set(self, **attrs) -> None:
        pass


_NULL_SPAN = _NullSpan()


class Tracer:
    """Trace lifecycle + the finished-trace ring buffer."""

    def __init__(self, max_traces: int = 64, sample_every: int = 1):
        self._lock = threading.Lock()
        self._traces: deque[Trace] = deque(maxlen=max_traces)
        self.sample_every = max(1, int(sample_every))
        self.sync_devices = False
        self._started = 0
        self._sampled_out = 0
        self._appended = 0  # monotone: doubles as the per-trace seq cursor
        self._dropped = 0  # traces evicted from the ring by newer arrivals
        # span-lifecycle listeners (the flight recorder): fn(event, span,
        # trace) with event "open" | "close". Zero-cost when empty — span()
        # only pays a truthiness check. Listener errors are swallowed; the
        # traced code must never fail because a recorder did.
        self._listeners: list = []

    def configure(self, *, max_traces: int | None = None,
                  sample_every: int | None = None,
                  sync_devices: bool | None = None) -> None:
        with self._lock:
            if max_traces is not None:
                self._traces = deque(self._traces, maxlen=max(1, int(max_traces)))
            if sample_every is not None:
                self.sample_every = max(1, int(sample_every))
            if sync_devices is not None:
                self.sync_devices = bool(sync_devices)

    # -- listeners -----------------------------------------------------------

    def add_listener(self, fn) -> None:
        with self._lock:
            if fn not in self._listeners:
                self._listeners.append(fn)

    def remove_listener(self, fn) -> None:
        with self._lock:
            if fn in self._listeners:
                self._listeners.remove(fn)

    def _notify(self, event: str, sp: Span, trace: Trace) -> None:
        for fn in list(self._listeners):
            try:
                fn(event, sp, trace)
            except Exception:
                pass

    # -- lifecycle -----------------------------------------------------------

    @contextmanager
    def start(self, name: str, trace_id: str | None = None, meta: dict | None = None):
        """Open a trace with a root span of the same name. If a trace is
        already active on this context, nest a plain child span instead (the
        outer request owns the trace). Deterministic 1-in-N sampling applies
        only to fresh roots."""
        if _CTX.get() is not None:
            with self.span(name) as sp:
                yield sp
            return
        with self._lock:
            self._started += 1
            sampled = (self._started % self.sample_every) == 0
            if not sampled:
                self._sampled_out += 1
        if not sampled:
            yield _NULL_SPAN
            return
        trace = Trace(trace_id or uuid.uuid4().hex[:16], name, meta)
        root = Span(trace.trace_id, None, name)
        trace.root = root
        token = _CTX.set((trace, root))
        if self._listeners:
            self._notify("open", root, trace)
        try:
            yield root
        finally:
            root.t1 = time.perf_counter()
            trace.add(root)
            _CTX.reset(token)
            if self._listeners:
                self._notify("close", root, trace)
            with self._lock:
                if (self._traces.maxlen is not None
                        and len(self._traces) == self._traces.maxlen):
                    self._dropped += 1
                trace.seq = self._appended
                self._appended += 1
                self._traces.append(trace)

    @contextmanager
    def span(self, name: str, **attrs):
        """A child span of the current context; no-op without an active
        trace (one ContextVar read)."""
        ctx = _CTX.get()
        if ctx is None:
            yield _NULL_SPAN
            return
        trace, parent = ctx
        sp = Span(trace.trace_id, parent.span_id, name, attrs)
        token = _CTX.set((trace, sp))
        if self._listeners:
            self._notify("open", sp, trace)
        try:
            yield sp
        finally:
            sp.t1 = time.perf_counter()
            trace.add(sp)
            _CTX.reset(token)
            if self._listeners:
                self._notify("close", sp, trace)

    # -- queries -------------------------------------------------------------

    def get(self, trace_id: str) -> Trace | None:
        with self._lock:
            for t in reversed(self._traces):
                if t.trace_id == trace_id:
                    return t
        return None

    def last(self, n: int = 10) -> list[Trace]:
        with self._lock:
            return list(self._traces)[-max(0, int(n)):]

    def page(self, n: int = 10, before: int | None = None) -> tuple[list[Trace], int | None]:
        """Newest-first page of finished traces, keyed on the stable ring
        sequence number. ``before`` bounds the page to traces with
        ``seq < before`` so successive pages never repeat an entry even
        while new traces arrive. Returns ``(traces, next_before)`` where
        ``next_before`` is the cursor for the following page (None when
        the ring is exhausted)."""
        n = max(0, int(n))
        with self._lock:
            candidates = [t for t in reversed(self._traces)
                          if before is None or (t.seq is not None and t.seq < before)]
        pg = candidates[:n]
        next_before = pg[-1].seq if pg and len(candidates) > n else None
        return pg, next_before

    def stats(self) -> dict:
        with self._lock:
            return {
                "stored": len(self._traces),
                "max_traces": self._traces.maxlen,
                "started": self._started,
                "sampled_out": self._sampled_out,
                "appended": self._appended,
                "dropped": self._dropped,
                "sample_every": self.sample_every,
                "sync_devices": self.sync_devices,
            }

    def reset(self) -> None:
        with self._lock:
            self._traces.clear()
            self._started = 0
            self._sampled_out = 0
            self._appended = 0
            self._dropped = 0


TRACER = Tracer()
span = TRACER.span
start_trace = TRACER.start


def current_trace_id() -> str | None:
    ctx = _CTX.get()
    return ctx[0].trace_id if ctx is not None else None


def current_span() -> "Span | _NullSpan":
    ctx = _CTX.get()
    return ctx[1] if ctx is not None else _NULL_SPAN


def device_sync(*arrays) -> bool:
    """Block until the given device arrays are ready — only when tracing
    with ``TRACER.sync_devices`` on, so the enclosing span's wall time
    includes the dispatched device work. Returns True if it synced."""
    if not TRACER.sync_devices or _CTX.get() is None:
        return False
    try:
        import jax

        jax.block_until_ready([a for a in arrays if a is not None])
        return True
    except Exception:
        return False
