"""Opt-in device profiling hooks around a mine.

:func:`profile` wraps a mining run in ``jax.profiler.trace`` (when a dump
directory is given — the xplane traces land there for TensorBoard /
xprof, the same ``profiler=xplane`` idiom the MaxText-style launch scripts
use) and records device-health gauges either way: executable-cache
hit/miss deltas across the run, level retirements, the run's
``peak_level_bytes``, and its wall time.

Everything heavier than the stdlib is imported lazily inside the context
manager, so ``repro.obs`` stays importable (and cheap) in processes that
never profile.

    from repro.obs import profile as obs_profile

    with obs_profile.profile(dump_dir="/tmp/xplane") as prof:
        result = service.mine(tau=1, kmax=4)
        prof.set_result(result.result)
"""

from __future__ import annotations

import time
from contextlib import contextmanager

__all__ = ["profile", "ProfileRecord"]


class ProfileRecord:
    """Mutable handle the ``profile`` context yields; ``set_result`` feeds
    the mined :class:`~repro.core.kyiv.MiningResult` so peak-memory and
    retirement gauges reflect the profiled run."""

    def __init__(self, dump_dir: str | None):
        self.dump_dir = dump_dir
        self.result = None
        self.wall_s: float | None = None
        self.exec_cache_delta: dict | None = None
        self.profiler_active = False

    def set_result(self, result) -> None:
        self.result = result


def _exec_totals():
    from ..core import exec_cache

    s = exec_cache.stats()
    return {"hits": s["hits"], "misses": s["misses"], "entries": s["entries"]}


@contextmanager
def profile(dump_dir: str | None = None, *, registry=None):
    """Profile one mine. ``dump_dir`` enables the ``jax.profiler`` xplane
    trace; without it only the gauges are recorded. Never raises out of the
    profiler itself — a broken/absent profiler degrades to gauges-only."""
    from . import metrics as _m

    reg = registry or _m.REGISTRY
    g_wall = reg.gauge(
        "repro_profile_last_wall_seconds", "Wall time of the last profiled mine."
    )
    g_cache = reg.gauge(
        "repro_profile_exec_cache_delta",
        "Executable-cache activity during the last profiled mine.",
        ("event",),
    )
    g_peak = reg.gauge(
        "repro_profile_peak_level_bytes",
        "peak_level_bytes of the last profiled mine.",
    )
    g_levels = reg.gauge(
        "repro_profile_levels_retired", "Levels mined by the last profiled mine."
    )
    c_runs = reg.counter(
        "repro_profile_runs_total", "Profiled mines.", ("profiler",)
    )

    rec = ProfileRecord(dump_dir)
    before = _exec_totals()
    t0 = time.perf_counter()
    cm = None
    if dump_dir is not None:
        try:
            import jax

            cm = jax.profiler.trace(dump_dir)
            cm.__enter__()
            rec.profiler_active = True
        except Exception:
            cm = None
    try:
        yield rec
    finally:
        if cm is not None:
            try:
                cm.__exit__(None, None, None)
            except Exception:
                pass
        rec.wall_s = time.perf_counter() - t0
        after = _exec_totals()
        rec.exec_cache_delta = {k: after[k] - before[k] for k in after}
        g_wall.set(rec.wall_s)
        for event, delta in rec.exec_cache_delta.items():
            g_cache.set(delta, event=event)
        if rec.result is not None:
            g_peak.set(getattr(rec.result, "peak_level_bytes", 0))
            g_levels.set(len(getattr(rec.result, "stats", ())))
        c_runs.inc(profiler="xplane" if rec.profiler_active else "off")
