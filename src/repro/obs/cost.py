"""Per-request cost accounting (stdlib leaf).

Aggregate histograms (PR 7) say mining is *sometimes* slow; operators need
to know **which request** was expensive and **why**. A
:class:`CostEnvelope` rides the request context (the same
``contextvars.copy_context()`` hop the tracer uses across the scheduler's
worker thread), and the existing span seams fold their counters into it:
``core/frontier.py`` adds per-level candidate pairs / rows scanned / bytes,
``core/placement.py`` adds device dispatches, the service adds
compile-vs-reuse executable deltas and the cache path taken. The finished
envelope is attached to every ``/mine`` response under ``info.cost``,
observed into per-path histogram families, and — when wall time crosses
``--slow-mine-threshold-s`` — appended to the ring-buffered
:class:`SlowMineLog` served at ``GET /debug/slowlog``.

Zero-cost discipline: without an attached envelope, :func:`add` is one
ContextVar read and a ``None`` check — library callers that never attach
pay nothing (same contract as ``obs.trace``).
"""

from __future__ import annotations

import contextvars
import threading
import time
from collections import deque
from contextlib import contextmanager

from . import metrics as _om

__all__ = [
    "CostEnvelope",
    "SlowMineLog",
    "attach",
    "add",
    "note",
    "current",
    "publish",
    "SLOW_MINES",
]

_CTX: "contextvars.ContextVar[CostEnvelope | None]" = contextvars.ContextVar(
    "repro_obs_cost", default=None
)

_COST_PAIRS = _om.histogram(
    "repro_mine_cost_candidate_pairs",
    "Candidate pairs generated per mine request, by serving path.",
    ("path",),
    buckets=_om.COUNT_BUCKETS,
)
_COST_ROWS = _om.histogram(
    "repro_mine_cost_rows_scanned",
    "Row-support scans per mine request (rows x levels), by serving path.",
    ("path",),
    buckets=_om.COUNT_BUCKETS,
)
_COST_BYTES = _om.histogram(
    "repro_mine_cost_device_bytes",
    "Device bytes moved per mine request, by serving path.",
    ("path",),
    buckets=_om.BYTE_BUCKETS,
)
SLOW_MINES = _om.counter(
    "repro_slow_mines_total",
    "Mine requests slower than the slow-mine threshold.",
    ("path",),
)


class CostEnvelope:
    """Accumulates one request's resource counters. Thread-safe: the
    scheduler worker and the submitting thread share the same object."""

    _FIELDS = (
        "rows_scanned",
        "candidate_pairs",
        "device_bytes",
        "device_dispatches",
        "levels",
        "itemsets_emitted",
        "executables_compiled",
        "executables_reused",
    )

    def __init__(self):
        self._lock = threading.Lock()
        self.t0 = time.perf_counter()
        self.wall_s = 0.0
        self.device_s = 0.0
        # serving path: cold | incremental | approx | refined | cached
        self.path = "unknown"
        self.trace_id: str | None = None
        self._counters = dict.fromkeys(self._FIELDS, 0)
        self._notes: dict = {}

    def add(self, **counters) -> None:
        with self._lock:
            for k, v in counters.items():
                if k not in self._counters:
                    raise KeyError(f"unknown cost counter {k!r}")
                self._counters[k] += int(v)

    def add_device_time(self, seconds: float) -> None:
        with self._lock:
            self.device_s += float(seconds)

    def note(self, **fields) -> None:
        """Attach non-additive facts (path, dataset version, epsilon...)."""
        with self._lock:
            for k, v in fields.items():
                if k == "path":
                    self.path = str(v)
                elif k == "trace_id":
                    self.trace_id = v
                else:
                    self._notes[k] = v

    def finish(self) -> "CostEnvelope":
        self.wall_s = time.perf_counter() - self.t0
        return self

    def to_dict(self) -> dict:
        with self._lock:
            d = dict(self._counters)
            d.update(self._notes)
            d["path"] = self.path
            d["wall_s"] = round(self.wall_s, 6)
            d["device_s"] = round(self.device_s, 6)
            if self.trace_id:
                d["trace_id"] = self.trace_id
            return d

    def __getitem__(self, key: str) -> int:
        with self._lock:
            return self._counters[key]


@contextmanager
def attach(envelope: "CostEnvelope | None" = None):
    """Bind an envelope to the current context; the same object is visible
    across the scheduler hop (``contextvars.copy_context()`` copies the
    binding, not the envelope). Yields the bound envelope."""
    env = envelope if envelope is not None else CostEnvelope()
    token = _CTX.set(env)
    try:
        yield env
    finally:
        _CTX.reset(token)


def current() -> CostEnvelope | None:
    return _CTX.get()


def add(**counters) -> None:
    """Fold counters into the request's envelope; no-op without one."""
    env = _CTX.get()
    if env is not None:
        env.add(**counters)


def note(**fields) -> None:
    env = _CTX.get()
    if env is not None:
        env.note(**fields)


def publish(env: CostEnvelope) -> None:
    """Observe a finished envelope into the per-path cost histograms, with
    the owning trace_id as the Prometheus exemplar."""
    ex = {"trace_id": env.trace_id} if env.trace_id else None
    _COST_PAIRS.observe(env["candidate_pairs"], exemplar=ex, path=env.path)
    _COST_ROWS.observe(env["rows_scanned"], exemplar=ex, path=env.path)
    _COST_BYTES.observe(env["device_bytes"], exemplar=ex, path=env.path)


class SlowMineLog:
    """Ring buffer of the slowest / threshold-crossing mine envelopes."""

    def __init__(self, threshold_s: float = 1.0, maxlen: int = 64):
        self.threshold_s = float(threshold_s)
        self._lock = threading.Lock()
        self._ring: deque[dict] = deque(maxlen=max(1, int(maxlen)))
        self.total = 0

    def offer(self, env: CostEnvelope, **extra) -> bool:
        """Record the envelope if it crossed the threshold. Returns whether
        it was recorded."""
        if env.wall_s < self.threshold_s:
            return False
        entry = env.to_dict()
        entry["at"] = time.time()
        entry.update(extra)
        with self._lock:
            self._ring.append(entry)
            self.total += 1
        SLOW_MINES.inc(path=env.path)
        return True

    def entries(self, n: int | None = None) -> list[dict]:
        with self._lock:
            out = list(self._ring)
        if n is not None:
            out = out[-max(0, int(n)):]
        return out[::-1]  # newest first

    def stats(self) -> dict:
        with self._lock:
            return {
                "threshold_s": self.threshold_s,
                "stored": len(self._ring),
                "maxlen": self._ring.maxlen,
                "total": self.total,
            }
