"""Process-wide metrics registry (stdlib only — this module is a leaf).

One :class:`MetricsRegistry` per process holds every metric family the
miner, the service layer and the HTTP endpoint record into: counters
(monotonic), gauges (set/add), and histograms over **fixed log-scale
buckets** (so per-stage level timings spanning microseconds to minutes land
in meaningful buckets without per-family tuning). The registry renders the
Prometheus text exposition format 0.0.4 for ``GET /metrics`` and a
JSON-friendly snapshot for the ``/stats`` fold-in.

Consistency: every mutation *and* every read (render / snapshot) takes the
one registry lock, and registered collectors — callbacks that mirror
component-local counters (result cache, scheduler, executable cache, …)
into registry values at scrape time — run under that same lock. A scrape
therefore never observes torn counters (a histogram whose bucket counts
disagree with its ``_count``, a cache hit without its request), no matter
how many mines/appends are in flight.

Import discipline: stdlib only, imported by ``repro.core``, the kernels
packages and the service layer alike — it must never import anything from
``repro`` (the reverse edges all exist).
"""

from __future__ import annotations

import math
import re
import threading
import time
from bisect import bisect_left
from typing import Callable, Iterable

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "REGISTRY",
    "counter",
    "gauge",
    "histogram",
    "register_collector",
    "unregister_collector",
    "render",
    "snapshot",
    "lint_exposition",
    "TIME_BUCKETS",
    "COUNT_BUCKETS",
    "BYTE_BUCKETS",
]

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")

# Fixed log-scale bucket ladders. Timings: half-decade steps from 100 µs to
# 1000 s (mining levels run anywhere in that range depending on dataset and
# placement). Counts/bytes: decade steps.
TIME_BUCKETS: tuple[float, ...] = tuple(
    round(10.0 ** (e / 2.0), 10) for e in range(-8, 7)
)  # 1e-4 .. ~3.16e2, 15 buckets
COUNT_BUCKETS: tuple[float, ...] = tuple(float(10**e) for e in range(0, 9))
BYTE_BUCKETS: tuple[float, ...] = tuple(float(4**e) for e in range(5, 19))


def _fmt(v: float) -> str:
    """Prometheus sample value / ``le`` formatting (no trailing .0 noise)."""
    if v == math.inf:
        return "+Inf"
    if v == -math.inf:
        return "-Inf"
    if v != v:  # NaN
        return "NaN"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def _escape_label(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


class _Family:
    """Base: one metric family (name + type + help + label names)."""

    mtype = "untyped"

    def __init__(self, registry: "MetricsRegistry", name: str, help: str,
                 labelnames: tuple[str, ...]):
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid metric name {name!r}")
        for ln in labelnames:
            if not _LABEL_RE.match(ln):
                raise ValueError(f"invalid label name {ln!r}")
        self._registry = registry
        self._lock = registry._lock
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._values: dict[tuple, float] = {}

    def _key(self, labels: dict) -> tuple:
        if set(labels) != set(self.labelnames):
            raise ValueError(
                f"{self.name}: expected labels {self.labelnames}, got "
                f"{tuple(labels)}"
            )
        return tuple(str(labels[ln]) for ln in self.labelnames)

    def _label_str(self, key: tuple) -> str:
        if not self.labelnames:
            return ""
        pairs = ",".join(
            f'{ln}="{_escape_label(lv)}"' for ln, lv in zip(self.labelnames, key)
        )
        return "{" + pairs + "}"

    def value(self, **labels) -> float:
        with self._lock:
            return self._values.get(self._key(labels), 0.0)

    # -- rendering -----------------------------------------------------------

    def _render_locked(self, out: list[str]) -> None:
        out.append(f"# HELP {self.name} {self.help}")
        out.append(f"# TYPE {self.name} {self.mtype}")
        for key in sorted(self._values):
            out.append(
                f"{self.name}{self._label_str(key)} {_fmt(self._values[key])}"
            )

    def _snapshot_locked(self) -> dict:
        return {
            "type": self.mtype,
            "values": {
                ",".join(k) if k else "": v for k, v in self._values.items()
            },
        }


class Counter(_Family):
    """Monotonic counter. ``inc`` for native event sites; ``set_total`` is
    reserved for registered collectors that mirror a component-local counter
    (the mirrored source is itself monotonic per component instance)."""

    mtype = "counter"

    def inc(self, amount: float = 1.0, **labels) -> None:
        if amount < 0:
            raise ValueError(f"{self.name}: counter increment must be >= 0")
        key = self._key(labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + amount

    def set_total(self, value: float, **labels) -> None:
        key = self._key(labels)
        with self._lock:
            self._values[key] = float(value)


class Gauge(_Family):
    mtype = "gauge"

    def set(self, value: float, **labels) -> None:
        key = self._key(labels)
        with self._lock:
            self._values[key] = float(value)

    def add(self, amount: float, **labels) -> None:
        key = self._key(labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0.0) + amount


class Histogram(_Family):
    """Histogram over fixed (log-scale by default) buckets.

    Per label set we keep ``[bucket_counts..., sum, count]``; all three
    update under the one registry lock, so a scrape's ``_bucket`` /
    ``_sum`` / ``_count`` samples are always mutually consistent.
    """

    mtype = "histogram"

    def __init__(self, registry, name, help, labelnames,
                 buckets: Iterable[float] | None = None):
        super().__init__(registry, name, help, labelnames)
        bs = tuple(sorted(float(b) for b in (buckets or TIME_BUCKETS)))
        if not bs:
            raise ValueError(f"{self.name}: histogram needs at least one bucket")
        self.buckets = bs
        self._series: dict[tuple, list] = {}
        # per-(labelset, bucket) exemplar: (value, labels, unix_ts) — the
        # most recent observation that carried one (OpenMetrics keeps one
        # exemplar per bucket; newest-wins is the standard behaviour)
        self._exemplars: dict[tuple, dict[int, tuple]] = {}

    def observe(self, value: float, exemplar: dict | None = None, **labels) -> None:
        key = self._key(labels)
        with self._lock:
            series = self._series.get(key)
            if series is None:
                series = self._series[key] = [0] * (len(self.buckets) + 1) + [0.0, 0]
            idx = bisect_left(self.buckets, value)
            series[idx] += 1
            series[-2] += float(value)
            series[-1] += 1
            if exemplar:
                self._exemplars.setdefault(key, {})[idx] = (
                    float(value),
                    {str(k): str(v) for k, v in exemplar.items()},
                    time.time(),
                )

    def series(self, **labels) -> dict:
        """JSON view: {"buckets": [(le, cumulative_count)...], "sum", "count"}."""
        key = self._key(labels)
        with self._lock:
            series = self._series.get(key)
            if series is None:
                return {"buckets": [], "sum": 0.0, "count": 0}
            acc, out = 0, []
            for le, c in zip(self.buckets + (math.inf,), series[:-2]):
                acc += c
                out.append((le, acc))
            return {"buckets": out, "sum": series[-2], "count": series[-1]}

    def _render_locked(self, out: list[str]) -> None:
        out.append(f"# HELP {self.name} {self.help}")
        out.append(f"# TYPE {self.name} {self.mtype}")
        for key in sorted(self._series):
            series = self._series[key]
            exemplars = self._exemplars.get(key, {})
            acc = 0
            for idx, (le, c) in enumerate(zip(self.buckets + (math.inf,), series[:-2])):
                acc += c
                lkey = key + (_fmt(le),)
                pairs = ",".join(
                    f'{ln}="{_escape_label(lv)}"'
                    for ln, lv in zip(self.labelnames + ("le",), lkey)
                )
                line = f"{self.name}_bucket{{{pairs}}} {acc}"
                ex = exemplars.get(idx)
                if ex is not None:
                    ev, elabels, ets = ex
                    epairs = ",".join(
                        f'{k}="{_escape_label(v)}"' for k, v in sorted(elabels.items())
                    )
                    line += f" # {{{epairs}}} {_fmt(ev)} {ets:.3f}"
                out.append(line)
            ls = self._label_str(key)
            out.append(f"{self.name}_sum{ls} {_fmt(series[-2])}")
            out.append(f"{self.name}_count{ls} {series[-1]}")

    def _snapshot_locked(self) -> dict:
        return {
            "type": self.mtype,
            "values": {
                ",".join(k) if k else "": {"sum": s[-2], "count": s[-1]}
                for k, s in self._series.items()
            },
        }


class MetricsRegistry:
    """All metric families + named collectors behind one lock."""

    def __init__(self):
        self._lock = threading.RLock()
        self._families: dict[str, _Family] = {}
        self._collectors: dict[str, Callable[[], None]] = {}
        self.collector_errors = 0

    def _get_or_create(self, cls, name, help, labelnames, **kw) -> _Family:
        with self._lock:
            fam = self._families.get(name)
            if fam is not None:
                if type(fam) is not cls or fam.labelnames != tuple(labelnames):
                    raise ValueError(
                        f"metric {name!r} re-registered with different "
                        f"type/labels"
                    )
                return fam
            fam = cls(self, name, help, tuple(labelnames), **kw)
            self._families[name] = fam
            return fam

    def counter(self, name: str, help: str, labelnames: tuple[str, ...] = ()) -> Counter:
        return self._get_or_create(Counter, name, help, labelnames)

    def gauge(self, name: str, help: str, labelnames: tuple[str, ...] = ()) -> Gauge:
        return self._get_or_create(Gauge, name, help, labelnames)

    def histogram(
        self, name: str, help: str, labelnames: tuple[str, ...] = (),
        buckets: Iterable[float] | None = None,
    ) -> Histogram:
        return self._get_or_create(Histogram, name, help, labelnames, buckets=buckets)

    def register_collector(self, name: str, fn: Callable[[], None]) -> None:
        """Run ``fn`` at every scrape, under the registry lock. Named so a
        replacement component (a new ``MiningService``) takes over its slot
        instead of stacking stale closures."""
        with self._lock:
            self._collectors[name] = fn

    def unregister_collector(self, name: str, fn: Callable[[], None] | None = None) -> None:
        """Remove the named collector; with ``fn`` given, only when it is
        still the registered one (so a closed component can't evict its
        replacement's collector)."""
        with self._lock:
            if fn is None or self._collectors.get(name) is fn:
                self._collectors.pop(name, None)

    def _run_collectors_locked(self) -> None:
        for fn in list(self._collectors.values()):
            try:
                fn()
            except Exception:
                # a broken collector must never fail the scrape
                self.collector_errors += 1

    def render(self) -> str:
        """Prometheus text exposition 0.0.4 — one consistent pass."""
        with self._lock:
            self._run_collectors_locked()
            out: list[str] = []
            for name in sorted(self._families):
                self._families[name]._render_locked(out)
            out.append("")
            return "\n".join(out)

    def snapshot(self) -> dict:
        """JSON-friendly registry view (the ``/stats`` fold-in), taken under
        the same lock as ``render`` — never torn."""
        with self._lock:
            self._run_collectors_locked()
            return {
                name: fam._snapshot_locked()
                for name, fam in sorted(self._families.items())
            }

    def reset(self) -> None:
        """Drop every family and collector (test isolation only)."""
        with self._lock:
            self._families.clear()
            self._collectors.clear()
            self.collector_errors = 0


REGISTRY = MetricsRegistry()


def counter(name: str, help: str, labelnames: tuple[str, ...] = ()) -> Counter:
    return REGISTRY.counter(name, help, labelnames)


def gauge(name: str, help: str, labelnames: tuple[str, ...] = ()) -> Gauge:
    return REGISTRY.gauge(name, help, labelnames)


def histogram(
    name: str, help: str, labelnames: tuple[str, ...] = (),
    buckets: Iterable[float] | None = None,
) -> Histogram:
    return REGISTRY.histogram(name, help, labelnames, buckets)


def register_collector(name: str, fn: Callable[[], None]) -> None:
    REGISTRY.register_collector(name, fn)


def unregister_collector(name: str, fn: Callable[[], None] | None = None) -> None:
    REGISTRY.unregister_collector(name, fn)


def render() -> str:
    return REGISTRY.render()


def snapshot() -> dict:
    return REGISTRY.snapshot()


# -- exposition linting (CI obs-smoke) ---------------------------------------

_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)(?P<labels>\{[^}]*\})?\s+(?P<value>\S+?)"
    r"(\s+\d+)?"
    r"(?P<exemplar>\s+#\s+\{(?P<exlabels>[^}]*)\}\s+(?P<exvalue>\S+)(\s+\S+)?)?$"
)


def lint_exposition(text: str) -> list[str]:
    """Validate a Prometheus text exposition: metric/label naming, TYPE
    before samples, no duplicate families, counter ``_total`` suffix,
    histogram ``le`` ordering and ``_count`` agreement. Returns a list of
    problems (empty == clean)."""
    problems: list[str] = []
    typed: dict[str, str] = {}
    seen_order: list[str] = []
    hist_buckets: dict[tuple, list[float]] = {}
    hist_last: dict[tuple, float] = {}
    sample_counts: dict[str, int] = {}

    for lineno, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("# HELP ") or line.startswith("# TYPE "):
            parts = line.split(None, 3)
            if len(parts) < 3:
                problems.append(f"line {lineno}: malformed comment {line!r}")
                continue
            name = parts[2]
            if not _NAME_RE.match(name):
                problems.append(f"line {lineno}: bad metric name {name!r}")
            if line.startswith("# TYPE "):
                if name in typed:
                    problems.append(f"line {lineno}: duplicate family {name!r}")
                typed[name] = parts[3] if len(parts) > 3 else "untyped"
                seen_order.append(name)
            continue
        if line.startswith("#"):
            continue
        m = _SAMPLE_RE.match(line)
        if not m:
            problems.append(f"line {lineno}: unparsable sample {line!r}")
            continue
        sname = m.group("name")
        base = sname
        for suffix in ("_bucket", "_sum", "_count"):
            if sname.endswith(suffix) and sname[: -len(suffix)] in typed:
                base = sname[: -len(suffix)]
                break
        if base not in typed:
            problems.append(f"line {lineno}: sample {sname!r} precedes its TYPE")
            continue
        if seen_order and seen_order[-1] != base and base in seen_order[:-1]:
            problems.append(
                f"line {lineno}: family {base!r} samples are not contiguous"
            )
        sample_counts[base] = sample_counts.get(base, 0) + 1
        mtype = typed[base]
        if mtype == "counter" and not base.endswith("_total"):
            problems.append(f"counter {base!r} does not end in _total")
        if m.group("exemplar"):
            # OpenMetrics: exemplars are only valid on histogram buckets
            if mtype != "histogram" or not sname.endswith("_bucket"):
                problems.append(
                    f"line {lineno}: exemplar on non-histogram-bucket sample "
                    f"{sname!r}"
                )
            for pair in filter(None, (m.group("exlabels") or "").split(",")):
                if "=" not in pair:
                    problems.append(f"line {lineno}: malformed exemplar label {pair!r}")
                    continue
                ename, evalue = pair.split("=", 1)
                if not _LABEL_RE.match(ename):
                    problems.append(f"line {lineno}: bad exemplar label name {ename!r}")
                if not (evalue.startswith('"') and evalue.endswith('"')):
                    problems.append(
                        f"line {lineno}: exemplar label value not quoted {evalue!r}"
                    )
            try:
                float(m.group("exvalue"))
            except (TypeError, ValueError):
                problems.append(
                    f"line {lineno}: non-numeric exemplar value "
                    f"{m.group('exvalue')!r}"
                )
        try:
            value = float(m.group("value"))
        except ValueError:
            problems.append(f"line {lineno}: non-numeric value {m.group('value')!r}")
            continue
        if mtype == "histogram" and sname.endswith("_bucket"):
            labels = m.group("labels") or "{}"
            le_m = re.search(r'le="([^"]*)"', labels)
            if not le_m:
                problems.append(f"line {lineno}: histogram bucket without le")
                continue
            le = math.inf if le_m.group(1) == "+Inf" else float(le_m.group(1))
            series = (base, re.sub(r'le="[^"]*",?', "", labels))
            prev = hist_last.get(series)
            if prev is not None and value < prev:
                problems.append(
                    f"line {lineno}: histogram {base!r} cumulative count "
                    f"decreases at le={le_m.group(1)}"
                )
            hist_last[series] = value
            hist_buckets.setdefault(series, []).append(le)
    for (base, _), les in hist_buckets.items():
        if les and les[-1] != math.inf:
            problems.append(f"histogram {base!r} series missing +Inf bucket")
        if les != sorted(les):
            problems.append(f"histogram {base!r} buckets out of order")
    for name in typed:
        if sample_counts.get(name, 0) == 0 and typed[name] != "untyped":
            # empty families are allowed (declared, nothing observed yet)
            pass
    return problems
