"""Unified observability substrate: metrics, traces, structured logs,
profiling hooks.

Three dependency-free (stdlib-only) primitives every layer of the miner
records into, plus an opt-in profiler shim:

* :mod:`repro.obs.metrics` — the process-wide registry of counters, gauges
  and fixed-log-bucket histograms; rendered as Prometheus text on
  ``GET /metrics`` and snapshotted (under one lock — never torn) into
  ``/stats``.
* :mod:`repro.obs.trace` — contextvar-propagated span trees per request
  (``trace_id``/``span_id``/``parent_id``), threaded from the HTTP layer
  through the scheduler into the level/batch loop and the placement
  dispatch seams; last-N finished traces served by ``GET /trace``.
* :mod:`repro.obs.flight` — the black-box flight recorder: a bounded,
  CRC-framed on-disk event ring (span open/close, checkpoints, breaker
  transitions, config) parsed into a ``LastCrashReport`` on restart.
* :mod:`repro.obs.cost` — per-request ``CostEnvelope`` accumulation
  (rows scanned, candidate pairs, device bytes, compile-vs-reuse) attached
  to ``/mine`` responses and the slow-mine forensics log.
* :mod:`repro.obs.logs` — structured (optionally JSON) logging carrying the
  active ``trace_id``.
* :mod:`repro.obs.profile` — ``jax.profiler`` xplane wrapping + device
  gauges around a mine (imported lazily; everything else here must stay
  importable without jax).

Import discipline: this package is a **leaf** like ``core/exec_cache.py`` —
``repro.core``, the kernels packages and ``repro.service`` all import it,
so nothing in it may import from the rest of ``repro`` at module scope.
"""

from . import cost, flight, logs, metrics, trace
from .cost import CostEnvelope, SlowMineLog
from .flight import FlightRecorder, LastCrashReport
from .metrics import REGISTRY, counter, gauge, histogram, lint_exposition
from .trace import TRACER, current_trace_id, span, start_trace

__all__ = [
    "cost",
    "flight",
    "logs",
    "metrics",
    "trace",
    "CostEnvelope",
    "SlowMineLog",
    "FlightRecorder",
    "LastCrashReport",
    "REGISTRY",
    "TRACER",
    "counter",
    "gauge",
    "histogram",
    "lint_exposition",
    "current_trace_id",
    "span",
    "start_trace",
]
