"""Structured logging with trace correlation (stdlib only — leaf module).

``setup()`` configures the ``repro`` logger hierarchy once: plain
single-line text by default, JSON objects with ``--log-json`` — either way
every record carries the active trace id (``repro.obs.trace``), so an
access-log line, an error and the ``/trace`` span tree of one request all
join on ``trace_id``.

Extra structured fields ride on ``logging``'s ``extra=`` mechanism:

    log.info("access", extra={"route": "/mine", "code": 200, "ms": 12.3})
"""

from __future__ import annotations

import json
import logging
import sys
import time

from .trace import current_trace_id

__all__ = ["setup", "get_logger", "JsonFormatter", "TextFormatter"]

# logging.LogRecord's own attribute names — anything else on a record came
# in through ``extra=`` and belongs in the structured payload
_RESERVED = frozenset(
    logging.LogRecord("", 0, "", 0, "", (), None).__dict__
) | {"message", "asctime", "taskName"}


def _extras(record: logging.LogRecord) -> dict:
    return {
        k: v for k, v in record.__dict__.items()
        if k not in _RESERVED and not k.startswith("_")
    }


class JsonFormatter(logging.Formatter):
    def format(self, record: logging.LogRecord) -> str:
        out = {
            "ts": round(record.created, 6),
            "level": record.levelname.lower(),
            "logger": record.name,
            "msg": record.getMessage(),
        }
        trace_id = getattr(record, "trace_id", None) or current_trace_id()
        if trace_id:
            out["trace_id"] = trace_id
        out.update(_extras(record))
        if record.exc_info:
            out["exc"] = self.formatException(record.exc_info)
        return json.dumps(out, default=str)


class TextFormatter(logging.Formatter):
    def format(self, record: logging.LogRecord) -> str:
        ts = time.strftime("%H:%M:%S", time.localtime(record.created))
        trace_id = getattr(record, "trace_id", None) or current_trace_id()
        head = f"{ts} {record.levelname:<7} {record.name}: {record.getMessage()}"
        fields = _extras(record)
        if trace_id:
            fields = {"trace_id": trace_id, **fields}
        if fields:
            head += " " + " ".join(f"{k}={v}" for k, v in fields.items())
        if record.exc_info:
            head += "\n" + self.formatException(record.exc_info)
        return head


def setup(level: str = "info", json_mode: bool = False, stream=None) -> logging.Logger:
    """(Re)configure the ``repro`` root logger; returns it. Idempotent —
    repeat calls replace the handler (tests re-setup with StringIO)."""
    logger = logging.getLogger("repro")
    for h in list(logger.handlers):
        logger.removeHandler(h)
    handler = logging.StreamHandler(stream or sys.stderr)
    handler.setFormatter(JsonFormatter() if json_mode else TextFormatter())
    logger.addHandler(handler)
    logger.setLevel(getattr(logging, level.upper(), logging.INFO))
    logger.propagate = False
    return logger


def get_logger(name: str = "repro") -> logging.Logger:
    return logging.getLogger(name)
