"""Black-box flight recorder: crash-persistent telemetry (stdlib leaf).

The durability layer (PR 6) recovers the *data* after a crash; this module
recovers the *explanation*. A :class:`FlightRecorder` keeps a bounded,
CRC-framed, append-only event ring on disk under ``wal_dir/flight/``
recording span open/close (via a tracer listener), level checkpoints,
placement dispatch failures, breaker transitions, WAL/snapshot events and
the resolved config at startup. On restart, :func:`recover` parses the
previous incarnation's ring into a :class:`LastCrashReport` — which spans
were in flight at death, the last completed/checkpointed level, which
request keys were active — served at ``GET /debug/lastcrash``.

Frame format mirrors ``service/wal.py``'s discipline exactly:
``KFLT | crc32(payload) | len(payload) | payload`` with a JSON payload
(one event dict). Replay walks the longest valid prefix per segment; a
torn tail (power cut mid-flush) is detected by CRC/length and dropped,
never propagated.

Boundedness + crash-isolation come from **incarnation-numbered segment
pairs**: incarnation ``N`` writes ``inc<N>.a`` / ``inc<N>.b``, rotating to
the other segment (truncating it) whenever the active one exceeds
``max_bytes // 2`` — total disk use stays ~``max_bytes``. A new
incarnation unlinks its predecessors' files *after* recovery has parsed
them, so an abandoned (killed-but-not-reaped) recorder keeps writing to an
unlinked inode instead of corrupting the live ring.

Hot-path cost: :meth:`FlightRecorder.record` appends a dict to an
in-memory buffer under a lock — no I/O. A daemon thread flushes
(frame + write + fdatasync) every ``fsync_interval_s``; **durable** kinds
(checkpoints, config, shutdown) flush the whole buffer inline so the
events that matter for forensics are on disk the moment they happen,
carrying any buffered span-opens with them.
"""

from __future__ import annotations

import json
import os
import struct
import threading
import time
import zlib
from dataclasses import dataclass, field

from . import metrics as _om

__all__ = [
    "FlightRecorder",
    "LastCrashReport",
    "recover",
    "read_segment",
    "FLIGHT_SPANS",
    "DURABLE_KINDS",
]

MAGIC = b"KFLT"
_HEADER = struct.Struct("<4sII")  # magic, crc32(payload), len(payload)

# Span names worth persisting. Everything else (per-batch micro-spans,
# wal.append on the hot path) stays in-memory-only — the ring is a crash
# narrative, not a full trace store.
FLIGHT_SPANS = frozenset({
    "service.mine",
    "service.append",
    "mine.cold",
    "mine.incremental",
    "mine.preprocess",
    "mine.sample",
    "mine.refine",
    "mine.level",
    "mine.checkpoint",
    "store.recover",
    "store.snapshot",
})

# Kinds that flush the buffer inline (fsync before returning): the events a
# postmortem cannot afford to lose to a crash landing inside the cadence
# window.
DURABLE_KINDS = frozenset({
    "config",
    "job.checkpoint",
    "store.snapshot",
    "store.recover",
    "breaker.transition",
    "shutdown",
})

_EVENTS = _om.counter(
    "repro_flight_events_total", "Flight-recorder events recorded.",
    ("kind",),
)
_FLUSHES = _om.counter(
    "repro_flight_flushes_total", "Flight-recorder buffer flushes (fsync'd)."
)
_FLT_BYTES = _om.counter(
    "repro_flight_bytes_written_total",
    "Flight-ring bytes written (incl. frame headers).",
)
_ROTATIONS = _om.counter(
    "repro_flight_rotations_total", "Flight-ring segment rotations."
)
_RECOVERIES = _om.counter(
    "repro_flight_recoveries_total",
    "Flight rings parsed into a LastCrashReport on startup.",
)


def _json_safe(v):
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    if isinstance(v, (list, tuple)):
        return [_json_safe(x) for x in v]
    if isinstance(v, dict):
        return {str(k): _json_safe(x) for k, x in v.items()}
    try:  # numpy scalars and friends
        return v.item()
    except Exception:
        return str(v)


def _fdatasync(fh) -> None:
    fh.flush()
    try:
        os.fdatasync(fh.fileno())
    except (AttributeError, OSError):
        os.fsync(fh.fileno())


def _segment_name(incarnation: int, side: str) -> str:
    return f"inc{incarnation}.{side}"


def scan_incarnations(directory: str) -> list[int]:
    """Incarnation numbers present in ``directory``, ascending."""
    incs: set[int] = set()
    try:
        names = os.listdir(directory)
    except FileNotFoundError:
        return []
    for name in names:
        if name.startswith("inc") and name[-2:] in (".a", ".b"):
            try:
                incs.add(int(name[3:-2]))
            except ValueError:
                pass
    return sorted(incs)


def read_segment(path: str) -> tuple[list[dict], int]:
    """Decode the longest valid frame prefix of one segment file.

    Returns ``(events, torn_bytes)`` — a torn/corrupt tail is tolerated
    (it was mid-flush at death), counted, and everything before it kept.
    """
    try:
        with open(path, "rb") as f:
            data = f.read()
    except FileNotFoundError:
        return [], 0
    events: list[dict] = []
    off = 0
    good_end = 0
    while off + _HEADER.size <= len(data):
        magic, crc, length = _HEADER.unpack_from(data, off)
        body = data[off + _HEADER.size: off + _HEADER.size + length]
        if magic != MAGIC or len(body) < length or zlib.crc32(body) != crc:
            break
        try:
            ev = json.loads(body.decode("utf-8"))
        except Exception:
            break
        if isinstance(ev, dict):
            events.append(ev)
        off += _HEADER.size + length
        good_end = off
    return events, len(data) - good_end


@dataclass
class LastCrashReport:
    """What the previous incarnation was doing when it stopped."""

    incarnation: int
    clean_shutdown: bool
    started_at: float | None
    last_event_at: float | None
    n_events: int
    torn_bytes: int
    config: dict | None
    # spans opened but never closed — the work in flight at death
    open_spans: list[dict] = field(default_factory=list)
    # the last durably checkpointed mine level (kind=job.checkpoint)
    last_checkpoint: dict | None = None
    # the last mine.level span that *completed* before death
    last_completed_level: int | None = None
    # cache keys of service.mine spans still open at death
    active_request_keys: list = field(default_factory=list)
    # trailing non-span events (breaker trips, dispatch failures, ...)
    recent_events: list[dict] = field(default_factory=list)

    def to_dict(self) -> dict:
        return {
            "incarnation": self.incarnation,
            "clean_shutdown": self.clean_shutdown,
            "started_at": self.started_at,
            "last_event_at": self.last_event_at,
            "n_events": self.n_events,
            "torn_bytes": self.torn_bytes,
            "config": self.config,
            "open_spans": self.open_spans,
            "last_checkpoint": self.last_checkpoint,
            "last_completed_level": self.last_completed_level,
            "active_request_keys": self.active_request_keys,
            "recent_events": self.recent_events,
        }


def _build_report(incarnation: int, events: list[dict], torn: int) -> LastCrashReport:
    events = sorted(events, key=lambda e: e.get("seq", 0))
    opens: dict[str, dict] = {}
    config = None
    last_checkpoint = None
    last_completed_level = None
    clean = False
    recent: list[dict] = []
    for ev in events:
        kind = ev.get("kind")
        if kind == "span.open":
            opens[ev.get("span_id", "")] = ev
        elif kind == "span.close":
            closed = opens.pop(ev.get("span_id", ""), None)
            if closed is not None and closed.get("name") == "mine.level":
                k = closed.get("attrs", {}).get("k")
                if isinstance(k, int):
                    last_completed_level = k
        elif kind == "config":
            config = ev.get("config")
        elif kind == "job.checkpoint":
            last_checkpoint = {
                k: v for k, v in ev.items() if k not in ("kind", "seq")
            }
        elif kind == "shutdown":
            clean = True
        else:
            recent.append(ev)
    open_spans = [
        {
            "name": e.get("name"),
            "trace_id": e.get("trace_id"),
            "span_id": e.get("span_id"),
            "attrs": e.get("attrs", {}),
            "t": e.get("t"),
        }
        for e in sorted(opens.values(), key=lambda e: e.get("seq", 0))
    ]
    active_keys = []
    for e in open_spans:
        key = e["attrs"].get("key")
        if key is not None and key not in active_keys:
            active_keys.append(key)
    return LastCrashReport(
        incarnation=incarnation,
        clean_shutdown=clean and not open_spans,
        started_at=events[0].get("t") if events else None,
        last_event_at=events[-1].get("t") if events else None,
        n_events=len(events),
        torn_bytes=torn,
        config=config,
        open_spans=open_spans,
        last_checkpoint=last_checkpoint,
        last_completed_level=last_completed_level,
        active_request_keys=active_keys,
        recent_events=recent[-16:],
    )


def recover(directory: str) -> LastCrashReport | None:
    """Parse the newest previous incarnation's ring into a report.

    Returns ``None`` when no previous incarnation exists (first boot).
    Also persists the report as ``lastcrash.json`` beside the ring so a
    postmortem can read it even after the next incarnation rotates.
    """
    incs = scan_incarnations(directory)
    if not incs:
        return None
    inc = incs[-1]
    events: list[dict] = []
    torn = 0
    for side in ("a", "b"):
        evs, t = read_segment(os.path.join(directory, _segment_name(inc, side)))
        events.extend(evs)
        torn += t
    report = _build_report(inc, events, torn)
    _RECOVERIES.inc()
    try:
        with open(os.path.join(directory, "lastcrash.json"), "w") as f:
            json.dump(report.to_dict(), f, indent=1, default=str)
    except OSError:
        pass
    return report


class FlightRecorder:
    """Bounded on-disk event ring with batched fsync'd writes."""

    def __init__(
        self,
        directory: str,
        *,
        fsync_interval_s: float = 0.25,
        max_bytes: int = 1 << 20,
    ):
        self.directory = directory
        os.makedirs(directory, exist_ok=True)
        self.fsync_interval_s = max(0.01, float(fsync_interval_s))
        self.max_bytes = max(4096, int(max_bytes))
        incs = scan_incarnations(directory)
        self.incarnation = (incs[-1] + 1) if incs else 1
        self._lock = threading.Lock()
        self._buffer: list[dict] = []
        self._seq = 0
        self._side = "a"
        self._fh = open(self._segment_path("a"), "ab")
        # reap predecessors: recovery (if any) already parsed them, and an
        # abandoned recorder holding an fd keeps its unlinked inode alive
        # without touching our files
        for inc in incs:
            for side in ("a", "b"):
                try:
                    os.unlink(os.path.join(directory, _segment_name(inc, side)))
                except OSError:
                    pass
        self.events_recorded = 0
        self.flushes = 0
        self.bytes_written = 0
        self.rotations = 0
        self.flush_errors = 0
        self._halted = False
        self._closed = False
        self._wake = threading.Event()
        self._thread = threading.Thread(
            target=self._flush_loop, name="flight-flusher", daemon=True
        )
        self._thread.start()

    def _segment_path(self, side: str) -> str:
        return os.path.join(self.directory, _segment_name(self.incarnation, side))

    # -- recording -----------------------------------------------------------

    def record(self, kind: str, *, durable: bool | None = None, **fields) -> None:
        """Buffer one event. ``durable`` kinds (or ``durable=True``) flush
        the whole buffer inline — fsync'd before returning."""
        if self._closed or self._halted:
            return
        ev = {"kind": kind, "t": time.time()}
        for k, v in fields.items():
            ev[k] = _json_safe(v)
        flush_now = durable if durable is not None else kind in DURABLE_KINDS
        with self._lock:
            if self._closed or self._halted:
                return
            ev["seq"] = self._seq
            self._seq += 1
            self._buffer.append(ev)
            self.events_recorded += 1
            if flush_now:
                self._flush_locked()
        _EVENTS.inc(kind=kind)

    def span_listener(self, event: str, sp, trace) -> None:
        """Tracer listener (``Tracer.add_listener``): persist open/close of
        the spans that narrate a mine. Never raises."""
        name = sp.name
        if name not in FLIGHT_SPANS and not name.startswith("http "):
            return
        if event == "open":
            self.record(
                "span.open",
                name=name,
                trace_id=sp.trace_id,
                span_id=sp.span_id,
                parent_id=sp.parent_id,
                attrs=sp.attrs,
            )
        else:
            self.record(
                "span.close",
                name=name,
                trace_id=sp.trace_id,
                span_id=sp.span_id,
                duration_s=round(sp.duration, 6),
            )

    # -- flushing ------------------------------------------------------------

    def _flush_loop(self) -> None:
        while not self._wake.wait(self.fsync_interval_s):
            with self._lock:
                if self._closed or self._halted:
                    return
                if self._buffer:
                    self._flush_locked()

    def _flush_locked(self) -> None:
        if not self._buffer:
            return
        frames = []
        for ev in self._buffer:
            payload = json.dumps(
                ev, separators=(",", ":"), default=str
            ).encode("utf-8")
            frames.append(
                _HEADER.pack(MAGIC, zlib.crc32(payload), len(payload)) + payload
            )
        blob = b"".join(frames)
        self._buffer.clear()
        try:
            self._fh.write(blob)
            _fdatasync(self._fh)
            self.flushes += 1
            self.bytes_written += len(blob)
            if self._fh.tell() > self.max_bytes // 2:
                self._rotate_locked()
        except OSError:
            self.flush_errors += 1
            return
        _FLUSHES.inc()
        _FLT_BYTES.inc(len(blob))

    def _rotate_locked(self) -> None:
        self._fh.close()
        self._side = "b" if self._side == "a" else "a"
        path = self._segment_path(self._side)
        # truncate the segment we are rotating into — its events are the
        # oldest in the ring and give way to new ones (bounded total size)
        self._fh = open(path, "wb")
        self.rotations += 1
        _ROTATIONS.inc()

    def flush(self) -> None:
        with self._lock:
            if not (self._closed or self._halted):
                self._flush_locked()

    # -- lifecycle -----------------------------------------------------------

    def halt(self) -> None:
        """Simulate instant process death: discard the in-memory buffer and
        stop flushing, leaving only what already reached disk. Test seam —
        a Python 'kill' unwinds context managers (recording span closes a
        real crash never would), so chaos tests call this the moment the
        KillPoint propagates."""
        with self._lock:
            self._halted = True
            self._buffer.clear()
        self._wake.set()

    def close(self) -> None:
        """Orderly shutdown: record the terminal event, flush, stop."""
        if self._closed:
            return
        self.record("shutdown", durable=True)
        with self._lock:
            self._closed = True
        self._wake.set()
        self._thread.join(timeout=2.0)
        with self._lock:
            try:
                self._fh.close()
            except OSError:
                pass

    def stats(self) -> dict:
        with self._lock:
            return {
                "directory": self.directory,
                "incarnation": self.incarnation,
                "events_recorded": self.events_recorded,
                "buffered": len(self._buffer),
                "flushes": self.flushes,
                "bytes_written": self.bytes_written,
                "rotations": self.rotations,
                "flush_errors": self.flush_errors,
                "fsync_interval_s": self.fsync_interval_s,
                "max_bytes": self.max_bytes,
                "halted": self._halted,
                "closed": self._closed,
            }
