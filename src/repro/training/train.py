"""Train-step builders: GSPMD step (sharding-constraint driven) and the
manual-DP variant with int8-compressed gradient reduction.

``make_train_step`` returns a jittable ``(params, opt_state, batch) ->
(params, opt_state, metrics)`` closure; with a :class:`~repro.distributed.
sharding.Plan` it is jitted with explicit in/out shardings so the dry-run can
lower it on the production meshes. The data loop/checkpoint orchestration
lives in ``launch/train.py``.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..models.zoo import Model
from .optimizer import OptConfig, adamw_init, adamw_update

__all__ = ["make_train_step", "init_train_state", "make_compressed_dp_step"]


def init_train_state(model: Model, key, opt_cfg: OptConfig):
    params = model.init(key)
    return params, adamw_init(params)


def make_train_step(model: Model, opt_cfg: OptConfig, plan=None, grad_accum: int = 1,
                    cast_bf16: bool = True):
    """grad_accum > 1 scans over microbatches (leading batch split), summing
    grads — the standard activation-memory lever: peak activation temp
    scales ~1/grad_accum while FLOPs/collectives per token are unchanged.

    cast_bf16 casts matrix params to bf16 *before* the layer scan, so the
    ZeRO/FSDP per-layer weight all-gathers move half the bytes (the compute
    already ran in bf16 via per-use casts; this hoists the cast above the
    gather). Norms/scales (1-D) stay f32. Master weights, grads and AdamW
    moments remain f32."""
    ctx = plan.ctx() if plan is not None else None

    def loss_fn(p, batch):
        if cast_bf16:
            p = jax.tree.map(
                lambda a: a.astype(jnp.bfloat16)
                if (a.dtype == jnp.float32 and a.ndim >= 2) else a,
                p,
            )
        return model.train_loss(p, ctx, batch)

    def train_step(params, opt_state, batch):
        if grad_accum == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            micro = jax.tree.map(
                lambda a: a.reshape(grad_accum, a.shape[0] // grad_accum, *a.shape[1:]),
                batch,
            )

            def accum(carry, mb):
                loss_sum, grads = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                return (loss_sum + l, jax.tree.map(jnp.add, grads, g)), None

            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss_sum, gsum), _ = jax.lax.scan(accum, (jnp.float32(0.0), zero), micro)
            loss = loss_sum / grad_accum
            grads = jax.tree.map(lambda g: g / grad_accum, gsum)
        new_params, new_opt, metrics = adamw_update(grads, opt_state, params, opt_cfg)
        metrics = dict(metrics, loss=loss)
        return new_params, new_opt, metrics

    if plan is None:
        return jax.jit(train_step)

    def shardings_for(abstract_params):
        pspec = plan.param_shardings(abstract_params)
        ospec = {
            "m": pspec,
            "v": pspec,
            "step": plan.replicated(),
        }
        return pspec, ospec

    return train_step, shardings_for


def make_compressed_dp_step(model: Model, opt_cfg: OptConfig, mesh, dp_axes):
    """Manual-DP step: per-shard grads -> int8 stochastic-rounded psum ->
    identical AdamW update on every shard. Demonstrates the wire-compression
    path; numerics validated against the exact step in tests."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from .compression import dequantize_int8, quantize_int8

    n_dp = 1
    for a in dp_axes:
        n_dp *= mesh.shape[a]

    def step(params, opt_state, batch, key):
        def local(params, batch, key):
            loss, grads = jax.value_and_grad(
                lambda p: model.train_loss(p, None, batch)
            )(params)
            leaves, treedef = jax.tree.flatten(grads)
            keys = jax.random.split(key[0], len(leaves))
            reduced = []
            for g, k in zip(leaves, keys):
                q, scale = quantize_int8(g, k)
                scale = jax.lax.pmax(scale, dp_axes)
                q32 = jax.lax.psum(q.astype(jnp.int32), dp_axes)
                reduced.append((q32.astype(jnp.float32) * scale / n_dp).astype(g.dtype))
            grads = treedef.unflatten(reduced)
            loss = jax.lax.pmean(loss, dp_axes)
            return loss, grads

        pspec = jax.tree.map(lambda _: P(), params)
        bspec = jax.tree.map(lambda _: P(dp_axes), batch)
        loss, grads = shard_map(
            local,
            mesh=mesh,
            in_specs=(pspec, bspec, P(None)),
            out_specs=(P(), pspec),
            check_rep=False,
        )(params, batch, key[None])
        new_params, new_opt, metrics = adamw_update(grads, opt_state, params, opt_cfg)
        return new_params, new_opt, dict(metrics, loss=loss)

    return jax.jit(step)
